"""RPC transport overhead: tcp fabric vs inproc fabric at equal load.

The cross-host transport's acceptance number: the SAME request stream,
worker count, and engine configuration served once through in-process
``FabricWorker`` threads and once through ``WorkerEndpoint`` replicas over
localhost TCP.  The wire adds framing + a socket hop + a scheduler handoff
per request; it must NOT add a compile, a copy of the feature store, or a
convoy — so end-to-end p99 stays within 3x of inproc (in practice the
delta is microseconds of framing against milliseconds of compute).

Reported per transport: throughput, total/queue p99, and for tcp the
rpc-wait p99 split (wire + remote scheduling time per request) plus the
byte ledger both directions.  The 3x bound is asserted in-bench.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import emit, engine_config
from repro.gns import FabricConfig, GNSEngine, ServeConfig, TenantConfig
from repro.graph.datasets import get_dataset
from repro.rpc import WorkerEndpoint

REQ_IDS = 8
TENANTS = ("mobile", "batch")


def _cfg(fast: bool, seed: int = 0):
    cfg = engine_config("gns", batch_size=128 if fast else 512, seed=seed)
    return dataclasses.replace(cfg, serve=ServeConfig(
        buckets=(32, 128), max_wait_ms=2.0, max_queue=4096))


def _build(fast: bool, seed: int = 0) -> GNSEngine:
    ds = get_dataset("ogbn-products", scale=0.25 if fast else 1.0, seed=seed)
    return GNSEngine(_cfg(fast, seed), dataset=ds)


def _fabric_cfg(n_requests: int, **kw) -> FabricConfig:
    return FabricConfig(
        workers=2,
        tenants=tuple(TenantConfig(t, max_queue=2 * n_requests)
                      for t in TENANTS),
        # transport overhead is the subject; failover chaos is bench_fabric's
        stall_timeout_ms=600_000.0, **kw)


def _drive(fab, eng, n_requests: int):
    """Warm both workers' compiled paths, then time a mixed-tenant flood."""
    rng = np.random.default_rng(3)
    for widx, t in ((0, TENANTS[0]), (1, TENANTS[1])):
        fab.submit(eng.ds.val_idx[:REQ_IDS], tenant=t,
                   worker=widx).result(timeout=600)
    t0 = time.perf_counter()
    futs = [fab.submit(rng.choice(eng.ds.val_idx, size=REQ_IDS,
                                  replace=False),
                       tenant=TENANTS[i % len(TENANTS)])
            for i in range(n_requests)]
    for f in futs:
        res = f.result(timeout=600)
        assert res.status == "ok", res.status
    return time.perf_counter() - t0


def run(fast: bool = True) -> list:
    n_requests = 96 if fast else 512
    rows = []

    # -- inproc baseline ---------------------------------------------------
    eng = _build(fast)
    fab = eng.serve_fabric(_fabric_cfg(n_requests))
    with fab:
        wall = _drive(fab, eng, n_requests)
    snap = fab.meter.snapshot()
    rows.append({
        "transport": "inproc", "requests": n_requests, "wall_s": wall,
        "requests_per_s": n_requests / wall,
        "total_p99_ms": snap["total_p99_ms"],
        "queue_wait_p99_ms": snap["queue_wait_p99_ms"],
        "rpc_wait_p99_ms": 0.0, "bytes_rpc_tx": 0, "bytes_rpc_rx": 0,
        "errors": snap["errors"],
    })

    # -- tcp: endpoint replicas on localhost -------------------------------
    eps = [WorkerEndpoint(_build(fast), index=i, heartbeat_ms=100.0)
           for i in range(2)]
    try:
        for ep in eps:
            ep.serve_in_thread()
        eng = _build(fast)
        fab = eng.serve_fabric(_fabric_cfg(
            n_requests, transport="tcp",
            endpoints=tuple(f"127.0.0.1:{ep.port}" for ep in eps)))
        with fab:
            wall = _drive(fab, eng, n_requests)
        snap = fab.meter.snapshot()
        rpc = fab.rpc_traffic()
        rows.append({
            "transport": "tcp", "requests": n_requests, "wall_s": wall,
            "requests_per_s": n_requests / wall,
            "total_p99_ms": snap["total_p99_ms"],
            "queue_wait_p99_ms": snap["queue_wait_p99_ms"],
            "rpc_wait_p99_ms": snap.get("rpc_wait_p99_ms", 0.0),
            "bytes_rpc_tx": rpc["bytes_rpc_tx"],
            "bytes_rpc_rx": rpc["bytes_rpc_rx"],
            "errors": snap["errors"],
        })
    finally:
        for ep in eps:
            ep.stop()

    base, tcp = rows
    tcp["p99_vs_inproc"] = round(tcp["total_p99_ms"]
                                 / max(base["total_p99_ms"], 1e-9), 3)
    base["p99_vs_inproc"] = 1.0
    emit("rpc_overhead", rows,
         ["transport", "requests", "requests_per_s", "total_p99_ms",
          "p99_vs_inproc", "queue_wait_p99_ms", "rpc_wait_p99_ms",
          "bytes_rpc_tx", "bytes_rpc_rx", "errors"])
    # the acceptance: the wire costs < 3x p99 at equal load
    assert tcp["total_p99_ms"] <= 3.0 * base["total_p99_ms"], rows
    assert tcp["errors"] == 0 and base["errors"] == 0, rows
    assert tcp["bytes_rpc_tx"] > 0 and tcp["bytes_rpc_rx"] > 0, rows
    return rows


if __name__ == "__main__":
    run()

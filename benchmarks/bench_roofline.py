"""Roofline table from the dry-run artifacts (deliverable g).

Reads benchmarks/results/dryrun/*.json (written by launch/dryrun.py) and
emits the per-(arch x shape x mesh) three-term table + markdown for
EXPERIMENTS.md §Roofline.  Pure aggregation — no jax needed.
"""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results"
DRYRUN = RESULTS / "dryrun"

FIELDS = ["arch", "shape", "mesh", "dominant", "compute_s", "memory_s",
          "collective_s", "roofline_fraction", "useful_ratio"]


def load_cells() -> list:
    rows = []
    for p in sorted(DRYRUN.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("status") == "ok" and not r.get("roofline"):
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r.get("mesh", "?"), "status": "proof",
                         "compile_s": r.get("compile_s")})
            continue                      # multipod compile-proof cells
        if r.get("status") != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r.get("mesh", "?"),
                         "status": r.get("status"),
                         "reason": r.get("reason", "")[:60]})
            continue
        t = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": "ok", "dominant": t["dominant"],
            "compute_s": t["compute_s"], "memory_s": t["memory_s"],
            "collective_s": t["collective_s"],
            "roofline_fraction": t["roofline_fraction"],
            "useful_ratio": t["useful_ratio"],
            "params_total": r["params_total"],
            "arg_bytes_per_device": r.get("arg_bytes_per_device", 0.0),
            "grad_accum": r.get("grad_accum", 1),
            "probe_mode": r.get("probe_mode", ""),
        })
    return rows


def markdown_table(rows: list, mesh: str = "16x16") -> str:
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | dominant "
           "| frac | useful |\n|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if r.get("status") == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped |"
                       f" — | — |\n")
            continue
        if r.get("status") == "proof":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"compiles ({r.get('compile_s')}s) | — | — |\n")
            continue
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | FAILED |"
                       f" — | — |\n")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} "
            f"| {r['memory_s']:.4f} | {r['collective_s']:.4f} "
            f"| {r['dominant']} | {r['roofline_fraction']:.3f} "
            f"| {r['useful_ratio']:.2f} |\n")
    return "".join(out)


def run(fast: bool = True) -> list:
    rows = load_cells()
    ok = [r for r in rows if r.get("status") == "ok"]
    print(f"dry-run cells: {len(rows)} ({len(ok)} probed, "
          f"{sum(1 for r in rows if r.get('status') == 'proof')} compile-proof, "
          f"{sum(1 for r in rows if r.get('status') == 'skipped')} skipped)")
    print(",".join(FIELDS))
    for r in ok:
        print(",".join(f"{r.get(f):.4f}" if isinstance(r.get(f), float)
                       else str(r.get(f)) for f in FIELDS))
    (RESULTS / "roofline_table.md").write_text(
        "### single-pod 16x16\n\n" + markdown_table(rows, "16x16") +
        "\n### multi-pod 2x16x16\n\n" + markdown_table(rows, "2x16x16"))
    return rows


if __name__ == "__main__":
    run()

"""Streaming ingest: serve-while-mutating replay (the ROADMAP item 4 bench).

A 2-worker :class:`~repro.serve.ServeFabric` serves a Zipf-skewed request
stream from the ``stream_replay`` preset while a GDELT-shaped temporal
event stream (``repro.data.temporal``) is ingested live: staged deltas are
drained by the fabric watchdog into async generation builds, the atomic
swap publishes merged structure + features together, and serving never
pauses.

Measured per phase (warm / ingest / recovered), with three acceptance
gates:

* **hit-rate recovery** — the device-tier hit fraction in the recovered
  window returns to within 0.1 of the pre-ingest window (the adaptive
  policy + serving-driven refreshes re-converge onto the mutated graph);
* **post-update correctness** — new nodes answer queries with finite
  logits, inserted edges are present in the adopted CSR (spot-checked
  against the event log);
* **zero steady-state recompilation** — the jit cache is bitwise flat
  across every merge (the device table keeps its padded shape).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.data import temporal_event_stream
from repro.gns import EngineConfig, FabricConfig, GNSEngine


def _wait(pred, timeout=120.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(0.02)
    return False


def _burst(fab, rng, hot, pool, n, hot_share=0.9, req_ids=8):
    futs = []
    for _ in range(n):
        src = hot if rng.random() < hot_share else pool
        ids = rng.choice(src, size=req_ids, replace=False)
        futs.append(fab.submit(ids))
    bad = [f for f in futs if f.result(timeout=600).status != "ok"]
    assert not bad, f"{len(bad)} failed requests"


def _tier_window(meter):
    d = meter.traffic.tier("device")
    return d.hits, d.misses


def _window_hit_rate(before, after):
    h = after[0] - before[0]
    m = after[1] - before[1]
    return h / (h + m) if (h + m) else 0.0


def run(fast: bool = True) -> list:
    cfg = EngineConfig.preset("stream_replay")
    if fast:
        import dataclasses
        cfg = dataclasses.replace(
            cfg, data=dataclasses.replace(cfg.data, scale=0.1))
    eng = GNSEngine(cfg)
    ds = eng.ds
    v0 = ds.graph.num_nodes
    rng = np.random.default_rng(0)
    pool = ds.val_idx.astype(np.int64)
    hot = rng.choice(pool, size=max(len(pool) // 20, 16), replace=False)
    n_warm = 40 if fast else 200
    events = temporal_event_stream(
        ds, num_batches=3 if fast else 8,
        events_per_batch=64 if fast else 256,
        new_node_frac=0.1, seed=3)

    fab = eng.serve_fabric(FabricConfig(workers=2, watch_interval_ms=20.0))
    rows = []
    with fab:
        # ---- warm: converge the cache onto the hot set -------------------
        t0 = time.perf_counter()
        _burst(fab, rng, hot, pool, n_warm)
        compiled0 = eng.infer_step._cache_size()
        w0 = _tier_window(fab.meter)
        _burst(fab, rng, hot, pool, n_warm // 2)
        warm_hit = _window_hit_rate(w0, _tier_window(fab.meter))
        rows.append({"phase": "warm", "wall_s": time.perf_counter() - t0,
                     "hit_rate": warm_hit, "merges": 0, "num_nodes": v0,
                     "rows_migrated": 0})

        # ---- ingest: events staged under live traffic --------------------
        t0 = time.perf_counter()
        w0 = _tier_window(fab.meter)
        for ev in events:
            eng.ingest_events(ev)
            _burst(fab, rng, hot, pool, 8)
        assert _wait(lambda: eng.pending_deltas == 0), "deltas not drained"
        assert _wait(lambda: eng.store.merges_applied >= 1), "no merge"
        ingest_hit = _window_hit_rate(w0, _tier_window(fab.meter))
        rows.append({"phase": "ingest",
                     "wall_s": time.perf_counter() - t0,
                     "hit_rate": ingest_hit,
                     "merges": eng.store.merges_applied,
                     "num_nodes": ds.graph.num_nodes,
                     "rows_migrated": eng.store.rows_migrated})

        # ---- recovered: the policy re-draws onto the merged graph --------
        t0 = time.perf_counter()
        w0 = _tier_window(fab.meter)
        _burst(fab, rng, hot, pool, n_warm)
        rec_hit = _window_hit_rate(w0, _tier_window(fab.meter))
        rows.append({"phase": "recovered",
                     "wall_s": time.perf_counter() - t0,
                     "hit_rate": rec_hit,
                     "merges": eng.store.merges_applied,
                     "num_nodes": ds.graph.num_nodes,
                     "rows_migrated": eng.store.rows_migrated})

        # ---- acceptance --------------------------------------------------
        # post-update correctness: new node served, inserted edge adopted
        assert ds.graph.num_nodes == v0 + events.total_new_nodes
        out = fab.infer(np.array([v0], np.int64), timeout=600)
        assert np.isfinite(out).all(), "new node produced non-finite logits"
        ev0 = events[0]
        s, d = int(ev0.src[0]), int(ev0.dst[0])
        g = ds.graph
        assert d in g.indices[g.indptr[s]:g.indptr[s + 1]], \
            "ingested edge missing from merged CSR"
        recompiles = eng.infer_step._cache_size() - compiled0
        assert recompiles == 0, f"{recompiles} recompiles across merges"
        assert rec_hit >= warm_hit - 0.1, (warm_hit, rec_hit)

    for r in rows:
        r["recompiles"] = 0
        r["delta_bytes"] = eng.meter.bytes_delta_upload
    emit("stream_ingest", rows,
         ["phase", "wall_s", "hit_rate", "merges", "num_nodes",
          "rows_migrated", "recompiles", "delta_bytes"])
    return rows


if __name__ == "__main__":
    run()

"""Paper Fig. 1/2: runtime breakdown (sample / slice+copy / compute) per
sampler, and the byte-traffic ledger behind it."""
from __future__ import annotations

from benchmarks.common import emit, run_trainer

FIELDS = ["dataset", "sampler", "sample_s", "copy_s", "compute_s",
          "bytes_streamed_mb", "copy_share_pct"]


def run(fast: bool = True) -> list:
    datasets = ["ogbn-products"] if fast else ["ogbn-products", "oag-paper"]
    rows = []
    for ds in datasets:
        for sampler in ("ns", "gns"):
            r = run_trainer(ds, sampler, epochs=2, scale=0.15 if fast else 1.0)
            b = r["breakdown"]
            total = max(b["total_s"], 1e-9)
            rows.append({
                "dataset": ds, "sampler": sampler,
                "sample_s": b["sample_s"], "copy_s": b["copy_s"],
                "compute_s": b["compute_s"],
                "bytes_streamed_mb": b["bytes_streamed"] / 1e6,
                "copy_share_pct": 100.0 * b["copy_s"] / total,
            })
    return emit("fig1_breakdown", rows, FIELDS)


if __name__ == "__main__":
    run(fast=True)

"""Shared benchmark plumbing: dataset prep, engine runs, CSV/JSON output.

Every bench_* module builds its engines from ONE preset
(``EngineConfig.preset("bench_ci")``, re-exported here as :data:`CI_PRESET`)
via :func:`engine_for` — so the configuration a benchmark measures is by
construction the configuration training uses, and batch-size / cache-frac
defaults cannot drift between modules (the PR-4 bugfix: bench_throughput
and bench_cache_sensitivity used to re-declare subtly different
``SamplerConfig`` defaults).
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path

import numpy as np

from repro.featurestore import CacheConfig
from repro.gns import EngineConfig, GNSEngine
from repro.graph.datasets import get_dataset

RESULTS = Path(__file__).resolve().parent / "results"


# CI-scale note: the paper's |C| = 1%|V| regime relies on hub coverage that
# only materializes on million-node power-law graphs (hub degree ~sqrt(n)).
# At the 0.15x container scale we match the CACHE COVERAGE of the paper's 1%
# rather than the raw fraction (5% of a 9k-node graph covers the same edge
# share as 1% of the 2.4M-node original); `--full` uses the true 1%.
CI_PRESET = EngineConfig.preset("bench_ci")
CI_CACHE_FRACTION = CI_PRESET.cache.fraction


def engine_config(sampler: str, *, batch_size=None, cache_fraction=None,
                  cache_period=None, cache_strategy=None, cache_async=None,
                  layer_size=None, fanouts=None, backend=None, prefetch=None,
                  seed: int = 0) -> EngineConfig:
    """The bench_ci preset with explicit field overrides (None = preset)."""
    cfg = CI_PRESET
    cache = dataclasses.replace(
        cfg.cache,
        **{k: v for k, v in dict(
            fraction=cache_fraction, period=cache_period,
            strategy=cache_strategy, async_refresh=cache_async).items()
           if v is not None})
    sampling = dataclasses.replace(
        cfg.sampling,
        **{k: v for k, v in dict(batch_size=batch_size, layer_size=layer_size,
                                 fanouts=fanouts, backend=backend).items()
           if v is not None})
    top = {k: v for k, v in dict(prefetch=prefetch).items() if v is not None}
    return dataclasses.replace(cfg, sampler=sampler, sampling=sampling,
                               cache=cache, seed=seed, **top)


def run_trainer(dataset: str, sampler: str, *, epochs: int = 2,
                scale: float = 0.25, batch_size: int = None,
                cache_fraction: float = None, cache_period: int = None,
                cache_strategy: str = None, cache_async: bool = None,
                layer_size: int = None, fanouts=None, backend: str = None,
                prefetch: bool = None, seed: int = 0,
                eval_batches: int = 8, max_batches=None):
    ds = get_dataset(dataset, scale=scale, seed=seed)
    cfg = engine_config(sampler, batch_size=batch_size,
                        cache_fraction=cache_fraction,
                        cache_period=cache_period,
                        cache_strategy=cache_strategy,
                        cache_async=cache_async, layer_size=layer_size,
                        fanouts=fanouts, backend=backend, prefetch=prefetch,
                        seed=seed)
    eng = GNSEngine(cfg, dataset=ds)
    t0 = time.perf_counter()
    rep = eng.fit(epochs, max_batches=max_batches, eval_every=epochs,
                  eval_batches=eval_batches)
    wall = time.perf_counter() - t0
    return {
        "dataset": dataset, "sampler": sampler, "epochs": epochs,
        "backend": cfg.sampling.backend,
        "nodes": ds.graph.num_nodes, "edges": ds.graph.num_edges,
        "f1": rep.val_acc[-1] if rep.val_acc else float("nan"),
        "loss": rep.losses[-1],
        "epoch_time_s": float(np.mean(rep.epoch_times)),
        "wall_s": wall,
        "input_nodes_per_batch": rep.input_nodes_per_batch,
        "cached_nodes_per_batch": rep.cached_nodes_per_batch,
        "isolated_per_batch": rep.isolated_per_batch,
        "breakdown": eng.meter.breakdown(),
    }


def emit(name: str, rows: list, csv_fields: list):
    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / f"{name}.json"
    out.write_text(json.dumps(rows, indent=1))
    print(f"\n# {name} -> {out}")
    print(",".join(csv_fields))
    for r in rows:
        print(",".join(_fmt(r.get(f)) for f in csv_fields))
    return rows


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.4f}"
    return str(v)

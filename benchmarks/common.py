"""Shared benchmark plumbing: dataset prep, trainer runs, CSV/JSON output."""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.core.cache import CacheConfig
from repro.core.sampler import SamplerConfig
from repro.graph.datasets import get_dataset
from repro.train.trainer import GNNTrainer

RESULTS = Path(__file__).resolve().parent / "results"


# CI-scale note: the paper's |C| = 1%|V| regime relies on hub coverage that
# only materializes on million-node power-law graphs (hub degree ~sqrt(n)).
# At the 0.15x container scale we match the CACHE COVERAGE of the paper's 1%
# rather than the raw fraction (5% of a 9k-node graph covers the same edge
# share as 1% of the 2.4M-node original); `--full` uses the true 1%.
CI_CACHE_FRACTION = 0.05


def run_trainer(dataset: str, sampler: str, *, epochs: int = 2,
                scale: float = 0.25, batch_size: int = 512,
                cache_fraction: float = CI_CACHE_FRACTION, cache_period: int = 1,
                cache_strategy: str = "auto", cache_async: bool = False,
                layer_size: int = 512, fanouts=(5, 10, 15), seed: int = 0,
                eval_batches: int = 8, max_batches=None):
    ds = get_dataset(dataset, scale=scale, seed=seed)
    scfg = SamplerConfig(
        batch_size=batch_size, fanouts=fanouts,
        cache=CacheConfig(fraction=cache_fraction, period=cache_period,
                          strategy=cache_strategy, async_refresh=cache_async),
        layer_size=layer_size)
    tr = GNNTrainer(ds, sampler, sampler_cfg=scfg, seed=seed)
    t0 = time.perf_counter()
    rep = tr.train(epochs, max_batches=max_batches, eval_every=epochs,
                   eval_batches=eval_batches)
    wall = time.perf_counter() - t0
    return {
        "dataset": dataset, "sampler": sampler, "epochs": epochs,
        "nodes": ds.graph.num_nodes, "edges": ds.graph.num_edges,
        "f1": rep.val_acc[-1] if rep.val_acc else float("nan"),
        "loss": rep.losses[-1],
        "epoch_time_s": float(np.mean(rep.epoch_times)),
        "wall_s": wall,
        "input_nodes_per_batch": rep.input_nodes_per_batch,
        "cached_nodes_per_batch": rep.cached_nodes_per_batch,
        "isolated_per_batch": rep.isolated_per_batch,
        "breakdown": tr.meter.breakdown(),
    }


def emit(name: str, rows: list, csv_fields: list):
    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / f"{name}.json"
    out.write_text(json.dumps(rows, indent=1))
    print(f"\n# {name} -> {out}")
    print(",".join(csv_fields))
    for r in rows:
        print(",".join(_fmt(r.get(f)) for f in csv_fields))
    return rows


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.4f}"
    return str(v)

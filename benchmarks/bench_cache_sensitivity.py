"""Paper Table 6: GNS F1 vs cache size x refresh period P — plus a cache
*policy* sweep (degree / random_walk / reverse_pagerank / adaptive / uniform)
reporting per-policy hit-rate and bytes_streamed on a synthetic power-law
graph (the regime where admission policy matters: hub coverage) — plus the
shard-aware refresh upload measurement (``run_sharded_upload``): per-
generation device-upload bytes with the table row-sharded over an n-device
mesh vs the replicated baseline (expected ratio 1/n) — plus the
locality-placement measurement (``run_locality``): cross-shard lookup
traffic under skewed per-DP-group demand, contiguous blocks vs the
locality-aware placement map (acceptance: local-hit fraction > 0.5 with
bitwise-identical gathers)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, run_trainer

FIELDS = ["cache_fraction", "period", "f1"]
POLICY_FIELDS = ["policy", "hit_rate", "bytes_streamed", "bytes_cache_fill",
                 "input_nodes_per_batch"]
SHARD_FIELDS = ["n_devices", "n_shards", "cache_rows",
                "upload_bytes_per_gen_sharded",
                "upload_bytes_per_gen_replicated", "upload_ratio"]
LOCALITY_FIELDS = ["placement", "n_shards", "n_groups", "local_hit_fraction",
                   "lanes_local", "lanes_remote", "bytes_cross_shard",
                   "hit_rate", "fast_path_batches", "total_batches",
                   "bitwise_equal_vs_contiguous"]

POLICY_SWEEP = ["degree", "random_walk", "reverse_pagerank", "adaptive",
                "uniform"]


def run(fast: bool = True) -> list:
    fractions = [0.05, 0.01] if fast else [0.01, 0.001, 0.0001]
    periods = [1, 5] if fast else [1, 2, 5, 10]
    epochs = 3 if fast else 10
    rows = []
    for frac in fractions:
        for p in periods:
            r = run_trainer("ogbn-products", "gns", epochs=epochs,
                            scale=0.15 if fast else 1.0,
                            cache_fraction=frac, cache_period=p)
            rows.append({"cache_fraction": frac, "period": p, "f1": r["f1"]})
    return emit("table6_cache_sensitivity", rows, FIELDS)


def run_policies(fast: bool = True, nodes: int = 6000, avg_degree: int = 10,
                 cache_fraction: float = None, epochs: int = 3,
                 seed: int = 0) -> list:
    """Sampling-only policy sweep on a power-law graph.

    Measures what the policy alone controls — device-cache hit-rate and
    streamed bytes — by driving the GNS sampler through the FeatureStore
    for a few epochs per policy (the adaptive policy needs the miss
    feedback loop, hence >1 epoch).  The sampler/cache config derives from
    the shared ``bench_ci`` preset (``benchmarks.common.engine_config``) —
    only the knobs this sweep is ABOUT (policy, and the smaller batch/
    fanouts the synthetic graph needs) are overridden, so the measured
    cache fraction is the one every trained benchmark uses.
    """
    from benchmarks.common import engine_config
    from repro.core.pipeline import EpochLoader
    from repro.core.sampler import GNSSampler
    from repro.graph.generate import powerlaw_graph

    if not fast:
        nodes, epochs = 30_000, 5
    g = powerlaw_graph(nodes, avg_degree=avg_degree, seed=seed)
    rng = np.random.default_rng(seed)
    feats = rng.standard_normal((g.num_nodes, 32)).astype(np.float32)
    labels = np.zeros(g.num_nodes, np.int32)
    train = np.sort(rng.choice(g.num_nodes, size=max(nodes // 5, 200),
                               replace=False).astype(np.int64))

    rows = []
    batch_size = 128        # the 6k-node synthetic graph wants small batches
    for policy in POLICY_SWEEP:
        ecfg = engine_config("gns", batch_size=batch_size, fanouts=(5, 10),
                             cache_fraction=cache_fraction,
                             cache_strategy=policy, seed=seed)
        cfg = ecfg.sampler_config()
        s = GNSSampler(g, cfg, feats, labels, train_idx=train)
        loader = EpochLoader(s, train, seed=seed)
        cached = inputs = streamed = 0
        for ep in range(epochs):
            for mb in loader.epoch(ep):
                cached += mb.num_cached
                inputs += mb.num_input
                streamed += mb.bytes_streamed
        m = s.store.meter
        n_batches = epochs * (len(train) // batch_size)
        rows.append({
            "policy": policy,
            "hit_rate": cached / max(inputs, 1),
            "bytes_streamed": streamed,
            "bytes_cache_fill": m.bytes_cache_fill,
            "input_nodes_per_batch": inputs / max(n_batches, 1),
        })
    return emit("cache_policy_sweep", rows, POLICY_FIELDS)


def run_sharded_upload(fast: bool = True, nodes: int = 6000,
                       feat_dim: int = 64, cache_fraction: float = 0.05,
                       refreshes: int = 3, seed: int = 0) -> list:
    """Per-generation refresh upload bytes: shard-aware vs replicated.

    Builds two feature stores over every device this process exposes (run
    under ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to mock an
    N-device mesh): one with the generation table row-sharded over a 1-D
    mesh — each device receives only its own rows — and one replicating the
    table to every device (the pre-sharding behavior).  The acceptance
    number is ``upload_ratio`` ~ 1/n_devices.
    """
    import jax
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.featurestore import CacheConfig, FeatureStore
    from repro.graph.generate import powerlaw_graph

    if not fast:
        nodes, refreshes = 30_000, 5
    devs = jax.devices()
    mesh = Mesh(np.asarray(devs), ("data",))
    g = powerlaw_graph(nodes, avg_degree=10, seed=seed)
    rng = np.random.default_rng(seed)
    feats = rng.standard_normal((g.num_nodes, feat_dim)).astype(np.float32)
    # identical shard-padded table rows for BOTH stores, so the emitted
    # ratio is exactly 1/n even when n does not divide the raw |C|
    cfg = CacheConfig(fraction=cache_fraction, shards=len(devs))

    def refresh_bytes(store):
        for v in range(refreshes):
            store.refresh(np.random.default_rng(seed + v), version=v)
        return store.meter.bytes_cache_upload // refreshes

    sharded = FeatureStore(feats, g, cfg, mesh=mesh, shard_axis="data")
    replicated = FeatureStore(feats, g, cfg,
                              sharding=NamedSharding(mesh, P()))
    up_sh = refresh_bytes(sharded)
    up_re = refresh_bytes(replicated)
    rows = [{
        "n_devices": len(devs),
        "n_shards": sharded.n_shards,
        "cache_rows": sharded.size,
        "upload_bytes_per_gen_sharded": up_sh,
        "upload_bytes_per_gen_replicated": up_re,
        "upload_ratio": up_sh / max(up_re, 1),
    }]
    return emit("sharded_upload", rows, SHARD_FIELDS)


def run_locality(fast: bool = True, nodes: int = 6000, feat_dim: int = 32,
                 n_shards: int = 4, n_groups: int = 4,
                 cache_fraction: float = 0.05, epochs: int = 2,
                 batch: int = 96, seed: int = 0) -> list:
    """Cross-shard lookup traffic: contiguous blocks vs locality placement.

    Skewed per-DP-group demand (each group mostly requests its own hot node
    set, the regime of Data Tiering, arXiv:2111.05894) drives two stores
    that draw IDENTICAL cache generations (same stateless policy, same
    seeds) and differ only in shard placement.  Measured per placement:

    * ``local_hit_fraction`` — cache hits served by the requesting group's
      home shard (meter ``lanes_local/remote``); contiguous lands near
      1/n_shards, locality must clear 0.5 (the PR acceptance number);
    * ``fast_path_batches`` — batches whose hits were ALL local, i.e. would
      take the fused kernel's psum-free fast path;
    * ``bitwise_equal_vs_contiguous`` — the assembled h0 rows (device-table
      gather + streamed) of every measured batch agree bit-for-bit between
      the two placements, so the permutation is traffic-only.
    """
    from repro.featurestore import CacheConfig, FeatureStore
    from repro.graph.generate import powerlaw_graph

    if not fast:
        nodes, epochs = 30_000, 4
    g = powerlaw_graph(nodes, avg_degree=10, seed=seed)
    rng = np.random.default_rng(seed)
    feats = rng.integers(-64, 65, (g.num_nodes, feat_dim)).astype(np.float32)

    def build(placement):
        cfg = CacheConfig(fraction=cache_fraction, shards=n_shards,
                          strategy="degree", placement=placement)
        return FeatureStore(feats, g, cfg, importance_mode=None)

    stores = {p: build(p) for p in ("contiguous", "locality")}
    for st in stores.values():
        st.refresh(np.random.default_rng(seed + 1), version=0)
    any_gen = next(iter(stores.values())).generation
    # each group's hot set: a disjoint subset of the (shared) cached ids,
    # small enough to fit its home shard's capacity, SCATTERED across the
    # slot space — under contiguous placement a group's hot slots therefore
    # spread over all shards (local fraction ~ 1/n_shards), which is exactly
    # the cross-shard traffic the locality placement removes
    per = min(any_gen.state.rows_per_shard - 2,
              any_gen.state.size // n_groups)
    cached_ids = np.random.default_rng(seed + 3).permutation(
        any_gen.state.node_ids)
    hot = {grp: cached_ids[grp * per:(grp + 1) * per] for grp in range(n_groups)}

    def epoch_traffic(st, measure=False):
        """One epoch of skewed traffic; optionally collect (batch, h0)."""
        out = []
        r = np.random.default_rng(seed + 7)
        gen = st.generation
        for grp in range(n_groups):
            for _ in range(4):
                own = r.choice(hot[grp], min(batch * 3 // 4, len(hot[grp])),
                               replace=False)
                rand = r.choice(g.num_nodes, batch - len(own), replace=False)
                ids = np.concatenate([own, rand.astype(np.int64)])
                slots, streamed, hits, _, local = st.assemble_input(
                    gen, ids, len(ids), group=grp)
                if measure:
                    tbl = np.asarray(gen.table)
                    h0 = np.where(slots[:, None] >= 0,
                                  tbl[np.clip(slots, 0, None)], streamed)
                    out.append((h0, local))
        return out

    results, h0s = {}, {}
    for name, st in stores.items():
        epoch_traffic(st)                       # learn the demand
        st.meter.lanes_local = st.meter.lanes_remote = 0
        st.meter.bytes_cross_shard = 0
        st.refresh(np.random.default_rng(seed + 2), version=1)
        measured = []                           # every post-refresh epoch
        for _ in range(max(epochs - 1, 1)):
            measured.extend(epoch_traffic(st, measure=True))
        m = st.meter
        dev = m.tier("device")
        h0s[name] = [h for h, _ in measured]
        results[name] = {
            "placement": name, "n_shards": n_shards, "n_groups": n_groups,
            "local_hit_fraction": round(m.local_hit_fraction, 4),
            "lanes_local": m.lanes_local, "lanes_remote": m.lanes_remote,
            "bytes_cross_shard": m.bytes_cross_shard,
            "hit_rate": round(dev.hit_rate, 4),
            "fast_path_batches": sum(l is not None for _, l in measured),
            "total_batches": len(measured),
        }
    # both stores drew the same generations -> identical resolved rows
    bitwise = all(
        (a == b).all() for a, b in zip(h0s["contiguous"], h0s["locality"]))
    for rec in results.values():
        rec["bitwise_equal_vs_contiguous"] = bitwise
    return emit("locality_placement", list(results.values()), LOCALITY_FIELDS)


if __name__ == "__main__":
    run_sharded_upload(fast=True)
    run_locality(fast=True)
    run_policies(fast=True)
    run(fast=True)

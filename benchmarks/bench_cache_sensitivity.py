"""Paper Table 6: GNS F1 vs cache size x refresh period P — plus a cache
*policy* sweep (degree / random_walk / reverse_pagerank / adaptive / uniform)
reporting per-policy hit-rate and bytes_streamed on a synthetic power-law
graph (the regime where admission policy matters: hub coverage)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, run_trainer

FIELDS = ["cache_fraction", "period", "f1"]
POLICY_FIELDS = ["policy", "hit_rate", "bytes_streamed", "bytes_cache_fill",
                 "input_nodes_per_batch"]

POLICY_SWEEP = ["degree", "random_walk", "reverse_pagerank", "adaptive",
                "uniform"]


def run(fast: bool = True) -> list:
    fractions = [0.05, 0.01] if fast else [0.01, 0.001, 0.0001]
    periods = [1, 5] if fast else [1, 2, 5, 10]
    epochs = 3 if fast else 10
    rows = []
    for frac in fractions:
        for p in periods:
            r = run_trainer("ogbn-products", "gns", epochs=epochs,
                            scale=0.15 if fast else 1.0,
                            cache_fraction=frac, cache_period=p)
            rows.append({"cache_fraction": frac, "period": p, "f1": r["f1"]})
    return emit("table6_cache_sensitivity", rows, FIELDS)


def run_policies(fast: bool = True, nodes: int = 6000, avg_degree: int = 10,
                 cache_fraction: float = 0.05, epochs: int = 3,
                 seed: int = 0) -> list:
    """Sampling-only policy sweep on a power-law graph.

    Measures what the policy alone controls — device-cache hit-rate and
    streamed bytes — by driving the GNS sampler through the FeatureStore
    for a few epochs per policy (the adaptive policy needs the miss
    feedback loop, hence >1 epoch).
    """
    from repro.core.cache import CacheConfig
    from repro.core.pipeline import EpochLoader
    from repro.core.sampler import GNSSampler, SamplerConfig
    from repro.graph.generate import powerlaw_graph

    if not fast:
        nodes, epochs = 30_000, 5
    g = powerlaw_graph(nodes, avg_degree=avg_degree, seed=seed)
    rng = np.random.default_rng(seed)
    feats = rng.standard_normal((g.num_nodes, 32)).astype(np.float32)
    labels = np.zeros(g.num_nodes, np.int32)
    train = np.sort(rng.choice(g.num_nodes, size=max(nodes // 5, 200),
                               replace=False).astype(np.int64))

    rows = []
    batch_size = 128
    for policy in POLICY_SWEEP:
        cfg = SamplerConfig(fanouts=(5, 10), batch_size=batch_size,
                            cache=CacheConfig(fraction=cache_fraction,
                                              period=1, strategy=policy))
        s = GNSSampler(g, cfg, feats, labels, train_idx=train)
        loader = EpochLoader(s, train, seed=seed)
        cached = inputs = streamed = 0
        for ep in range(epochs):
            for mb in loader.epoch(ep):
                cached += mb.num_cached
                inputs += mb.num_input
                streamed += mb.bytes_streamed
        m = s.store.meter
        n_batches = epochs * (len(train) // batch_size)
        rows.append({
            "policy": policy,
            "hit_rate": cached / max(inputs, 1),
            "bytes_streamed": streamed,
            "bytes_cache_fill": m.bytes_cache_fill,
            "input_nodes_per_batch": inputs / max(n_batches, 1),
        })
    return emit("cache_policy_sweep", rows, POLICY_FIELDS)


if __name__ == "__main__":
    run_policies(fast=True)
    run(fast=True)

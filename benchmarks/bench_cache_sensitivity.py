"""Paper Table 6: GNS F1 vs cache size x refresh period P — plus a cache
*policy* sweep (degree / random_walk / reverse_pagerank / adaptive / uniform)
reporting per-policy hit-rate and bytes_streamed on a synthetic power-law
graph (the regime where admission policy matters: hub coverage) — plus the
shard-aware refresh upload measurement (``run_sharded_upload``): per-
generation device-upload bytes with the table row-sharded over an n-device
mesh vs the replicated baseline (expected ratio 1/n)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, run_trainer

FIELDS = ["cache_fraction", "period", "f1"]
POLICY_FIELDS = ["policy", "hit_rate", "bytes_streamed", "bytes_cache_fill",
                 "input_nodes_per_batch"]
SHARD_FIELDS = ["n_devices", "n_shards", "cache_rows",
                "upload_bytes_per_gen_sharded",
                "upload_bytes_per_gen_replicated", "upload_ratio"]

POLICY_SWEEP = ["degree", "random_walk", "reverse_pagerank", "adaptive",
                "uniform"]


def run(fast: bool = True) -> list:
    fractions = [0.05, 0.01] if fast else [0.01, 0.001, 0.0001]
    periods = [1, 5] if fast else [1, 2, 5, 10]
    epochs = 3 if fast else 10
    rows = []
    for frac in fractions:
        for p in periods:
            r = run_trainer("ogbn-products", "gns", epochs=epochs,
                            scale=0.15 if fast else 1.0,
                            cache_fraction=frac, cache_period=p)
            rows.append({"cache_fraction": frac, "period": p, "f1": r["f1"]})
    return emit("table6_cache_sensitivity", rows, FIELDS)


def run_policies(fast: bool = True, nodes: int = 6000, avg_degree: int = 10,
                 cache_fraction: float = 0.05, epochs: int = 3,
                 seed: int = 0) -> list:
    """Sampling-only policy sweep on a power-law graph.

    Measures what the policy alone controls — device-cache hit-rate and
    streamed bytes — by driving the GNS sampler through the FeatureStore
    for a few epochs per policy (the adaptive policy needs the miss
    feedback loop, hence >1 epoch).
    """
    from repro.core.cache import CacheConfig
    from repro.core.pipeline import EpochLoader
    from repro.core.sampler import GNSSampler, SamplerConfig
    from repro.graph.generate import powerlaw_graph

    if not fast:
        nodes, epochs = 30_000, 5
    g = powerlaw_graph(nodes, avg_degree=avg_degree, seed=seed)
    rng = np.random.default_rng(seed)
    feats = rng.standard_normal((g.num_nodes, 32)).astype(np.float32)
    labels = np.zeros(g.num_nodes, np.int32)
    train = np.sort(rng.choice(g.num_nodes, size=max(nodes // 5, 200),
                               replace=False).astype(np.int64))

    rows = []
    batch_size = 128
    for policy in POLICY_SWEEP:
        cfg = SamplerConfig(fanouts=(5, 10), batch_size=batch_size,
                            cache=CacheConfig(fraction=cache_fraction,
                                              period=1, strategy=policy))
        s = GNSSampler(g, cfg, feats, labels, train_idx=train)
        loader = EpochLoader(s, train, seed=seed)
        cached = inputs = streamed = 0
        for ep in range(epochs):
            for mb in loader.epoch(ep):
                cached += mb.num_cached
                inputs += mb.num_input
                streamed += mb.bytes_streamed
        m = s.store.meter
        n_batches = epochs * (len(train) // batch_size)
        rows.append({
            "policy": policy,
            "hit_rate": cached / max(inputs, 1),
            "bytes_streamed": streamed,
            "bytes_cache_fill": m.bytes_cache_fill,
            "input_nodes_per_batch": inputs / max(n_batches, 1),
        })
    return emit("cache_policy_sweep", rows, POLICY_FIELDS)


def run_sharded_upload(fast: bool = True, nodes: int = 6000,
                       feat_dim: int = 64, cache_fraction: float = 0.05,
                       refreshes: int = 3, seed: int = 0) -> list:
    """Per-generation refresh upload bytes: shard-aware vs replicated.

    Builds two feature stores over every device this process exposes (run
    under ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to mock an
    N-device mesh): one with the generation table row-sharded over a 1-D
    mesh — each device receives only its own rows — and one replicating the
    table to every device (the pre-sharding behavior).  The acceptance
    number is ``upload_ratio`` ~ 1/n_devices.
    """
    import jax
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.core.cache import CacheConfig
    from repro.featurestore import FeatureStore
    from repro.graph.generate import powerlaw_graph

    if not fast:
        nodes, refreshes = 30_000, 5
    devs = jax.devices()
    mesh = Mesh(np.asarray(devs), ("data",))
    g = powerlaw_graph(nodes, avg_degree=10, seed=seed)
    rng = np.random.default_rng(seed)
    feats = rng.standard_normal((g.num_nodes, feat_dim)).astype(np.float32)
    # identical shard-padded table rows for BOTH stores, so the emitted
    # ratio is exactly 1/n even when n does not divide the raw |C|
    cfg = CacheConfig(fraction=cache_fraction, shards=len(devs))

    def refresh_bytes(store):
        for v in range(refreshes):
            store.refresh(np.random.default_rng(seed + v), version=v)
        return store.meter.bytes_cache_upload // refreshes

    sharded = FeatureStore(feats, g, cfg, mesh=mesh, shard_axis="data")
    replicated = FeatureStore(feats, g, cfg,
                              sharding=NamedSharding(mesh, P()))
    up_sh = refresh_bytes(sharded)
    up_re = refresh_bytes(replicated)
    rows = [{
        "n_devices": len(devs),
        "n_shards": sharded.n_shards,
        "cache_rows": sharded.size,
        "upload_bytes_per_gen_sharded": up_sh,
        "upload_bytes_per_gen_replicated": up_re,
        "upload_ratio": up_sh / max(up_re, 1),
    }]
    return emit("sharded_upload", rows, SHARD_FIELDS)


if __name__ == "__main__":
    run_sharded_upload(fast=True)
    run_policies(fast=True)
    run(fast=True)

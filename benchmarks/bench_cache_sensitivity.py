"""Paper Table 6: GNS F1 vs cache size x refresh period P."""
from __future__ import annotations

from benchmarks.common import emit, run_trainer

FIELDS = ["cache_fraction", "period", "f1"]


def run(fast: bool = True) -> list:
    fractions = [0.05, 0.01] if fast else [0.01, 0.001, 0.0001]
    periods = [1, 5] if fast else [1, 2, 5, 10]
    epochs = 3 if fast else 10
    rows = []
    for frac in fractions:
        for p in periods:
            r = run_trainer("ogbn-products", "gns", epochs=epochs,
                            scale=0.15 if fast else 1.0,
                            cache_fraction=frac, cache_period=p)
            rows.append({"cache_fraction": frac, "period": p, "f1": r["f1"]})
    return emit("table6_cache_sensitivity", rows, FIELDS)


if __name__ == "__main__":
    run(fast=True)

"""Multi-tenant serve fabric: fairness, isolation, placement-aware routing.

Three measurements (the fabric PR's acceptance numbers):

* :func:`run_fairness` — p99 total latency across a (tenants x workers)
  grid at CONSTANT total load: the fabric's weighted-fair scheduling must
  keep multi-tenant p99 within 2x the single-tenant baseline at the same
  worker count (tenancy adds scheduling, not convoying).
* :func:`run_isolation` — a flooding tenant (tiny quota, oversubscribed)
  next to a quiet tenant: the flood collects its OWN QueueFull while the
  quiet tenant sees zero rejections and a bounded p99 — per-tenant
  admission means one tenant's burst never becomes everyone's backpressure.
* :func:`run_routing` — skewed disjoint per-tenant hot sets on a 2x2
  sharded mesh (needs >= 4 devices; run under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=4``, else skipped):
  the placement-derived routing table sends the majority of owned ids to
  the worker whose home shard owns them (route_local_fraction > 0.5).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import emit, engine_config
from repro.gns import FabricConfig, GNSEngine, ServeConfig, TenantConfig
from repro.graph.datasets import get_dataset
from repro.serve import QueueFull

REQ_IDS = 8                       # ids per request (a user-page fetch)


def _build(fast: bool, seed: int = 0) -> GNSEngine:
    scale = 0.25 if fast else 1.0
    ds = get_dataset("ogbn-products", scale=scale, seed=seed)
    cfg = engine_config("gns", batch_size=128 if fast else 512, seed=seed)
    cfg = dataclasses.replace(cfg, serve=ServeConfig(
        buckets=(32, 128), max_wait_ms=2.0, max_queue=4096))
    return GNSEngine(cfg, dataset=ds)


def _stream(fab, eng, tenants, n_requests, rng):
    """Submit a fixed total load round-robin across tenants, await all."""
    futs = []
    for i in range(n_requests):
        ids = rng.choice(eng.ds.val_idx, size=REQ_IDS, replace=False)
        futs.append(fab.submit(ids, tenant=tenants[i % len(tenants)]))
    for f in futs:
        f.result(timeout=600)


# ---------------------------------------------------------------------------
def run_fairness(fast: bool = True) -> list:
    """p99 vs (tenants x workers) at constant total load."""
    n_requests = 96 if fast else 512
    grid = [(1, 1), (2, 1), (2, 2), (4, 2)]
    rows = []
    for n_tenants, n_workers in grid:
        eng = _build(fast)
        tenants = [f"tenant{i}" for i in range(n_tenants)]
        fab = eng.serve_fabric(FabricConfig(
            workers=n_workers,
            tenants=tuple(TenantConfig(t, max_queue=n_requests)
                          for t in tenants)))
        rng = np.random.default_rng(0)
        with fab:
            # warm every worker's compiled path before timing
            for t in tenants:
                fab.infer(eng.ds.val_idx[:REQ_IDS], tenant=t, timeout=600)
            t0 = time.perf_counter()
            _stream(fab, eng, tenants, n_requests, rng)
            wall = time.perf_counter() - t0
        snap = fab.meter.snapshot()
        rows.append({
            "tenants": n_tenants, "workers": n_workers,
            "requests": n_requests, "wall_s": wall,
            "requests_per_s": n_requests / wall,
            "batches": snap["batches"],
            "fill_fraction": snap["fill_fraction"],
            "queue_wait_p99_ms": snap["queue_wait_p99_ms"],
            "total_p99_ms": snap["total_p99_ms"],
            "rejected": snap["rejected"],
        })
    base = next(r for r in rows if r["tenants"] == 1 and r["workers"] == 1)
    for r in rows:
        r["p99_vs_single"] = round(r["total_p99_ms"] / base["total_p99_ms"], 3)
    emit("fabric_fairness", rows,
         ["tenants", "workers", "requests", "requests_per_s",
          "total_p99_ms", "p99_vs_single", "queue_wait_p99_ms",
          "fill_fraction", "rejected"])
    # the acceptance: tenancy at matched worker count costs < 2x p99
    multi = next(r for r in rows if (r["tenants"], r["workers"]) == (4, 2))
    two = next(r for r in rows if (r["tenants"], r["workers"]) == (2, 2))
    assert multi["total_p99_ms"] < 2.0 * max(base["total_p99_ms"],
                                             two["total_p99_ms"]), rows
    return rows


# ---------------------------------------------------------------------------
def run_isolation(fast: bool = True) -> list:
    """A flooding tenant next to a quiet one: the flood eats its own
    QueueFull, the quiet tenant is untouched."""
    n_quiet = 32 if fast else 128
    n_flood = 8 * n_quiet
    eng = _build(fast)
    fab = eng.serve_fabric(FabricConfig(
        workers=2,
        tenants=(TenantConfig("flood", weight=1.0, max_queue=8),
                 TenantConfig("quiet", weight=1.0, max_queue=n_quiet))))
    rng = np.random.default_rng(1)
    flood_rejects = 0
    quiet_futs = []
    with fab:
        fab.infer(eng.ds.val_idx[:REQ_IDS], tenant="quiet", timeout=600)
        for i in range(n_flood):
            ids = rng.choice(eng.ds.val_idx, size=REQ_IDS, replace=False)
            try:
                fab.submit(ids, tenant="flood")
            except QueueFull:
                flood_rejects += 1
            if i % (n_flood // n_quiet) == 0:
                quiet_futs.append(fab.submit(
                    rng.choice(eng.ds.val_idx, size=REQ_IDS, replace=False),
                    tenant="quiet"))
        for f in quiet_futs:
            f.result(timeout=600)
    snap = fab.meter.snapshot()
    t = snap["tenants"]
    rows = [{
        "tenant": "flood", "offered": n_flood,
        "served": t["flood"]["served"], "rejected": t["flood"]["rejected"],
        "total_p99_ms": t["flood"]["total_p99_ms"],
    }, {
        # +1: the warm-up request above also rode the quiet tenant
        "tenant": "quiet", "offered": len(quiet_futs) + 1,
        "served": t["quiet"]["served"], "rejected": t["quiet"]["rejected"],
        "total_p99_ms": t["quiet"]["total_p99_ms"],
    }]
    emit("fabric_isolation", rows,
         ["tenant", "offered", "served", "rejected", "total_p99_ms"])
    assert rows[0]["rejected"] == flood_rejects > 0, rows
    assert rows[1]["rejected"] == 0, rows
    assert rows[1]["served"] == rows[1]["offered"], rows
    return rows


# ---------------------------------------------------------------------------
def run_routing(fast: bool = True) -> list:
    """Placement-aware routing on a sharded mesh (>= 4 devices or skip)."""
    import jax
    if len(jax.devices()) < 4:
        print("# fabric_routing: needs >= 4 devices "
              "(XLA_FLAGS=--xla_force_host_platform_device_count=4) — skip")
        return []
    from repro.gns.config import MeshConfig
    n_requests = 48 if fast else 512
    # the smoke-test shape (tests/test_fabric_chaos.py): fused input at a
    # small hidden dim — the measurement here is ROUTING locality, not
    # model throughput, and CPU-mesh compile/step times for big models
    # would otherwise dwarf the request stream
    ds = get_dataset("ogbn-products", scale=0.1 if fast else 1.0, seed=0)
    cfg = engine_config("gns", batch_size=32, cache_strategy="adaptive",
                        cache_fraction=0.3, fanouts=(3, 4), seed=0)
    cfg = dataclasses.replace(
        cfg, mesh=MeshConfig(data=2, model=2),
        model=dataclasses.replace(cfg.model, input_impl="fused",
                                  hidden_dim=16),
        cache=dataclasses.replace(cfg.cache, placement="locality"),
        serve=ServeConfig(buckets=(8, 32), max_wait_ms=2.0, max_queue=4096))
    eng = GNSEngine(cfg, dataset=ds)
    fab = eng.serve_fabric(FabricConfig(
        workers=2,
        tenants=(TenantConfig("a", max_queue=2 * n_requests),
                 TenantConfig("b", max_queue=2 * n_requests)),
        # stall-failover is the CHAOS battery's subject, not this bench's:
        # on a loaded CPU box legitimate batches can outlive any sane stall
        # timeout, and re-route ping-pong would poison the locality number
        stall_timeout_ms=600_000.0))
    rng = np.random.default_rng(2)
    half = len(ds.val_idx) // 2
    hot = {"a": rng.choice(ds.val_idx[:half], size=30, replace=False),
           "b": rng.choice(ds.val_idx[half:], size=30, replace=False)}
    with fab:
        # warm each worker's compiled path before the flood
        for widx, t in ((0, "a"), (1, "b")):
            fab.submit(rng.choice(hot[t], size=REQ_IDS // 2, replace=False),
                       tenant=t, worker=widx).result(timeout=600)
        futs = [fab.submit(rng.choice(hot[t], size=REQ_IDS // 2,
                                      replace=False), tenant=t)
                for i in range(n_requests) for t in ("a", "b")]
        for f in futs:
            f.result(timeout=600)
    snap = fab.meter.snapshot()
    rt = snap["routing"]
    rows = [{
        "requests": 2 * n_requests, "n_shards": eng.store.n_shards,
        "route_local_fraction": rt["route_local_fraction"],
        "routed_known_ids": rt["routed_known_ids"],
        "route_fallbacks": rt["route_fallbacks"],
        "worker_batches": rt["worker_batches"],
        "total_p99_ms": snap["total_p99_ms"],
    }]
    emit("fabric_routing", rows,
         ["requests", "n_shards", "route_local_fraction",
          "routed_known_ids", "route_fallbacks", "total_p99_ms"])
    assert rows[0]["route_local_fraction"] > 0.5, rows
    return rows


def run(fast: bool = True) -> None:
    run_fairness(fast)
    run_isolation(fast)
    run_routing(fast)


if __name__ == "__main__":
    run()

"""Paper Table 3: F1 + time/epoch for NS / GNS / LADIES / LazyGCN.

Synthetic datasets replicate the paper's dataset *shapes* (graph/datasets.py)
at container scale; the quantity compared is the RELATIVE speed and accuracy
of the four samplers, which is scale-transportable (the paper's 2-4x GNS/NS
gap comes from per-batch input-node counts, reproduced in bench_input_nodes).

Configuration comes from the shared ``bench_ci`` engine preset via
``common.run_trainer`` — no sampler/cache defaults are re-declared here, so
this table and bench_cache_sensitivity measure the same trained config.
"""
from __future__ import annotations

from benchmarks.common import emit, run_trainer

FIELDS = ["dataset", "sampler", "f1", "epoch_time_s",
          "input_nodes_per_batch", "speedup_vs_ns"]


def run(fast: bool = True) -> list:
    datasets = ["yelp", "ogbn-products"] if fast else [
        "yelp", "amazon", "oag-paper", "ogbn-products", "ogbn-papers"]
    scale = 0.15 if fast else 1.0
    epochs = 2 if fast else 10
    rows = []
    for ds in datasets:
        base_t = None
        for sampler in ("ns", "gns", "ladies", "lazygcn"):
            r = run_trainer(ds, sampler, epochs=epochs, scale=scale,
                            max_batches=30 if fast else None)
            if sampler == "ns":
                base_t = r["epoch_time_s"]
            r["speedup_vs_ns"] = base_t / max(r["epoch_time_s"], 1e-9)
            rows.append(r)
    return emit("table3_throughput", rows, FIELDS)


if __name__ == "__main__":
    run(fast=True)

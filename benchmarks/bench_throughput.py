"""Paper Table 3: F1 + time/epoch for NS / GNS / LADIES / LazyGCN.

Synthetic datasets replicate the paper's dataset *shapes* (graph/datasets.py)
at container scale; the quantity compared is the RELATIVE speed and accuracy
of the four samplers, which is scale-transportable (the paper's 2-4x GNS/NS
gap comes from per-batch input-node counts, reproduced in bench_input_nodes).

Configuration comes from the shared ``bench_ci`` engine preset via
``common.run_trainer`` — no sampler/cache defaults are re-declared here, so
this table and bench_cache_sensitivity measure the same trained config.
"""
from __future__ import annotations

from benchmarks.common import emit, run_trainer

FIELDS = ["dataset", "sampler", "f1", "epoch_time_s",
          "input_nodes_per_batch", "speedup_vs_ns"]


BACKEND_FIELDS = ["dataset", "sampler", "backend", "f1", "epoch_time_s",
                  "prefetch_wait_s", "input_nodes_per_batch"]


def run_backend(fast: bool = True) -> list:
    """Host vs device GNS sampling backend, prefetched (ISSUE 6 tentpole).

    Both rows run the same bench_ci GNS config with the prefetcher on; the
    device backend moves the layer-0 draw + gather into the compiled step,
    so the host-side sampler does less work per batch — visible as a lower
    ``prefetch_wait_s`` (time fit() blocked on the sampler thread) and a
    lower epoch time.
    """
    scale = 0.15 if fast else 1.0
    epochs = 2 if fast else 10
    rows = []
    for backend in ("host", "device"):
        r = run_trainer("ogbn-products", "gns", epochs=epochs, scale=scale,
                        max_batches=30 if fast else None,
                        backend=backend, prefetch=True)
        r["prefetch_wait_s"] = r["breakdown"].get("prefetch_wait_s")
        rows.append(r)
    return emit("backend_sampling", rows, BACKEND_FIELDS)


def run(fast: bool = True) -> list:
    datasets = ["yelp", "ogbn-products"] if fast else [
        "yelp", "amazon", "oag-paper", "ogbn-products", "ogbn-papers"]
    scale = 0.15 if fast else 1.0
    epochs = 2 if fast else 10
    rows = []
    for ds in datasets:
        base_t = None
        for sampler in ("ns", "gns", "ladies", "lazygcn"):
            r = run_trainer(ds, sampler, epochs=epochs, scale=scale,
                            max_batches=30 if fast else None)
            if sampler == "ns":
                base_t = r["epoch_time_s"]
            r["speedup_vs_ns"] = base_t / max(r["epoch_time_s"], 1e-9)
            rows.append(r)
    return emit("table3_throughput", rows, FIELDS)


if __name__ == "__main__":
    run(fast=True)
    run_backend(fast=True)

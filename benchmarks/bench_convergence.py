"""Paper Fig. 3: F1 vs epoch for all four samplers (convergence parity)."""
from __future__ import annotations

from repro.featurestore import CacheConfig
from repro.core.sampler import SamplerConfig
from repro.graph.datasets import get_dataset
from repro.train.trainer import GNNTrainer
from benchmarks.common import emit

FIELDS = ["sampler", "epoch", "f1"]


def run(fast: bool = True) -> list:
    ds = get_dataset("ogbn-products", scale=0.15 if fast else 1.0)
    epochs = 4 if fast else 10
    rows = []
    for sampler in ("ns", "gns", "ladies", "lazygcn"):
        scfg = SamplerConfig(batch_size=512,
                             cache=CacheConfig(fraction=0.01, period=1))
        tr = GNNTrainer(ds, sampler, sampler_cfg=scfg)
        rep = tr.train(epochs, eval_every=1)
        for ep, f1 in enumerate(rep.val_acc, start=1):
            rows.append({"sampler": sampler, "epoch": ep, "f1": f1})
    return emit("fig3_convergence", rows, FIELDS)


if __name__ == "__main__":
    run(fast=True)

"""Paper Table 4: average #input nodes per minibatch, NS vs GNS (+ cached).

The mechanism behind the paper's speedup: GNS shrinks the input layer 3-6x
and serves a large share of it from the device cache.
"""
from __future__ import annotations

from benchmarks.common import emit, run_trainer

FIELDS = ["dataset", "input_nodes_ns", "input_nodes_gns", "cached_gns",
          "reduction_x"]


def run(fast: bool = True) -> list:
    # Table-4 regime: the sample tree (batch x prod(fanouts)) must stay well
    # under |V| or dedup saturates and hides the reduction (EXPERIMENTS.md).
    datasets = ["yelp", "ogbn-products"] if fast else [
        "yelp", "amazon", "oag-paper", "ogbn-products", "ogbn-papers"]
    scale = 2.0 if fast else 1.0
    bsz = 128 if fast else 1000
    rows = []
    for ds in datasets:
        ns = run_trainer(ds, "ns", epochs=1, scale=scale, batch_size=bsz,
                         max_batches=20)
        gns = run_trainer(ds, "gns", epochs=1, scale=scale, batch_size=bsz,
                          max_batches=20)
        rows.append({
            "dataset": ds,
            "input_nodes_ns": ns["input_nodes_per_batch"],
            "input_nodes_gns": gns["input_nodes_per_batch"],
            "cached_gns": gns["cached_nodes_per_batch"],
            "reduction_x": ns["input_nodes_per_batch"]
            / max(gns["input_nodes_per_batch"], 1.0),
        })
    return emit("table4_input_nodes", rows, FIELDS)


if __name__ == "__main__":
    run(fast=True)

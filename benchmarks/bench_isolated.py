"""Paper Table 5: % isolated first-layer target nodes in LADIES vs layer size."""
from __future__ import annotations

import numpy as np

from repro.core.sampler import LadiesSampler, SamplerConfig
from repro.graph.datasets import get_dataset
from benchmarks.common import emit

FIELDS = ["layer_size", "isolated_pct"]


def run(fast: bool = True) -> list:
    ds = get_dataset("ogbn-products", scale=0.15 if fast else 1.0)
    rng = np.random.default_rng(0)
    rows = []
    sizes = [256, 512, 1000, 5000] if fast else [256, 512, 1000, 5000, 10000]
    for s in sizes:
        cfg = SamplerConfig(batch_size=512, layer_size=s)
        sampler = LadiesSampler(ds.graph, cfg, ds.features, ds.labels)
        iso, tot = 0, 0
        for i in range(4):
            targets = rng.choice(ds.train_idx, size=cfg.batch_size,
                                 replace=False)
            mb = sampler.sample(targets, rng)
            iso += mb.num_isolated
            tot += cfg.batch_size
        rows.append({"layer_size": s, "isolated_pct": 100.0 * iso / tot})
    return emit("table5_isolated", rows, FIELDS)


if __name__ == "__main__":
    run(fast=True)

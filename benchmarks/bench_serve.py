"""Serving: dynamic micro-batching vs per-request `GNSEngine.infer()`.

Two measurements (PR 5 acceptance):

* :func:`run_throughput` — the same request stream served (a) by looping
  the one-shot ``infer()`` per request and (b) by the persistent
  :class:`~repro.serve.GNSServer` at EQUAL batch budget (the server's
  largest bucket == ``infer()``'s padded batch).  Micro-batching coalesces
  many small requests into one padded step, so sampling AND compute
  amortize: the acceptance asserts >= 3x request throughput with ZERO
  steady-state recompilation (one compiled step per size bucket).
* :func:`run_trajectory` — a Zipf-skewed request stream against the
  adaptive policy with serving-driven refreshes
  (``ServeConfig.refresh_every``): the per-batch device-tier hit fraction
  must RISE across the stream as the cache re-draws toward the inference
  hot set (the paper's cache loop closed over a serving workload).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import emit, engine_config
from repro.gns import GNSEngine, ServeConfig
from repro.graph.datasets import get_dataset

REQ_IDS = 8                       # ids per request (a user-page fetch)


def _build(fast: bool, *, strategy: str = "auto",
           serve: ServeConfig = None, seed: int = 0) -> GNSEngine:
    scale = 0.25 if fast else 1.0
    ds = get_dataset("ogbn-products", scale=scale, seed=seed)
    cfg = engine_config("gns", batch_size=128 if fast else 512,
                        cache_strategy=strategy, seed=seed)
    if serve is not None:
        cfg = dataclasses.replace(cfg, serve=serve)
    return GNSEngine(cfg, dataset=ds)


def _requests(eng: GNSEngine, n: int, rng, hot=None,
              hot_share: float = 0.0) -> list:
    pool = eng.ds.val_idx
    out = []
    for _ in range(n):
        src = hot if hot is not None and rng.random() < hot_share else pool
        out.append(rng.choice(src, size=REQ_IDS, replace=False))
    return out


# ---------------------------------------------------------------------------
def run_throughput(fast: bool = True) -> list:
    n_requests = 64 if fast else 512
    rng = np.random.default_rng(0)

    # (a) per-request one-shot infer(): every request pays a full padded
    # batch (sampling + compiled step) on its own
    eng_a = _build(fast)
    reqs = _requests(eng_a, n_requests, rng)
    eng_a.infer(reqs[0])                          # warm: compile + cold cache
    t0 = time.perf_counter()
    for ids in reqs:
        eng_a.infer(ids)
    wall_a = time.perf_counter() - t0

    # (b) the serving loop at EQUAL batch budget: largest bucket == the
    # engine batch infer() pads to
    budget = eng_a.scfg.batch_size
    serve = ServeConfig(buckets=(budget // 4, budget), max_wait_ms=5.0,
                        max_queue=4 * n_requests)
    eng_b = _build(fast, serve=serve)
    with eng_b.serve() as srv:
        srv.infer(reqs[0], timeout=600)           # warm small bucket
        srv.submit(np.resize(reqs[0], budget)).result(timeout=600)  # large
        warm_entries = eng_b.infer_step._cache_size()
        t0 = time.perf_counter()
        futs = [srv.submit(ids) for ids in reqs]
        for f in futs:
            f.result(timeout=600)
        wall_b = time.perf_counter() - t0
        recompiles = eng_b.infer_step._cache_size() - warm_entries
    snap = srv.meter.snapshot()

    rows = [{
        "mode": "per_request_infer", "requests": n_requests,
        "wall_s": wall_a, "requests_per_s": n_requests / wall_a,
        "batches": n_requests, "speedup": 1.0, "recompiles": 0,
        "fill_fraction": REQ_IDS / budget,
    }, {
        "mode": "server_microbatch", "requests": n_requests,
        "wall_s": wall_b, "requests_per_s": n_requests / wall_b,
        "batches": snap["batches"], "speedup": wall_a / wall_b,
        "recompiles": recompiles,
        "fill_fraction": snap["fill_fraction"],
        "queue_wait_p99_ms": snap["queue_wait_p99_ms"],
        "total_p99_ms": snap["total_p99_ms"],
    }]
    emit("serve_throughput", rows,
         ["mode", "requests", "wall_s", "requests_per_s", "batches",
          "speedup", "recompiles", "fill_fraction"])
    return rows


# ---------------------------------------------------------------------------
def run_trajectory(fast: bool = True) -> list:
    n_requests = 150 if fast else 1000
    rng = np.random.default_rng(1)
    eng = _build(fast, strategy="adaptive",
                 serve=ServeConfig(buckets=(32, 128), max_wait_ms=2.0,
                                   refresh_every=10,
                                   max_queue=4 * n_requests))
    hot = rng.choice(eng.ds.val_idx, size=max(len(eng.ds.val_idx) // 20, 16),
                     replace=False)
    with eng.serve() as srv:
        for ids in _requests(eng, n_requests, rng, hot=hot, hot_share=0.9):
            srv.infer(ids, timeout=600)           # sequential: a live stream
    traj = srv.meter.hit_trajectory()
    k = max(len(traj) // 4, 1)
    early, late = float(np.mean(traj[:k])), float(np.mean(traj[-k:]))
    rows = [{
        "requests": n_requests, "batches": srv.meter.batches,
        "swaps": srv.meter.swaps_observed,
        "hit_frac_early": early, "hit_frac_late": late,
        "hit_improvement": late - early,
        "cache_hit_rate": srv.meter.cache_hit_rate,
    }]
    emit("serve_trajectory", rows,
         ["requests", "batches", "swaps", "hit_frac_early", "hit_frac_late",
          "hit_improvement", "cache_hit_rate"])
    return rows


def run(fast: bool = True) -> None:
    run_throughput(fast)
    run_trajectory(fast)


if __name__ == "__main__":
    run()

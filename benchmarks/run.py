"""Benchmark harness entry point — one module per paper table/figure.

  Table 3  bench_throughput        F1 + time/epoch, 4 samplers
  Table 4  bench_input_nodes       #input nodes per batch NS vs GNS
  Table 5  bench_isolated          LADIES isolated-node pathology
  Table 6  bench_cache_sensitivity GNS cache size x refresh period
  Fig 1/2  bench_breakdown         runtime breakdown + byte ledger
  Fig 3    bench_convergence       F1 vs epoch, 4 samplers
  §Roofline bench_roofline         aggregates dry-run JSONs (no compute)
  Serving  bench_serve             micro-batched GNSServer vs infer() loop
  Fabric   bench_fabric            multi-tenant fairness/isolation/routing
  Stream   bench_stream            serve-while-mutating temporal replay
  RPC      bench_rpc               tcp transport overhead vs inproc fabric

``python -m benchmarks.run`` runs all at CI scale (--full for paper scale);
each prints CSV and persists JSON under benchmarks/results/.
"""
from __future__ import annotations

import argparse
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale datasets/epochs (hours on CPU)")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names (e.g. throughput,roofline)")
    args = ap.parse_args(argv)

    from benchmarks import (bench_breakdown, bench_cache_sensitivity,
                            bench_convergence, bench_fabric,
                            bench_input_nodes, bench_isolated,
                            bench_roofline, bench_rpc, bench_serve,
                            bench_stream, bench_throughput)
    all_benches = {
        "throughput": bench_throughput.run,
        "input_nodes": bench_input_nodes.run,
        "isolated": bench_isolated.run,
        "cache_sensitivity": bench_cache_sensitivity.run,
        "breakdown": bench_breakdown.run,
        "convergence": bench_convergence.run,
        "roofline": bench_roofline.run,
        "serve": bench_serve.run,
        "fabric": bench_fabric.run,
        "stream": bench_stream.run,
        "rpc": bench_rpc.run,
    }
    names = (args.only.split(",") if args.only else list(all_benches))
    for name in names:
        t0 = time.perf_counter()
        print(f"\n{'=' * 60}\n== bench: {name}\n{'=' * 60}")
        all_benches[name](fast=not args.full)
        print(f"[{name}: {time.perf_counter() - t0:.1f}s]")


if __name__ == "__main__":
    main()

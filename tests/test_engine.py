"""Unified engine API (src/repro/gns): config, engine verbs, shim parity.

Three layers of coverage:

* in-process: ``EngineConfig`` round-trip + presets, golden-path
  ``fit``/``evaluate``/``infer`` on the synthetic dataset, bitwise
  GNNTrainer-shim vs direct-engine parity, and the group-collation layout
  (``collate_groups`` + ``SageConfig.num_groups``) checked against
  per-group forwards with no mesh at all;
* subprocess on 4 forced host devices: the PR acceptance — ONE compiled
  train step serves batches homed on different cache shards without
  retracing (single jit cache entry across >= 3 distinct-home-shard
  batches), the dynamic home-shard-vector gathers are bitwise-equal to the
  PR-3 static-arg fast path, and the engine trains end-to-end at DP = 2
  with per-group home shards inside one step.

Subprocesses are used because jax locks the device count at first init.
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.sampler import SamplerConfig
from repro.featurestore import CacheConfig
from repro.gns import EngineConfig, GNSEngine, ServeConfig, collate_groups
from repro.gns.config import DataConfig, MeshConfig, ModelConfig
from repro.graph.datasets import get_dataset


def _run_sub(code: str, timeout: int = 600) -> str:
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run(
        [sys.executable, "-c", code],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


@pytest.fixture(scope="module")
def tiny_ds():
    return get_dataset("tiny", seed=0)


def _tiny_cfg(sampler="gns", **kw):
    scfg = SamplerConfig(fanouts=(3, 4), batch_size=32,
                         cache=CacheConfig(fraction=0.1, period=1))
    return EngineConfig(sampler=sampler, sampling=scfg, cache=scfg.cache,
                        seed=0, **kw)


# ---------------------------------------------------------------------------
# EngineConfig: round-trip + presets
# ---------------------------------------------------------------------------

def test_engine_config_round_trips_through_dict():
    cfg = EngineConfig(
        sampler="gns",
        data=DataConfig(name="yelp", scale=0.3, seed=7),
        sampling=SamplerConfig(batch_size=64, fanouts=(2, 3),
                               importance_mode="paper", layer_size=128),
        cache=CacheConfig(fraction=0.02, period=3, strategy="degree",
                          walk_fanouts=(4, 2), async_refresh=True,
                          shards=4, placement="locality",
                          refresh_timeout_s=1.5),
        model=ModelConfig(hidden_dim=64, input_impl="fused"),
        mesh=MeshConfig(data=2, model=2),
        serve=ServeConfig(buckets=(16, 64), max_queue=32, max_wait_ms=1.5,
                          default_deadline_ms=250.0, refresh_every=8,
                          latency_window=64),
        seed=11, prefetch=True)
    d = cfg.to_dict()
    json.dumps(d)                       # JSON-safe, whole tree
    back = EngineConfig.from_dict(d)
    assert back == cfg
    # and the double round-trip is a fixed point
    assert EngineConfig.from_dict(back.to_dict()) == back


def test_engine_config_round_trip_defaults_and_no_mesh():
    cfg = EngineConfig()
    assert cfg.mesh is None
    back = EngineConfig.from_dict(json.loads(json.dumps(cfg.to_dict())))
    assert back == cfg


def test_presets_and_overrides():
    base = EngineConfig.preset("bench_ci")
    assert base.sampling.batch_size == 512
    over = EngineConfig.preset("bench_ci", sampler="ns", seed=3)
    assert over.sampler == "ns" and over.seed == 3
    assert over.cache == base.cache
    # the sampler config handed to make_sampler carries THE cache config
    assert base.sampler_config().cache is base.cache


# ---------------------------------------------------------------------------
# golden path: fit / evaluate / infer on the synthetic dataset
# ---------------------------------------------------------------------------

def test_engine_fit_evaluate_infer_smoke(tiny_ds):
    eng = GNSEngine(_tiny_cfg(), dataset=tiny_ds)
    rep = eng.fit(2, max_batches=4, eval_every=2, eval_batches=2)
    assert len(rep.losses) == 2 and np.isfinite(rep.losses).all()
    assert rep.losses[-1] < rep.losses[0]
    assert rep.val_acc and 0.0 <= rep.val_acc[-1] <= 1.0
    assert eng.meter.steps == 8
    f1 = eng.evaluate(tiny_ds.val_idx, num_batches=2)
    assert 0.0 <= f1 <= 1.0

    # infer: logits for arbitrary ids, live generation, no side effects
    refreshes = eng.store.refreshes
    steps = eng.meter.steps
    ids = tiny_ds.val_idx[:50]
    logits = eng.infer(ids)
    assert logits.shape == (50, tiny_ds.num_classes)
    assert np.isfinite(logits).all()
    assert eng.store.refreshes == refreshes      # reused the live generation
    assert eng.meter.steps == steps              # no training side effects
    assert eng.store.record                      # accounting restored
    # inference is deterministic per call (fixed internal rng)...
    np.testing.assert_array_equal(eng.infer(ids), logits)
    # ...and short requests wrap-pad to a full batch without erroring
    assert eng.infer(ids[:7]).shape == (7, tiny_ds.num_classes)


def test_engine_describe_without_mesh(tiny_ds):
    eng = GNSEngine(_tiny_cfg(), dataset=tiny_ds)
    rec = eng.describe()
    assert rec["status"] == "ok" and rec["mesh"] is None
    assert rec["cache_rows"] > 0
    assert rec["input_rows_per_batch"] > 0


def test_describe_diff_mode(tiny_ds):
    """gns.describe.diff: identical configs diff as same (volatile keys
    excluded); a cache-fraction change shows up in BOTH the config layer
    and the lowering/traffic record layer."""
    from repro.gns.describe import diff, diff_records

    a = _tiny_cfg()
    b = dataclasses.replace(a, cache=CacheConfig(fraction=0.2, period=1))
    same = diff(a, a, dataset_a=tiny_ds, dataset_b=tiny_ds)
    assert same["same"] and same["record"]["same"], same
    d = diff(a, b, dataset_a=tiny_ds, dataset_b=tiny_ds)
    assert not d["same"]
    assert "cache.fraction" in d["config"]["changed"]
    assert "cache_rows" in d["record"]["changed"]
    # records with different keys land in only_a/only_b, not changed
    r = diff_records({"x": 1, "both": 2}, {"y": 3, "both": 2})
    assert r["only_a"] == {"x": 1} and r["only_b"] == {"y": 3}
    assert not r["changed"] and not r["same"]


def test_engine_ns_sampler_has_no_store(tiny_ds):
    eng = GNSEngine(_tiny_cfg(sampler="ns"), dataset=tiny_ds)
    assert eng.store is None
    rep = eng.fit(1, max_batches=2)
    assert np.isfinite(rep.losses).all()


# ---------------------------------------------------------------------------
# GNNTrainer shim: bitwise parity with the direct engine
# ---------------------------------------------------------------------------

def test_trainer_shim_bitwise_parity(tiny_ds):
    import jax

    from repro.train.trainer import GNNTrainer

    scfg = SamplerConfig(fanouts=(3, 4), batch_size=32,
                         cache=CacheConfig(fraction=0.1, period=1))
    eng = GNSEngine(EngineConfig(sampler="gns", sampling=scfg,
                                 cache=scfg.cache, seed=0),
                    dataset=tiny_ds)
    rep_e = eng.fit(2, max_batches=4)

    tr = GNNTrainer(tiny_ds, "gns", sampler_cfg=scfg, seed=0)
    rep_t = tr.train(2, max_batches=4)

    assert rep_t.losses == rep_e.losses
    for a, b in zip(jax.tree_util.tree_leaves(eng.params),
                    jax.tree_util.tree_leaves(tr.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(eng.opt_state),
                    jax.tree_util.tree_leaves(tr.opt_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the shim's state aliases the engine's (same run, not a copy)
    assert tr.meter is tr.engine.meter
    assert tr.store is tr.engine.store


# ---------------------------------------------------------------------------
# group collation: collate_groups + SageConfig.num_groups, no mesh needed
# ---------------------------------------------------------------------------

def test_collated_forward_matches_per_group(tiny_ds):
    """forward(collated batch, num_groups=2) must reproduce the two
    per-group forwards row-for-row — the layout contract the DP>1 engine
    and the dry-run structs both build on."""
    import jax.numpy as jnp

    from repro.core.pipeline import EpochLoader
    from repro.core.sampler import make_sampler
    from repro.models import graphsage

    scfg = SamplerConfig(fanouts=(3, 4), batch_size=16)
    sampler = make_sampler("ns", tiny_ds.graph, scfg, tiny_ds.features,
                           tiny_ds.labels)
    loader = EpochLoader(sampler, tiny_ds.train_idx, seed=1, max_batches=2)
    mbs = list(loader.epoch(0))
    assert len(mbs) == 2

    mcfg1 = graphsage.SageConfig(feat_dim=tiny_ds.feat_dim, hidden_dim=16,
                                 num_classes=tiny_ds.num_classes,
                                 num_layers=2)
    mcfg2 = dataclasses.replace(mcfg1, num_groups=2)
    params = graphsage.init_params(__import__("jax").random.PRNGKey(0), mcfg1)
    table = graphsage.dummy_cache_table(tiny_ds.feat_dim)

    step, home = collate_groups(mbs, fused=False)
    assert home.tolist() == [-1, -1]
    out = np.asarray(graphsage.forward(params, step.device, table, mcfg2))
    parts = [np.asarray(graphsage.forward(params, mb.device, table, mcfg1))
             for mb in mbs]
    np.testing.assert_allclose(out, np.concatenate(parts), rtol=1e-5,
                               atol=1e-5)
    # collated bookkeeping is the sum of the parts
    assert step.num_input == sum(mb.num_input for mb in mbs)
    assert step.bytes_streamed == sum(mb.bytes_streamed for mb in mbs)


def test_collate_single_batch_is_identity(tiny_ds):
    from repro.core.pipeline import EpochLoader
    from repro.core.sampler import make_sampler

    scfg = SamplerConfig(fanouts=(3,), batch_size=8)
    sampler = make_sampler("ns", tiny_ds.graph, scfg, tiny_ds.features,
                           tiny_ds.labels)
    mb = next(iter(EpochLoader(sampler, tiny_ds.train_idx, seed=0,
                               max_batches=1).epoch(0)))
    step, home = collate_groups([mb], fused=True)
    assert step is mb
    assert home.tolist() == [-1]


# ---------------------------------------------------------------------------
# subprocess on 4 forced host devices: the DP>1 fast-path acceptance
# ---------------------------------------------------------------------------

ENGINE_MESH_CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses
import numpy as np
import jax
import jax.numpy as jnp

from repro.core.minibatch import (DeviceBatch, MiniBatch, block_pad_sizes,
                                  make_block)
from repro.core.sampler import SamplerConfig
from repro.featurestore import CacheConfig, home_shard
from repro.gns import EngineConfig, GNSEngine
from repro.gns.config import MeshConfig, ModelConfig
from repro.graph.datasets import get_dataset
from repro.kernels.ops import cache_lookup_agg
from repro.launch import sharding as shlib

assert len(jax.devices()) == 4

# ---- 1) one compiled step, >= 3 distinct home shards, zero retracing ----
# Engine on a (data=1, model=4) mesh: cache row-sharded over 4 shards, G=1.
ds = get_dataset("tiny", seed=0)
B, FANOUTS = 16, (3, 4)
scfg = SamplerConfig(fanouts=FANOUTS, batch_size=B,
                     cache=CacheConfig(fraction=0.3, placement="locality"))
cfg = EngineConfig(sampler="gns", sampling=scfg, cache=scfg.cache,
                   model=ModelConfig(input_impl="fused", hidden_dim=16),
                   mesh=MeshConfig(data=1, model=4), seed=0)
eng = GNSEngine(cfg, dataset=ds)
assert eng.num_groups == 1
assert eng.mcfg.cache_shard_axis == "model"
store = eng.store

# teach the placement solver skewed per-group demand, then refresh so each
# group's hot rows co-locate with its home shard.  DISJOINT hot sets (one
# permutation, sliced) small enough for both the home shard's capacity and
# the input-layer pad.
store.refresh(np.random.default_rng(1), version=0)
gen0 = store.generation
rng = np.random.default_rng(9)
pads = block_pad_sizes(B, FANOUTS)
s0 = pads[0][1]
hot_n = min(gen0.state.rows_per_shard - 2, s0 - 8)
perm = rng.permutation(gen0.state.node_ids)
hot = {g: np.sort(perm[g * hot_n:(g + 1) * hot_n]) for g in range(4)}
for _ in range(3):
    for g in range(4):
        store.assemble_input(store.generation, hot[g], len(hot[g]), group=g)
gen = store.refresh(np.random.default_rng(2), version=1)
assert gen.state.placement is not None and not gen.state.placement.is_identity

# hand-build structurally-identical minibatches whose input rows are one
# group's hot set -> fully local, home shard = group % 4
rngb = np.random.default_rng(3)

def build_batch(g):
    ids = hot[g][gen.state.slot_of[hot[g]] >= 0]
    n_in = len(ids)
    assert n_in > 0
    ids_p = np.concatenate([ids, np.zeros(s0 - n_in, np.int64)])
    store.record = False
    slots, streamed, hits, _, local = store.assemble_input(
        gen, ids_p, n_in, group=g)
    store.record = True
    assert hits == n_in and local == home_shard(g, 4) == g, (g, local)
    blocks = []
    for li, (d, s) in enumerate(pads):
        k = FANOUTS[li]
        # lanes must stay inside the block's REAL source rows: the padded
        # input ids for layer 0, the previous block's dst rows above it
        bound = n_in if li == 0 else pads[li][1]
        idx = rngb.integers(0, max(bound, 1), (d, k))
        w = rngb.integers(-2, 3, (d, k)).astype(np.float64)
        blocks.append(make_block(idx, w, d, s))
    mask = np.zeros(s0, np.float32); mask[:n_in] = 1.0
    lbl = rngb.integers(0, ds.num_classes, B).astype(np.int32)
    lmask = np.ones(B, np.float32)
    dev = DeviceBatch(blocks=tuple(blocks), input_cache_slots=slots,
                      input_streamed=streamed, input_mask=mask,
                      labels=lbl, label_mask=lmask)
    return MiniBatch(device=dev, input_node_ids=ids_p, num_input=n_in,
                     num_cached=hits, cache_gen=gen, local_shard=local), \
        slots, streamed, blocks[0]

batches = [build_batch(g) for g in (0, 1, 2, 3)]
# warm-up on home shard 0: the second call settles the arg-placement cache
# entry (step outputs come back committed/sharded, unlike the first call's
# host arrays) — home-shard values play no part in either trace
losses = [eng.run_batch(batches[0][0])[0] for _ in range(2)]
warm = eng._train_step._cache_size()
# THE acceptance: three MORE batches, each homed on a DIFFERENT shard
# (1, 2, 3), all served by the warm compiled entries — zero retracing
losses += [eng.run_batch(mb)[0] for mb, *_ in batches[1:]]
assert all(np.isfinite(l) for l in losses), losses
assert eng._train_step._cache_size() == warm, (
    eng._train_step._cache_size(), warm)
print("SINGLE_TRACE_OK", [round(l, 4) for l in losses])

# ---- 2) dynamic home-shard gathers bitwise-equal to the static PR-3 path
mesh = eng.mesh
for mb, slots, streamed, blk0 in batches:
    ls = mb.local_shard
    args = (gen.table, jnp.asarray(streamed), jnp.asarray(slots),
            jnp.asarray(blk0.nbr_idx), jnp.asarray(blk0.nbr_w))
    dyn = cache_lookup_agg(*args, mesh=mesh, shard_axis="model",
                           local_shards=jnp.array([ls], jnp.int32))
    sta = cache_lookup_agg(*args, mesh=mesh, shard_axis="model",
                           local_shard=int(ls))
    psum = cache_lookup_agg(*args, mesh=mesh, shard_axis="model")
    np.testing.assert_array_equal(np.asarray(dyn), np.asarray(sta))
    np.testing.assert_array_equal(np.asarray(dyn), np.asarray(psum))
print("BITWISE_VS_STATIC_OK")

# ---- 3) DP = 2: per-group home shards inside ONE compiled step ----------
scfg2 = SamplerConfig(fanouts=(3, 4), batch_size=16,
                      cache=CacheConfig(fraction=0.2, placement="locality"))
cfg2 = EngineConfig(sampler="gns", sampling=scfg2, cache=scfg2.cache,
                    model=ModelConfig(input_impl="fused", hidden_dim=16),
                    mesh=MeshConfig(data=2, model=2), seed=0)
eng2 = GNSEngine(cfg2)
assert eng2.num_groups == 2
rep = eng2.fit(2, max_batches=3)
assert np.isfinite(rep.losses).all(), rep.losses
assert eng2.meter.steps == 6
# <= 2: one trace + the arg-placement variant after step 1 (see above);
# 6 steps of varying per-group home shards add NOTHING
assert eng2._train_step._cache_size() <= 2, eng2._train_step._cache_size()
# evaluation + inference ride the same mesh (psum path, single batches)
f1 = eng2.evaluate(eng2.ds.val_idx, num_batches=2)
assert 0.0 <= f1 <= 1.0
logits = eng2.infer(eng2.ds.val_idx[:20])
assert logits.shape == (20, eng2.ds.num_classes)
assert np.isfinite(logits).all()
print("DP2_ENGINE_OK", [round(l, 4) for l in rep.losses])

# ---- 3b) run_batch refuses a raw (un-collated) minibatch at DP > 1 ------
import numpy as _np
raw = eng2.sampler.sample(eng2.ds.train_idx[:16], _np.random.default_rng(0))
try:
    eng2.run_batch(raw)
    raise SystemExit("run_batch accepted an un-collated batch at DP=2")
except AssertionError as e:
    assert "GROUP-COLLATED" in str(e), e

# ---- 3c) fused WITHOUT a cache axis collates with offsets (global op) ---
# An 'ns' engine has no store, so the fused op runs on the GLOBAL collated
# arrays — layer-0 indices must be group-offset like the upper layers, and
# the collated logits must reproduce the per-group forwards.
from repro.gns import collate_groups
from repro.models import graphsage as _gs
cfg3 = EngineConfig(sampler="ns", sampling=SamplerConfig(fanouts=(3, 4),
                                                         batch_size=16),
                    model=ModelConfig(input_impl="fused", hidden_dim=16),
                    mesh=MeshConfig(data=2, model=2), seed=0)
eng3 = GNSEngine(cfg3)
assert eng3.num_groups == 2 and not eng3._collate_fused
rng3 = np.random.default_rng(5)
mbs = [eng3.sampler.sample(eng3.ds.train_idx[i * 16:(i + 1) * 16], rng3)
       for i in range(2)]
step3, _ = collate_groups(mbs, fused=eng3._collate_fused)
with shlib.use_mesh(None):
    out = np.asarray(_gs.forward(eng3.params, jax.device_put(step3.device),
                                 eng3._dummy_cache, eng3.mcfg))
    parts = [np.asarray(_gs.forward(eng3.params, jax.device_put(mb.device),
                                    eng3._dummy_cache, eng3.mcfg_eval))
             for mb in mbs]
np.testing.assert_allclose(out, np.concatenate(parts), rtol=1e-5, atol=1e-5)
rep3 = eng3.fit(1, max_batches=2)
assert np.isfinite(rep3.losses).all(), rep3.losses
print("FUSED_NOAXIS_COLLATE_OK")
"""


@pytest.mark.dryrun
def test_engine_dynamic_fast_path_on_mesh_subprocess():
    """PR-4 acceptance on the forced-host 4-device mesh: one compiled train
    step serves >= 3 distinct-home-shard batches with a single jit cache
    entry, bitwise-equal to the static-arg fast path, and the engine trains
    at DP = 2 with per-group home shards inside one step."""
    out = _run_sub(ENGINE_MESH_CODE, timeout=900)
    for marker in ("SINGLE_TRACE_OK", "BITWISE_VS_STATIC_OK",
                   "DP2_ENGINE_OK", "FUSED_NOAXIS_COLLATE_OK"):
        assert marker in out, out[-3000:]

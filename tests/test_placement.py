"""Property-based invariants of the locality-aware cache shard placement.

Runs under real ``hypothesis`` when installed, else the seeded fallback shim
(tests/_hypothesis_fallback.py) — same contract as tests/test_kernels.py.

The invariants every placement must hold, whatever traffic produced it:

* slot -> (shard, local row) -> device row round-trips (a bijection over the
  full padded table);
* every shard receives exactly ``rows_per_shard`` rows (balanced capacity);
* padding slots are placed but never handed to lookups by the store;
* an identity placement (and a placement solved from no traffic) degrades
  bit-for-bit to PR 2's contiguous ``divmod`` blocks.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # pragma: no cover
    from _hypothesis_fallback import given, settings, st

from repro.featurestore import (CacheConfig, FeatureStore, home_shard,
                                identity_placement, sample_cache,
                                solve_placement)
from repro.featurestore.store import CacheState
from repro.graph.generate import powerlaw_graph


def _random_placement(rng, n_groups, n_shards, rows_per_shard):
    rows = n_shards * rows_per_shard
    traffic = rng.integers(0, 40, (n_groups, rows)).astype(np.float64)
    traffic[:, rng.random(rows) < 0.3] = 0.0     # cold rows incl. "padding"
    return solve_placement(traffic, n_shards, rows_per_shard,
                           seed=int(rng.integers(2 ** 31)))


# ---------------------------------------------------------------------------
# solver invariants
# ---------------------------------------------------------------------------

@settings(max_examples=25)
@given(n_shards=st.integers(1, 6), rows_per_shard=st.integers(1, 12),
       n_groups=st.integers(1, 5), seed=st.integers(0, 10 ** 6))
def test_placement_is_balanced_bijection(n_shards, rows_per_shard,
                                         n_groups, seed):
    rng = np.random.default_rng(seed)
    pm = _random_placement(rng, n_groups, n_shards, rows_per_shard)
    rows = n_shards * rows_per_shard
    dev = pm.device_row_of_slot
    # bijection over the full padded table
    assert sorted(dev.tolist()) == list(range(rows))
    np.testing.assert_array_equal(pm.slot_of_device_row[dev],
                                  np.arange(rows, dtype=np.int32))
    # shard/local round-trip through the map's own views
    slots = np.arange(rows)
    np.testing.assert_array_equal(
        pm.shard_of_slot(slots) * rows_per_shard + pm.local_row_of_slot(slots),
        dev)
    # balanced capacity: every shard exactly rows_per_shard rows
    counts = np.bincount(dev // rows_per_shard, minlength=n_shards)
    assert (counts == rows_per_shard).all(), counts
    # negatives (miss lanes) pass through untouched
    assert pm.device_rows(np.array([-1, -7]))[0] == -1
    assert (pm.shard_of_slot(np.array([-1])) == -1).all()


@settings(max_examples=15)
@given(n_shards=st.integers(2, 5), rows_per_shard=st.integers(2, 10),
       seed=st.integers(0, 10 ** 6))
def test_placement_deterministic_under_seed(n_shards, rows_per_shard, seed):
    rng = np.random.default_rng(seed)
    rows = n_shards * rows_per_shard
    traffic = rng.integers(0, 5, (3, rows)).astype(np.float64)  # many ties
    a = solve_placement(traffic, n_shards, rows_per_shard, seed=seed)
    b = solve_placement(traffic, n_shards, rows_per_shard, seed=seed)
    np.testing.assert_array_equal(a.device_row_of_slot, b.device_row_of_slot)


@settings(max_examples=15)
@given(n_shards=st.integers(2, 5), rows_per_shard=st.integers(2, 8),
       seed=st.integers(0, 10 ** 6))
def test_hot_rows_win_their_home_shard(n_shards, rows_per_shard, seed):
    """The hottest rows_per_shard rows of one dominant group must all land
    on that group's home shard — the greedy hot-row-first guarantee."""
    rng = np.random.default_rng(seed)
    rows = n_shards * rows_per_shard
    group = int(rng.integers(0, n_shards))
    traffic = np.zeros((n_shards, rows))
    hot = rng.choice(rows, rows_per_shard, replace=False)
    traffic[group, hot] = 1000 + rng.integers(0, 100, rows_per_shard)
    # background noise from other groups, strictly colder
    traffic += rng.integers(0, 5, traffic.shape)
    pm = solve_placement(traffic, n_shards, rows_per_shard, seed=seed)
    assert (pm.shard_of_slot(hot) == home_shard(group, n_shards)).all()


def test_all_zero_traffic_decays_to_identity():
    pm = solve_placement(np.zeros((3, 12)), 4, 3, seed=9)
    assert pm.is_identity
    np.testing.assert_array_equal(pm.device_row_of_slot, np.arange(12))


# ---------------------------------------------------------------------------
# second-choice spill (PR-4 satellite): overflow rows land with their
# second-hottest group, not first-free-in-shard-order
# ---------------------------------------------------------------------------

@settings(max_examples=20)
@given(n_shards=st.integers(3, 6), rows_per_shard=st.integers(2, 8),
       seed=st.integers(0, 10 ** 6))
def test_spilled_rows_take_second_hottest_groups_shard(n_shards,
                                                       rows_per_shard, seed):
    """Group A's hot set overflows its home shard; every overflow row's
    second-hottest group is B — the spill must land on home(B), which has
    free capacity, never on the (emptier, earlier-in-shard-order) others."""
    rng = np.random.default_rng(seed)
    rows = n_shards * rows_per_shard
    a, b = 1, 2                       # homes 1 and 2: shard 0 stays coldest,
                                      # so shard-order spill would pick 0
    overflow = rows_per_shard // 2 + 1
    hot = rng.choice(rows, rows_per_shard + overflow, replace=False)
    traffic = np.zeros((n_shards, rows))
    traffic[a, hot] = 1000 + rng.integers(0, 50, len(hot))
    traffic[b, hot] = 10 + rng.integers(0, 5, len(hot))   # 2nd-hottest: B
    pm = solve_placement(traffic, n_shards, rows_per_shard, seed=seed)
    got = pm.shard_of_slot(hot)
    # A's home takes exactly its capacity of the hottest rows...
    assert (got == home_shard(a, n_shards)).sum() == rows_per_shard
    # ...and EVERY overflow row lands on B's home (capacity permitting:
    # overflow <= rows_per_shard by construction), not on shard 0
    spilled = got[got != home_shard(a, n_shards)]
    assert (spilled == home_shard(b, n_shards)).all(), got


def test_spill_falls_back_to_shard_order_when_second_choice_full():
    """When the second-hottest group's shard is also at capacity the
    leftover rows take the old shard-order fill — and the assignment stays
    a balanced bijection."""
    n_shards, rps = 3, 2
    rows = n_shards * rps
    traffic = np.zeros((n_shards, rows))
    # groups 1 and 2 both want ALL rows (1 hottest, 2 second) -> shards 1, 2
    # fill to capacity and the remaining rows must land on shard 0
    traffic[1] = 100 + np.arange(rows)
    traffic[2] = 10 + np.arange(rows)
    pm = solve_placement(traffic, n_shards, rps, seed=0)
    counts = np.bincount(pm.shard_of_slot(np.arange(rows)), minlength=n_shards)
    assert (counts == rps).all(), counts


def test_zero_traffic_group_is_never_a_spill_choice():
    """A group with zero traffic for a row must not attract its spill: the
    row's only real demand is group 1 (home 1); overflow rows fall back to
    shard order (shard 0 first), NOT to silent-zero groups' homes."""
    n_shards, rps = 4, 2
    rows = n_shards * rps
    traffic = np.zeros((n_shards, rows))
    traffic[1] = 50 + np.arange(rows)      # one group wants everything
    pm = solve_placement(traffic, n_shards, rps, seed=3)
    got = pm.shard_of_slot(np.arange(rows))
    assert (got == 1).sum() == rps
    # every shard still exactly at capacity (bijection invariant holds)
    counts = np.bincount(got, minlength=n_shards)
    assert (counts == rps).all(), counts


# ---------------------------------------------------------------------------
# CacheState: permuted mapping vs PR 2's arithmetic blocks
# ---------------------------------------------------------------------------

@settings(max_examples=15)
@given(n_shards=st.integers(1, 4), rows_per_shard=st.integers(1, 16))
def test_identity_placement_degrades_to_contiguous(n_shards, rows_per_shard):
    """CacheState with an identity placement == CacheState with none: the
    permuted mapping must decay bit-for-bit to PR 2's divmod blocks."""
    rows = n_shards * rows_per_shard
    g = powerlaw_graph(200, avg_degree=4, seed=0)
    state = sample_cache(g, CacheConfig(fraction=0.1, shards=n_shards),
                         np.random.default_rng(0), table_rows=rows,
                         n_shards=n_shards)
    slots = np.concatenate([[-1], np.arange(rows)])
    arith_shard = state.shard_of(slots).copy()
    arith_local = state.local_row(slots).copy()
    arith_dev = state.device_rows(slots).copy()
    state.placement = identity_placement(n_shards, rows)
    np.testing.assert_array_equal(state.shard_of(slots), arith_shard)
    np.testing.assert_array_equal(state.local_row(slots), arith_local)
    np.testing.assert_array_equal(state.device_rows(slots), arith_dev)
    assert state.placement.is_identity


@settings(max_examples=10)
@given(seed=st.integers(0, 10 ** 6))
def test_cache_state_permuted_roundtrip(seed):
    rng = np.random.default_rng(seed)
    g = powerlaw_graph(600, avg_degree=5, seed=1)
    n_shards = 4
    state = sample_cache(g, CacheConfig(fraction=0.1, shards=n_shards),
                         rng)
    rps = state.rows_per_shard
    state.placement = _random_placement(rng, 3, n_shards, rps)
    slots = state.slot_of[state.node_ids]
    dev = state.device_rows(slots)
    # shard*rps + local == device row, and the inverse recovers the slot
    np.testing.assert_array_equal(
        state.shard_of(slots) * rps + state.local_row(slots), dev)
    np.testing.assert_array_equal(
        state.placement.slot_of_device_row[dev], slots)


def test_padding_rows_never_handed_to_lookups():
    """Slots >= |C| (table padding) are placed on the device but must never
    come out of assemble_input: a lane pointing at a padding row would read
    all-zero garbage as a 'cached' feature."""
    g = powerlaw_graph(500, avg_degree=3, seed=2)
    feats = np.random.default_rng(2).standard_normal(
        (g.num_nodes, 8)).astype(np.float32)
    # random_walk mass from a tiny train set leaves most of V at zero
    # probability -> fewer real rows than the padded table
    cfg = CacheConfig(fraction=0.2, shards=4, placement="locality",
                      strategy="random_walk", walk_fanouts=(2,))
    store = FeatureStore(feats, g, cfg, importance_mode=None,
                         train_idx=np.array([0, 1, 2], dtype=np.int64))
    gen = store.refresh(np.random.default_rng(0))
    n = gen.state.size
    assert n < store.size, "test needs real padding rows"
    # force a non-trivial placement on the next generation
    rng = np.random.default_rng(3)
    for grp in range(4):
        ids = rng.choice(g.num_nodes, 64, replace=False).astype(np.int64)
        store.assemble_input(store.generation, ids, len(ids), group=grp)
    gen = store.refresh(np.random.default_rng(1), version=1)
    state = gen.state
    pad_dev_rows = set(
        state.device_rows(np.arange(state.size, store.size)).tolist())
    ids_p = rng.choice(g.num_nodes, 256, replace=False).astype(np.int64)
    slots, _, hits, _, _ = store.assemble_input(gen, ids_p, len(ids_p))
    assert hits > 0
    hit_rows = set(slots[slots >= 0].tolist())
    assert not (hit_rows & pad_dev_rows), (hit_rows, pad_dev_rows)
    # every hit row maps back to a REAL slot whose node is the requested id
    real = slots >= 0
    back = state.placement.slot_of_device_row[slots[real]] \
        if state.placement is not None else slots[real]
    np.testing.assert_array_equal(state.node_ids[back], ids_p[real])


def test_store_locality_generation_uploads_permuted_table():
    """Device table rows must follow the placement permutation: row
    device_row_of_slot[s] holds node_ids[s]'s features, bitwise."""
    g = powerlaw_graph(800, avg_degree=6, seed=3)
    feats = np.random.default_rng(4).integers(
        -64, 65, (g.num_nodes, 8)).astype(np.float32)
    store = FeatureStore(feats, g, CacheConfig(fraction=0.05, shards=4,
                                               placement="locality"))
    store.refresh(np.random.default_rng(0))
    rng = np.random.default_rng(5)
    for grp in range(4):
        ids = rng.choice(g.num_nodes, 96, replace=False).astype(np.int64)
        store.assemble_input(store.generation, ids, len(ids), group=grp)
    gen = store.refresh(np.random.default_rng(1), version=1)
    state = gen.state
    assert state.placement is not None and not state.placement.is_identity
    dev = state.device_rows(np.arange(state.size))
    np.testing.assert_array_equal(np.asarray(gen.table)[dev],
                                  feats[state.node_ids])
    # staging tier stays in LOGICAL order (host reads are placement-blind)
    np.testing.assert_array_equal(gen.staged[:state.size],
                                  feats[state.node_ids])
    rows = store.gather_rows(state.node_ids[:50], gen=gen, record=False)
    np.testing.assert_array_equal(rows, feats[state.node_ids[:50]])


def test_contiguous_config_never_permutes():
    """placement='contiguous' (the reproducibility switch) must keep the
    PR 2 layout even when traffic histograms exist."""
    g = powerlaw_graph(500, avg_degree=5, seed=6)
    feats = np.random.default_rng(6).standard_normal(
        (g.num_nodes, 8)).astype(np.float32)
    store = FeatureStore(feats, g, CacheConfig(fraction=0.05, shards=4))
    store.refresh(np.random.default_rng(0))
    rng = np.random.default_rng(7)
    for grp in range(4):
        ids = rng.choice(g.num_nodes, 64, replace=False).astype(np.int64)
        store.assemble_input(store.generation, ids, len(ids), group=grp)
    gen = store.refresh(np.random.default_rng(1), version=1)
    assert gen.state.placement is None
    n = gen.state.size
    np.testing.assert_array_equal(np.asarray(gen.table)[:n],
                                  feats[gen.state.node_ids])

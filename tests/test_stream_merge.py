"""Streaming-ingest unit layer: merge_delta_csr properties + DeltaBuffer.

Property tests (hypothesis, or the fallback shim in bare environments)
pin the merge kernel's one contract — **merge ≡ rebuild**: applying a
drained delta batch to a CSR must produce the *bitwise* CSR a from-scratch
``CSRGraph.from_edges`` over the post-delta edge set produces (indptr AND
indices AND dtypes).  Everything downstream (eq. 11 probabilities, cache
membership, routing) trusts that equivalence.

All jax-free: the merge is pure host-side numpy, and the buffer is a
plain threading.Lock structure.
"""
import threading

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                          # pragma: no cover
    from _hypothesis_fallback import given, settings, st

from repro.graph.csr import CSRGraph
from repro.serve.server import QueueFull
from repro.stream import DeltaBatch, DeltaBuffer, merge_delta_csr


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _random_graph(rng, num_nodes, num_edges):
    src = rng.integers(0, num_nodes, size=num_edges)
    dst = rng.integers(0, num_nodes, size=num_edges)
    return CSRGraph.from_edges(src, dst, num_nodes), (src, dst)


def _edge_set(g: CSRGraph):
    """Directed edge set of a CSR as a set of (u, v) pairs."""
    u = np.repeat(np.arange(g.num_nodes), np.diff(g.indptr))
    return set(zip(u.tolist(), g.indices.tolist()))


def _batch(src, dst, op, *, node_feats=None, node_base=0, seq0=0):
    src = np.asarray(src, dtype=np.int64)
    op = np.asarray(op, dtype=np.int8)
    seq = np.arange(seq0, seq0 + len(src), dtype=np.int64)
    return DeltaBatch(
        edge_src=src, edge_dst=np.asarray(dst, dtype=np.int64),
        edge_op=op, edge_seq=seq,
        node_feats=node_feats,
        node_labels=None if node_feats is None
        else np.zeros(len(node_feats), np.int64),
        node_base=node_base,
        first_seq=seq0, last_seq=seq0 + max(len(src) - 1, 0))


def _rebuild_reference(g: CSRGraph, batch: DeltaBatch) -> CSRGraph:
    """From-scratch post-delta rebuild: replay ops on the edge SET, then
    run the canonical ``from_edges`` construction."""
    v_new = g.num_nodes + batch.num_new_nodes
    edges = _edge_set(g)
    for s, d, o in zip(batch.edge_src.tolist(), batch.edge_dst.tolist(),
                       batch.edge_op.tolist()):
        if s == d:
            continue
        pairs = [(s, d), (d, s)]             # symmetrized, like from_edges
        for p in pairs:
            if o > 0:
                edges.add(p)
            else:
                edges.discard(p)
    if edges:
        src, dst = map(np.asarray, zip(*sorted(edges)))
    else:
        src = dst = np.zeros(0, np.int64)
    # already symmetrized + loop-free: plain dedup build over the pair set
    return CSRGraph.from_edges(src, dst, v_new, symmetrize=False)


def _assert_bitwise_equal(a: CSRGraph, b: CSRGraph):
    assert a.num_nodes == b.num_nodes
    assert a.indptr.dtype == b.indptr.dtype, (a.indptr.dtype, b.indptr.dtype)
    assert a.indices.dtype == b.indices.dtype
    assert np.array_equal(a.indptr, b.indptr)
    assert np.array_equal(a.indices, b.indices)


# ---------------------------------------------------------------------------
# merge ≡ rebuild (the central property)
# ---------------------------------------------------------------------------

@settings(max_examples=25)
@given(seed=st.integers(0, 10_000),
       num_nodes=st.integers(2, 40),
       num_edges=st.integers(0, 120),
       num_ops=st.integers(0, 60),
       n_new=st.integers(0, 6))
def test_merge_equals_rebuild(seed, num_nodes, num_edges, num_ops, n_new):
    rng = np.random.default_rng(seed)
    g, _ = _random_graph(rng, num_nodes, num_edges)
    v_new = num_nodes + n_new
    src = rng.integers(0, v_new, size=num_ops)
    dst = rng.integers(0, v_new, size=num_ops)
    op = rng.choice(np.array([1, -1], np.int8), size=num_ops)
    feats = (np.zeros((n_new, 4), np.float32) if n_new else None)
    batch = _batch(src, dst, op, node_feats=feats, node_base=num_nodes)
    merged = merge_delta_csr(g, batch)
    _assert_bitwise_equal(merged, _rebuild_reference(g, batch))


@settings(max_examples=15)
@given(seed=st.integers(0, 10_000))
def test_duplicate_insert_idempotent(seed):
    rng = np.random.default_rng(seed)
    g, _ = _random_graph(rng, 30, 80)
    src = rng.integers(0, 30, size=20)
    dst = rng.integers(0, 30, size=20)
    once = merge_delta_csr(g, _batch(src, dst, np.ones(20)))
    # same inserts again, twice over — including edges that already exist
    src3, dst3 = np.tile(src, 2), np.tile(dst, 2)
    thrice = merge_delta_csr(once, _batch(src3, dst3, np.ones(40)))
    _assert_bitwise_equal(once, thrice)


@settings(max_examples=15)
@given(seed=st.integers(0, 10_000))
def test_last_op_wins_within_batch(seed):
    rng = np.random.default_rng(seed)
    g, _ = _random_graph(rng, 25, 60)
    s, d = 3, 7
    # insert-then-delete → absent
    out = merge_delta_csr(g, _batch([s, s], [d, d], [1, -1]))
    assert (s, d) not in _edge_set(out) and (d, s) not in _edge_set(out)
    # delete-then-insert → present
    out = merge_delta_csr(g, _batch([s, s], [d, d], [-1, 1]))
    es = _edge_set(out)
    assert (s, d) in es and (d, s) in es


def test_delete_then_reinsert_round_trip_across_drains():
    """delete in one drained batch, re-insert in the next → the original
    structure comes back bitwise (merge is history-free)."""
    rng = np.random.default_rng(7)
    g, _ = _random_graph(rng, 40, 150)
    # pick genuinely-present edges to remove
    u = np.repeat(np.arange(g.num_nodes), np.diff(g.indptr))
    pick = rng.choice(len(u), size=min(10, g.num_edges), replace=False)
    s, d = u[pick], g.indices[pick].astype(np.int64)
    after_del = merge_delta_csr(g, _batch(s, d, -np.ones(len(s))))
    assert after_del.num_edges < g.num_edges
    after_reins = merge_delta_csr(
        after_del, _batch(s, d, np.ones(len(s)), seq0=100))
    _assert_bitwise_equal(g, after_reins)


@settings(max_examples=15)
@given(seed=st.integers(0, 10_000))
def test_sorted_indices_invariant(seed):
    """Per-row neighbor lists stay strictly increasing (the CSR invariant
    every binary-search consumer relies on)."""
    rng = np.random.default_rng(seed)
    g, _ = _random_graph(rng, 35, 100)
    src = rng.integers(0, 35, size=40)
    dst = rng.integers(0, 35, size=40)
    op = rng.choice(np.array([1, -1], np.int8), size=40)
    m = merge_delta_csr(g, _batch(src, dst, op))
    for r in range(m.num_nodes):
        row = m.indices[m.indptr[r]:m.indptr[r + 1]]
        assert np.all(np.diff(row) > 0), (r, row)


def test_empty_batch_is_identity():
    rng = np.random.default_rng(3)
    g, _ = _random_graph(rng, 20, 50)
    out = merge_delta_csr(g, _batch([], [], []))
    _assert_bitwise_equal(g, out)


# ---------------------------------------------------------------------------
# DeltaBuffer: admission, sequencing, drain atomicity
# ---------------------------------------------------------------------------

def test_buffer_bounded_admission():
    buf = DeltaBuffer(10, 4, max_pending=5)
    buf.add_edges([0, 1, 2], [3, 4, 5])
    with pytest.raises(QueueFull):
        buf.add_edges([0, 1, 2], [3, 4, 5])       # 3 + 3 > 5
    assert buf.pending() == 3 and buf.rejected == 3
    buf.add_edges([6], [7])                       # 3 + 1 fits
    assert buf.pending() == 4
    batch = buf.drain()
    assert batch.num_ops == 4 and buf.pending() == 0
    # capacity freed by the drain
    buf.add_edges([0, 1, 2], [3, 4, 5])
    assert buf.pending() == 3


def test_buffer_seq_monotonic_and_drain_order():
    buf = DeltaBuffer(10, 4)
    s0 = buf.add_edges([0, 1], [2, 3])
    s1 = buf.delete_edges([4], [5])
    assert s1 == s0 + 2
    b = buf.drain()
    assert np.array_equal(b.edge_seq, np.arange(3))
    assert np.array_equal(b.edge_op, [1, 1, -1])
    assert b.first_seq == 0 and b.last_seq == 2
    # seq keeps counting across drains
    s2 = buf.add_edges([6], [7])
    assert s2 == 3 and buf.drain().first_seq == 3


def test_buffer_add_nodes_contiguous_ids_and_edge_bounds():
    buf = DeltaBuffer(100, 3)
    ids = buf.add_nodes(np.zeros((4, 3), np.float32))
    assert np.array_equal(ids, np.arange(100, 104))
    assert buf.next_node == 104
    buf.add_edges(ids[:2], [0, 1])                # staged ids usable at once
    with pytest.raises(AssertionError):
        buf.add_edges([104], [0])                 # beyond the staged space
    b = buf.drain()
    assert b.num_new_nodes == 4 and b.node_base == 100
    assert b.node_labels is not None and len(b.node_labels) == 4


def test_buffer_drain_empty_returns_none():
    buf = DeltaBuffer(5, 2)
    assert buf.drain() is None
    assert buf.pending() == 0


def test_buffer_concurrent_producers_unique_seqs():
    buf = DeltaBuffer(64, 2, max_pending=100_000)
    n_threads, per = 8, 200

    def work():
        for _ in range(per):
            buf.add_edges([1], [2])

    ts = [threading.Thread(target=work) for _ in range(n_threads)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    b = buf.drain()
    assert b.num_ops == n_threads * per
    assert len(np.unique(b.edge_seq)) == b.num_ops
    assert buf.admitted == n_threads * per


def test_buffer_payload_bytes():
    buf = DeltaBuffer(10, 4)
    buf.add_edges([0, 1], [2, 3])
    buf.add_nodes(np.zeros((2, 4), np.float32))
    b = buf.drain()
    expect = (2 * 8 * 3) + (2 * 1)      # src+dst+seq int64, op int8
    expect += 2 * 4 * 4 + 2 * 8         # feats f32, labels int64
    assert b.payload_bytes == expect

"""Fixture: lock-discipline violations (never imported — parsed only)."""
import threading

from repro.analysis import guarded_by


@guarded_by("_lock", "_shadow", "_pending", writes_only=("_live",))
class Store:
    def __init__(self):
        self._lock = threading.Lock()
        self._shadow = None
        self._pending = None
        self._live = None
        self._thread = threading.Thread(target=self._refresh)
        self._thread.start()

    def _refresh(self):
        with self._lock:
            self._shadow = object()
        self._pending = True         # lock-unguarded-write

    def peek(self):
        return self._shadow          # lock-unguarded-read

    def swap(self):
        with self._lock:
            self._live = self._shadow   # both under the lock: clean
            self._shadow = None

    def publish(self, gen):
        self._live = gen             # lock-unguarded-write (writes_only attr)


def poll(store):
    return store._shadow             # lock-unguarded-read (external access)

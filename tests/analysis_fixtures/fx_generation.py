"""Fixture: generation-pinning violations (never imported — parsed only)."""


def tearing_batch(store, ids):
    slots = store.generation.state.slot_of[ids]     # gen-chained-read
    table = store.generation.table                  # gen-chained-read (+2nd
    return slots, table                             # read: gen-multi-read)


def peek_buffers(store):
    return store._shadow is not None                # gen-direct-private


def pinned_batch(store, ids):
    gen = store.generation                          # single snapshot: clean
    return gen.state.slot_of[ids], gen.table

"""Fixture: meter-pairing warning (never imported — parsed only).

Lives under a path the meter lint scopes to via --root; the scan root for
fixtures makes every file in scope.
"""
import jax
import jax.numpy as jnp


def unbooked_upload(buf, sharding):
    tbl = jax.device_put(jnp.asarray(buf), sharding)   # meter-unpaired-transfer
    return tbl


def booked_upload(buf, sharding, meter):
    tbl = jax.device_put(jnp.asarray(buf), sharding)
    meter.bytes_cache_upload += int(tbl.nbytes)        # paired: clean
    return tbl

"""Fixture: retrace-hazard violations (never imported — parsed only)."""
import functools

import jax


@jax.jit
def stepper(params, batch, n_steps: int):     # retrace-scalar-arg: n_steps
    return params, batch, n_steps


@functools.partial(jax.jit, static_argnames=("n_steps",))
def stepper_ok(params, batch, n_steps: int):  # static: clean
    return params, batch, n_steps


def drive(params, batches):
    out = []
    for b in batches:
        out.append(stepper(params, b, len(b)))   # retrace-scalar-flow
    return out

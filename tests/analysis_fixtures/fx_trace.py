"""Fixture: trace-purity violations (never imported — parsed only)."""
import random
import time

import jax

_EVENTS = []


@jax.jit
def impure_step(x, flag):
    if flag:                         # trace-host-branch: `flag` not static
        x = x + 1
    noise = random.random()          # trace-nondeterminism
    t0 = time.perf_counter()         # trace-nondeterminism
    _EVENTS.append(t0)               # trace-mutation (closed-over list)
    return x * noise


@jax.jit
def counting_step(x):
    global _COUNT                    # trace-global-state
    _COUNT = 1
    return x


class Model:
    def __call__(self, x):
        return jax.jit(self._fwd)(x)

    def _fwd(self, x):
        self.calls = 0               # trace-self-mutation
        return x

"""ServeFabric chaos battery: stall, death, failover, swap-in-flight.

In-process (meshless, tiny dataset, runtime lock sanitizer armed by
conftest):

* a STALLED worker leaves the routing rotation, its queued requests are
  re-routed to a healthy worker, and it re-enters the rotation when it
  wakes up;
* a KILLED worker (thread aborts mid-batch) has its in-flight batch
  reclaimed by the watchdog and re-routed — the request is still served;
* with every worker dead, requests fail fast with :class:`WorkerDown`;
* a mid-stream generation swap UNDER an in-flight (stalled) batch leaves
  its result bitwise-identical to a no-swap fabric run and pinned to the
  old generation — the single-server guarantee survives the fleet.

Subprocess (4 forced host devices, ``@pytest.mark.dryrun`` — the CI
``fabric-smoke`` acceptance): a 2-worker fabric on the 2x2 sharded fused
mesh serving two tenants with skewed disjoint hot sets — per-tenant
isolation holds, routing is majority-local (> 0.5), p99 stays bounded,
and a worker kill mid-stream fails over without losing a request.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.analysis import TrackedLock
from repro.core.sampler import SamplerConfig
from repro.featurestore import CacheConfig
from repro.gns import (EngineConfig, FabricConfig, GNSEngine, ServeConfig,
                       TenantConfig)
from repro.graph.datasets import get_dataset
from repro.serve import ServeFabric, WorkerDown


@pytest.fixture(scope="module")
def tiny_ds():
    return get_dataset("tiny", seed=0)


def _engine(tiny_ds, seed=0):
    scfg = SamplerConfig(fanouts=(3, 4), batch_size=32,
                         cache=CacheConfig(fraction=0.1))
    cfg = EngineConfig(sampler="gns", sampling=scfg, cache=scfg.cache,
                       seed=seed,
                       serve=ServeConfig(buckets=(8, 32), max_wait_ms=5.0))
    return GNSEngine(cfg, dataset=tiny_ds)


def _fabric(eng, **kw):
    kw.setdefault("workers", 2)
    kw.setdefault("stall_timeout_ms", 100.0)
    kw.setdefault("watch_interval_ms", 20.0)
    return ServeFabric(eng, cfg=FabricConfig(**kw))


def _wait(pred, timeout=10.0, interval=0.01):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(interval)
    return False


# ---------------------------------------------------------------------------
# failover
# ---------------------------------------------------------------------------

def test_stalled_worker_requests_rerouted_then_recovers(tiny_ds):
    eng = _engine(tiny_ds)
    fab = _fabric(eng)
    assert isinstance(fab._sample_lock, TrackedLock)   # sanitizer sees it
    with fab:
        fab.infer(tiny_ds.val_idx[:4], timeout=120)    # warm both workers'
        fab.infer(tiny_ds.val_idx[4:8], timeout=120)   # compiled step
        w0 = fab.workers[0]
        w0.stall_s = 0.8                               # >> stall_timeout
        stuck = fab.submit(tiny_ds.val_idx[:4], worker=0)
        # wait until the batch is actually in flight (prepare done, stalled)
        assert _wait(lambda: len(w0._inflight) > 0)
        # these pile up in worker 0's scheduler behind the stall ...
        queued = [fab.submit(tiny_ds.val_idx[i * 4:(i + 1) * 4], worker=0)
                  for i in range(1, 4)]
        # ... until the watchdog declares the stall and re-routes them
        assert _wait(lambda: fab.healthy() == [1]), fab.healthy()
        for f in queued:
            assert f.result(timeout=120).status == "ok"
        # the stalled batch itself still completes (the worker lives)
        assert stuck.result(timeout=120).status == "ok"
        w0.stall_s = 0.0
        # a fresh heartbeat puts worker 0 back into the rotation
        assert _wait(lambda: fab.healthy() == [0, 1]), fab.healthy()
    m = fab.meter
    assert m.failovers >= 1
    assert m.retries_total >= 3
    snap = m.snapshot()
    assert snap["errors"] == 0
    assert snap["routing"]["worker_batches"].get(1, 0) >= 1


def test_killed_worker_inflight_reclaimed_and_served(tiny_ds):
    eng = _engine(tiny_ds)
    fab = _fabric(eng)
    with fab:
        fab.infer(tiny_ds.val_idx[:4], timeout=120)    # warm
        w0 = fab.workers[0]
        w0.stall_s = 0.3          # hold the batch so the kill flag is seen
        w0.kill()                 # next batch aborts the thread mid-flight
        fut = fab.submit(tiny_ds.val_idx[:8], worker=0)
        assert _wait(lambda: not w0.alive()), "worker thread did not die"
        # the watchdog reclaims the in-flight batch and re-routes it
        res = fut.result(timeout=120)
        assert res.status == "ok"
        # a dead worker never recovers
        assert fab.healthy() == [1]
        # un-pinned traffic keeps flowing through the survivor
        assert fab.infer(tiny_ds.val_idx[:4], timeout=120).shape[0] == 4
    m = fab.meter
    assert m.failovers >= 1 and m.retries_total >= 1
    assert m.errors == 0


def test_all_workers_dead_fails_fast(tiny_ds):
    eng = _engine(tiny_ds)
    fab = _fabric(eng, workers=1)
    with fab:
        fab.infer(tiny_ds.val_idx[:4], timeout=120)    # warm
        w0 = fab.workers[0]
        w0.kill()
        fut = fab.submit(tiny_ds.val_idx[:4], worker=0)
        assert _wait(lambda: not w0.alive())
        with pytest.raises(WorkerDown):
            fut.result(timeout=120)
        with pytest.raises(WorkerDown):                # un-pinned submit too
            _wait(lambda: fab.healthy() == [], timeout=5.0)
            fab.submit(tiny_ds.val_idx[:4])


# ---------------------------------------------------------------------------
# swap under an in-flight batch: bitwise identity across the fleet
# ---------------------------------------------------------------------------

def test_inflight_results_bitwise_identical_across_swap(tiny_ds):
    """Two fabrics, same seed, all requests pinned to worker 0 and served
    one at a time.  Fabric B's last request is held in flight (stall hook,
    after sampling) while the live generation is swapped under it — its
    logits must equal fabric A's no-swap run bitwise, still pinned to the
    old generation; the NEXT request adopts the new one."""
    chunks = [tiny_ds.val_idx[i * 8:(i + 1) * 8] for i in range(5)]

    def run(swap_under_last):
        eng = _engine(tiny_ds, seed=3)
        # huge stall timeout: the stall must NOT trigger failover here
        fab = _fabric(eng, stall_timeout_ms=60_000.0)
        out = []
        with fab:
            w0 = fab.workers[0]
            for i, ids in enumerate(chunks):
                if swap_under_last and i == len(chunks) - 1:
                    w0.stall_s = 1.5
                    fut = fab.submit(ids, worker=0)
                    assert _wait(lambda: len(w0._inflight) > 0)
                    # the batch is sampled and pinned; swap the live
                    # generation UNDER it
                    v0 = eng.store.version
                    eng.store.refresh(np.random.default_rng(99),
                                      version=v0 + 1)
                    assert eng.store.version == v0 + 1
                    out.append(fut.result(timeout=120))
                    w0.stall_s = 0.0
                else:
                    out.append(fab.submit(ids, worker=0).result(timeout=120))
            if swap_under_last:
                # a fresh request adopts the new generation (monotonic)
                follow = fab.submit(chunks[0], worker=0).result(timeout=120)
                assert follow.cache_version == out[-1].cache_version + 1
        return out

    plain = run(swap_under_last=False)
    swapped = run(swap_under_last=True)
    assert all(r.status == "ok" for r in plain + swapped)
    for a, b in zip(plain, swapped):
        np.testing.assert_array_equal(a.logits, b.logits)
        assert a.cache_version == b.cache_version == 0


# ---------------------------------------------------------------------------
# subprocess: the CI fabric-smoke acceptance (4 forced host devices)
# ---------------------------------------------------------------------------

FABRIC_SMOKE_CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ["REPRO_LOCK_SANITIZER"] = "1"
import time
import numpy as np
import jax

from repro.analysis import enable_sanitizer
enable_sanitizer(True)

from repro.core.sampler import SamplerConfig
from repro.featurestore import CacheConfig
from repro.gns import (EngineConfig, FabricConfig, GNSEngine, ServeConfig,
                       TenantConfig)
from repro.gns.config import MeshConfig, ModelConfig

assert len(jax.devices()) == 4

# production shape at CI scale: 2 DP groups x 2 cache shards, fused input,
# locality placement — each fabric worker owns one DP group/home shard
scfg = SamplerConfig(fanouts=(3, 4), batch_size=32,
                     cache=CacheConfig(fraction=0.05, strategy="adaptive",
                                       placement="locality"))
cfg = EngineConfig(sampler="gns", sampling=scfg, cache=scfg.cache,
                   model=ModelConfig(input_impl="fused", hidden_dim=16),
                   mesh=MeshConfig(data=2, model=2),
                   serve=ServeConfig(buckets=(8, 32), max_wait_ms=2.0),
                   seed=0)
eng = GNSEngine(cfg)
assert eng.store.n_shards == 2
ds = eng.ds

fab = eng.serve_fabric(FabricConfig(
    workers=2,
    tenants=(TenantConfig("mobile", weight=2.0, max_queue=64),
             TenantConfig("batch", weight=1.0, max_queue=64)),
    stall_timeout_ms=2000.0, watch_interval_ms=50.0))

rng = np.random.default_rng(7)
# skewed DISJOINT per-tenant hot sets: routing + placement should converge
# each tenant's traffic onto one worker's home shard
half = len(ds.val_idx) // 2
hot_a = rng.choice(ds.val_idx[:half], size=30, replace=False)
hot_b = rng.choice(ds.val_idx[half:], size=30, replace=False)

with fab:
    futs = []
    for i in range(60):
        tenant, hot = (("mobile", hot_a) if i % 2 == 0 else ("batch", hot_b))
        ids = rng.choice(hot, size=int(rng.integers(2, 8)), replace=False)
        futs.append(fab.submit(ids, tenant=tenant))
    res = [f.result(timeout=600) for f in futs]
    assert all(r.status == "ok" for r in res), [r.status for r in res]

    # chaos mid-stream: kill worker 0, traffic fails over losslessly
    fab.workers[0].kill()
    fut = fab.submit(rng.choice(hot_a, size=4, replace=False),
                     tenant="mobile", worker=0)
    deadline = time.monotonic() + 60
    while fab.workers[0].alive() and time.monotonic() < deadline:
        time.sleep(0.05)
    assert not fab.workers[0].alive()
    assert fut.result(timeout=600).status == "ok"      # re-routed + served
    tail = [fab.submit(rng.choice(hot_b, size=4, replace=False),
                       tenant="batch") for _ in range(6)]
    assert all(f.result(timeout=600).status == "ok" for f in tail)
    assert fab.healthy() == [1]

snap = fab.meter.snapshot()

# 1) per-tenant isolation ledger: both tenants fully served, nothing shed
for t in ("mobile", "batch"):
    assert snap["tenants"][t]["rejected"] == 0, snap["tenants"]
assert snap["tenants"]["mobile"]["served"] >= 31
assert snap["tenants"]["batch"]["served"] >= 36

# 2) placement-aware routing: majority of owned ids routed to their owner
rt = snap["routing"]
assert rt["routed_known_ids"] > 0, rt
assert rt["route_local_fraction"] > 0.5, rt
# both workers actually served before the kill
assert set(rt["worker_batches"]) == {0, 1}, rt

# 3) failover happened and was lossless
assert rt["failovers"] >= 1 and rt["retries"] >= 1, rt
assert snap["errors"] == 0, snap

# 4) p99 bounded on the CI box
assert snap["total_p99_ms"] is not None and snap["total_p99_ms"] < 60000, snap

print("FABRIC_SMOKE_OK", "local=", rt["route_local_fraction"],
      "p99_ms=", snap["total_p99_ms"], "failovers=", rt["failovers"])
"""


def _run_sub(code: str, timeout: int = 900) -> str:
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run(
        [sys.executable, "-c", code],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


@pytest.mark.dryrun
def test_fabric_smoke_on_mesh_subprocess():
    """The CI fabric-smoke acceptance: 2 workers on the forced-host 2x2
    mesh, two skewed tenants — isolation, majority-local routing, lossless
    kill-failover, bounded p99, lock sanitizer armed throughout."""
    out = _run_sub(FABRIC_SMOKE_CODE)
    assert "FABRIC_SMOKE_OK" in out, out[-3000:]

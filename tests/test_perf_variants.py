"""§Perf beyond-paper variants must be EXACT versus their baselines.

Forward-debug policy (system methodology): each optimization is validated
against the unoptimized implementation to machine-ish tolerance before its
roofline delta is recorded in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import xlstm as xl
from repro.models.lm import get_model, make_batch


def test_chunked_mlstm_equals_parallel_cell():
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    b, h, s, d = 2, 3, 24, 8
    q = jax.random.normal(ks[0], (b, h, s, d))
    k = jax.random.normal(ks[1], (b, h, s, d))
    v = jax.random.normal(ks[2], (b, h, s, d))
    i_raw = jax.random.normal(ks[3], (b, h, s)) * 2
    logf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, h, s)))

    fcum = jnp.cumsum(logf, -1)
    dmat = fcum[..., :, None] - fcum[..., None, :] + i_raw[..., None, :]
    mask = jnp.arange(s)[:, None] >= jnp.arange(s)[None, :]
    dmat = jnp.where(mask[None, None], dmat, -jnp.inf)
    m = dmat.max(-1)
    w = jnp.exp(dmat - m[..., None])
    cw = jnp.einsum("bhtd,bhsd->bhts", q, k) * w
    ref = jnp.einsum("bhts,bhsv->bhtv", cw, v) / \
        jnp.maximum(jnp.abs(cw.sum(-1)), jnp.exp(-m))[..., None]

    for chunk in (4, 6, 12):
        out = xl._mlstm_chunked(q, k, v, i_raw, logf, chunk=chunk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-5)


def test_chunked_mlstm_full_model():
    cfg = get_config("xlstm-125m").reduced()
    cfg_c = dataclasses.replace(
        cfg, xlstm=dataclasses.replace(cfg.xlstm, chunk=8))
    m, mc = get_model(cfg), get_model(cfg_c)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 32, 2, jax.random.PRNGKey(1))
    np.testing.assert_allclose(float(m.loss(params, batch)),
                               float(mc.loss(params, batch)), rtol=1e-5)


@pytest.mark.parametrize("arch", ["qwen2-7b", "gemma-2b"])  # untied + tied
def test_chunked_ce_exact(arch):
    cfg = get_config(arch).reduced()
    cfg_c = dataclasses.replace(cfg, chunked_ce=8)
    m, mc = get_model(cfg), get_model(cfg_c)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 32, 2, jax.random.PRNGKey(1))
    np.testing.assert_allclose(float(m.loss(params, batch)),
                               float(mc.loss(params, batch)), rtol=1e-5)
    g0 = jax.grad(m.loss)(params, batch)
    g1 = jax.grad(mc.loss)(params, batch)
    for a, b in zip(jax.tree_util.tree_leaves(g0),
                    jax.tree_util.tree_leaves(g1)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-4, atol=5e-6)

"""Per-kernel allclose vs pure-jnp oracles, interpret=True on CPU.

Sweeps shapes/dtypes per the deliverable spec; hypothesis drives randomized
index/weight patterns for gather_agg.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                          # bare env: seeded fallback shim
    from _hypothesis_fallback import given, settings, st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gather_agg import gather_agg_pallas
from repro.kernels.ops import flash_attention, gather_agg


# ---------------------------------------------------------------------------
# gather_agg
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d,b,k", [
    (32, 16, 8, 4),
    (128, 64, 16, 8),
    (1000, 128, 32, 15),
    (64, 96, 7, 5),       # d not a power of two
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_agg_matches_ref(n, d, b, k, dtype):
    rng = np.random.default_rng(0)
    feat = jnp.asarray(rng.normal(size=(n, d)), dtype)
    idx = jnp.asarray(rng.integers(0, n, (b, k)), jnp.int32)
    w = jnp.asarray(rng.random((b, k)), jnp.float32)
    out = gather_agg_pallas(feat, idx, w, block_d=min(d, 64), interpret=True)
    expect = ref.gather_agg_ref(feat, idx, w)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=tol, atol=tol)


def test_gather_agg_zero_weight_lanes_ignore_index():
    """Padded lanes (w=0) must not contribute, whatever their index."""
    feat = jnp.asarray(np.full((10, 8), 1e30), jnp.float32)
    idx = jnp.zeros((4, 3), jnp.int32)
    w = jnp.zeros((4, 3), jnp.float32)
    out = gather_agg_pallas(feat, idx, w, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


@given(
    n=st.integers(4, 200),
    b=st.integers(1, 16),
    k=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_gather_agg_property(n, b, k, seed):
    rng = np.random.default_rng(seed)
    d = int(rng.choice([8, 16, 32]))
    feat = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n, (b, k)), jnp.int32)
    w = jnp.asarray(rng.normal(size=(b, k)), jnp.float32)
    out = gather_agg_pallas(feat, idx, w, block_d=d, interpret=True)
    expect = ref.gather_agg_ref(feat, idx, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)


def test_gather_agg_ops_wrapper_dispatch():
    rng = np.random.default_rng(1)
    feat = jnp.asarray(rng.normal(size=(50, 24)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 50, (6, 4)), jnp.int32)
    w = jnp.asarray(rng.random((6, 4)), jnp.float32)
    out_k = gather_agg(feat, idx, w, impl="pallas")
    out_r = gather_agg(feat, idx, w, impl="reference")
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

def _rand_qkv(rng, b, hq, hkv, sq, sk, dh, dtype=jnp.float32):
    q = jnp.asarray(rng.normal(size=(b, hq, sq, dh)), dtype)
    k = jnp.asarray(rng.normal(size=(b, hkv, sk, dh)), dtype)
    v = jnp.asarray(rng.normal(size=(b, hkv, sk, dh)), dtype)
    return q, k, v


@pytest.mark.parametrize("b,hq,hkv,s,dh,blk", [
    (1, 2, 2, 64, 32, 16),     # MHA
    (2, 4, 2, 128, 64, 32),    # GQA group=2
    (1, 8, 1, 64, 64, 16),     # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_causal_matches_ref(b, hq, hkv, s, dh, blk, dtype):
    rng = np.random.default_rng(0)
    q, k, v = _rand_qkv(rng, b, hq, hkv, s, s, dh, dtype)
    out = flash_attention_pallas(q, k, v, causal=True, block_q=blk,
                                 block_k=blk, interpret=True)
    expect = ref.mha_ref(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


def test_flash_sliding_window():
    rng = np.random.default_rng(1)
    q, k, v = _rand_qkv(rng, 1, 2, 2, 128, 128, 32)
    out = flash_attention_pallas(q, k, v, causal=True, window=32,
                                 block_q=32, block_k=32, interpret=True)
    expect = ref.mha_ref(q, k, v, causal=True, window=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


def test_flash_cross_attention_no_causal():
    rng = np.random.default_rng(2)
    q, k, v = _rand_qkv(rng, 2, 2, 2, 32, 96, 32)
    out = flash_attention_pallas(q, k, v, causal=False, block_q=16,
                                 block_k=32, interpret=True)
    expect = ref.mha_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


def test_flash_decode_one_token_against_cache():
    """Sq=1 decode against a longer KV cache, end-aligned positions."""
    rng = np.random.default_rng(3)
    q, k, v = _rand_qkv(rng, 1, 4, 2, 1, 256, 64)
    out = flash_attention(q, k, v, causal=True, block_q=16, block_k=64)
    expect = ref.mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


def test_flash_kv_len_masks_padding():
    """Keys beyond kv_len must be invisible."""
    rng = np.random.default_rng(4)
    q, k, v = _rand_qkv(rng, 1, 2, 2, 32, 64, 32)
    # poison the padded tail
    k = k.at[:, :, 48:, :].set(1e5)
    v = v.at[:, :, 48:, :].set(1e5)
    out = flash_attention_pallas(q, k, v, causal=False, kv_len=48,
                                 q_offset=48 - 32, block_q=16, block_k=16,
                                 interpret=True)
    expect = ref.mha_ref(q, k[:, :, :48], v[:, :, :48], causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


def test_flash_ops_wrapper_pads_odd_lengths():
    rng = np.random.default_rng(5)
    q, k, v = _rand_qkv(rng, 1, 2, 1, 37, 53, 32)
    out = flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
    expect = ref.mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# kernel wired into the model
# ---------------------------------------------------------------------------

def test_graphsage_pallas_impl_matches_reference():
    import dataclasses
    from repro.core.sampler import SamplerConfig, make_sampler
    from repro.graph.datasets import get_dataset
    from repro.models import graphsage

    ds = get_dataset("tiny", seed=0)
    cfg = SamplerConfig(fanouts=(3, 4, 5), batch_size=8)
    s = make_sampler("ns", ds.graph, cfg, ds.features, ds.labels)
    rng = np.random.default_rng(0)
    s.start_epoch(0, rng)
    mb = s.sample(rng.choice(ds.train_idx, 8, replace=False).astype(np.int64), rng)

    mcfg = graphsage.SageConfig(feat_dim=ds.feat_dim, hidden_dim=16,
                                num_classes=ds.num_classes)
    params = graphsage.init_params(jax.random.PRNGKey(0), mcfg)
    table = graphsage.dummy_cache_table(ds.feat_dim)
    ref_logits = graphsage.forward(params, mb.device, table, mcfg)
    pal_cfg = dataclasses.replace(mcfg, aggregate_impl="pallas")
    pal_logits = graphsage.forward(params, mb.device, table, pal_cfg)
    np.testing.assert_allclose(np.asarray(pal_logits), np.asarray(ref_logits),
                               rtol=1e-4, atol=1e-4)

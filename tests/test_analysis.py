"""gnscheck's own coverage: every rule fires on its fixture (positive),
the repo at HEAD is clean against the checked-in baseline (negative), the
baseline ratchet rejects both new and stale entries, and the runtime lock
sanitizer actually raises on unguarded writes and lock-order inversions.
"""
import subprocess
import sys
import threading
from pathlib import Path

import pytest

from repro.analysis import (LockDisciplineError, LockOrderError, TrackedLock,
                            enable_sanitizer, guarded_by, holds_lock,
                            reset_lock_order, sanitizer_enabled)
from repro.analysis.baseline import compare, keyed, load, write
from repro.analysis.common import RepoIndex, Violation, find_trace_roots
from repro.analysis.__main__ import main, run_passes

REPO = Path(__file__).resolve().parents[1]
FIXTURES = Path(__file__).parent / "analysis_fixtures"
SRC = REPO / "src" / "repro"


@pytest.fixture(scope="module")
def fixture_violations():
    index = RepoIndex(FIXTURES, package_prefix="analysis_fixtures")
    return run_passes(index)


def _rules(violations, path=None):
    return {v.rule for v in violations
            if path is None or v.path == path}


# ---------------------------------------------------------------------------
# positive: one known violation per rule class
# ---------------------------------------------------------------------------

def test_trace_purity_rules_fire(fixture_violations):
    rules = _rules(fixture_violations, "fx_trace.py")
    assert {"trace-nondeterminism", "trace-host-branch", "trace-mutation",
            "trace-global-state", "trace-self-mutation"} <= rules


def test_lock_rules_fire(fixture_violations):
    got = [(v.rule, v.symbol) for v in fixture_violations
           if v.path == "fx_locks.py" and v.rule.startswith("lock-")]
    assert ("lock-unguarded-write", "Store._refresh") in got   # _pending
    assert ("lock-unguarded-read", "Store.peek") in got        # _shadow
    assert ("lock-unguarded-write", "Store.publish") in got    # writes_only
    assert ("lock-unguarded-read", "poll") in got              # external
    # the correctly locked method is NOT flagged
    assert all(sym != "Store.swap" for _, sym in got)


def test_generation_rules_fire(fixture_violations):
    vs = [v for v in fixture_violations if v.path == "fx_generation.py"]
    assert {"gen-chained-read", "gen-multi-read",
            "gen-direct-private"} <= {v.rule for v in vs}
    # the pinned-snapshot idiom stays clean
    assert all(v.symbol != "pinned_batch" for v in vs)


def test_retrace_rules_fire(fixture_violations):
    vs = [v for v in fixture_violations if v.path == "fx_retrace.py"]
    assert {"retrace-scalar-arg", "retrace-scalar-flow"} <= \
        {v.rule for v in vs}
    # static_argnames exempts the annotated twin
    assert all(v.symbol != "stepper_ok" for v in vs)


def test_meter_lint_is_error_tier(fixture_violations):
    # promoted from warning tier in the fabric PR: every engine transfer
    # funnels through the metered GNSEngine._put_batch, so unpaired
    # transfers are regressions now
    vs = [v for v in fixture_violations if v.path == "fx_meter.py"]
    assert [v.rule for v in vs] == ["meter-unpaired-transfer"]
    assert vs[0].severity == "error"
    assert vs[0].symbol == "unbooked_upload"


def test_pad_registry_guards_the_padding_idiom():
    # the real adjacency module still carries its power-of-two idiom …
    index = RepoIndex(SRC, package_prefix="repro")
    from repro.analysis import retrace
    assert not [v for v in retrace.run(index)
                if v.rule == "retrace-pad-registry"]
    # … and a stripped copy of the function is caught
    import tempfile
    with tempfile.TemporaryDirectory() as td:
        pkg = Path(td) / "sampling"
        pkg.mkdir()
        (pkg / "adjacency.py").write_text(
            "def build_device_cache_adj(state, host_adj, degrees,"
            " lam=None, meter=None):\n"
            "    cap = max(1024, 7)\n"         # padding idiom dropped
            "    return cap\n")
        broken = RepoIndex(Path(td), package_prefix="x")
        vs = [v for v in retrace.run(broken)
              if v.rule == "retrace-pad-registry"]
        assert vs and "bit_length" in vs[0].message


# ---------------------------------------------------------------------------
# negative: the repo at HEAD is clean
# ---------------------------------------------------------------------------

def test_repo_is_clean_against_baseline():
    index = RepoIndex(SRC, package_prefix="repro")
    violations = run_passes(index)
    base = load(REPO / ".github" / "gnscheck-baseline.txt")
    new, stale = compare(violations, base)
    assert not new, "\n".join(v.render() for v in new)
    assert not stale, stale


def test_trace_roots_cover_the_jit_surface():
    index = RepoIndex(SRC, package_prefix="repro")
    roots = find_trace_roots(index)
    kinds = {r.kind for r in roots}
    assert {"jit", "pallas", "shard_map"} <= kinds
    # the sites the ISSUE names must be in the walked region
    refs = {r.ref for r in roots}
    assert "repro.gns.engine:make_train_step.train_step" in refs
    assert any("pallas" == r.kind for r in roots)
    reach = index.reachable([r.ref for r in roots])
    assert len(reach) >= 40   # the traced call graph, not just the roots


# ---------------------------------------------------------------------------
# CLI + baseline ratchet
# ---------------------------------------------------------------------------

def test_cli_exit_codes(tmp_path):
    # fixtures: violations, no baseline -> nonzero
    assert main(["--root", str(FIXTURES)]) == 1
    # write a baseline, rerun against it -> zero (all baselined)
    bl = tmp_path / "bl.txt"
    assert main(["--root", str(FIXTURES), "--baseline", str(bl),
                 "--write-baseline"]) == 0
    assert main(["--root", str(FIXTURES), "--baseline", str(bl)]) == 0
    # a stale entry (violation fixed but entry kept) -> nonzero
    bl.write_text(bl.read_text() + "bogus-rule|gone.py|fn|x\n")
    assert main(["--root", str(FIXTURES), "--baseline", str(bl)]) == 1
    # an unpaired transfer is error tier now: it fails outright, and the
    # baseline ratchet (not --strict-warnings) is the only way to carry it
    clean = tmp_path / "clean"
    clean.mkdir()
    (clean / "m.py").write_text(
        "import jax, jax.numpy as jnp\n"
        "def up(buf, sh):\n"
        "    return jax.device_put(jnp.asarray(buf), sh)\n")
    assert main(["--root", str(clean)]) == 1
    bl2 = tmp_path / "bl2.txt"
    assert main(["--root", str(clean), "--baseline", str(bl2),
                 "--write-baseline"]) == 0
    assert main(["--root", str(clean), "--baseline", str(bl2)]) == 0


def test_cli_module_entrypoint_runs_clean_on_repo():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis",
         "--baseline", str(REPO / ".github" / "gnscheck-baseline.txt")],
        capture_output=True, text=True, cwd=str(REPO))
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_baseline_keys_are_line_number_free(tmp_path):
    v1 = Violation("r", "p.py", 10, "f", "m", detail="d")
    v2 = Violation("r", "p.py", 99, "f", "m", detail="d")  # moved 89 lines
    assert v1.key() == v2.key()
    assert keyed([v1, v2]) == ["r|p.py|f|d", "r|p.py|f|d#2"]
    bl = tmp_path / "b.txt"
    write(bl, [v1, v2])
    assert load(bl) == sorted(["r|p.py|f|d", "r|p.py|f|d#2"])
    new, stale = compare([v2, v1], load(bl))
    assert not new and not stale


def test_suppression_comment():
    import tempfile
    with tempfile.TemporaryDirectory() as td:
        (Path(td) / "m.py").write_text(
            "import jax, time\n"
            "@jax.jit\n"
            "def f(x):\n"
            "    t = time.time()  # gnscheck: ignore[trace-nondeterminism]\n"
            "    u = time.time()\n"
            "    return x\n")
        index = RepoIndex(Path(td), package_prefix="x")
        vs = [v for v in run_passes(index)
              if v.rule == "trace-nondeterminism"]
        assert len(vs) == 1 and vs[0].line == 5


# ---------------------------------------------------------------------------
# runtime sanitizer
# ---------------------------------------------------------------------------

def test_sanitizer_is_armed_under_pytest():
    assert sanitizer_enabled()    # conftest.py switched it on


def test_unguarded_write_raises():
    @guarded_by("_lock", "value")
    class Box:
        def __init__(self):
            self._lock = threading.Lock()
            self.value = 0        # __init__ is exempt (pre-publication)

        def good(self, v):
            with self._lock:
                self.value = v

        def bad(self, v):
            self.value = v

    b = Box()
    assert isinstance(b._lock, TrackedLock)
    b.good(7)
    with pytest.raises(LockDisciplineError):
        b.bad(8)
    assert b.value == 7           # the faulting write never landed


def test_writes_only_attrs_allow_lockfree_reads():
    @guarded_by("_lock", writes_only=("live",))
    class Pub:
        def __init__(self):
            self._lock = threading.Lock()
            self.live = None

        def publish(self, g):
            with self._lock:
                self.live = g

    p = Pub()
    p.publish(42)
    assert p.live == 42           # snapshot read, no lock, no raise
    with pytest.raises(LockDisciplineError):
        p.live = 43               # but a bare write still needs the lock


def test_holds_lock_decorator_enforces_ownership():
    @guarded_by("_lock", "n")
    class C:
        def __init__(self):
            self._lock = threading.Lock()
            self.n = 0

        @holds_lock("_lock")
        def _bump_locked(self):
            self.n += 1

        def bump(self):
            with self._lock:
                self._bump_locked()

    c = C()
    c.bump()
    assert c.n == 1
    with pytest.raises(LockDisciplineError):
        c._bump_locked()          # called without the lock


def test_lock_order_cycle_raises():
    reset_lock_order()
    try:
        a = TrackedLock(threading.Lock(), "A.lock")
        b = TrackedLock(threading.Lock(), "B.lock")
        with a:
            with b:               # records A -> B
                pass
        with pytest.raises(LockOrderError):
            with b:
                with a:           # B -> A closes the cycle
                    pass
        assert not a.locked()     # released before the raise
    finally:
        reset_lock_order()


def test_real_featurestore_locks_are_tracked():
    """The annotated production class actually gets wrapped locks, and its
    refresh lifecycle runs clean under the sanitizer."""
    import numpy as np
    from repro.featurestore import CacheConfig, FeatureStore
    from repro.graph.generate import powerlaw_graph

    g = powerlaw_graph(300, avg_degree=4, seed=0)
    feats = np.random.default_rng(0).standard_normal(
        (g.num_nodes, 8)).astype(np.float32)
    store = FeatureStore(feats, g, CacheConfig(fraction=0.2),
                         train_idx=np.arange(100))
    assert isinstance(store._lock, TrackedLock)
    store.refresh(np.random.default_rng(0), version=0)
    assert store.begin_refresh(np.random.default_rng(1), version=1)
    assert store.wait_refresh(timeout=30.0)
    assert store.swaps == 2 and store.refreshes == 2

"""Prefetcher straggler mitigation: timeout→reuse, errors, clean shutdown —
plus the slow-shard-UPLOAD extension (PR 3): a generation whose device
upload straggles past ``CacheConfig.refresh_timeout_s`` must neither block
``swap_if_ready`` nor the epoch-boundary absorb; training keeps consuming
the old generation until the upload lands."""
import threading
import time

import numpy as np
import pytest

from repro.core.pipeline import EpochLoader, Prefetcher


def _slow_iter(items, delays):
    for item, d in zip(items, delays):
        time.sleep(d)
        yield item


def test_passthrough_no_timeout():
    p = Prefetcher(iter(range(5)), depth=2)
    assert list(p) == [0, 1, 2, 3, 4]
    assert p.reused == 0


def test_straggler_timeout_reuses_last_batch():
    # item 0 arrives fast; item 1 is a straggler -> consumer reuses item 0
    it = _slow_iter(["a", "b"], [0.0, 0.6])
    p = Prefetcher(it, depth=1, timeout_s=0.1)
    out = []
    t0 = time.perf_counter()
    for x in p:
        out.append(x)
        if time.perf_counter() - t0 > 5.0:   # safety
            break
    assert out[0] == "a"
    assert out[-1] == "b"                    # straggler still delivered
    assert "a" in out[1:-1]                  # at least one reuse in between
    assert p.reused >= 1
    assert out.count("a") == 1 + p.reused


def test_first_item_straggler_blocks_instead_of_reusing():
    # nothing to reuse yet -> the consumer must block for the first batch
    it = _slow_iter(["x"], [0.3])
    p = Prefetcher(it, depth=1, timeout_s=0.05)
    out = list(p)
    assert out == ["x"]
    assert p.reused == 0


def test_error_propagates_through_sentinel():
    def bad():
        yield 1
        yield 2
        raise ValueError("sampler exploded")

    p = Prefetcher(bad(), depth=2)
    got = []
    with pytest.raises(ValueError, match="sampler exploded"):
        for x in p:
            got.append(x)
    assert got == [1, 2]                     # items before the error survive


def test_clean_shutdown_joins_worker():
    p = Prefetcher(iter(range(10)), depth=2)
    assert list(p) == list(range(10))
    p._thread.join(timeout=5.0)
    assert not p._thread.is_alive()
    # iterating an exhausted prefetcher after shutdown must not hang: the
    # queue is empty and the worker is gone, so a fresh consumer would block
    # forever — guard by checking the thread really exited above.


def test_reused_counter_zero_when_producer_keeps_up():
    it = _slow_iter(range(4), [0.0] * 4)
    p = Prefetcher(it, depth=4, timeout_s=1.0)
    assert list(p) == [0, 1, 2, 3]
    assert p.reused == 0


# ---------------------------------------------------------------------------
# slow shard-upload stragglers (ROADMAP follow-up, PR 3)
# ---------------------------------------------------------------------------

def _gns_setup(upload_delay, refresh_timeout_s):
    from repro.core.sampler import GNSSampler, SamplerConfig
    from repro.featurestore import CacheConfig, FeatureStore
    from repro.graph.generate import powerlaw_graph

    g = powerlaw_graph(600, avg_degree=6, seed=0)
    feats = np.random.default_rng(0).standard_normal(
        (g.num_nodes, 8)).astype(np.float32)
    labels = np.zeros(g.num_nodes, np.int32)
    train = np.arange(400, dtype=np.int64)
    cfg = SamplerConfig(
        fanouts=(3, 4), batch_size=50,
        cache=CacheConfig(fraction=0.05, period=1, async_refresh=True,
                          refresh_timeout_s=refresh_timeout_s))
    store = FeatureStore(feats, g, cfg.cache, build_adjacency=True)
    s = GNSSampler(g, cfg, feats, labels, train_idx=train, store=store)
    # initial (synchronous) generation uploads fast; only REFRESH uploads
    # straggle — the scenario is a slow device, not a broken first build
    s.ensure_cache(np.random.default_rng(1))
    store.upload_delay = upload_delay
    return s, store, train


def test_slow_upload_does_not_block_swap_or_steps():
    """An async refresh whose shard upload straggles: swap_if_ready stays
    False (never blocks), the epoch-boundary absorb gives up after
    refresh_timeout_s, and every batch keeps consuming the OLD generation
    until the upload finally lands."""
    s, store, train = _gns_setup(upload_delay=0.6, refresh_timeout_s=0.05)
    loader = EpochLoader(s, train, seed=0, max_batches=4)
    v0 = s._gen.version

    # epoch 1 kicks the straggling async refresh; batches must keep flowing
    # against v0 while the upload sleeps
    t0 = time.perf_counter()
    versions = [mb.cache_version for mb in loader.epoch(1)]
    assert versions == [v0] * 4, versions
    assert store.refreshing                      # still stuck in the upload
    assert not store.swap_if_ready()             # never blocks, never lies
    # epoch 2's absorb must time out (0.05s) instead of joining the 0.6s
    # upload: the epoch start stays an order of magnitude under the delay
    t1 = time.perf_counter()
    it = loader.epoch(2)
    first = next(it)
    assert time.perf_counter() - t1 < 0.45
    assert first.cache_version == v0
    for mb in it:
        assert mb.cache_version == v0
    # once the upload lands, the swap is adopted at the next boundary
    assert store.wait_refresh(timeout=10.0)
    store.upload_delay = 0.0
    s.adopt_generation()
    versions = {mb.cache_version for mb in loader.epoch(3)}
    assert v0 not in versions and len(versions) >= 1, versions
    assert time.perf_counter() - t0 < 30.0


def test_slow_upload_composes_with_prefetcher_reuse():
    """The two straggler layers compose: with the producer never blocking on
    the upload (timeout path) the Prefetcher sees a steady batch stream and
    its own reuse path stays idle."""
    s, store, train = _gns_setup(upload_delay=0.4, refresh_timeout_s=0.02)
    loader = EpochLoader(s, train, seed=0, max_batches=6)
    p = Prefetcher(loader.epoch(1), depth=2, timeout_s=2.0)
    got = list(p)
    assert len(got) == 6
    assert p.reused == 0            # producer never stalled on the upload
    store.wait_refresh(timeout=10.0)


def test_no_timeout_configured_preserves_blocking_absorb():
    """refresh_timeout_s=None keeps PR 2 semantics: the epoch-boundary
    absorb joins the in-flight build (upload included) before continuing."""
    s, store, train = _gns_setup(upload_delay=0.15, refresh_timeout_s=None)
    loader = EpochLoader(s, train, seed=0, max_batches=2)
    list(loader.epoch(1))           # kicks the slow async refresh
    v_before = s._gen.version
    t0 = time.perf_counter()
    first = next(loader.epoch(2))   # absorb must BLOCK through the upload
    waited = time.perf_counter() - t0
    assert first.cache_version != v_before
    assert waited >= 0.1, waited

"""Prefetcher straggler mitigation: timeout→reuse, errors, clean shutdown."""
import threading
import time

import pytest

from repro.core.pipeline import Prefetcher


def _slow_iter(items, delays):
    for item, d in zip(items, delays):
        time.sleep(d)
        yield item


def test_passthrough_no_timeout():
    p = Prefetcher(iter(range(5)), depth=2)
    assert list(p) == [0, 1, 2, 3, 4]
    assert p.reused == 0


def test_straggler_timeout_reuses_last_batch():
    # item 0 arrives fast; item 1 is a straggler -> consumer reuses item 0
    it = _slow_iter(["a", "b"], [0.0, 0.6])
    p = Prefetcher(it, depth=1, timeout_s=0.1)
    out = []
    t0 = time.perf_counter()
    for x in p:
        out.append(x)
        if time.perf_counter() - t0 > 5.0:   # safety
            break
    assert out[0] == "a"
    assert out[-1] == "b"                    # straggler still delivered
    assert "a" in out[1:-1]                  # at least one reuse in between
    assert p.reused >= 1
    assert out.count("a") == 1 + p.reused


def test_first_item_straggler_blocks_instead_of_reusing():
    # nothing to reuse yet -> the consumer must block for the first batch
    it = _slow_iter(["x"], [0.3])
    p = Prefetcher(it, depth=1, timeout_s=0.05)
    out = list(p)
    assert out == ["x"]
    assert p.reused == 0


def test_error_propagates_through_sentinel():
    def bad():
        yield 1
        yield 2
        raise ValueError("sampler exploded")

    p = Prefetcher(bad(), depth=2)
    got = []
    with pytest.raises(ValueError, match="sampler exploded"):
        for x in p:
            got.append(x)
    assert got == [1, 2]                     # items before the error survive


def test_clean_shutdown_joins_worker():
    p = Prefetcher(iter(range(10)), depth=2)
    assert list(p) == list(range(10))
    p._thread.join(timeout=5.0)
    assert not p._thread.is_alive()
    # iterating an exhausted prefetcher after shutdown must not hang: the
    # queue is empty and the worker is gone, so a fresh consumer would block
    # forever — guard by checking the thread really exited above.


def test_reused_counter_zero_when_producer_keeps_up():
    it = _slow_iter(range(4), [0.0] * 4)
    p = Prefetcher(it, depth=4, timeout_s=1.0)
    assert list(p) == [0, 1, 2, 3]
    assert p.reused == 0

"""Per-arch smoke tests (deliverable f).

For every assigned architecture: instantiate the REDUCED config (same family,
tiny dims), run one forward/train step on CPU, assert output shapes and no
NaNs; then run the decode path and check prefill-via-decode agrees with the
train-mode forward at the last position — this cross-validates the fancy
decode math against the parallel forms (MLA absorbed attention, Mamba2
chunked-SSD vs recurrence, mLSTM parallel vs recurrent, SWA ring buffer).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import encdec, hybrid, transformer, xlstm_lm
from repro.models.lm import enc_dec_split, get_model, make_batch

jax.config.update("jax_platform_name", "cpu")

ARCHS = list_archs()
B, S = 2, 24


def _setup(arch):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, S, B, jax.random.PRNGKey(1))
    return cfg, model, params, batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_shapes_and_finite(arch):
    cfg, model, params, batch = _setup(arch)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: loss={loss}"
    leaves = jax.tree_util.tree_leaves(grads)
    assert leaves, arch
    for g in leaves:
        assert jnp.all(jnp.isfinite(g.astype(jnp.float32))), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_parallel_forward(arch):
    cfg, model, params, batch = _setup(arch)
    tokens = batch["tokens"]

    if cfg.encoder_layers > 0:
        enc_len = batch["frame_embeds"].shape[1]
        state = model.decode_init(B, tokens.shape[1] + 4, enc_len)
        state["cross"] = encdec.prefill_encoder(params, cfg,
                                                batch["frame_embeds"])
        logits_dec, state = model.decode_step(params, tokens, state)
        # parallel reference: full enc-dec forward, last position
        enc_out = encdec.encode(params, cfg, batch["frame_embeds"])
        h = transformer.embed_tokens(params, cfg, tokens)
        b, s, _ = h.shape
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

        def body(carry, bp):
            out, _ = encdec._dec_block(bp, cfg, carry, pos, enc_out=enc_out)
            return out, None

        h, _ = jax.lax.scan(body, h, params["decoder"])
        ref = transformer.unembed(params, cfg, h)[:, -1]
    elif cfg.xlstm is not None:
        state = model.decode_init(B)
        logits_dec, state = model.decode_step(params, tokens, state)
        ref = xlstm_lm.xlstm_forward(params, cfg, tokens)[:, -1]
    elif cfg.ssm is not None:
        state = model.decode_init(B, tokens.shape[1] + 4)
        logits_dec, state = model.decode_step(params, tokens, state)
        ref = hybrid.hybrid_forward(params, cfg, tokens)[:, -1]
    else:
        state = model.decode_init(B, tokens.shape[1] + 4)
        logits_dec, state = model.decode_step(params, tokens, state)
        ref = transformer.lm_forward(params, cfg, tokens)[:, -1]

    assert logits_dec.shape == (B, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits_dec)), arch
    np.testing.assert_allclose(np.asarray(logits_dec, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-3, atol=2e-3)
    # one more single-token step advances cleanly
    nxt = jnp.argmax(logits_dec, -1)[:, None].astype(jnp.int32)
    logits2, state2 = model.decode_step(params, nxt, state)
    assert jnp.all(jnp.isfinite(logits2)), arch
    assert int(state2["pos"]) == tokens.shape[1] + 1


@pytest.mark.parametrize("arch", ["internvl2-1b", "seamless-m4t-medium"])
def test_frontend_stub_batches(arch):
    """[audio]/[vlm] archs consume stub frontend embeddings (DESIGN.md §5)."""
    cfg, model, params, batch = _setup(arch)
    if cfg.frontend == "vision":
        assert "patch_embeds" in batch
        p = batch["patch_embeds"].shape[1]
        assert p + batch["tokens"].shape[1] == S
    else:
        s_enc, s_dec = enc_dec_split(cfg, S)
        assert batch["frame_embeds"].shape == (B, s_enc, cfg.d_model)
        assert batch["tokens"].shape == (B, s_dec)
    loss = model.loss(params, batch)
    assert jnp.isfinite(loss)

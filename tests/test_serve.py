"""ServeEngine: batched decode across families, grouping, determinism."""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.serve import Request, ServeEngine
from repro.models.lm import enc_dec_split, get_model


def _engine(arch, **kw):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, ServeEngine(cfg, params, **kw)


@pytest.mark.parametrize("arch", ["qwen2-7b", "zamba2-2.7b", "xlstm-125m",
                                  "h2o-danube-3-4b"])
def test_generate_batch_shapes(arch):
    cfg, eng = _engine(arch, max_batch=4)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, 64, 12).astype(np.int32),
                    max_new_tokens=5) for _ in range(3)]
    comps = eng.generate_batch(reqs)
    assert len(comps) == 3
    for c in comps:
        assert len(c.tokens) == 5
        assert (c.tokens >= 0).all() and (c.tokens < cfg.vocab_size).all()


def test_batching_matches_single():
    """Lockstep batch decoding must equal one-request decoding (greedy)."""
    _, eng = _engine("qwen2-7b", max_batch=4)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, 64, 10).astype(np.int32) for _ in range(3)]
    solo = [eng.generate_batch([Request(p, max_new_tokens=6)])[0].tokens
            for p in prompts]
    batched = eng.generate_batch([Request(p, max_new_tokens=6)
                                  for p in prompts])
    for s, b in zip(solo, batched):
        np.testing.assert_array_equal(s, b.tokens)


def test_serve_groups_mixed_lengths():
    _, eng = _engine("xlstm-125m", max_batch=2)
    rng = np.random.default_rng(2)
    reqs = [Request(rng.integers(0, 64, L).astype(np.int32), max_new_tokens=3)
            for L in (8, 12, 8, 12, 8)]
    comps = eng.serve(reqs)
    assert all(c is not None and len(c.tokens) == 3 for c in comps)


def test_eos_stops_slot():
    cfg, eng = _engine("xlstm-125m", max_batch=2)
    rng = np.random.default_rng(3)
    p = rng.integers(0, 64, 8).astype(np.int32)
    free = eng.generate_batch([Request(p, max_new_tokens=6, eos_id=-1)])[0]
    eos_id = int(free.tokens[1])       # force EOS at the 2nd generated token
    comp = eng.generate_batch([Request(p, max_new_tokens=6, eos_id=eos_id)])[0]
    assert len(comp.tokens) == 2 and comp.tokens[-1] == eos_id


def test_encdec_serving():
    cfg, eng = _engine("seamless-m4t-medium", max_batch=2)
    rng = np.random.default_rng(4)
    frames = rng.standard_normal((2, 6, cfg.d_model)).astype(np.float32)
    reqs = [Request(rng.integers(0, 64, 5).astype(np.int32), max_new_tokens=4)
            for _ in range(2)]
    comps = eng.generate_batch(reqs, frame_embeds=frames)
    assert all(len(c.tokens) == 4 for c in comps)

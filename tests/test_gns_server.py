"""GNS serving subsystem (src/repro/serve): micro-batching, backpressure,
deadlines, generation-swap safety, serving-driven cache adaptation.

Three layers of coverage:

* unit: the MicroBatcher's coalescing/bucketing/carry rules, driven
  directly with no threads;
* in-process server: submit/result round trips, admission control
  (QueueFull), deadline expiry, zero steady-state recompilation, the
  serving accounting split (serve meter populated, training meter
  untouched, adaptive-policy EMA fed), and the serving-driven refresh
  converging the cache onto the inference hot set;
* THE swap satellite: a refresh swap mid-stream leaves in-flight request
  results bitwise-identical to a no-swap run (each minibatch pins its
  generation), and adopted generations stay monotonic under serving load;
* subprocess serve-smoke on 4 forced host devices (the CI job): skewed
  request stream with a mid-stream refresh on the sharded fused mesh —
  p99 bounded, cache-hit improvement > 0, zero recompilation.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.core.sampler import SamplerConfig
from repro.featurestore import CacheConfig
from repro.gns import EngineConfig, GNSEngine, ServeConfig
from repro.graph.datasets import get_dataset
from repro.serve import GNSServer, MicroBatcher, QueueFull, ServerClosed
from repro.serve.server import _Pending, ServeFuture


@pytest.fixture(scope="module")
def tiny_ds():
    return get_dataset("tiny", seed=0)


def _engine(tiny_ds, serve=None, strategy="auto", fraction=0.1, seed=0):
    scfg = SamplerConfig(fanouts=(3, 4), batch_size=32,
                         cache=CacheConfig(fraction=fraction,
                                           strategy=strategy))
    cfg = EngineConfig(sampler="gns", sampling=scfg, cache=scfg.cache,
                      seed=seed, serve=serve if serve is not None
                      else ServeConfig(buckets=(8, 32), max_wait_ms=5.0))
    return GNSEngine(cfg, dataset=tiny_ds)


def _pending(ids, deadline=None):
    return _Pending(node_ids=np.asarray(ids, np.int64), future=ServeFuture(),
                    t_submit=time.monotonic(), deadline=deadline)


# ---------------------------------------------------------------------------
# MicroBatcher unit tests (no threads)
# ---------------------------------------------------------------------------

def test_batcher_rejects_bad_buckets():
    with pytest.raises(AssertionError):
        MicroBatcher((32, 8), max_wait_s=0.0, max_queue=4)
    with pytest.raises(AssertionError):
        MicroBatcher((), max_wait_s=0.0, max_queue=4)


def test_batcher_bucket_for():
    b = MicroBatcher((8, 32, 128), max_wait_s=0.0, max_queue=8)
    assert b.bucket_for(1) == 8 and b.bucket_for(8) == 8
    assert b.bucket_for(9) == 32 and b.bucket_for(128) == 128
    with pytest.raises(AssertionError):
        b.bucket_for(129)


def test_batcher_coalesces_to_capacity_and_carries_overflow():
    b = MicroBatcher((8, 16), max_wait_s=0.0, max_queue=16)
    reqs = [_pending(np.arange(6)) for _ in range(4)]   # 4 x 6 ids, cap 16
    for r in reqs:
        assert b.offer(r)
    first = b.next_batch(timeout=0.0)
    # 6 + 6 fit, the third (6 more -> 18 > 16) is carried, FIFO preserved
    assert [id(p) for p in first] == [id(reqs[0]), id(reqs[1])]
    second = b.next_batch(timeout=0.0)
    assert [id(p) for p in second] == [id(reqs[2]), id(reqs[3])]
    assert b.next_batch(timeout=0.0) is None
    assert b.qsize() == 0


def test_batcher_queue_bound():
    b = MicroBatcher((8,), max_wait_s=0.0, max_queue=2)
    assert b.offer(_pending([1]))
    assert b.offer(_pending([2]))
    assert not b.offer(_pending([3]))       # admission control refusal


def test_batcher_window_respects_deadline():
    """The batching window never holds a request past its deadline."""
    b = MicroBatcher((8,), max_wait_s=10.0, max_queue=4)
    dl = time.monotonic() + 0.02
    assert b.offer(_pending([1], deadline=dl))
    t0 = time.monotonic()
    batch = b.next_batch(timeout=0.1)
    took = time.monotonic() - t0
    assert len(batch) == 1
    assert took < 1.0, f"window ignored the deadline ({took:.3f}s)"


# ---------------------------------------------------------------------------
# in-process server: golden path + control flow
# ---------------------------------------------------------------------------

def test_server_submit_result_roundtrip(tiny_ds):
    eng = _engine(tiny_ds)
    with eng.serve() as srv:
        futs = [srv.submit(tiny_ds.val_idx[i * 5:(i + 1) * 5])
                for i in range(8)]
        results = [f.result(timeout=120) for f in futs]
    for i, r in enumerate(results):
        assert r.status == "ok", r
        assert r.logits.shape == (5, tiny_ds.num_classes)
        assert np.isfinite(r.logits).all()
        assert r.total_s >= r.queue_wait_s >= 0.0
        assert r.bucket in (8, 32)
        assert r.cache_version >= 0
    m = srv.meter
    assert m.served == m.submitted == 8
    assert m.rejected == m.expired == m.errors == 0
    assert 0 < m.batches <= 8
    assert 0.0 < m.fill_fraction <= 1.0
    json.dumps(m.snapshot())                  # JSON-safe view
    p = m.percentiles()
    assert p["total_p99_ms"] >= p["queue_wait_p50_ms"] >= 0.0


def test_server_rejects_when_queue_full(tiny_ds):
    eng = _engine(tiny_ds, serve=ServeConfig(buckets=(8,), max_queue=2))
    srv = GNSServer(eng)
    with srv._state_lock:                 # accept without a worker draining
        srv._accepting = True
    srv.submit([1]); srv.submit([2])
    with pytest.raises(QueueFull):
        srv.submit([3])
    assert srv.meter.rejected == 1 and srv.meter.submitted == 3


def test_server_rejects_oversized_and_closed(tiny_ds):
    eng = _engine(tiny_ds)
    srv = GNSServer(eng)
    with pytest.raises(ServerClosed):
        srv.submit([1])                   # never started
    with srv._state_lock:
        srv._accepting = True
    with pytest.raises(ValueError):
        srv.submit(np.arange(33))         # > largest bucket
    with pytest.raises(ValueError):
        srv.submit([])


def test_deadline_expiry_never_touches_the_device(tiny_ds):
    eng = _engine(tiny_ds)
    srv = GNSServer(eng)
    with srv._state_lock:
        srv._accepting = True
    fut = srv.submit([1, 2, 3], deadline_ms=1.0)
    time.sleep(0.05)                      # expire while queued (no worker)
    srv.start()
    try:
        res = fut.result(timeout=60)
    finally:
        srv.stop()
    assert res.status == "expired" and res.logits is None
    assert srv.meter.expired == 1 and srv.meter.served == 0
    assert srv.meter.batches == 0         # nothing shipped to the device


def test_deadline_on_idle_server_is_served_not_expired(tiny_ds):
    """A lone request whose deadline is shorter than the batching window
    must be DISPATCHED before the deadline (window closes with margin),
    not held until it expires on an otherwise idle server."""
    eng = _engine(tiny_ds, serve=ServeConfig(buckets=(8,), max_wait_ms=500.0))
    with eng.serve() as srv:
        srv.infer(tiny_ds.val_idx[:4], timeout=120)      # warm the step
        res = srv.submit(tiny_ds.val_idx[:4],
                         deadline_ms=100.0).result(timeout=120)
    assert res.status == "ok", res
    assert srv.meter.expired == 0


def test_results_are_isolated_copies(tiny_ds):
    """Coalesced requests must not see each other's rows through a shared
    batch array (multi-tenant isolation; no view into the padded batch)."""
    eng = _engine(tiny_ds, serve=ServeConfig(buckets=(32,), max_wait_ms=50.0))
    with eng.serve() as srv:
        futs = [srv.submit(tiny_ds.val_idx[i * 4:(i + 1) * 4])
                for i in range(4)]
        results = [f.result(timeout=120) for f in futs]
    assert srv.meter.batches < 4              # actually coalesced
    for r in results:
        assert r.logits.base is None, "logits must be an owning copy"
        assert r.logits.shape == (4, tiny_ds.num_classes)


def test_server_stop_then_submit_raises(tiny_ds):
    eng = _engine(tiny_ds)
    srv = eng.serve().start()
    srv.stop()
    with pytest.raises(ServerClosed):
        srv.submit([1])


def test_zero_recompilation_across_steady_state(tiny_ds):
    """One compiled inference step per size bucket — a steady-state stream
    of mixed request sizes adds no jit cache entries after warmup."""
    eng = _engine(tiny_ds)
    rng = np.random.default_rng(0)
    with eng.serve() as srv:
        # warm both buckets explicitly: an 8-sized and a 32-sized batch
        srv.infer(tiny_ds.val_idx[:4], timeout=120)
        srv.infer(tiny_ds.val_idx[:20], timeout=120)
        warm = eng.infer_step._cache_size()
        assert warm <= 2
        for _ in range(12):
            n = int(rng.integers(1, 30))
            ids = rng.choice(tiny_ds.val_idx, size=n, replace=False)
            srv.infer(ids, timeout=120)
        assert eng.infer_step._cache_size() == warm
    assert srv.meter.served == 14


def test_serving_accounting_split(tiny_ds):
    """Serving traffic lands on the serve meter and feeds the adaptive
    policy EMA; the TRAINING meter sees none of it."""
    eng = _engine(tiny_ds, strategy="adaptive")
    eng.fit(1, max_batches=2)
    before_steps = eng.meter.steps
    before_dev = (eng.meter.tier("device").hits,
                  eng.meter.tier("device").misses)
    ema_before = eng.store.policy._ema.sum()
    with eng.serve() as srv:
        for i in range(4):
            srv.infer(tiny_ds.val_idx[i * 8:(i + 1) * 8], timeout=120)
    assert eng.meter.steps == before_steps
    assert (eng.meter.tier("device").hits,
            eng.meter.tier("device").misses) == before_dev
    dev = srv.meter.traffic.tier("device")
    assert dev.hits + dev.misses > 0          # serving tier view populated
    assert eng.store.policy._ema.sum() > ema_before   # EMA fed by serving
    assert eng.store.record                   # mode restored


# ---------------------------------------------------------------------------
# THE swap satellite: generation pinning + monotonic adoption under serving
# ---------------------------------------------------------------------------

def test_inflight_results_bitwise_identical_across_swap(tiny_ds):
    """A refresh swap mid-stream must leave in-flight request results
    bitwise-identical to a no-swap run: each prepared minibatch pins the
    generation it was assembled against, so the compiled step reads the
    matching slot-map/table pair whatever the live generation does."""
    eng = _engine(tiny_ds)
    eng.ensure_cache(np.random.default_rng(0))
    eng.store.record = False
    try:
        mbs = [eng.infer_prepare(tiny_ds.val_idx[i * 8:(i + 1) * 8],
                                 bucket=8, rng=np.random.default_rng(i))
               for i in range(4)]
        v0 = eng.store.version
        no_swap = [eng.infer_compute(mb) for mb in mbs]

        # swap the live generation UNDER the in-flight batches
        eng.store.refresh(np.random.default_rng(99), version=v0 + 1)
        assert eng.store.version == v0 + 1
        swapped = [eng.infer_compute(mb) for mb in mbs]
        for a, b in zip(no_swap, swapped):
            np.testing.assert_array_equal(a, b)
        for mb in mbs:
            assert mb.cache_version == v0      # still pinned to their gen

        # fresh batches adopt the NEW generation — monotonic, never back
        mb2 = eng.infer_prepare(tiny_ds.val_idx[:8], bucket=8,
                                rng=np.random.default_rng(7))
        assert mb2.cache_version == v0 + 1
    finally:
        eng.store.record = True


def test_adopted_generations_monotonic_under_serving(tiny_ds):
    """Serving-driven refreshes (ServeConfig.refresh_every) swap between
    batches; the per-batch pinned versions must be non-decreasing and must
    actually advance."""
    eng = _engine(tiny_ds, serve=ServeConfig(
        buckets=(8,), max_wait_ms=0.5, refresh_every=2))
    with eng.serve() as srv:
        deadline = time.monotonic() + 60
        i = 0
        while srv.meter.swaps_observed < 2 and time.monotonic() < deadline:
            ids = tiny_ds.val_idx[(i % 8) * 8:(i % 8) * 8 + 8]
            srv.infer(ids, timeout=120)
            i += 1
    trail = srv.meter.generation_trail()
    assert srv.meter.swaps_observed >= 2, (srv.meter.swaps_observed, trail)
    assert all(a <= b for a, b in zip(trail, trail[1:])), trail
    assert trail[-1] > trail[0], trail


def test_failed_serving_refresh_does_not_kill_the_loop(tiny_ds):
    """A background generation build that raises must not take down the
    worker: the error surfaces on the meter/server and requests keep being
    served off the live generation."""
    eng = _engine(tiny_ds, serve=ServeConfig(buckets=(8,), max_wait_ms=0.5,
                                             refresh_every=1))
    with eng.serve() as srv:
        def boom(*a, **kw):
            raise RuntimeError("injected build failure")
        eng.store._build = boom
        deadline = time.monotonic() + 60
        while (srv.meter.refresh_failures == 0
               and time.monotonic() < deadline):
            srv.infer(tiny_ds.val_idx[:8], timeout=120)
        # the loop survived the failed build and kept serving
        res = srv.submit(tiny_ds.val_idx[8:16]).result(timeout=120)
    assert res.status == "ok"
    assert srv.meter.refresh_failures >= 1
    assert isinstance(srv.refresh_error, RuntimeError)
    assert srv.meter.errors == 0          # request path never saw it


def test_stop_without_drain_cancels_after_join(tiny_ds):
    """stop(drain=False): queued requests are cancelled only after the
    worker exits — a request is either served or failed, never both."""
    eng = _engine(tiny_ds, serve=ServeConfig(buckets=(8,), max_queue=64))
    srv = eng.serve().start()
    futs = [srv.submit(tiny_ds.val_idx[:4]) for _ in range(40)]
    srv.stop(drain=False)
    outcomes = []
    for f in futs:
        try:
            outcomes.append(f.result(timeout=60).status)
        except ServerClosed:
            outcomes.append("cancelled")
    assert all(o in ("ok", "cancelled") for o in outcomes), outcomes
    assert srv.meter.served == outcomes.count("ok")
    assert srv.meter.errors == 0


def test_serving_refresh_converges_cache_to_inference_hot_set(tiny_ds):
    """The closed cache loop: a skewed serving stream feeds the adaptive
    EMA, so the next generation admits the inference hot set — its cached
    share rises after the refresh."""
    eng = _engine(tiny_ds, strategy="adaptive", fraction=0.05)
    eng.ensure_cache(np.random.default_rng(0))
    rng = np.random.default_rng(42)
    hot = rng.choice(tiny_ds.val_idx, size=40, replace=False)
    before = float(eng.store.state.in_cache[hot].mean())
    with eng.serve() as srv:
        for _ in range(30):
            srv.infer(rng.choice(hot, size=8, replace=False), timeout=120)
    eng.store.refresh(np.random.default_rng(1), version=1)
    after = float(eng.store.state.in_cache[hot].mean())
    # the EMA also credits the hot set's sampled neighborhoods, which
    # compete for the 5% of slots — a step improvement, not total takeover
    assert after >= max(4 * before, 0.3), (before, after)


# ---------------------------------------------------------------------------
# subprocess serve-smoke on 4 forced host devices (the CI job)
# ---------------------------------------------------------------------------

SERVE_SMOKE_CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import time
import numpy as np
import jax

from repro.core.sampler import SamplerConfig
from repro.featurestore import CacheConfig
from repro.gns import EngineConfig, GNSEngine, ServeConfig
from repro.gns.config import MeshConfig, ModelConfig

assert len(jax.devices()) == 4

# the production shape at CI scale: sharded cache + fused input + locality
# placement on the 4-device mesh, adaptive admission fed by serving traffic
scfg = SamplerConfig(fanouts=(3, 4), batch_size=32,
                     cache=CacheConfig(fraction=0.05, strategy="adaptive",
                                       placement="locality"))
cfg = EngineConfig(sampler="gns", sampling=scfg, cache=scfg.cache,
                   model=ModelConfig(input_impl="fused", hidden_dim=16),
                   mesh=MeshConfig(data=1, model=4),
                   serve=ServeConfig(buckets=(8, 32), max_wait_ms=2.0,
                                     refresh_every=6),
                   seed=0)
eng = GNSEngine(cfg)
ds = eng.ds

rng = np.random.default_rng(7)
hot = rng.choice(ds.val_idx, size=40, replace=False)

with eng.serve() as srv:
    # skewed stream: 85% of requests draw from the hot set; the mid-stream
    # refreshes (refresh_every=6) re-draw the cache toward it
    warm_done = None
    for i in range(60):
        if rng.random() < 0.85:
            ids = rng.choice(hot, size=int(rng.integers(2, 8)), replace=False)
        else:
            ids = rng.choice(ds.val_idx, size=int(rng.integers(2, 8)),
                             replace=False)
        srv.infer(ids, timeout=300)
        if i == 9:
            warm_done = eng.infer_step._cache_size()

# drain any straggling refresh AFTER the worker stopped (swap-point free)
eng.store.wait_refresh(timeout=60)
m = srv.meter
snap = m.snapshot()
assert m.served == 60 and m.errors == 0, snap

# 1) steady-state zero recompilation: no new compiled steps after warmup
assert warm_done is not None
assert eng.infer_step._cache_size() == warm_done, (
    eng.infer_step._cache_size(), warm_done)

# 2) p99 bound: queue wait + compute stay sane on the CI box
assert snap["total_p99_ms"] is not None and snap["total_p99_ms"] < 30000, snap

# 3) cache-hit improvement: the serving-driven refreshes lifted the hit
#    fraction of the skewed stream (first batches vs last batches)
traj = m.hit_trajectory()
k = max(len(traj) // 4, 1)
early, late = float(np.mean(traj[:k])), float(np.mean(traj[-k:]))
assert m.swaps_observed >= 1, snap
assert late > early, (early, late, traj)

# 4) monotonic generation adoption under the mid-stream refreshes
trail = m.generation_trail()
assert all(a <= b for a, b in zip(trail, trail[1:])), trail
assert trail[-1] > trail[0], trail

print("SERVE_SMOKE_OK", round(early, 3), "->", round(late, 3),
      "p99_ms=", snap["total_p99_ms"], "swaps=", m.swaps_observed)
"""


def _run_sub(code: str, timeout: int = 900) -> str:
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run(
        [sys.executable, "-c", code],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


@pytest.mark.dryrun
def test_serve_smoke_on_mesh_subprocess():
    """The CI serve-smoke acceptance: skewed stream + mid-stream refresh on
    the forced-host 4-device mesh — p99 bounded, hit rate improves, zero
    steady-state recompilation, monotonic generation trail."""
    out = _run_sub(SERVE_SMOKE_CODE)
    assert "SERVE_SMOKE_OK" in out, out[-3000:]

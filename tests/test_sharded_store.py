"""Shard-aware cache generations on a real (mocked) multi-device mesh.

Three layers of coverage:

* in-process (1 device): the logical slot -> (shard, local row) mapping and
  the padded table layout, no mesh required;
* subprocess on 4 forced host devices: shard-aware upload really moves
  1/n_shards of the replicated bytes, per-device shards hold exactly their
  contiguous row blocks, the fused sharded lookup matches the oracle
  bitwise, and the generation-swap race audit — a stress run with the async
  refresher swapping mid-epoch where every batch's gather must be bitwise
  identical to a synchronous resolve against its own generation;
* a ``dryrun``-marked reduced pod dry-run: the production lowering path
  (``input_impl="fused"`` + row-sharded cache + shard_map over the cache
  axis) compiled on a mocked 1x4 mesh (the CI fused-mesh job).

Subprocesses are used because jax locks the device count at first init.
"""
from __future__ import annotations

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.featurestore import CacheConfig, FeatureStore, sample_cache
from repro.graph.generate import powerlaw_graph


def _run_sub(code: str, timeout: int = 600) -> str:
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run(
        [sys.executable, "-c", code],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


# ---------------------------------------------------------------------------
# in-process: logical shard layout (no mesh)
# ---------------------------------------------------------------------------

def test_cache_config_pads_rows_to_shards():
    cfg = CacheConfig(fraction=0.01, shards=4)
    for v in (997, 1000, 123_456):
        rows = cfg.size(v)
        assert rows % 4 == 0
        assert rows >= max(int(v * 0.01), 1)
    assert cfg.size(1000) == FeatureStore.padded_rows(1000, 0.01, multiple=4)


def test_cache_state_slot_shard_roundtrip():
    g = powerlaw_graph(1200, avg_degree=6, seed=0)
    cfg = CacheConfig(fraction=0.05, shards=4)
    state = sample_cache(g, cfg, np.random.default_rng(0))
    assert state.n_shards == 4
    assert state.table_rows == cfg.size(g.num_nodes)
    rps = state.rows_per_shard
    assert rps * 4 == state.table_rows
    slots = state.slot_of[state.node_ids]
    # global slot == shard * rows_per_shard + local row, shard in range
    np.testing.assert_array_equal(
        state.shard_of(slots) * rps + state.local_row(slots), slots)
    assert state.shard_of(slots).max() < 4
    assert state.local_row(slots).max() < rps
    # misses stay -1 through both maps
    assert state.shard_of(np.array([-1]))[0] == -1
    assert state.local_row(np.array([-1]))[0] == -1


def test_store_logical_shards_single_device():
    """CacheConfig(shards=n) on one device: padded table, metered upload."""
    g = powerlaw_graph(800, avg_degree=6, seed=1)
    feats = np.random.default_rng(1).standard_normal(
        (g.num_nodes, 8)).astype(np.float32)
    store = FeatureStore(feats, g, CacheConfig(fraction=0.05, shards=4))
    assert store.size % 4 == 0 and store.n_shards == 4
    gen = store.refresh(np.random.default_rng(0))
    assert np.asarray(gen.table).shape == (store.size, 8)
    n = gen.state.size
    np.testing.assert_array_equal(np.asarray(gen.table)[:n],
                                  feats[gen.state.node_ids])
    # one device: the "sharded" upload degenerates to the full table
    assert store.meter.bytes_cache_upload == store.size * 8 * 4
    assert store.meter.uploads == 1


def test_trainer_with_mesh_runs_fused_sharded_path():
    """GNNTrainer(mesh=...) + input_impl='fused': the jitted steps run under
    the mesh scope and the model inherits the store's shard axis, so the
    input layer goes through the per-shard kernel + psum instead of an
    all-gather of the table (1-device host mesh: the layout degenerates but
    the whole mesh-scoped path executes end to end)."""
    from repro.core.sampler import SamplerConfig
    from repro.graph.datasets import get_dataset
    from repro.launch.mesh import make_host_mesh
    from repro.models import graphsage
    from repro.train.trainer import GNNTrainer

    ds = get_dataset("tiny", seed=0)
    mesh = make_host_mesh(1, 1)
    scfg = SamplerConfig(fanouts=(3, 4), batch_size=16,
                         cache=CacheConfig(fraction=0.2))
    mcfg = graphsage.SageConfig(feat_dim=ds.feat_dim, hidden_dim=16,
                                num_classes=ds.num_classes, num_layers=2,
                                input_impl="fused")
    tr = GNNTrainer(ds, "gns", sampler_cfg=scfg, model_cfg=mcfg, mesh=mesh)
    assert tr.mcfg.cache_shard_axis == tr.store.shard_axis == "model"
    rep = tr.train(1, max_batches=2)
    assert np.isfinite(rep.losses).all(), rep.losses
    assert tr.meter.uploads >= 1 and tr.meter.bytes_cache_upload > 0


# ---------------------------------------------------------------------------
# subprocess: 4 forced host devices
# ---------------------------------------------------------------------------

MESH_CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.pipeline import EpochLoader
from repro.core.sampler import GNSSampler, SamplerConfig
from repro.featurestore import CacheConfig, FeatureStore
from repro.graph.generate import powerlaw_graph
from repro.kernels import ref as kref
from repro.kernels.ops import cache_lookup_agg

devs = jax.devices()
assert len(devs) == 4, devs
mesh = Mesh(np.asarray(devs), ("model",))

g = powerlaw_graph(2000, avg_degree=8, seed=0)
rng = np.random.default_rng(0)
# integer-valued f32 features -> every gather/parity check below is BITWISE
feats = rng.integers(-64, 65, (g.num_nodes, 16)).astype(np.float32)

# ---- 1) shard-aware upload: each device gets ONLY its contiguous rows ----
cfg = CacheConfig(fraction=0.05)
st = FeatureStore(feats, g, cfg, mesh=mesh, shard_axis="model")
assert st.n_shards == 4 and st.size % 4 == 0
gen = st.refresh(np.random.default_rng(1), version=0)
table_bytes = st.size * 16 * 4
assert st.meter.bytes_cache_upload == table_bytes, (
    st.meter.bytes_cache_upload, table_bytes)

repl = FeatureStore(feats, g, cfg, sharding=NamedSharding(mesh, P()))
repl.refresh(np.random.default_rng(1), version=0)
repl_bytes = 4 * repl.size * 16 * 4
assert repl.meter.bytes_cache_upload == repl_bytes, (
    repl.meter.bytes_cache_upload, repl_bytes)
# acceptance: sharded upload ~ 1/n of the replicated baseline
assert st.meter.bytes_cache_upload * 4 <= repl.meter.bytes_cache_upload * 1.01

n = gen.state.size
full = np.zeros((st.size, 16), np.float32)
full[:n] = feats[gen.state.node_ids]
np.testing.assert_array_equal(np.asarray(gen.table), full)
rps = gen.state.rows_per_shard
assert rps == st.size // 4
for shard in gen.table.addressable_shards:
    assert shard.data.shape == (rps, 16)
    np.testing.assert_array_equal(np.asarray(shard.data), full[shard.index])
# recycle gen's staging half (two more builds): the retired generation's
# sharded device table must remain bitwise intact — no shard may alias the
# reused host staging buffer
st.refresh(np.random.default_rng(2), version=1)
st.refresh(np.random.default_rng(3), version=2)
assert gen.retired
np.testing.assert_array_equal(np.asarray(gen.table), full)
print("UPLOAD_OK")

# ---- 2) fused sharded lookup on the real mesh: bitwise vs the oracle ----
gen2 = st.generation                  # live (the retired gen dropped its
state = gen2.state                    # O(V) slot map by design)
full2 = np.zeros((st.size, 16), np.float32)
full2[:state.size] = feats[state.node_ids]
s0, b, k = 160, 12, 5
ids = rng.choice(g.num_nodes, s0, replace=False).astype(np.int64)
slots = state.slot_of[ids].astype(np.int32)
assert (slots >= 0).any() and (slots < 0).any()
streamed = np.where(slots[:, None] >= 0, 0.0, feats[ids]).astype(np.float32)
idx = rng.integers(0, s0, (b, k)).astype(np.int32)
w = rng.integers(-4, 5, (b, k)).astype(np.float32)
out = cache_lookup_agg(gen2.table, jnp.asarray(streamed), jnp.asarray(slots),
                       jnp.asarray(idx), jnp.asarray(w),
                       mesh=mesh, shard_axis="model")
expect = kref.cache_lookup_agg_ref(jnp.asarray(full2), jnp.asarray(streamed),
                                   jnp.asarray(slots), jnp.asarray(idx),
                                   jnp.asarray(w))
np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))
print("FUSED_SHARDED_OK")

# ---- 2b) DP>1: group-local semantics, forward AND custom-VJP backward ---
# On a (data=2, model=2) mesh each DP group's idx/slots index its OWN rows;
# the reference is the unsharded op run per group against the full table.
mesh22 = Mesh(np.asarray(devs).reshape(2, 2), ("data", "model"))
rng4 = np.random.default_rng(42)
C, D, s0l, bl, K = 16, 16, 20, 6, 3
table = rng4.integers(-8, 9, (C, D)).astype(np.float32)
groups = []
for _ in range(2):
    sl = np.full(s0l, -1, np.int32)
    pos = rng4.choice(s0l, 10, replace=False)
    sl[pos] = rng4.permutation(C)[:10].astype(np.int32)
    stg = rng4.integers(-8, 9, (s0l, D)).astype(np.float32)
    stg[sl >= 0] = 0
    ixg = rng4.integers(0, s0l, (bl, K)).astype(np.int32)
    wwg = rng4.integers(-3, 4, (bl, K)).astype(np.float32)
    groups.append((sl, stg, ixg, wwg))
slots_glob = np.concatenate([gp[0] for gp in groups])
streamed_glob = np.concatenate([gp[1] for gp in groups])
idx_glob = np.concatenate([gp[2] for gp in groups])
w_glob = np.concatenate([gp[3] for gp in groups])

out22 = cache_lookup_agg(jnp.asarray(table), jnp.asarray(streamed_glob),
                         jnp.asarray(slots_glob), jnp.asarray(idx_glob),
                         jnp.asarray(w_glob), mesh=mesh22, shard_axis="model")
ref22 = np.concatenate([
    np.asarray(cache_lookup_agg(jnp.asarray(table), jnp.asarray(stg),
                                jnp.asarray(sl), jnp.asarray(ixg),
                                jnp.asarray(wwg)))
    for sl, stg, ixg, wwg in groups])
np.testing.assert_array_equal(np.asarray(out22), ref22)

def loss_sh(tbl, st, ww):
    o = cache_lookup_agg(tbl, st, jnp.asarray(slots_glob),
                         jnp.asarray(idx_glob), ww,
                         mesh=mesh22, shard_axis="model")
    return (o ** 2).sum()

gt, gs, gw = jax.grad(loss_sh, argnums=(0, 1, 2))(
    jnp.asarray(table), jnp.asarray(streamed_glob), jnp.asarray(w_glob))

def loss_g(tbl, st, ww, sl, ixg):
    o = cache_lookup_agg(tbl, st, jnp.asarray(sl), jnp.asarray(ixg), ww)
    return (o ** 2).sum()

rt = np.zeros_like(table)
rs, rw = [], []
for sl, stg, ixg, wwg in groups:
    a, b_, c = jax.grad(loss_g, argnums=(0, 1, 2))(
        jnp.asarray(table), jnp.asarray(stg), jnp.asarray(wwg), sl, ixg)
    rt += np.asarray(a)
    rs.append(np.asarray(b_))
    rw.append(np.asarray(c))
np.testing.assert_allclose(np.asarray(gt), rt, rtol=1e-5, atol=1e-5)
np.testing.assert_allclose(np.asarray(gs), np.concatenate(rs),
                           rtol=1e-5, atol=1e-5)
np.testing.assert_allclose(np.asarray(gw), np.concatenate(rw),
                           rtol=1e-5, atol=1e-5)
print("FUSED_DP_GRAD_OK")

# ---- 2c) locality placement end-to-end on the mesh + psum-free fast path
# A store with placement="locality" learns skewed per-group traffic, the
# next generation co-locates each group's hot rows with its home shard, the
# per-device table shards hold exactly the permuted blocks, and a fully-
# local batch takes the kernel's psum-free fast path BITWISE-identically
# (forward AND the shared custom-VJP backward).
from repro.featurestore import home_shard

cfgL = CacheConfig(fraction=0.05, placement="locality")
stL = FeatureStore(feats, g, cfgL, mesh=mesh, shard_axis="model")
stL.refresh(np.random.default_rng(1), version=0)
rngL = np.random.default_rng(9)
genL0 = stL.generation
# hot sets smaller than rows_per_shard, so a group's surviving hot rows can
# never overflow its home shard's capacity (which would break full locality)
hot_n = genL0.state.rows_per_shard - 2
hot = {grp: np.sort(rngL.choice(genL0.state.node_ids, hot_n, replace=False))
       for grp in range(4)}
for _ in range(3):
    for grp in range(4):
        stL.assemble_input(stL.generation, hot[grp], len(hot[grp]), group=grp)
genL = stL.refresh(np.random.default_rng(2), version=1)
state = genL.state
assert state.placement is not None and not state.placement.is_identity
rpsL = state.rows_per_shard
# per-device shards hold the PERMUTED rows: device row r = node
# node_ids[slot_of_device_row[r]]
fullL = np.zeros((stL.size, 16), np.float32)
fullL[state.device_rows(np.arange(state.size))] = feats[state.node_ids]
for shard in genL.table.addressable_shards:
    np.testing.assert_array_equal(np.asarray(shard.data), fullL[shard.index])

# a group-0 batch of its (still-cached) hot rows is fully local -> fast path
ids0 = hot[0][state.slot_of[hot[0]] >= 0]
ids0_p = np.concatenate([ids0, np.zeros(8, np.int64)])
stL.record = False
slotsL, streamedL, hitsL, _, localL = stL.assemble_input(
    genL, ids0_p, len(ids0), group=0)
stL.record = True
assert hitsL == len(ids0) > 0
assert localL == home_shard(0, 4) == 0, localL
idxL = np.random.default_rng(3).integers(0, len(ids0_p), (6, 4)).astype(np.int32)
wL = np.random.default_rng(4).integers(-3, 4, (6, 4)).astype(np.float32)
a_fast = cache_lookup_agg(genL.table, jnp.asarray(streamedL),
                          jnp.asarray(slotsL), jnp.asarray(idxL),
                          jnp.asarray(wL), mesh=mesh, shard_axis="model",
                          local_shard=localL)
a_psum = cache_lookup_agg(genL.table, jnp.asarray(streamedL),
                          jnp.asarray(slotsL), jnp.asarray(idxL),
                          jnp.asarray(wL), mesh=mesh, shard_axis="model")
a_ref = kref.cache_lookup_agg_ref(jnp.asarray(fullL), jnp.asarray(streamedL),
                                  jnp.asarray(slotsL), jnp.asarray(idxL),
                                  jnp.asarray(wL))
np.testing.assert_array_equal(np.asarray(a_fast), np.asarray(a_psum))
np.testing.assert_array_equal(np.asarray(a_fast), np.asarray(a_ref))

def lossL(tbl, st_, ww, local_shard):
    o = cache_lookup_agg(tbl, st_, jnp.asarray(slotsL), jnp.asarray(idxL),
                         ww, mesh=mesh, shard_axis="model",
                         local_shard=local_shard)
    return (o ** 2).sum()

g_fast = jax.grad(lossL, argnums=(0, 1, 2))(
    genL.table, jnp.asarray(streamedL), jnp.asarray(wL), localL)
g_psum = jax.grad(lossL, argnums=(0, 1, 2))(
    genL.table, jnp.asarray(streamedL), jnp.asarray(wL), None)
for gf, gp in zip(g_fast, g_psum):
    np.testing.assert_array_equal(np.asarray(gf), np.asarray(gp))
print("LOCALITY_FAST_PATH_OK")

# ---- 3) swap-race stress: async refresher swaps MID-EPOCH ---------------
labels = np.zeros(g.num_nodes, np.int32)
train = np.arange(1200, dtype=np.int64)
scfg = SamplerConfig(fanouts=(3, 4), batch_size=64,
                     cache=CacheConfig(fraction=0.05, period=1,
                                       async_refresh=True))
store = FeatureStore(feats, g, scfg.cache, mesh=mesh, shard_axis="model",
                     build_adjacency=True)
store.refresh_delay = 0.05           # land the swap a few batches in
s = GNSSampler(g, scfg, feats, labels, train_idx=train, store=store)
loader = EpochLoader(s, train, seed=0)
seen, mid_epoch_swaps = set(), 0
for ep in range(12):       # loop until a swap demonstrably lands mid-epoch
    # sweep the build latency down so some epoch straddles the sampling
    # duration whatever this host's speed — the swap then lands mid-epoch
    store.refresh_delay = 0.05 / (ep + 1)
    ep_versions = []
    for mb in loader.epoch(ep):
        gen = mb.cache_gen
        assert gen is not None and not gen.retired
        ep_versions.append(mb.cache_version)
        assert mb.cache_version == gen.version
        nin = mb.num_input
        ids = mb.input_node_ids[:nin]
        slots = mb.device.input_cache_slots[:nin]
        # the batch's slots must resolve against ITS generation's shard
        # tables: gathering through (sharded table | streamed) must equal
        # the ground-truth feature rows BITWISE — any slot torn across a
        # swap would fetch another generation's row and differ
        tbl = np.asarray(gen.table)
        h0 = np.where(slots[:, None] >= 0, tbl[np.clip(slots, 0, None)],
                      mb.device.input_streamed[:nin])
        np.testing.assert_array_equal(h0, feats[ids])
        # and a SYNCHRONOUS re-resolve against the same generation must
        # reproduce the async-sampled batch exactly
        store.record = False
        slots2, streamed2, _, _, _ = store.assemble_input(
            gen, mb.input_node_ids, nin)
        store.record = True
        np.testing.assert_array_equal(slots2, mb.device.input_cache_slots)
        np.testing.assert_array_equal(streamed2, mb.device.input_streamed)
    seen.update(ep_versions)
    if len(set(ep_versions)) > 1:
        mid_epoch_swaps += 1
    store.wait_refresh(timeout=10.0)
    s.adopt_generation()
    if ep >= 2 and mid_epoch_swaps >= 1 and len(seen) >= 2:
        break
assert len(seen) >= 2, seen                  # refreshes actually happened
assert mid_epoch_swaps >= 1, "no swap landed mid-epoch; stress is vacuous"
print("SWAP_STRESS_OK")
"""


def test_sharded_store_on_mesh_subprocess():
    out = _run_sub(MESH_CODE)
    for marker in ("UPLOAD_OK", "FUSED_SHARDED_OK", "FUSED_DP_GRAD_OK",
                   "LOCALITY_FAST_PATH_OK", "SWAP_STRESS_OK"):
        assert marker in out, out[-2000:]


# ---------------------------------------------------------------------------
# reduced pod dry-run: fused input path on a mocked 1x4 mesh (CI job)
# ---------------------------------------------------------------------------

DRYRUN_FUSED_CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax
from jax.sharding import Mesh

from repro.launch import dryrun_gnn

mesh = Mesh(np.asarray(jax.devices()).reshape(1, 4), ("data", "model"))
# default = the ENGINE lowering: dynamic home-shard vector, one compiled
# step for any mix of per-group fast paths (gns.engine.make_train_step)
rec = dryrun_gnn.run(mesh=mesh, num_nodes=5000, feat_dim=32, num_classes=8,
                     cache_frac=0.05, batch=16, fanouts=(3, 4), hidden_dim=16,
                     input_impl="fused")
assert rec["status"] == "ok" and rec["input_impl"] == "fused", rec
assert rec["cache_shard_axis"] == "model"
assert rec["fast_path"] == "dynamic" and rec["local_fast_path"], rec
assert rec["dp_groups"] == 1
assert rec["cache_rows"] % 4 == 0
assert rec["upload_bytes_per_gen_replicated"] == \
    4 * rec["upload_bytes_per_gen_sharded"]
# locality placement sim rides the record: the solver must beat contiguous
assert rec["lookup_local_frac_locality"] > rec["lookup_local_frac_contiguous"]
assert rec["crossshard_bytes_per_batch_locality"] < \
    rec["crossshard_bytes_per_batch_contiguous"]
# the legacy lowerings still compile on the same mesh: the PR-3 static-arg
# fast path and the plain psum path (no locality gate)
rec_sta = dryrun_gnn.run(mesh=mesh, num_nodes=5000, feat_dim=32,
                         num_classes=8, cache_frac=0.05, batch=16,
                         fanouts=(3, 4), hidden_dim=16, input_impl="fused",
                         fast_path="static")
assert rec_sta["status"] == "ok" and rec_sta["fast_path"] == "static", rec_sta
rec_off = dryrun_gnn.run(mesh=mesh, num_nodes=5000, feat_dim=32,
                         num_classes=8, cache_frac=0.05, batch=16,
                         fanouts=(3, 4), hidden_dim=16, input_impl="fused",
                         fast_path="off")
assert rec_off["status"] == "ok" and not rec_off["local_fast_path"], rec_off
print("DRYRUN_FUSED_OK", rec["mesh"], rec["roofline"]["dominant"],
      "local-hit", rec["lookup_local_frac_locality"])
"""


@pytest.mark.dryrun
def test_dryrun_gnn_fused_small_mesh():
    """The pod-scale lowering path — SageConfig(input_impl="fused") with the
    row-sharded cache table and shard_map over the cache axis — compiled on
    a mocked multi-device mesh (the CI fused-mesh job runs this with
    XLA_FLAGS=--xla_force_host_platform_device_count=4)."""
    out = _run_sub(DRYRUN_FUSED_CODE)
    assert "DRYRUN_FUSED_OK" in out, out[-2000:]

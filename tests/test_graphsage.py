"""GraphSAGE forward/backward on padded blocks + trainer smoke."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.featurestore import CacheConfig
from repro.core.sampler import SamplerConfig, make_sampler
from repro.graph.datasets import get_dataset
from repro.models import graphsage
from repro.train.trainer import GNNTrainer


@pytest.fixture(scope="module")
def ds():
    return get_dataset("tiny", seed=0)


def _minibatch(ds, name="ns", batch=16, fanouts=(3, 4, 5)):
    cfg = SamplerConfig(fanouts=fanouts, batch_size=batch,
                        cache=CacheConfig(fraction=0.05))
    s = make_sampler(name, ds.graph, cfg, ds.features, ds.labels,
                     train_idx=ds.train_idx)
    rng = np.random.default_rng(0)
    s.start_epoch(0, rng)
    targets = rng.choice(ds.train_idx, size=batch, replace=False)
    return s, s.sample(targets.astype(np.int64), rng)


def test_forward_shapes_and_finite(ds):
    s, mb = _minibatch(ds)
    cfg = graphsage.SageConfig(feat_dim=ds.feat_dim, hidden_dim=32,
                               num_classes=ds.num_classes)
    params = graphsage.init_params(jax.random.PRNGKey(0), cfg)
    logits = graphsage.forward(params, mb.device,
                               graphsage.dummy_cache_table(ds.feat_dim), cfg)
    assert logits.shape == (16, ds.num_classes)
    assert jnp.isfinite(logits).all()


def test_reference_aggregate_matches_manual(ds):
    h = jnp.asarray(np.random.default_rng(0).normal(size=(50, 8)), jnp.float32)
    idx = jnp.asarray(np.random.default_rng(1).integers(0, 50, (10, 4)), jnp.int32)
    w = jnp.asarray(np.random.default_rng(2).random((10, 4)), jnp.float32)
    out = graphsage.reference_aggregate(h, idx, w)
    manual = np.zeros((10, 8), np.float32)
    for d in range(10):
        for k in range(4):
            manual[d] += np.asarray(w)[d, k] * np.asarray(h)[np.asarray(idx)[d, k]]
    np.testing.assert_allclose(np.asarray(out), manual, rtol=1e-4, atol=1e-6)


def test_grad_flows_through_cache_path(ds):
    """GNS path: cache-hit rows must still contribute gradients to layer 0."""
    s, mb = _minibatch(ds, name="gns")
    cfg = graphsage.SageConfig(feat_dim=ds.feat_dim, hidden_dim=16,
                               num_classes=ds.num_classes)
    params = graphsage.init_params(jax.random.PRNGKey(0), cfg)
    cache_rows = ds.features[s.cache.node_ids]
    table = jnp.asarray(cache_rows, jnp.float32)
    loss, _ = graphsage.loss_fn(params, mb.device, table, cfg)
    grads = jax.grad(lambda p: graphsage.loss_fn(p, mb.device, table, cfg)[0])(params)
    assert jnp.isfinite(loss)
    gnorm = sum(float(jnp.abs(g).sum()) for g in jax.tree_util.tree_leaves(grads))
    assert gnorm > 0


@pytest.mark.parametrize("name", ["ns", "gns"])
def test_trainer_loss_decreases(ds, name):
    scfg = SamplerConfig(fanouts=(3, 4, 5), batch_size=64,
                         cache=CacheConfig(fraction=0.1, period=1))
    tr = GNNTrainer(ds, name, sampler_cfg=scfg, seed=0)
    report = tr.train(epochs=3, max_batches=6)
    assert report.losses[-1] < report.losses[0]
    assert np.isfinite(report.losses).all()


def test_trainer_traffic_accounting(ds):
    scfg = SamplerConfig(fanouts=(3, 4, 5), batch_size=64,
                         cache=CacheConfig(fraction=0.1, period=1))
    tr = GNNTrainer(ds, "gns", sampler_cfg=scfg, seed=0)
    report = tr.train(epochs=1, max_batches=4)
    m = report.meter
    assert m.steps == 4
    assert m.bytes_cache_fill > 0          # cache got uploaded
    assert report.cached_nodes_per_batch > 0
    # GNS per-batch traffic far below the all-streamed equivalent
    full = report.input_nodes_per_batch * ds.feat_dim * 4
    assert m.bytes_streamed / m.steps < full

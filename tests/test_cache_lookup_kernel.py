"""Fused cache-lookup + first-layer-gather kernel vs the ref.py oracle.

Bitwise parity on CPU interpret mode uses integer-valued f32 inputs: the
kernel's accumulation order matches the reference exactly, and with exactly
representable products the backend's mul+add→FMA contraction is rounding-
neutral, so equality is bit-for-bit.  Continuous-float sweeps cover the
same paths at 1-ulp tolerance.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.cache_lookup import cache_lookup_agg_pallas
from repro.kernels.ops import cache_lookup_agg


def _case(rng, c, s0, d, b, k, exact=False, miss_frac=0.5):
    if exact:
        cache = rng.integers(-128, 129, (c, d)).astype(np.float32)
        streamed = rng.integers(-128, 129, (s0, d)).astype(np.float32)
        w = rng.integers(-8, 9, (b, k)).astype(np.float32)
    else:
        cache = rng.normal(size=(c, d)).astype(np.float32)
        streamed = rng.normal(size=(s0, d)).astype(np.float32)
        w = rng.normal(size=(b, k)).astype(np.float32)
    slots = np.full(s0, -1, np.int32)
    n_hit = min(c, int(s0 * (1 - miss_frac)))
    slots[rng.choice(s0, n_hit, replace=False)] = rng.permutation(c)[:n_hit]
    idx = rng.integers(0, s0, (b, k)).astype(np.int32)
    # streamed rows are zero where cached (as the store assembles them)
    streamed[slots >= 0] = 0.0 if not exact else streamed[slots >= 0] * 0
    return (jnp.asarray(cache), jnp.asarray(streamed), jnp.asarray(slots),
            jnp.asarray(idx), jnp.asarray(w))


@pytest.mark.parametrize("c,s0,d,b,k,block_d", [
    (16, 64, 32, 8, 4, 16),
    (50, 200, 64, 16, 8, 64),
    (30, 100, 48, 7, 5, 48),     # d not a power of two
])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_cache_lookup_bitwise_parity(c, s0, d, b, k, block_d, seed):
    rng = np.random.default_rng(seed)
    args = _case(rng, c, s0, d, b, k, exact=True)
    out = cache_lookup_agg_pallas(*args, block_d=block_d, interpret=True)
    expect = ref.cache_lookup_agg_ref(*args)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("miss_frac", [0.0, 0.5, 1.0])
def test_cache_lookup_float_parity(miss_frac):
    rng = np.random.default_rng(3)
    args = _case(rng, 40, 150, 32, 12, 6, exact=False, miss_frac=miss_frac)
    out = cache_lookup_agg_pallas(*args, block_d=32, interpret=True)
    expect = ref.cache_lookup_agg_ref(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-6)


def test_cache_lookup_zero_weight_lanes_ignore_index():
    """Padded lanes (w=0) must not contribute, whatever their index/slot."""
    cache = jnp.asarray(np.full((10, 8), 1e30), jnp.float32)
    streamed = jnp.asarray(np.full((20, 8), -1e30), jnp.float32)
    slots = jnp.asarray(np.r_[np.arange(10), np.full(10, -1)], jnp.int32)
    idx = jnp.zeros((4, 3), jnp.int32)
    w = jnp.zeros((4, 3), jnp.float32)
    out = cache_lookup_agg_pallas(cache, streamed, slots, idx, w, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_cache_lookup_all_miss_matches_gather_agg():
    """With an empty cache the fused kernel degenerates to gather_agg over
    the streamed rows."""
    rng = np.random.default_rng(4)
    cache = jnp.zeros((5, 16), jnp.float32)
    streamed = jnp.asarray(rng.normal(size=(60, 16)), jnp.float32)
    slots = jnp.full((60,), -1, jnp.int32)
    idx = jnp.asarray(rng.integers(0, 60, (9, 4)), jnp.int32)
    w = jnp.asarray(rng.random((9, 4)), jnp.float32)
    out = cache_lookup_agg_pallas(cache, streamed, slots, idx, w, interpret=True)
    expect = ref.gather_agg_ref(streamed, idx, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# shard-aware slot mapping (per-shard local rows, contiguous blocks)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", [2, 4])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_sharded_slot_mapping_bitwise_parity(n_shards, seed):
    """Σ_shards kernel(local table shard, shard-local slots, masked lanes)
    must reproduce the single-device fused kernel BITWISE on integer-valued
    inputs: the decomposition only adds zero terms to the fixed-order sum."""
    from repro.kernels.cache_lookup import cache_lookup_agg_shard_partial

    rng = np.random.default_rng(seed)
    c, s0, d, b, k = 24, 96, 32, 9, 5
    args = _case(rng, c, s0, d, b, k, exact=True)
    full = cache_lookup_agg_pallas(*args, block_d=16, interpret=True)
    cache, streamed, slots, idx, w = args
    rps = c // n_shards
    parts = sum(
        cache_lookup_agg_shard_partial(
            cache[s * rps:(s + 1) * rps], streamed, slots, idx, w, s, rps,
            block_d=16, interpret=True)
        for s in range(n_shards))
    np.testing.assert_array_equal(np.asarray(parts), np.asarray(full))


def test_sharded_lanes_contributed_exactly_once():
    """Every (b, k) lane is claimed by exactly one shard: the slot owner for
    hits, shard 0 for misses — so the psum never double counts."""
    from repro.kernels.cache_lookup import shard_lane_weights

    rng = np.random.default_rng(7)
    n_shards, rps = 4, 6
    lane_slots = jnp.asarray(
        rng.integers(-1, n_shards * rps, (8, 5)).astype(np.int32))
    w = jnp.asarray(rng.normal(size=(8, 5)).astype(np.float32))
    claimed = sum(
        (shard_lane_weights(w, lane_slots, s, rps) != 0).astype(np.int32)
        for s in range(n_shards))
    np.testing.assert_array_equal(np.asarray(claimed),
                                  np.asarray((w != 0).astype(np.int32)))


def test_shard_slot_map_local_rows():
    from repro.kernels.cache_lookup import shard_slot_map

    slots = jnp.asarray(np.array([-1, 0, 5, 6, 11, 23], np.int32))
    rps = 6
    np.testing.assert_array_equal(
        np.asarray(shard_slot_map(slots, 0, rps)), [-1, 0, 5, -1, -1, -1])
    np.testing.assert_array_equal(
        np.asarray(shard_slot_map(slots, 1, rps)), [-1, -1, -1, 0, 5, -1])
    np.testing.assert_array_equal(
        np.asarray(shard_slot_map(slots, 3, rps)), [-1, -1, -1, -1, -1, 5])


def _local_case(rng, nsh, rps, s0, d, b, k, owner):
    """Integer-exact case whose hit slots ALL live on shard `owner`."""
    c = nsh * rps
    cache = rng.integers(-64, 65, (c, d)).astype(np.float32)
    streamed = rng.integers(-64, 65, (s0, d)).astype(np.float32)
    w = rng.integers(-4, 5, (b, k)).astype(np.float32)
    slots = np.full(s0, -1, np.int32)
    pos = rng.choice(s0, rps, replace=False)
    slots[pos] = (owner * rps + rng.permutation(rps)).astype(np.int32)
    streamed[slots >= 0] = 0
    idx = rng.integers(0, s0, (b, k)).astype(np.int32)
    return (jnp.asarray(cache), jnp.asarray(streamed), jnp.asarray(slots),
            jnp.asarray(idx), jnp.asarray(w))


@pytest.mark.parametrize("owner", [0, 1, 3])
@pytest.mark.parametrize("seed", [0, 1])
def test_local_fast_path_partial_bitwise(owner, seed):
    """In-process fast-path oracle: the owner shard's claim_all partial on a
    fully-local batch IS the full fused kernel, bitwise — no psum term from
    any other shard is needed (they would all be exactly zero)."""
    from repro.kernels.cache_lookup import cache_lookup_agg_shard_partial

    rng = np.random.default_rng(seed)
    nsh, rps = 4, 6
    cache, streamed, slots, idx, w = _local_case(rng, nsh, rps, 96, 32, 9, 5,
                                                 owner)
    full = cache_lookup_agg_pallas(cache, streamed, slots, idx, w,
                                   block_d=16, interpret=True)
    local_tbl = cache[owner * rps:(owner + 1) * rps]
    fast = cache_lookup_agg_shard_partial(local_tbl, streamed, slots, idx, w,
                                          owner, rps, block_d=16,
                                          interpret=True, claim_all=True)
    np.testing.assert_array_equal(np.asarray(fast), np.asarray(full))
    # and every OTHER shard's owner-claim partial is exactly zero
    for s in range(nsh):
        if s == owner:
            continue
        part = cache_lookup_agg_shard_partial(
            cache[s * rps:(s + 1) * rps], streamed, slots, idx, w, s, rps,
            block_d=16, interpret=True)
        # misses are claimed by shard 0 in the psum decomposition, so only
        # truly unrelated shards vanish; mask the miss term out for shard 0
        if s != 0:
            np.testing.assert_array_equal(np.asarray(part), 0.0)


def test_ops_local_shard_ignored_without_mesh():
    """local_shard is a mesh-path concept; meshless calls must not change."""
    rng = np.random.default_rng(6)
    args = _case(rng, 20, 80, 24, 6, 4, exact=True)
    base = cache_lookup_agg(*args)
    fast = cache_lookup_agg(*args, local_shard=2)
    np.testing.assert_array_equal(np.asarray(fast), np.asarray(base))


def test_fused_vjp_matches_reference_grad():
    """The custom VJP (Pallas has no AD rules) must agree with autodiff
    through the pure-jnp oracle for cache table, streamed rows and weights."""
    rng = np.random.default_rng(11)
    cache, streamed, slots, idx, w = _case(rng, 20, 80, 16, 6, 4, exact=False)

    def loss_fused(c, s, ww):
        return (cache_lookup_agg(c, s, slots, idx, ww) ** 2).sum()

    def loss_ref(c, s, ww):
        return (ref.cache_lookup_agg_ref(c, s, slots, idx, ww) ** 2).sum()

    gf = jax.grad(loss_fused, argnums=(0, 1, 2))(cache, streamed, w)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(cache, streamed, w)
    for a, b in zip(gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


def test_ops_wrapper_dispatch():
    rng = np.random.default_rng(5)
    args = _case(rng, 20, 80, 24, 6, 4)
    out_k = cache_lookup_agg(*args, impl="pallas")
    out_r = cache_lookup_agg(*args, impl="reference")
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-6)


def test_graphsage_fused_input_matches_reference():
    """input_impl='fused' forward == reference forward on a real GNS batch."""
    from repro.core.sampler import SamplerConfig, make_sampler
    from repro.featurestore import CacheConfig
    from repro.graph.datasets import get_dataset
    from repro.models import graphsage

    ds = get_dataset("tiny", seed=0)
    cfg = SamplerConfig(fanouts=(3, 4, 5), batch_size=8,
                        cache=CacheConfig(fraction=0.2))
    s = make_sampler("gns", ds.graph, cfg, ds.features, ds.labels,
                     train_idx=ds.train_idx)
    rng = np.random.default_rng(0)
    s.start_epoch(0, rng)
    mb = s.sample(rng.choice(ds.train_idx, 8, replace=False).astype(np.int64),
                  rng)
    assert mb.num_cached > 0            # exercise the cache-hit lane

    mcfg = graphsage.SageConfig(feat_dim=ds.feat_dim, hidden_dim=16,
                                num_classes=ds.num_classes)
    params = graphsage.init_params(jax.random.PRNGKey(0), mcfg)
    table = mb.cache_gen.table
    ref_logits = graphsage.forward(params, mb.device, table, mcfg)
    fused_cfg = dataclasses.replace(mcfg, input_impl="fused")
    fused_logits = graphsage.forward(params, mb.device, table, fused_cfg)
    np.testing.assert_allclose(np.asarray(fused_logits),
                               np.asarray(ref_logits), rtol=1e-4, atol=1e-4)

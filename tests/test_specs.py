"""input_specs construction for all 40 (arch x shape) cells on a small mesh.

The production 16x16/2x16x16 meshes are exercised by launch/dryrun.py (a
separate process — device count is locked at first jax init).  Here a 1x1
mesh over the CPU device checks that every cell's struct/sharding pytrees
are well-formed and consistent, so spec bugs surface in seconds not in the
hours-long dry-run sweep.
"""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_archs, shape_applicable
from repro.launch.mesh import make_host_mesh
from repro.launch.specs import input_specs
from repro.launch.sharding import infer_logical_axes, spec_for
from repro.models.lm import get_model

CELLS = [(a, s) for a in list_archs() for s in SHAPES]


@pytest.mark.parametrize("arch,shape_name", CELLS)
def test_cell_specs_build(arch, shape_name):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        pytest.skip(why)
    mesh = make_host_mesh(1, 1)
    specs = input_specs(cfg, shape, mesh)
    p_structs, p_sh = specs["params"]
    assert (jax.tree_util.tree_structure(p_structs).num_leaves ==
            jax.tree_util.tree_structure(p_sh).num_leaves)
    if shape.kind in ("decode", "prefill"):
        t_struct, _ = specs["tokens"]
        if shape.kind == "decode":
            assert t_struct.shape == (shape.global_batch, 1)
        else:                         # prefill: the whole prompt
            assert t_struct.shape[0] == shape.global_batch
            assert 1 < t_struct.shape[1] <= shape.seq_len
        s_structs, s_sh = specs["state"]
        assert (jax.tree_util.tree_structure(s_structs).num_leaves ==
                jax.tree_util.tree_structure(s_sh).num_leaves)
        # SWA archs must hold a ring buffer, not the full 500k cache
        if cfg.sliding_window and shape_name == "long_500k":
            for kp, l in jax.tree_util.tree_flatten_with_path(s_structs)[0]:
                path = "/".join(str(getattr(k, "key", k)) for k in kp)
                if path.endswith("/k"):
                    assert l.shape[-2] <= cfg.sliding_window
    else:
        b_structs, _ = specs["batch"]
        accum = max(cfg.grad_accum, 1)
        for l in jax.tree_util.tree_leaves(b_structs):
            assert l.shape[0] == accum
        total = sum(l.shape[1] for l in jax.tree_util.tree_leaves(b_structs)
                    if l.shape) // len(jax.tree_util.tree_leaves(b_structs))
        assert total == shape.global_batch // accum


def test_param_rules_divisibility_fallback():
    """Non-divisible dims must fall back to replication (production mesh
    sizes stubbed — the pytest process only has 1 real device)."""
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    spec = spec_for(FakeMesh(), ("model", None), (28, 64))
    assert spec == jax.sharding.PartitionSpec(None, None)
    spec = spec_for(FakeMesh(), ("model", None), (32, 64))
    assert spec == jax.sharding.PartitionSpec("model", None)
    # batch spans (pod, data); 28 doesn't divide 16 -> replicated
    spec = spec_for(FakeMesh(), ("batch", None), (28, 64))
    assert spec == jax.sharding.PartitionSpec(None, None)


def test_infer_logical_axes_right_aligned():
    assert infer_logical_axes("layers/attn/wq", (12, 512, 512)) == \
        (None, None, "model")
    assert infer_logical_axes("layers/moe/experts_w2", (12, 8, 64, 512)) == \
        (None, "expert", "model", None)
    assert infer_logical_axes("embed", (1000, 64)) == ("model", None)


def test_decode_state_total_bytes_sane():
    """long_500k zamba2: 9 shared KV caches at 500k must stay < 64 GB total
    (the seq-sharded layout then fits 256 chips comfortably)."""
    cfg = get_config("zamba2-2.7b")
    mesh = make_host_mesh(1, 1)
    specs = input_specs(cfg, SHAPES["long_500k"], mesh)
    s_structs, _ = specs["state"]
    total = sum(np.prod(l.shape) * l.dtype.itemsize
                for l in jax.tree_util.tree_leaves(s_structs))
    assert total < 64e9, f"{total/1e9:.1f} GB"

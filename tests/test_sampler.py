"""Sampler structural invariants for all four methods (§3.3 + baselines).

Every sampler emits the same static-shape MiniBatch format, so one set of
invariants covers them:
  * block shapes are run-constant (static padding),
  * nbr_idx stays within the block's src axis,
  * dst nodes are a prefix of the src array (self-representation contract),
  * masked lanes have zero weight,
  * GNS input layer draws only from the cache; top-up lanes are non-cached,
  * GNS minibatches touch far fewer distinct input nodes than NS (Table 4),
  * LazyGCN recycles identical batches within a period.
"""
import numpy as np
import pytest

from repro.featurestore import CacheConfig
from repro.core.minibatch import block_pad_sizes
from repro.core.sampler import (GNSSampler, LadiesSampler, LazyGCNSampler,
                                NeighborSampler, SamplerConfig, make_sampler)
from repro.graph.datasets import get_dataset


@pytest.fixture(scope="module")
def ds():
    return get_dataset("tiny", seed=0)


def _mk(ds, name, **kw):
    cfg = SamplerConfig(fanouts=kw.pop("fanouts", (3, 4, 5)),
                        batch_size=kw.pop("batch_size", 32),
                        cache=CacheConfig(fraction=0.05, period=1),
                        **kw)
    s = make_sampler(name, ds.graph, cfg, ds.features, ds.labels,
                     train_idx=ds.train_idx)
    s.start_epoch(0, np.random.default_rng(0))
    return s


def _targets(ds, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.choice(ds.train_idx, size=n, replace=False).astype(np.int64)


@pytest.mark.parametrize("name", ["ns", "gns", "ladies", "lazygcn"])
def test_block_invariants(ds, name):
    s = _mk(ds, name)
    rng = np.random.default_rng(1)
    shapes0 = None
    for trial in range(3):
        mb = s.sample(_targets(ds, 32, seed=trial), rng)
        blocks = mb.device.blocks
        assert len(blocks) == 3
        shapes = [(b.nbr_idx.shape, b.num_src, b.num_dst) for b in blocks]
        if shapes0 is None:
            shapes0 = shapes
        assert shapes == shapes0, "static shapes must not vary across batches"
        # chain: src of block i+1 == dst count of block i
        for i, b in enumerate(blocks):
            assert b.nbr_idx.shape[0] == b.num_dst
            assert b.nbr_idx.max() < b.num_src
            assert b.nbr_idx.min() >= 0
            # masked lanes => zero weight; real rows flagged by dst_mask
            assert np.all(b.nbr_w[b.dst_mask == 0] == 0)
            if i + 1 < len(blocks):
                assert blocks[i + 1].num_src == b.num_dst * 0 + blocks[i + 1].num_src
        # input feature arrays sized to block[0].num_src
        assert mb.device.input_streamed.shape[0] == blocks[0].num_src
        assert mb.device.input_cache_slots.shape[0] == blocks[0].num_src
        assert mb.num_input <= blocks[0].num_src


def test_ns_weights_are_means(ds):
    s = _mk(ds, "ns")
    mb = s.sample(_targets(ds, 32), np.random.default_rng(2))
    for b in mb.device.blocks:
        rows = b.dst_mask > 0
        sums = b.nbr_w[rows].sum(axis=1)
        valid = (b.nbr_w[rows] > 0).any(axis=1)
        np.testing.assert_allclose(sums[valid], 1.0, rtol=1e-5)


def test_gns_input_layer_cache_only(ds):
    s = _mk(ds, "gns")
    mb = s.sample(_targets(ds, 32), np.random.default_rng(3))
    in_blk = mb.device.blocks[0]
    # every input-layer sampled neighbor (excluding dst self rows) is cached
    d = in_blk.num_dst
    lanes = in_blk.nbr_w > 0
    src_rows = np.unique(in_blk.nbr_idx[lanes])
    ids = mb.input_node_ids[src_rows]
    cached = s.cache.in_cache[ids]
    # non-dst sources must all be cached (dst nodes can appear as their own
    # neighbors' sources when they are in each other's neighbor lists)
    non_dst = src_rows >= d
    assert cached[non_dst].all()


def test_gns_fewer_input_nodes_than_ns(ds):
    """Paper Table 4: GNS minibatches touch far fewer distinct input nodes."""
    ns = _mk(ds, "ns", fanouts=(5, 10, 15))
    gns = _mk(ds, "gns", fanouts=(5, 10, 15))
    rng = np.random.default_rng(4)
    t = _targets(ds, 32)
    n_ns = np.mean([ns.sample(t, rng).num_input for _ in range(5)])
    n_gns = np.mean([gns.sample(t, rng).num_input for _ in range(5)])
    assert n_gns < 0.7 * n_ns, (n_ns, n_gns)


def test_gns_cached_fraction_counted(ds):
    s = _mk(ds, "gns")
    mb = s.sample(_targets(ds, 32), np.random.default_rng(5))
    assert 0 < mb.num_cached <= mb.num_input
    assert mb.bytes_streamed == (mb.num_input - mb.num_cached) * ds.feat_dim * 4


def test_ladies_isolated_counted(ds):
    s = _mk(ds, "ladies", layer_size=8)   # tiny layer -> isolated rows appear
    mb = s.sample(_targets(ds, 32), np.random.default_rng(6))
    assert mb.num_isolated >= 0
    in_blk = mb.device.blocks[0]
    rows = in_blk.dst_mask > 0
    isolated = (np.abs(in_blk.nbr_w[rows]).sum(axis=1) == 0).sum()
    assert mb.num_isolated == isolated


def test_ladies_layer_size_bounds_new_nodes(ds):
    s = _mk(ds, "ladies", layer_size=16)
    mb = s.sample(_targets(ds, 32), np.random.default_rng(7))
    # each layer adds at most layer_size new nodes over the previous
    # (src = dst ++ sampled), so input node count <= batch + L*layer_size
    assert mb.num_input <= 32 + 3 * 16


def test_lazygcn_recycles(ds):
    s = _mk(ds, "lazygcn", recycle_period=3, recycle_growth=1.0)
    rng = np.random.default_rng(8)
    t = _targets(ds, 32)
    mbs = [s.sample(t, rng) for _ in range(3)]
    # identical recycled structure within a period
    b0 = mbs[0].device.blocks[0].nbr_idx
    assert np.array_equal(b0, mbs[1].device.blocks[0].nbr_idx)
    assert np.array_equal(b0, mbs[2].device.blocks[0].nbr_idx)
    # recycled steps stream zero fresh bytes
    assert mbs[1].bytes_streamed == 0 and mbs[2].bytes_streamed == 0
    # fresh sample next period
    mb3 = s.sample(t, rng)
    assert not np.array_equal(b0, mb3.device.blocks[0].nbr_idx)


def test_pad_sizes_chain():
    sizes = block_pad_sizes(10, (3, 4, 5))
    # output layer k=5: dst=10, src=60; middle k=4: dst=60, src=300;
    # input k=3: dst=300, src=1200.  List is input-first.
    assert sizes == [(300, 1200), (60, 300), (10, 60)]

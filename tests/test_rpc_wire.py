"""Property battery for the RPC wire framing (``repro.rpc.wire``).

The transport's correctness floor: every frame kind round-trips bitwise
(dtype + shape + bytes preserved through the zero-copy path), and every
class of malformed input — truncated header, truncated body, garbage magic,
oversize announcements, descriptor lies — is REJECTED with
:class:`FrameError` before any payload-sized allocation, never decoded into
something plausible.  Runs property-style under hypothesis when installed,
via the seeded fallback shim otherwise (tier-1 bare-container rule).
"""
from __future__ import annotations

import socket
import threading

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                          # pragma: no cover
    from _hypothesis_fallback import given, settings, st

from repro.featurestore.placement import RoutingTable
from repro.rpc import wire
from repro.rpc.wire import (ChannelClosed, FrameError, decode_frame,
                            encode_frame, pack_table, recv_frame, send_frame,
                            unpack_table)

ALL_KINDS = sorted(wire.KINDS)
DTYPES = [np.int64, np.int32, np.int16, np.int8, np.float32, np.float64,
          np.uint8, np.bool_]


def _bytes_of(frame_bufs) -> bytes:
    return b"".join(bytes(b) for b in frame_bufs)


def _roundtrip(kind, meta, arrays):
    bufs, total = encode_frame(kind, meta, arrays)
    raw = _bytes_of(bufs)
    assert len(raw) == total
    k, m, a = decode_frame(raw)
    assert k == kind
    assert m == dict(meta or {})
    assert set(a) == set(arrays or {})
    for name, arr in (arrays or {}).items():
        got = a[name]
        assert got.dtype == np.asarray(arr).dtype, name
        assert got.shape == np.ascontiguousarray(arr).shape, name
        np.testing.assert_array_equal(got, np.asarray(arr))
    return raw


# ---------------------------------------------------------------------------
# round-trip properties
# ---------------------------------------------------------------------------

def test_all_kinds_roundtrip_empty():
    for kind in ALL_KINDS:
        _roundtrip(kind, {}, {})
        _roundtrip(kind, {"x": 1, "s": "τ", "none": None, "f": 0.5,
                          "nested": {"a": [1, 2]}}, {})


@settings(max_examples=25)
@given(st.integers(0, len(ALL_KINDS) - 1),
       st.integers(0, len(DTYPES) - 1),
       st.integers(0, 3),                    # ndim
       st.integers(0, 9),                    # dim size
       st.integers(1, 4))                    # number of arrays
def test_roundtrip_dtype_shape_preserved(ki, di, ndim, dim, n_arrays):
    rng = np.random.default_rng(ki * 1000 + di * 100 + ndim * 10 + dim)
    arrays = {}
    for j in range(n_arrays):
        dt = DTYPES[(di + j) % len(DTYPES)]
        shape = tuple(int(rng.integers(0, dim + 1)) for _ in range(ndim))
        arrays[f"a{j}"] = (rng.integers(0, 2, size=shape).astype(dt)
                           if dt is np.bool_ else
                           (rng.random(size=shape) * 100).astype(dt))
    _roundtrip(ALL_KINDS[ki], {"req": ki}, arrays)


def test_roundtrip_empty_and_scalar_shapes():
    # 0-d, 0-length, and F-ordered inputs all survive (encode makes them
    # C-contiguous; shape/dtype are authoritative from the descriptor)
    _roundtrip(wire.RESULT, {}, {"s": np.float32(3.5) * np.ones(())})
    _roundtrip(wire.RESULT, {}, {"e": np.zeros((0, 4), np.int64)})
    f_ordered = np.asfortranarray(np.arange(12, np.float32(12) + 12)
                                  .reshape(3, 4))
    bufs, _ = encode_frame(wire.RESULT, {}, {"f": f_ordered})
    _, _, a = decode_frame(_bytes_of(bufs))
    np.testing.assert_array_equal(a["f"], f_ordered)


def test_zero_copy_views_on_receive():
    arr = np.arange(64, dtype=np.int64)
    raw = _bytes_of(encode_frame(wire.REQUEST, {"req": 1}, {"ids": arr})[0])
    _, _, a = decode_frame(raw)
    # the decoded array is a VIEW over the frame buffer, not a copy
    assert a["ids"].base is not None


# ---------------------------------------------------------------------------
# rejection properties
# ---------------------------------------------------------------------------

def test_unknown_kind_and_reserved_key_rejected_on_encode():
    with pytest.raises(FrameError):
        encode_frame(200, {}, {})
    with pytest.raises(FrameError):
        encode_frame(wire.HELLO, {wire._ARRAYS_KEY: []}, {})


def test_oversize_payload_rejected_on_encode(monkeypatch):
    monkeypatch.setattr(wire, "MAX_FRAME_BYTES", 1 << 10)
    with pytest.raises(FrameError):
        encode_frame(wire.REQUEST, {}, {"x": np.zeros(1 << 12, np.int8)})


@settings(max_examples=25)
@given(st.integers(0, 200))
def test_truncated_frame_rejected(cut):
    raw = _roundtrip(wire.REQUEST, {"req": 7},
                     {"ids": np.arange(17, dtype=np.int64)})
    cut = min(cut, len(raw) - 1)
    with pytest.raises(FrameError):
        decode_frame(raw[:cut])


@settings(max_examples=25)
@given(st.integers(0, 19), st.integers(0, 255))
def test_garbage_prefix_rejected(pos, val):
    raw = bytearray(_roundtrip(wire.HEARTBEAT, {"beat_age_s": 0.0}, {}))
    orig = raw[pos]
    raw[pos] = (orig + 1 + val) % 256
    if raw[pos] == orig:
        raw[pos] = (orig + 1) % 256
    with pytest.raises(FrameError):
        decode_frame(bytes(raw))


def test_admission_bounds_checked_before_allocation():
    # a header announcing a 2^60-byte payload must be refused from the
    # 20-byte prefix alone (no payload-sized allocation attempt)
    hdr = wire.HEADER.pack(wire.MAGIC, wire.REQUEST, 0, 0, 0, 1 << 60)
    with pytest.raises(FrameError, match="admission"):
        decode_frame(hdr)
    hdr = wire.HEADER.pack(wire.MAGIC, wire.REQUEST, 0, 0,
                           wire.MAX_META_BYTES + 1, 0)
    with pytest.raises(FrameError, match="admission"):
        decode_frame(hdr)


def test_descriptor_lies_rejected():
    # descriptor claims more bytes than the payload carries
    bufs, _ = encode_frame(wire.RESULT, {}, {"x": np.zeros(4, np.int64)})
    raw = bytearray(_bytes_of(bufs))
    raw2 = raw.replace(b'"<i8",[4]', b'"<i8",[9]')
    assert raw2 != raw
    with pytest.raises(FrameError):
        decode_frame(bytes(raw2))
    # trailing junk after a complete frame
    with pytest.raises(FrameError, match="trailing"):
        decode_frame(bytes(raw) + b"\x00")
    # meta that is valid JSON but not an object
    mb = b"[1,2]"
    hdr = wire.HEADER.pack(wire.MAGIC, wire.HELLO, 0, 0, len(mb), 0)
    with pytest.raises(FrameError, match="not a JSON object"):
        decode_frame(hdr + mb)


# ---------------------------------------------------------------------------
# socket IO: framing survives a real stream, EOF classes are distinct
# ---------------------------------------------------------------------------

def test_send_recv_over_socketpair():
    a, b = socket.socketpair()
    try:
        frames = [
            (wire.HELLO, {"index": 0}, {}),
            (wire.REQUEST, {"req": 1, "tenant": "t0"},
             {"ids": np.arange(33, dtype=np.int64)}),
            (wire.RESULT, {"req": 1, "status": "ok"},
             {"logits": np.random.default_rng(0)
              .normal(size=(8, 5)).astype(np.float32)}),
        ]
        sent = []

        def pump():
            for kind, meta, arrays in frames:
                sent.append(send_frame(a, kind, meta, arrays))
            a.close()                        # clean EOF at a boundary

        t = threading.Thread(target=pump)
        t.start()
        for i, (kind, meta, arrays) in enumerate(frames):
            k, m, arr, n = recv_frame(b)
            assert (k, m) == (kind, meta)
            for name in arrays:
                np.testing.assert_array_equal(arr[name], arrays[name])
            assert n == sent[i]
        with pytest.raises(ChannelClosed):   # boundary EOF: clean close
            recv_frame(b)
        t.join()
    finally:
        a.close()
        b.close()


def test_mid_frame_eof_is_frame_error():
    a, b = socket.socketpair()
    try:
        bufs, _ = encode_frame(wire.REQUEST, {"req": 1},
                               {"ids": np.arange(100, dtype=np.int64)})
        raw = _bytes_of(bufs)
        a.sendall(raw[:len(raw) // 2])
        a.close()
        with pytest.raises(FrameError, match="mid-frame"):
            recv_frame(b)
    finally:
        a.close()
        b.close()


# ---------------------------------------------------------------------------
# routing-table transport
# ---------------------------------------------------------------------------

def test_pack_unpack_table_roundtrip():
    t = RoutingTable(
        shard_of_node=np.array([0, 1, -1, 1, 0], dtype=np.int16),
        n_shards=2, version=7)
    meta, arrays = pack_table(t)
    raw = _bytes_of(encode_frame(wire.SWAPPED, meta, arrays)[0])
    _, m, a = decode_frame(raw)
    t2 = unpack_table(m, a)
    assert (t2.n_shards, t2.version) == (2, 7)
    np.testing.assert_array_equal(t2.shard_of_node, t.shard_of_node)
    assert t2.shard_of_node.dtype == np.int16

    meta, arrays = pack_table(None)
    assert unpack_table(meta, arrays) is None

"""Checkpoint/restore of the UN-MERGED streaming delta log.

The fault-tolerance gap this closes: a crash between an ``ingest()`` and the
next generation merge used to lose the staged ops — params/opt state were
checkpointed, the op log was not.  Now ``engine.save`` ships the seq-stamped
log through the checkpoint's ``aux`` side-payload (variable shapes between
saves, so it cannot ride the fixed-shape pytree path) and ``engine.restore``
re-stages it with the ORIGINAL seqs:

* buffer-level ``state()``/``restore()`` round-trips bitwise;
* engine-level save → fresh-process restore → merge produces the identical
  post-merge structure the uncrashed engine would have built;
* replay is idempotent under last-op-wins: restoring a checkpoint whose ops
  were already merged and merging again changes nothing bitwise.
"""
from __future__ import annotations

import jax
import numpy as np

from repro import checkpoint as ckpt
from repro.core.sampler import SamplerConfig
from repro.featurestore import CacheConfig
from repro.gns import (EngineConfig, GNSEngine, ServeConfig, StreamConfig)
from repro.graph.datasets import get_dataset
from repro.stream import DeltaBuffer


def _engine(seed=0):
    # fresh dataset per engine: merges mutate the engine's dataset view
    ds = get_dataset("tiny", seed=0)
    scfg = SamplerConfig(fanouts=(3, 4), batch_size=32,
                         cache=CacheConfig(fraction=0.1, strategy="adaptive"))
    cfg = EngineConfig(sampler="gns", sampling=scfg, cache=scfg.cache,
                       serve=ServeConfig(buckets=(8, 32), max_wait_ms=2.0),
                       stream=StreamConfig(merge_min_pending=1),
                       seed=seed)
    return GNSEngine(cfg, dataset=ds)


def _stage(buf: DeltaBuffer, rng: np.ndarray = None):
    """A representative mixed log: inserts, a delete, new nodes with edges
    referencing them — including an insert/delete conflict on one edge
    (last-op-wins fodder)."""
    buf.add_edges([1, 2, 3], [4, 5, 6])
    buf.delete_edges([1], [4])              # conflicts with the insert above
    ids = buf.add_nodes(np.arange(2 * buf.feat_dim, dtype=np.float32)
                        .reshape(2, buf.feat_dim),
                        labels=np.array([3, 1]))
    buf.add_edges(ids, [0, 7])
    return ids


def _drain_tuple(buf: DeltaBuffer):
    b = buf.drain()
    assert b is not None
    return (b.edge_src, b.edge_dst, b.edge_op, b.edge_seq,
            b.node_feats, b.node_labels, b.node_base, b.first_seq, b.last_seq)


# ---------------------------------------------------------------------------
# buffer level
# ---------------------------------------------------------------------------

def test_buffer_state_roundtrip_bitwise():
    a = DeltaBuffer(100, 4)
    _stage(a)
    st = a.state()

    b = DeltaBuffer(100, 4)
    b.restore(st)
    assert b.pending() == a.pending()
    assert b.next_node == a.next_node

    ta, tb = _drain_tuple(a), _drain_tuple(b)
    for xa, xb in zip(ta, tb):
        if isinstance(xa, np.ndarray):
            np.testing.assert_array_equal(xa, xb)
        else:
            assert xa == xb
    # post-drain: both allocate the next seq/id identically
    assert a.add_edges([0], [1]) == b.add_edges([0], [1])


def test_restore_replaces_and_is_idempotent():
    a = DeltaBuffer(50, 4)
    _stage(a)
    st = a.state()

    b = DeltaBuffer(50, 4)
    b.add_edges([9], [8])                   # pre-existing staged junk
    b.restore(st)
    b.restore(st)                           # restore∘restore == restore
    assert b.pending() == a.pending()
    np.testing.assert_array_equal(b.state()["edge_seq"], st["edge_seq"])

    # the seq/id clocks never rewind below what this buffer handed out
    c = DeltaBuffer(50, 4)
    c.add_edges(np.arange(30), np.arange(1, 31))    # 30 seqs consumed
    c.restore(st)
    assert c.add_edges([0], [1]) >= 30


def test_empty_buffer_state_roundtrip():
    a = DeltaBuffer(10, 3)
    st = a.state()
    assert len(st["edge_src"]) == 0 and len(st["node_feats"]) == 0
    b = DeltaBuffer(10, 3)
    b.restore(st)
    assert b.pending() == 0 and b.drain() is None


# ---------------------------------------------------------------------------
# engine level: save → restore in a "new process" → merge ≡ uncrashed merge
# ---------------------------------------------------------------------------

def test_engine_save_restore_merge_equivalence(tmp_path):
    a = _engine(seed=3)
    a.ensure_cache()
    new = a.ingest_nodes(
        np.random.default_rng(0).normal(
            size=(2, a.ds.feat_dim)).astype(np.float32),
        labels=np.zeros(2, np.int64))
    a.ingest(new, a.ds.val_idx[:2])
    a.ingest(a.ds.val_idx[:1], a.ds.val_idx[3:4])
    staged = a.pending_deltas
    assert staged > 0

    path = a.save(tmp_path / "ckpt", step=7)
    assert (path / "aux.npz").exists()
    # the manifest self-describes the side-payload
    assert ckpt.latest_step(tmp_path / "ckpt") == 7
    aux = ckpt.load_aux(tmp_path / "ckpt")
    assert len(aux["stream/edge_src"]) == 3     # 2 new->val ops + 1 val->val
    assert len(aux["stream/node_feats"]) == 2

    # "crash": a fresh engine (same config/seed, pre-ingest dataset) restores
    b = _engine(seed=3)
    b.ensure_cache()
    step = b.restore(tmp_path / "ckpt")
    assert step == 7
    assert b.pending_deltas == staged

    # params/opt state round-tripped bitwise
    for xa, xb in zip(jax.tree_util.tree_leaves(a.params),
                      jax.tree_util.tree_leaves(b.params)):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))

    # merging the restored log rebuilds the exact structure the uncrashed
    # engine builds
    a.merge_deltas()
    b.merge_deltas()
    np.testing.assert_array_equal(a.ds.graph.indptr, b.ds.graph.indptr)
    np.testing.assert_array_equal(a.ds.graph.indices, b.ds.graph.indices)
    np.testing.assert_array_equal(a.ds.features, b.ds.features)
    np.testing.assert_array_equal(a.ds.labels, b.ds.labels)


def test_replay_after_merge_is_noop(tmp_path):
    """Restoring a checkpoint whose EDGE ops already merged and merging
    again is bitwise a no-op — the last-op-wins contract that makes replay
    safe when the crash happened after the merge but before the checkpoint
    was garbage-collected."""
    eng = _engine(seed=5)
    eng.ensure_cache()
    eng.ingest(eng.ds.val_idx[:2], eng.ds.val_idx[5:7])
    eng.ingest(eng.ds.val_idx[:1], eng.ds.val_idx[5:6], op="delete")
    eng.save(tmp_path / "ckpt", step=1)

    eng.merge_deltas()
    indptr0 = eng.ds.graph.indptr.copy()
    indices0 = eng.ds.graph.indices.copy()

    eng.restore(tmp_path / "ckpt")          # re-stage the already-merged ops
    assert eng.pending_deltas > 0
    eng.merge_deltas()
    np.testing.assert_array_equal(eng.ds.graph.indptr, indptr0)
    np.testing.assert_array_equal(eng.ds.graph.indices, indices0)


def test_save_without_stream_has_no_aux(tmp_path):
    ds = get_dataset("tiny", seed=0)
    scfg = SamplerConfig(fanouts=(3, 4), batch_size=32,
                         cache=CacheConfig(fraction=0.1))
    eng = GNSEngine(EngineConfig(sampler="gns", sampling=scfg,
                                 cache=scfg.cache, seed=1), dataset=ds)
    eng.save(tmp_path / "ckpt", step=0)
    assert ckpt.load_aux(tmp_path / "ckpt") == {}
    step = eng.restore(tmp_path / "ckpt")
    assert step == 0

"""Multi-tier feature store: policies, tier accounting, async double-buffer."""
import threading
import time

import numpy as np
import pytest

from repro.featurestore import CacheConfig
from repro.core.pipeline import EpochLoader
from repro.core.sampler import GNSSampler, SamplerConfig
from repro.featurestore import (FeatureStore, POLICIES, make_policy,
                                register_policy, CachePolicy)
from repro.featurestore.policies import (degree_cache_probs,
                                         reverse_pagerank_cache_probs)
from repro.graph.generate import powerlaw_graph


@pytest.fixture(scope="module")
def g():
    return powerlaw_graph(3000, avg_degree=8, seed=0)


@pytest.fixture(scope="module")
def feats(g):
    rng = np.random.default_rng(0)
    return rng.standard_normal((g.num_nodes, 16)).astype(np.float32)


def _store(g, feats, strategy="degree", fraction=0.05, train_idx=None, **kw):
    cfg = CacheConfig(fraction=fraction, strategy=strategy, **kw)
    return FeatureStore(feats, g, cfg, train_idx=train_idx)


# ---------------------------------------------------------------------------
# policy registry
# ---------------------------------------------------------------------------

def test_registry_has_required_policies():
    for name in ("degree", "random_walk", "uniform", "reverse_pagerank",
                 "adaptive"):
        assert name in POLICIES
    assert len(POLICIES) >= 4


def test_make_policy_unknown_raises():
    with pytest.raises(ValueError, match="unknown cache policy"):
        make_policy("nope")


def test_register_custom_policy(g):
    @register_policy
    class _Fixed(CachePolicy):
        name = "_test_fixed"

        def scores(self, graph, train_idx=None):
            s = np.zeros(graph.num_nodes)
            s[:10] = 1.0
            return s

    try:
        p = make_policy("_test_fixed")
        probs = p.probs(g)
        assert probs[:10].sum() == pytest.approx(1.0)
        assert (probs[10:] == 0).all()
    finally:
        del POLICIES["_test_fixed"]


def test_reverse_pagerank_concentrates_near_train(g):
    rng = np.random.default_rng(1)
    train = rng.choice(g.num_nodes, size=40, replace=False)
    p = reverse_pagerank_cache_probs(g, train, iters=10)
    assert p.sum() == pytest.approx(1.0)
    hood = np.array(sorted({v for t in train for v in [t, *g.neighbors(t)]}))
    assert p[hood].sum() > 3 * len(hood) / g.num_nodes


def test_adaptive_observe_sees_hits_working_set_stable(g, feats):
    """Churn regression (ROADMAP follow-up): nodes that become cache hits
    must keep feeding the adaptive EMA.  With miss-only feedback a stable
    working set stops being observed once cached, its EMA decays below the
    degree prior, it is evicted, misses again — oscillating churn.  With
    full-traffic feedback the hot set stays cached across refreshes.
    """
    from repro.core.minibatch import pad_to

    # a hot working set of LOW-degree nodes: the degree prior alone would
    # never keep them cached, so retention isolates the EMA feedback path
    hot = np.argsort(g.degrees)[:60].astype(np.int64)
    # fast decay: miss-only feedback would churn within a few refreshes
    policy = make_policy("adaptive", decay=0.3)
    cfg = CacheConfig(fraction=0.05, strategy="adaptive")
    store = FeatureStore(feats, g, cfg, policy=policy)
    rng = np.random.default_rng(0)
    store.refresh(rng, version=0)
    ids_p = pad_to(hot, 64)
    retention = []
    for v in range(1, 9):
        for _ in range(3):          # the epoch's traffic: all requests hot
            store.assemble_input(store.generation, ids_p, len(hot))
        store.refresh(rng, version=v)
        retention.append(store.state.in_cache[hot].mean())
    # after the first feedback-informed refresh the hot set must be cached
    # and STAY cached (no oscillation), refresh after refresh
    assert all(r >= 0.9 for r in retention[1:]), retention
    assert retention[-1] >= 0.95, retention


def test_adaptive_policy_tracks_misses(g):
    p = make_policy("adaptive")
    p.bind(g)
    hot = np.arange(50, 80)
    for _ in range(5):
        p.observe(hot)
    probs = p.probs(g)
    # observed nodes hold most of the mass once feedback accumulates
    assert probs[hot].sum() > 0.5
    # cold start equals the degree prior
    p2 = make_policy("adaptive")
    p2.bind(g)
    np.testing.assert_allclose(p2.probs(g), degree_cache_probs(g))


# ---------------------------------------------------------------------------
# tiers
# ---------------------------------------------------------------------------

def test_generation_pairs_state_and_table(g, feats):
    store = _store(g, feats)
    gen = store.refresh(np.random.default_rng(0), version=3)
    assert gen.version == 3 and store.version == 3
    n = gen.state.size
    np.testing.assert_array_equal(np.asarray(gen.table)[:n],
                                  feats[gen.state.node_ids])
    np.testing.assert_array_equal(gen.staged[:n], feats[gen.state.node_ids])


def test_assemble_input_tier_accounting(g, feats):
    store = _store(g, feats)
    gen = store.refresh(np.random.default_rng(0))
    ids = np.arange(200, dtype=np.int64)
    ids_p = np.concatenate([ids, np.zeros(56, np.int64)])
    slots, streamed, hits, bts, _ = store.assemble_input(gen, ids_p, len(ids))
    misses = (slots[:200] < 0).sum()
    assert hits + misses == 200
    assert bts == misses * feats.shape[1] * 4
    assert store.meter.tier("device").hits == hits
    assert store.meter.tier("device").misses == misses
    assert store.meter.tier("host").bytes_read == bts
    # streamed rows hold exactly the missed features, hits stay zero
    miss_mask = (slots < 0) & (np.arange(256) < 200)
    np.testing.assert_array_equal(streamed[miss_mask], feats[ids_p[miss_mask]])
    hit_mask = slots >= 0
    assert (streamed[hit_mask] == 0).all()
    # padded tail is never resolved against the cache
    assert (slots[200:] == -1).all()


def test_stale_generation_staging_retired(g, feats):
    """A generation handle held across two refreshes must never serve
    another generation's rows from the recycled staging buffer — the store
    retires the half and falls back to the host tier."""
    store = _store(g, feats, fraction=0.03)
    old = store.refresh(np.random.default_rng(0), version=0)
    store.refresh(np.random.default_rng(1), version=1)    # uses other half
    assert not old.retired
    store.refresh(np.random.default_rng(2), version=2)    # recycles old's half
    assert old.retired
    ids = old.state.node_ids[:8]
    rows = store.gather_rows(ids, gen=old)
    np.testing.assert_array_equal(rows, feats[ids])       # host tier, correct
    # the retired gen's device table is untouched (fresh array per build)
    np.testing.assert_array_equal(np.asarray(old.table)[:4],
                                  feats[old.state.node_ids[:4]])


def test_gather_rows_staging_tier(g, feats):
    store = _store(g, feats)
    gen = store.refresh(np.random.default_rng(0))
    cached_ids = gen.state.node_ids[:10]
    other_ids = np.where(~gen.state.in_cache)[0][:10]
    rows = store.gather_rows(np.concatenate([cached_ids, other_ids]), gen)
    np.testing.assert_array_equal(rows[:10], feats[cached_ids])
    np.testing.assert_array_equal(rows[10:], feats[other_ids])
    assert store.meter.tier("staging").hits == 10
    assert store.meter.tier("staging").misses == 10
    assert store.meter.tier("host").hits == 10


# ---------------------------------------------------------------------------
# async double-buffered refresh
# ---------------------------------------------------------------------------

def test_async_refresh_steps_proceed_and_no_torn_reads(g, feats):
    """Training-analog steps keep running against the live generation while a
    slow refresh builds the shadow; a snapshot is never torn (its table always
    matches its own state), and the swap lands only at the swap point."""
    store = _store(g, feats, fraction=0.03)
    store.refresh(np.random.default_rng(0), version=0)
    store.refresh_delay = 0.3                 # slow background build
    assert store.begin_refresh(np.random.default_rng(1), version=1)
    assert not store.begin_refresh(np.random.default_rng(2), version=9)  # busy

    steps = 0
    t0 = time.perf_counter()
    while store.refreshing and time.perf_counter() - t0 < 5.0:
        gen = store.generation          # the one atomic read a step performs
        assert gen.version == 0         # shadow never leaks before the swap
        n = gen.state.size
        np.testing.assert_array_equal(np.asarray(gen.table)[:4],
                                      feats[gen.state.node_ids[:4]])
        assert n <= store.size
        steps += 1
    assert steps >= 3                   # steps ran *during* the refresh
    assert store.wait_refresh(timeout=5.0)
    assert store.version == 1
    gen = store.generation
    np.testing.assert_array_equal(np.asarray(gen.table)[:4],
                                  feats[gen.state.node_ids[:4]])
    assert store.swaps == 2 and store.refreshes == 2


def test_async_refresh_hammered_snapshots_consistent(g, feats):
    """A reader thread hammering snapshots across many swap cycles never sees
    a (state, table) pair from two different generations."""
    store = _store(g, feats, fraction=0.02)
    store.refresh(np.random.default_rng(0), version=0)
    stop = threading.Event()
    torn = []

    def reader():
        while not stop.is_set():
            gen = store.generation
            tbl = np.asarray(gen.table)[:2]
            if not (tbl == store.features[gen.state.node_ids[:2]]).all():
                torn.append(gen.version)

    t = threading.Thread(target=reader)
    t.start()
    try:
        for v in range(1, 6):
            store.begin_refresh(np.random.default_rng(v), version=v)
            store.wait_refresh(timeout=5.0)
    finally:
        stop.set()
        t.join(5.0)
    assert store.version == 5
    assert not torn


def test_async_refresh_error_surfaces_at_swap(g, feats):
    store = _store(g, feats)
    store.refresh(np.random.default_rng(0))

    def boom(*a, **kw):
        raise RuntimeError("policy exploded")

    store.policy.probs = boom
    store._static_probs = None
    store.begin_refresh(np.random.default_rng(1), version=1)
    store._thread.join(5.0)
    with pytest.raises(RuntimeError, match="policy exploded"):
        store.swap_if_ready()


def test_gns_sampler_async_epoch_loop(g, feats):
    """End-to-end: async-refresh GNS sampler adopts the new generation at a
    batch boundary, and every minibatch carries the generation its slots
    index into."""
    labels = np.zeros(g.num_nodes, np.int32)
    train = np.arange(0, 1500, dtype=np.int64)
    cfg = SamplerConfig(fanouts=(3, 4), batch_size=64,
                        cache=CacheConfig(fraction=0.05, period=1,
                                          async_refresh=True))
    s = GNSSampler(g, cfg, feats, labels, train_idx=train)
    loader = EpochLoader(s, train, seed=0, max_batches=4)
    seen_versions = set()
    for ep in range(3):
        for mb in loader.epoch(ep):
            gen = mb.cache_gen
            assert gen is not None
            seen_versions.add(gen.version)
            # slots resolve against THIS generation's slot map
            real = mb.input_node_ids[:mb.num_input]
            np.testing.assert_array_equal(
                mb.device.input_cache_slots[:mb.num_input],
                gen.state.slot_of[real])
        # drain any in-flight refresh so the test is deterministic
        s.store.wait_refresh(timeout=5.0)
        s.adopt_generation()
    assert len(seen_versions) >= 2          # refreshes actually happened
    assert s.store.refreshes >= 2


def test_sync_refresh_absorbs_inflight_async_build(g, feats):
    """refresh() during an async build must not race it into the same
    staging half — it waits, swaps, then builds on the freed half."""
    store = _store(g, feats, fraction=0.03)
    store.refresh(np.random.default_rng(0), version=0)
    store.refresh_delay = 0.2
    assert store.begin_refresh(np.random.default_rng(1), version=1)
    store.refresh_delay = 0.0
    gen = store.refresh(np.random.default_rng(2), version=2)   # absorbs v1
    assert gen.version == 2 and store.version == 2
    assert store.refreshes == 3                 # v1 completed, not clobbered
    n = gen.state.size
    np.testing.assert_array_equal(np.asarray(gen.table)[:n],
                                  feats[gen.state.node_ids])


def test_record_flag_suspends_metering_and_feedback(g, feats):
    """Eval-path lookups (store.record=False) touch neither the meter nor
    the adaptive policy's miss EMA."""
    store = _store(g, feats, strategy="adaptive")
    gen = store.refresh(np.random.default_rng(0))
    ids_p = np.arange(100, dtype=np.int64)
    store.record = False
    slots, streamed, hits, bts, _ = store.assemble_input(gen, ids_p, 100)
    assert bts > 0                              # batch-level bytes still reported
    assert not store.meter.tiers                # no tier counters created
    assert store.policy._ema.sum() == 0         # no miss feedback
    store.record = True
    store.assemble_input(gen, ids_p, 100)
    assert store.meter.tier("device").hits + store.meter.tier("device").misses == 100
    assert store.policy._ema.sum() > 0


# ---------------------------------------------------------------------------
# policy quality: smarter admission >= degree on a power-law graph
# ---------------------------------------------------------------------------

def _hit_rate(g, feats, strategy, epochs=3, seed=0):
    labels = np.zeros(g.num_nodes, np.int32)
    train = np.random.default_rng(7).choice(
        g.num_nodes, size=600, replace=False).astype(np.int64)
    cfg = SamplerConfig(fanouts=(3, 5), batch_size=100,
                        cache=CacheConfig(fraction=0.05, period=1,
                                          strategy=strategy))
    s = GNSSampler(g, cfg, feats, labels, train_idx=np.sort(train))
    loader = EpochLoader(s, np.sort(train), seed=seed, max_batches=6)
    cached = inputs = 0
    for ep in range(epochs):
        for mb in loader.epoch(ep):
            cached += mb.num_cached
            inputs += mb.num_input
    return cached / max(inputs, 1)


def test_adaptive_policy_beats_degree_hit_rate(g, feats):
    hr_deg = _hit_rate(g, feats, "degree")
    hr_ada = _hit_rate(g, feats, "adaptive")
    # cold-start epoch is degree-identical; feedback epochs only improve it
    assert hr_ada >= hr_deg * 0.95, (hr_ada, hr_deg)

"""Cross-host serving transport acceptance (``repro.rpc`` + ServeFabric).

In-process (meshless tiny dataset, endpoints served on threads inside this
process, runtime lock sanitizer armed by conftest):

* ``transport="tcp"`` serves the SAME request stream bitwise-identically to
  ``transport="inproc"`` — same seeds, same generation, same routing;
* killing one endpoint's connection mid-stream re-serves its shipped-but-
  unanswered requests on the survivor (the watchdog DEAD path over
  ``take_inflight``), losslessly; with every endpoint dead the futures fail
  fast with :class:`WorkerDown`;
* an endpoint survives its coordinator: a second fabric re-adopts the warm
  replica after the first disconnects;
* ``Router.adopt`` is safe against concurrent ``route`` readers (the
  snapshot-swap contract the remote SWAPPED path leans on);
* cross-host observability: wire bytes metered per direction, remote tenant
  ledgers aggregated into the coordinator meter, per-request rpc wait
  split out of queue wait.

Subprocess (``@pytest.mark.dryrun`` — the CI ``rpc-smoke`` acceptance):
two REAL endpoint processes on the forced-host 2x2 mesh + a coordinator
process over localhost TCP, sanitizer armed end to end — majority-local
routing, zero errors, and lossless recovery after a mid-stream SIGKILL of
one endpoint.
"""
from __future__ import annotations

import json
import os
import signal
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core.sampler import SamplerConfig
from repro.featurestore import CacheConfig
from repro.featurestore.placement import RoutingTable
from repro.gns import (EngineConfig, FabricConfig, GNSEngine, ServeConfig,
                       TenantConfig)
from repro.graph.datasets import get_dataset
from repro.rpc import RemoteWorkerProxy, WorkerEndpoint, parse_endpoint
from repro.serve import Router, ServeFabric, WorkerDown


def _mk_engine(seed=0):
    # fresh dataset per engine: each endpoint replica owns its own copy
    ds = get_dataset("tiny", seed=0)
    scfg = SamplerConfig(fanouts=(3, 4), batch_size=32,
                         cache=CacheConfig(fraction=0.1,
                                           placement="locality", shards=2))
    cfg = EngineConfig(sampler="gns", sampling=scfg, cache=scfg.cache,
                       serve=ServeConfig(buckets=(8, 32), max_wait_ms=2.0),
                       seed=seed)
    return GNSEngine(cfg, dataset=ds)


def _endpoints(n=2, seed=0, heartbeat_ms=25.0):
    eps = []
    for i in range(n):
        ep = WorkerEndpoint(_mk_engine(seed), index=i,
                            heartbeat_ms=heartbeat_ms)
        ep.serve_in_thread()                 # bind() runs synchronously
        eps.append(ep)
    return eps


def _tcp_fabric(eng, eps, **kw):
    kw.setdefault("stall_timeout_ms", 5000.0)
    kw.setdefault("watch_interval_ms", 20.0)
    cfg = FabricConfig(workers=len(eps), transport="tcp",
                       endpoints=tuple(f"127.0.0.1:{ep.port}" for ep in eps),
                       **kw)
    return ServeFabric(eng, cfg=cfg)


def _wait(pred, timeout=30.0, interval=0.01):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(interval)
    return False


def _request_stream(ds, n=14):
    """A deterministic mixed-tenant request sequence."""
    rng = np.random.default_rng(42)
    out = []
    for i in range(n):
        ids = rng.choice(ds.val_idx, size=int(rng.integers(2, 8)),
                         replace=False).astype(np.int64)
        out.append(("mobile" if i % 2 == 0 else "batch", ids))
    return out


# ---------------------------------------------------------------------------
# config surface
# ---------------------------------------------------------------------------

def test_fabric_config_tcp_json_roundtrip():
    cfg = EngineConfig(
        serve=ServeConfig(fabric=FabricConfig(
            workers=2, transport="tcp",
            endpoints=("127.0.0.1:7001", "hostb:7002"),
            heartbeat_ms=50.0, connect_retries=3)))
    d = json.loads(json.dumps(cfg.to_dict()))
    back = EngineConfig.from_dict(d).serve.fabric
    assert back.transport == "tcp"
    assert back.endpoints == ("127.0.0.1:7001", "hostb:7002")
    assert back.heartbeat_ms == 50.0 and back.connect_retries == 3

    assert parse_endpoint("hostb:7002") == ("hostb", 7002)
    assert parse_endpoint(":7002") == ("127.0.0.1", 7002)
    assert parse_endpoint("7002") == ("127.0.0.1", 7002)


# ---------------------------------------------------------------------------
# bitwise identity: tcp ≡ inproc
# ---------------------------------------------------------------------------

def test_tcp_results_bitwise_identical_to_inproc():
    reqs = _request_stream(get_dataset("tiny", seed=0))

    def run_inproc():
        eng = _mk_engine(seed=4)
        fab = ServeFabric(eng, cfg=FabricConfig(workers=2))
        out = []
        with fab:
            for tenant, ids in reqs:
                out.append(fab.submit(ids, tenant=tenant).result(timeout=600))
        return out

    def run_tcp():
        eps = _endpoints(2, seed=4)
        try:
            fab = _tcp_fabric(_mk_engine(seed=4), eps)
            out = []
            with fab:
                for tenant, ids in reqs:
                    out.append(fab.submit(ids, tenant=tenant)
                               .result(timeout=600))
            return out, fab
        finally:
            for ep in eps:
                ep.stop()

    inproc = run_inproc()
    tcp, fab = run_tcp()
    assert all(r.status == "ok" for r in inproc + tcp)
    for a, b in zip(inproc, tcp):
        np.testing.assert_array_equal(a.logits, b.logits)
        assert a.cache_version == b.cache_version
        assert a.bucket == b.bucket
    # the wire was actually used, both directions, and metered
    rpc = fab.rpc_traffic()
    assert rpc["bytes_rpc_tx"] > 0 and rpc["bytes_rpc_rx"] > 0
    assert fab.snapshot()["rpc"] == rpc


def test_endpoint_survives_coordinator_and_readopts():
    eps = _endpoints(1, seed=6)
    try:
        ids = get_dataset("tiny", seed=0).val_idx[:4].astype(np.int64)
        fab1 = _tcp_fabric(_mk_engine(seed=6), eps)
        with fab1:
            r1 = fab1.submit(ids).result(timeout=600)
        # fab1 disconnected cleanly; the endpoint keeps its warm replica
        # (same process, same generation, serving ledger accumulates)
        fab2 = _tcp_fabric(_mk_engine(seed=6), eps)
        with fab2:
            r2 = fab2.submit(ids).result(timeout=600)
            stats = fab2.pull_remote_stats(timeout=30.0)
        assert r1.status == "ok" and r2.status == "ok"
        assert r2.cache_version == r1.cache_version   # no rebuild between
        assert r2.logits.shape == r1.logits.shape
        # the replica's ledger spans BOTH coordinator sessions
        assert stats[0]["counters"]["served"] == 2
    finally:
        for ep in eps:
            ep.stop()


# ---------------------------------------------------------------------------
# chaos: mid-stream endpoint loss
# ---------------------------------------------------------------------------

def test_killed_endpoint_inflight_rerouted_to_survivor():
    eps = _endpoints(2, seed=7)
    try:
        ds = get_dataset("tiny", seed=0)
        fab = _tcp_fabric(_mk_engine(seed=7), eps)
        with fab:
            fab.submit(ds.val_idx[:4], worker=0).result(timeout=600)  # warm
            fab.submit(ds.val_idx[:4], worker=1).result(timeout=600)
            w1 = fab.workers[1]
            assert isinstance(w1, RemoteWorkerProxy)
            # hold results on endpoint 1 so requests sit shipped-but-
            # unanswered, then sever the connection mid-flight
            eps[1].stall_s = 0.5
            futs = [fab.submit(ds.val_idx[i * 4:(i + 1) * 4], worker=1)
                    for i in range(3)]
            assert _wait(lambda: w1.inflight_count() > 0
                         or w1.scheduler.qsize() > 0)
            w1.kill()                        # one-call network partition
            assert _wait(lambda: not w1.alive()), "sender thread stuck"
            # the watchdog reclaims + re-routes; the survivor serves all
            for f in futs:
                assert f.result(timeout=600).status == "ok"
            assert _wait(lambda: fab.healthy() == [0]), fab.healthy()
            # un-pinned traffic keeps flowing
            assert fab.infer(ds.val_idx[:4], timeout=600).shape[0] == 4
        m = fab.meter
        assert m.failovers >= 1 and m.retries_total >= 1
        assert m.errors == 0
        # endpoint 1 is still running (partition, not crash): it reconnects
        fab2 = _tcp_fabric(_mk_engine(seed=7), [eps[1]])
        with fab2:
            assert fab2.infer(ds.val_idx[:4], timeout=600).shape[0] == 4
    finally:
        for ep in eps:
            ep.stop()


def test_all_endpoints_dead_fails_fast():
    eps = _endpoints(1, seed=8)
    try:
        ds = get_dataset("tiny", seed=0)
        fab = _tcp_fabric(_mk_engine(seed=8), eps)
        with fab:
            fab.infer(ds.val_idx[:4], timeout=600)     # warm
            w0 = fab.workers[0]
            eps[0].stall_s = 0.5
            fut = fab.submit(ds.val_idx[:8], worker=0)
            _wait(lambda: w0.inflight_count() > 0)
            w0.kill()
            assert _wait(lambda: not w0.alive())
            with pytest.raises(WorkerDown):
                fut.result(timeout=600)
            _wait(lambda: fab.healthy() == [], timeout=5.0)
            with pytest.raises(WorkerDown):
                fab.submit(ds.val_idx[:4])
    finally:
        for ep in eps:
            ep.stop()


# ---------------------------------------------------------------------------
# satellite: Router.adopt vs concurrent route (snapshot-swap contract)
# ---------------------------------------------------------------------------

def test_router_adopt_concurrent_with_route():
    """The watchdog (inproc) and the channel receiver threads (tcp SWAPPED
    frames) adopt tables while submit threads route — the sanitizer-armed
    hammer for the ``_rtable`` snapshot-swap annotation."""
    router = Router(range(2), 2, mode="locality")
    rng = np.random.default_rng(0)
    tables = [RoutingTable(
        shard_of_node=rng.integers(-1, 2, size=500).astype(np.int16),
        n_shards=2, version=v) for v in range(8)]
    router.adopt(tables[0])
    stop = threading.Event()
    errs = []

    def route_loop():
        r = np.random.default_rng(1)
        try:
            while not stop.is_set():
                ids = r.integers(0, 500, size=6)
                d = router.route(ids, [0, 1])
                assert d.worker in (0, 1)
        except BaseException as e:          # pragma: no cover
            errs.append(e)

    def adopt_loop():
        try:
            for i in range(400):
                router.adopt(tables[i % len(tables)])
        except BaseException as e:          # pragma: no cover
            errs.append(e)

    readers = [threading.Thread(target=route_loop) for _ in range(4)]
    writer = threading.Thread(target=adopt_loop)
    for t in readers:
        t.start()
    writer.start()
    writer.join(60)
    stop.set()
    for t in readers:
        t.join(60)
    assert not errs, errs
    assert router.table_version == tables[399 % len(tables)].version


# ---------------------------------------------------------------------------
# cross-host observability
# ---------------------------------------------------------------------------

def test_remote_stats_aggregation_and_rpc_wait_split():
    eps = _endpoints(2, seed=9)
    try:
        ds = get_dataset("tiny", seed=0)
        fab = _tcp_fabric(_mk_engine(seed=9), eps)
        with fab:
            for tenant, ids in _request_stream(ds, n=8):
                fab.submit(ids, tenant=tenant).result(timeout=600)
            raw = fab.pull_remote_stats(timeout=30.0)
            snap = fab.snapshot()
        # every live endpoint answered with its own ledger + wire counters
        assert set(raw) == {0, 1}
        for idx, stats in raw.items():
            assert stats["index"] == idx
            assert stats["counters"]["bytes_rpc_rx"] > 0
        served_remote = sum(s["counters"]["served"] for s in raw.values())
        assert served_remote == 8
        # ... and landed in the coordinator meter's remote section
        assert set(snap["remote"]) == {"0", "1"}
        # per-tenant fair-share ledgers exist per proxy scheduler
        offered = sum(c.get("mobile", {}).get("offered", 0)
                      for c in snap["scheduler_counters"].values())
        assert offered == 4
        # rpc wait is split out of queue wait (percentile present)
        assert "rpc_wait_p99_ms" in snap
        assert snap["errors"] == 0
        # both directions metered on the coordinator side
        assert snap["rpc"]["bytes_rpc_tx"] > 0
        assert snap["rpc"]["bytes_rpc_rx"] > 0
        # ... and mirrored endpoint-side (tx there ~ rx here)
        ep_tx = sum(ep.meter.traffic.bytes_rpc_tx for ep in eps)
        assert ep_tx >= snap["rpc"]["bytes_rpc_rx"]
    finally:
        for ep in eps:
            ep.stop()


# ---------------------------------------------------------------------------
# subprocess: the CI rpc-smoke acceptance (real processes, localhost TCP)
# ---------------------------------------------------------------------------

RPC_COORD_CODE = r"""
import os, signal, time
import numpy as np
import jax

from repro.analysis import enable_sanitizer
enable_sanitizer(True)

from repro.gns import EngineConfig, FabricConfig, GNSEngine, TenantConfig

assert len(jax.devices()) == 4

import json
with open({cfg_path!r}) as f:
    cfg = EngineConfig.from_dict(json.load(f))
eng = GNSEngine(cfg)
ds = eng.ds

fab = eng.serve_fabric(FabricConfig(
    workers=2, transport="tcp",
    endpoints=("127.0.0.1:{port0}", "127.0.0.1:{port1}"),
    tenants=(TenantConfig("mobile", weight=2.0, max_queue=64),
             TenantConfig("batch", weight=1.0, max_queue=64)),
    stall_timeout_ms=5000.0, watch_interval_ms=50.0, heartbeat_ms=50.0))

rng = np.random.default_rng(7)
half = len(ds.val_idx) // 2
hot_a = rng.choice(ds.val_idx[:half], size=30, replace=False)
hot_b = rng.choice(ds.val_idx[half:], size=30, replace=False)

with fab:
    futs = []
    for i in range(40):
        tenant, hot = (("mobile", hot_a) if i % 2 == 0 else ("batch", hot_b))
        ids = rng.choice(hot, size=int(rng.integers(2, 8)), replace=False)
        futs.append(fab.submit(ids, tenant=tenant))
    res = [f.result(timeout=600) for f in futs]
    assert all(r.status == "ok" for r in res), [r.status for r in res]

    # chaos mid-stream: SIGKILL endpoint 0 with requests in flight
    w0 = fab.workers[0]
    futs = [fab.submit(rng.choice(hot_a, size=4, replace=False),
                       tenant="mobile", worker=0) for _ in range(4)]
    os.kill({pid0}, signal.SIGKILL)
    deadline = time.monotonic() + 120
    while w0.alive() and time.monotonic() < deadline:
        time.sleep(0.05)
    assert not w0.alive(), "proxy sender survived the endpoint SIGKILL"
    # reclaimed + re-served on the survivor, losslessly
    assert all(f.result(timeout=600).status == "ok" for f in futs)
    tail = [fab.submit(rng.choice(hot_b, size=4, replace=False),
                       tenant="batch") for _ in range(6)]
    assert all(f.result(timeout=600).status == "ok" for f in tail)
    assert fab.healthy() == [1], fab.healthy()
    remote = fab.pull_remote_stats(timeout=30.0)
    assert set(remote) == (set((1,))), remote
    snap = fab.snapshot()

rt = snap["routing"]
assert rt["routed_known_ids"] > 0, rt
assert rt["route_local_fraction"] > 0.5, rt
assert rt["failovers"] >= 1 and rt["retries"] >= 1, rt
assert snap["errors"] == 0, snap
assert snap["rpc"]["bytes_rpc_tx"] > 0 and snap["rpc"]["bytes_rpc_rx"] > 0
assert "rpc_wait_p99_ms" in snap, sorted(snap)

print("RPC_SMOKE_OK", "local=", rt["route_local_fraction"],
      "failovers=", rt["failovers"], "rpc=", snap["rpc"])
"""


def _smoke_config() -> dict:
    """The CI-scale production shape: 2 DP groups x 2 cache shards on the
    forced-host 2x2 mesh, fused input, locality placement."""
    from repro.gns.config import MeshConfig, ModelConfig
    scfg = SamplerConfig(fanouts=(3, 4), batch_size=32,
                         cache=CacheConfig(fraction=0.05,
                                           strategy="adaptive",
                                           placement="locality"))
    return EngineConfig(
        sampler="gns", sampling=scfg, cache=scfg.cache,
        model=ModelConfig(input_impl="fused", hidden_dim=16),
        mesh=MeshConfig(data=2, model=2),
        serve=ServeConfig(buckets=(8, 32), max_wait_ms=2.0),
        seed=0).to_dict()


def _sub_env():
    return dict(os.environ, PYTHONPATH="src",
                XLA_FLAGS="--xla_force_host_platform_device_count=4",
                REPRO_LOCK_SANITIZER="1")


@pytest.mark.dryrun
def test_rpc_smoke_two_processes_subprocess(tmp_path):
    """The CI rpc-smoke acceptance: 2 endpoint PROCESSES + a coordinator
    process over localhost TCP on the forced-host 2x2 mesh — majority-local
    routing, zero errors, lossless recovery after a mid-stream SIGKILL,
    lock sanitizer armed in all three processes."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    cfg_path = str(tmp_path / "engine.json")
    with open(cfg_path, "w") as f:
        json.dump(_smoke_config(), f)

    eps = []
    try:
        ports = []
        for i in range(2):
            p = subprocess.Popen(
                [sys.executable, "-m", "repro.rpc.endpoint",
                 "--config", cfg_path, "--index", str(i),
                 "--port", "0", "--heartbeat-ms", "50"],
                cwd=root, env=_sub_env(), stdout=subprocess.PIPE,
                stderr=subprocess.PIPE, text=True)
            eps.append(p)
        for p in eps:
            line = p.stdout.readline()      # blocks until the replica is up
            assert "GNS_ENDPOINT_READY" in line, (
                line, p.stderr.read() if p.poll() is not None else "")
            ports.append(int(dict(kv.split("=") for kv in
                                  line.split()[1:])["port"]))

        code = RPC_COORD_CODE.format(cfg_path=cfg_path, port0=ports[0],
                                     port1=ports[1], pid0=eps[0].pid)
        proc = subprocess.run([sys.executable, "-c", code], cwd=root,
                              env=_sub_env(), capture_output=True,
                              text=True, timeout=900)
        assert proc.returncode == 0, proc.stderr[-4000:]
        assert "RPC_SMOKE_OK" in proc.stdout, proc.stdout[-3000:]
        # endpoint 0 was SIGKILLed by the coordinator; endpoint 1 survived
        assert eps[0].poll() is not None
        assert eps[1].poll() is None
    finally:
        for p in eps:
            if p.poll() is None:
                p.kill()
            p.wait(timeout=30)

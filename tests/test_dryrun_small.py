"""Dry-run machinery on a small (2x4) host-device mesh, in a subprocess.

The production 16x16 / 2x16x16 sweep lives in launch/dryrun.py (hours); this
test proves the same lowering path — input_specs + param rules + shard_map
attention + MoE dispatch + jit(in/out shardings).lower().compile() — on 8
fake devices with reduced configs, in CI time.  Subprocess because jax locks
the device count at first init.
"""
from __future__ import annotations

import os
import subprocess
import sys

import pytest

CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.launch import sharding as shlib
from repro.launch.specs import input_specs
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.models.lm import get_model
from repro.optim.adam import AdamConfig, AdamW

mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
shapes = [ShapeSpec("t", 32, 8, "train"), ShapeSpec("d", 32, 8, "decode"),
          ShapeSpec("p", 32, 8, "prefill")]
archs = ["qwen2-7b", "deepseek-v2-236b", "zamba2-2.7b", "xlstm-125m",
         "seamless-m4t-medium", "h2o-danube-3-4b"]

for arch in archs:
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    for shape in shapes:
        with shlib.use_mesh(mesh):
            specs = input_specs(cfg, shape, mesh, model=model)
            p_structs, p_sh = specs["params"]
            if shape.kind in ("decode", "prefill"):
                step = (make_serve_step(model) if shape.kind == "decode"
                        else make_prefill_step(model))
                t_struct, t_sh = specs["tokens"]
                s_structs, s_sh = specs["state"]
                c = jax.jit(step, in_shardings=(p_sh, t_sh, s_sh),
                            out_shardings=(t_sh, s_sh)).lower(
                                p_structs, t_struct, s_structs).compile()
            else:
                opt = AdamW(AdamConfig(lr=1e-3))
                step = make_train_step(model, opt)
                b_structs, b_sh = specs["batch"]
                o_structs = jax.eval_shape(opt.init, p_structs)
                o_sh = {"m": p_sh, "v": p_sh,
                        "step": jax.sharding.NamedSharding(
                            mesh, jax.sharding.PartitionSpec())}
                loss_sh = jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec())
                c = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                            out_shardings=(p_sh, o_sh, loss_sh)).lower(
                                p_structs, o_structs, b_structs).compile()
            assert c.cost_analysis() is not None
        print("ok", arch, shape.kind, flush=True)
print("ALL_OK")
"""


@pytest.mark.dryrun
def test_small_mesh_dryrun_all_families():
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run([sys.executable, "-c", CODE], cwd=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), env=env,
        capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "ALL_OK" in proc.stdout, proc.stdout[-2000:]

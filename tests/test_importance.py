"""§3.4 importance coefficients: numerics + the unbiasedness property (eq. 5).

The decisive test: over repeated cache draws + GNS neighbor sampling, the
weighted aggregation Σ w·h must converge to the full-neighborhood mean.
This is exactly eq. (5)/(B.15) — the property Theorem 1's proof rests on.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                          # bare env: seeded fallback shim
    from _hypothesis_fallback import given, settings, st

from repro.featurestore import CacheConfig
from repro.core.importance import (cache_hit_prob, importance_coefficients,
                                   solve_inclusion_lambda)
from repro.core.sampler import GNSSampler, SamplerConfig
from repro.core.variance import full_neighbor_mean, sampled_mean_once
from repro.graph.generate import powerlaw_graph


# ---------------------------------------------------------------------------
# unit / numeric behavior
# ---------------------------------------------------------------------------

def test_cache_hit_prob_limits():
    p = np.array([0.0, 1e-9, 0.5, 1.0 - 1e-13])
    pc = cache_hit_prob(p, cache_size=100)
    assert pc[0] == 0.0
    assert pc[1] == pytest.approx(1e-7, rel=1e-3)   # ~ |C|*p for tiny p
    assert pc[2] > 1 - 1e-12                         # saturates
    assert np.all((0 <= pc) & (pc <= 1))


def test_solve_lambda_calibrates_to_cache_size():
    """Non-degenerate case: Σ_i (1 - exp(-λ p_i)) == |C| at the solution."""
    rng = np.random.default_rng(0)
    p = rng.pareto(1.5, size=5000) + 1e-6
    p /= p.sum()
    for c in (10, 100, 1000):
        lam = solve_inclusion_lambda(p, c)
        assert lam is not None and lam >= c
        total = cache_hit_prob(p, c, lam=lam).sum()
        assert total == pytest.approx(c, rel=1e-4)


def test_solve_lambda_degenerate_cache_covers_support():
    """|C| >= positive support: every node is included w.p. 1 (λ* = ∞) —
    must warn and fall back to the independence approximation, not fail to
    bracket."""
    p = np.full(50, 1.0 / 50)
    for c in (50, 51, 500):
        with pytest.warns(RuntimeWarning, match="positive-probability nodes"):
            assert solve_inclusion_lambda(p, c) is None


def test_solve_lambda_all_zero_probs():
    with pytest.warns(RuntimeWarning, match="all-zero"):
        assert solve_inclusion_lambda(np.zeros(100), 10) is None


def test_cache_hit_prob_degenerate_lam_falls_back():
    """A degenerate λ (inf / nan / <= 0) must warn and return the
    independence-approximation probabilities, which stay in [0, 1]."""
    p = np.array([0.0, 1e-4, 0.5])
    expect = cache_hit_prob(p, 20)                # independence path
    for bad in (np.inf, np.nan, 0.0, -3.0):
        with pytest.warns(RuntimeWarning, match="degenerate lam"):
            got = cache_hit_prob(p, 20, lam=bad)
        np.testing.assert_array_equal(got, expect)
        assert np.all((0 <= got) & (got <= 1))


def test_store_lambda_degenerate_cache_still_refreshes():
    """End-to-end: a FeatureStore whose cache covers the whole graph must
    refresh cleanly (λ falls back to None -> eq. 11 weights)."""
    import warnings as _w
    from repro.featurestore import FeatureStore
    g = powerlaw_graph(300, avg_degree=6, seed=0)
    feats = np.random.default_rng(0).standard_normal(
        (g.num_nodes, 8)).astype(np.float32)
    store = FeatureStore(feats, g, CacheConfig(fraction=1.0))
    with _w.catch_warnings():
        _w.simplefilter("ignore", RuntimeWarning)
        gen = store.refresh(np.random.default_rng(0))
    assert gen.lam is None
    assert gen.state.in_cache.all()


@given(p=st.floats(1e-12, 0.99), c=st.integers(1, 10_000))
@settings(max_examples=200, deadline=None)
def test_cache_hit_prob_monotone_bounded(p, c):
    pc = float(cache_hit_prob(np.array([p]), c)[0])
    assert 0.0 <= pc <= 1.0
    assert pc >= p * 0.9999 or c == 1  # more draws -> higher prob
    pc2 = float(cache_hit_prob(np.array([p]), c + 1)[0])
    assert pc2 >= pc - 1e-15


@given(
    probs=st.lists(st.floats(1e-8, 0.2), min_size=1, max_size=8),
    cache_size=st.integers(1, 1000),
    fanout=st.integers(1, 32),
    ncv=st.integers(0, 64),
)
@settings(max_examples=200, deadline=None)
def test_coefficients_positive_bounded(probs, cache_size, fanout, ncv):
    p = np.array(probs)
    for mode in ("ht", "paper"):
        c = importance_coefficients(p, cache_size, fanout, np.full_like(p, ncv),
                                    mode=mode)
        assert np.all(c > 0)
        if mode == "ht":
            assert np.all(c <= 1.0 + 1e-9)   # an inclusion probability


# ---------------------------------------------------------------------------
# the eq. (5) unbiasedness property (Monte-Carlo)
# ---------------------------------------------------------------------------

def _mc_estimates(g, h, nodes, mode, trials, fanout=6, fraction=0.05):
    cfg = SamplerConfig(fanouts=(fanout,), batch_size=len(nodes),
                        cache=CacheConfig(fraction=fraction, period=1),
                        importance_mode=mode)
    s = GNSSampler(g, cfg, h.astype(np.float32), np.zeros(g.num_nodes, np.int32))
    ests = np.zeros((trials, len(nodes), h.shape[1]))
    for t in range(trials):
        s.refresh_cache(np.random.default_rng(1000 + t), version=t)
        ests[t] = sampled_mean_once(s, nodes, h, np.random.default_rng(2000 + t))
    return ests


@pytest.mark.slow
def test_gns_weight_sum_unbiased():
    """Exact form of eq. (5): with h ≡ 1, E[Σ_k w] must be exactly 1.

    This isolates the importance-weight bookkeeping from feature noise:
    any systematic error in eq. (11)/(12) or the top-up weights shows up as a
    deterministic shift of the weight-sum mean.
    """
    g = powerlaw_graph(3000, avg_degree=12, seed=5)
    h = np.ones((g.num_nodes, 1))
    # probe a degree-diverse set including hubs (cache interacts with hubs)
    order = np.argsort(g.degrees)
    nodes = np.concatenate([order[-16:], order[len(order) // 2: len(order) // 2 + 16]]).astype(np.int64)
    trials = 400
    ests = _mc_estimates(g, h, nodes, "ht", trials)
    mean = ests.mean(axis=0)[:, 0]             # E[Σw] per node
    se = ests.std(axis=0)[:, 0] / np.sqrt(trials)
    z = np.abs(mean - 1.0) / np.maximum(se, 1e-4)
    # systematic bias (signed mean across nodes) must vanish; per-node
    # deviations are MC noise and are checked against their standard errors
    assert abs(np.mean(mean - 1.0)) < 0.02, mean
    assert (z < 5).mean() > 0.9, (mean, z)


@pytest.mark.slow
def test_gns_aggregation_unbiased_zscore():
    """MC mean of the weighted aggregation matches the exact mean within SE."""
    g = powerlaw_graph(3000, avg_degree=12, seed=5)
    rng = np.random.default_rng(0)
    h = rng.normal(size=(g.num_nodes, 4))
    nodes = np.argsort(g.degrees)[-24:].astype(np.int64)
    target = full_neighbor_mean(g, h, nodes)
    trials = 400
    ests = _mc_estimates(g, h, nodes, "ht", trials)
    mean = ests.mean(axis=0)
    se = ests.std(axis=0) / np.sqrt(trials)
    z = np.abs(mean - target) / np.maximum(se, 1e-5)
    assert (z < 5).mean() > 0.95, f"fraction within 5 SE: {(z < 5).mean():.3f}"


@pytest.mark.slow
def test_gns_variance_decreases_with_cache_size():
    """Theorem 1 trend: larger cache fraction C̃ -> smaller estimator MSE."""
    from repro.core.variance import estimator_mse
    g = powerlaw_graph(3000, avg_degree=12, seed=6)
    rng = np.random.default_rng(0)
    h = rng.normal(size=(g.num_nodes, 8))
    nodes = rng.choice(g.num_nodes, size=64, replace=False).astype(np.int64)
    mse_small = estimator_mse(g, h, nodes, "gns", fanout=5,
                              cache_fraction=0.002, trials=60, seed=1)
    mse_big = estimator_mse(g, h, nodes, "gns", fanout=5,
                            cache_fraction=0.10, trials=60, seed=1)
    assert mse_big < mse_small

"""Streaming-ingest integration: serve-while-mutating acceptance.

In-process (meshless, tiny dataset, runtime lock sanitizer armed by
conftest):

* **visibility + pinning + no-recompile** — a batch pinned pre-merge
  recomputes bitwise-identically after the merge (its generation carries
  the pre-merge graph), post-merge sampling traverses the new edges and
  serves brand-new nodes, and the jit cache stays flat across the merge
  (the device table keeps its padded shape);
* **deterministic replay** — the same temporal event stream folded into
  two independent engines produces bitwise-identical post-merge CSRs;
* **ingest-while-serving** — a live 2-worker fabric drains staged deltas
  through its watchdog (async build → atomic swap → router re-adopt) and
  the routed-local fraction after the incremental placement re-solve does
  not regress by more than 0.05.

Subprocess (4 forced host devices, ``@pytest.mark.dryrun`` — the CI
``stream-smoke`` acceptance): the same contract on the 2x2 sharded fused
mesh with the lock sanitizer armed — ingest under live traffic, post-merge
queries see the new structure, zero steady-state recompilation.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.core.sampler import SamplerConfig
from repro.data import temporal_event_stream
from repro.featurestore import CacheConfig
from repro.gns import (EngineConfig, FabricConfig, GNSEngine, ServeConfig,
                       StreamConfig)
from repro.graph.datasets import get_dataset


def _engine(seed=0, *, shards=2):
    # fresh dataset per engine: merges mutate the engine's dataset view
    ds = get_dataset("tiny", seed=0)
    scfg = SamplerConfig(fanouts=(3, 4), batch_size=32,
                         cache=CacheConfig(fraction=0.1, strategy="adaptive",
                                           placement="locality",
                                           shards=shards))
    cfg = EngineConfig(sampler="gns", sampling=scfg, cache=scfg.cache,
                       serve=ServeConfig(buckets=(8, 32), max_wait_ms=2.0),
                       stream=StreamConfig(merge_min_pending=1),
                       seed=seed)
    return GNSEngine(cfg, dataset=ds)


def _wait(pred, timeout=30.0, interval=0.01):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(interval)
    return False


# ---------------------------------------------------------------------------
# visibility + pinning + recompile-flat (engine level)
# ---------------------------------------------------------------------------

def test_merge_visibility_pinning_and_no_recompile():
    eng = _engine()
    eng.ensure_cache()
    v0 = eng.ds.graph.num_nodes
    ids = eng.ds.val_idx[:8].astype(np.int64)

    mb0 = eng.infer_prepare(ids, bucket=32)
    out0 = eng.infer_compute(mb0)
    compiled0 = eng.infer_step._cache_size()

    new = eng.ingest_nodes(
        np.random.default_rng(0).normal(
            size=(2, eng.ds.feat_dim)).astype(np.float32),
        labels=np.zeros(2, np.int64))
    eng.ingest(new, [int(ids[0]), int(ids[1])])
    # also delete one real edge (first val target with any neighbors)
    g = eng.ds.graph
    u = next(int(i) for i in eng.ds.val_idx
             if g.indptr[i + 1] > g.indptr[i])
    v = int(g.indices[g.indptr[u]])
    eng.ingest([u], [v], op="delete")
    assert eng.pending_deltas == 5

    eng.merge_deltas()
    assert eng.pending_deltas == 0
    assert eng.store.merges_applied == 1
    assert eng.ds.graph.num_nodes == v0 + 2
    assert eng.meter.bytes_delta_upload > 0

    # (a) the pinned pre-merge batch recomputes bitwise-identically, off the
    # pre-merge structure its generation carries
    assert mb0.cache_gen.graph.num_nodes == v0
    np.testing.assert_array_equal(out0, eng.infer_compute(mb0))

    # (b) post-merge sampling runs on the merged structure
    assert eng.sampler.g.num_nodes == v0 + 2
    gm = eng.sampler.g
    nb = gm.indices[gm.indptr[int(ids[0])]:gm.indptr[int(ids[0]) + 1]]
    assert int(new[0]) in nb                       # inserted edge visible
    nb_u = gm.indices[gm.indptr[u]:gm.indptr[u + 1]]
    assert v not in nb_u                           # deleted edge gone
    # brand-new node is queryable end to end
    out_new = eng.infer_compute(eng.infer_prepare(new[:1], bucket=32))
    assert out_new.shape[0] == 32 and np.isfinite(out_new[:1]).all()

    # (c) the merge retraced nothing: table keeps its padded shape, batch
    # shapes are bucket-static
    assert eng.infer_step._cache_size() == compiled0

    # describe() surfaces the run state, and diff() treats it as volatile
    rec = eng.describe()
    assert rec["stream"]["merges_applied"] == 1
    from repro.gns.describe import diff_records
    eng.ingest([int(ids[0])], [int(ids[3])])
    d = diff_records(rec, eng.describe())
    assert d["same"] and not d["changed"], d


def test_event_stream_replay_deterministic():
    """Same seed → same stream → bitwise-identical post-merge structure on
    two independent engines (merge ≡ rebuild, end to end)."""
    def run():
        eng = _engine(seed=1)
        eng.ensure_cache()
        stream = temporal_event_stream(eng.ds, num_batches=3,
                                       events_per_batch=24,
                                       new_node_frac=0.15, seed=11)
        for ev in stream:
            eng.ingest_events(ev)
        eng.merge_deltas()
        return eng

    a, b = run(), run()
    assert a.ds.graph.num_nodes == b.ds.graph.num_nodes
    np.testing.assert_array_equal(a.ds.graph.indptr, b.ds.graph.indptr)
    np.testing.assert_array_equal(a.ds.graph.indices, b.ds.graph.indices)
    np.testing.assert_array_equal(a.ds.features, b.ds.features)
    np.testing.assert_array_equal(a.ds.labels, b.ds.labels)


# ---------------------------------------------------------------------------
# ingest while a fabric serves: watchdog drain + local-fraction floor
# ---------------------------------------------------------------------------

def test_fabric_drains_deltas_and_local_fraction_holds():
    eng = _engine(seed=2)
    fab = eng.serve_fabric(FabricConfig(workers=2, watch_interval_ms=20.0))
    rng = np.random.default_rng(9)
    ds = eng.ds
    half = len(ds.val_idx) // 2
    hot_a = ds.val_idx[:half][:12].astype(np.int64)
    hot_b = ds.val_idx[half:][:12].astype(np.int64)

    def burst(n=16):
        futs = []
        for i in range(n):
            hot = hot_a if i % 2 == 0 else hot_b
            ids = rng.choice(hot, size=int(rng.integers(2, 8)), replace=False)
            futs.append(fab.submit(ids))
        assert all(f.result(timeout=600).status == "ok" for f in futs)

    def route_counts():
        m = fab.meter
        with m.lock:            # rw-guarded counters: lock to read, too
            return m.routed_known_ids, m.routed_local_ids

    def frac(c0, c1):
        known = c1[0] - c0[0]
        local = c1[1] - c0[1]
        return known, (local / known if known else 0.0)

    with fab:
        burst()                                    # warm + demand histograms
        # seed ingest: one edge — the watchdog must drain it (async build →
        # swap → router re-adopt) without any explicit refresh call
        eng.ingest([int(hot_a[0])], [int(hot_b[0])])
        assert _wait(lambda: eng.store.merges_applied >= 1), "no merge"
        assert _wait(lambda: eng.pending_deltas == 0)
        swaps0 = fab.meter.snapshot()["swaps_observed"]
        assert _wait(lambda: fab.meter.snapshot()["swaps_observed"] >= 1), \
            "watchdog never swapped the merged generation in"

        # pre-ingest window: placement solved from the warm traffic
        c0 = route_counts()
        burst()
        known1, frac1 = frac(c0, route_counts())

        # the mutation burst: temporal events staged while serving is live
        stream = temporal_event_stream(ds, num_batches=2,
                                       events_per_batch=24,
                                       new_node_frac=0.1, seed=3)
        merges0 = eng.store.merges_applied
        for ev in stream:
            eng.ingest_events(ev)
            burst(6)                               # serving never pauses
        assert _wait(lambda: eng.store.merges_applied > merges0), "no merge"
        assert _wait(lambda: eng.pending_deltas == 0)
        assert _wait(lambda: fab.meter.snapshot()["swaps_observed"] > swaps0)

        # post-ingest window, same hot sets
        c2 = route_counts()
        burst()
        known2, frac2 = frac(c2, route_counts())

        # post-merge structure is serveable: a new node answers queries
        new_first = int(ds.graph.num_nodes - stream.total_new_nodes)
        out = fab.infer(np.array([new_first], np.int64), timeout=600)
        assert out.shape[0] == 1 and np.isfinite(out).all()

    # the incremental re-solve held the routed-local floor (acceptance (d))
    if known1 > 0 and known2 > 0:
        assert frac2 >= frac1 - 0.05, (frac1, frac2)
    assert fab.meter.snapshot()["errors"] == 0
    assert eng.store.merges_applied >= merges0 + 1
    rec = eng.describe()["stream"]
    assert rec["merges_applied"] == eng.store.merges_applied
    assert rec["pending_deltas"] == 0


# ---------------------------------------------------------------------------
# subprocess: the CI stream-smoke acceptance (4 forced host devices)
# ---------------------------------------------------------------------------

STREAM_SMOKE_CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ["REPRO_LOCK_SANITIZER"] = "1"
import time
import numpy as np
import jax

from repro.analysis import enable_sanitizer
enable_sanitizer(True)

from repro.core.sampler import SamplerConfig
from repro.data import temporal_event_stream
from repro.featurestore import CacheConfig
from repro.gns import (EngineConfig, FabricConfig, GNSEngine, ServeConfig,
                       StreamConfig)
from repro.gns.config import MeshConfig, ModelConfig

assert len(jax.devices()) == 4

# production shape at CI scale: 2 DP groups x 2 cache shards, fused input,
# locality placement, streaming ingest armed
scfg = SamplerConfig(fanouts=(3, 4), batch_size=32,
                     cache=CacheConfig(fraction=0.05, strategy="adaptive",
                                       placement="locality"))
cfg = EngineConfig(sampler="gns", sampling=scfg, cache=scfg.cache,
                   model=ModelConfig(input_impl="fused", hidden_dim=16),
                   mesh=MeshConfig(data=2, model=2),
                   serve=ServeConfig(buckets=(8, 32), max_wait_ms=2.0),
                   stream=StreamConfig(merge_min_pending=1),
                   seed=0)
eng = GNSEngine(cfg)
assert eng.store.n_shards == 2
ds = eng.ds
v0 = ds.graph.num_nodes

fab = eng.serve_fabric(FabricConfig(workers=2, stall_timeout_ms=2000.0,
                                    watch_interval_ms=50.0))
rng = np.random.default_rng(7)
hot = rng.choice(ds.val_idx, size=24, replace=False).astype(np.int64)


def wait(pred, timeout=120.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(0.05)
    return False


with fab:
    # warm both buckets' compiled steps, then freeze the jit-cache watermark
    fab.infer(hot[:4], timeout=600)
    fab.infer(hot[:20], timeout=600)
    compiled0 = eng.infer_step._cache_size()

    # pin a pre-merge answer at the engine level (deterministic rng)
    mb0 = eng.infer_prepare(hot[:8], bucket=32,
                            rng=np.random.default_rng(123))
    out0 = eng.infer_compute(mb0)

    # ingest under live traffic: watchdog drains, serving never pauses
    stream = temporal_event_stream(ds, num_batches=2, events_per_batch=24,
                                   new_node_frac=0.1, seed=3)
    futs = []
    for ev in stream:
        eng.ingest_events(ev)
        for _ in range(8):
            ids = rng.choice(hot, size=int(rng.integers(2, 8)),
                             replace=False)
            futs.append(fab.submit(ids))
    assert all(f.result(timeout=600).status == "ok" for f in futs)
    assert wait(lambda: eng.store.merges_applied >= 1), "no merge applied"
    assert wait(lambda: eng.pending_deltas == 0), "deltas not drained"
    assert wait(lambda: fab.meter.snapshot()["swaps_observed"] >= 1), \
        "merged generation never swapped in"

    # (a) pre-swap pinned batch replays bitwise off the old generation
    assert mb0.cache_gen.graph.num_nodes == v0
    np.testing.assert_array_equal(out0, eng.infer_compute(mb0))

    # (b) post-swap queries see the new structure (new node served)
    assert ds.graph.num_nodes == v0 + stream.total_new_nodes
    new_id = np.array([v0], np.int64)
    out_new = fab.infer(new_id, timeout=600)
    assert out_new.shape[0] == 1 and np.isfinite(out_new).all()

    # (c) zero steady-state recompilation across the merges
    assert eng.infer_step._cache_size() == compiled0, (
        eng.infer_step._cache_size(), compiled0)

snap = fab.meter.snapshot()
assert snap["errors"] == 0, snap
print("STREAM_SMOKE_OK", "merges=", eng.store.merges_applied,
      "migrated=", eng.store.rows_migrated,
      "delta_bytes=", eng.meter.bytes_delta_upload)
"""


def _run_sub(code: str, timeout: int = 900) -> str:
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run(
        [sys.executable, "-c", code],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


@pytest.mark.dryrun
def test_stream_smoke_on_mesh_subprocess():
    """The CI stream-smoke acceptance: ingest while a 2-worker fabric on
    the forced-host 2x2 mesh serves — pre-swap bitwise replay, post-swap
    visibility, jit cache flat, lock sanitizer armed throughout."""
    out = _run_sub(STREAM_SMOKE_CODE)
    assert "STREAM_SMOKE_OK" in out, out[-3000:]

"""Tiny stand-in for ``hypothesis`` when it isn't installed.

The tier-1 suite must run in bare environments (CI containers without the
``test`` extra).  This shim implements just the surface the property tests
use — ``given`` / ``settings`` / ``strategies.integers|floats|lists`` — by
drawing a fixed number of seeded pseudo-random examples per test.  It keeps
the property tests as randomized smoke coverage; install ``hypothesis``
(``pip install repro[test]``) for real shrinking/replay.

Usage (see tests/test_kernels.py)::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:                      # pragma: no cover
        from _hypothesis_fallback import given, settings, st
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np

_MAX_EXAMPLES_CAP = 25      # keep the fallback suite fast


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


class st:                                    # mimics `strategies` module
    @staticmethod
    def integers(min_value, max_value) -> _Strategy:
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value, max_value) -> _Strategy:
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def lists(elem: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elem.draw(rng) for _ in range(n)]
        return _Strategy(draw)


def settings(max_examples: int = 20, **_ignored):
    """Order-independent with ``given``: stamps whichever callable it wraps."""
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*pos_strategies, **strategies):
    def deco(fn):
        if pos_strategies:
            # hypothesis maps positional strategies to the rightmost params
            params = list(inspect.signature(fn).parameters)
            names = params[len(params) - len(pos_strategies):]
            merged = dict(zip(names, pos_strategies), **strategies)
        else:
            merged = strategies

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples",
                        getattr(fn, "_fallback_max_examples", 20))
            n = min(n, _MAX_EXAMPLES_CAP)
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = np.random.default_rng(seed)
            for _ in range(n):
                drawn = {k: s.draw(rng) for k, s in merged.items()}
                fn(*args, **kwargs, **drawn)

        # hide the drawn params from pytest's fixture resolution (hypothesis
        # does the same): expose only the remaining (fixture) parameters
        remaining = [p for name, p in inspect.signature(fn).parameters.items()
                     if name not in merged]
        wrapper.__signature__ = inspect.Signature(remaining)
        del wrapper.__wrapped__
        return wrapper
    return deco

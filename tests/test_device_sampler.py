"""Device-resident GNS sampling (repro.sampling): correctness + parity.

Covers the ISSUE-6 satellite test matrix:
  * stateless-RNG determinism and replay stability,
  * jnp-reference bitwise parity for the fused gather kernel (interpret
    mode — same accumulation order, exactly-representable products),
  * chi-square statistical parity of the device draw's per-lane marginal
    against the host sampler's uniform cached-neighbor marginal,
  * importance-weight unbiasedness extended to the device backend
    (E[Σ w·f] = Σ_{u∈N_C(v)} f_u / (p^C_u · deg v), both regimes),
  * generation-swap safety (a batch pinned to gen N draws gen N's CSR and
    gathers gen N's table even after a refresh),
  * host-fallback lanes for uncached destinations,
  * per-batch seeded pipeline RNG (run-to-run reproducible batches),
  * the prefetcher idle-time metric and the unified refresh hint.
"""
import dataclasses
import time

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.core.minibatch import block_pad_sizes
from repro.core.pipeline import EpochLoader, Prefetcher
from repro.core.sampler import SamplerConfig, make_sampler
from repro.featurestore import CacheConfig
from repro.featurestore.meter import TrafficMeter
from repro.graph.datasets import get_dataset
from repro.sampling import (DeviceCacheAdj, DeviceGNSSampler, draw_lanes,
                            gns_sample_agg, mix32, slot_gather_agg_pallas,
                            slot_gather_agg_ref)


@pytest.fixture(scope="module")
def ds():
    return get_dataset("tiny", seed=0)


def _mk_device(ds, batch_size=32, fanouts=(3, 4, 5), fraction=0.2):
    cfg = SamplerConfig(fanouts=fanouts, batch_size=batch_size,
                        cache=CacheConfig(fraction=fraction, period=1),
                        backend="device")
    s = make_sampler("gns", ds.graph, cfg, ds.features, ds.labels,
                     train_idx=ds.train_idx)
    s.start_epoch(0, np.random.default_rng(0))
    return s


def _targets(ds, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.choice(ds.train_idx, size=n, replace=False).astype(np.int64)


def _toy_adj(nbrs_per_row, hitp=None, deg=None, rows=None):
    """DeviceCacheAdj from a python list-of-lists of neighbor rows."""
    if rows is None:
        rows = len(nbrs_per_row)
    counts = [len(n) for n in nbrs_per_row] + [0] * (rows - len(nbrs_per_row))
    indptr = np.zeros(rows + 1, np.int32)
    np.cumsum(counts, out=indptr[1:])
    nnz = int(indptr[-1])
    cap = 1 << max(1024, nnz).bit_length()
    indices = np.zeros(cap, np.int32)
    flat = [r for n in nbrs_per_row for r in n]
    indices[:nnz] = flat
    # hitp/deg are indexed by device-table ROW; the real builder sizes them
    # to the table, so the toy must cover every neighbor row too
    nrows = max([rows] + [r + 1 for n in nbrs_per_row for r in n])
    if hitp is None:
        hitp = np.full(nrows, 0.5)
    else:
        hitp = np.concatenate([np.asarray(hitp, np.float64),
                               np.full(nrows - len(hitp), 0.5)])
    if deg is None:
        deg = np.array([max(len(n), 1) for n in nbrs_per_row]
                       + [1] * (nrows - len(nbrs_per_row)))
    else:
        deg = np.concatenate([np.asarray(deg, np.float64),
                              np.ones(nrows - len(deg))])
    return DeviceCacheAdj(indptr=jnp.asarray(indptr),
                          indices=jnp.asarray(indices),
                          deg=jnp.asarray(np.asarray(deg, np.float32)),
                          hitp=jnp.asarray(np.asarray(hitp, np.float32)))


# ---------------------------------------------------------------------------
# RNG
# ---------------------------------------------------------------------------

def test_mix32_deterministic_and_avalanche():
    a = np.arange(64, dtype=np.uint32)
    h1 = np.asarray(mix32(jnp.uint32(1), jnp.uint32(2), jnp.asarray(a)))
    h2 = np.asarray(mix32(jnp.uint32(1), jnp.uint32(2), jnp.asarray(a)))
    assert h1.dtype == np.uint32
    np.testing.assert_array_equal(h1, h2)           # pure function of inputs
    assert len(np.unique(h1)) == 64                 # no collisions on 64 ctrs
    h3 = np.asarray(mix32(jnp.uint32(1), jnp.uint32(3), jnp.asarray(a)))
    assert (h1 != h3).mean() > 0.9                  # key change reshuffles


def test_draw_lanes_replay_stable():
    adj = _toy_adj([[0, 1, 2, 3, 4, 5], [1, 2], []])
    dst = jnp.asarray([0, 1, 2, -1], jnp.int32)
    key = jnp.asarray([[123, 456]], jnp.uint32)
    r1, w1 = draw_lanes(adj, dst, key, k=3)
    r2, w2 = draw_lanes(adj, dst, key, k=3)
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    np.testing.assert_array_equal(np.asarray(w1), np.asarray(w2))
    r3, _ = draw_lanes(adj, dst, jnp.asarray([[124, 456]], jnp.uint32), k=3)
    assert not np.array_equal(np.asarray(r1)[0], np.asarray(r3)[0])


def test_draw_lanes_regimes():
    adj = _toy_adj([[0, 1, 2, 3, 4, 5], [1, 2], []],
                   deg=[10, 4, 1], hitp=[0.5, 0.5, 0.5])
    dst = jnp.asarray([0, 1, 2, -1], jnp.int32)
    key = jnp.asarray([[7, 9]], jnp.uint32)
    rows, w = draw_lanes(adj, dst, key, k=3)
    rows, w = np.asarray(rows), np.asarray(w)
    # n_c > k: every lane alive, drawn rows within the neighbor list
    assert (w[0] > 0).all() and set(rows[0]) <= {0, 1, 2, 3, 4, 5}
    # weight formula: 1 / (hitp * min(k,nc)/nc * deg) = nc/(hitp*k*deg)
    np.testing.assert_allclose(w[0], 6 / (0.5 * 3 * 10), rtol=1e-6)
    # n_c <= k: take-all — first nc lanes are the full list, rest dead
    assert rows[1, 0] == 1 and rows[1, 1] == 2 and rows[1, 2] == -1
    assert w[1, 2] == 0.0
    np.testing.assert_allclose(w[1, :2], 1 / (0.5 * 1.0 * 4), rtol=1e-6)
    # isolated (nc == 0) and padding rows: all lanes dead
    assert (rows[2] == -1).all() and (w[2] == 0).all()
    assert (rows[3] == -1).all() and (w[3] == 0).all()


# ---------------------------------------------------------------------------
# gather kernel parity
# ---------------------------------------------------------------------------

def test_slot_gather_bitwise_parity_interpret():
    rng = np.random.default_rng(0)
    cache = jnp.asarray(
        rng.integers(-8, 8, size=(16, 8)).astype(np.float32))
    lanes = jnp.asarray(rng.integers(-1, 16, size=(5, 4)).astype(np.int32))
    w = jnp.asarray(rng.integers(0, 4, size=(5, 4)).astype(np.float32))
    ref = slot_gather_agg_ref(cache, lanes, w)
    pal = slot_gather_agg_pallas(cache, lanes, w, block_d=8, interpret=True)
    # integer-valued inputs: every product/sum is exactly representable, so
    # the matching accumulation order gives bit-identical results
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal))


def test_gns_sample_agg_impl_parity():
    adj = _toy_adj([[0, 1, 2, 3], [1, 2], [0]], rows=8)
    cache = jnp.asarray(
        np.random.default_rng(1).normal(size=(8, 16)).astype(np.float32))
    dst = jnp.asarray([0, 1, 2, -1], jnp.int32)
    k = 3
    fb_rows = jnp.full((4, k), -1, jnp.int32)
    fb_w = jnp.zeros((4, k), jnp.float32)
    key = jnp.asarray([[5, 6]], jnp.uint32)
    a_ref = gns_sample_agg(adj, cache, dst, fb_rows, fb_w, key,
                           impl="reference")
    a_pal = gns_sample_agg(adj, cache, dst, fb_rows, fb_w, key,
                           impl="pallas", block_d=16)
    np.testing.assert_allclose(np.asarray(a_ref), np.asarray(a_pal),
                               rtol=1e-6, atol=1e-6)


def test_gns_sample_agg_fallback_lanes():
    adj = _toy_adj([[0, 1]], rows=8)
    cache = jnp.asarray(np.eye(8, 4, dtype=np.float32))
    dst = jnp.asarray([-1], jnp.int32)          # uncached destination
    fb_rows = jnp.asarray([[2, 3, -1]], jnp.int32)
    fb_w = jnp.asarray([[0.5, 2.0, 7.0]], jnp.float32)   # dead lane w ignored
    key = jnp.asarray([[1, 2]], jnp.uint32)
    out = np.asarray(gns_sample_agg(adj, cache, dst, fb_rows, fb_w, key,
                                    impl="reference"))
    expect = 0.5 * np.eye(8, 4)[2] + 2.0 * np.eye(8, 4)[3]
    np.testing.assert_allclose(out[0], expect, rtol=1e-6)


# ---------------------------------------------------------------------------
# statistics: marginal parity + unbiasedness
# ---------------------------------------------------------------------------

def _chi2_crit(df):
    """~p=1e-4 upper critical value (normal tail approx, generous)."""
    return df + 4.0 * np.sqrt(2.0 * df) + 4.0


def test_chi_square_marginal_parity_device_vs_host():
    """Device lanes for an n_c > k row are marginally uniform over the
    cached neighbor list — the same marginal the host's without-replacement
    draw has, so expected per-neighbor counts match k/n_c exactly."""
    nc, k, trials = 7, 3, 4000
    adj = _toy_adj([list(range(nc))], rows=8)
    dst = jnp.asarray([0], jnp.int32)
    counts = np.zeros(nc)
    draw = jax.jit(lambda key: draw_lanes(adj, dst, key, k)[0])
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 2 ** 32, size=(trials, 1, 2), dtype=np.uint32)
    for t in range(trials):
        rows = np.asarray(draw(jnp.asarray(keys[t])))[0]
        np.add.at(counts, rows, 1)
    expected = trials * k / nc
    chi2 = float(((counts - expected) ** 2 / expected).sum())
    assert chi2 < _chi2_crit(nc - 1), (chi2, counts)


def test_device_draw_unbiased_both_regimes():
    """Monte-Carlo E[Σ w·f] = Σ_{u∈N_C(v)} f_u/(p^C_u · deg v) — the same
    conditional expectation the host input layer's estimator has."""
    hitp = np.array([0.9, 0.5, 0.7, 0.3, 0.8, 0.6, 0.5, 0.5])
    deg = np.array([9.0, 2.0])
    adj = _toy_adj([[0, 1, 2, 3, 4, 5], [5, 6]], hitp=hitp, deg=deg, rows=8)
    f = np.random.default_rng(3).normal(size=8).astype(np.float32)
    dst = jnp.asarray([0, 1], jnp.int32)
    k, trials = 3, 6000
    est = np.zeros(2)
    draw = jax.jit(lambda key: draw_lanes(adj, dst, key, k))
    keys = np.random.default_rng(1).integers(
        0, 2 ** 32, size=(trials, 1, 2), dtype=np.uint32)
    for t in range(trials):
        rows, w = draw(jnp.asarray(keys[t]))
        rows, w = np.asarray(rows), np.asarray(w)
        est += (np.where(rows >= 0, w * f[np.clip(rows, 0, None)], 0.0)
                .sum(axis=1))
    est /= trials
    want0 = sum(f[u] / (hitp[u] * deg[0]) for u in [0, 1, 2, 3, 4, 5])
    want1 = sum(f[u] / (hitp[u] * deg[1]) for u in [5, 6])
    np.testing.assert_allclose(est[0], want0, rtol=0.05)
    np.testing.assert_allclose(est[1], want1, rtol=1e-5)  # take-all: exact


# ---------------------------------------------------------------------------
# sampler / pipeline integration
# ---------------------------------------------------------------------------

def test_device_batch_shape_and_fallback(ds):
    s = _mk_device(ds, fraction=0.05)      # small cache -> real fallbacks
    mb = s.sample(_targets(ds, 32), np.random.default_rng(1))
    d0 = block_pad_sizes(32, (3, 4, 5))[0][0]
    dev = mb.device
    assert dev.input_cache_slots.shape == (d0,)
    assert dev.input_fb_rows.shape == dev.input_fb_w.shape == (d0, 3)
    assert dev.sample_key.shape == (1, 2)
    real = dev.input_mask > 0
    miss = (dev.input_cache_slots < 0) & real
    assert miss.any(), "tiny cache should miss some inputs"
    # fallback lanes only on uncached real rows; weights pair with live rows
    assert (dev.input_fb_rows[~miss] == -1).all()
    alive = dev.input_fb_rows >= 0
    assert (dev.input_fb_w[alive] > 0).all()
    assert (dev.input_fb_w[~alive] == 0).all()
    # fallback rows index the device table
    tbl_rows = mb.cache_gen.device_adj.table_rows
    assert dev.input_fb_rows[alive].max() < tbl_rows
    # upper-layer blocks keep the host chain; the input block is a
    # placeholder with matching src/dst
    assert dev.blocks[0].num_src == dev.blocks[0].num_dst == d0
    assert dev.blocks[1].num_src == d0


def test_device_vs_host_statistical_parity(ds):
    """The two backends' input-layer estimators agree in expectation: over
    many batches of the same targets, mean Σ_lanes w per cached dst matches
    the analytic Σ_{u∈N_C} 1/(p^C_u·deg) for BOTH, within Monte-Carlo
    noise."""
    cfg = SamplerConfig(fanouts=(3, 4, 5), batch_size=32,
                        cache=CacheConfig(fraction=0.2, period=1))
    host = make_sampler("gns", ds.graph, cfg, ds.features, ds.labels,
                        train_idx=ds.train_idx)
    host.start_epoch(0, np.random.default_rng(0))
    gen = host._gen
    ids = _targets(ds, 32, seed=2)
    cached = ids[gen.state.in_cache[ids]]
    nc = gen.cache_adj.indptr[cached + 1] - gen.cache_adj.indptr[cached]
    cached = cached[nc > 0][:8]
    assert len(cached) >= 2
    k, trials = 3, 800
    rng = np.random.default_rng(5)
    h_sum = np.zeros(len(cached))
    for _ in range(trials):
        _, mask, w = host._sample_layer(cached, k, rng, allow_topup=False)
        h_sum += np.where(mask, w, 0.0).sum(axis=1)
    # device draw on the same generation (shared store contract)
    dev = _toy_adj([[]])   # placeholder; use the real generation's CSR
    from repro.sampling.adjacency import build_device_cache_adj
    dadj = build_device_cache_adj(gen.state, gen.cache_adj,
                                  ds.graph.degrees, lam=gen.lam)
    rows = gen.state.device_rows(gen.state.slot_of[cached])
    dstj = jnp.asarray(rows, jnp.int32)
    draw = jax.jit(lambda key: draw_lanes(dadj, dstj, key, k))
    keys = rng.integers(0, 2 ** 32, size=(trials, 1, 2), dtype=np.uint32)
    d_sum = np.zeros(len(cached))
    for t in range(trials):
        _, w = draw(jnp.asarray(keys[t]))
        d_sum += np.asarray(w).sum(axis=1)
    np.testing.assert_allclose(d_sum / trials, h_sum / trials, rtol=0.08)


def test_generation_swap_safety(ds):
    s = _mk_device(ds)
    rng = np.random.default_rng(2)
    mb = s.sample(_targets(ds, 32), rng)
    v0 = mb.cache_gen.version
    adj0 = mb.cache_gen.device_adj
    tbl0 = mb.cache_gen.table
    s.refresh_cache(rng, version=v0 + 1)           # swap the live generation
    assert s._gen.version == v0 + 1
    # the batch stays pinned: same generation object, same CSR, same table
    assert mb.cache_gen.version == v0
    assert mb.cache_gen.device_adj is adj0
    assert mb.cache_gen.table is tbl0
    # retire() keeps the device CSR (device-resident, still draw-able)
    mb.cache_gen.retire()
    assert mb.cache_gen.device_adj is adj0
    # the pinned pair still evaluates: draw + gather against gen v0
    out = gns_sample_agg(
        adj0, tbl0,
        jnp.asarray(mb.device.input_cache_slots),
        jnp.asarray(mb.device.input_fb_rows),
        jnp.asarray(mb.device.input_fb_w),
        jnp.asarray(mb.device.sample_key), impl="reference")
    assert np.isfinite(np.asarray(out)).all()


def test_epoch_loader_per_batch_rng_reproducible(ds):
    """S1: batch (epoch, i) is a pure function of the seed — prefetch
    interleaving or earlier batches can no longer perturb later draws."""
    def batches(prefetch):
        s = _mk_device(ds)
        loader = EpochLoader(s, ds.train_idx, seed=11, max_batches=4)
        it = loader.epoch(0)
        if prefetch:
            it = Prefetcher(it, depth=2)
        return [(mb.input_node_ids.copy(), mb.device.sample_key.copy(),
                 mb.device.input_fb_rows.copy()) for mb in it]
    a, b_, c = batches(False), batches(False), batches(True)
    for x, y, z in zip(a, b_, c):
        for i in range(3):
            np.testing.assert_array_equal(x[i], y[i])
            np.testing.assert_array_equal(x[i], z[i])


def test_prefetcher_wait_metric():
    meter = TrafficMeter()

    def slow():
        for i in range(3):
            time.sleep(0.05)
            yield i

    waited = list(Prefetcher(slow(), depth=2, meter=meter))
    assert waited == [0, 1, 2]
    p = Prefetcher(slow(), depth=2, meter=meter)
    assert list(p) == [0, 1, 2]
    assert p.wait_s > 0.0
    assert meter.t_prefetch_wait >= p.wait_s
    assert "prefetch_wait_s" in meter.breakdown()


def test_refresh_config_unification():
    """S3: one EngineConfig.refresh hint drives both schedules."""
    from repro.gns.config import EngineConfig, RefreshConfig
    cfg = EngineConfig.preset(
        "quickstart",
        refresh=RefreshConfig(period=3, async_refresh=True, serve_every=5))
    assert cfg.cache_config().period == 3
    assert cfg.cache_config().async_refresh is True
    assert cfg.sampler_config().cache.period == 3
    assert cfg.serve_config().refresh_every == 5
    # round-trips through the JSON-safe dict form
    cfg2 = EngineConfig.from_dict(cfg.to_dict())
    assert cfg2.refresh == cfg.refresh
    assert cfg2.serve_config().refresh_every == 5
    # None hint leaves the sub-configs untouched
    base = EngineConfig.preset("quickstart")
    assert base.cache_config() == base.cache
    assert base.serve_config() == base.serve


def test_device_backend_fit_and_eval(ds):
    import repro.gns as gns
    from repro.gns.config import EngineConfig
    cfg = EngineConfig.preset("quickstart")
    cfg = dataclasses.replace(
        cfg,
        sampling=dataclasses.replace(cfg.sampling, backend="device",
                                     batch_size=32, fanouts=(3, 4, 5)),
    )
    from repro.gns.engine import GNSEngine
    eng = GNSEngine(cfg, dataset=ds)
    assert isinstance(eng.sampler, DeviceGNSSampler)
    rep = eng.fit(epochs=2, max_batches=3)
    assert np.isfinite(rep.losses).all()
    assert rep.losses[-1] < rep.losses[0] + 0.5      # training, not diverging
    acc = eng.evaluate(num_batches=2)
    assert 0.0 <= acc <= 1.0
    d = eng.describe()
    assert d["sampler_backend"] == "device"
    # device backend ships D0 input rows, not D0*(1+k0)
    pads = block_pad_sizes(32, (3, 4, 5))
    assert d["input_rows_per_batch"] == pads[0][0]

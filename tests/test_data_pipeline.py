"""Token pipeline + vocab cache (the GNS-analog LM substrate)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                          # bare env: seeded fallback shim
    from _hypothesis_fallback import given, settings, st

from repro.featurestore import TrafficMeter
from repro.data.tokens import SyntheticCorpus, TokenPipeline
from repro.data.vocab_cache import (VocabCache, VocabCacheConfig,
                                    embed_with_cache, sampled_softmax_loss)


# ---------------------------------------------------------------------------
# token pipeline
# ---------------------------------------------------------------------------

def test_corpus_deterministic_and_host_sharded():
    c = SyntheticCorpus(1000, seed=3)
    a = c.batch(0, 5, batch=8, seq_len=16)
    b = c.batch(0, 5, batch=8, seq_len=16)
    np.testing.assert_array_equal(a, b)
    # host shards are disjoint slices of the same global batch definition
    h0 = c.batch(0, 5, batch=8, seq_len=16, host=0, num_hosts=2)
    h1 = c.batch(0, 5, batch=8, seq_len=16, host=1, num_hosts=2)
    assert h0.shape == h1.shape == (4, 16)
    assert not np.array_equal(h0, h1)


def test_corpus_zipf_skew():
    c = SyntheticCorpus(5000, zipf_a=1.2, seed=0)
    toks = c.batch(0, 0, batch=64, seq_len=256)
    counts = np.bincount(toks.reshape(-1), minlength=5000)
    top = np.sort(counts)[::-1]
    assert top[:50].sum() > 0.35 * counts.sum()     # heavy head


def test_pipeline_resume_matches():
    c = SyntheticCorpus(100, seed=1)
    p = TokenPipeline(c, batch=4, seq_len=8, accum=2)
    full = list(p.epoch(0, steps=5))
    tail = list(p.epoch(0, steps=5, start_step=3))
    assert len(full) == 5 and len(tail) == 2
    np.testing.assert_array_equal(full[3]["tokens"], tail[0]["tokens"])
    assert full[0]["tokens"].shape == (2, 2, 8)     # [accum, B/accum, S]


# ---------------------------------------------------------------------------
# vocab cache
# ---------------------------------------------------------------------------

def _cache(vocab=512, dim=16, frac=0.25, strategy="sampled", seed=0):
    rng = np.random.default_rng(seed)
    table = rng.standard_normal((vocab, dim)).astype(np.float32)
    vc = VocabCache(table, VocabCacheConfig(fraction=frac, strategy=strategy),
                    seed=seed)
    return table, vc


def test_assembly_exact():
    """Cache-hit + streamed assembly reproduces the full-table lookup exactly
    (GNS input layer: h0 = where(slot>=0, cache[slot], streamed))."""
    table, vc = _cache()
    rng = np.random.default_rng(1)
    toks = rng.integers(0, 512, size=(4, 11))
    vc.observe(toks)
    vc.refresh(0)
    batch = vc.assemble(toks)
    out = embed_with_cache(jnp.asarray(vc.table), {
        "slots": jnp.asarray(batch["slots"]),
        "streamed": jnp.asarray(batch["streamed"]),
        "miss_local": jnp.asarray(batch["miss_local"]),
    })
    np.testing.assert_allclose(np.asarray(out), table[toks], rtol=1e-6)


def test_hit_rate_improves_with_skew_and_observation():
    table, vc = _cache(vocab=2000, frac=0.05, strategy="topk")
    c = SyntheticCorpus(2000, zipf_a=1.3, seed=2)
    toks = c.batch(0, 0, batch=32, seq_len=128)
    cold = None
    for it in range(3):
        vc.observe(toks)
        vc.refresh(it)
        hr = vc.hit_rate(toks)
        cold = hr if cold is None else cold
    uniform_hr = 0.05
    assert hr > 4 * uniform_hr, hr       # skew-aware cache beats uniform


def test_streaming_bytes_drop_with_cache(tmp_path):
    """Table 4 analog: streamed bytes shrink when the hot set is cached."""
    table, vc = _cache(vocab=2000, frac=0.10, strategy="topk")
    c = SyntheticCorpus(2000, zipf_a=1.3, seed=4)
    toks = c.batch(0, 1, batch=32, seq_len=128)
    m_nocache = TrafficMeter()
    full_bytes = np.unique(toks).size * table.shape[1] * 4
    vc.observe(toks)
    vc.refresh(0)
    m = TrafficMeter()
    vc.assemble(toks, meter=m)
    assert m.bytes_streamed < 0.6 * full_bytes


@settings(deadline=None, max_examples=20)
@given(st.integers(1, 40))
def test_inclusion_probs_bounds(size_scale):
    _, vc = _cache(vocab=256, frac=size_scale / 40.0)
    ids = np.arange(256)
    p = vc.inclusion_probs(ids)
    assert np.all(p >= 0) and np.all(p <= 1)
    # monotone in the underlying frequency
    vc.freq = np.arange(1, 257, dtype=np.float64)
    vc.probs = vc.freq / vc.freq.sum()
    p2 = vc.inclusion_probs(ids)
    assert p2[-1] >= p2[0]


def test_sampled_softmax_close_to_full():
    """With the cache covering the whole vocab, sampled softmax == full CE."""
    rng = np.random.default_rng(0)
    v, d, t = 64, 8, 32
    table = rng.standard_normal((v, d)).astype(np.float32)
    unembed = rng.standard_normal((v, d)).astype(np.float32)
    hidden = rng.standard_normal((t, d)).astype(np.float32)
    labels = rng.integers(0, v, t)

    # full-coverage cache, inclusion prob 1 -> exact softmax with the
    # positive row counted once in the partition
    neg = jnp.asarray(unembed)
    incl = jnp.ones((v,))
    loss = sampled_softmax_loss(jnp.asarray(hidden), jnp.asarray(labels),
                                jnp.asarray(unembed[labels]), neg, incl)
    logits = hidden @ unembed.T
    logz = np.log(np.exp(logits).sum(1) + np.exp((hidden * unembed[labels]).sum(1)))
    full = (logz - (hidden * unembed[labels]).sum(1)).mean()
    np.testing.assert_allclose(float(loss), full, rtol=1e-5)

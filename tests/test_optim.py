"""Optimizer substrate: AdamW modes, schedules, gradient compression."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                          # bare env: seeded fallback shim
    from _hypothesis_fallback import given, settings, st

from repro.optim.adam import AdamConfig, AdamW, clip_by_global_norm
from repro.optim.compression import (ErrorFeedbackState, compress_int8,
                                     decompress_int8, ef_compress_update)


def _quadratic(dim=8, seed=0):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((dim, dim)))
    a = a @ a.T + dim * jnp.eye(dim)
    b = jnp.asarray(rng.standard_normal(dim))
    return lambda x: 0.5 * x @ a @ x - b @ x, a, b


def test_adamw_converges_quadratic():
    f, a, b = _quadratic()
    opt = AdamW(AdamConfig(lr=5e-2))
    x = {"x": jnp.zeros(8)}
    state = opt.init(x)
    for _ in range(400):
        g = jax.grad(lambda p: f(p["x"]))(x)
        x, state = opt.update(g, state, x)
    target = jnp.linalg.solve(a, b)
    np.testing.assert_allclose(np.asarray(x["x"]), np.asarray(target),
                               atol=1e-2)


def test_bf16_moments_close_to_f32():
    f, _, _ = _quadratic(seed=1)
    results = []
    for mdt in (jnp.float32, jnp.bfloat16):
        opt = AdamW(AdamConfig(lr=5e-2, moment_dtype=mdt))
        x = {"x": jnp.zeros(8)}
        state = opt.init(x)
        for _ in range(300):
            g = jax.grad(lambda p: f(p["x"]))(x)
            x, state = opt.update(g, state, x)
        results.append(float(f(x["x"])))
    assert abs(results[0] - results[1]) < 0.05 * (abs(results[0]) + 1)


def test_clip_by_global_norm():
    g = {"a": jnp.ones(4) * 10.0, "b": jnp.ones(2) * -10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    total = jnp.sqrt(sum(jnp.sum(jnp.square(l))
                         for l in jax.tree_util.tree_leaves(clipped)))
    np.testing.assert_allclose(float(total), 1.0, rtol=1e-5)
    assert float(norm) > 1.0


@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(1e-3, 1e3))
@settings(max_examples=25, deadline=None)
def test_int8_roundtrip_bounded_error(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(64) * scale, jnp.float32)
    q, s = compress_int8(x)
    err = np.abs(np.asarray(decompress_int8(q, s) - x))
    assert err.max() <= float(s) / 2 + 1e-6      # half-ulp of the quant grid


def test_error_feedback_recovers_mean():
    """EF accumulates what quantization drops: the long-run average of the
    decompressed stream matches the true gradient (the convergence
    mechanism behind the 4x all-reduce saving)."""
    rng = np.random.default_rng(0)
    true = {"g": jnp.asarray(rng.standard_normal(32), jnp.float32)}
    ef = ErrorFeedbackState.init(true)
    acc = jnp.zeros_like(true["g"])
    steps = 200
    for _ in range(steps):
        out, ef = ef_compress_update(true, ef)
        acc = acc + out["g"]
    np.testing.assert_allclose(np.asarray(acc / steps), np.asarray(true["g"]),
                               atol=2e-2)

"""§3.2 cache sampling tests."""
import numpy as np
import pytest

from repro.featurestore import (CacheConfig, cache_probs, degree_cache_probs,
                                random_walk_cache_probs, sample_cache)
from repro.graph.generate import powerlaw_graph


@pytest.fixture(scope="module")
def g():
    return powerlaw_graph(5000, avg_degree=10, seed=0)


def test_degree_probs_normalized(g):
    p = degree_cache_probs(g)
    assert np.isclose(p.sum(), 1.0)
    # proportionality to degree
    deg = g.degrees
    i, j = np.argmax(deg), np.argmin(deg)
    assert p[i] / max(p[j], 1e-12) == pytest.approx(deg[i] / max(deg[j], 1e-9), rel=1e-6)


def test_random_walk_probs_mass_near_train(g):
    rng = np.random.default_rng(0)
    train = rng.choice(g.num_nodes, size=50, replace=False)
    p = random_walk_cache_probs(g, train, fanouts=(15, 10, 5))
    assert np.isclose(p.sum(), 1.0)
    # mass concentrates around the training set: the 1-hop neighborhood holds
    # far more probability than its uniform share (walk length is 3, so the
    # mass spreads to ~2 hops — Theorem: reachable-with-high-prob, §3.2 req 2)
    hood = np.array(sorted({v for t in train for v in [t, *g.neighbors(t)]}))
    mass = p[hood].sum()
    uniform_share = len(hood) / g.num_nodes
    assert mass > 3 * uniform_share
    assert mass > 0.2


def test_sample_cache_size_and_uniqueness(g):
    cfg = CacheConfig(fraction=0.01)
    rng = np.random.default_rng(1)
    c = sample_cache(g, cfg, rng)
    assert c.size == cfg.size(g.num_nodes) == 50
    assert len(np.unique(c.node_ids)) == c.size
    assert c.in_cache.sum() == c.size
    # slot map round-trips
    np.testing.assert_array_equal(c.node_ids[c.slot_of[c.node_ids]], c.node_ids)
    assert (c.slot_of[~c.in_cache] == -1).all()


def test_cache_biased_toward_degree(g):
    """Degree-biased cache covers far more edge endpoints than uniform (§3.2)."""
    cfg_deg = CacheConfig(fraction=0.01, strategy="degree")
    cfg_uni = CacheConfig(fraction=0.01, strategy="uniform")
    rng = np.random.default_rng(2)
    cov_deg, cov_uni = [], []
    for t in range(5):
        cd = sample_cache(g, cfg_deg, np.random.default_rng(10 + t))
        cu = sample_cache(g, cfg_uni, np.random.default_rng(20 + t))
        cov_deg.append(cd.in_cache[g.indices].mean())
        cov_uni.append(cu.in_cache[g.indices].mean())
    assert np.mean(cov_deg) > 3 * np.mean(cov_uni)


def test_auto_strategy_switches(g):
    rng = np.random.default_rng(0)
    small_train = rng.choice(g.num_nodes, size=10, replace=False)
    big_train = np.arange(g.num_nodes)
    p_small = cache_probs(g, CacheConfig(strategy="auto"), small_train)
    p_big = cache_probs(g, CacheConfig(strategy="auto"), big_train)
    p_deg = degree_cache_probs(g)
    # big train fraction -> degree distribution
    np.testing.assert_allclose(p_big, p_deg)
    # small train fraction -> random-walk (different from degree)
    assert not np.allclose(p_small, p_deg)


def test_core_cache_shims_are_gone():
    """The PR-4 one-release deprecation shims (`repro.core.cache` /
    `repro.core.device_cache`) served their release and are removed — the
    only import path is `repro.featurestore`."""
    import importlib

    for mod in ("repro.core.cache", "repro.core.device_cache"):
        with pytest.raises(ModuleNotFoundError):
            importlib.import_module(mod)

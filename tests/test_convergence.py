"""Paper Fig. 3 / Table 3 analog: GNS converges like NS at matched settings.

Scaled to the container: tiny SBM dataset, few epochs.  The claims we verify:
  * both NS and GNS reach good accuracy (the task is learnable),
  * GNS accuracy is within a few points of NS (paper: 78.01 vs 78.44 etc.),
  * GNS streams far fewer bytes than NS (the systems win).
"""
import numpy as np
import pytest

from repro.featurestore import CacheConfig
from repro.core.sampler import SamplerConfig
from repro.graph.datasets import get_dataset
from repro.train.trainer import GNNTrainer


@pytest.mark.slow
def test_gns_matches_ns_accuracy():
    ds = get_dataset("tiny", seed=1)
    results = {}
    for name in ["ns", "gns"]:
        scfg = SamplerConfig(fanouts=(5, 10, 15), batch_size=128,
                             cache=CacheConfig(fraction=0.05, period=1))
        tr = GNNTrainer(ds, name, sampler_cfg=scfg, seed=0)
        tr.train(epochs=4, max_batches=7)
        acc = tr.evaluate(ds.val_idx, num_batches=4)
        results[name] = (acc, tr.meter.bytes_streamed)
    acc_ns, bytes_ns = results["ns"]
    acc_gns, bytes_gns = results["gns"]
    assert acc_ns > 0.55, f"NS failed to learn: {acc_ns}"
    assert acc_gns > acc_ns - 0.07, f"GNS {acc_gns} vs NS {acc_ns}"
    # the systems claim: much less host->device feature traffic.  At this
    # 2k-node scale the reduction is graph-size-limited (~0.65x); the paper's
    # 4-6x shows up at larger scale (benchmarks/bench_input_nodes.py sweeps).
    assert bytes_gns < 0.7 * bytes_ns, (bytes_gns, bytes_ns)


@pytest.mark.slow
def test_gns_convergence_tracks_full_neighbor_baseline():
    """Convergence REGRESSION pin (paper Fig. 3: GNS converges like exact
    training): GNS training loss must track the *full-neighbor* baseline —
    NS with fanouts >= max degree, i.e. exact mean aggregation with zero
    sampling noise — within a pinned gap after N epochs.  Nothing else in
    the suite guards against a sampler/cache/placement change silently
    degrading convergence while keeping single-batch math 'correct'.

    Pinned numbers (fully seeded; margins ~5x the observed values so only a
    genuine regression trips them): observed final-gap ~0.06 and GNS
    end-loss ~0.22 at this config.
    """
    ds = get_dataset("tiny", scale=0.5, seed=3)
    max_deg = int(ds.graph.degrees.max())
    epochs, batches = 6, 8

    full_cfg = SamplerConfig(fanouts=(max_deg, max_deg), batch_size=32)
    tr_full = GNNTrainer(ds, "ns", sampler_cfg=full_cfg, seed=0)
    rep_full = tr_full.train(epochs=epochs, max_batches=batches)

    gns_cfg = SamplerConfig(fanouts=(8, 12), batch_size=32,
                            cache=CacheConfig(fraction=0.1, period=1))
    tr_gns = GNNTrainer(ds, "gns", sampler_cfg=gns_cfg, seed=0)
    rep_gns = tr_gns.train(epochs=epochs, max_batches=batches)

    # end-of-training gap, averaged over the last two epochs to damp
    # single-epoch sampling noise
    end_full = float(np.mean(rep_full.losses[-2:]))
    end_gns = float(np.mean(rep_gns.losses[-2:]))
    assert end_gns - end_full < 0.4, (rep_gns.losses, rep_full.losses)
    # and GNS must actually have converged, not merely matched a broken
    # baseline (full-neighbor end-loss ~0.06 here)
    assert end_full < 0.3, rep_full.losses
    assert end_gns < 0.6, rep_gns.losses
    # monotone-ish trajectory: the loss must have dropped by >5x overall
    assert rep_gns.losses[-1] < rep_gns.losses[0] / 5, rep_gns.losses

"""Paper Fig. 3 / Table 3 analog: GNS converges like NS at matched settings.

Scaled to the container: tiny SBM dataset, few epochs.  The claims we verify:
  * both NS and GNS reach good accuracy (the task is learnable),
  * GNS accuracy is within a few points of NS (paper: 78.01 vs 78.44 etc.),
  * GNS streams far fewer bytes than NS (the systems win).
"""
import numpy as np
import pytest

from repro.core.cache import CacheConfig
from repro.core.sampler import SamplerConfig
from repro.graph.datasets import get_dataset
from repro.train.trainer import GNNTrainer


@pytest.mark.slow
def test_gns_matches_ns_accuracy():
    ds = get_dataset("tiny", seed=1)
    results = {}
    for name in ["ns", "gns"]:
        scfg = SamplerConfig(fanouts=(5, 10, 15), batch_size=128,
                             cache=CacheConfig(fraction=0.05, period=1))
        tr = GNNTrainer(ds, name, sampler_cfg=scfg, seed=0)
        tr.train(epochs=4, max_batches=7)
        acc = tr.evaluate(ds.val_idx, num_batches=4)
        results[name] = (acc, tr.meter.bytes_streamed)
    acc_ns, bytes_ns = results["ns"]
    acc_gns, bytes_gns = results["gns"]
    assert acc_ns > 0.55, f"NS failed to learn: {acc_ns}"
    assert acc_gns > acc_ns - 0.07, f"GNS {acc_gns} vs NS {acc_ns}"
    # the systems claim: much less host->device feature traffic.  At this
    # 2k-node scale the reduction is graph-size-limited (~0.65x); the paper's
    # 4-6x shows up at larger scale (benchmarks/bench_input_nodes.py sweeps).
    assert bytes_gns < 0.7 * bytes_ns, (bytes_gns, bytes_ns)

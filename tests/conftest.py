"""Suite-wide test wiring: the gnscheck runtime lock sanitizer.

Armed BEFORE any repro class is instantiated (locks are wrapped in
ownership-tracking proxies at assignment time, i.e. inside ``__init__``):
every test in the suite then runs with

* unguarded writes to ``@guarded_by`` attributes raising
  :class:`~repro.analysis.LockDisciplineError` at the faulting line, and
* the global lock-acquisition order recorded, so the first A->B / B->A
  inversion anywhere in the suite raises
  :class:`~repro.analysis.LockOrderError` deterministically

— the PR-5 ``begin_refresh``/``wait_refresh`` race class as a plain test
failure instead of a stress-test lottery.
"""
import os

os.environ.setdefault("REPRO_LOCK_SANITIZER", "1")

from repro.analysis import enable_sanitizer  # noqa: E402

enable_sanitizer(True)

"""Graph substrate tests: CSR, generators, partitioning."""
import numpy as np
import pytest

from repro.graph.csr import CSRGraph
from repro.graph.generate import powerlaw_graph, sbm_graph, node_features_from_labels
from repro.graph.datasets import get_dataset
from repro.graph.partition import hash_partition


def test_csr_from_edges_basic():
    src = np.array([0, 1, 2, 2])
    dst = np.array([1, 2, 0, 3])
    g = CSRGraph.from_edges(src, dst, 4)
    assert g.num_nodes == 4
    # symmetrized + deduped
    assert set(g.neighbors(2).tolist()) == {0, 1, 3}
    assert set(g.neighbors(0).tolist()) == {1, 2}
    assert g.degrees.sum() == g.num_edges


def test_csr_no_self_loops():
    g = CSRGraph.from_edges(np.array([0, 1, 1]), np.array([0, 1, 2]), 3)
    for v in range(3):
        assert v not in g.neighbors(v)


def test_powerlaw_degree_tail():
    g = powerlaw_graph(20_000, avg_degree=10, seed=1)
    deg = g.degrees
    assert 5 <= deg.mean() <= 20
    # heavy tail: max degree far above mean
    assert deg.max() > 10 * deg.mean()


def test_sample_neighbors_small_degree_full():
    g = CSRGraph.from_edges(np.array([0, 0]), np.array([1, 2]), 4)
    rng = np.random.default_rng(0)
    nbrs, mask = g.sample_neighbors(np.array([0, 3]), k=5, rng=rng)
    assert mask[0].sum() == 2 and set(nbrs[0][mask[0]].tolist()) == {1, 2}
    assert mask[1].sum() == 0  # isolated node


def test_sample_neighbors_no_replacement():
    # star: node 0 connected to 1..20
    src = np.zeros(20, dtype=np.int64)
    dst = np.arange(1, 21)
    g = CSRGraph.from_edges(src, dst, 21)
    rng = np.random.default_rng(0)
    for _ in range(10):
        nbrs, mask = g.sample_neighbors(np.array([0]), k=10, rng=rng)
        picked = nbrs[0][mask[0]]
        assert len(picked) == 10
        assert len(np.unique(picked)) == 10  # distinct


def test_sample_neighbors_uniformity():
    src = np.zeros(8, dtype=np.int64)
    dst = np.arange(1, 9)
    g = CSRGraph.from_edges(src, dst, 9)
    rng = np.random.default_rng(0)
    counts = np.zeros(9)
    for _ in range(2000):
        nbrs, mask = g.sample_neighbors(np.array([0]), k=2, rng=rng)
        for x in nbrs[0][mask[0]]:
            counts[x] += 1
    freq = counts[1:] / counts[1:].sum()
    assert np.allclose(freq, 1 / 8, atol=0.02)


def test_induced_cache_adjacency():
    g = powerlaw_graph(2000, avg_degree=8, seed=2)
    rng = np.random.default_rng(0)
    cache_mask = rng.random(2000) < 0.1
    s = g.induced_cache_adjacency(cache_mask)
    assert s.num_nodes == g.num_nodes
    for v in rng.integers(0, 2000, size=50):
        expected = sorted(u for u in g.neighbors(v) if cache_mask[u])
        assert sorted(s.neighbors(v).tolist()) == expected


def test_sbm_homophily():
    g, labels = sbm_graph(5000, num_blocks=8, avg_degree=10, p_in=0.8, seed=3)
    src = np.repeat(np.arange(g.num_nodes), g.degrees)
    same = (labels[src] == labels[g.indices]).mean()
    assert same > 0.5  # strongly assortative vs 1/8 baseline


def test_features_class_separated():
    labels = np.random.default_rng(0).integers(0, 4, size=1000).astype(np.int32)
    x = node_features_from_labels(labels, 16, noise=0.1, seed=0)
    # class means well separated at low noise
    mus = np.stack([x[labels == c].mean(0) for c in range(4)])
    d = np.linalg.norm(mus[0] - mus[1])
    assert d > 1.0


def test_dataset_splits_disjoint():
    ds = get_dataset("tiny", seed=0)
    all_idx = np.concatenate([ds.train_idx, ds.val_idx, ds.test_idx])
    assert len(np.unique(all_idx)) == len(all_idx)
    assert ds.features.shape == (ds.graph.num_nodes, 32)


def test_hash_partition_covers_graph():
    g = powerlaw_graph(3000, avg_degree=6, seed=4)
    parts = hash_partition(g, 4)
    total_owned = sum(p.num_owned for p in parts)
    assert total_owned == g.num_nodes
    # per-part CSR matches global rows
    p = parts[1]
    for i in [0, 5, len(p.owned) - 1]:
        v = p.owned[i]
        local = p.local_indices[p.local_indptr[i]:p.local_indptr[i + 1]]
        np.testing.assert_array_equal(local, g.neighbors(v))

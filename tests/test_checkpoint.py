"""Checkpoint store: atomicity, keep-N, resume, reshard-on-load API."""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.checkpoint.store import latest_step


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"layers": [{"w": jax.random.normal(k, (4, 8)),
                        "b": jnp.zeros((8,))}],
            "step_scale": jnp.float32(1.5)}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 7, t, extra={"epoch": 3})
    loaded, step, extra = load_checkpoint(tmp_path, t)
    assert step == 7 and extra == {"epoch": 3}
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_n_and_latest(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(tmp_path, s, t, keep=2)
    steps = sorted(p.name for p in tmp_path.iterdir() if p.name.startswith("step_"))
    assert steps == ["step_00000004", "step_00000005"]
    assert latest_step(tmp_path) == 5


def test_partial_write_is_invisible(tmp_path):
    """A crash mid-write (simulated leftover tmp dir) must not be loadable."""
    t = _tree()
    save_checkpoint(tmp_path, 1, t)
    junk = tmp_path / ".step_9_partial"
    junk.mkdir()
    (junk / "arrays.npz").write_bytes(b"corrupt")
    assert latest_step(tmp_path) == 1          # tmp dirs are never candidates
    _, step, _ = load_checkpoint(tmp_path, t)
    assert step == 1


def test_shape_mismatch_rejected(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 1, t)
    bad = {"layers": [{"w": jnp.zeros((5, 8)), "b": jnp.zeros((8,))}],
           "step_scale": jnp.float32(0.0)}
    with pytest.raises(AssertionError):
        load_checkpoint(tmp_path, bad)


def test_manager_restore_or_init(tmp_path):
    mgr = CheckpointManager(tmp_path, every=2, keep=3)
    t = _tree()
    assert mgr.maybe_save(1, t) is None        # not on cadence
    assert mgr.maybe_save(2, t) is not None
    t2, step, _ = mgr.restore_or_init(_tree(seed=1))
    assert step == 2
    np.testing.assert_array_equal(np.asarray(t2["layers"][0]["w"]),
                                  np.asarray(t["layers"][0]["w"]))


def test_train_loop_resume_bitexact(tmp_path):
    """Kill-and-restart: resumed run reproduces the uninterrupted loss path
    (checkpoint + deterministic pipeline = the fault-tolerance contract)."""
    from repro.configs import get_config
    from repro.launch.train import train_loop

    cfg = get_config("xlstm-125m").reduced()
    full = train_loop(cfg, steps=6, batch=4, seq_len=16, log_every=0,
                      ckpt_dir=str(tmp_path / "a"), ckpt_every=3)
    # interrupted run: 4 steps (checkpoint lands at step 3), then resume
    part = train_loop(cfg, steps=4, batch=4, seq_len=16, log_every=0,
                      ckpt_dir=str(tmp_path / "b"), ckpt_every=3)
    resumed = train_loop(cfg, steps=6, batch=4, seq_len=16, log_every=0,
                         ckpt_dir=str(tmp_path / "b"), ckpt_every=3,
                         resume=True)
    assert resumed.resumed_from == 3
    np.testing.assert_allclose(resumed.losses, full.losses[3:], rtol=1e-5)

"""FairScheduler invariants (property-style) + fabric-level isolation.

The scheduler is engine-free, so the stride-scheduling guarantees are
driven with plain integer items across randomized tenant counts, weights
and loads:

* work conservation — everything admitted is popped exactly once;
* FIFO within a tenant — one tenant's requests never reorder;
* weight-proportional share — under saturation, throughput converges to
  the weight ratio (stride scheduling's O(1) per-tenant error);
* no starvation — any positive-weight tenant is served at least once
  every ~ceil(W/w) pops while backlogged;
* quota isolation — a flooding tenant is refused at ITS quota while other
  tenants' admissions are untouched;
* rejoin rule — an idle tenant cannot hoard credit and monopolize the
  worker when it comes back.

The last test closes the loop on a real (meshless) engine: a two-worker
:class:`~repro.serve.ServeFabric` with a flooding tenant and a quiet
tenant — the flood eats its own QueueFull, the quiet tenant's requests
are all admitted and served.
"""
import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                          # pragma: no cover
    from _hypothesis_fallback import given, settings, st

from repro.serve import FairScheduler, QueueFull, UnknownTenant
from repro.gns.config import TenantConfig

WEIGHTS = st.lists(st.floats(0.5, 8.0), min_size=2, max_size=5)
LOADS = st.lists(st.integers(1, 40), min_size=2, max_size=5)


def _names(n):
    return [f"t{i}" for i in range(n)]


def _mk(weights, quota=10_000):
    return FairScheduler(
        [TenantConfig(n, weight=w, max_queue=quota)
         for n, w in zip(_names(len(weights)), weights)])


# ---------------------------------------------------------------------------
# properties
# ---------------------------------------------------------------------------

@settings(max_examples=25)
@given(weights=WEIGHTS, loads=LOADS)
def test_work_conservation_and_fifo(weights, loads):
    loads = (loads * len(weights))[: len(weights)]   # one load per tenant
    sched = _mk(weights)
    offered = {n: [] for n in _names(len(weights))}
    for step in range(max(loads)):
        for name, load in zip(offered, loads):
            if step < load:
                assert sched.offer(name, (name, step))
                offered[name].append((name, step))
    popped = {n: [] for n in offered}
    while True:
        nxt = sched.pop()
        if nxt is None:
            break
        name, item = nxt
        assert item[0] == name               # items never cross tenants
        popped[name].append(item)
    for name in offered:
        assert popped[name] == offered[name]  # conservation AND FIFO
    assert sched.qsize() == 0


@settings(max_examples=25)
@given(weights=WEIGHTS)
def test_weight_proportional_share_under_saturation(weights):
    sched = _mk(weights)
    names = _names(len(weights))
    per_tenant = 300
    for name in names:
        for i in range(per_tenant):
            sched.offer(name, i)
    total_w = sum(weights)
    pops = 200                               # << per_tenant: stays saturated
    counts = {n: 0 for n in names}
    for _ in range(pops):
        name, _item = sched.pop()
        counts[name] += 1
    for name, w in zip(names, weights):
        expected = pops * w / total_w
        # stride scheduling's per-tenant error is O(1) dispatches; allow a
        # small constant slop scaled by the worst weight ratio
        slop = 2.0 + max(weights) / min(weights)
        assert abs(counts[name] - expected) <= slop, (
            name, counts[name], expected, weights)


@settings(max_examples=25)
@given(weights=WEIGHTS)
def test_no_starvation(weights):
    sched = _mk(weights)
    names = _names(len(weights))
    pops = 150
    for name in names:
        for i in range(pops):                # everyone stays backlogged
            sched.offer(name, i)
    total_w = sum(weights)
    last_seen = {n: -1 for n in names}
    for k in range(pops):
        name, _ = sched.pop()
        last_seen[name] = k
        for other, w in zip(names, weights):
            bound = math.ceil(total_w / w) + len(names)
            assert k - last_seen[other] <= bound, (
                f"{other} (weight {w}) starved for {k - last_seen[other]} "
                f"pops (bound {bound})")


@settings(max_examples=25)
@given(quota=st.integers(1, 8), flood=st.integers(9, 60))
def test_quota_isolates_admission(quota, flood):
    sched = FairScheduler([TenantConfig("flood", max_queue=quota),
                           TenantConfig("quiet", max_queue=quota)])
    accepted = sum(sched.offer("flood", i) for i in range(flood))
    assert accepted == quota                 # the flood hits ITS bound
    for i in range(quota):                   # ... and quiet is untouched
        assert sched.offer("quiet", i)
    # under the flood, quiet still gets its fair share of service
    quiet_served = sum(1 for _ in range(2 * quota)
                       if sched.pop()[0] == "quiet")
    assert quiet_served >= quota - 1


@settings(max_examples=25)
@given(idle_pops=st.integers(5, 60), burst=st.integers(2, 20))
def test_rejoin_after_idle_hoards_no_credit(idle_pops, burst):
    sched = _mk([1.0, 1.0])                  # equal weights: fair = alternate
    for i in range(idle_pops + burst + 5):
        sched.offer("t0", i)
    for _ in range(idle_pops):               # t1 idle while t0 dispatches
        assert sched.pop()[0] == "t0"
    for i in range(burst):
        sched.offer("t1", i)
    lead = 0
    for _ in range(2 * burst):
        name, _ = sched.pop()
        lead += 1 if name == "t1" else -1
        # without the rejoin rule t1's pass would lag vtime by idle_pops
        # strides and it would burst-monopolize; with it, equal weights
        # never let it lead by more than a couple of dispatches
        assert lead <= 2, (lead, idle_pops, burst)


# ---------------------------------------------------------------------------
# deterministic edges
# ---------------------------------------------------------------------------

def test_unknown_tenant_without_auto_register():
    sched = FairScheduler([TenantConfig("a")], auto_register=False)
    with pytest.raises(UnknownTenant):
        sched.offer("ghost", 1)
    assert sched.offer("a", 1)


def test_push_front_preserves_fifo():
    sched = _mk([1.0])
    for i in range(3):
        sched.offer("t0", i)
    name, item = sched.pop()
    assert item == 0
    sched.push_front("t0", item)             # batcher refused it
    assert [sched.pop()[1] for _ in range(3)] == [0, 1, 2]


def test_invalid_weight_rejected():
    with pytest.raises(ValueError):
        FairScheduler([TenantConfig("bad", weight=0.0)])


def test_drain_and_depths():
    sched = _mk([1.0, 2.0])
    sched.offer("t0", 1)
    sched.offer("t1", 2)
    sched.offer("t1", 3)
    assert sched.depths() == {"t0": 1, "t1": 2}
    assert sorted(sched.drain()) == [("t0", 1), ("t1", 2), ("t1", 3)]
    assert sched.qsize() == 0 and sched.pop() is None


# ---------------------------------------------------------------------------
# fabric-level isolation (real engine, meshless)
# ---------------------------------------------------------------------------

def test_fabric_isolates_tenants_end_to_end():
    from repro.gns import EngineConfig, FabricConfig, GNSEngine, TenantConfig
    eng = GNSEngine(EngineConfig.preset("quickstart"))
    fab = eng.serve_fabric(FabricConfig(
        workers=2,
        tenants=(TenantConfig("flood", weight=1.0, max_queue=3),
                 TenantConfig("quiet", weight=1.0, max_queue=64))))
    rng = np.random.default_rng(7)
    n_nodes = eng.ds.graph.num_nodes
    flood_rejects = 0
    quiet_futs = []
    with fab:
        for _ in range(120):
            try:
                fab.submit(rng.integers(0, n_nodes, size=4), tenant="flood")
            except QueueFull:
                flood_rejects += 1
        for _ in range(10):
            quiet_futs.append(
                fab.submit(rng.integers(0, n_nodes, size=4), tenant="quiet"))
        results = [f.result(timeout=60) for f in quiet_futs]
    assert flood_rejects > 0                 # the flood hit its own quota
    assert all(r.status == "ok" for r in results)
    snap = fab.meter.snapshot()
    assert snap["tenants"]["quiet"]["rejected"] == 0
    assert snap["tenants"]["quiet"]["served"] == 10
    assert snap["tenants"]["flood"]["rejected"] == flood_rejects
    # the flood's shed requests are ITS problem: quiet saw no rejection and
    # every quiet request completed with logits of the right shape
    assert results[0].logits.shape[1] == eng.ds.num_classes

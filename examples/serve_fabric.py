"""Multi-tenant fabric quickstart: a worker fleet over one shared cache.

Fits a small GNS engine, then serves two tenants with very different
contracts through :class:`~repro.serve.ServeFabric`:

* ``mobile`` — latency-sensitive, weight 2.0, small per-tenant queue;
* ``batch``  — throughput traffic, weight 1.0, deep queue, oversubscribed
  on purpose so it sheds (``QueueFull``) at ITS OWN quota.

Each worker runs a weighted-fair stride scheduler feeding the same
size-bucketed micro-batcher `GNSServer` uses, so the fleet inherits the
zero-recompilation serving path while adding tenant isolation, routing,
and failover on top.  Midway through the stream one worker is killed to
show the watchdog reclaiming its in-flight requests onto the survivor.
Prints the per-tenant latency/shed breakdown at the end.

Run:  PYTHONPATH=src python examples/serve_fabric.py [--requests 200]
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core.sampler import SamplerConfig
from repro.featurestore import CacheConfig
from repro.gns import (EngineConfig, FabricConfig, GNSEngine, ServeConfig,
                       TenantConfig)
from repro.gns.config import DataConfig
from repro.serve import QueueFull


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--kill-worker", action="store_true",
                    help="kill worker 0 mid-stream to exercise failover")
    args = ap.parse_args()

    cfg = EngineConfig(
        sampler="gns",
        data=DataConfig(name="ogbn-products", scale=args.scale),
        sampling=SamplerConfig(batch_size=128, fanouts=(5, 10)),
        cache=CacheConfig(fraction=0.05, strategy="adaptive"),
        serve=ServeConfig(buckets=(16, 64), max_wait_ms=2.0))
    engine = GNSEngine(cfg)
    print(f"fitting on {engine.ds.graph.num_nodes:,} nodes ...")
    engine.fit(1, max_batches=20)

    fab = engine.serve_fabric(FabricConfig(
        workers=args.workers,
        tenants=(
            TenantConfig("mobile", weight=2.0, max_queue=args.requests + 8),
            # oversubscribed on purpose: sheds at its own quota
            TenantConfig("batch", weight=1.0, max_queue=16))))

    rng = np.random.default_rng(0)
    pool = engine.ds.val_idx
    futs, shed = [], 0
    print(f"serving {args.requests} mobile + {args.requests} batch requests "
          f"across {args.workers} workers ...")
    with fab:
        for i in range(args.requests):
            ids = rng.choice(pool, size=int(rng.integers(2, 10)),
                             replace=False)
            futs.append(fab.submit(ids, tenant="mobile"))
            try:
                fab.submit(rng.choice(pool, size=4), tenant="batch")
            except QueueFull:
                shed += 1                     # batch's problem, not mobile's
            if args.kill_worker and i == args.requests // 2:
                fab.workers[0].kill()
                print("killed worker 0 — watchdog re-routes its queue "
                      "and reclaims in-flight requests ...")
        for f in futs:
            r = f.result(timeout=600)
            assert r.status == "ok" and np.isfinite(r.logits).all()

    snap = fab.meter.snapshot()
    t = snap["tenants"]
    print(f"served {snap['served']}/{snap['submitted']} in "
          f"{snap['batches']} micro-batches "
          f"(fill {snap['fill_fraction']:.0%}, shed {shed} batch requests)")
    for name in ("mobile", "batch"):
        ts = t[name]
        print(f"  {name:>6}: served {ts['served']:>4}  "
              f"rejected {ts['rejected']:>4}  "
              f"p50/p99 {ts['total_p50_ms']}/{ts['total_p99_ms']} ms")
    if args.kill_worker:
        rt = snap["routing"]
        print(f"failovers {rt['failovers']}, retries {rt['retries']}, "
              f"healthy workers at exit: {sorted(fab.healthy())}")
    assert t["mobile"]["rejected"] == 0       # isolation: mobile never shed


if __name__ == "__main__":
    main()

"""GNS applied to LM embedding tables: the hot-vocab cache demo.

The paper's mechanism (frequency-biased device cache + streamed misses +
periodic refresh) on the LM substrate: a Zipf token stream against a
large-vocab embedding table kept in host memory.  Prints hit rate and
host->device byte savings per refresh period, the LM analog of paper
Tables 4/6.

Run:  PYTHONPATH=src python examples/vocab_cache_demo.py \
          [--vocab 152064] [--frac 0.01]
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.featurestore import TrafficMeter
from repro.data.tokens import SyntheticCorpus
from repro.data.vocab_cache import VocabCache, VocabCacheConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--vocab", type=int, default=152064)   # qwen2-7b vocab
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--frac", type=float, default=0.01)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--zipf", type=float, default=1.2)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    table = rng.standard_normal((args.vocab, args.dim)).astype(np.float32)
    corpus = SyntheticCorpus(args.vocab, zipf_a=args.zipf, seed=1)

    for strategy in ("topk", "sampled"):
        vc = VocabCache(table, VocabCacheConfig(fraction=args.frac,
                                                strategy=strategy))
        meter = TrafficMeter()
        nocache_bytes = 0
        hits = []
        for step in range(args.steps):
            toks = corpus.batch(0, step, batch=16, seq_len=512)
            vc.observe(toks)
            if step % 5 == 0:                       # periodic refresh (P=5)
                vc.refresh(step, meter)
            vc.assemble(toks, meter)
            hits.append(vc.hit_rate(toks))
            nocache_bytes += np.unique(toks).size * args.dim * 4
        saved = 1 - meter.bytes_streamed / nocache_bytes
        print(f"[{strategy:>7}] cache {args.frac:.1%} of vocab "
              f"({vc.size:,} rows): hit rate {np.mean(hits[5:]):.1%}, "
              f"streamed {meter.bytes_streamed/1e6:.1f} MB vs "
              f"{nocache_bytes/1e6:.1f} MB uncached "
              f"({saved:.1%} saved; cache fills "
              f"{meter.bytes_cache_fill/1e6:.1f} MB)")


if __name__ == "__main__":
    main()

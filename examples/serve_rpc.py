"""Cross-host serving quickstart: endpoint replicas over localhost TCP.

The deployment shape this demonstrates (one process per box in production;
here everything runs on localhost so the example is self-contained):

* N ``WorkerEndpoint`` processes, each hosting a full engine replica —
  its own feature-store cache, compiled inference buckets, and fair
  scheduler — started with::

      python -m repro.rpc.endpoint --config engine.json --index 0 --port 7001

* ONE coordinator that connects a :class:`~repro.serve.ServeFabric` with
  ``transport="tcp"`` to those endpoints.  Clients talk to the coordinator
  exactly as they would to an in-process fabric — routing, tenancy,
  heartbeat liveness, and failover all ride the same code path, just with
  :class:`~repro.rpc.RemoteWorkerProxy` in place of a worker thread.

By default this script spawns the endpoints as REAL subprocesses (the
honest cross-host rehearsal: separate interpreters, separate caches, bytes
on a socket).  ``--in-thread`` serves them on threads instead, which is
faster to start when you just want to see the API.  ``--kill-endpoint``
SIGKILLs endpoint 0 mid-stream to show lossless failover onto the
survivor.

Run:  PYTHONPATH=src python examples/serve_rpc.py [--requests 100]
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import tempfile
import time

import numpy as np

from repro.core.sampler import SamplerConfig
from repro.featurestore import CacheConfig
from repro.gns import (EngineConfig, FabricConfig, GNSEngine, ServeConfig,
                       TenantConfig)
from repro.gns.config import DataConfig


def _engine_config(scale: float) -> EngineConfig:
    return EngineConfig(
        sampler="gns",
        data=DataConfig(name="ogbn-products", scale=scale),
        sampling=SamplerConfig(batch_size=128, fanouts=(5, 10)),
        cache=CacheConfig(fraction=0.05, strategy="adaptive"),
        serve=ServeConfig(buckets=(16, 64), max_wait_ms=2.0))


def _spawn_subprocess_endpoints(cfg: EngineConfig, n: int):
    """One ``python -m repro.rpc.endpoint`` process per replica."""
    fd, cfg_path = tempfile.mkstemp(suffix=".json")
    with os.fdopen(fd, "w") as f:
        json.dump(cfg.to_dict(), f)
    procs, ports = [], []
    for i in range(n):
        procs.append(subprocess.Popen(
            [sys.executable, "-m", "repro.rpc.endpoint",
             "--config", cfg_path, "--index", str(i), "--port", "0"],
            env=dict(os.environ, PYTHONPATH="src"),
            stdout=subprocess.PIPE, text=True))
    for p in procs:
        line = p.stdout.readline()           # blocks until the replica is up
        assert "GNS_ENDPOINT_READY" in line, line
        ports.append(int(dict(kv.split("=")
                              for kv in line.split()[1:])["port"]))
        print(f"  endpoint up: pid={p.pid} port={ports[-1]}")
    return procs, ports, cfg_path


def _spawn_thread_endpoints(cfg: EngineConfig, n: int):
    from repro.rpc import WorkerEndpoint
    eps = []
    for i in range(n):
        ep = WorkerEndpoint(GNSEngine(cfg), index=i)
        ep.serve_in_thread()
        eps.append(ep)
        print(f"  endpoint up (thread): port={ep.port}")
    return eps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=100)
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--endpoints", type=int, default=2)
    ap.add_argument("--in-thread", action="store_true",
                    help="serve endpoints on threads instead of subprocesses")
    ap.add_argument("--kill-endpoint", action="store_true",
                    help="SIGKILL endpoint 0 mid-stream (subprocess mode)")
    args = ap.parse_args()

    cfg = _engine_config(args.scale)
    print(f"starting {args.endpoints} endpoint replicas ...")
    procs, eps = [], []
    if args.in_thread:
        eps = _spawn_thread_endpoints(cfg, args.endpoints)
        ports = [ep.port for ep in eps]
    else:
        procs, ports, _ = _spawn_subprocess_endpoints(cfg, args.endpoints)

    try:
        coordinator = GNSEngine(cfg)
        fab = coordinator.serve_fabric(FabricConfig(
            workers=args.endpoints, transport="tcp",
            endpoints=tuple(f"127.0.0.1:{p}" for p in ports),
            tenants=(TenantConfig("mobile", weight=2.0,
                                  max_queue=args.requests + 8),
                     TenantConfig("batch", weight=1.0,
                                  max_queue=args.requests + 8))))

        rng = np.random.default_rng(0)
        pool = coordinator.ds.val_idx
        print(f"serving {args.requests} requests over TCP ...")
        with fab:
            futs = []
            for i in range(args.requests):
                ids = rng.choice(pool, size=int(rng.integers(2, 10)),
                                 replace=False)
                futs.append(fab.submit(
                    ids, tenant="mobile" if i % 2 == 0 else "batch"))
                if (args.kill_endpoint and procs
                        and i == args.requests // 2):
                    os.kill(procs[0].pid, signal.SIGKILL)
                    print("SIGKILLed endpoint 0 — the heartbeat lapses, the "
                          "watchdog reclaims its in-flight requests, and "
                          "the survivor re-serves them ...")
            for f in futs:
                r = f.result(timeout=600)
                assert r.status == "ok" and np.isfinite(r.logits).all()
            remote = fab.pull_remote_stats(timeout=30.0)
            snap = fab.snapshot()

        print(f"served {args.requests}/{args.requests}; wire bytes "
              f"tx={snap['rpc']['bytes_rpc_tx']:,} "
              f"rx={snap['rpc']['bytes_rpc_rx']:,}")
        for idx, stats in sorted(remote.items()):
            c = stats["counters"]
            print(f"  endpoint {idx}: served {c['served']:>4}  "
                  f"rx {c['bytes_rpc_rx']:>9,}B  tx {c['bytes_rpc_tx']:>9,}B")
        if args.kill_endpoint:
            rt = snap["routing"]
            print(f"failovers {rt['failovers']}, retries {rt['retries']}, "
                  f"healthy at exit: {fab.healthy()}")
    finally:
        for ep in eps:
            ep.stop()
        for p in procs:
            if p.poll() is None:
                p.kill()
            p.wait(timeout=30)


if __name__ == "__main__":
    main()

"""Quickstart: GNS vs node-wise sampling on a synthetic power-law graph.

Reproduces the paper's core claim at laptop scale in ~a minute: GNS reaches
the same F1 as NS while moving far fewer feature bytes host->device and
far fewer distinct input nodes per minibatch (paper Tables 3 & 4).

Run:  PYTHONPATH=src python examples/quickstart.py [--epochs 3]
"""
from __future__ import annotations

import argparse

from repro.core.cache import CacheConfig
from repro.core.sampler import SamplerConfig
from repro.graph.datasets import get_dataset
from repro.train.trainer import GNNTrainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=2)
    # Table-4 regime: sample tree (batch x prod(fanouts)) << |V|, power-law
    # hubs intact — see EXPERIMENTS.md §Repro regime note.
    ap.add_argument("--dataset", default="ogbn-products")
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--batch-size", type=int, default=128)
    ap.add_argument("--max-batches", type=int, default=30)
    args = ap.parse_args()

    ds = get_dataset(args.dataset, scale=args.scale)
    print(f"dataset: {ds.name}  |V|={ds.graph.num_nodes:,} "
          f"|E|={ds.graph.num_edges:,} feat={ds.feat_dim}")

    results = {}
    for name in ("ns", "gns"):
        scfg = SamplerConfig(batch_size=args.batch_size, fanouts=(5, 10, 15),
                             cache=CacheConfig(fraction=0.05, period=1))
        tr = GNNTrainer(ds, name, sampler_cfg=scfg)
        rep = tr.train(args.epochs, max_batches=args.max_batches,
                       eval_every=args.epochs)
        results[name] = (rep, tr.meter)
        print(f"\n== {name.upper()} ==")
        print(f"  epoch time:        {rep.epoch_times[-1]:.2f}s")
        print(f"  final loss:        {rep.losses[-1]:.4f}")
        print(f"  val micro-F1:      {rep.val_acc[-1]:.4f}")
        print(f"  input nodes/batch: {rep.input_nodes_per_batch:,.0f}"
              f"  (cached: {rep.cached_nodes_per_batch:,.0f})")
        print(f"  bytes streamed:    {tr.meter.bytes_streamed/1e6:,.1f} MB")

    ns_bytes = results["ns"][1].bytes_streamed
    gns_bytes = results["gns"][1].bytes_streamed
    ns_in = results["ns"][0].input_nodes_per_batch
    gns_in = results["gns"][0].input_nodes_per_batch
    print(f"\nGNS vs NS:  input nodes {ns_in/max(gns_in,1):.1f}x fewer, "
          f"streamed bytes {ns_bytes/max(gns_bytes,1):.1f}x fewer "
          f"(paper Table 4: 3-6x fewer input nodes)")


if __name__ == "__main__":
    main()

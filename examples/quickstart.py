"""Quickstart: GNS vs node-wise sampling on a synthetic power-law graph.

Reproduces the paper's core claim at laptop scale in ~a minute: GNS reaches
the same F1 as NS while moving far fewer feature bytes host->device and
far fewer distinct input nodes per minibatch (paper Tables 3 & 4).

Everything runs through the unified engine API (``repro.gns``): one
declarative ``EngineConfig`` preset, one ``GNSEngine`` per sampler.

Run:  PYTHONPATH=src python examples/quickstart.py [--epochs 3]
"""
from __future__ import annotations

import argparse
import dataclasses

from repro.gns import EngineConfig, GNSEngine
from repro.gns.config import DataConfig
from repro.graph.datasets import get_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=2)
    # Table-4 regime: sample tree (batch x prod(fanouts)) << |V|, power-law
    # hubs intact — see EXPERIMENTS.md §Repro regime note.
    ap.add_argument("--dataset", default="ogbn-products")
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--batch-size", type=int, default=128)
    ap.add_argument("--max-batches", type=int, default=30)
    args = ap.parse_args()

    base = EngineConfig.preset(
        "quickstart",
        data=DataConfig(name=args.dataset, scale=args.scale))
    base = dataclasses.replace(
        base, sampling=dataclasses.replace(base.sampling,
                                           batch_size=args.batch_size))
    ds = get_dataset(args.dataset, scale=args.scale)
    print(f"dataset: {ds.name}  |V|={ds.graph.num_nodes:,} "
          f"|E|={ds.graph.num_edges:,} feat={ds.feat_dim}")

    results = {}
    for name in ("ns", "gns"):
        engine = GNSEngine(dataclasses.replace(base, sampler=name),
                           dataset=ds)
        rep = engine.fit(args.epochs, max_batches=args.max_batches,
                         eval_every=args.epochs)
        results[name] = (rep, engine.meter)
        print(f"\n== {name.upper()} ==")
        print(f"  epoch time:        {rep.epoch_times[-1]:.2f}s")
        print(f"  final loss:        {rep.losses[-1]:.4f}")
        print(f"  val micro-F1:      {rep.val_acc[-1]:.4f}")
        print(f"  input nodes/batch: {rep.input_nodes_per_batch:,.0f}"
              f"  (cached: {rep.cached_nodes_per_batch:,.0f})")
        print(f"  bytes streamed:    {engine.meter.bytes_streamed/1e6:,.1f} MB")

    ns_bytes = results["ns"][1].bytes_streamed
    gns_bytes = results["gns"][1].bytes_streamed
    ns_in = results["ns"][0].input_nodes_per_batch
    gns_in = results["gns"][0].input_nodes_per_batch
    print(f"\nGNS vs NS:  input nodes {ns_in/max(gns_in,1):.1f}x fewer, "
          f"streamed bytes {ns_bytes/max(gns_bytes,1):.1f}x fewer "
          f"(paper Table 4: 3-6x fewer input nodes)")


if __name__ == "__main__":
    main()

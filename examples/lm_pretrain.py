"""LM pretraining driver over the assigned architecture zoo.

Runs the REAL distributed training loop (grad accumulation, remat,
checkpoint/restart, deterministic sharded data pipeline) for any of the 10
assigned archs.  On this CPU container use --reduced (same family/block
pattern at smoke scale); on a pod the same entry point runs the full config
under the production mesh (launch/train.py).

Run:  PYTHONPATH=src python examples/lm_pretrain.py --arch xlstm-125m \
          --reduced --steps 50 [--ckpt-dir /tmp/ckpt --resume]
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs import get_config, list_archs
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m", choices=list_archs())
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"arch={cfg.name} family={cfg.family} layers={cfg.num_layers} "
          f"d_model={cfg.d_model} vocab={cfg.vocab_size} "
          f"(reduced={args.reduced})")

    rep = train_loop(cfg, steps=args.steps, batch=args.batch,
                     seq_len=args.seq_len, lr=args.lr,
                     ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                     resume=args.resume, log_every=10)
    print(f"\nloss: {rep.losses[0]:.4f} -> {rep.losses[-1]:.4f} "
          f"({len(rep.losses)} steps, resumed from {rep.resumed_from})")
    print(f"mean step time: {np.mean(rep.step_times[1:]) * 1e3:.1f} ms; "
          f"checkpoints written: {rep.checkpoints}")
    assert rep.losses[-1] < rep.losses[0], "training should reduce loss"


if __name__ == "__main__":
    main()

"""End-to-end driver: 3-layer GraphSAGE + GNS on an ogbn-products-like graph.

The paper's training setup (§4.1) end to end, through the unified engine API
(``repro.gns``): degree-based cache sampling (1% of |V|), cache-prioritized
neighbor sampling with eq. (10)-(12) importance correction, prefetched host
pipeline, AdamW(3e-3), periodic checkpointing with restart, and the Fig. 1/2
runtime breakdown printed at the end.  A few hundred steps by default.

``--mesh DxM`` builds a (data=D, model=M) host mesh (run under
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to mock N devices):
the cache table row-shards over 'model', the engine collates one minibatch
per DP group per step, and the fused input layer rides the device-resident
home-shard vector — the DP > 1 fast-path regime in one compiled step.

Run:  PYTHONPATH=src python examples/train_gns_graphsage.py \
          [--sampler gns|ns|ladies|lazygcn] [--steps 300] [--scale 1.0] \
          [--mesh 2x2] [--infer 64]
"""
from __future__ import annotations

import argparse
import json

from repro.checkpoint import CheckpointManager
from repro.core.sampler import SamplerConfig
from repro.featurestore import CacheConfig
from repro.gns import EngineConfig, GNSEngine
from repro.gns.config import DataConfig, MeshConfig, ModelConfig


def main():
    from repro.featurestore import POLICIES

    ap = argparse.ArgumentParser()
    ap.add_argument("--sampler", default="gns",
                    choices=["gns", "ns", "ladies", "lazygcn"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--dataset", default="ogbn-products")
    ap.add_argument("--scale", type=float, default=0.5)
    ap.add_argument("--batch-size", type=int, default=1000)
    ap.add_argument("--cache-frac", type=float, default=0.01)
    ap.add_argument("--cache-policy", default="auto",
                    choices=["auto", *sorted(POLICIES)],
                    help="cache-admission policy (featurestore registry)")
    ap.add_argument("--async-refresh", action="store_true",
                    help="double-buffered background cache refresh")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="host mesh, e.g. 2x2 = (data=2, model=2): sharded "
                         "cache + fused input + DP>1 home-shard fast path")
    ap.add_argument("--infer", type=int, default=0, metavar="N",
                    help="after training, run mini-batch inference on N "
                         "validation nodes through the live cache")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--prefetch", action="store_true", default=True)
    args = ap.parse_args()

    mesh_cfg, model_cfg = None, ModelConfig()
    if args.mesh:
        import jax

        d, m = (int(x) for x in args.mesh.lower().split("x"))
        mesh_cfg = MeshConfig(data=d, model=m)
        # a sharded cache table wants the fused input path (the "where"
        # path cannot exploit the row-sharded layout).  Off-TPU the Pallas
        # kernel runs in interpret mode — Python-per-lane, minutes per step
        # at these fanouts — so use the jnp reference backend inside the
        # same shard_map body (identical sharding/fast-path logic; the
        # dry-run lowers the same way).
        kernel = "pallas" if jax.default_backend() == "tpu" else "reference"
        model_cfg = ModelConfig(input_impl="fused", input_kernel=kernel)

    cfg = EngineConfig(
        sampler=args.sampler,
        data=DataConfig(name=args.dataset, scale=args.scale),
        sampling=SamplerConfig(batch_size=args.batch_size,
                               fanouts=(5, 10, 15)),
        cache=CacheConfig(fraction=args.cache_frac, period=1,
                          strategy=args.cache_policy,
                          async_refresh=args.async_refresh),
        model=model_cfg, mesh=mesh_cfg, prefetch=args.prefetch)
    engine = GNSEngine(cfg)
    ds = engine.ds
    print(f"{ds.name}: |V|={ds.graph.num_nodes:,} |E|={ds.graph.num_edges:,} "
          f"train={len(ds.train_idx):,} feat={ds.feat_dim}"
          + (f"  mesh={args.mesh} dp_groups={engine.num_groups}"
             if args.mesh else ""))

    # one optimizer step consumes num_groups minibatches at DP > 1
    steps_per_epoch = max(
        len(ds.train_idx) // (args.batch_size * max(engine.num_groups, 1)), 1)
    epochs = max(args.steps // steps_per_epoch, 1)
    mgr = CheckpointManager(args.ckpt_dir, every=1) if args.ckpt_dir else None

    rep = engine.fit(epochs, eval_every=1)
    if mgr:
        mgr.maybe_save(epochs, (engine.params, engine.opt_state))

    print(f"\n== {args.sampler.upper()} on {ds.name} "
          f"({epochs} epochs x {steps_per_epoch} steps) ==")
    print(f"loss: {rep.losses[0]:.4f} -> {rep.losses[-1]:.4f}")
    print(f"val micro-F1: {[round(a, 4) for a in rep.val_acc]}")
    print(f"epoch times (s): {[round(t, 2) for t in rep.epoch_times]}")
    print(f"input nodes/batch: {rep.input_nodes_per_batch:,.0f} "
          f"(cached {rep.cached_nodes_per_batch:,.0f}, "
          f"isolated {rep.isolated_per_batch:.1f})")
    print("runtime breakdown (paper Fig. 2):")
    print(json.dumps(engine.meter.breakdown(), indent=2))
    if engine.store is not None:
        dev = engine.meter.tier("device")
        print(f"feature store: policy={engine.store.policy.name} "
              f"generations={engine.store.refreshes} "
              f"swaps={engine.store.swaps} "
              f"device hit-rate={dev.hit_rate:.3f}")
    if args.infer:
        ids = ds.val_idx[:args.infer]
        logits = engine.infer(ids)
        preds = logits.argmax(axis=-1)
        acc = float((preds == ds.labels[ids]).mean())
        print(f"infer: {len(ids)} nodes through the live cache generation, "
              f"top-1 agreement with labels {acc:.3f}")


if __name__ == "__main__":
    main()

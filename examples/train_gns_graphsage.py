"""End-to-end driver: 3-layer GraphSAGE + GNS on an ogbn-products-like graph.

The paper's training setup (§4.1) end to end: degree-based cache sampling
(1% of |V|), cache-prioritized neighbor sampling with eq. (10)-(12)
importance correction, prefetched host pipeline, AdamW(3e-3), periodic
checkpointing with restart, and the Fig. 1/2 runtime breakdown printed at
the end.  A few hundred steps by default.

Run:  PYTHONPATH=src python examples/train_gns_graphsage.py \
          [--sampler gns|ns|ladies|lazygcn] [--steps 300] [--scale 1.0]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.cache import CacheConfig
from repro.core.sampler import SamplerConfig
from repro.graph.datasets import get_dataset
from repro.train.trainer import GNNTrainer


def main():
    from repro.featurestore import POLICIES

    ap = argparse.ArgumentParser()
    ap.add_argument("--sampler", default="gns",
                    choices=["gns", "ns", "ladies", "lazygcn"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--dataset", default="ogbn-products")
    ap.add_argument("--scale", type=float, default=0.5)
    ap.add_argument("--batch-size", type=int, default=1000)
    ap.add_argument("--cache-frac", type=float, default=0.01)
    ap.add_argument("--cache-policy", default="auto",
                    choices=["auto", *sorted(POLICIES)],
                    help="cache-admission policy (featurestore registry)")
    ap.add_argument("--async-refresh", action="store_true",
                    help="double-buffered background cache refresh")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--prefetch", action="store_true", default=True)
    args = ap.parse_args()

    ds = get_dataset(args.dataset, scale=args.scale)
    print(f"{ds.name}: |V|={ds.graph.num_nodes:,} |E|={ds.graph.num_edges:,} "
          f"train={len(ds.train_idx):,} feat={ds.feat_dim}")

    scfg = SamplerConfig(batch_size=args.batch_size, fanouts=(5, 10, 15),
                         cache=CacheConfig(fraction=args.cache_frac, period=1,
                                           strategy=args.cache_policy,
                                           async_refresh=args.async_refresh))
    tr = GNNTrainer(ds, args.sampler, sampler_cfg=scfg)

    steps_per_epoch = max(len(ds.train_idx) // args.batch_size, 1)
    epochs = max(args.steps // steps_per_epoch, 1)
    mgr = CheckpointManager(args.ckpt_dir, every=1) if args.ckpt_dir else None

    rep = tr.train(epochs, prefetch=args.prefetch, eval_every=1)
    if mgr:
        mgr.maybe_save(epochs, (tr.params, tr.opt_state))

    print(f"\n== {args.sampler.upper()} on {ds.name} "
          f"({epochs} epochs x {steps_per_epoch} steps) ==")
    print(f"loss: {rep.losses[0]:.4f} -> {rep.losses[-1]:.4f}")
    print(f"val micro-F1: {[round(a, 4) for a in rep.val_acc]}")
    print(f"epoch times (s): {[round(t, 2) for t in rep.epoch_times]}")
    print(f"input nodes/batch: {rep.input_nodes_per_batch:,.0f} "
          f"(cached {rep.cached_nodes_per_batch:,.0f}, "
          f"isolated {rep.isolated_per_batch:.1f})")
    print("runtime breakdown (paper Fig. 2):")
    print(json.dumps(tr.meter.breakdown(), indent=2))
    if tr.store is not None:
        dev = tr.meter.tier("device")
        print(f"feature store: policy={tr.store.policy.name} "
              f"generations={tr.store.refreshes} swaps={tr.store.swaps} "
              f"device hit-rate={dev.hit_rate:.3f}")


if __name__ == "__main__":
    main()

"""Batched LM serving demo: the decode engine over any assigned arch.

Shows exact-length request batching, prefill + token-by-token decode with
per-slot EOS, and per-family decode state (KV cache / MLA latent / SSM /
mLSTM matrix memory).  Enc-dec archs get stub audio frames.

Run:  PYTHONPATH=src python examples/serve_lm.py --arch zamba2-2.7b \
          [--requests 6] [--temperature 0.8]
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.launch.serve import Request, ServeEngine
from repro.models.lm import get_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-2.7b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()      # CPU container scale
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_batch=4, temperature=args.temperature)

    rng = np.random.default_rng(0)
    lens = rng.choice([8, 8, 12], size=args.requests)   # mixed-length queue
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, L).astype(np.int32),
                    max_new_tokens=args.max_new) for L in lens]

    frames = None
    if cfg.encoder_layers > 0:
        frames = rng.standard_normal(
            (len(reqs), 8, cfg.d_model)).astype(np.float32)
        comps = eng.generate_batch(reqs[:eng.max_batch],
                                   frame_embeds=frames[:eng.max_batch])
    else:
        comps = eng.serve(reqs)

    for i, c in enumerate(comps):
        tps = c.steps / max(c.decode_s, 1e-9)
        print(f"req{i} (len {len(reqs[i].prompt)}): "
              f"tokens={list(c.tokens[:8])}{'...' if len(c.tokens) > 8 else ''} "
              f"prefill={c.prefill_s*1e3:.0f}ms decode={tps:,.0f} tok/s")


if __name__ == "__main__":
    main()

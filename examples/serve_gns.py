"""Serving quickstart: the persistent GNS serving loop (repro.serve).

Fits a small GNS engine, then serves a skewed request stream through
``GNSServer``: requests are coalesced into size-bucketed padded batches
(one compiled inference step per bucket — zero steady-state recompilation),
every batch rides the live cache generation safely, and the serving traffic
feeds the adaptive policy so periodic refreshes pull the cache toward the
inference hot set.  Prints the latency/traffic snapshot at the end.

Run:  PYTHONPATH=src python examples/serve_gns.py [--requests 200]
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core.sampler import SamplerConfig
from repro.featurestore import CacheConfig
from repro.gns import EngineConfig, GNSEngine, ServeConfig
from repro.gns.config import DataConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--epochs", type=int, default=1)
    ap.add_argument("--hot-share", type=float, default=0.9,
                    help="fraction of requests drawn from the hot set")
    args = ap.parse_args()

    cfg = EngineConfig(
        sampler="gns",
        data=DataConfig(name="ogbn-products", scale=args.scale),
        sampling=SamplerConfig(batch_size=128, fanouts=(5, 10)),
        cache=CacheConfig(fraction=0.05, strategy="adaptive"),
        serve=ServeConfig(buckets=(16, 64, 128), max_wait_ms=2.0,
                          refresh_every=16,
                          # the example fires the whole stream before
                          # collecting results, so the queue must hold it
                          # (a real client sheds/retries on QueueFull)
                          max_queue=args.requests + 8))
    engine = GNSEngine(cfg)
    print(f"fitting on {engine.ds.graph.num_nodes:,} nodes ...")
    engine.fit(args.epochs, max_batches=20)

    rng = np.random.default_rng(0)
    pool = engine.ds.val_idx
    hot = rng.choice(pool, size=max(len(pool) // 20, 16), replace=False)
    print(f"serving {args.requests} requests "
          f"({args.hot_share:.0%} from a {len(hot)}-node hot set) ...")
    with engine.serve() as server:
        futs = []
        for _ in range(args.requests):
            src = hot if rng.random() < args.hot_share else pool
            ids = rng.choice(src, size=int(rng.integers(2, 10)),
                             replace=False)
            futs.append(server.submit(ids))       # deadline_ms=... optional
        for f in futs:
            logits = f.result(timeout=600).logits
            assert np.isfinite(logits).all()

    snap = server.meter.snapshot()
    traj = server.meter.hit_trajectory()
    k = max(len(traj) // 4, 1)
    print(f"served {snap['served']}/{snap['submitted']} in "
          f"{snap['batches']} micro-batches "
          f"(fill {snap['fill_fraction']:.0%}, "
          f"compiled steps: {engine.infer_step._cache_size()})")
    print(f"latency: queue p50/p99 {snap['queue_wait_p50_ms']}/"
          f"{snap['queue_wait_p99_ms']} ms, "
          f"total p50/p99 {snap['total_p50_ms']}/{snap['total_p99_ms']} ms")
    print(f"cache: hit rate {snap['cache_hit_rate']:.2%}, "
          f"hit trajectory {np.mean(traj[:k]):.2f} -> {np.mean(traj[-k:]):.2f} "
          f"over {snap['swaps_observed']} serving-driven refresh swaps")


if __name__ == "__main__":
    main()

"""Pure-jnp oracles for the device-sampling kernels (the allclose targets
and the off-TPU production path — ``SageConfig.sample_kernel="reference"``)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def slot_gather_agg_ref(cache_table: jax.Array, lane_rows: jax.Array,
                        w: jax.Array) -> jax.Array:
    """out[b] = Σ_k w[b,k] · cache_table[lane_rows[b,k]]; dead lanes
    (``lane_rows < 0``) contribute exactly 0.

    Sequential f32 accumulation over k — the same association order as the
    Pallas kernel's K-innermost grid — so interpret-mode parity is bitwise
    whenever per-step products are exactly representable (see
    ``kernels.ref.cache_lookup_agg_ref`` for the FMA caveat).
    """
    lr = lane_rows.astype(jnp.int32)
    rows = jnp.take(cache_table, jnp.clip(lr, 0), axis=0).astype(jnp.float32)
    wf = jnp.where(lr >= 0, w.astype(jnp.float32), 0.0)
    out = jnp.zeros((lr.shape[0], cache_table.shape[1]), jnp.float32)
    for k in range(lr.shape[1]):       # static K; matches kernel accum order
        out = out + wf[:, k:k + 1] * rows[:, k]
    return out

"""Device-resident ``cache_adj``: the induced cached-neighbor CSR as device
arrays, rows reordered by the placement permutation.

The host :class:`~repro.graph.csr.CacheAdjacency` spans the FULL node-id
space (|V|+1 row pointers) because the host sampler queries arbitrary node
ids.  The device sampler only ever starts from rows of the device cache
table, so the device CSR is restricted to — and indexed by — **device rows**
(the slot→(shard, local row) permutation the placement solver produced):
row ``r`` of the table is row ``r`` of the CSR, and its adjacency list holds
the device rows of its cached neighbors.  That makes the fused kernel's
layer-0 draw a pure table-row computation — no node ids, no host lookups —
and keeps a shard's hot rows contiguous in both the table AND the structure
(the carried placement-aware ``cache_adj`` item): a locality-placed
generation's frequent dst rows and their neighbor lists live in the same
shard block the feature rows do.

Built once per generation (``FeatureStore._build``), uploaded alongside the
feature table, and carried on :class:`~repro.featurestore.store.Generation`
so the atomic swap publishes structure and features together — a batch
sampled against generation *g* draws from *g*'s CSR and gathers *g*'s rows,
mid-swap or not.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.importance import cache_hit_prob


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DeviceCacheAdj:
    """The per-generation device CSR over cache-table rows (all leaves).

    ``indices`` is padded to a power-of-two capacity (min 1024) so the edge
    count drifting between generations does not retrace the compiled step
    for every new nnz — only when it crosses a power of two.
    """
    indptr: jnp.ndarray   # int32 [table_rows + 1]  device-row order
    indices: jnp.ndarray  # int32 [cap]  neighbor DEVICE rows (pad = 0)
    deg: jnp.ndarray      # f32 [table_rows]  FULL-graph degree of the row's
                          # node (eq. 10's deg(v); 0 for unoccupied pad rows)
    hitp: jnp.ndarray     # f32 [table_rows]  cache-inclusion probability
                          # p_u^C (eq. 11 / calibrated λ) of the row's node

    @property
    def table_rows(self) -> int:
        return self.indptr.shape[0] - 1


def build_device_cache_adj(state, host_adj, degrees: np.ndarray,
                           lam=None, meter=None) -> DeviceCacheAdj:
    """Materialize one generation's device CSR from the host induced CSR.

    Args:
      state: the generation's :class:`CacheState` (membership + placement).
      host_adj: ``graph.induced_cache_adjacency`` over the full id space.
      degrees: full-graph degree per node (the eq. 10 normalizer).
      lam: the generation's calibrated inclusion λ (None = eq. 11).
      meter: optional :class:`~repro.featurestore.meter.TrafficMeter`; the
        four array uploads below land on ``bytes_adj_upload`` (separate from
        ``bytes_cache_upload`` so the sharded-upload ratio stays a pure
        feature-table number).

    All importance inputs that the host sampler computes per batch
    (``probs[nbrs]`` → ``cache_hit_prob``) are precomputed here per ROW in
    float64 and stored as f32 — the device draw then never touches the O(V)
    probability vector.
    """
    rows = state.table_rows if state.table_rows else state.size
    dr = state.device_rows(np.arange(state.size))
    node_of_row = np.full(rows, -1, dtype=np.int64)
    node_of_row[dr] = state.node_ids
    occ = node_of_row >= 0
    nodes = node_of_row[occ]

    counts = np.zeros(rows, dtype=np.int64)
    counts[occ] = host_adj.indptr[nodes + 1] - host_adj.indptr[nodes]
    indptr = np.zeros(rows + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    nnz = int(indptr[-1])

    # flat ragged gather: row r's slice of the host CSR, in device-row order
    rep = np.repeat(np.arange(rows), counts)
    off = np.arange(nnz, dtype=np.int64) - np.repeat(indptr[:-1], counts)
    starts = host_adj.indptr[np.maximum(node_of_row, 0)]
    nbr_ids = host_adj.indices[starts[rep] + off]
    # neighbors of a cached node's induced list are cached by construction,
    # so slot_of >= 0 and the device-row map is total
    nbr_rows = state.device_rows(state.slot_of[nbr_ids]).astype(np.int32)

    cap = max(1024, nnz)
    cap = 1 << (cap - 1).bit_length()
    indices = np.zeros(cap, dtype=np.int32)
    indices[:nnz] = nbr_rows

    deg = np.zeros(rows, dtype=np.float32)
    deg[occ] = degrees[nodes]
    hitp = np.zeros(rows, dtype=np.float32)
    hitp[occ] = cache_hit_prob(state.probs[nodes], state.size, lam=lam)

    adj = DeviceCacheAdj(
        indptr=jnp.asarray(indptr.astype(np.int32)),
        indices=jnp.asarray(indices),
        deg=jnp.asarray(deg),
        hitp=jnp.asarray(hitp))
    if meter is not None:
        meter.bytes_adj_upload += sum(
            int(np.asarray(a).nbytes)
            for a in (adj.indptr, adj.indices, adj.deg, adj.hitp))
    return adj

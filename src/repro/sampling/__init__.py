"""Device-resident GNS sampling subsystem (ROADMAP item 2).

Layer map:

* :mod:`repro.sampling.rng` — counter-based stateless RNG (fmix32 chain).
* :mod:`repro.sampling.adjacency` — per-generation ``cache_adj`` CSR as
  device arrays in placement (device-row) order.
* :mod:`repro.sampling.kernels` — fused draw → slot lookup → layer-0 gather
  (Pallas kernel + shard_map dispatch), plus the plain-jnp ``draw_lanes``.
* :mod:`repro.sampling.ref` — jnp oracle for the gather kernel.
* :mod:`repro.sampling.device_sampler` — the ``backend="device"`` sampler
  the engine instantiates via ``make_sampler``.
"""
from repro.sampling.adjacency import DeviceCacheAdj, build_device_cache_adj
from repro.sampling.device_sampler import DeviceGNSSampler
from repro.sampling.kernels import draw_lanes, gns_sample_agg, slot_gather_agg_pallas
from repro.sampling.ref import slot_gather_agg_ref
from repro.sampling.rng import mix32, murmur_fmix

__all__ = [
    "DeviceCacheAdj",
    "DeviceGNSSampler",
    "build_device_cache_adj",
    "draw_lanes",
    "gns_sample_agg",
    "mix32",
    "murmur_fmix",
    "slot_gather_agg_pallas",
    "slot_gather_agg_ref",
]

"""``backend="device"`` GNS sampler: the input layer moves on-device.

:class:`DeviceGNSSampler` keeps the host :class:`~repro.core.sampler.GNSSampler`
machinery for the UPPER layers (top-up sampling needs the full graph, which
only the host holds) but stops materializing the input layer on the host.
What changes per batch:

* the input-layer block degenerates to a placeholder — ``pad_sizes[0]``
  shrinks from ``(D0, D0·(1+k0))`` to ``(D0, D0)``, so the batch ships D0
  input rows instead of S0 = D0·(1+k0): at the default fanouts that is a
  (1+k0)× cut in streamed input features and padded id arrays, the §2.2
  host-bandwidth term the paper attacks;
* the layer-0 draw happens inside the compiled step
  (:func:`repro.sampling.kernels.gns_sample_agg`) against the generation's
  :class:`~repro.sampling.adjacency.DeviceCacheAdj`, keyed by a per-batch
  64-bit key (``DeviceBatch.sample_key``) — the host only hands over seed
  rows (``input_cache_slots``) and the key;
* input rows the cache does NOT cover (the miss path) fall back to the host
  sampler: ``_sample_layer(allow_topup=False)`` draws their cached-neighbor
  lanes exactly as the host backend would, and the lanes ride along as
  ``input_fb_rows``/``input_fb_w`` (device-table rows + weights) that the
  fused op merges in.  A generation covers its own cached nodes' neighbors
  by construction, so fallback only triggers for uncached destinations.

The estimator is the host one — w = 1/(p^C_u·min(k,n_c)/n_c·deg v), eq.
(10)–(12) — with one documented difference: rows with n_c > k draw WITH
replacement on device (independent lanes, counter RNG) where the host draws
without.  Per-lane marginals and the expectation are identical (both
property-tested); only the joint differs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.minibatch import LayerBlock, MiniBatch, make_block
from repro.core.sampler import GNSSampler, SamplerConfig, _assemble, _union_src
from repro.featurestore.store import FeatureStore
from repro.graph.csr import CSRGraph


class DeviceGNSSampler(GNSSampler):
    """GNS with the input layer sampled on device (see module docstring)."""

    name = "gns"
    backend = "device"

    def __init__(self, graph: CSRGraph, cfg: SamplerConfig,
                 features: np.ndarray, labels: np.ndarray,
                 train_idx: Optional[np.ndarray] = None,
                 store: Optional[FeatureStore] = None):
        super().__init__(graph, cfg, features, labels,
                         train_idx=train_idx, store=store)
        # generations must carry the device CSR from here on (set before the
        # first refresh builds one)
        self.store.build_device_adj = True
        d0 = self.pad_sizes[0][0]
        # input block is a placeholder: src axis == dst axis (the device draw
        # replaces the host gather, so no neighbor lanes ship)
        self.pad_sizes = [(d0, d0)] + list(self.pad_sizes[1:])

    def sample(self, targets: np.ndarray, rng: np.random.Generator) -> MiniBatch:
        assert self.cache is not None, "call start_epoch/refresh_cache first"
        gen = self._gen
        assert gen.device_adj is not None, (
            "device backend needs generations built with build_device_adj")
        cfg = self.cfg
        ids = np.asarray(targets, dtype=np.int64)
        blocks: list[LayerBlock] = []
        for li in range(cfg.num_layers - 1, 0, -1):   # upper layers: host path
            k = cfg.fanouts[li]
            nbrs, mask, w = self._sample_layer(ids, k, rng, allow_topup=True)
            src_ids, idx = _union_src(ids, nbrs, mask, self._stamp)
            pad_dst, pad_src = self.pad_sizes[li]
            blocks.append(make_block(idx, np.where(mask, w, 0.0),
                                     pad_dst, pad_src))
            ids = src_ids
        # placeholder input block: zero lanes/weights, dst == src rows (the
        # layer-1 src chain guarantees len(ids) <= d0 == old S1 bound)
        d0 = self.pad_sizes[0][0]
        n0 = len(ids)
        blocks.append(make_block(np.zeros((n0, 1), dtype=np.int64),
                                 np.zeros((n0, 1)), d0, d0))
        mb = _assemble(blocks, ids, targets, self.features, self.labels,
                       self.pad_sizes, cfg.batch_size,
                       store=self.store, gen=gen)

        k0 = cfg.fanouts[0]
        slots = mb.device.input_cache_slots          # device rows, -1 = miss
        real = mb.device.input_mask > 0
        fb_rows = np.full((d0, k0), -1, dtype=np.int32)
        fb_w = np.zeros((d0, k0), dtype=np.float32)
        fb = (slots < 0) & real                      # uncached real dst rows
        if fb.any():
            fb_ids = mb.input_node_ids[fb]
            nbrs, mask, w = self._sample_layer(fb_ids, k0, rng,
                                               allow_topup=False)
            state = gen.state
            rows = state.device_rows(state.slot_of[nbrs]).astype(np.int32)
            fb_rows[fb] = np.where(mask, rows, -1)
            fb_w[fb] = np.where(mask, w, 0.0).astype(np.float32)

        key = rng.integers(0, 2 ** 32, size=(1, 2), dtype=np.uint32)

        # isolated = real dst rows the device draw AND the fallback both
        # leave laneless (mirrors the host backend's Table-5 counter)
        nc = (gen.cache_adj.indptr[mb.input_node_ids + 1]
              - gen.cache_adj.indptr[mb.input_node_ids])
        covered = np.where(slots >= 0, nc > 0, (fb_w > 0).any(axis=1))
        iso = int((real & ~covered).sum())

        dev = dataclasses.replace(mb.device, input_fb_rows=fb_rows,
                                  input_fb_w=fb_w, sample_key=key)
        return dataclasses.replace(mb, device=dev, num_isolated=iso)

"""Counter-based stateless RNG for the device-resident GNS sampler.

Replay contract: the device draw for destination row ``r`` of the batch
sampled with key ``(lo, hi)`` depends ONLY on ``(lo, hi, r, lane)`` — never
on program order, device count, or how many draws other rows made.  The
host hands each batch a fresh 64-bit key (``DeviceGNSSampler.sample``
draws it from the per-batch seeded generator of the epoch loader), so

  * re-running a batch reproduces its sample bit-for-bit (replay-stable),
  * the same step sharded over any number of devices draws the same lanes
    (the counter is the GLOBAL row index, not a per-device stream), and
  * two batches with different keys are independent.

The generator is the murmur3 finalizer (fmix32) chained over the key and
counter words — a full-avalanche 32-bit mixer whose Pallas lowering is four
shifts/xors and two multiplies per word, identical in plain jnp, so the
kernel and the reference path produce the SAME bits (the bitwise-parity
test relies on this).  jax's threefry would also work but keys/counters
thread awkwardly through scalar-prefetch SMEM; fmix32 keeps the whole draw
expressible on values already in registers.
"""
from __future__ import annotations

import jax.numpy as jnp


def murmur_fmix(x: jnp.ndarray) -> jnp.ndarray:
    """murmur3's 32-bit finalizer: bijective, full avalanche."""
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def mix32(*words: jnp.ndarray) -> jnp.ndarray:
    """Hash any number of uint32 words (broadcast together) to uint32 bits.

    ``mix32(key_lo, key_hi, row, lane)`` is the device sampler's per-lane
    counter stream.  Chaining fmix32 over the words (seeded with the golden
    ratio so a single zero word still avalanches) keeps every word's bits
    influencing the result.
    """
    h = jnp.uint32(0x9E3779B9)
    for w in words:
        h = murmur_fmix(h ^ w.astype(jnp.uint32))
    return h

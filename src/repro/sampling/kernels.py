"""Device-resident GNS layer-0 sampling: fused draw → slot lookup → gather.

The host GNS input layer (``GNSSampler._sample_layer(allow_topup=False)``)
does three things per destination node: draw up to ``k`` cached neighbors,
compute the eq. (10)–(12) importance weights, and emit lanes the feature
gather consumes.  This module does the same ON DEVICE against the
generation's :class:`~repro.sampling.adjacency.DeviceCacheAdj`:

* :func:`draw_lanes` — the candidate draw + weight computation in plain jnp
  (counter-based stateless RNG, ``rng.mix32``): per destination row, if the
  row has ``n_c <= k`` cached neighbors it takes ALL of them (the host
  sampler's take-all regime — lanes beyond ``n_c`` are dead); otherwise it
  makes ``k`` uniform draws WITH replacement (``bits mod n_c``).  Both
  regimes weight lanes ``w = 1/(p^C_u · min(k, n_c)/n_c · deg(v))`` — the
  exact host formula — so the conditional estimator
  ``E[Σ w·f | cache] = Σ_{u∈N_C(v)} f_u / (p^C_u · deg(v))`` is identical
  to the host sampler's (per-lane marginals match; the joint differs by
  with- vs without-replacement, a documented approximation whose modulo
  bias is < n_c/2³² and whose unbiasedness is property-tested).
* :func:`slot_gather_agg_pallas` — the Pallas gather-aggregate over the
  drawn table rows (one launch; scalar-prefetched lane rows drive the
  BlockSpec index map exactly like ``kernels/cache_lookup.py``).
* :func:`gns_sample_agg` — the jitted entry the model's layer 0 calls:
  draw, merge host-fallback lanes (destination rows NOT in the cache are
  sampled by the host — ``top-up misses fall back to the host path``), and
  dispatch the gather to the Pallas kernel, the jnp reference, or the
  shard_map-over-cache-axis path (draw stays GLOBAL — the adjacency is
  replicated and tiny next to the feature table; only the feature gather
  runs per-shard + psum, mirroring ``kernels.ops._fused_forward``).

The draw itself stays jnp rather than living inside the Pallas body: it is
a handful of int ops per lane that XLA fuses into the surrounding step for
free, while the gather is the bandwidth-bound part that needs the kernel —
the same split (lane math XLA-side, row DMA Pallas-side) the fused
cache-lookup kernel documents for its SMEM budget.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.sampling.adjacency import DeviceCacheAdj
from repro.sampling.ref import slot_gather_agg_ref
from repro.sampling.rng import mix32


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# candidate draw + importance weights (eq. 10-12 on device rows)
# ---------------------------------------------------------------------------

def draw_lanes(adj: DeviceCacheAdj, dst_rows: jax.Array, keys: jax.Array,
               k: int, num_groups: int = 1
               ) -> tuple[jax.Array, jax.Array]:
    """Per-destination cached-neighbor draw with importance weights.

    Args:
      adj: the generation's device CSR.
      dst_rows: int32 [B] device-table row per destination (-1 = not cached
        or padding — those rows draw nothing here; the host fallback covers
        real uncached destinations).
      keys: uint32 [num_groups, 2] per-batch RNG key (one per DP group).
      k: the input-layer fanout (static).
      num_groups: DP groups collated into the batch (static); row ``r``'s
        counter is its GROUP-LOCAL index so each group's draw matches the
        same batch sampled ungrouped.

    Returns ``(lane_rows, lane_w)`` of shape [B, k]: device-table rows
    (-1 = dead lane) and f32 weights (0 on dead lanes).
    """
    B = dst_rows.shape[0]
    assert B % max(num_groups, 1) == 0, (B, num_groups)
    pad = B // max(num_groups, 1)
    dst = dst_rows.astype(jnp.int32)
    rowc = jnp.clip(dst, 0)
    start = jnp.take(adj.indptr, rowc)
    n_c = jnp.take(adj.indptr, rowc + 1) - start              # int32 [B]

    key_lo = jnp.repeat(keys[:, 0], pad, total_repeat_length=B)
    key_hi = jnp.repeat(keys[:, 1], pad, total_repeat_length=B)
    local = jnp.arange(B, dtype=jnp.uint32) % jnp.uint32(max(pad, 1))
    lane = jnp.arange(k, dtype=jnp.uint32)
    bits = mix32(key_lo[:, None], key_hi[:, None],
                 local[:, None], lane[None, :])               # [B, k] u32

    take_all = (n_c <= k)[:, None]
    ncs = jnp.maximum(n_c, 1)
    off_draw = (bits % ncs[:, None].astype(jnp.uint32)).astype(jnp.int32)
    off_seq = jnp.minimum(lane.astype(jnp.int32)[None, :],
                          jnp.maximum(n_c - 1, 0)[:, None])
    off = jnp.where(take_all, off_seq, off_draw)
    flat = jnp.clip(start[:, None] + off, 0, adj.indices.shape[0] - 1)
    rows = jnp.take(adj.indices, flat)                        # [B, k]

    alive = ((dst >= 0) & (n_c > 0))[:, None]
    alive = alive & jnp.where(
        take_all, lane.astype(jnp.int32)[None, :] < n_c[:, None], True)

    # the exact host weight: coeff = p^C_u * min(k, n_c)/n_c (clamped),
    # w = 1/(coeff * max(deg, 1))  — importance.importance_coefficients
    # with the hit probabilities precomputed per row at build time
    ncf = jnp.maximum(n_c.astype(jnp.float32), 1.0)[:, None]
    hitp = jnp.take(adj.hitp, jnp.clip(rows, 0))
    coeff = jnp.maximum(hitp * (jnp.minimum(float(k), ncf) / ncf), 1e-6)
    deg = jnp.maximum(jnp.take(adj.deg, rowc), 1.0)[:, None]
    w = jnp.where(alive, 1.0 / (coeff * deg), 0.0)
    rows = jnp.where(alive, rows, -1)
    return rows, w


# ---------------------------------------------------------------------------
# Pallas gather-aggregate over drawn table rows
# ---------------------------------------------------------------------------

def _kernel(lane_ref, w_ref, cache_ref, out_ref):
    b = pl.program_id(0)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # dead lanes were pre-masked to w == 0 and row 0 XLA-side, so the DMA'd
    # tile is discarded by the multiply; accumulation order is fixed
    # (K innermost, ascending) and matches slot_gather_agg_ref bitwise for
    # exactly-representable products (see kernels/cache_lookup.py)
    out_ref[...] += w_ref[b, k] * cache_ref[...].astype(out_ref.dtype)


def slot_gather_agg_pallas(cache_table: jax.Array, lane_rows: jax.Array,
                           w: jax.Array, block_d: int = 2048,
                           interpret: bool = False) -> jax.Array:
    """out[b] = Σ_k w[b,k] · cache_table[lane_rows[b,k]]  ([B, D] f32).

    ``lane_rows`` rides scalar prefetch (SMEM) and drives the cache-row
    BlockSpec index map; per grid step the pipeline DMAs one (1, block_d)
    tile at row ``max(lane_rows[b,k], 0)``.  Grid (B, D/block_d, K) with K
    innermost keeps the output tile VMEM-resident across the accumulation.
    """
    _, d = cache_table.shape
    bsz, num_k = lane_rows.shape
    block_d = min(block_d, d)
    while d % block_d:                 # largest divisor <= requested block
        block_d -= 1
    grid = (bsz, d // block_d, num_k)

    lr = lane_rows.astype(jnp.int32)
    w_eff = jnp.where(lr >= 0, w.astype(jnp.float32), 0.0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                   # lane rows ride in SMEM
        grid=grid,
        in_specs=[
            # weights: full (B, K) in VMEM — tiny (4·B·K bytes)
            pl.BlockSpec((bsz, num_k), lambda b, db, k, lane_ref: (0, 0)),
            # cache rows: the drawn table row (clamped for dead lanes)
            pl.BlockSpec((1, block_d),
                         lambda b, db, k, lane_ref:
                         (jnp.maximum(lane_ref[b, k], 0), db)),
        ],
        out_specs=pl.BlockSpec((1, block_d),
                               lambda b, db, k, lane_ref: (b, db)),
    )
    fn = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, d), jnp.float32),
        interpret=interpret,
    )
    return fn(lr, w_eff, cache_table)


# ---------------------------------------------------------------------------
# the fused entry point the model's layer 0 calls
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("impl", "block_d", "mesh",
                                             "shard_axis", "num_groups"))
def gns_sample_agg(adj: DeviceCacheAdj, cache_table: jax.Array,
                   dst_rows: jax.Array, fb_rows: jax.Array,
                   fb_w: jax.Array, keys: jax.Array, *,
                   impl: str = "reference", block_d: int = 512,
                   mesh=None, shard_axis: Optional[str] = None,
                   num_groups: int = 1) -> jax.Array:
    """Fused device GNS input layer: draw + weight + gather.  [B, D] f32.

    ``dst_rows`` is the batch's ``input_cache_slots`` vector (device rows of
    the destination nodes, -1 for uncached/padding); ``fb_rows``/``fb_w``
    carry the host-sampled fallback lanes for uncached real destinations
    (-1/0 elsewhere).  Cached rows draw on device; uncached rows use their
    fallback lanes verbatim — the miss path falls back to the host sampler.

    Not differentiable and deliberately so: the layer-0 aggregate has no
    parameter dependence, so the model wraps every operand in
    ``stop_gradient`` and the backward never enters this op (no custom VJP
    needed — contrast ``kernels.ops.cache_lookup_agg`` whose streamed rows
    sit on the grad path of its fused h_dst assembly).
    """
    k = fb_rows.shape[1]
    drawn, w = draw_lanes(adj, dst_rows, keys, k, num_groups=num_groups)
    uncached = (dst_rows.astype(jnp.int32) < 0)[:, None]
    lane_rows = jnp.where(uncached, fb_rows.astype(jnp.int32), drawn)
    lane_w = jnp.where(uncached, fb_w.astype(jnp.float32), w)

    if mesh is not None and shard_axis in getattr(mesh, "axis_names", ()):
        from jax.sharding import PartitionSpec as P

        from repro.kernels.cache_lookup import shard_slot_map
        from repro.kernels.ops import _dp_spec
        from repro.launch.sharding import shard_map_compat

        n = mesh.shape[shard_axis]
        rows_tot = cache_table.shape[0]
        assert rows_tot % n == 0, (rows_tot, n)
        rps = rows_tot // n
        dp, bspec = _dp_spec(mesh, shard_axis)

        def body(tbl, lr, lw):
            # each shard gathers only the lanes whose row it owns (the
            # elementwise shard_slot_map works on [B, K]); dead + foreign
            # lanes are zero-weighted and the partials psum — only zero
            # terms are added, so integer-exact inputs stay bitwise equal
            # to the single-device gather
            shard = jax.lax.axis_index(shard_axis)
            local = shard_slot_map(lr, shard, rps)
            w_eff = jnp.where(local >= 0, lw, 0.0)
            if impl == "reference":
                part = slot_gather_agg_ref(tbl, local, w_eff)
            else:
                part = slot_gather_agg_pallas(tbl, local, w_eff,
                                              block_d=block_d,
                                              interpret=_interpret())
            return jax.lax.psum(part, shard_axis)

        fn = shard_map_compat(
            body, mesh=mesh,
            in_specs=(P(shard_axis, None), P(bspec, None), P(bspec, None)),
            out_specs=P(bspec, None))
        return fn(cache_table, lane_rows, lane_w)

    if impl == "reference":
        return slot_gather_agg_ref(cache_table, lane_rows, lane_w)
    return slot_gather_agg_pallas(cache_table, lane_rows, lane_w,
                                  block_d=block_d, interpret=_interpret())

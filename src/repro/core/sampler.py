"""§3.3 — Minibatch samplers: GNS + the paper's three baselines.

All samplers are host-side (the paper samples in CPU, §2.2) and fully
vectorized numpy.  They emit :class:`repro.core.minibatch.MiniBatch` objects
with run-constant padded shapes.

Implemented:

* :class:`NeighborSampler` — node-wise neighbor sampling (GraphSAGE/NS), the
  paper's primary baseline.
* :class:`GNSSampler`      — the paper's contribution: cache-prioritized
  sampling with importance correction; input layer samples *only* from the
  cache (§4.1 setup).
* :class:`LadiesSampler`   — layer-dependent importance sampling (LADIES),
  with the paper's observed isolated-node pathology measurable per batch.
* :class:`LazyGCNSampler`  — mega-batch recycling (LazyGCN): fresh NS sample
  every R iterations, recycled in between (recycle growth rate rho).

Weight conventions (all carried in ``nbr_w`` so the device step is identical
for every sampler — one compiled train_step serves all four):

* NS:     w = 1/|valid lanes|                       (plain mean, unbiased)
* GNS:    cached lane  w = 1/(p_u^(ℓ) · deg(v)),    p from eq. (11)–(12)
          top-up lane  w = |N(v)\\C| / (t_v · deg(v))
          → E[Σ w·h] = full-neighborhood *mean* (property-tested)
* LADIES: w = row-normalized 1/q_u  (the LADIES P̃ row normalization)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.featurestore import CacheConfig, CacheState
from repro.core.importance import importance_coefficients
from repro.core.minibatch import (DeviceBatch, LayerBlock, MiniBatch,
                                  block_pad_sizes, make_block, pad_to)
from repro.featurestore.store import FeatureStore, Generation
from repro.graph.csr import CSRGraph


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    fanouts: Sequence[int] = (5, 10, 15)   # input-layer first (paper: 15,10,5 top-down)
    batch_size: int = 1000
    # GNS
    cache: CacheConfig = dataclasses.field(default_factory=CacheConfig)
    importance_mode: str = "ht"            # "ht" | "paper"  (see importance.py)
    backend: str = "host"                  # "host" | "device" — where the GNS
                                           # input layer draws (device = the
                                           # fused Pallas/jnp sampler over the
                                           # generation's cache_adj CSR)
    # LADIES
    layer_size: int = 512                  # nodes sampled per layer
    lane_cap: int = 32                     # max edges kept per dst row (HT-subsampled)
    # LazyGCN
    recycle_period: int = 2                # R
    recycle_growth: float = 1.1            # rho

    @property
    def num_layers(self) -> int:
        return len(self.fanouts)


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

class _Stamp:
    """O(1) membership/local-index lookup over node ids, reusable across calls."""

    def __init__(self, num_nodes: int):
        self._ver = np.zeros(num_nodes, dtype=np.int64)
        self._idx = np.zeros(num_nodes, dtype=np.int64)
        self._gen = 0

    def set(self, ids: np.ndarray):
        self._gen += 1
        self._ver[ids] = self._gen
        self._idx[ids] = np.arange(len(ids))

    def contains(self, ids: np.ndarray) -> np.ndarray:
        return self._ver[ids] == self._gen

    def index(self, ids: np.ndarray) -> np.ndarray:
        return self._idx[ids]


def _union_src(dst_ids: np.ndarray, nbrs: np.ndarray, mask: np.ndarray,
               stamp: _Stamp) -> tuple[np.ndarray, np.ndarray]:
    """src ids = dst ++ (unique new neighbors); return (src_ids, local nbr idx).

    Masked lanes map to index 0 (their weight is 0 so the gathered value is
    discarded by the aggregation).
    """
    stamp.set(dst_ids)
    flat = nbrs[mask]
    new = np.unique(flat[~stamp.contains(flat)]) if len(flat) else flat[:0]
    src_ids = np.concatenate([dst_ids, new.astype(dst_ids.dtype)])
    stamp.set(src_ids)
    idx = np.zeros(nbrs.shape, dtype=np.int64)
    idx[mask] = stamp.index(nbrs[mask])
    return src_ids, idx


def _assemble(blocks_topdown: list[LayerBlock], input_ids: np.ndarray,
              targets: np.ndarray, features: np.ndarray, labels: np.ndarray,
              pad_sizes: list[tuple[int, int]], batch_pad: int,
              store: Optional[FeatureStore] = None,
              gen: Optional[Generation] = None) -> MiniBatch:
    """Pad, split input features into cache hits vs streamed rows, count bytes."""
    blocks = list(reversed(blocks_topdown))          # input-first
    s0 = pad_sizes[0][1]
    n_in = len(input_ids)
    ids_p = pad_to(input_ids.astype(np.int64), s0)
    input_mask = np.zeros(s0, dtype=np.float32)
    input_mask[:n_in] = 1.0

    if store is not None and gen is not None:
        # tier-resolved lookup: device-cache hits + metered host-gather
        # misses; slots are DEVICE rows (placement-permuted), and
        # local_shard gates the fused kernel's psum-free fast path
        slots, streamed, num_cached, bytes_streamed, local_shard = \
            store.assemble_input(gen, ids_p, n_in)
    else:
        slots = np.full(s0, -1, dtype=np.int32)
        miss = (slots < 0) & (input_mask > 0)
        streamed = np.zeros((s0, features.shape[1]), dtype=np.float32)
        streamed[miss] = features[ids_p[miss]]       # the CPU "slice" step (§2.2 step 2)
        num_cached = 0
        bytes_streamed = int(miss.sum()) * features.shape[1] * 4
        local_shard = None

    lbl = pad_to(labels[targets].astype(np.int32), batch_pad)
    lmask = np.zeros(batch_pad, dtype=np.float32)
    lmask[:len(targets)] = 1.0

    in_blk = blocks[0]
    real_rows = in_blk.dst_mask > 0
    isolated = int((np.abs(in_blk.nbr_w[real_rows]).sum(axis=1) == 0).sum())

    dev = DeviceBatch(blocks=tuple(blocks), input_cache_slots=slots,
                      input_streamed=streamed, input_mask=input_mask,
                      labels=lbl, label_mask=lmask)
    return MiniBatch(device=dev, input_node_ids=ids_p, num_input=n_in,
                     num_cached=num_cached, bytes_streamed=bytes_streamed,
                     num_isolated=isolated, cache_gen=gen,
                     local_shard=local_shard)


# ---------------------------------------------------------------------------
# Node-wise neighbor sampling (NS — GraphSAGE baseline)
# ---------------------------------------------------------------------------

class NeighborSampler:
    """Paper baseline: uniform node-wise neighbor sampling, mean weights."""

    name = "ns"

    def __init__(self, graph: CSRGraph, cfg: SamplerConfig,
                 features: np.ndarray, labels: np.ndarray):
        self.g, self.cfg = graph, cfg
        self.features, self.labels = features, labels
        self.pad_sizes = block_pad_sizes(cfg.batch_size, cfg.fanouts)
        self._stamp = _Stamp(graph.num_nodes)

    def start_epoch(self, epoch: int, rng: np.random.Generator):
        pass  # stateless across epochs

    def sample(self, targets: np.ndarray, rng: np.random.Generator) -> MiniBatch:
        cfg = self.cfg
        ids = np.asarray(targets, dtype=np.int64)
        blocks: list[LayerBlock] = []
        for li in range(cfg.num_layers - 1, -1, -1):      # output -> input
            k = cfg.fanouts[li]
            nbrs, mask = self.g.sample_neighbors(ids, k, rng)
            src_ids, idx = _union_src(ids, nbrs, mask, self._stamp)
            cnt = np.maximum(mask.sum(axis=1, keepdims=True), 1)
            w = np.where(mask, 1.0 / cnt, 0.0)
            pad_dst, pad_src = self.pad_sizes[li]
            blocks.append(make_block(idx, w, pad_dst, pad_src))
            ids = src_ids
        return _assemble(blocks, ids, targets, self.features, self.labels,
                         self.pad_sizes, cfg.batch_size)


# ---------------------------------------------------------------------------
# GNS — the paper's contribution
# ---------------------------------------------------------------------------

class GNSSampler:
    """Cache-prioritized neighbor sampling with importance correction (§3).

    The cache lifecycle is delegated to a :class:`FeatureStore`: the store
    owns the versioned generations (membership + staging + device table +
    induced cached-neighbor subgraph), ``start_epoch`` triggers a refresh
    every ``cache.period`` epochs (paper Table 6), and with
    ``cache.async_refresh`` the next generation is built on a background
    thread while sampling continues against the live one — the sampler adopts
    the new generation at the next swap point (``adopt_generation``).
    """

    name = "gns"

    def __init__(self, graph: CSRGraph, cfg: SamplerConfig,
                 features: np.ndarray, labels: np.ndarray,
                 train_idx: Optional[np.ndarray] = None,
                 store: Optional[FeatureStore] = None):
        self.g, self.cfg = graph, cfg
        self.features, self.labels = features, labels
        self.train_idx = train_idx
        self.pad_sizes = block_pad_sizes(cfg.batch_size, cfg.fanouts)
        self._stamp = _Stamp(graph.num_nodes)
        # calibrated inclusion rate for eq. (11) under w/o-replacement caches
        # rides on each generation (store._solve_lambda); "paper" mode uses
        # the raw eq. (11) approximation.
        self.store = store if store is not None else FeatureStore(
            features, graph, cfg.cache, train_idx=train_idx,
            importance_mode=cfg.importance_mode, build_adjacency=True)
        self.store.build_adjacency = True    # §3.3 induced subgraph per refresh
        self._gen: Optional[Generation] = None
        self._epoch = -1

    # -- cache lifecycle ---------------------------------------------------
    @property
    def cache(self) -> Optional[CacheState]:
        return self._gen.state if self._gen is not None else None

    @property
    def cache_adj(self):
        return self._gen.cache_adj if self._gen is not None else None

    @property
    def _lam(self) -> Optional[float]:
        return self._gen.lam if self._gen is not None else None

    def refresh_cache(self, rng: np.random.Generator, version: int = 0):
        """Synchronous refresh + immediate adoption (seed-compatible API)."""
        self.store.refresh(rng, version=version)
        self.adopt_generation()

    def adopt_generation(self) -> bool:
        """Start sampling against the store's live generation (cheap: the
        expensive scoring/gather/adjacency work happened at build time).

        Swap-race contract (audited for the sharded path in
        tests/test_sharded_store.py): adoption only moves FORWARD — every
        batch sampled before this call keeps the generation object it was
        assembled against (``MiniBatch.cache_gen``), whose state/table pair
        (and, sharded, its per-device table shards) is immutable for the
        generation's lifetime, so a batch sampled against generation *g*
        can never resolve slots against *g+1* shard tables.
        """
        gen = self.store.generation
        if gen is None or gen is self._gen:
            return False
        assert self._gen is None or gen.version >= self._gen.version, (
            "generation adoption must be monotonic",
            gen.version, self._gen.version)
        self._gen = gen
        # streaming ingest: structure rides the swap.  A generation built
        # after a delta merge carries the post-merge graph (Generation.graph);
        # adopting it here — and only here — means every batch sampled before
        # this call used the pre-merge CSR end to end, and every batch after
        # sees the merged one, with the grown feature/label tiers adopted in
        # the same step.
        g = getattr(gen, "graph", None)
        if g is not None and g is not self.g:
            if g.num_nodes != self.g.num_nodes:
                self._stamp = _Stamp(g.num_nodes)
            self.g = g
            self.features = self.store.features
            if self.store.labels is not None:
                self.labels = self.store.labels
        return True

    def ensure_cache(self, rng: Optional[np.random.Generator] = None):
        if self._gen is None:
            self.refresh_cache(rng or np.random.default_rng(0), version=0)

    def start_epoch(self, epoch: int, rng: np.random.Generator):
        due = self._gen is None or epoch % self.cfg.cache.period == 0
        if due and (epoch != self._epoch or self._gen is None):
            if self.cfg.cache.async_refresh and self._gen is not None:
                # bounded staleness: if the previous refresh is still in
                # flight when the next one comes due, absorb it first — but
                # only up to ``refresh_timeout_s``: a straggling build (e.g.
                # a slow shard *upload*, the pipeline's straggler contract
                # extended in PR 3) must not stall the epoch, so on timeout
                # we keep consuming the old generation (paper Table 6:
                # stale caches are accuracy-neutral) and retry the absorb at
                # the next due point.
                if self.store.refreshing or self.store.swap_if_ready():
                    self.store.wait_refresh(
                        timeout=self.cfg.cache.refresh_timeout_s)
                    self.adopt_generation()
                if not self.store.refreshing:
                    self.store.begin_refresh(rng, version=epoch)
            else:
                self.refresh_cache(rng, version=epoch)
        self._epoch = epoch
        self.adopt_generation()

    # -- sampling ------------------------------------------------------------
    def _sample_layer(self, ids: np.ndarray, k: int, rng: np.random.Generator,
                      allow_topup: bool) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Returns (nbrs, mask, weights) of shape (n, k) / weights f64."""
        g, cache = self.g, self.cache
        deg = (g.indptr[ids + 1] - g.indptr[ids]).astype(np.float64)
        n_c = (self.cache_adj.indptr[ids + 1] - self.cache_adj.indptr[ids]).astype(np.float64)

        # 1) cached neighbors first (from the induced subgraph S)
        c_nbrs, c_mask = self.cache_adj.sample_neighbors(ids, k, rng)
        coeff = importance_coefficients(
            cache.probs[c_nbrs], cache.size, k, n_c[:, None],
            mode=self.cfg.importance_mode, lam=self._lam)
        w_uncond = 1.0 / (coeff * np.maximum(deg, 1.0)[:, None])

        if not allow_topup:
            # input layer: cache-only -> the cache draw is the only source of
            # randomness covering the neighborhood; use the unconditional
            # eq. (11)/(12) inclusion weights.
            return c_nbrs, c_mask, np.where(c_mask, w_uncond, 0.0)

        # Upper layers (§3.3 top-up).  Weighting must avoid double counting
        # (the paper leaves top-up weights unspecified — see importance.py):
        #  * rows with N_C(v) < k take ALL cached neighbors and top up; given
        #    the realized cache this is exact coverage of N_C plus uniform
        #    coverage of N\C -> conditional HT weights, no p^C factor:
        #       cached lane w = 1/deg,  top-up lane w = (deg-N_C)/(t_v·deg)
        #  * rows with N_C(v) >= k never see non-cached neighbors, so the
        #    cache randomness must be integrated over -> unconditional
        #    eq. (11)/(12) weights as at the input layer.
        cond_rows = (n_c < k)[:, None]
        w_cond = 1.0 / np.maximum(deg, 1.0)[:, None]
        w = np.where(c_mask, np.where(cond_rows, w_cond, w_uncond), 0.0)

        # 2) top-up lanes from non-cached neighbors
        need = k - c_mask.sum(axis=1)
        rows = np.where((need > 0) & (deg - n_c > 0))[0]
        if len(rows):
            t_nbrs, t_mask = g.sample_neighbors(ids[rows], k, rng)
            t_mask &= ~cache.in_cache[t_nbrs]            # rejection: non-cached only
            # keep at most `need` lanes per row
            lane_rank = np.cumsum(t_mask, axis=1)
            t_mask &= lane_rank <= need[rows, None]
            t_act = t_mask.sum(axis=1)
            non_c = (deg - n_c)[rows]
            tw = np.where(
                t_mask,
                (non_c / (np.maximum(t_act, 1) * np.maximum(deg[rows], 1.0)))[:, None],
                0.0)
            # pack top-up lanes into the free lanes after the cached ones
            free = ~c_mask[rows]
            free_rank = np.cumsum(free, axis=1)
            take = np.zeros_like(free)
            # map j-th valid top-up lane -> j-th free lane (vectorized pack)
            t_rank = np.cumsum(t_mask, axis=1)
            for j in range(1, k + 1):
                src_lane = (t_mask & (t_rank == j))
                dst_lane = (free & (free_rank == j))
                has = src_lane.any(axis=1) & dst_lane.any(axis=1)
                if not has.any():
                    break
                si = src_lane[has].argmax(axis=1)
                di = dst_lane[has].argmax(axis=1)
                rsel = rows[has]
                c_nbrs[rsel, di] = t_nbrs[has, si]
                c_mask[rsel, di] = True
                w[rsel, di] = tw[has, si]
            del take
        return c_nbrs, c_mask, w

    def sample(self, targets: np.ndarray, rng: np.random.Generator) -> MiniBatch:
        assert self.cache is not None, "call start_epoch/refresh_cache first"
        cfg = self.cfg
        ids = np.asarray(targets, dtype=np.int64)
        blocks: list[LayerBlock] = []
        for li in range(cfg.num_layers - 1, -1, -1):
            k = cfg.fanouts[li]
            allow_topup = li != 0        # input layer: cache only (§4.1)
            nbrs, mask, w = self._sample_layer(ids, k, rng, allow_topup)
            src_ids, idx = _union_src(ids, nbrs, mask, self._stamp)
            pad_dst, pad_src = self.pad_sizes[li]
            blocks.append(make_block(idx, np.where(mask, w, 0.0), pad_dst, pad_src))
            ids = src_ids
        return _assemble(blocks, ids, targets, self.features, self.labels,
                         self.pad_sizes, cfg.batch_size,
                         store=self.store, gen=self._gen)


# ---------------------------------------------------------------------------
# LADIES — layer-dependent importance sampling baseline
# ---------------------------------------------------------------------------

class LadiesSampler:
    """LADIES [Zou et al. '19], as benchmarked by the paper.

    q_u ∝ Σ_{v ∈ B_ℓ} Â²_{v,u} with Â row-normalized; samples ``layer_size``
    distinct nodes per layer, keeps edges between consecutive layers with
    1/(s·q_u) importance weights, row-renormalized (the LADIES P̃).  Rows with
    no sampled neighbor are the *isolated nodes* of paper Table 5.
    """

    name = "ladies"

    def __init__(self, graph: CSRGraph, cfg: SamplerConfig,
                 features: np.ndarray, labels: np.ndarray):
        self.g, self.cfg = graph, cfg
        self.features, self.labels = features, labels
        self._stamp = _Stamp(graph.num_nodes)
        self._inv_deg = 1.0 / np.maximum(graph.degrees, 1).astype(np.float64)
        b, s, L = cfg.batch_size, cfg.layer_size, cfg.num_layers
        # src chain: S_ℓ = D_ℓ + layer_size (input-first list)
        self.pad_sizes = [(b + (L - 1 - li) * s, b + (L - li) * s)
                          for li in range(L)]

    def start_epoch(self, epoch: int, rng: np.random.Generator):
        pass

    def _layer_probs(self, cur: np.ndarray) -> np.ndarray:
        """q ∝ Σ_{v∈cur} Â²_{v,·} — touched entries only."""
        g = self.g
        starts, ends = g.indptr[cur], g.indptr[cur + 1]
        lens = ends - starts
        total = int(lens.sum())
        if total == 0:
            return np.zeros(0, np.int64), np.zeros(0, np.float64)
        flat_src = np.repeat(np.arange(len(cur)), lens)
        flat_idx = np.concatenate([g.indices[s:e] for s, e in zip(starts, ends)])
        contrib = (self._inv_deg[cur[flat_src]]) ** 2
        cand, inv = np.unique(flat_idx, return_inverse=True)
        q = np.zeros(len(cand), dtype=np.float64)
        np.add.at(q, inv, contrib)
        return cand, q / q.sum()

    def sample(self, targets: np.ndarray, rng: np.random.Generator) -> MiniBatch:
        cfg = self.cfg
        ids = np.asarray(targets, dtype=np.int64)
        blocks: list[LayerBlock] = []
        K = cfg.lane_cap
        for li in range(cfg.num_layers - 1, -1, -1):
            cand, q = self._layer_probs(ids)
            s = min(cfg.layer_size, len(cand))
            if s > 0:
                gumbel = -np.log(-np.log(rng.random(len(cand)) + 1e-300) + 1e-300)
                keys = np.log(q + 1e-300) + gumbel
                picked = cand[np.argpartition(keys, -s)[-s:]]
            else:
                picked = cand
            self._stamp.set(picked)
            # node-id -> q lookup for weight computation
            qfull = np.zeros(self.g.num_nodes, dtype=np.float64)
            qfull[cand] = q
            # lanes: for each dst, neighbors ∩ picked, HT-subsampled to K
            nbrs = np.zeros((len(ids), K), dtype=np.int64)
            mask = np.zeros((len(ids), K), dtype=bool)
            w = np.zeros((len(ids), K), dtype=np.float64)
            starts, ends = self.g.indptr[ids], self.g.indptr[ids + 1]
            for r, (a, b) in enumerate(zip(starts, ends)):   # per-dst ragged; ids are small
                nb = self.g.indices[a:b]
                hit = nb[self._stamp.contains(nb)]
                if len(hit) == 0:
                    continue
                if len(hit) > K:
                    hit = rng.choice(hit, size=K, replace=False)
                    corr = 1.0   # row renorm below absorbs subsample correction
                else:
                    corr = 1.0
                m = len(hit)
                nbrs[r, :m] = hit
                mask[r, :m] = True
                w[r, :m] = corr / np.maximum(qfull[hit], 1e-12)
            rs = w.sum(axis=1, keepdims=True)
            w = np.where(mask, w / np.maximum(rs, 1e-12), 0.0)   # LADIES row norm
            src_ids, idx = _union_src(ids, nbrs, mask, self._stamp)
            pad_dst, pad_src = self.pad_sizes[li]
            blocks.append(make_block(idx, w, pad_dst, pad_src))
            ids = src_ids
        return _assemble(blocks, ids, targets, self.features, self.labels,
                         self.pad_sizes, cfg.batch_size)


# ---------------------------------------------------------------------------
# LazyGCN — mega-batch recycling baseline
# ---------------------------------------------------------------------------

class LazyGCNSampler:
    """LazyGCN [Ramezani et al. '20]: fresh NS sample every R iterations,
    recycled (identical computation graph) in between; recycle count grows by
    rho per period.  Captures the reuse/overfit tradeoff the paper measures
    (Fig. 4); the rho-growing megabatch is modeled by growing the recycle
    count (static shapes stay fixed), a simplification noted in DESIGN.md.
    """

    name = "lazygcn"

    def __init__(self, graph: CSRGraph, cfg: SamplerConfig,
                 features: np.ndarray, labels: np.ndarray):
        self.inner = NeighborSampler(graph, cfg, features, labels)
        self.cfg = cfg
        self._cached: Optional[MiniBatch] = None
        self._uses_left = 0
        self._period = 0

    @property
    def pad_sizes(self):
        return self.inner.pad_sizes

    def start_epoch(self, epoch: int, rng: np.random.Generator):
        self._cached, self._uses_left = None, 0

    def sample(self, targets: np.ndarray, rng: np.random.Generator) -> MiniBatch:
        if self._uses_left > 0 and self._cached is not None:
            self._uses_left -= 1
            mb = self._cached
            # recycled batch: zero fresh feature traffic (mega-batch stays on device)
            return dataclasses.replace(mb, bytes_streamed=0, num_input=mb.num_input)
        mb = self.inner.sample(targets, rng)
        r = max(int(round(self.cfg.recycle_period *
                          (self.cfg.recycle_growth ** self._period))), 1)
        self._period += 1
        self._cached, self._uses_left = mb, r - 1
        return mb


SAMPLERS = {
    "ns": NeighborSampler,
    "gns": GNSSampler,
    "ladies": LadiesSampler,
    "lazygcn": LazyGCNSampler,
}


def make_sampler(name: str, graph: CSRGraph, cfg: SamplerConfig,
                 features: np.ndarray, labels: np.ndarray,
                 train_idx: Optional[np.ndarray] = None,
                 store: Optional[FeatureStore] = None):
    if name == "gns":
        if getattr(cfg, "backend", "host") == "device":
            # lazy import: keeps core.sampler importable without jax and
            # avoids the sampler <-> sampling package cycle
            from repro.sampling.device_sampler import DeviceGNSSampler
            return DeviceGNSSampler(graph, cfg, features, labels,
                                    train_idx=train_idx, store=store)
        return GNSSampler(graph, cfg, features, labels, train_idx=train_idx,
                          store=store)
    return SAMPLERS[name](graph, cfg, features, labels)

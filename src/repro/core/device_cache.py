"""Device-resident feature cache + CPU↔device traffic accounting.

The paper's central systems claim is that a small device-pinned cache removes
most of the host→device feature traffic (Fig. 1: 60–80% of step time is data
copy).  :class:`DeviceCache` owns the cached feature rows on device;
:class:`TrafficMeter` accounts every byte that crosses the host boundary so
the benchmark harness can reproduce the paper's breakdown (Fig. 2, Table 4).

On a pod, the cache tensor is *sharded over the model axis* (row-wise); the
single-device path here is the degenerate 1-shard case.  ``sharding`` may be
any ``jax.sharding.Sharding`` — the dry-run passes a NamedSharding over the
production mesh.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import CacheState


@dataclasses.dataclass
class TrafficMeter:
    """Aggregate host↔device + host-memory traffic counters (bytes / seconds)."""
    bytes_streamed: int = 0        # host -> device feature rows (PCIe analog)
    bytes_sliced: int = 0          # host-memory gather (CPU bandwidth, step 2)
    bytes_cache_fill: int = 0      # one-time cache refresh transfers
    t_sample: float = 0.0
    t_slice: float = 0.0
    t_copy: float = 0.0
    t_compute: float = 0.0
    steps: int = 0

    def add_batch(self, bytes_streamed: int):
        self.bytes_streamed += bytes_streamed
        self.bytes_sliced += bytes_streamed
        self.steps += 1

    def breakdown(self) -> dict:
        total = self.t_sample + self.t_slice + self.t_copy + self.t_compute
        return {
            "sample_s": round(self.t_sample, 4),
            "slice_s": round(self.t_slice, 4),
            "copy_s": round(self.t_copy, 4),
            "compute_s": round(self.t_compute, 4),
            "total_s": round(total, 4),
            "bytes_streamed": self.bytes_streamed,
            "bytes_cache_fill": self.bytes_cache_fill,
            "steps": self.steps,
        }


class DeviceCache:
    """Features of the cached nodes, pinned on device (§3.2).

    ``refresh`` uploads the feature rows of a new :class:`CacheState`
    generation; the trainer then assembles input-layer features as::

        h0 = where(slot >= 0, cache_table[slot], streamed_rows)

    inside the jitted step (see models/graphsage.py).
    """

    def __init__(self, feat_dim: int, size: int,
                 sharding: Optional[jax.sharding.Sharding] = None,
                 dtype=jnp.float32):
        self.feat_dim = feat_dim
        self.size = size
        self.sharding = sharding
        self.dtype = dtype
        self.table: Optional[jax.Array] = None
        self.version: int = -1

    def refresh(self, cache: CacheState, host_features: np.ndarray,
                meter: Optional[TrafficMeter] = None) -> jax.Array:
        t0 = time.perf_counter()
        rows = host_features[cache.node_ids].astype(np.float32)
        rows = np.pad(rows, ((0, self.size - len(rows)), (0, 0)))
        tbl = jnp.asarray(rows, dtype=self.dtype)
        if self.sharding is not None:
            tbl = jax.device_put(tbl, self.sharding)
        self.table = tbl
        self.version = cache.version
        if meter is not None:
            meter.bytes_cache_fill += rows.nbytes
            meter.t_copy += time.perf_counter() - t0
        return tbl

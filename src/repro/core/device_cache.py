"""DEPRECATED import path — device-tier machinery lives in
:mod:`repro.featurestore`.

One-release deprecation re-export (PR 4): :class:`TrafficMeter` /
:class:`TierStats` forward to :mod:`repro.featurestore.meter`; the seed-era
``DeviceCache`` single-table uploader is gone — its behavior is a strict
subset of :class:`repro.featurestore.store.FeatureStore` (tiering, policy
plug-in, shard-aware upload, async double-buffered refresh).  Migrate with
``from repro.featurestore import TrafficMeter``; this shim will be removed
in the release after next.
"""
from __future__ import annotations

import warnings

warnings.warn(
    "repro.core.device_cache is deprecated: import TrafficMeter/TierStats "
    "from repro.featurestore instead (DeviceCache was absorbed by "
    "FeatureStore; this re-export shim will be removed next release)",
    DeprecationWarning, stacklevel=2)

from repro.featurestore.meter import TierStats, TrafficMeter    # noqa: E402

__all__ = ["TrafficMeter", "TierStats"]

"""Device feature cache (compatibility shim).

:class:`TrafficMeter` moved to :mod:`repro.featurestore.meter` (now with
per-tier hit/miss/byte accounting); the device-table lifecycle moved into
:class:`repro.featurestore.store.FeatureStore`, which pairs every uploaded
table with the :class:`CacheState` generation it was built from.

:class:`DeviceCache` is kept for callers that only need the bare
"upload these rows" behavior of the seed implementation.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.featurestore.meter import TierStats, TrafficMeter
from repro.featurestore.store import CacheState

__all__ = ["DeviceCache", "TrafficMeter", "TierStats"]


class DeviceCache:
    """Features of the cached nodes, pinned on device (§3.2).

    Superseded by :class:`repro.featurestore.store.FeatureStore` (which adds
    tiering, policy plug-in, and async double-buffered refresh); retained as
    the minimal single-table uploader.
    """

    def __init__(self, feat_dim: int, size: int,
                 sharding: Optional[jax.sharding.Sharding] = None,
                 dtype=jnp.float32):
        self.feat_dim = feat_dim
        self.size = size
        self.sharding = sharding
        self.dtype = dtype
        self.table: Optional[jax.Array] = None
        self.version: int = -1

    def refresh(self, cache: CacheState, host_features: np.ndarray,
                meter: Optional[TrafficMeter] = None) -> jax.Array:
        t0 = time.perf_counter()
        rows = host_features[cache.node_ids].astype(np.float32)
        rows = np.pad(rows, ((0, self.size - len(rows)), (0, 0)))
        tbl = jnp.asarray(rows, dtype=self.dtype)
        if self.sharding is not None:
            tbl = jax.device_put(tbl, self.sharding)
        self.table = tbl
        self.version = cache.version
        if meter is not None:
            meter.bytes_cache_fill += rows.nbytes
            meter.t_copy += time.perf_counter() - t0
        return tbl

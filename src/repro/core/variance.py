"""§3.5 probes: empirical estimator variance / gradient-MSE trends.

Theorem 1 predicts the sampled-gradient MSE shrinks as cache fraction C̃ and
average degree C_d grow (the 1/(c·C̃·C_d·N₁N₂) terms).  We cannot re-derive
the constants, but we *can* verify the monotone trend empirically — these
probes back tests/test_variance.py and benchmarks/bench_convergence.py.
"""
from __future__ import annotations

import numpy as np

from repro.featurestore import CacheConfig
from repro.core.sampler import GNSSampler, NeighborSampler, SamplerConfig
from repro.graph.csr import CSRGraph


def full_neighbor_mean(g: CSRGraph, h: np.ndarray, nodes: np.ndarray) -> np.ndarray:
    """Exact one-hop mean aggregation (the eq. 5 target)."""
    out = np.zeros((len(nodes), h.shape[1]), dtype=np.float64)
    for r, v in enumerate(nodes):
        nb = g.neighbors(v)
        if len(nb):
            out[r] = h[nb].mean(axis=0)
    return out


def sampled_mean_once(sampler, nodes: np.ndarray, h: np.ndarray,
                      rng: np.random.Generator) -> np.ndarray:
    """One-draw weighted estimate using a 1-layer sampler's block.

    For a 1-layer sampler the block's src array *is* the input-node array, so
    the block gather indexes directly into ``h[input_node_ids]``.
    """
    mb = sampler.sample(nodes, rng)
    blk = mb.device.blocks[-1]                 # output layer block
    feat = h[mb.input_node_ids]
    d = len(nodes)
    w = blk.nbr_w[:d][..., None]
    gathered = feat[blk.nbr_idx[:d]]
    return (w * gathered).sum(axis=1)


def estimator_mse(g: CSRGraph, h: np.ndarray, nodes: np.ndarray,
                  sampler_name: str, fanout: int, cache_fraction: float,
                  trials: int, seed: int = 0,
                  labels: np.ndarray | None = None) -> float:
    """Monte-Carlo MSE of the sampled one-hop mean vs the exact mean."""
    rng = np.random.default_rng(seed)
    cfg = SamplerConfig(fanouts=(fanout,), batch_size=len(nodes),
                        cache=CacheConfig(fraction=cache_fraction, period=1))
    lbl = labels if labels is not None else np.zeros(g.num_nodes, np.int32)
    if sampler_name == "gns":
        s = GNSSampler(g, cfg, h.astype(np.float32), lbl)
        s.start_epoch(0, rng)
    else:
        s = NeighborSampler(g, cfg, h.astype(np.float32), lbl)
        s.start_epoch(0, rng)
    target = full_neighbor_mean(g, h, nodes)
    errs = []
    for t in range(trials):
        if sampler_name == "gns" and t and t % 8 == 0:
            s.refresh_cache(rng, version=t)    # re-randomize the cache too
        est = sampled_mean_once(s, nodes, h, rng)
        errs.append(((est - target) ** 2).mean())
    return float(np.mean(errs))

"""§3.2 — Sample Cache (compatibility shim).

The cache machinery was absorbed into :mod:`repro.featurestore`:

* probability constructions (eq. 6, eqs. 7–9, reverse PageRank, adaptive)
  live in :mod:`repro.featurestore.policies` behind the ``CachePolicy``
  registry;
* ``CacheConfig`` / ``CacheState`` / ``sample_cache`` / ``cache_probs`` live
  in :mod:`repro.featurestore.store` next to the :class:`FeatureStore`
  facade that owns cache generations at runtime.

This module re-exports the original names so existing imports keep working.
"""
from __future__ import annotations

from repro.featurestore.policies import (degree_cache_probs,
                                         random_walk_cache_probs,
                                         reverse_pagerank_cache_probs,
                                         uniform_cache_probs)
from repro.featurestore.store import (CacheConfig, CacheState, cache_probs,
                                      resolve_strategy, sample_cache)

__all__ = [
    "CacheConfig", "CacheState", "cache_probs", "resolve_strategy",
    "sample_cache", "degree_cache_probs", "random_walk_cache_probs",
    "reverse_pagerank_cache_probs", "uniform_cache_probs",
]

"""DEPRECATED import path — the §3.2 cache machinery lives in
:mod:`repro.featurestore`.

This module is a one-release deprecation re-export (PR 4): importing it
warns, and every name forwards to its real home —

* probability constructions (eq. 6, eqs. 7–9, reverse PageRank, adaptive)
  -> :mod:`repro.featurestore.policies`;
* ``CacheConfig`` / ``CacheState`` / ``sample_cache`` / ``cache_probs`` /
  ``resolve_strategy`` -> :mod:`repro.featurestore.store`.

Migrate with ``from repro.featurestore import CacheConfig`` (see README
"Engine API" for the full migration table).  The module will be removed in
the release after next.
"""
from __future__ import annotations

import warnings

warnings.warn(
    "repro.core.cache is deprecated: import CacheConfig/CacheState/"
    "sample_cache/cache_probs from repro.featurestore instead "
    "(this re-export shim will be removed next release)",
    DeprecationWarning, stacklevel=2)

from repro.featurestore.policies import (degree_cache_probs,            # noqa: E402
                                         random_walk_cache_probs,
                                         reverse_pagerank_cache_probs,
                                         uniform_cache_probs)
from repro.featurestore.store import (CacheConfig, CacheState,          # noqa: E402
                                      cache_probs, resolve_strategy,
                                      sample_cache)

__all__ = [
    "CacheConfig", "CacheState", "cache_probs", "resolve_strategy",
    "sample_cache", "degree_cache_probs", "random_walk_cache_probs",
    "reverse_pagerank_cache_probs", "uniform_cache_probs",
]

"""§3.2 — Sample Cache.

GNS periodically samples a global node set C (the *cache*) whose features are
pinned in device memory.  Two probability constructions from the paper:

* eq. (6): degree-proportional — used when most nodes are training nodes.
      p_i = deg(i) / Σ_k deg(k)
* eqs. (7)–(9): L-step random-walk mass from the training set — used when the
  training set is a small fraction of V (e.g. ogbn-papers100M, 1% train).
      P^0 = uniform on V_S;   P^ℓ = (D·A + I) P^{ℓ-1},  D = diag(fanout_ℓ/deg)

The cache is resampled every ``period`` epochs (paper Table 6: P ∈ {1,2,5,10};
P ≤ 5 with |C| = 1%·|V| is accuracy-neutral).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.graph.csr import CSRGraph


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    fraction: float = 0.01          # |C| / |V|   (paper default 1%)
    period: int = 1                 # refresh every `period` epochs (Table 6 P)
    strategy: str = "auto"          # degree | random_walk | uniform | auto
    train_frac_threshold: float = 0.5   # auto: degree if train_frac >= this
    walk_fanouts: Sequence[int] = (15, 10, 5)  # per-layer fanouts for eq. (7)

    def size(self, num_nodes: int) -> int:
        return max(int(num_nodes * self.fraction), 1)


def degree_cache_probs(g: CSRGraph) -> np.ndarray:
    """eq. (6): p_i = deg(i) / Σ deg(k)."""
    deg = g.degrees.astype(np.float64)
    s = deg.sum()
    if s == 0:
        return np.full(g.num_nodes, 1.0 / g.num_nodes)
    return deg / s


def random_walk_cache_probs(g: CSRGraph, train_idx: np.ndarray,
                            fanouts: Sequence[int]) -> np.ndarray:
    """eqs. (7)–(9): L-step fanout-weighted walk mass from the training set.

    P^ℓ = (D·A + I) P^{ℓ-1} with D = diag(fanout_ℓ / deg).  The product
    fanout/deg is exactly the probability that a specific neighbor is drawn by
    node-wise sampling with that layer's fanout, so P^L is the expected
    visitation mass of node-wise sampling rooted at the training set.
    """
    n = g.num_nodes
    p = np.zeros(n, dtype=np.float64)
    p[train_idx] = 1.0 / max(len(train_idx), 1)
    deg = np.maximum(g.degrees, 1).astype(np.float64)
    src = np.repeat(np.arange(n, dtype=np.int64), g.degrees)  # edge sources
    dst = g.indices.astype(np.int64)
    for fanout in fanouts:
        scale = np.minimum(fanout / deg, 1.0)                 # row weight of D·A
        contrib = p[src] * scale[src]
        nxt = p.copy()                                        # the +I term
        np.add.at(nxt, dst, contrib)
        p = nxt
        s = p.sum()
        if s > 0:
            p /= s
    return p


def cache_probs(g: CSRGraph, cfg: CacheConfig,
                train_idx: Optional[np.ndarray] = None) -> np.ndarray:
    strategy = cfg.strategy
    if strategy == "auto":
        train_frac = 0.0 if train_idx is None else len(train_idx) / g.num_nodes
        strategy = "degree" if train_frac >= cfg.train_frac_threshold else "random_walk"
        if train_idx is None:
            strategy = "degree"
    if strategy == "degree":
        return degree_cache_probs(g)
    if strategy == "random_walk":
        assert train_idx is not None, "random_walk strategy needs train_idx"
        return random_walk_cache_probs(g, train_idx, cfg.walk_fanouts)
    if strategy == "uniform":
        return np.full(g.num_nodes, 1.0 / g.num_nodes)
    raise ValueError(f"unknown cache strategy: {strategy}")


@dataclasses.dataclass
class CacheState:
    """One sampled cache generation (versioned for async refresh at pod scale)."""
    node_ids: np.ndarray        # int64 [|C|]  sorted
    probs: np.ndarray           # float64 [V]  the distribution it was drawn from
    in_cache: np.ndarray        # bool [V]
    slot_of: np.ndarray         # int32 [V]  position in node_ids or -1
    version: int = 0

    @property
    def size(self) -> int:
        return len(self.node_ids)


def sample_cache(g: CSRGraph, cfg: CacheConfig, rng: np.random.Generator,
                 train_idx: Optional[np.ndarray] = None,
                 probs: Optional[np.ndarray] = None,
                 version: int = 0) -> CacheState:
    """Draw the cache without replacement according to the §3.2 distribution."""
    if probs is None:
        probs = cache_probs(g, cfg, train_idx)
    size = min(cfg.size(g.num_nodes), int((probs > 0).sum()))
    # Efficient weighted sampling w/o replacement: Gumbel top-k on log p.
    with np.errstate(divide="ignore"):
        logp = np.log(probs)
    gumbel = -np.log(-np.log(rng.random(g.num_nodes) + 1e-300) + 1e-300)
    keys = np.where(np.isfinite(logp), logp + gumbel, -np.inf)
    ids = np.sort(np.argpartition(keys, -size)[-size:].astype(np.int64))
    in_cache = np.zeros(g.num_nodes, dtype=bool)
    in_cache[ids] = True
    slot_of = np.full(g.num_nodes, -1, dtype=np.int32)
    slot_of[ids] = np.arange(size, dtype=np.int32)
    return CacheState(node_ids=ids, probs=probs, in_cache=in_cache,
                      slot_of=slot_of, version=version)

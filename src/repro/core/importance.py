"""§3.4 — Importance sampling coefficients (eqs. 10–12).

Cached neighbors are biased samples; eq. (10) rescales aggregated features by
1/p so the sampled aggregation is an unbiased estimator of the full-neighbor
aggregation (eq. 5):

    p_u^C      = 1 - (1 - p_u)^{|C|}                       (eq. 11)
    p_u^(ℓ-1)  = p_u^C * k / min(k, N_C(v))                (eq. 12, as printed)
    h_N(v)     = f({ 1/p_u^(ℓ-1) * h_u })                  (eq. 10)

where p_u is the cache-sampling probability of u (eq. 6 / eq. 8), k the
fanout, and N_C(v) the number of v's neighbors present in the cache.

Faithfulness note (documented in DESIGN.md): the paper's eq. (12) as printed
is not the Horvitz–Thompson inclusion probability of its own §3.3 sampling
procedure (take min(k, N_C(v)) cached neighbors *without replacement*), and
the paper itself is inconsistent between eq. (10) (weights 1/p) and
Algorithm 1 line 17 (weights p).  The HT inclusion probability of the
procedure is

    p_u^(ℓ-1) = p_u^C * min(k, N_C(v)) / N_C(v)            ("ht" mode)

which is what makes eq. (5)/(B.15) (unbiasedness) actually hold — and what
the convergence proof assumes.  We therefore default to ``mode="ht"`` and
property-test unbiasedness against a brute-force full aggregation
(tests/test_importance.py); ``mode="paper"`` implements eq. (12) literally
for fidelity comparisons.

Numerics: (1-p)^{|C|} underflows for hub nodes (p·|C| ≫ 1) so p^C saturates
at 1 — hubs are effectively always cached.  Computed via log1p/expm1; the
final inverse weight is clamped to keep variance bounded.
"""
from __future__ import annotations

import warnings

import numpy as np


def cache_hit_prob(p: np.ndarray, cache_size: int,
                   lam: float | None = None) -> np.ndarray:
    """eq. (11): probability a node lands in a |C|-sized cache drawn from p.

    With ``lam=None`` this is the paper's independence approximation
    (sampling w/o replacement treated as |C| independent draws); stable for
    tiny p via log1p.  With a calibrated ``lam`` (see
    :func:`solve_inclusion_lambda`) it is the successive-sampling inclusion
    probability 1 - exp(-λ·p), which removes the systematic hub bias of
    eq. (11) under without-replacement caches (measured at +10–15% E[Σw]
    inflation on power-law hubs — see tests/test_importance.py).
    """
    p = np.asarray(p, dtype=np.float64)
    if lam is not None and not (np.isfinite(lam) and lam > 0):
        # degenerate calibration (failed bracket, inf/nan, non-positive):
        # the λ path would return inclusion probabilities that don't sum to
        # |C| — fall back to the independence approximation instead.
        warnings.warn(
            f"cache_hit_prob: degenerate lam={lam!r}; falling back to the "
            "independence approximation (eq. 11)", RuntimeWarning)
        lam = None
    if lam is None:
        return -np.expm1(cache_size * np.log1p(-np.minimum(p, 1.0 - 1e-12)))
    return -np.expm1(-lam * p)


def solve_inclusion_lambda(probs: np.ndarray, cache_size: int,
                           tol: float = 1e-6,
                           max_iter: int = 200) -> float | None:
    """Calibrate λ so that Σ_i (1 - exp(-λ p_i)) = |C|.

    This is the classic inclusion-probability approximation for weighted
    sampling without replacement (successive sampling / Gumbel top-k): the
    paper's eq. (11) corresponds to λ = |C|, which *undershoots* when the
    distribution is skewed (hub probabilities saturate, so the remaining mass
    must be upweighted).  One-time cost per cache distribution — the GNS
    distribution is global and static (§3.6), so this is amortized like the
    distribution itself.

    Degenerate inputs return ``None`` with a warning, which callers
    (``cache_hit_prob(lam=None)``) treat as "use the independence
    approximation": a cache at least as large as the positive-probability
    support (every such node is included w.p. 1, λ* = ∞), an all-zero
    probability vector, or a bracket that fails to close numerically.
    """
    p = np.asarray(probs, dtype=np.float64)
    p = p[p > 0]
    if len(p) == 0:
        warnings.warn("solve_inclusion_lambda: all-zero probability vector; "
                      "falling back to the independence approximation",
                      RuntimeWarning)
        return None
    if cache_size >= len(p):
        warnings.warn(
            f"solve_inclusion_lambda: cache_size={cache_size} >= "
            f"{len(p)} positive-probability nodes — every node is cached "
            "(λ* = ∞); falling back to the independence approximation",
            RuntimeWarning)
        return None
    m = float(cache_size)

    def total(lam: float) -> float:
        return float(-np.expm1(-lam * p).sum())

    lo = float(cache_size)          # Σ(1-e^{-mp}) <= Σ m·p = m, so λ* >= m
    hi = lo
    for _ in range(64):
        if total(hi) >= m * (1 - 1e-12):
            break
        hi *= 2.0
    else:
        warnings.warn(
            "solve_inclusion_lambda: bisection failed to bracket "
            f"(cache_size={cache_size}, support={len(p)}); falling back to "
            "the independence approximation", RuntimeWarning)
        return None
    for _ in range(max_iter):
        mid = 0.5 * (lo + hi)
        if total(mid) < m:
            lo = mid
        else:
            hi = mid
        if hi - lo <= tol * max(lo, 1.0):
            break
    return 0.5 * (lo + hi)


def importance_coefficients(neighbor_probs: np.ndarray,
                            cache_size: int,
                            fanout: int,
                            num_cached_neighbors: np.ndarray,
                            mode: str = "ht",
                            lam: float | None = None) -> np.ndarray:
    """Per-sampled-neighbor inclusion coefficient p_u^(ℓ-1).

    Args:
      neighbor_probs: p_u (cache distribution mass) for each sampled cached
        neighbor, shape (..., k).
      cache_size: |C|.
      fanout: k.
      num_cached_neighbors: N_C(v) of the destination node, broadcastable.
      mode: "ht" (Horvitz–Thompson, unbiased — default) or "paper" (eq. 12
        literal).

    Callers aggregate with weight 1/p_u^(ℓ-1) (eq. 10).  Clamped below so the
    inverse weight stays bounded.
    """
    p_c = cache_hit_prob(neighbor_probs, cache_size, lam=lam)
    ncv = np.maximum(np.asarray(num_cached_neighbors, dtype=np.float64), 1.0)
    k = float(fanout)
    if mode == "ht":
        coeff = p_c * np.minimum(k, ncv) / ncv
    elif mode == "paper":
        coeff = p_c * (k / np.minimum(k, ncv))
    else:
        raise ValueError(f"unknown importance mode: {mode}")
    return np.maximum(coeff, 1e-6)

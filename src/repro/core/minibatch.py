"""Static-shape padded minibatch blocks.

DGL (the paper's substrate) builds *ragged* message-flow blocks per minibatch.
XLA/TPU requires static shapes, so we adapt the block format (DESIGN.md §2):

Every GNN layer ℓ is a :class:`LayerBlock` mapping a padded source-node array
(representations at layer ℓ-1) to a padded destination-node array (layer ℓ):

* ``nbr_idx[d, k]`` — index into this block's **source axis** of the k-th
  sampled neighbor of destination d.  Pure gather; no scatter needed.
* ``nbr_w[d, k]``  — aggregation weight.  Carries BOTH the importance-sampling
  correction of eq. (10)–(12) AND the mean normalization; padded lanes are 0,
  so masked lanes drop out of the weighted sum for free.
* destinations are the **first** ``num_dst`` entries of the source array, so
  the self-representation needed by GraphSAGE's concat is ``h_src[:num_dst]``.

The padded layout turns sparse neighbor aggregation into a dense
``gather + weighted sum over k`` — exactly the shape the Pallas ``gather_agg``
kernel consumes (kernels/gather_agg.py), and MXU/VPU-friendly on TPU.

All arrays are numpy on the host; the trainer ships the *device part* (a
registered pytree, :class:`DeviceBatch`) to the accelerator each step.  Shapes
depend only on (batch, fanouts), never on the sampled graph — one XLA
compilation for the whole run.  Host-only metadata (actual node counts, bytes
streamed) lives on :class:`MiniBatch` and never enters the traced path, so
varying counts cannot trigger recompilation.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np
import jax


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LayerBlock:
    nbr_idx: np.ndarray   # int32 [D, K] gather indices into src axis
    nbr_w: np.ndarray     # f32   [D, K] aggregation weights (0 = masked lane)
    dst_mask: np.ndarray  # f32   [D]    1 for real dst rows
    num_src: int = dataclasses.field(metadata=dict(static=True), default=0)
    num_dst: int = dataclasses.field(metadata=dict(static=True), default=0)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DeviceBatch:
    """The traced pytree a train/eval step consumes.

    The last three fields are populated only by the device-backend GNS
    sampler (``repro.sampling.device_sampler``): host-sampled fallback
    lanes for input rows the cache does not cover, and the batch's
    stateless-RNG key for the on-device layer-0 draw.  Host-backend
    batches leave them ``None`` (the pytree simply has fewer leaves).
    """
    blocks: tuple                  # tuple[LayerBlock], input -> output order
    input_cache_slots: np.ndarray  # int32 [S0]  slot in device cache or -1
    input_streamed: np.ndarray     # f32 [S0, F] host-gathered rows (0 for hits)
    input_mask: np.ndarray         # f32 [S0]
    labels: np.ndarray             # int32 [B]
    label_mask: np.ndarray         # f32 [B]
    input_fb_rows: object = None   # int32 [S0, K0] host-fallback lanes as
                                   # device-table rows (-1 = dead lane)
    input_fb_w: object = None      # f32 [S0, K0] fallback lane weights
    sample_key: object = None      # uint32 [G, 2] per-batch draw key
                                   # (G = collated DP groups)


@dataclasses.dataclass
class MiniBatch:
    """Host-side minibatch: device pytree + untraced bookkeeping."""
    device: DeviceBatch
    input_node_ids: np.ndarray     # int64 [S0] global ids (pad = 0)
    num_input: int = 0             # distinct input nodes (paper Table 4)
    num_cached: int = 0            # of which served by the device cache
    bytes_streamed: int = 0        # host->device feature bytes this batch
    num_isolated: int = 0          # input-layer dst rows with no valid lane (Table 5)
    cache_gen: object = None       # featurestore.Generation the slots index into
                                   # (pairs slots with THEIR device table — on a
                                   # sharded mesh, with their per-device table
                                   # shards — so an async cache swap can never
                                   # tear a batch; retention of a superseded
                                   # generation's O(V) state is bounded by the
                                   # prefetch depth — at most `depth` queued
                                   # batches hold it)
    local_shard: object = None     # int when EVERY cache hit of this batch
                                   # resolves on the requesting DP group's
                                   # home shard (locality-aware placement) —
                                   # gates the fused kernel's psum-free fast
                                   # path; None = cross-shard psum required

    @property
    def cache_version(self) -> int:
        """Version of the generation the slots resolve against (-1 = none)."""
        return self.cache_gen.version if self.cache_gen is not None else -1


def block_pad_sizes(batch_size: int, fanouts: Sequence[int]) -> list[tuple[int, int]]:
    """Static (num_dst, num_src) per block, input-layer first.

    Worst case without dedup: S_ℓ = D_ℓ·(1+k_ℓ), chained from the output layer
    (D_L = batch) down to the input layer.  Dedup only shrinks the *real*
    counts; padding uses the bound so shapes are run-constant.
    """
    sizes = []
    d = batch_size
    for k in reversed(list(fanouts)):      # output layer first
        s = d * (1 + k)
        sizes.append((d, s))
        d = s
    return list(reversed(sizes))           # back to input-first


def pad_to(arr: np.ndarray, n: int, axis: int = 0, fill=0) -> np.ndarray:
    pad = n - arr.shape[axis]
    assert pad >= 0, f"padded size {n} < actual {arr.shape[axis]}"
    if pad == 0:
        return arr
    widths = [(0, 0)] * arr.ndim
    widths[axis] = (0, pad)
    return np.pad(arr, widths, constant_values=fill)


def make_block(nbr_idx: np.ndarray, nbr_w: np.ndarray,
               pad_dst: int, pad_src: int) -> LayerBlock:
    """Pad a ragged (D, K) block to the static (pad_dst, K) shape."""
    d, _ = nbr_idx.shape
    dst_mask = np.zeros(pad_dst, dtype=np.float32)
    dst_mask[:d] = 1.0
    return LayerBlock(
        nbr_idx=pad_to(nbr_idx.astype(np.int32), pad_dst, axis=0),
        nbr_w=pad_to(nbr_w.astype(np.float32), pad_dst, axis=0),
        dst_mask=dst_mask,
        num_src=pad_src,
        num_dst=pad_dst,
    )

"""Sampler pipeline: epoch iteration + asynchronous prefetch.

The paper parallelizes sampling with multiprocessing (§3.3) so the GPU never
waits for the CPU.  This container has one core, so we use a bounded-queue
*thread* prefetcher — the numpy sampler releases the GIL in its hot loops and
at pod scale there is one sampler pipeline per host anyway.

Straggler mitigation (DESIGN.md §4): the queue is bounded and the consumer
can specify a timeout; on timeout it *reuses the previous cache version /
last batch* rather than blocking the whole data-parallel step — exploiting
the paper's own Table 6 result that stale caches (refresh period P ≤ 5) are
accuracy-neutral.

The same contract covers slow shard **uploads** (PR 3): ``swap_if_ready``
only ever publishes a *completed* build (upload included), so the between-
batches poll below never blocks on one; and with
``CacheConfig(refresh_timeout_s=...)`` the epoch-boundary absorb in
``GNSSampler.start_epoch`` gives a straggling upload a bounded grace window
and then keeps training on the old generation instead of stalling the
producer (which would in turn trip the Prefetcher's batch-reuse path
downstream).
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Iterator, Optional

import numpy as np

from repro.analysis import guarded_by
from repro.core.minibatch import MiniBatch


class EpochLoader:
    """Shuffles targets, drives the sampler's cache lifecycle, yields batches.

    Drop-last semantics (static shapes want full batches; the paper's epoch
    is |V_s|/batch_size iterations, same convention).

    When the sampler sits on a :class:`repro.featurestore.FeatureStore` with
    an async refresh in flight, the loader polls ``swap_if_ready`` between
    batches: a completed shadow generation is atomically published and the
    sampler adopts it before the next ``sample`` call, so refresh cost
    overlaps sampling/compute instead of stalling the step.
    """

    def __init__(self, sampler, train_idx: np.ndarray, seed: int = 0,
                 max_batches: Optional[int] = None, dp_groups: int = 1):
        """``dp_groups`` > 1 is the engine's DP regime: batch ``i`` belongs
        to DP group ``i % dp_groups`` (the store's per-group histograms and
        home-shard metering follow), the epoch is truncated to whole group
        rounds, and generation swaps are only polled at round boundaries so
        the ``dp_groups`` batches collated into one train step always share
        one cache generation."""
        self.sampler = sampler
        self.train_idx = np.asarray(train_idx, dtype=np.int64)
        self.seed = seed
        self.max_batches = max_batches
        self.dp_groups = max(int(dp_groups), 1)

    def _poll_store(self):
        """Swap point: publish a completed shadow generation, then have the
        sampler adopt it BEFORE the next ``sample`` call.

        Ordering matters for the swap-race contract (see
        ``GNSSampler.adopt_generation``): the swap and the adoption both
        happen here, between batches, on the sampling thread — never while a
        batch is being assembled — so a single batch's slot map, weights and
        cache adjacency all come from one generation.  Already-queued batches
        keep their own ``cache_gen`` (and its immutable device table /
        per-device shards); only future batches see the new generation.
        """
        store = getattr(self.sampler, "store", None)
        if store is not None and store.swap_if_ready():
            adopt = getattr(self.sampler, "adopt_generation", None)
            if adopt is not None:
                adopt()

    def epoch(self, epoch: int) -> Iterator[MiniBatch]:
        rng = np.random.default_rng(self.seed + 7919 * epoch)
        self.sampler.start_epoch(epoch, rng)
        b = self.sampler.cfg.batch_size if hasattr(self.sampler, "cfg") \
            else self.sampler.inner.cfg.batch_size
        perm = rng.permutation(len(self.train_idx))
        n_batches = len(self.train_idx) // b
        if self.max_batches is not None:
            n_batches = min(n_batches, self.max_batches)
        rounded = n_batches - n_batches % self.dp_groups   # whole rounds only
        if n_batches and not rounded:
            raise ValueError(
                f"epoch yields {n_batches} minibatch(es) but dp_groups="
                f"{self.dp_groups} needs at least one full round per step — "
                f"lower batch_size or raise max_batches")
        n_batches = rounded
        store = getattr(self.sampler, "store", None)
        for i in range(n_batches):
            if i % self.dp_groups == 0:
                self._poll_store()
            if store is not None and self.dp_groups > 1:
                store.dp_group = i % self.dp_groups
            targets = self.train_idx[perm[i * b:(i + 1) * b]]
            # per-batch seeded generator: batch (epoch, i) draws the same
            # sample no matter how the prefetcher thread interleaves with
            # cache refreshes or how many batches preceded it — the
            # host-vs-device statistical parity tests (and any replay)
            # depend on this; the epoch rng above stays dedicated to the
            # permutation + cache lifecycle
            batch_rng = np.random.default_rng(
                np.random.SeedSequence((self.seed & 0xFFFFFFFF, epoch, i)))
            yield self.sampler.sample(targets, batch_rng)


@guarded_by("_lock", writes_only=("_err",))
class Prefetcher:
    """Bounded-queue background prefetch with straggler timeout.

    ``wait_s`` accumulates the consumer's time blocked on the queue — the
    *sampler-stall* metric (ROADMAP item 2): when the host sampler is the
    bottleneck the consumer idles here instead of stepping the device.
    With ``meter`` set, the same time lands on
    ``TrafficMeter.t_prefetch_wait`` so the benchmark breakdown reports it.
    """

    _SENTINEL = object()

    def __init__(self, it: Iterator[MiniBatch], depth: int = 2,
                 timeout_s: Optional[float] = None, meter=None):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._timeout = timeout_s
        self._meter = meter
        self._lock = threading.Lock()   # guards the producer's _err publish
                                        # (consumer reads it lock-free after
                                        # the SENTINEL — queue.put/get is the
                                        # happens-before edge)
        self._err: Optional[BaseException] = None
        self._last: Optional[MiniBatch] = None
        self.reused = 0                       # straggler-mitigation reuse count
        self.wait_s = 0.0                     # consumer time blocked on queue
        self._thread = threading.Thread(target=self._run, args=(it,), daemon=True)
        self._thread.start()

    def _run(self, it):
        try:
            for item in it:
                self._q.put(item)
        except BaseException as e:  # surfaced on the consumer side
            with self._lock:
                self._err = e
        finally:
            self._q.put(self._SENTINEL)

    def _note_wait(self, dt: float):
        self.wait_s += dt
        if self._meter is not None:
            self._meter.t_prefetch_wait += dt

    def __iter__(self):
        while True:
            t0 = time.perf_counter()
            try:
                item = self._q.get(timeout=self._timeout)
            except queue.Empty:
                self._note_wait(time.perf_counter() - t0)
                # straggler: reuse the last batch instead of stalling the step
                if self._last is None:
                    t1 = time.perf_counter()
                    item = self._q.get()      # nothing to reuse yet: block
                    self._note_wait(time.perf_counter() - t1)
                else:
                    self.reused += 1
                    yield self._last
                    continue
            else:
                self._note_wait(time.perf_counter() - t0)
            if item is self._SENTINEL:
                if self._err is not None:
                    raise self._err
                return
            self._last = item
            yield item

"""GNS core — the paper's contribution (KDD'21).

Pieces map 1:1 onto the paper's sections:

* :mod:`repro.core.sampler`     — §3.3 cache-prioritized neighbor sampling + the
  three baselines the paper compares against (NS, LADIES, LazyGCN)
* :mod:`repro.core.importance`  — §3.4 importance coefficients (eq. 11–12)
* :mod:`repro.core.minibatch`   — static-shape padded minibatch blocks (TPU
  adaptation of DGL's ragged blocks; see DESIGN.md §2)
* :mod:`repro.core.pipeline`    — threaded prefetch (the paper's multiprocessing
  sampler, adapted to a 1-core container / per-host thread at pod scale)
* :mod:`repro.core.variance`    — §3.5 empirical gradient-MSE / variance probes

The §3.2 cache machinery (``CacheConfig`` / ``sample_cache`` / the policy
probability constructions) and the traffic meter live in
:mod:`repro.featurestore`; this package re-exports the common names for
convenience.  (The deprecated ``repro.core.cache`` / ``repro.core
.device_cache`` shim paths were removed after their one-release grace
period — import from ``repro.featurestore``.)
"""
from repro.featurestore import (CacheConfig, TrafficMeter,
                                degree_cache_probs, random_walk_cache_probs,
                                sample_cache)
from repro.core.sampler import (
    GNSSampler, NeighborSampler, LadiesSampler, LazyGCNSampler, SamplerConfig)
from repro.core.importance import cache_hit_prob, importance_coefficients
from repro.core.minibatch import MiniBatch, LayerBlock

__all__ = [
    "CacheConfig", "degree_cache_probs", "random_walk_cache_probs", "sample_cache",
    "GNSSampler", "NeighborSampler", "LadiesSampler", "LazyGCNSampler", "SamplerConfig",
    "cache_hit_prob", "importance_coefficients",
    "MiniBatch", "LayerBlock", "TrafficMeter",
]

"""LR schedules as step -> multiplier functions (composed with AdamConfig.lr)."""
from __future__ import annotations

import jax.numpy as jnp


def constant():
    return lambda step: jnp.ones((), dtype=jnp.float32)


def warmup_cosine(warmup_steps: int, total_steps: int, min_frac: float = 0.1):
    def sched(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / jnp.maximum(warmup_steps, 1), 1.0)
        prog = jnp.clip((s - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return warm * cos
    return sched


def inverse_sqrt(warmup_steps: int):
    def sched(step):
        s = jnp.maximum(step.astype(jnp.float32), 1.0)
        w = float(max(warmup_steps, 1))
        return jnp.minimum(s / w, jnp.sqrt(w / s))
    return sched

"""AdamW in pure JAX with giant-model memory options.

Memory modes (DESIGN.md §4 — what makes arctic-480b fit 16 GB/chip v5e):

* ``moment_dtype=bf16``: first/second moments in bfloat16 halves optimizer
  state (the update math still runs in f32; moments are rounded on store).
  Classic trick from large-scale MoE training; convergence impact is
  negligible for the second moment and small for the first at these scales.
* the *sharding* of the moments follows the parameters, so with ZeRO-style
  fully-sharded params (launch/sharding.py) the optimizer state is fully
  sharded too.

The optimizer is a pytree-in/pytree-out pure function — safe under jit,
shard_map and microbatch accumulation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 3e-3                 # paper §4.1: ADAM, lr 0.003
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: Optional[float] = None
    moment_dtype: Any = jnp.float32  # jnp.bfloat16 for giant MoE configs


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gnorm


class AdamW:
    """Functional AdamW: ``state = init(params)``, ``params, state = update(...)``."""

    def __init__(self, cfg: AdamConfig = AdamConfig(),
                 lr_schedule: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None):
        self.cfg = cfg
        self.lr_schedule = lr_schedule

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, dtype=self.cfg.moment_dtype)
        return {
            "m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), dtype=jnp.int32),
        }

    def update(self, grads, state, params):
        cfg = self.cfg
        if cfg.clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, cfg.clip_norm)
        step = state["step"] + 1
        lr = cfg.lr if self.lr_schedule is None else self.lr_schedule(step) * cfg.lr
        b1, b2 = cfg.b1, cfg.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g32
            v32 = v.astype(jnp.float32) * b2 + (1 - b2) * jnp.square(g32)
            mhat = m32 / bc1
            vhat = v32 / bc2
            delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
            if cfg.weight_decay:
                delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - lr * delta
            return (newp.astype(p.dtype),
                    m32.astype(cfg.moment_dtype),
                    v32.astype(cfg.moment_dtype))

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "step": step}

"""Gradient compression with error feedback for the DP all-reduce path.

At 1000+ nodes the data-parallel all-reduce of gradients is a first-order
cost (roofline collective term).  We implement the standard int8 uniform
quantization with *error feedback* (EF-SGD, Karimireddy et al. '19): the
quantization residual is carried to the next step, which restores the full
convergence rate of SGD/Adam despite ~4x less all-reduce traffic.

Usage inside a shard_map'd train step::

    q, scale = compress_int8(grad)
    q_sum   = jax.lax.psum(q.astype(jnp.int32), axis_name="data")
    grad'   = q_sum.astype(jnp.float32) * scale / n_shards

The compressed representation is what crosses ICI; the roofline analysis
counts the 1-byte payload (launch/dryrun.py lowers both variants so the
collective-bytes delta is visible in §Perf).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


def compress_int8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization: x ≈ q * scale."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


@dataclasses.dataclass
class ErrorFeedbackState:
    residual: Any  # pytree matching grads

    @staticmethod
    def init(params):
        return ErrorFeedbackState(
            residual=jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))


def ef_compress_update(grads, ef: ErrorFeedbackState,
                       axis_name: str | None = None):
    """Error-feedback compressed (pseudo-)all-reduce.

    Adds the carried residual, quantizes to int8, optionally psums across
    ``axis_name`` (when called inside shard_map), and stores the new residual
    = (input - quantized).  Returns (decompressed grads, new EF state).
    """
    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, scale = compress_int8(x)
        if axis_name is not None:
            qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
            n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
            out = qsum.astype(jnp.float32) * scale / n
        else:
            out = decompress_int8(q, scale)
        new_r = x - decompress_int8(q, scale)
        return out, new_r

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = treedef.unflatten([o[0] for o in outs])
    new_r = treedef.unflatten([o[1] for o in outs])
    return new_g, ErrorFeedbackState(residual=new_r)

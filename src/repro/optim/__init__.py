"""Optimizers + distributed-optimization tricks (pure JAX, no optax)."""
from repro.optim.adam import AdamW, AdamConfig, clip_by_global_norm
from repro.optim.schedules import warmup_cosine, constant
from repro.optim.compression import (compress_int8, decompress_int8,
                                     ErrorFeedbackState, ef_compress_update)

__all__ = [
    "AdamW", "AdamConfig", "clip_by_global_norm",
    "warmup_cosine", "constant",
    "compress_int8", "decompress_int8", "ErrorFeedbackState",
    "ef_compress_update",
]

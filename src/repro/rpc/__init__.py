"""Cross-host serving transport (stdlib + numpy only).

The fabric's cross-HOST leg: length-prefixed binary framing with zero-copy
numpy payloads (:mod:`repro.rpc.wire`), a retrying heartbeat-carrying client
:class:`~repro.rpc.channel.Channel`, a per-host worker process
(:class:`~repro.rpc.endpoint.WorkerEndpoint`, ``python -m
repro.rpc.endpoint``), and the :class:`~repro.rpc.proxy.RemoteWorkerProxy`
that slots into :class:`~repro.serve.fabric.ServeFabric` unchanged
(``FabricConfig(transport="tcp", endpoints=("host:port", ...))``).

Deliberately importable without jax: the coordinator half (wire, channel,
proxy) runs on a bare CPU host; only the endpoint pulls in the engine.
"""
from .channel import Channel, RpcError
from .proxy import RemoteWorkerProxy, parse_endpoint
from .wire import (ChannelClosed, FrameError, MAX_FRAME_BYTES, decode_frame,
                   encode_frame, pack_table, recv_frame, send_frame,
                   unpack_table)

__all__ = [
    "Channel", "ChannelClosed", "FrameError", "MAX_FRAME_BYTES",
    "RemoteWorkerProxy", "RpcError", "WorkerEndpoint", "decode_frame",
    "encode_frame", "pack_table", "parse_endpoint", "recv_frame",
    "send_frame", "unpack_table",
]


def __getattr__(name):
    # WorkerEndpoint imports the serve stack (which imports the engine's
    # dependencies on use) — resolve it lazily so `import repro.rpc` stays
    # cheap on coordinator-only hosts
    if name == "WorkerEndpoint":
        from .endpoint import WorkerEndpoint
        return WorkerEndpoint
    raise AttributeError(name)

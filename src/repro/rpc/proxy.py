"""RemoteWorkerProxy — a fabric worker whose compute lives across TCP.

The proxy satisfies the exact surface ``ServeFabric`` + its watchdog consume
from :class:`~repro.serve.fabric.FabricWorker` (``start/alive/join/kill``,
``beat_age``, ``take_inflight``, ``backlog``, ``.scheduler``, ``.batcher``,
``.copy_meter``, ``.index``/``.group``), so the fabric's admission control,
weighted-fair scheduling, routing, STALLED/DEAD watchdog semantics and
failover re-routing all work UNCHANGED over the wire:

* admission + fair order stay coordinator-side: ``fabric.submit`` offers
  into the proxy's real :class:`FairScheduler`; a sender thread pops in
  weighted-fair order and ships REQUEST frames (at most
  ``ServeConfig.max_queue`` outstanding — backlog beyond that stays in the
  scheduler where per-tenant quotas keep meaning something);
* shipped-but-unanswered requests live in ``_outstanding`` — the remote
  analogue of the worker's in-flight batch.  When the channel dies the
  sender thread exits, the watchdog sees ``alive() == False`` (the DEAD
  path), and ``take_inflight()`` hands the orphans back for re-routing on
  survivors — capped by ``FabricConfig.max_retries`` then ``WorkerDown``,
  exactly the in-proc chaos contract;
* ``beat_age`` merges local heartbeat silence with the endpoint's own
  reported worker beat age, so the STALLED path fires both for a dead
  network and for a wedged remote compute loop;
* ``kill()`` severs the connection (a network partition in one call — the
  chaos tests' remote analogue of the in-proc kill hook).
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.analysis import guarded_by
from repro.featurestore.meter import TrafficMeter
from repro.serve.batcher import MicroBatcher
from repro.serve.metrics import BatchRecord
from repro.serve.server import ServeResult
from repro.serve.tenancy import FairScheduler

from . import wire
from .channel import Channel, RpcError


def parse_endpoint(addr: str) -> Tuple[str, int]:
    """``"host:port"`` (or bare ``":port"`` / ``"port"``) -> (host, port)."""
    if ":" in addr:
        host, _, port = addr.rpartition(":")
        return host or "127.0.0.1", int(port)
    return "127.0.0.1", int(addr)


@guarded_by("_plock", "_outstanding")
class RemoteWorkerProxy:
    """Drop-in fabric worker backed by one :class:`Channel` to an endpoint.

    ``_outstanding`` (req id -> (pending, t_sent)) is written by the sender
    thread and the channel's receiver thread, reclaimed by the watchdog —
    all under ``_plock``.
    """

    def __init__(self, fabric, index: int, address: str):
        self.fabric = fabric
        self.index = index
        self.group = index
        self.address = address
        cfg, serve_cfg = fabric.cfg, fabric.serve_cfg
        self.scheduler = FairScheduler(
            cfg.tenants, default_weight=cfg.default_weight,
            default_quota=cfg.default_quota)
        # interface parity only (capacity check, stop()-time drain): the
        # remote batcher does the real coalescing
        self.batcher = MicroBatcher(
            serve_cfg.buckets, max_wait_s=serve_cfg.max_wait_ms * 1e-3,
            max_queue=max(serve_cfg.max_queue, 2 * len(serve_cfg.buckets)))
        # this proxy's wire traffic (tx under the channel send lock, rx on
        # its receiver thread) — aggregated by ServeFabric.snapshot()
        self.copy_meter = TrafficMeter()
        self.channel = Channel(
            name=f"worker{index}", meter=self.copy_meter,
            on_frame=self._on_frame,
            seed=fabric.engine.cfg.seed + 0xC4A + index)
        self._plock = threading.Lock()
        self._outstanding: Dict[int, tuple] = {}
        self._req_seq = 0               # sender thread only
        self._inflight_cap = max(serve_cfg.max_queue, 1)
        self._sender: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    # worker interface (what fabric/watchdog/stop call)
    # ------------------------------------------------------------------
    def start(self) -> None:
        assert self._sender is None, "proxy already started"
        cfg = self.fabric.cfg
        host, port = parse_endpoint(self.address)
        self.channel.connect(
            host, port, timeout_s=cfg.connect_timeout_ms * 1e-3,
            retries=cfg.connect_retries,
            backoff_s=cfg.connect_backoff_ms * 1e-3)
        _kind, meta, arrays = self.channel.call(
            wire.HELLO, {"index": self.index},
            timeout=max(cfg.connect_timeout_ms * 1e-3, 30.0))
        self.fabric._adopt_remote_table(self.index, wire.unpack_table(
            meta, arrays))
        self._sender = threading.Thread(
            target=self._send_loop, daemon=True,
            name=f"gns-rpc-send-{self.index}")
        self._sender.start()

    def alive(self) -> bool:
        t = self._sender
        return t is not None and t.is_alive()

    def join(self, timeout: float) -> None:
        t = self._sender
        if t is not None:
            t.join(timeout)

    def kill(self) -> None:
        """Chaos hook: sever the connection (a one-call network partition).
        The endpoint keeps running; this coordinator's watchdog reclaims."""
        self.channel.close()

    def beat_age(self, now: float) -> float:
        return self.channel.beat_age(now)

    def take_inflight(self) -> List:
        """Watchdog reclaim of shipped-but-unanswered requests — only
        meaningful once the sender thread is dead (channel down: no RESULT
        can race the reclaim)."""
        with self._plock:
            out = [p for p, _t in self._outstanding.values()]
            self._outstanding = {}
        return out

    def backlog(self) -> int:
        return self.scheduler.qsize() + self.inflight_count() \
            + self.batcher.qsize()

    def inflight_count(self) -> int:
        with self._plock:
            return len(self._outstanding)

    # ------------------------------------------------------------------
    # control plane
    # ------------------------------------------------------------------
    def request_refresh(self, version: Optional[int] = None) -> None:
        try:
            self.channel.send(wire.REFRESH, {"version": version})
        except RpcError:
            pass                        # dead channel: watchdog's business

    def fetch_remote_stats(self, timeout: float = 5.0) -> dict:
        _kind, meta, _arrays = self.channel.call(
            wire.STATS_REQ, timeout=timeout)
        return meta

    # ------------------------------------------------------------------
    # sender thread: scheduler -> wire, weighted-fair, bounded in-flight
    # ------------------------------------------------------------------
    def _send_loop(self) -> None:
        fab = self.fabric
        try:
            while True:
                if not self.channel.rpc_connected:
                    return
                if fab.stopping and (not fab.drain_on_stop
                                     or self._drained()):
                    return
                if self.inflight_count() >= self._inflight_cap:
                    time.sleep(0.001)
                    continue
                nxt = self.scheduler.pop()
                if nxt is None:
                    self.scheduler.work_ev.wait(timeout=0.02)
                    continue
                tenant, p = nxt
                now = time.monotonic()
                self._req_seq += 1
                rid = self._req_seq
                with self._plock:
                    self._outstanding[rid] = (p, now)
                meta = {"req": rid, "tenant": tenant,
                        "attempts": p.attempts,
                        "deadline_ms": (max((p.deadline - now) * 1e3, 0.0)
                                        if p.deadline is not None else None)}
                try:
                    self.channel.send(wire.REQUEST, meta,
                                      {"ids": p.node_ids})
                except RpcError:
                    # p stays in _outstanding: the watchdog's DEAD path
                    # reclaims it via take_inflight()
                    return
        finally:
            if fab.stopping:
                # drained (or drain disabled): a clean goodbye — the
                # endpoint goes back to accept() with a warm replica
                self.channel.close()

    def _drained(self) -> bool:
        return (self.scheduler.qsize() == 0 and self.inflight_count() == 0
                and self.batcher.qsize() == 0)

    # ------------------------------------------------------------------
    # receiver callback (channel recv thread)
    # ------------------------------------------------------------------
    def _on_frame(self, kind: int, meta: dict, arrays: dict) -> None:
        fab = self.fabric
        if kind == wire.RESULT:
            rid = int(meta["req"])
            with self._plock:
                entry = self._outstanding.pop(rid, None)
            if entry is None:
                return              # already reclaimed/re-routed elsewhere
            p, t_sent = entry
            now = time.monotonic()
            status = meta.get("status", "error")
            total_s = now - p.t_submit
            if status == "ok":
                remote_total = float(meta.get("remote_total_s", 0.0))
                # wire + (de)serialization time: round trip minus the span
                # the endpoint actually held the request
                rpc_s = max((now - t_sent) - remote_total, 0.0)
                qw = (t_sent - p.t_submit) \
                    + float(meta.get("queue_wait_s", 0.0))
                compute_s = float(meta.get("compute_s", 0.0))
                late = p.deadline is not None and now > p.deadline
                res = ServeResult(
                    logits=np.array(arrays["logits"], copy=True),
                    status="ok", queue_wait_s=qw, compute_s=compute_s,
                    total_s=total_s, bucket=int(meta.get("bucket", 0)),
                    cache_version=int(meta.get("cache_version", -1)))
                fab.meter.observe_request(qw, compute_s, total_s,
                                          tenant=p.tenant, late=late,
                                          rpc_s=rpc_s)
                p.future._complete(res)
            elif status == "expired":
                fab.meter.observe_expired(total_s, tenant=p.tenant)
                p.future._complete(ServeResult(
                    logits=None, status="expired", queue_wait_s=total_s,
                    total_s=total_s))
            else:
                fab.meter.observe_error(1)
                p.future._fail(RpcError(meta.get("error", "remote error")))
        elif kind == wire.BATCH:
            fab.meter.observe_batch(
                BatchRecord(
                    bucket=int(meta["bucket"]),
                    n_requests=int(meta["n_requests"]),
                    n_ids=int(meta["n_ids"]),
                    compute_s=float(meta["compute_s"]),
                    cache_version=int(meta["cache_version"]),
                    hit_fraction=float(meta["hit_fraction"])),
                worker=self.index)
        elif kind == wire.SWAPPED:
            fab._on_remote_swap(self.index,
                                wire.unpack_table(meta, arrays))
        elif kind == wire.ERROR:
            fab._note_fabric_error(RpcError(
                meta.get("error", f"endpoint {self.index} reported a "
                                  "fatal error")))

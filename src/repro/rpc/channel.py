"""Client side of the transport: one TCP connection to one endpoint.

A :class:`Channel` owns the socket, a receiver thread, and the liveness
bookkeeping the fabric watchdog consumes:

- **connect** retries with exponential backoff and *deterministic* jitter
  (seeded rng — chaos tests replay bit-for-bit);
- **send** serializes frame writes under a send lock and books
  ``bytes_rpc_tx`` on the channel's TrafficMeter;
- **call** is the request/response helper for control RPCs (HELLO,
  STATS_REQ): a per-request deadline bounds the wait, correlation rides
  the reserved ``rpc_id`` meta key;
- the receiver thread dispatches HEARTBEAT frames into lock-free-readable
  liveness fields (``beat_age`` mirrors the in-proc worker contract:
  local silence + the remote worker's own reported beat age) and hands
  every other frame to the owner's ``on_frame`` callback.

Disconnect (EOF, RST, frame garbage) fails all pending calls and flips
``rpc_connected`` — the proxy's sender thread exits on seeing it, which is
exactly the "thread gone" signal the watchdog's DEAD path keys on.
"""
from __future__ import annotations

import socket
import threading
import time
from typing import Callable, Dict, Optional

import numpy as np

from repro.analysis import TrackedLock, guarded_by, sanitizer_enabled

from . import wire


class RpcError(RuntimeError):
    """Transport-level failure: connect exhausted, channel closed, call
    timed out, or the peer reported an error."""


class _CallSlot:
    """One outstanding control RPC (event + first-wins result)."""

    __slots__ = ("_ev", "_reply", "_err")

    def __init__(self):
        self._ev = threading.Event()
        self._reply = None
        self._err: Optional[BaseException] = None

    def complete(self, reply) -> None:
        self._reply = reply
        self._ev.set()

    def fail(self, err: BaseException) -> None:
        self._err = err
        self._ev.set()

    def wait(self, timeout: float):
        if not self._ev.wait(timeout):
            raise TimeoutError("rpc call timed out")
        if self._err is not None:
            raise self._err
        return self._reply


@guarded_by("_clock", "_pending_rpc",
            writes_only=("rpc_connected", "hb_mono", "hb_remote_age_s"))
class Channel:
    """One coordinator-side connection; thread-safe send + receiver loop.

    ``rpc_connected`` / ``hb_mono`` / ``hb_remote_age_s`` follow the
    writes_only snapshot contract: written under ``_clock``, read lock-free
    by the watchdog via :meth:`beat_age` and by the proxy's ``alive``.
    """

    def __init__(self, name: str = "rpc", meter=None,
                 on_frame: Optional[Callable] = None, seed: int = 0):
        self.name = name
        self.meter = meter                  # TrafficMeter (this channel's)
        self.on_frame = on_frame
        self._clock = threading.Lock()
        # send serialization is its own lock (never held across recv);
        # wrapped so the sanitizer's lock-order graph sees it
        lk = threading.Lock()
        self._send_mu = (TrackedLock(lk, "Channel._send_mu")
                         if sanitizer_enabled() else lk)
        self._pending_rpc: Dict[int, _CallSlot] = {}
        self.rpc_connected = False
        self.hb_mono = time.monotonic()
        self.hb_remote_age_s = 0.0
        self.tx_frames = 0                  # send-lock holders only
        self.rx_frames = 0                  # receiver thread only
        self._rpc_seq = 0                   # call() issuers under _send_mu
        self._sock: Optional[socket.socket] = None
        self._recv_thread: Optional[threading.Thread] = None
        self._jitter = np.random.default_rng(seed)

    # ------------------------------------------------------------------
    def connect(self, host: str, port: int, *, timeout_s: float = 5.0,
                retries: int = 5, backoff_s: float = 0.05) -> None:
        """Dial with bounded retries + exponential backoff.  Jitter comes
        from the channel's seeded rng, so a replayed chaos run retries on
        the exact same schedule."""
        last: Optional[BaseException] = None
        for attempt in range(retries + 1):
            try:
                s = socket.create_connection((host, port), timeout=timeout_s)
                s.settimeout(None)
                s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                self._sock = s
                with self._clock:
                    self.rpc_connected = True
                    self.hb_mono = time.monotonic()
                t = threading.Thread(target=self._recv_loop, daemon=True,
                                     name=f"gns-rpc-recv-{self.name}")
                self._recv_thread = t
                t.start()
                return
            except OSError as e:
                last = e
                if attempt < retries:
                    delay = (backoff_s * (2 ** attempt)
                             * (1.0 + 0.25 * float(self._jitter.random())))
                    time.sleep(delay)
        raise RpcError(f"connect to {host}:{port} failed after "
                       f"{retries + 1} attempts: {last}")

    # ------------------------------------------------------------------
    def send(self, kind: int, meta=None, arrays=None) -> int:
        """Write one frame (serialized against other senders)."""
        with self._send_mu:
            sock = self._sock
            if sock is None or not self.rpc_connected:
                raise RpcError(f"channel {self.name} is closed")
            try:
                n = wire.send_frame(sock, kind, meta, arrays)
            except OSError as e:
                self._mark_dead()
                raise RpcError(f"send on {self.name} failed: {e}") from e
            self.tx_frames += 1
            if self.meter is not None:
                self.meter.bytes_rpc_tx += n
            return n

    def call(self, kind: int, meta=None, arrays=None,
             timeout: float = 10.0):
        """Request/response control RPC with a per-request deadline.
        Returns ``(kind, meta, arrays)`` of the reply."""
        with self._send_mu:
            self._rpc_seq += 1
            rid = self._rpc_seq
        slot = _CallSlot()
        with self._clock:
            self._pending_rpc[rid] = slot
        md = dict(meta or {})
        md["rpc_id"] = rid
        try:
            self.send(kind, md, arrays)
            return slot.wait(timeout)
        finally:
            with self._clock:
                self._pending_rpc.pop(rid, None)

    # ------------------------------------------------------------------
    def beat_age(self, now: float) -> float:
        """Watchdog liveness signal: local heartbeat silence plus the
        remote worker's own reported beat age, so a stalled remote compute
        loop surfaces through a perfectly healthy TCP connection."""
        return max(now - self.hb_mono, 0.0) + self.hb_remote_age_s

    def close(self) -> None:
        self._mark_dead()

    # ------------------------------------------------------------------
    def _mark_dead(self) -> None:
        with self._clock:
            self.rpc_connected = False
            pend, self._pending_rpc = dict(self._pending_rpc), {}
        sock, self._sock = self._sock, None
        if sock is not None:
            try:
                sock.close()
            except OSError:
                pass
        for slot in pend.values():
            slot.fail(RpcError(f"channel {self.name} disconnected"))

    def _recv_loop(self) -> None:
        sock = self._sock
        try:
            while sock is not None:
                kind, meta, arrays, n = wire.recv_frame(sock)
                self.rx_frames += 1
                if self.meter is not None:
                    self.meter.bytes_rpc_rx += n
                if kind == wire.HEARTBEAT:
                    with self._clock:
                        self.hb_mono = time.monotonic()
                        self.hb_remote_age_s = float(
                            meta.get("beat_age_s", 0.0))
                    continue
                rid = meta.get("rpc_id")
                if rid is not None:
                    with self._clock:
                        slot = self._pending_rpc.pop(rid, None)
                    if slot is not None:
                        slot.complete((kind, meta, arrays))
                        continue
                cb = self.on_frame
                if cb is not None:
                    cb(kind, meta, arrays)
        except (wire.ChannelClosed, wire.FrameError, OSError):
            pass
        finally:
            self._mark_dead()

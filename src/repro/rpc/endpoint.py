"""Server side of the transport: one process hosting one fabric worker.

A :class:`WorkerEndpoint` owns its OWN engine + cache-generation replica
(built from the same ``EngineConfig`` JSON the coordinator holds, with the
same seeded rng streams — generation 0 and the per-worker sampling rng are
therefore bitwise-identical to the in-proc fabric's, which is what makes
``transport="tcp"`` results bitwise-equal to ``transport="inproc"``) and
mirrors the FabricWorker serve loop:

    recv REQUEST -> micro-batcher -> infer_prepare/infer_compute
    -> RESULT (+ one BATCH record per served batch)

plus a heartbeat thread (liveness + the worker's own beat age, so a stalled
compute loop is visible through a healthy TCP connection), REFRESH handling
(the coordinator's watchdog drives the refresh cadence; the endpoint swaps
locally and ships the new routing table back in a SWAPPED frame), and a
STATS reply for cross-host tenant aggregation.

Run one per host::

    python -m repro.rpc.endpoint --config engine.json --index 0 --port 0

``--port 0`` binds an ephemeral port; the chosen one is announced on stdout
as ``GNS_ENDPOINT_READY host=... port=... index=...`` before serving.
The endpoint survives coordinator disconnects (it keeps listening), so a
rebooted coordinator re-adopts a warm replica.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import socket
import threading
import time
from typing import List, Optional

import numpy as np

from repro.analysis import guarded_by
from repro.serve.batcher import MicroBatcher
from repro.serve.metrics import BatchRecord, ServeMeter

from . import wire


@dataclasses.dataclass
class _EpPending:
    """One request in the endpoint's batcher (batcher contract: it reads
    ``node_ids`` and ``deadline``)."""
    req: int                          # coordinator correlation id
    node_ids: np.ndarray
    tenant: str
    t_recv: float                     # endpoint-local monotonic receipt
    deadline: Optional[float]         # endpoint-local monotonic absolute


@guarded_by("_esend", "_ep_conn")
@guarded_by("_elock", writes_only=("ep_last_beat",))
class WorkerEndpoint:
    """One remote fabric worker: engine replica + serve loop + transport.

    ``_ep_conn`` (the live coordinator connection) is guarded by the send
    lock ``_esend`` — every frame write and the accept/EOF swaps happen
    under it.  ``ep_last_beat`` follows the FabricWorker writes_only
    contract: written under ``_elock`` once per loop, read lock-free by the
    heartbeat thread.
    """

    def __init__(self, engine, index: int = 0, *, host: str = "127.0.0.1",
                 port: int = 0, heartbeat_ms: float = 100.0):
        self.engine = engine
        self.index = index
        self.group = index              # DP group / home shard, as in-proc
        self.host = host
        self.port = port
        self.heartbeat_ms = heartbeat_ms
        serve_cfg = engine.cfg.serve_config()
        self.serve_cfg = serve_cfg
        self.batcher = MicroBatcher(
            serve_cfg.buckets, max_wait_s=serve_cfg.max_wait_ms * 1e-3,
            max_queue=max(serve_cfg.max_queue, 2 * len(serve_cfg.buckets)))
        self.meter = ServeMeter(latency_window=serve_cfg.latency_window)
        # same rng streams as the in-proc fabric: worker sampling rng and
        # the refresh/cold-start rng — bitwise generation parity
        self._rng = np.random.default_rng(engine.cfg.seed + 0xFAB0 + index)
        self._refresh_rng = np.random.default_rng(engine.cfg.seed + 0x5E12)
        self._esend = threading.Lock()
        self._ep_conn: Optional[socket.socket] = None
        self._elock = threading.Lock()
        self.ep_last_beat = time.monotonic()
        self.stall_s = 0.0              # chaos hook: sleep mid-batch
        self._stop_ev = threading.Event()
        self._lsock: Optional[socket.socket] = None
        self._threads: List[threading.Thread] = []

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def bind(self) -> int:
        """Bind + listen; returns the (possibly ephemeral) port."""
        assert self._lsock is None, "endpoint already bound"
        s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        s.bind((self.host, self.port))
        s.listen(2)
        self._lsock = s
        self.port = s.getsockname()[1]
        return self.port

    def start(self) -> "WorkerEndpoint":
        """Warm the replica (generation 0) and start the serve threads."""
        if self._lsock is None:
            self.bind()
        if not self._threads:
            self.engine.ensure_cache(self._refresh_rng)
            for target, name in ((self._compute_loop, "compute"),
                                 (self._hb_loop, "heartbeat")):
                t = threading.Thread(
                    target=target, daemon=True,
                    name=f"gns-endpoint-{self.index}-{name}")
                t.start()
                self._threads.append(t)
        return self

    def serve_forever(self) -> None:
        """Accept loop: one coordinator at a time, reconnects welcome."""
        self.start()
        self._lsock.settimeout(0.2)
        try:
            while not self._stop_ev.is_set():
                try:
                    conn, _addr = self._lsock.accept()
                except socket.timeout:
                    continue
                except OSError:
                    break
                conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                self._handle(conn)
        finally:
            self.stop()
            for t in self._threads:
                t.join(timeout=5.0)

    def serve_in_thread(self) -> threading.Thread:
        """Test/bench helper: run :meth:`serve_forever` on a daemon thread."""
        if self._lsock is None:
            self.bind()
        t = threading.Thread(target=self.serve_forever, daemon=True,
                             name=f"gns-endpoint-{self.index}-accept")
        t.start()
        return t

    def stop(self) -> None:
        self._stop_ev.set()
        ls, self._lsock = self._lsock, None
        if ls is not None:
            try:
                ls.close()
            except OSError:
                pass
        with self._esend:
            conn, self._ep_conn = self._ep_conn, None
        if conn is not None:
            try:
                conn.close()
            except OSError:
                pass

    # ------------------------------------------------------------------
    # transport
    # ------------------------------------------------------------------
    def _send(self, kind: int, meta=None, arrays=None) -> bool:
        """Ship one frame to the connected coordinator; False = no
        connection (the frame is dropped — results for a vanished
        coordinator are reclaimed on ITS side by the watchdog)."""
        with self._esend:
            conn = self._ep_conn
            if conn is None:
                return False
            try:
                n = wire.send_frame(conn, kind, meta, arrays)
            except OSError:
                self._ep_conn = None
                try:
                    conn.close()
                except OSError:
                    pass
                return False
            self.meter.traffic.bytes_rpc_tx += n
            return True

    def _handle(self, conn: socket.socket) -> None:
        with self._esend:
            self._ep_conn = conn
        try:
            while not self._stop_ev.is_set():
                kind, meta, arrays, n = wire.recv_frame(conn)
                self.meter.traffic.bytes_rpc_rx += n
                self._dispatch(kind, meta, arrays)
                if kind == wire.SHUTDOWN:
                    self._stop_ev.set()
                    return
        except (wire.ChannelClosed, wire.FrameError, OSError):
            pass                  # coordinator went away: back to accept()
        finally:
            with self._esend:
                if self._ep_conn is conn:
                    self._ep_conn = None
            try:
                conn.close()
            except OSError:
                pass

    def _dispatch(self, kind: int, meta: dict, arrays: dict) -> None:
        if kind == wire.REQUEST:
            now = time.monotonic()
            dl_ms = meta.get("deadline_ms")
            p = _EpPending(
                req=int(meta["req"]),
                # copy out of the recv buffer (the frame buffer is reused)
                node_ids=np.array(arrays["ids"], dtype=np.int64),
                tenant=str(meta.get("tenant", "default")),
                t_recv=now,
                deadline=now + dl_ms * 1e-3 if dl_ms is not None else None)
            self.meter.observe_submit(p.tenant)
            if not self.batcher.offer(p):
                self.meter.observe_reject(p.tenant)
                self._send(wire.RESULT, {
                    "req": p.req, "status": "error",
                    "error": "endpoint batcher at capacity"})
        elif kind == wire.HELLO:
            md, arrs = self._table_frame()
            md["rpc_id"] = meta.get("rpc_id")
            md["capacity"] = self.batcher.capacity
            md["index"] = self.index
            self._send(wire.HELLO_ACK, md, arrs)
        elif kind == wire.REFRESH:
            self._begin_refresh(meta.get("version"))
        elif kind == wire.STATS_REQ:
            self._send(wire.STATS, {
                "rpc_id": meta.get("rpc_id"), "index": self.index,
                "tenants": self.meter.tenant_snapshot(),
                "counters": {
                    "served": self.meter.snapshot().get("served", 0),
                    "bytes_rpc_tx": self.meter.traffic.bytes_rpc_tx,
                    "bytes_rpc_rx": self.meter.traffic.bytes_rpc_rx,
                }})
        # SHUTDOWN is handled by the recv loop; unknown-but-valid kinds are
        # ignored (forward compatibility)

    def _table_frame(self):
        store = self.engine.store
        table = store.routing_table() if store is not None else None
        md, arrs = wire.pack_table(table)
        md["version"] = store.version if store is not None else -1
        return md, arrs

    def _begin_refresh(self, version) -> None:
        store = self.engine.store
        if store is None or store.refreshing:
            return
        try:
            store.begin_refresh(
                self._refresh_rng,
                version=int(version) if version is not None
                else store.version + 1)
        except BaseException:
            self.meter.observe_refresh_failure()

    # ------------------------------------------------------------------
    # serve loop (the FabricWorker._run shape, minus the scheduler pump —
    # weighted-fair order is applied coordinator-side before shipping)
    # ------------------------------------------------------------------
    def _hb_loop(self) -> None:
        hb_s = max(self.heartbeat_ms * 1e-3, 1e-3)
        while not self._stop_ev.wait(hb_s):
            now = time.monotonic()
            self._send(wire.HEARTBEAT, {
                "beat_age_s": max(now - self.ep_last_beat, 0.0),
                "backlog": self.batcher.qsize()})

    def _poll_swap(self) -> None:
        store = self.engine.store
        if store is None:
            return
        try:
            if store.swap_if_ready():
                self.meter.observe_swap()
                md, arrs = self._table_frame()
                self._send(wire.SWAPPED, md, arrs)
        except BaseException:
            self.meter.observe_refresh_failure()

    def _compute_loop(self) -> None:
        while True:
            with self._elock:
                self.ep_last_beat = time.monotonic()
            self._poll_swap()
            batch = self.batcher.next_batch(timeout=0.02)
            if batch is None:
                if self._stop_ev.is_set():
                    return
                continue
            t_start = time.monotonic()
            live, expired = [], []
            for p in batch:
                (expired if p.deadline is not None and p.deadline < t_start
                 else live).append(p)
            for p in expired:
                self.meter.observe_expired(t_start - p.t_recv,
                                           tenant=p.tenant)
                self._send(wire.RESULT, {
                    "req": p.req, "status": "expired",
                    "queue_wait_s": t_start - p.t_recv,
                    "remote_total_s": t_start - p.t_recv})
            if not live:
                continue
            try:
                self._serve_batch(live, t_start)
            except BaseException as e:
                self.meter.observe_error(len(live))
                for p in live:
                    self._send(wire.RESULT, {
                        "req": p.req, "status": "error", "error": repr(e)})
            if self._stop_ev.is_set() and self.batcher.qsize() == 0:
                return

    def _serve_batch(self, live: List[_EpPending], t_start: float) -> None:
        eng = self.engine
        ids = np.concatenate([p.node_ids for p in live])
        bucket = self.batcher.bucket_for(len(ids))
        t0 = time.perf_counter()
        store = eng.store
        if store is not None:
            store.dp_group = self.group
            with store.serving(self.meter.traffic):
                mb = eng.infer_prepare(ids, bucket=bucket, rng=self._rng)
        else:
            mb = eng.infer_prepare(ids, bucket=bucket, rng=self._rng)
        if self.stall_s:
            time.sleep(self.stall_s)    # chaos hook: remote in-flight stall
        logits = eng.infer_compute(mb, meter=self.meter.traffic)
        compute_s = time.perf_counter() - t0
        t_done = time.monotonic()
        version = mb.cache_version
        rec = {"bucket": bucket, "n_requests": len(live), "n_ids": len(ids),
               "compute_s": compute_s, "cache_version": version,
               "hit_fraction": mb.num_cached / max(mb.num_input, 1)}
        self.meter.observe_batch(BatchRecord(**rec), worker=self.index)
        self._send(wire.BATCH, rec)
        lo = 0
        for p in live:
            n = len(p.node_ids)
            qw = t_start - p.t_recv
            self.meter.observe_request(
                qw, compute_s, t_done - p.t_recv, tenant=p.tenant,
                late=p.deadline is not None and t_done > p.deadline)
            self._send(wire.RESULT, {
                "req": p.req, "status": "ok", "queue_wait_s": qw,
                "compute_s": compute_s, "remote_total_s": t_done - p.t_recv,
                "bucket": bucket, "cache_version": version},
                {"logits": logits[lo:lo + n]})
            lo += n


# ---------------------------------------------------------------------------
# process entrypoint
# ---------------------------------------------------------------------------

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="GNS fabric worker endpoint (one per host)")
    ap.add_argument("--config", required=True,
                    help="EngineConfig JSON file (the coordinator's config)")
    ap.add_argument("--index", type=int, default=0,
                    help="worker index = DP group = home shard")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 = ephemeral (announced on stdout)")
    ap.add_argument("--heartbeat-ms", type=float, default=100.0)
    args = ap.parse_args(argv)

    from repro.gns.config import EngineConfig
    from repro.gns.engine import GNSEngine
    with open(args.config) as f:
        cfg = EngineConfig.from_dict(json.load(f))
    engine = GNSEngine(cfg)
    ep = WorkerEndpoint(engine, args.index, host=args.host, port=args.port,
                        heartbeat_ms=args.heartbeat_ms)
    port = ep.bind()
    print(f"GNS_ENDPOINT_READY host={args.host} port={port} "
          f"index={args.index}", flush=True)
    ep.serve_forever()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

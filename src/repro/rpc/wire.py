"""Length-prefixed binary wire framing with zero-copy numpy payloads.

One frame = fixed header + JSON meta + concatenated raw array bytes:

    +--------+------+-------+----------+----------+-------------+
    | magic  | kind | flags | n_arrays | meta_len | payload_len |
    | 4B     | u8   | u8    | u16      | u32      | u64         |
    +--------+------+-------+----------+----------+-------------+
    | meta: UTF-8 JSON (meta_len bytes)                         |
    +-----------------------------------------------------------+
    | payload: array bytes back to back (payload_len bytes)     |
    +-----------------------------------------------------------+

Array layout (dtype string, shape) travels inside the meta JSON under the
reserved ``__arrays__`` key, so the payload itself is raw C-contiguous
bytes — the sender hands ``memoryview``s straight to the socket (no
serialization copy of feature/logit tensors) and the receiver reconstructs
views with ``np.frombuffer``.

Everything here is stdlib + numpy only: the transport must work on a bare
CPU coordinator host with no accelerator runtime.
"""
from __future__ import annotations

import json
import struct
from typing import Dict, Mapping, Optional, Tuple

import numpy as np

MAGIC = b"GNS1"
HEADER = struct.Struct("!4sBBHIQ")          # magic kind flags n_arrays meta payload

# admission bounds: a peer announcing a giant frame is refused BEFORE any
# allocation happens (a garbage length prefix must not OOM the receiver)
MAX_META_BYTES = 1 << 24                    # 16 MiB of JSON is already absurd
MAX_FRAME_BYTES = 1 << 28                   # 256 MiB payload ceiling

# message kinds --------------------------------------------------------------
HELLO = 1          # coordinator -> endpoint: handshake (worker index)
HELLO_ACK = 2      # endpoint -> coordinator: capacity + routing table
REQUEST = 3        # coordinator -> endpoint: one serve request (ids payload)
RESULT = 4         # endpoint -> coordinator: logits / expired / error
HEARTBEAT = 5      # endpoint -> coordinator: liveness + remote beat age
BATCH = 6          # endpoint -> coordinator: one served BatchRecord
REFRESH = 7        # coordinator -> endpoint: kick an async cache refresh
SWAPPED = 8        # endpoint -> coordinator: generation swapped (new table)
STATS_REQ = 9      # coordinator -> endpoint: pull tenant/meter stats
STATS = 10         # endpoint -> coordinator: stats reply
SHUTDOWN = 11      # coordinator -> endpoint: graceful stop
ERROR = 12         # endpoint -> coordinator: fatal endpoint-side failure

KINDS = frozenset({HELLO, HELLO_ACK, REQUEST, RESULT, HEARTBEAT, BATCH,
                   REFRESH, SWAPPED, STATS_REQ, STATS, SHUTDOWN, ERROR})

_ARRAYS_KEY = "__arrays__"


class FrameError(RuntimeError):
    """Malformed frame: bad magic, truncation, oversize, garbage meta."""


class ChannelClosed(ConnectionError):
    """Peer closed the connection at a clean frame boundary."""


# ---------------------------------------------------------------------------
# encode / decode
# ---------------------------------------------------------------------------

def encode_frame(kind: int,
                 meta: Optional[Mapping] = None,
                 arrays: Optional[Mapping[str, np.ndarray]] = None,
                 ) -> Tuple[list, int]:
    """Build a frame as a list of send buffers (header+meta, then one
    memoryview per array — no payload concatenation copy).

    Returns ``(buffers, total_bytes)``.
    """
    if kind not in KINDS:
        raise FrameError(f"unknown frame kind {kind!r}")
    md = dict(meta or {})
    if _ARRAYS_KEY in md:
        raise FrameError(f"meta key {_ARRAYS_KEY!r} is reserved")
    descs = []
    bufs = []
    payload = 0
    for name, arr in (arrays or {}).items():
        a = np.ascontiguousarray(arr)
        descs.append([str(name), a.dtype.str, list(a.shape)])
        if a.nbytes:
            bufs.append(memoryview(a).cast("B"))
        payload += a.nbytes
    md[_ARRAYS_KEY] = descs
    mb = json.dumps(md, separators=(",", ":")).encode("utf-8")
    if len(mb) > MAX_META_BYTES:
        raise FrameError(f"meta too large ({len(mb)} bytes)")
    if payload > MAX_FRAME_BYTES:
        raise FrameError(f"payload too large ({payload} bytes)")
    hdr = HEADER.pack(MAGIC, kind, 0, len(descs), len(mb), payload)
    total = HEADER.size + len(mb) + payload
    return [hdr + mb] + bufs, total


def _decode_body(kind: int, n_arrays: int, meta_len: int, payload_len: int,
                 body) -> Tuple[int, dict, Dict[str, np.ndarray]]:
    """Shared tail of frame decoding: ``body`` is meta+payload bytes."""
    if len(body) != meta_len + payload_len:
        raise FrameError("truncated frame body")
    try:
        meta = json.loads(bytes(body[:meta_len]).decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise FrameError(f"garbage meta JSON: {e}") from None
    if not isinstance(meta, dict):
        raise FrameError("meta is not a JSON object")
    descs = meta.pop(_ARRAYS_KEY, None)
    if not isinstance(descs, list) or len(descs) != n_arrays:
        raise FrameError("array descriptor count mismatch")
    arrays: Dict[str, np.ndarray] = {}
    off = meta_len
    for d in descs:
        try:
            name, dtype_str, shape = d
            dt = np.dtype(dtype_str)
            shape = tuple(int(s) for s in shape)
        except (TypeError, ValueError) as e:
            raise FrameError(f"garbage array descriptor {d!r}: {e}") from None
        count = int(np.prod(shape, dtype=np.int64)) if shape else 1
        nbytes = count * dt.itemsize
        if off + nbytes > meta_len + payload_len:
            raise FrameError("array descriptors overrun payload")
        arrays[name] = np.frombuffer(body, dtype=dt, count=count,
                                     offset=off).reshape(shape)
        off += nbytes
    if off != meta_len + payload_len:
        raise FrameError("payload bytes left over after array descriptors")
    return kind, meta, arrays


def decode_frame(buf) -> Tuple[int, dict, Dict[str, np.ndarray]]:
    """Decode one complete frame from a bytes-like buffer (strict: the
    buffer must hold exactly one frame)."""
    if len(buf) < HEADER.size:
        raise FrameError("truncated header")
    magic, kind, _flags, n_arrays, meta_len, payload_len = \
        HEADER.unpack_from(buf, 0)
    if magic != MAGIC:
        raise FrameError(f"bad magic {magic!r}")
    if kind not in KINDS:
        raise FrameError(f"unknown frame kind {kind}")
    if meta_len > MAX_META_BYTES or payload_len > MAX_FRAME_BYTES:
        raise FrameError("frame exceeds admission bounds")
    total = HEADER.size + meta_len + payload_len
    if len(buf) < total:
        raise FrameError("truncated frame")
    if len(buf) > total:
        raise FrameError("trailing bytes after frame")
    body = memoryview(buf)[HEADER.size:]
    return _decode_body(kind, n_arrays, meta_len, payload_len, body)


# ---------------------------------------------------------------------------
# socket IO
# ---------------------------------------------------------------------------

def send_frame(sock, kind: int, meta: Optional[Mapping] = None,
               arrays: Optional[Mapping[str, np.ndarray]] = None) -> int:
    """Write one frame; returns bytes sent.  Caller serializes writers."""
    bufs, total = encode_frame(kind, meta, arrays)
    for b in bufs:
        sock.sendall(b)
    return total


def _recv_exact(sock, n: int, *, at_boundary: bool) -> bytearray:
    out = bytearray(n)
    view = memoryview(out)
    got = 0
    while got < n:
        k = sock.recv_into(view[got:], n - got)
        if k == 0:
            if got == 0 and at_boundary:
                raise ChannelClosed("peer closed connection")
            raise FrameError("connection closed mid-frame")
        got += k
    return out


def recv_frame(sock) -> Tuple[int, dict, Dict[str, np.ndarray], int]:
    """Read one frame; returns ``(kind, meta, arrays, total_bytes)``.

    Raises :class:`ChannelClosed` on clean EOF between frames,
    :class:`FrameError` on anything malformed.
    """
    hdr = _recv_exact(sock, HEADER.size, at_boundary=True)
    magic, kind, _flags, n_arrays, meta_len, payload_len = HEADER.unpack(hdr)
    if magic != MAGIC:
        raise FrameError(f"bad magic {bytes(magic)!r}")
    if kind not in KINDS:
        raise FrameError(f"unknown frame kind {kind}")
    if meta_len > MAX_META_BYTES or payload_len > MAX_FRAME_BYTES:
        raise FrameError("frame exceeds admission bounds")
    body = _recv_exact(sock, meta_len + payload_len, at_boundary=False)
    k, meta, arrays = _decode_body(kind, n_arrays, meta_len, payload_len, body)
    return k, meta, arrays, HEADER.size + meta_len + payload_len


# ---------------------------------------------------------------------------
# routing-table transport
# ---------------------------------------------------------------------------

def pack_table(table) -> Tuple[dict, Dict[str, np.ndarray]]:
    """Serialize a ``RoutingTable`` (or None) into (meta, arrays)."""
    if table is None:
        return {"has_table": False}, {}
    meta = {"has_table": True, "n_shards": int(table.n_shards),
            "table_version": int(table.version)}
    return meta, {"shard_of_node": np.asarray(table.shard_of_node,
                                              dtype=np.int16)}


def unpack_table(meta: Mapping, arrays: Mapping[str, np.ndarray]):
    """Inverse of :func:`pack_table`; returns a RoutingTable or None."""
    if not meta.get("has_table"):
        return None
    from repro.featurestore.placement import RoutingTable
    return RoutingTable(
        shard_of_node=np.array(arrays["shard_of_node"], dtype=np.int16),
        n_shards=int(meta["n_shards"]),
        version=int(meta["table_version"]))

"""Attention variants: MHA/GQA/MQA (+bias, +sliding window), MLA, cross-attn.

Layout conventions:
  activations x: [B, S, d_model]
  q/k/v heads:   [B, H, S, Dh]
  KV cache:      {"k": [B, Hkv, S_max, Dh], "v": ..., } updated at a traced
                 position; MLA caches the *compressed* c_kv + shared k_rope
                 (the whole point of MLA: 576 B/token/layer at any head count)

Both a reference jnp path (dry-run / CPU) and the Pallas flash kernel are
supported via ``cfg.attn_impl``; the reference path lets XLA fuse/shard
freely under GSPMD, the kernel path is the TPU-native execution plan.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ref as kref
from repro.launch.sharding import (axis_size, constrain, constrain_hard,
                                   shard_map_compat)
from repro.models.common import apply_rope, dense_init, rms_norm


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_attn(key, cfg: ArchConfig, cross: bool = False) -> dict:
    if cfg.mla is not None and not cross:
        return _init_mla(key, cfg)
    d, h, hkv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    dh = cfg.head_dim_eff
    ks = jax.random.split(key, 4)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    p = {
        "wq": dense_init(ks[0], d, h * dh, dt),
        "wk": dense_init(ks[1], d, hkv * dh, dt),
        "wv": dense_init(ks[2], d, hkv * dh, dt),
        "wo": dense_init(ks[3], h * dh, d, dt),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((h * dh,), dt)
        p["bk"] = jnp.zeros((hkv * dh,), dt)
        p["bv"] = jnp.zeros((hkv * dh,), dt)
    return p


def _init_mla(key, cfg: ArchConfig) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    ks = jax.random.split(key, 7)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {
        "q_down": dense_init(ks[0], d, m.q_lora, dt),
        "q_norm": jnp.zeros((m.q_lora,), jnp.float32),
        "q_up": dense_init(ks[1], m.q_lora, h * (m.qk_nope + m.qk_rope), dt),
        "kv_down": dense_init(ks[2], d, m.kv_lora + m.qk_rope, dt),
        "kv_norm": jnp.zeros((m.kv_lora,), jnp.float32),
        "k_up": dense_init(ks[3], m.kv_lora, h * m.qk_nope, dt),
        "v_up": dense_init(ks[4], m.kv_lora, h * m.v_head, dt),
        "wo": dense_init(ks[5], h * m.v_head, d, dt),
    }


# ---------------------------------------------------------------------------
# forward — GQA family
# ---------------------------------------------------------------------------

def _split_heads(x, n_heads, dh):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, dh).transpose(0, 2, 1, 3)


def _attend(q, k, v, *, causal, window, impl, kv_len=None, q_pos=None,
            kv_pos=None):
    if impl == "pallas" and kv_len is None and q_pos is None:
        from repro.kernels.ops import flash_attention
        return flash_attention(q, k, v, causal=causal, window=window)
    return kref.mha_ref(q, k, v, causal=causal, window=window, kv_len=kv_len,
                        q_pos=q_pos, kv_pos=kv_pos)


def _batch_spec_axes(mesh) -> Optional[tuple]:
    from repro.launch.sharding import batch_axes
    axes = batch_axes(mesh)
    return axes if axes else None


def sharded_attention(q, k, v, *, causal, window, impl,
                      q_pos=None, kv_pos=None):
    """Multi-token attention with shard_map-pinned parallelism.

    GSPMD's free choice on the reference attention produced involuntary
    full-rematerialization copies of [B,H,S,S] scores (§Perf iteration 0-2).
    shard_map removes the choice: inside the mapped body everything is LOCAL.

      * heads mode (Hq and Hkv both divide 'model'): q/k/v head-sharded —
        attention contributes ZERO collectives fwd AND bwd;
      * seq mode (otherwise): q sharded over Sq on 'model', k/v replicated —
        forward local; backward psums only dk/dv ([B,Hkv,S,Dh], tiny next to
        the [B,H,S,S] tensors GSPMD all-reduced);
      * fallback to plain GSPMD when shapes don't divide (smoke tests).

    Masking is entirely positional: q_pos [Sq] / kv_pos [Sk] (defaults
    arange) drive causal + sliding-window + unwritten-slot masks inside the
    pure mha_ref oracle, so train, dense-cache prefill (kv_pos = -1 beyond
    kv_len) and SWA ring prefill all share this one wrapper.
    """
    from repro.launch.sharding import current_mesh

    b, hq, sq, dh = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    if q_pos is None:
        q_pos = jnp.arange(sq, dtype=jnp.int32)
    if kv_pos is None:
        kv_pos = jnp.arange(sk, dtype=jnp.int32)

    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names or mesh.shape["model"] == 1:
        return _attend(q, k, v, causal=causal, window=window, impl=impl,
                       q_pos=q_pos, kv_pos=kv_pos)
    tp = mesh.shape["model"]
    baxes = _batch_spec_axes(mesh)
    bsz = 1
    for a in (baxes or ()):
        bsz *= mesh.shape[a]
    if b % max(bsz, 1) != 0:
        baxes, bsz = None, 1
    bspec = (baxes if baxes and len(baxes) > 1 else
             (baxes[0] if baxes else None))

    from jax.sharding import PartitionSpec as P

    def body(qb, kb, vb, qp, kp):
        return kref.mha_ref(qb, kb, vb, causal=causal, window=window,
                            q_pos=qp, kv_pos=kp)

    if baxes and "model" in baxes:
        # pure-DP scope: the whole mesh is batch — attention fully local
        qspec = P(bspec, None, None, None)
        io = dict(in_specs=(qspec, qspec, qspec, P(None), P(None)),
                  out_specs=qspec)
    elif hq % tp == 0 and hkv % tp == 0:
        qspec = P(bspec, "model", None, None)
        io = dict(in_specs=(qspec, qspec, qspec, P(None), P(None)),
                  out_specs=qspec)
    elif sq % tp == 0:
        qspec = P(bspec, None, "model", None)
        kvspec = P(bspec, None, None, None)
        io = dict(in_specs=(qspec, kvspec, kvspec, P("model"), P(None)),
                  out_specs=qspec)
    else:
        return _attend(q, k, v, causal=causal, window=window, impl=impl,
                       q_pos=q_pos, kv_pos=kv_pos)

    fn = shard_map_compat(body, mesh=mesh, **io)
    return fn(q, k, v, q_pos, kv_pos)


def attn_forward(p: dict, cfg: ArchConfig, x: jnp.ndarray,
                 positions: jnp.ndarray, *, causal: bool = True,
                 kv_cache: Optional[dict] = None,
                 cache_pos: Optional[jnp.ndarray] = None,
                 cross_kv: Optional[tuple] = None):
    """Returns (out [B,S,d], new_kv_cache | None).

    Train/prefill: kv_cache None.  Decode: kv_cache holds [B,Hkv,S_max,Dh];
    the S new tokens are written at ``cache_pos`` and attention runs over the
    cache with dynamic kv_len = cache_pos + S.
    """
    if cfg.mla is not None and cross_kv is None:
        return mla_forward(p, cfg, x, positions, causal=causal,
                           kv_cache=kv_cache, cache_pos=cache_pos)
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_eff
    q = x @ p["wq"]
    if "bq" in p:
        q = q + p["bq"]
    q = _split_heads(q, h, dh)

    if cross_kv is not None:
        k, v = cross_kv                            # precomputed encoder K/V
        if x.shape[1] > 1:
            out = sharded_attention(q, k, v, causal=False, window=None,
                                    impl=cfg.attn_impl)
        else:
            out = _attend(q, k, v, causal=False, window=None,
                          impl=cfg.attn_impl)
        b, s = x.shape[:2]
        out = out.transpose(0, 2, 1, 3).reshape(b, s, h * dh)
        return out @ p["wo"], None

    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    k = _split_heads(k, hkv, dh)
    v = _split_heads(v, hkv, dh)
    q = apply_rope(q, positions[:, None, :], cfg.rope_theta)
    k = apply_rope(k, positions[:, None, :], cfg.rope_theta)

    new_cache = None
    kv_len = None
    q_pos = kv_pos = None
    if kv_cache is not None:
        s_new = x.shape[1]
        if "slot_pos" in kv_cache:
            # SWA ring buffer: window-sized cache, slots addressed mod window,
            # per-slot absolute positions drive the mask (order-free).
            # Attention runs over [old ring contents ++ new tokens] so that a
            # multi-token prefill sees its own in-window keys even when they
            # will be evicted from the ring right after (write happens below).
            max_len = kv_cache["k"].shape[2]
            abs_pos = cache_pos + jnp.arange(s_new, dtype=jnp.int32)
            q_pos = abs_pos
            kv_pos = jnp.concatenate([kv_cache["slot_pos"], abs_pos])
            k_att = jnp.concatenate(
                [kv_cache["k"].astype(k.dtype), k], axis=2)
            v_att = jnp.concatenate(
                [kv_cache["v"].astype(v.dtype), v], axis=2)
            # ring write: keep only the last `window` new tokens
            kk, vv, wpos = k, v, abs_pos
            if s_new >= max_len:
                kk, vv = kk[:, :, -max_len:], vv[:, :, -max_len:]
                wpos = wpos[-max_len:]
            slots = wpos % max_len
            ck = kv_cache["k"].at[:, :, slots].set(kk.astype(kv_cache["k"].dtype))
            cv = kv_cache["v"].at[:, :, slots].set(vv.astype(kv_cache["v"].dtype))
            spos = kv_cache["slot_pos"].at[slots].set(wpos)
            new_cache = {"k": ck, "v": cv, "slot_pos": spos}
            k, v = k_att, v_att
        else:
            ck = jax.lax.dynamic_update_slice(
                kv_cache["k"], k.astype(kv_cache["k"].dtype), (0, 0, cache_pos, 0))
            cv = jax.lax.dynamic_update_slice(
                kv_cache["v"], v.astype(kv_cache["v"].dtype), (0, 0, cache_pos, 0))
            new_cache = {"k": ck, "v": cv}
            k, v = ck, cv
            kv_len = cache_pos + s_new
            if s_new > 1:            # cache prefill: positional mask form
                q_pos = cache_pos + jnp.arange(s_new, dtype=jnp.int32)
                sk = k.shape[2]
                idx = jnp.arange(sk, dtype=jnp.int32)
                kv_pos = jnp.where(idx < kv_len, idx, -1)
                kv_len = None

    if kv_cache is None or x.shape[1] > 1:
        # train / prefill (multi-token): shard_map-pinned parallel attention
        # (heads or seq mode — see sharded_attention; §Perf iterations 0-3)
        out = sharded_attention(q, k, v, causal=causal,
                                window=cfg.sliding_window,
                                impl=cfg.attn_impl, q_pos=q_pos, kv_pos=kv_pos)
    else:
        # single-token decode: batch/head sharding under GSPMD
        q = constrain(q, "batch", "model", None, None)
        k = constrain(k, "batch", "model", "seq", None)
        out = _attend(q, k, v, causal=causal, window=cfg.sliding_window,
                      impl=cfg.attn_impl, kv_len=kv_len, q_pos=q_pos,
                      kv_pos=kv_pos)
    b, s = x.shape[:2]
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * dh)
    return out @ p["wo"], new_cache


def make_cross_kv(p: dict, cfg: ArchConfig, enc_out: jnp.ndarray):
    """Precompute encoder K/V for the decoder's cross-attention."""
    hkv, dh = cfg.num_kv_heads, cfg.head_dim_eff
    k = _split_heads(enc_out @ p["wk"], hkv, dh)
    v = _split_heads(enc_out @ p["wv"], hkv, dh)
    return k, v


def cache_dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None,
                  ring: Optional[bool] = None):
    dtype = dtype or cache_dtype(cfg)
    ring = (cfg.sliding_window is not None) if ring is None else ring
    hkv, dh = cfg.num_kv_heads, cfg.head_dim_eff
    cache = {"k": jnp.zeros((batch, hkv, max_len, dh), dtype),
             "v": jnp.zeros((batch, hkv, max_len, dh), dtype)}
    if ring:
        cache["slot_pos"] = jnp.full((max_len,), -1, jnp.int32)
    return cache


# ---------------------------------------------------------------------------
# MLA (deepseek-v2)
# ---------------------------------------------------------------------------

def mla_forward(p: dict, cfg: ArchConfig, x: jnp.ndarray,
                positions: jnp.ndarray, *, causal: bool = True,
                kv_cache: Optional[dict] = None,
                cache_pos: Optional[jnp.ndarray] = None):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads

    ql = rms_norm(x @ p["q_down"], p["q_norm"])
    q = (ql @ p["q_up"]).reshape(b, s, h, m.qk_nope + m.qk_rope).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :m.qk_nope], q[..., m.qk_nope:]
    q_rope = apply_rope(q_rope, positions[:, None, :], cfg.rope_theta)

    kvd = x @ p["kv_down"]
    c_kv = rms_norm(kvd[..., :m.kv_lora], p["kv_norm"])       # [B,S,kv_lora]
    k_rope = apply_rope(kvd[..., None, m.kv_lora:].transpose(0, 2, 1, 3),
                        positions[:, None, :], cfg.rope_theta)  # [B,1,S,rope]

    new_cache = None
    if kv_cache is not None:
        c_all = jax.lax.dynamic_update_slice(
            kv_cache["c_kv"], c_kv.astype(kv_cache["c_kv"].dtype), (0, cache_pos, 0))
        r_all = jax.lax.dynamic_update_slice(
            kv_cache["k_rope"], k_rope[:, 0].astype(kv_cache["k_rope"].dtype),
            (0, cache_pos, 0))
        new_cache = {"c_kv": c_all, "k_rope": r_all}
        kv_len = cache_pos + s
        if s == 1:
            # single-token decode: absorbed projections, attention in the
            # compressed c_kv space (the MLA cache-size win)
            return _mla_absorbed_attend(p, cfg, q_nope, q_rope, c_all, r_all,
                                        kv_len, b, s), new_cache
        # multi-token PREFILL: expand-form over the written cache (absorbed
        # form would build [B,H,S,S] f32 logits without flash blocking).
        q_pos = cache_pos + jnp.arange(s, dtype=jnp.int32)
        sk = c_all.shape[1]
        idx = jnp.arange(sk, dtype=jnp.int32)
        kv_pos = jnp.where(idx < kv_len, idx, -1)
        c_src, r_src, s_kv = c_all, r_all[:, None], sk
    else:
        q_pos = kv_pos = None
        c_src, r_src, s_kv = c_kv, k_rope, s

    # train/prefill: expand keys/values per head (standard formulation)
    k_nope = (c_src @ p["k_up"]).reshape(b, s_kv, h, m.qk_nope).transpose(0, 2, 1, 3)
    v = (c_src @ p["v_up"]).reshape(b, s_kv, h, m.v_head).transpose(0, 2, 1, 3)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(r_src.astype(k_nope.dtype),
                                                  (b, h, s_kv, m.qk_rope))],
                        axis=-1)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    scale = (m.qk_nope + m.qk_rope) ** -0.5
    out = _mla_attend(qf, k, v, scale, causal, q_pos, kv_pos)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * m.v_head)
    return out @ p["wo"], new_cache


def _mla_attend(qf, k, v, scale, causal, q_pos, kv_pos):
    """MLA expand-form attention: scale folded into q, then the shared
    sharded_attention wrapper (128 heads divide the model axis -> heads
    mode, zero attention collectives)."""
    dh = qf.shape[-1]
    qs = qf * (scale * dh ** 0.5)        # mha_ref rescales by dh^-0.5
    return sharded_attention(qs, k, v, causal=causal, window=None,
                             impl="reference", q_pos=q_pos, kv_pos=kv_pos)


def _mla_absorbed_attend(p, cfg, q_nope, q_rope, c_all, r_all, kv_len, b, s):
    """Decode path with absorbed projections (attention in c_kv space).

    k_up absorbed into q:  q_c = q_nope · W_kup  -> [B,H,S,kv_lora]
    v_up absorbed out:     ctx · W_vup per head.
    KV cache bytes/token = kv_lora + rope = 576 (bf16: 1152B) regardless of
    the 128 heads — this is what makes deepseek-v2 long_500k feasible.
    """
    m = cfg.mla
    h = cfg.num_heads
    w_kup = p["k_up"].reshape(m.kv_lora, h, m.qk_nope)
    q_c = jnp.einsum("bhsn,lhn->bhsl", q_nope.astype(jnp.float32),
                     w_kup.astype(jnp.float32))               # [B,H,S,kv_lora]
    s_kv = c_all.shape[1]
    logits = jnp.einsum("bhsl,btl->bhst", q_c, c_all.astype(jnp.float32))
    logits += jnp.einsum("bhsr,btr->bhst", q_rope.astype(jnp.float32),
                         r_all.astype(jnp.float32))
    logits *= (m.qk_nope + m.qk_rope) ** -0.5
    t_idx = jnp.arange(s_kv)[None, None, None, :]
    q_idx = (kv_len - s) + jnp.arange(s)[None, None, :, None]
    mask = t_idx <= q_idx
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bhst,btl->bhsl", probs, c_all.astype(jnp.float32))
    w_vup = p["v_up"].reshape(m.kv_lora, h, m.v_head)
    out = jnp.einsum("bhsl,lhv->bhsv", ctx, w_vup.astype(jnp.float32))
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * m.v_head).astype(q_nope.dtype)
    return out @ p["wo"]


def init_mla_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or cache_dtype(cfg)
    m = cfg.mla
    return {"c_kv": jnp.zeros((batch, max_len, m.kv_lora), dtype),
            "k_rope": jnp.zeros((batch, max_len, m.qk_rope), dtype)}

"""Decoder-only LM assembly: dense / MoE / MLA / VLM backbones.

Layers are *stacked* (params carry a leading L dim) and applied with
``jax.lax.scan`` so XLA compiles one block body regardless of depth — the
difference between minutes and hours when dry-running 60-layer deepseek on a
512-device mesh.  Heterogeneous stacks (deepseek's leading dense layers) are
expressed as consecutive scan groups.

``remat=True`` wraps the block in jax.checkpoint (policy: save nothing,
recompute in backward) — with microbatch accumulation in launch/train.py this
is what bounds activation memory for train_4k on the big archs.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.launch.sharding import constrain
from repro.models import attention as attn
from repro.models import scan_util
from repro.models import ffn as ffn_mod
from repro.models import moe as moe_mod
from repro.models.common import (cross_entropy, embed_init, grad_cast,
                                 rms_norm, stack_init)


# ---------------------------------------------------------------------------
# one transformer block
# ---------------------------------------------------------------------------

def init_block(key, cfg: ArchConfig, kind: str) -> dict:
    ks = jax.random.split(key, 2)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    p = {
        "norm1": jnp.zeros((cfg.d_model,), jnp.float32),
        "norm2": jnp.zeros((cfg.d_model,), jnp.float32),
        "attn": attn.init_attn(ks[0], cfg),
    }
    if kind == "moe":
        p["moe"] = moe_mod.init_moe(ks[1], cfg)
    else:
        p["ffn"] = ffn_mod.init_ffn(ks[1], cfg.d_model, cfg.d_ff,
                                    cfg.gated_ffn, dt)
    return p


def block_forward(bp: dict, cfg: ArchConfig, h: jnp.ndarray,
                  positions: jnp.ndarray, kind: str,
                  cache: Optional[dict] = None,
                  cache_pos=None):
    h = constrain(h, "batch", None, None)
    a, new_cache = attn.attn_forward(bp["attn"], cfg, rms_norm(h, bp["norm1"]),
                                     positions, kv_cache=cache,
                                     cache_pos=cache_pos)
    h = h + a
    x2 = rms_norm(h, bp["norm2"])
    if kind == "moe":
        h = h + moe_mod.moe_forward(bp["moe"], cfg, x2)
    else:
        h = h + ffn_mod.ffn_forward(bp["ffn"], cfg.ffn_act, x2, cfg.gated_ffn)
    if cfg.bf16_grad_stream:
        h = grad_cast(h)          # backward cotangent pinned to h.dtype
    return h, new_cache


# ---------------------------------------------------------------------------
# layer groups
# ---------------------------------------------------------------------------

def layer_groups(cfg: ArchConfig) -> list[tuple[str, int, str]]:
    """[(group_name, num_layers, block_kind)] — scan groups in order."""
    if cfg.moe is not None:
        nd = cfg.moe.first_dense_layers
        groups = []
        if nd:
            groups.append(("layers_dense", nd, "dense"))
        groups.append(("layers_moe", cfg.num_layers - nd, "moe"))
        return groups
    return [("layers", cfg.num_layers, "dense")]


def init_lm(key, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 3 + len(layer_groups(cfg)))
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    # untied input tables are named embed_in and shard on d_model (local
    # row gather + sharded grads); tied tables shard on vocab so the UNEMBED
    # side stays local — launch/sharding.py rule table, EXPERIMENTS.md §Perf.
    in_key = "embed" if cfg.tie_embeddings else "embed_in"
    params = {
        in_key: embed_init(ks[0], cfg.vocab_size, cfg.d_model, dt),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = embed_init(ks[1], cfg.d_model, cfg.vocab_size, dt)
    for i, (name, n, kind) in enumerate(layer_groups(cfg)):
        params[name] = stack_init(ks[3 + i], n,
                                  lambda k, kind=kind: init_block(k, cfg, kind))
    return params


def _scan_group(params_g, cfg: ArchConfig, h, positions, kind: str,
                caches=None, cache_pos=None):
    body = functools.partial(block_forward, cfg=cfg, positions=positions,
                             kind=kind, cache_pos=cache_pos)

    def scan_fn(carry, xs):
        if caches is None:
            bp = xs
            out, _ = body(bp, h=carry)
            return out, None
        bp, cache = xs
        out, new_cache = body(bp, h=carry, cache=cache)
        return out, new_cache

    fn = jax.checkpoint(scan_fn) if (cfg.remat and caches is None) else scan_fn
    xs = params_g if caches is None else (params_g, caches)
    h, new_caches = scan_util.scan(fn, h, xs)
    return h, new_caches


# ---------------------------------------------------------------------------
# train / prefill forward
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg: ArchConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    table = params["embed_in"] if "embed_in" in params else params["embed"]
    h = jnp.take(table, tokens, axis=0)
    if cfg.scale_embed:
        h = h * (cfg.d_model ** 0.5)
    return h


def unembed(params, cfg: ArchConfig, h: jnp.ndarray) -> jnp.ndarray:
    h = rms_norm(h, params["final_norm"])
    if cfg.tie_embeddings:
        logits = h @ params["embed"].T
    else:
        logits = h @ params["unembed"]
    return constrain(logits, "batch", None, "model")


def lm_forward(params: dict, cfg: ArchConfig, tokens: jnp.ndarray,
               prefix_embeds: Optional[jnp.ndarray] = None,
               return_hidden: bool = False) -> jnp.ndarray:
    """tokens [B, S_text]; prefix_embeds [B, P, d] (VLM stub frontend)."""
    h = embed_tokens(params, cfg, tokens)
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    h = constrain(h, "batch", None, None)
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    for name, n, kind in layer_groups(cfg):
        h, _ = _scan_group(params[name], cfg, h, positions, kind)
    if return_hidden:
        return h
    return unembed(params, cfg, h)


def unembed_weight(params, cfg: ArchConfig) -> jnp.ndarray:
    return params["embed"].T if cfg.tie_embeddings else params["unembed"]


def lm_loss(params: dict, cfg: ArchConfig, batch: dict) -> jnp.ndarray:
    """Next-token CE.  batch: tokens [B,S] (+ patch_embeds for vlm)."""
    tokens = batch["tokens"]
    prefix = batch.get("patch_embeds")
    if cfg.chunked_ce:
        from repro.models.common import chunked_unembed_ce
        h = lm_forward(params, cfg, tokens, prefix_embeds=prefix,
                       return_hidden=True)
        if prefix is not None:
            h = h[:, prefix.shape[1]:]
        h = rms_norm(h, params["final_norm"])
        b, s = tokens.shape
        labels = jnp.concatenate(
            [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)
        mask = jnp.concatenate(
            [jnp.ones((b, s - 1), jnp.float32), jnp.zeros((b, 1), jnp.float32)],
            axis=1)
        return chunked_unembed_ce(h, unembed_weight(params, cfg), labels,
                                  mask, cfg.chunked_ce)
    logits = lm_forward(params, cfg, tokens, prefix_embeds=prefix)
    if prefix is not None:
        logits = logits[:, prefix.shape[1]:]          # text positions only
    return cross_entropy(logits[:, :-1], tokens[:, 1:])


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ArchConfig, batch: int, cache_len: int) -> dict:
    """Stacked per-layer KV caches (+ scalar position).

    SWA archs allocate a ring buffer of window size — the memory feature that
    qualifies them for long_500k (DESIGN.md §5).
    """
    eff_len = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
    groups = {}
    for name, n, _ in layer_groups(cfg):
        if cfg.mla is not None:
            one = attn.init_mla_cache(cfg, batch, eff_len)
        else:
            one = attn.init_kv_cache(cfg, batch, eff_len)
        groups[name] = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (n, *x.shape)), one)
    return {"caches": groups, "pos": jnp.zeros((), jnp.int32)}


def lm_decode_step(params: dict, cfg: ArchConfig, tokens: jnp.ndarray,
                   state: dict) -> tuple[jnp.ndarray, dict]:
    """tokens [B, S_new] (S_new=1 for autoregressive decode)."""
    h = embed_tokens(params, cfg, tokens)
    b, s, _ = h.shape
    pos = state["pos"]
    positions = pos + jnp.arange(s, dtype=jnp.int32)[None]
    positions = jnp.broadcast_to(positions, (b, s))
    cache_pos = pos          # absolute; SWA ring wrap handled in attn_forward
    new_caches = {}
    for name, n, kind in layer_groups(cfg):
        h, nc = _scan_group(params[name], cfg, h, positions, kind,
                            caches=state["caches"][name], cache_pos=cache_pos)
        new_caches[name] = nc
    logits = unembed(params, cfg, h)
    return logits[:, -1], {"caches": new_caches, "pos": pos + s}

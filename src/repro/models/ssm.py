"""Mamba2 (SSD) block — chunked, matmul-dominant (TPU-native form).

The zamba2 backbone.  The State-Space Dual form computes, per head h with
scalar decay a_t = exp(dt_t · A_h):

    y_t = C_t · h_t,   h_t = a_t · h_{t-1} + dt_t · B_t ⊗ x_t

Chunked algorithm (Mamba2 paper §6): split S into chunks of Q; the
intra-chunk part is a (Q×Q) masked-decay attention-like matmul, the
inter-chunk part is a scan over per-chunk states [H, P, N].  Everything is
einsum — MXU-friendly, unlike the sequential scan a CUDA kernel would use
(hardware adaptation noted in DESIGN.md).

Decode is the O(1) recurrent update on [B, H, P, N] state + conv ring buffer.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.launch.sharding import constrain
from repro.models.common import dense_init, rms_norm


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads


def init_ssm(key, cfg: ArchConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_inner, n_heads = _dims(cfg)
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    ks = jax.random.split(key, 5)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {
        # in_proj -> [z (gate), x, B, C, dt]
        "in_proj": dense_init(ks[0], d, 2 * d_inner + 2 * s.n_groups * s.d_state + n_heads, dt),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, conv_dim), jnp.float32)
                   * (s.d_conv ** -0.5)).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        "ssm_d": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((n_heads,), 1e-2))).astype(jnp.float32),
        "norm_scale": jnp.zeros((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[2], d_inner, d, dt),
    }


def _split_proj(cfg: ArchConfig, proj: jnp.ndarray):
    s = cfg.ssm
    d_inner, n_heads = _dims(cfg)
    gn = s.n_groups * s.d_state
    z, x, bb, cc, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + gn, 2 * d_inner + 2 * gn],
        axis=-1)
    return z, x, bb, cc, dt


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv1d.  x: [B,S,C]; w: [K,C].  Returns (y, new_state).

    ``state`` is the last K-1 inputs from the previous call (decode ring
    buffer); new_state is the updated buffer.
    """
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)          # [B, S+K-1, C]
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k)) + b
    new_state = xp[:, -(k - 1):, :] if k > 1 else pad[:, :0]
    return jax.nn.silu(y), new_state


def ssm_forward(p: dict, cfg: ArchConfig, x_in: jnp.ndarray,
                state: Optional[dict] = None):
    """x_in: [B, S, d].  Returns (y, new_state | None).

    Train/prefill: state None (chunked SSD).  Decode: state holds
    {"conv": [B,K-1,convdim], "ssd": [B,H,P,N]} and S is typically 1.
    """
    s_cfg = cfg.ssm
    d_inner, n_heads = _dims(cfg)
    b, seq, _ = x_in.shape
    hd, n = s_cfg.head_dim, s_cfg.d_state

    proj = x_in @ p["in_proj"]
    z, x, bb, cc, dt_raw = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([x, bb, cc], axis=-1)
    conv_state = state["conv"] if state is not None else None
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], p["conv_b"], conv_state)
    x, bb, cc = jnp.split(conv_out, [d_inner, d_inner + s_cfg.n_groups * n], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])   # [B,S,H]
    a = -jnp.exp(p["a_log"])                                          # [H]
    decay = jnp.exp(dt * a)                                           # [B,S,H] in (0,1)

    xh = x.reshape(b, seq, n_heads, hd).astype(jnp.float32)
    # group->head broadcast (n_groups=1 for zamba2)
    bbh = jnp.repeat(bb.reshape(b, seq, s_cfg.n_groups, n),
                     n_heads // s_cfg.n_groups, axis=2).astype(jnp.float32)
    cch = jnp.repeat(cc.reshape(b, seq, s_cfg.n_groups, n),
                     n_heads // s_cfg.n_groups, axis=2).astype(jnp.float32)
    dx = xh * dt[..., None]                                           # dt·x

    if state is not None:
        # recurrent decode: h' = a h + B ⊗ dx ; y = C·h' + D x
        h0 = state["ssd"].astype(jnp.float32)                         # [B,H,P,N]

        def step(h, inp):
            a_t, b_t, c_t, dx_t = inp                                  # [B,H],[B,H,N],...
            h = h * a_t[..., None, None] + jnp.einsum("bhp,bhn->bhpn", dx_t, b_t)
            y = jnp.einsum("bhpn,bhn->bhp", h, c_t)
            return h, y

        seq_first = lambda t: jnp.moveaxis(t, 1, 0)
        hT, ys = jax.lax.scan(step, h0, (seq_first(decay), seq_first(bbh),
                                         seq_first(cch), seq_first(dx)))
        y = jnp.moveaxis(ys, 0, 1)                                    # [B,S,H,P]
        y = y + xh * p["ssm_d"][None, None, :, None]
        new_state = {"conv": new_conv, "ssd": hT.astype(state["ssd"].dtype)}
    else:
        y = _ssd_chunked(decay, bbh, cch, dx, s_cfg.chunk)
        y = y + xh * p["ssm_d"][None, None, :, None]
        new_state = None

    y = y.reshape(b, seq, d_inner).astype(x_in.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["norm_scale"])
    out = y @ p["out_proj"]
    return constrain(out, "batch", None, None), new_state


def _ssd_chunked(decay, bbh, cch, dx, chunk: int):
    """Chunked SSD.  decay [B,S,H]; bbh/cch [B,S,H,N]; dx [B,S,H,P] -> [B,S,H,P]."""
    b, s, h = decay.shape
    n = bbh.shape[-1]
    p = dx.shape[-1]
    q = min(chunk, s)
    while s % q:
        q -= 1
    nc = s // q
    rs = lambda t: t.reshape(b, nc, q, *t.shape[2:])
    decay_c, b_c, c_c, dx_c = rs(decay), rs(bbh), rs(cch), rs(dx)

    logd = jnp.log(jnp.maximum(decay_c, 1e-20))                  # [B,NC,Q,H]
    cum = jnp.cumsum(logd, axis=2)                               # Σ_{r<=t} log a_r
    total = cum[:, :, -1]                                        # [B,NC,H]

    # intra-chunk: L[t,s] = exp(cum[t]-cum[s]) for s<=t (decay between s and t)
    lt = cum[:, :, :, None, :] - cum[:, :, None, :, :]           # [B,NC,Q,Q,H]
    mask = (jnp.arange(q)[:, None] >= jnp.arange(q)[None, :])[None, None, ..., None]
    lmat = jnp.where(mask, jnp.exp(lt), 0.0)                     # [B,NC,Q,Q,H]
    scores = jnp.einsum("bcthn,bcshn->bctsh", c_c, b_c) * lmat
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", scores, dx_c)

    # chunk-final states: S_c = Σ_s (a_{s+1..Q}) B_s ⊗ dx_s
    decay_after = jnp.exp(total[:, :, None, :] - cum)            # [B,NC,Q,H]
    chunk_state = jnp.einsum("bcsh,bcshn,bcshp->bchnp",
                             decay_after, b_c, dx_c)             # [B,NC,H,N,P]

    # inter-chunk scan over chunk states
    def scan_fn(carry, inp):
        tot, st = inp                                            # [B,H], [B,H,N,P]
        new = carry * jnp.exp(tot)[..., None, None] + st
        return new, carry                                        # emit PREVIOUS state

    init = jnp.zeros((b, h, n, p), jnp.float32)
    _, prev_states = jax.lax.scan(
        scan_fn, init, (jnp.moveaxis(total, 1, 0), jnp.moveaxis(chunk_state, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)                # [B,NC,H,N,P]

    # inter-chunk contribution: y_t += (a_{1..t}) C_t · h_prev
    decay_into = jnp.exp(cum)                                    # [B,NC,Q,H]
    y_inter = jnp.einsum("bcthn,bchnp->bcthp", c_c, prev_states) * decay_into[..., None]
    return (y_intra + y_inter).reshape(b, s, h, p)


def init_ssm_state(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> dict:
    s = cfg.ssm
    d_inner, n_heads = _dims(cfg)
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
        "ssd": jnp.zeros((batch, n_heads, s.head_dim, s.d_state), dtype),
    }

"""Model zoo: the paper's GNN (GraphSAGE/GCN on padded blocks) + the assigned
LM-family architectures (see repro/configs)."""

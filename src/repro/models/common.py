"""Shared model building blocks: norms, RoPE, init helpers.

All models are functional: params are plain nested dicts of jnp arrays
(sharding is inferred from leaf names — launch/sharding.py rule table), and
every forward is a pure function usable under jit / scan / shard_map.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.float32,
               scale: Optional[float] = None) -> jnp.ndarray:
    scale = scale if scale is not None else (in_dim ** -0.5)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps) * scale + bias
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 1e4) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 1e4) -> jnp.ndarray:
    """x: [..., S, Dh]; positions: broadcastable to [..., S]."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                        # [Dh/2]
    ang = positions[..., None].astype(jnp.float32) * freqs   # [..., S, Dh/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., 0::2], x32[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def activation(name: str):
    return {
        "gelu": jax.nn.gelu,
        "relu": jax.nn.relu,
        "silu": jax.nn.silu,
        "swish": jax.nn.silu,
        "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
    }[name]


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean token NLL in f32.  logits [..., V], labels [...] int."""
    logits32 = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits32, axis=-1)
    gold = jnp.take_along_axis(logits32, labels[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


@jax.custom_vjp
def grad_cast(x: jnp.ndarray) -> jnp.ndarray:
    """Identity whose BACKWARD casts the cotangent to x's dtype.

    §Perf (deepseek iteration 2): f32 cotangents created inside a block
    (f32 router/gating math, f32 attention internals) can survive the
    block's transpose and cross TP boundaries at double width even though
    the primal stream is bf16.  Placing grad_cast on the residual stream at
    block boundaries pins the backward to the forward's dtype.
    """
    return x


def _grad_cast_fwd(x):
    return x, jnp.zeros((0,), x.dtype)      # dtype token (dtypes aren't JAX types)


def _grad_cast_bwd(token, ct):
    return (ct.astype(token.dtype),)


grad_cast.defvjp(_grad_cast_fwd, _grad_cast_bwd)


def chunked_unembed_ce(h: jnp.ndarray, w: jnp.ndarray, labels: jnp.ndarray,
                       mask: jnp.ndarray, chunk: int) -> jnp.ndarray:
    """Fused block-wise unembed + cross-entropy (§Perf — beyond-paper).

    h [B,S,d] post-final-norm hiddens; w [d,V] unembedding; labels/mask
    [B,S].  Scans over S-blocks so the [B,S,V] logits tensor (f32: tens of
    GB at 4k x 150k-vocab) never materializes — each block's logits live
    only inside one remat'd scan body (recomputed in backward).
    """
    from repro.models import scan_util

    b, s, d = h.shape
    assert s % chunk == 0, (s, chunk)
    nb = s // chunk
    blk = lambda t: jnp.moveaxis(
        t.reshape(b, nb, chunk, *t.shape[2:]), 1, 0)      # [NB,B,C,...]

    def body(carry, xs):
        h_b, l_b, m_b = xs
        logits = (h_b @ w).astype(jnp.float32)            # [B,C,V] one block
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l_b[..., None].astype(jnp.int32),
                                   axis=-1)[..., 0]
        nll = (logz - gold) * m_b
        return (carry[0] + nll.sum(), carry[1] + m_b.sum()), None

    body = jax.checkpoint(body)
    (tot, cnt), _ = scan_util.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (blk(h), blk(labels), blk(mask.astype(jnp.float32))))
    return tot / jnp.maximum(cnt, 1.0)


def stack_init(key, n: int, init_fn):
    """Initialize n copies of a param tree and stack leaves on axis 0."""
    keys = jax.random.split(key, n)
    trees = [init_fn(k) for k in keys]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, axis=0), *trees)

"""xLSTM blocks: mLSTM (matrix memory, parallelizable) + sLSTM (scalar, scan).

xlstm-125m config: 12 blocks, mostly mLSTM with sLSTM at configured indices
(the paper's xLSTM[7:1] ratio).  Both carry O(1) decode state, which is what
qualifies the arch for the 500k-token decode shape.

mLSTM parallel (train) form — stabilized exponential gating (xLSTM paper,
eq. 19-27): with log-forget cumsums F_t and input gates ĩ_s,

    D[t,s] = F_t - F_s + ĩ_s   (s <= t)
    m_t    = max_s D[t,s]
    W[t,s] = exp(D[t,s] - m_t)
    h_t    = Σ_s W[t,s] (q_t·k_s) v_s / max(|Σ_s W (q·k)|, exp(-m_t))

Decode form: matrix memory C [B,H,Dqk,Dv], normalizer n [B,H,Dqk], running
max m [B,H].

sLSTM: per-head recurrent with exponential gates + stabilizer; sequential by
construction -> lax.scan over time (the paper's CUDA kernel has no TPU
analogue; the scan is the idiomatic mapping, noted in DESIGN.md).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import scan_util
from repro.launch.sharding import constrain
from repro.models.common import dense_init, rms_norm


def _dims(cfg: ArchConfig):
    x = cfg.xlstm
    d_inner = int(x.proj_factor * cfg.d_model)
    d_qk = int(x.qk_factor * d_inner)
    return d_inner, d_qk, x.num_heads


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    d_inner, d_qk, nh = _dims(cfg)
    ks = jax.random.split(key, 8)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {
        "w_up": dense_init(ks[0], d, 2 * d_inner, dt),      # x -> (inner, gate)
        "wq": dense_init(ks[1], d_inner, d_qk, dt),
        "wk": dense_init(ks[2], d_inner, d_qk, dt),
        "wv": dense_init(ks[3], d_inner, d_inner, dt),
        "w_if": dense_init(ks[4], d_inner, 2 * nh, dt),     # input/forget gates
        "w_o": dense_init(ks[5], d_inner, d_inner, dt),     # output gate
        "norm_scale": jnp.zeros((d_inner,), jnp.float32),
        "w_down": dense_init(ks[6], d_inner, d, dt),
    }


def mlstm_forward(p: dict, cfg: ArchConfig, x: jnp.ndarray,
                  state: Optional[dict] = None):
    d_inner, d_qk, nh = _dims(cfg)
    b, s, _ = x.shape
    hq, hv = d_qk // nh, d_inner // nh

    up = x @ p["w_up"]
    inner, gate = jnp.split(up, 2, axis=-1)
    q = (inner @ p["wq"]).reshape(b, s, nh, hq).transpose(0, 2, 1, 3)
    k = (inner @ p["wk"]).reshape(b, s, nh, hq).transpose(0, 2, 1, 3)
    v = (inner @ p["wv"]).reshape(b, s, nh, hv).transpose(0, 2, 1, 3)
    q = constrain(q, "batch", "model", None, None)
    gates = (inner @ p["w_if"]).astype(jnp.float32).reshape(b, s, nh, 2)
    i_raw = gates[..., 0].transpose(0, 2, 1)                   # [B,H,S]
    f_raw = gates[..., 1].transpose(0, 2, 1)
    logf = jax.nn.log_sigmoid(f_raw)
    scale = hq ** -0.5

    if state is None:
        if cfg.xlstm.chunk and s > cfg.xlstm.chunk and s % cfg.xlstm.chunk == 0:
            h = _mlstm_chunked(q.astype(jnp.float32) * scale,
                               k.astype(jnp.float32), v.astype(jnp.float32),
                               i_raw, logf, cfg.xlstm.chunk)
        else:
            fcum = jnp.cumsum(logf, axis=-1)                   # F_t
            dmat = fcum[..., :, None] - fcum[..., None, :] + i_raw[..., None, :]
            mask = jnp.arange(s)[:, None] >= jnp.arange(s)[None, :]
            dmat = jnp.where(mask[None, None], dmat, -jnp.inf)
            m = dmat.max(axis=-1)                              # [B,H,S]
            w = jnp.exp(dmat - m[..., None])
            scores = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32),
                                k.astype(jnp.float32)) * scale
            cw = scores * w
            denom = jnp.maximum(jnp.abs(cw.sum(-1)), jnp.exp(-m))  # [B,H,S]
            h = jnp.einsum("bhts,bhsv->bhtv", cw, v.astype(jnp.float32))
            h = h / denom[..., None]
        new_state = None
    else:
        # recurrent decode over s steps
        def step(carry, inp):
            c_mat, n_vec, m_run = carry
            q_t, k_t, v_t, i_t, lf_t = inp                     # [B,H,hq],... [B,H]
            m_new = jnp.maximum(lf_t + m_run, i_t)
            fg = jnp.exp(lf_t + m_run - m_new)
            ig = jnp.exp(i_t - m_new)
            c_mat = fg[..., None, None] * c_mat + ig[..., None, None] * \
                jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
            n_vec = fg[..., None] * n_vec + ig[..., None] * k_t
            num = jnp.einsum("bhk,bhkv->bhv", q_t * scale, c_mat)
            den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q_t * scale, n_vec)),
                              jnp.exp(-m_new))
            return (c_mat, n_vec, m_new), num / den[..., None]

        sf = lambda t: jnp.moveaxis(t, 2, 0)
        carry0 = (state["c"].astype(jnp.float32), state["n"].astype(jnp.float32),
                  state["m"].astype(jnp.float32))
        carryT, hs = jax.lax.scan(step, carry0,
                                  (sf(q.astype(jnp.float32)),
                                   sf(k.astype(jnp.float32)),
                                   sf(v.astype(jnp.float32)),
                                   sf(i_raw), sf(logf)))
        h = jnp.moveaxis(hs, 0, 2)                             # [B,H,S,hv]
        new_state = {"c": carryT[0], "n": carryT[1], "m": carryT[2]}

    h = h.transpose(0, 2, 1, 3).reshape(b, s, d_inner).astype(x.dtype)
    o = jax.nn.sigmoid((inner @ p["w_o"]).astype(jnp.float32)).astype(x.dtype)
    h = rms_norm(h, p["norm_scale"]) * o * jax.nn.silu(gate)
    return h @ p["w_down"], new_state


def _mlstm_chunked(q, k, v, i_raw, logf, chunk: int):
    """Chunkwise-parallel mLSTM (TFLA-style; §Perf iteration — beyond-paper).

    q [B,H,S,dq] (pre-scaled), k/v f32, gates i_raw/logf [B,H,S].  Splits S
    into Q-chunks: intra-chunk uses the stabilized parallel form on [Q,Q]
    tiles; inter-chunk carries the matrix memory (C, n, m) recurrently —
    exactly the decode recurrence, batched per chunk.  Unrolled algebra of
    the per-step recurrence (stabilizer maxes combine associatively):

      m_t = max(F_t + m0, max_{s<=t} (F_t - F_s + i_s))
      C_t = e^{F_t+m0-m_t} C0 + sum_s e^{F_t-F_s+i_s-m_t} k_s v_s^T
      h_t = [e^{F_t+m0-m_t} (q_t C0) + sum_s W[t,s](q_t k_s) v_s] / denom
      denom = max(|same with n|, e^{-m_t})

    Memory: O(S*Q) instead of O(S^2) — the quadratic [S,S] decay matrices
    that dominate the xlstm train_4k/prefill_32k memory term vanish.
    """
    b, h, s, dq = q.shape
    dv = v.shape[-1]
    nc = s // chunk
    rs = lambda t: t.reshape(*t.shape[:2], nc, chunk, *t.shape[3:])
    qc, kc, vc = rs(q), rs(k), rs(v)                    # [B,H,NC,Q,*]
    ic, fc = rs(i_raw), rs(logf)
    fcum = jnp.cumsum(fc, axis=-1)                      # F_t within chunk
    ftot = fcum[..., -1]                                # [B,H,NC]

    # intra-chunk stabilized parallel pieces (per chunk)
    dmat = fcum[..., :, None] - fcum[..., None, :] + ic[..., None, :]
    mask = jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :]
    dmat = jnp.where(mask[None, None, None], dmat, -jnp.inf)
    m_intra = dmat.max(axis=-1)                         # [B,H,NC,Q]

    def chunk_step(carry, xs):
        c0, n0, m0 = carry                              # [B,H,dq,dv],[B,H,dq],[B,H]
        qk, kk, vk, fk, ik, dk, mk, ftk = xs            # fk = in-chunk cumsum
        # combined stabilizer: running-max carry vs intra max
        m_t = jnp.maximum(fk + m0[..., None], mk)       # [B,H,Q]
        w = jnp.exp(dk - m_t[..., None])                # [B,H,Q,Q]
        scores = jnp.einsum("bhtd,bhsd->bhts", qk, kk)
        cw = scores * w
        num = jnp.einsum("bhts,bhsv->bhtv", cw, vk)
        den = cw.sum(-1)
        carry_scale = jnp.exp(fk + m0[..., None] - m_t)  # [B,H,Q]
        num = num + carry_scale[..., None] * jnp.einsum("bhtd,bhdv->bhtv", qk, c0)
        den = den + carry_scale * jnp.einsum("bhtd,bhd->bht", qk, n0)
        hk = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # chunk-end carry (t = Q): decay each in-chunk key to the boundary
        m_q = m_t[..., -1]
        dec = jnp.exp(ftk[..., None] - fk + ik - m_q[..., None])  # [B,H,Q]
        c1 = (jnp.exp(ftk + m0 - m_q)[..., None, None] * c0
              + jnp.einsum("bhs,bhsd,bhsv->bhdv", dec, kk, vk))
        n1 = (jnp.exp(ftk + m0 - m_q)[..., None] * n0
              + jnp.einsum("bhs,bhsd->bhd", dec, kk))
        return (c1, n1, m_q), hk

    carry = (jnp.zeros((b, h, dq, dv), jnp.float32),
             jnp.zeros((b, h, dq), jnp.float32),
             jnp.full((b, h), -1e30, jnp.float32))
    seq_first = lambda t: jnp.moveaxis(t, 2, 0)         # NC to the front
    _, hs = scan_util.scan(chunk_step, carry,
                           (seq_first(qc), seq_first(kc), seq_first(vc),
                            seq_first(fcum), seq_first(ic), seq_first(dmat),
                            seq_first(m_intra), seq_first(ftot)))
    # hs [NC,B,H,Q,dv] -> [B,H,S,dv]
    return jnp.moveaxis(hs, 0, 2).reshape(b, h, s, dv)


def init_mlstm_state(cfg: ArchConfig, batch: int) -> dict:
    d_inner, d_qk, nh = _dims(cfg)
    hq, hv = d_qk // nh, d_inner // nh
    return {"c": jnp.zeros((batch, nh, hq, hv), jnp.float32),
            "n": jnp.zeros((batch, nh, hq), jnp.float32),
            "m": jnp.full((batch, nh), -1e30, jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    nh = cfg.xlstm.num_heads
    hd = d // nh
    ks = jax.random.split(key, 4)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {
        # 4 gates (i, f, z, o) from input; block-diagonal recurrent per head
        "w_ih": dense_init(ks[0], d, 4 * d, dt),
        "w_hh": (jax.random.normal(ks[1], (nh, hd, 4 * hd), jnp.float32)
                 * hd ** -0.5).astype(dt),
        "b_gates": jnp.zeros((4 * d,), jnp.float32),
        "norm_scale": jnp.zeros((d,), jnp.float32),
        "w_down": dense_init(ks[2], d, d, dt),
    }


def slstm_forward(p: dict, cfg: ArchConfig, x: jnp.ndarray,
                  state: Optional[dict] = None):
    d = cfg.d_model
    nh = cfg.xlstm.num_heads
    hd = d // nh
    b, s, _ = x.shape
    if state is None:
        state = init_slstm_state(cfg, b)

    gx = (x @ p["w_ih"]).astype(jnp.float32) + p["b_gates"]     # [B,S,4d]

    def step(carry, g_t):
        h, c, n, m = carry                                      # [B,nh,hd] each, m [B,nh,hd]
        rec = jnp.einsum("bhd,hdk->bhk", h, p["w_hh"].astype(jnp.float32))
        g = g_t.reshape(b, nh, 4 * hd) + rec
        i_r, f_r, z_r, o_r = jnp.split(g, 4, axis=-1)
        m_new = jnp.maximum(f_r + m, i_r)                       # exp-gate stabilizer
        ig = jnp.exp(i_r - m_new)
        fg = jnp.exp(f_r + m - m_new)
        c = fg * c + ig * jnp.tanh(z_r)
        n = fg * n + ig
        h_new = jax.nn.sigmoid(o_r) * c / jnp.maximum(n, 1.0)
        return (h_new, c, n, m_new), h_new

    carry0 = (state["h"], state["c"], state["n"], state["m"])
    carryT, hs = jax.lax.scan(step, carry0, jnp.moveaxis(gx, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    h = rms_norm(h, p["norm_scale"])
    new_state = {"h": carryT[0], "c": carryT[1], "n": carryT[2], "m": carryT[3]}
    return h @ p["w_down"], new_state


def init_slstm_state(cfg: ArchConfig, batch: int) -> dict:
    d = cfg.d_model
    nh = cfg.xlstm.num_heads
    hd = d // nh
    z = lambda: jnp.zeros((batch, nh, hd), jnp.float32)
    return {"h": z(), "c": z(), "n": z(),
            "m": jnp.full((batch, nh, hd), -1e30, jnp.float32)}

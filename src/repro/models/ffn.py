"""Dense FFN: gated (SwiGLU/GeGLU) and plain MLP variants."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.launch.sharding import constrain
from repro.models.common import activation, dense_init


def init_ffn(key, d_model: int, d_ff: int, gated: bool, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p = {"w1": dense_init(ks[0], d_model, d_ff, dtype),
         "w2": dense_init(ks[1], d_ff, d_model, dtype)}
    if gated:
        p["w3"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def ffn_forward(p: dict, cfg_act: str, x: jnp.ndarray,
                gated: bool = True) -> jnp.ndarray:
    """x: [..., d_model] (rank 2 for MoE token-major, rank 3 for [B,S,d])."""
    act = activation(cfg_act)
    mid = (None,) * (x.ndim - 2)
    h = x @ p["w1"]
    h = constrain(h, "batch", *mid, "model")
    if gated:
        h = act(h) * (x @ p["w3"])
    else:
        h = act(h)
    out = h @ p["w2"]
    return constrain(out, "batch", *mid, None)

"""xLSTM language-model assembly (xlstm-125m).

Block pattern: mostly mLSTM with sLSTM at ``cfg.xlstm.slstm_at`` — expressed
as consecutive same-kind *runs*, each run a scan group over stacked params
(same compile-once-per-block-kind property as transformer.py).

Every block is pre-norm residual: ``h = h + block(rms_norm(h))``.
Decode state is O(1) per layer: mLSTM matrix memory / sLSTM scalar cells —
the property that qualifies this arch for long_500k.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.launch.sharding import constrain
from repro.models import scan_util
from repro.models import xlstm as xl
from repro.models.common import embed_init, rms_norm, stack_init
from repro.models.transformer import embed_tokens, unembed, cross_entropy


def layer_runs(cfg: ArchConfig) -> list[tuple[str, int, str]]:
    """[(group_name, count, kind)] — consecutive same-kind runs."""
    slstm = set(cfg.xlstm.slstm_at)
    kinds = ["slstm" if i in slstm else "mlstm" for i in range(cfg.num_layers)]
    runs, start = [], 0
    for i in range(1, cfg.num_layers + 1):
        if i == cfg.num_layers or kinds[i] != kinds[start]:
            runs.append((f"run{len(runs)}_{kinds[start]}", i - start, kinds[start]))
            start = i
    return runs


def _init_block(key, cfg: ArchConfig, kind: str) -> dict:
    p = {"norm": jnp.zeros((cfg.d_model,), jnp.float32)}
    if kind == "mlstm":
        p["cell"] = xl.init_mlstm(key, cfg)
    else:
        p["cell"] = xl.init_slstm(key, cfg)
    return p


def init_xlstm_lm(key, cfg: ArchConfig) -> dict:
    runs = layer_runs(cfg)
    ks = jax.random.split(key, 2 + len(runs))
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    in_key = "embed" if cfg.tie_embeddings else "embed_in"
    params = {
        in_key: embed_init(ks[0], cfg.vocab_size, cfg.d_model, dt),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = embed_init(ks[1], cfg.d_model, cfg.vocab_size, dt)
    for i, (name, n, kind) in enumerate(runs):
        params[name] = stack_init(ks[2 + i], n,
                                  lambda k, kind=kind: _init_block(k, cfg, kind))
    return params


def _scan_run(params_r, cfg: ArchConfig, h, kind: str, states=None):
    fwd = xl.mlstm_forward if kind == "mlstm" else xl.slstm_forward

    def body(carry, xs):
        if states is None:
            bp = xs
            out, _ = fwd(bp["cell"], cfg, rms_norm(carry, bp["norm"]))
            return carry + out, None
        bp, st = xs
        out, new_st = fwd(bp["cell"], cfg, rms_norm(carry, bp["norm"]),
                          state=st)
        return carry + out, new_st

    fn = jax.checkpoint(body) if (cfg.remat and states is None) else body
    xs = params_r if states is None else (params_r, states)
    return scan_util.scan(fn, h, xs)


def xlstm_forward(params: dict, cfg: ArchConfig, tokens: jnp.ndarray):
    h = embed_tokens(params, cfg, tokens)
    h = constrain(h, "batch", None, None)
    for name, n, kind in layer_runs(cfg):
        h, _ = _scan_run(params[name], cfg, h, kind)
    return unembed(params, cfg, h)


def xlstm_loss(params: dict, cfg: ArchConfig, batch: dict) -> jnp.ndarray:
    tokens = batch["tokens"]
    logits = xlstm_forward(params, cfg, tokens)
    return cross_entropy(logits[:, :-1], tokens[:, 1:])


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ArchConfig, batch: int) -> dict:
    groups = {}
    for name, n, kind in layer_runs(cfg):
        one = (xl.init_mlstm_state(cfg, batch) if kind == "mlstm"
               else xl.init_slstm_state(cfg, batch))
        groups[name] = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (n, *x.shape)), one)
    return {"states": groups, "pos": jnp.zeros((), jnp.int32)}


def decode_step(params: dict, cfg: ArchConfig, tokens: jnp.ndarray,
                state: dict) -> tuple[jnp.ndarray, dict]:
    h = embed_tokens(params, cfg, tokens)
    new_states = {}
    for name, n, kind in layer_runs(cfg):
        h, ns = _scan_run(params[name], cfg, h, kind,
                          states=state["states"][name])
        new_states[name] = ns
    logits = unembed(params, cfg, h)
    return logits[:, -1], {"states": new_states,
                           "pos": state["pos"] + tokens.shape[1]}

"""Mixture-of-Experts layer with expert parallelism (deepseek-v2, arctic).

Dispatch is the capacity-based gather/scatter formulation (MaxText-style,
TPU-friendly — no [T, E, C] one-hot tensor):

  1. router scores [T, E] (f32), token-choice top-k gate values;
  2. per expert, ``top_k(C)`` over the token axis selects which tokens the
     expert processes (capacity C = ceil(T·k/E·cf)); tokens beyond capacity
     are dropped (standard capacity drops — gate mass renormalized);
  3. gather  x_e = x[idx_e]  -> [E, C, d]   (E sharded on 'model' = EP),
  4. expert FFN via stacked einsum  [E, C, d] x [E, d, f],
  5. scatter-add back with gate weights.

Under GSPMD the gather/scatter happen per data shard (token axis stays on
'data'/'pod'); the [E, ...] tensors shard on 'model', so the only cross-chip
traffic is the activation all-to-all XLA inserts around the expert einsums —
exactly the EP traffic the roofline analysis counts.

deepseek-v2 extras: 2 shared (always-on) experts + first layer dense.
arctic extra: a dense FFN residual in parallel with the routed experts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoECfg
from repro.launch.sharding import constrain, shard_map_compat
from repro.models.common import activation, dense_init
from repro.models.ffn import ffn_forward, init_ffn


def init_moe(key, cfg: ArchConfig) -> dict:
    m = cfg.moe
    d = cfg.d_model
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    ks = jax.random.split(key, 6)
    scale = d ** -0.5

    def experts_w(k, din, dout):
        return (jax.random.normal(k, (m.num_experts, din, dout), jnp.float32)
                * scale).astype(dt)

    p = {
        "router": dense_init(ks[0], d, m.num_experts, jnp.float32),
        "experts_w1": experts_w(ks[1], d, m.d_expert),
        "experts_w3": experts_w(ks[2], d, m.d_expert),
        "experts_w2": (jax.random.normal(ks[3], (m.num_experts, m.d_expert, d),
                                         jnp.float32) * m.d_expert ** -0.5).astype(dt),
    }
    if m.num_shared:
        p["shared"] = init_ffn(ks[4], d, m.d_expert * m.num_shared, True, dt)
    if m.dense_residual:
        p["dense"] = init_ffn(ks[5], d, cfg.d_ff, True, dt)
    return p


def _routed_experts(xt, router, w1, w3, w2, *, cfg: ArchConfig,
                    num_local_experts: int, expert_offset) -> jnp.ndarray:
    """Routed-expert computation over LOCAL tokens and LOCAL experts.

    xt [T_loc, d]; w1/w3 [E_loc, d, f]; w2 [E_loc, f, d].  Pure function —
    runs identically as the single-device path (E_loc = E, offset 0) and as
    the shard_map body (E_loc = E/tp, offset = rank*E_loc).
    """
    m = cfg.moe
    t, d = xt.shape
    scores = xt.astype(jnp.float32) @ router                    # [T_loc, E]
    probs = jax.nn.softmax(scores, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, m.top_k)         # [T_loc, k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # affinity[t, e] = gate value if e in token t's top-k else 0  (scatter,
    # avoids a [T, k, E] one-hot intermediate)
    affinity = jnp.zeros((t, m.num_experts), jnp.float32)
    affinity = affinity.at[jnp.arange(t)[:, None], gate_idx].add(gate_vals)
    aff_loc = jax.lax.dynamic_slice(
        affinity, (0, expert_offset), (t, num_local_experts))   # [T_loc, E_loc]

    # per-(shard, expert) capacity selection — LOCAL top_k over T_loc tokens,
    # the standard distributed-MoE capacity semantics (EXPERIMENTS.md §Perf
    # iteration 0.e: a global [E, T] top_k all-gathered 1M tokens per layer)
    cap = int(max(1, round(t * m.top_k / m.num_experts * m.capacity_factor)))
    cap = min(cap, t)
    top_aff, top_idx = jax.lax.top_k(aff_loc.T, cap)            # [E_loc, C]
    x_e = jnp.take(xt, top_idx, axis=0)                         # [E_loc, C, d]

    act = activation(cfg.ffn_act)
    h = jnp.einsum("ecd,edf->ecf", x_e, w1)
    g = jnp.einsum("ecd,edf->ecf", x_e, w3)
    h = act(h) * g
    y_e = jnp.einsum("ecf,efd->ecd", h, w2)                     # [E_loc, C, d]
    # slots an expert filled with zero-affinity tokens (under-subscription)
    # carry weight 0 and vanish here.  Gate weights are f32; cast the product
    # back to the activation dtype or the f32 result promotes the whole
    # residual stream — doubling every downstream activation/grad/collective
    # (EXPERIMENTS.md §Perf deepseek iteration 1).
    y_e = (y_e.astype(jnp.float32) * top_aff[..., None]).astype(xt.dtype)

    out = jnp.zeros((t, d), xt.dtype)
    out = out.at[top_idx.reshape(-1)].add(y_e.reshape(-1, d))
    return out


def moe_forward(p: dict, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    """x: [B, S, d] -> [B, S, d].

    On a mesh: shard_map with tokens on ('pod','data') and experts on
    'model' — router + gating replicated per model rank (tiny), expert
    FFNs fully local, ONE psum over 'model' combines each token's top-k
    expert outputs.  No global [E, T] top_k, no token all-gathers.
    """
    from repro.launch.sharding import current_mesh

    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)

    mesh = current_mesh()
    tp = (mesh.shape["model"] if mesh is not None
          and "model" in mesh.axis_names else 1)
    if (mesh is None or tp == 1 or m.num_experts % tp != 0
            or t % _dp_size(mesh) != 0):
        out = _routed_experts(xt, p["router"], p["experts_w1"],
                              p["experts_w3"], p["experts_w2"], cfg=cfg,
                              num_local_experts=m.num_experts,
                              expert_offset=0)
    else:
        from jax.sharding import PartitionSpec as P
        e_loc = m.num_experts // tp
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        dp = dp if len(dp) > 1 else dp[0]

        def body(xt_loc, router, w1, w3, w2):
            from repro.models.common import grad_cast
            # d(xt_loc) is promoted to f32 by the f32 router/gating path and
            # would cross the shard_map transpose psum at double width;
            # grad_cast pins the outgoing cotangent to xt's dtype BEFORE the
            # psum (§Perf deepseek iteration 3).
            xt_loc = grad_cast(xt_loc)
            rank = jax.lax.axis_index("model")
            out = _routed_experts(xt_loc, router, w1, w3, w2, cfg=cfg,
                                  num_local_experts=e_loc,
                                  expert_offset=rank * e_loc)
            return jax.lax.psum(out, "model")   # combine top-k expert outputs

        out = shard_map_compat(
            body, mesh=mesh,
            in_specs=(P(dp, None), P(None, None), P("model", None, None),
                      P("model", None, None), P("model", None, None)),
            out_specs=P(dp, None),
        )(xt, p["router"], p["experts_w1"], p["experts_w3"], p["experts_w2"])

    out = constrain(out, "batch", None)
    if m.num_shared:
        out = out + ffn_forward(p["shared"], cfg.ffn_act, xt, gated=True)
    if m.dense_residual:
        out = out + ffn_forward(p["dense"], cfg.ffn_act, xt, gated=True)
    return out.reshape(b, s, d)


def _dp_size(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def moe_aux_loss(p: dict, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Load-balancing auxiliary loss (Switch-style f·P)."""
    m = cfg.moe
    xt = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    probs = jax.nn.softmax(xt @ p["router"], axis=-1)
    _, gate_idx = jax.lax.top_k(probs, m.top_k)
    frac = jax.nn.one_hot(gate_idx, m.num_experts).sum((0, 1)) / gate_idx.size
    imp = probs.mean(0)
    return m.num_experts * jnp.sum(frac * imp)

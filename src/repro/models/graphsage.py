"""GraphSAGE (mean aggregator) on static padded minibatch blocks.

The paper trains 3-layer GraphSAGE (§4.1).  The forward pass consumes the
:class:`repro.core.minibatch.DeviceBatch` format shared by all four samplers,
so a single compiled step serves NS / GNS / LADIES / LazyGCN — the importance
weighting of eq. (10) is entirely inside ``nbr_w``.

Layer ℓ (paper eq. 1/3 with mean aggregator + concat update):

    a_v = Σ_k  w[v,k] · h_src[idx[v,k]]          (weighted neighbor mean)
    h'_v = g(W · [h_v ; a_v] + b)

The aggregation is the compute hot-spot and maps to the Pallas
``gather_agg`` kernel (kernels/gather_agg.py); ``aggregate_impl`` picks the
kernel or the pure-jnp reference (CPU/dry-run default).

The input layer assembles features from the device cache (hits) and the
streamed rows (misses) — the data-movement core of the paper:

    h0 = where(slot >= 0, cache_table[slot], streamed)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.minibatch import DeviceBatch, LayerBlock


@dataclasses.dataclass(frozen=True)
class SageConfig:
    feat_dim: int
    hidden_dim: int = 256              # paper: 256/512
    num_classes: int = 32
    num_layers: int = 3
    aggregate_impl: str = "reference"  # "reference" | "pallas"
    input_impl: str = "where"          # "where" | "fused"  (fused = Pallas
                                       # cache-lookup + layer-0 gather in one
                                       # pass; h0 is never materialized)
    input_kernel: str = "pallas"       # fused-op backend: "pallas" | "reference"
                                       # (the pod dry-run lowers "reference" —
                                       # interpret-mode grids at paper scale
                                       # are uncompilable from a CPU host)
    sample_kernel: str = "reference"   # device-sampling gather backend:
                                       # "pallas" | "reference" (same split as
                                       # input_kernel; engine resolves "auto"
                                       # by jax.default_backend())
    cache_shard_axis: Optional[str] = None
                                       # mesh axis the cache table is row-
                                       # sharded over; with a mesh in scope
                                       # the fused op runs per-shard + psum
    num_groups: int = 1                # DP groups collated into one batch:
                                       # every device array is the group-
                                       # order concat of per-group arrays
                                       # (block pads stay PER-GROUP), so dst
                                       # selection gathers each group's
                                       # leading rows instead of slicing a
                                       # global prefix — the GNSEngine's
                                       # DP > 1 regime


def reference_aggregate(h_src: jnp.ndarray, nbr_idx: jnp.ndarray,
                        nbr_w: jnp.ndarray) -> jnp.ndarray:
    """Pure-jnp oracle for the gather + weighted-mean aggregation."""
    gathered = jnp.take(h_src, nbr_idx, axis=0)        # [D, K, F]
    return jnp.einsum("dk,dkf->df", nbr_w, gathered)


def _get_aggregate(impl: str) -> Callable:
    if impl == "pallas":
        from repro.kernels.ops import gather_agg
        return gather_agg
    return reference_aggregate


def init_params(rng: jax.Array, cfg: SageConfig) -> dict:
    keys = jax.random.split(rng, cfg.num_layers)
    params = {"layers": []}
    in_dim = cfg.feat_dim
    for i in range(cfg.num_layers):
        out_dim = cfg.num_classes if i == cfg.num_layers - 1 else cfg.hidden_dim
        scale = jnp.sqrt(2.0 / (2 * in_dim))
        w = jax.random.normal(keys[i], (2 * in_dim, out_dim), jnp.float32) * scale
        b = jnp.zeros((out_dim,), jnp.float32)
        params["layers"].append({"w": w, "b": b})
        in_dim = out_dim
    return params


def assemble_input(batch: DeviceBatch, cache_table: jnp.ndarray,
                   prefix: Optional[int] = None,
                   rows: Optional[np.ndarray] = None) -> jnp.ndarray:
    """h0 from cache hits + streamed misses (the GNS data path).

    ``prefix`` statically truncates to the first N rows — the fused input
    path only needs the destination self-rows, not the full padded h0.
    ``rows`` (a static index vector) generalizes the prefix to non-leading
    selections: a group-collated batch's destination self-rows are each
    group's leading block, not a global prefix (see ``_dst_rows``).
    """
    slots = batch.input_cache_slots
    streamed = batch.input_streamed
    mask = batch.input_mask
    if rows is not None:
        rows = jnp.asarray(rows, jnp.int32)
        slots = jnp.take(slots, rows, axis=0)
        streamed = jnp.take(streamed, rows, axis=0)
        mask = jnp.take(mask, rows, axis=0)
    elif prefix is not None:
        slots, streamed, mask = slots[:prefix], streamed[:prefix], mask[:prefix]
    hit = slots >= 0
    cached_rows = jnp.take(cache_table, jnp.clip(slots, 0), axis=0)
    h0 = jnp.where(hit[:, None], cached_rows, streamed)
    return h0 * mask[:, None]


def _dst_rows(num_groups: int, blk: LayerBlock) -> Optional[np.ndarray]:
    """Global rows of the destination self-representations, group-collated.

    With one group the destinations are the array's leading ``num_dst`` rows
    (slice, no gather).  A collated batch concatenates G groups' per-group-
    padded arrays, so group g's destinations live at ``g·num_src + [0,
    num_dst)`` of the layer's global source array — a static index vector.
    """
    if num_groups <= 1:
        return None
    return np.concatenate([g * blk.num_src + np.arange(blk.num_dst)
                           for g in range(num_groups)]).astype(np.int32)


def forward(params: dict, batch: DeviceBatch, cache_table: jnp.ndarray,
            cfg: SageConfig, local_shard=None, device_adj=None) -> jnp.ndarray:
    """Returns logits [B_padded, num_classes].

    ``local_shard`` forwards the locality fast-path gate to the fused input
    op: a static int when the batch assembler established that every cache
    hit of THIS batch resolves on that shard (see
    ``FeatureStore.assemble_input``), or a TRACED int32 home-shard vector
    (one entry per DP group, -1 = no contract) — the device-resident form
    that lets one compiled step serve any mix of home shards (GNSEngine).

    ``device_adj`` (a :class:`repro.sampling.DeviceCacheAdj`, paired with a
    ``backend="device"`` batch carrying ``sample_key``) switches layer 0 to
    the on-device GNS draw: the neighbor aggregate comes straight from the
    fused draw→gather op and the batch ships NO layer-0 neighbor lanes.
    """
    agg = _get_aggregate(cfg.aggregate_impl)
    device = device_adj is not None and batch.sample_key is not None
    fused = cfg.input_impl == "fused" and not device
    h = None if (fused or device) else assemble_input(batch, cache_table)
    for i, (blk, layer) in enumerate(zip(batch.blocks, params["layers"])):
        dst_rows = _dst_rows(cfg.num_groups, blk)
        if i == 0 and device:
            # device-resident GNS input layer: draw + importance weights +
            # feature gather inside the step (repro.sampling.kernels).  The
            # aggregate has no parameter dependence — stop_gradient keeps
            # the backward out of the (forward-only) Pallas op entirely.
            from repro.launch.sharding import current_mesh
            from repro.sampling.kernels import gns_sample_agg
            mesh = current_mesh()
            axis = cfg.cache_shard_axis
            if mesh is None or axis not in getattr(mesh, "axis_names", ()):
                mesh = axis = None
            sg = jax.lax.stop_gradient
            a = gns_sample_agg(
                jax.tree_util.tree_map(sg, device_adj), sg(cache_table),
                sg(batch.input_cache_slots), sg(batch.input_fb_rows),
                sg(batch.input_fb_w), sg(batch.sample_key),
                impl=cfg.sample_kernel, mesh=mesh, shard_axis=axis,
                num_groups=cfg.num_groups)
            h_dst = assemble_input(batch, cache_table,
                                   prefix=blk.num_dst, rows=dst_rows)
        elif i == 0 and fused:
            # one Pallas pass: cache/streamed select + layer-0 gather-agg;
            # self rows come from a statically-sliced prefix assembly.  On a
            # mesh with the cache table row-sharded over cfg.cache_shard_axis
            # each device runs the kernel on its own shard (psum'd partials,
            # or the psum-free local fast path when the batch is fully local).
            from repro.kernels.ops import cache_lookup_agg
            from repro.launch.sharding import current_mesh
            mesh = current_mesh()
            axis = cfg.cache_shard_axis
            if mesh is None or axis not in getattr(mesh, "axis_names", ()):
                mesh = axis = None
            if local_shard is None or isinstance(local_shard,
                                                 (int, np.integer)):
                ls_static, ls_vec = local_shard, None
            else:                     # traced per-group home-shard vector
                ls_static, ls_vec = None, local_shard
            a = cache_lookup_agg(cache_table, batch.input_streamed,
                                 batch.input_cache_slots,
                                 blk.nbr_idx, blk.nbr_w,
                                 impl=cfg.input_kernel,
                                 mesh=mesh, shard_axis=axis,
                                 local_shard=ls_static,
                                 local_shards=ls_vec)
            h_dst = assemble_input(batch, cache_table,
                                   prefix=blk.num_dst, rows=dst_rows)
        else:
            h_dst = (h[: blk.num_dst] if dst_rows is None
                     else jnp.take(h, jnp.asarray(dst_rows), axis=0))
            a = agg(h, blk.nbr_idx, blk.nbr_w)
        z = jnp.concatenate([h_dst, a], axis=-1) @ layer["w"] + layer["b"]
        h = jax.nn.relu(z) if i < len(batch.blocks) - 1 else z
        h = h * blk.dst_mask[:, None]
    return h


def loss_fn(params: dict, batch: DeviceBatch, cache_table: jnp.ndarray,
            cfg: SageConfig, local_shard=None,
            device_adj=None) -> tuple[jnp.ndarray, jnp.ndarray]:
    logits = forward(params, batch, cache_table, cfg, local_shard=local_shard,
                     device_adj=device_adj)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, batch.labels[:, None].astype(jnp.int32),
                               axis=-1)[:, 0]
    denom = jnp.maximum(batch.label_mask.sum(), 1.0)
    loss = (nll * batch.label_mask).sum() / denom
    acc = ((jnp.argmax(logits, -1) == batch.labels) * batch.label_mask).sum() / denom
    return loss, acc


def dummy_cache_table(feat_dim: int) -> jnp.ndarray:
    """1-row zero cache for samplers without a device cache (NS/LADIES)."""
    return jnp.zeros((1, feat_dim), jnp.float32)

"""Encoder-decoder assembly (seamless-m4t): bidirectional encoder over stub
frame embeddings + causal decoder with cross-attention.

The modality frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings [B, S_enc, d_model] directly (``input_specs``
provides them).  Encoder and decoder stacks are scan-stacked like
transformer.py; the decoder block adds a cross-attention sublayer whose K/V
are projected from the (layer-constant) encoder output inside the scan body.

Decode: per-layer self-attn KV caches + per-layer *precomputed* cross K/V
([L, B, H, S_enc, Dh] — computed once by ``prefill_encoder``), so each decode
step re-reads the compressed cross context but never re-runs the encoder.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.launch.sharding import constrain
from repro.models import attention as attn
from repro.models import scan_util
from repro.models import ffn as ffn_mod
from repro.models.common import cross_entropy, embed_init, rms_norm, stack_init
from repro.models.transformer import embed_tokens, unembed


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _init_enc_block(key, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 2)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {
        "norm1": jnp.zeros((cfg.d_model,), jnp.float32),
        "norm2": jnp.zeros((cfg.d_model,), jnp.float32),
        "attn": attn.init_attn(ks[0], cfg),
        "ffn": ffn_mod.init_ffn(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_ffn, dt),
    }


def _init_dec_block(key, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 3)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {
        "norm1": jnp.zeros((cfg.d_model,), jnp.float32),
        "norm_x": jnp.zeros((cfg.d_model,), jnp.float32),
        "norm2": jnp.zeros((cfg.d_model,), jnp.float32),
        "attn": attn.init_attn(ks[0], cfg),
        "xattn": attn.init_attn(ks[1], cfg, cross=True),
        "ffn": ffn_mod.init_ffn(ks[2], cfg.d_model, cfg.d_ff, cfg.gated_ffn, dt),
    }


def init_encdec(key, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "embed_in": embed_init(ks[0], cfg.vocab_size, cfg.d_model,
                               jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        "unembed": embed_init(ks[1], cfg.d_model, cfg.vocab_size,
                              jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32),
        "encoder": stack_init(ks[2], cfg.encoder_layers,
                              lambda k: _init_enc_block(k, cfg)),
        "decoder": stack_init(ks[3], cfg.num_layers,
                              lambda k: _init_dec_block(k, cfg)),
    }


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------

def encode(params: dict, cfg: ArchConfig, frame_embeds: jnp.ndarray) -> jnp.ndarray:
    """frame_embeds [B, S_enc, d] -> encoder output [B, S_enc, d]."""
    h = frame_embeds.astype(
        jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    h = constrain(h, "batch", None, None)
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(carry, bp):
        x = carry
        a, _ = attn.attn_forward(bp["attn"], cfg, rms_norm(x, bp["norm1"]),
                                 positions, causal=False)
        x = x + a
        x = x + ffn_mod.ffn_forward(bp["ffn"], cfg.ffn_act,
                                    rms_norm(x, bp["norm2"]), cfg.gated_ffn)
        return x, None

    fn = jax.checkpoint(body) if cfg.remat else body
    h, _ = scan_util.scan(fn, h, params["encoder"])
    return h


def prefill_encoder(params: dict, cfg: ArchConfig,
                    frame_embeds: jnp.ndarray) -> dict:
    """Run the encoder once and project per-decoder-layer cross K/V."""
    enc_out = encode(params, cfg, frame_embeds)

    def project(bp):
        k, v = attn.make_cross_kv(bp["xattn"], cfg, enc_out)
        return {"k": k, "v": v}

    cross = jax.vmap(project)(
        jax.tree_util.tree_map(lambda x: x, params["decoder"]))
    return cross                              # leaves [L, B, Hkv, S_enc, Dh]


# ---------------------------------------------------------------------------
# decoder
# ---------------------------------------------------------------------------

def _dec_block(bp, cfg: ArchConfig, h, positions, enc_out=None,
               cross_kv=None, cache=None, cache_pos=None):
    """One decoder block.  Cross K/V either projected from enc_out (train)
    or precomputed (decode)."""
    a, new_cache = attn.attn_forward(bp["attn"], cfg, rms_norm(h, bp["norm1"]),
                                     positions, kv_cache=cache,
                                     cache_pos=cache_pos)
    h = h + a
    if cross_kv is None:
        cross_kv = attn.make_cross_kv(bp["xattn"], cfg, enc_out)
    xa, _ = attn.attn_forward(bp["xattn"], cfg, rms_norm(h, bp["norm_x"]),
                              positions, cross_kv=cross_kv)
    h = h + xa
    h = h + ffn_mod.ffn_forward(bp["ffn"], cfg.ffn_act,
                                rms_norm(h, bp["norm2"]), cfg.gated_ffn)
    return h, new_cache


def encdec_loss(params: dict, cfg: ArchConfig, batch: dict) -> jnp.ndarray:
    """batch: frame_embeds [B, S_enc, d] + tokens [B, S_dec]."""
    enc_out = encode(params, cfg, batch["frame_embeds"])
    tokens = batch["tokens"]
    h = embed_tokens(params, cfg, tokens)
    h = constrain(h, "batch", None, None)
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(carry, bp):
        out, _ = _dec_block(bp, cfg, carry, positions, enc_out=enc_out)
        return out, None

    fn = jax.checkpoint(body) if cfg.remat else body
    h, _ = scan_util.scan(fn, h, params["decoder"])
    logits = unembed(params, cfg, h)
    return cross_entropy(logits[:, :-1], tokens[:, 1:])


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ArchConfig, batch: int, cache_len: int,
                      enc_len: int) -> dict:
    """Zero self-attn caches + zero cross-KV slots (filled by prefill)."""
    one = attn.init_kv_cache(cfg, batch, cache_len)
    caches = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (cfg.num_layers, *x.shape)), one)
    hkv, dh = cfg.num_kv_heads, cfg.head_dim_eff
    cdt = attn.cache_dtype(cfg)
    cross = {"k": jnp.zeros((cfg.num_layers, batch, hkv, enc_len, dh), cdt),
             "v": jnp.zeros((cfg.num_layers, batch, hkv, enc_len, dh), cdt)}
    return {"caches": caches, "cross": cross, "pos": jnp.zeros((), jnp.int32)}


def decode_step(params: dict, cfg: ArchConfig, tokens: jnp.ndarray,
                state: dict) -> tuple[jnp.ndarray, dict]:
    h = embed_tokens(params, cfg, tokens)
    b, s, _ = h.shape
    pos = state["pos"]
    positions = jnp.broadcast_to(pos + jnp.arange(s, dtype=jnp.int32)[None],
                                 (b, s))

    def body(carry, xs):
        bp, cache, cross = xs
        out, new_cache = _dec_block(bp, cfg, carry, positions,
                                    cross_kv=(cross["k"], cross["v"]),
                                    cache=cache, cache_pos=pos)
        return out, new_cache

    h, new_caches = scan_util.scan(body, h, (params["decoder"], state["caches"],
                                           state["cross"]))
    logits = unembed(params, cfg, h)
    return logits[:, -1], {"caches": new_caches, "cross": state["cross"],
                           "pos": pos + s}

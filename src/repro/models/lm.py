"""Unified LM model API — one interface over all 10 assigned architectures.

``get_model(cfg)`` dispatches on the config's family markers and returns a
:class:`ModelAPI` whose members are pure functions (jit/pjit-safe):

  init(key)                     -> params pytree
  loss(params, batch)           -> scalar CE      (lowered for train shapes)
  decode_init(batch, cache_len) -> decode state   (zeros; structure source)
  decode_step(params, tok, st)  -> (logits, st')  (lowered for decode shapes)

Batch layouts by family (see launch/specs.input_specs):
  decoder       {"tokens": [B, S]}
  vlm           {"tokens": [B, S - P], "patch_embeds": [B, P, d]}  (P frontend
                tokens prepended; total positions == S for roofline parity)
  audio enc-dec {"frame_embeds": [B, S/4, d], "tokens": [B, 3S/4]} (frontend
                stub frames + text; total positions == S)
  ssm/hybrid    {"tokens": [B, S]}
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encdec, hybrid, transformer, xlstm_lm


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ArchConfig
    init: Callable[[jax.Array], Any]
    loss: Callable[[Any, dict], jnp.ndarray]
    decode_init: Callable[..., Any]
    decode_step: Callable[[Any, jnp.ndarray, Any], tuple]
    # prefill(params, tokens [B,S], state) -> (logits [B,V], state').
    # Attention families: decode_step with S tokens (fills the KV cache).
    # Recurrent families (ssm/xlstm/hybrid): the PARALLEL form — a per-token
    # recurrence would be wrong for both speed and the dry-run cost model;
    # final-state emission is omitted (cost delta negligible, DESIGN.md §5).
    prefill: Callable[[Any, jnp.ndarray, Any], tuple] = None


def enc_dec_split(cfg: ArchConfig, seq_len: int) -> tuple[int, int]:
    """(S_enc, S_dec) with S_enc + S_dec == seq_len (audio enc-dec)."""
    s_enc = max(seq_len // 4, 1)
    return s_enc, seq_len - s_enc


def get_model(cfg: ArchConfig) -> ModelAPI:
    if cfg.encoder_layers > 0:
        dec = lambda p, t, s: encdec.decode_step(p, cfg, t, s)
        return ModelAPI(
            cfg=cfg,
            init=lambda key: encdec.init_encdec(key, cfg),
            loss=lambda p, b: encdec.encdec_loss(p, cfg, b),
            decode_init=lambda batch, cache_len, enc_len: (
                encdec.init_decode_state(cfg, batch, cache_len, enc_len)),
            decode_step=dec,
            prefill=dec,
        )
    if cfg.xlstm is not None:
        def xl_prefill(p, t, s):
            logits = xlstm_lm.xlstm_forward(p, cfg, t)
            return logits[:, -1], s
        return ModelAPI(
            cfg=cfg,
            init=lambda key: xlstm_lm.init_xlstm_lm(key, cfg),
            loss=lambda p, b: xlstm_lm.xlstm_loss(p, cfg, b),
            decode_init=lambda batch, cache_len=0: (
                xlstm_lm.init_decode_state(cfg, batch)),
            decode_step=lambda p, t, s: xlstm_lm.decode_step(p, cfg, t, s),
            prefill=xl_prefill,
        )
    if cfg.ssm is not None:
        def hy_prefill(p, t, s):
            logits = hybrid.hybrid_forward(p, cfg, t)
            return logits[:, -1], s
        return ModelAPI(
            cfg=cfg,
            init=lambda key: hybrid.init_hybrid(key, cfg),
            loss=lambda p, b: hybrid.hybrid_loss(p, cfg, b),
            decode_init=lambda batch, cache_len: (
                hybrid.init_decode_state(cfg, batch, cache_len)),
            decode_step=lambda p, t, s: hybrid.decode_step(p, cfg, t, s),
            prefill=hy_prefill,
        )
    # decoder-only (dense / moe / mla / vlm-with-patch-prefix)
    dec = lambda p, t, s: transformer.lm_decode_step(p, cfg, t, s)
    return ModelAPI(
        cfg=cfg,
        init=lambda key: transformer.init_lm(key, cfg),
        loss=lambda p, b: transformer.lm_loss(p, cfg, b),
        decode_init=lambda batch, cache_len: (
            transformer.init_decode_state(cfg, batch, cache_len)),
        decode_step=dec,
        prefill=dec,
    )


def make_batch(cfg: ArchConfig, seq_len: int, batch: int,
               rng: Optional[jax.Array] = None, vocab_clip: int = 0) -> dict:
    """Concrete random batch of the family's layout (smoke tests/examples)."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(rng)
    vocab = min(cfg.vocab_size, vocab_clip) if vocab_clip else cfg.vocab_size
    if cfg.encoder_layers > 0:
        s_enc, s_dec = enc_dec_split(cfg, seq_len)
        return {
            "frame_embeds": jax.random.normal(k1, (batch, s_enc, cfg.d_model),
                                              jnp.float32),
            "tokens": jax.random.randint(k2, (batch, s_dec), 0, vocab,
                                         jnp.int32),
        }
    if cfg.frontend == "vision":
        p = min(cfg.frontend_tokens, max(seq_len - 1, 1))
        return {
            "patch_embeds": jax.random.normal(k1, (batch, p, cfg.d_model),
                                              jnp.float32),
            "tokens": jax.random.randint(k2, (batch, seq_len - p), 0, vocab,
                                         jnp.int32),
        }
    return {"tokens": jax.random.randint(k2, (batch, seq_len), 0, vocab,
                                         jnp.int32)}

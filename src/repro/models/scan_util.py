"""Scan wrapper with an unroll context for dry-run cost probes.

XLA's ``cost_analysis`` counts a ``while`` (lax.scan) body ONCE, not
multiplied by the trip count (verified empirically — see
EXPERIMENTS.md §Dry-run "cost accounting"), so a scanned 28-layer model
under-reports FLOPs/bytes/collectives by ~28x.  The dry-run therefore
compiles each cell twice:

  1. the production program (scanned layers) — the compile/shard proof and
     memory_analysis artifact;
  2. a cost probe under ``unrolled()`` — every layer/accum scan fully
     unrolled so cost_analysis and the HLO collective census are exact.

Only LAYER and grad-accum scans go through this wrapper.  Time-step scans
(sLSTM recurrence, SSD inter-chunk state scan) stay rolled — their
undercounted share is small (<5%, quantified in EXPERIMENTS.md) and
unrolling 4k time steps would be un-compilable.
"""
from __future__ import annotations

import contextlib
import threading

import jax

_state = threading.local()


def unroll_scans() -> bool:
    return getattr(_state, "unroll", False)


@contextlib.contextmanager
def unrolled(on: bool = True):
    prev = unroll_scans()
    _state.unroll = on
    try:
        yield
    finally:
        _state.unroll = prev


def scan(f, init, xs, length=None):
    """lax.scan that fully unrolls inside an ``unrolled()`` scope."""
    return jax.lax.scan(f, init, xs, length=length,
                        unroll=True if unroll_scans() else 1)

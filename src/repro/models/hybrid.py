"""Hybrid Mamba2 + shared-attention assembly (zamba2-2.7b).

Structure: ``num_layers`` Mamba2 blocks; after every ``shared_attn_every``
of them, ONE shared transformer block (self-attn + FFN, a single parameter
set reused across all invocations) is applied — zamba2's parameter-sharing
trick.  With 54 layers and cadence 6 that is 9 invocations of the shared
block, each with its own KV cache (weights shared, state not).

Scan layout: outer ``lax.scan`` over the 9 groups; body = inner scan over the
6 Mamba2 blocks of the group (params reshaped [G, C, ...]) followed by the
shared block (params closed over — constant across groups).  Compiles one
group body regardless of depth.

Pure-SSM configs (shared_attn_every == 0) degenerate to a single scan over
all Mamba2 blocks — the same module serves both families.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.launch.sharding import constrain
from repro.models import attention as attn
from repro.models import scan_util
from repro.models import ffn as ffn_mod
from repro.models import ssm
from repro.models.common import embed_init, rms_norm, stack_init
from repro.models.transformer import embed_tokens, unembed, cross_entropy


def group_dims(cfg: ArchConfig) -> tuple[int, int]:
    """(num_groups, group_size); group_size == num_layers if no shared attn."""
    c = cfg.shared_attn_every
    if not c:
        return 1, cfg.num_layers
    assert cfg.num_layers % c == 0, (cfg.num_layers, c)
    return cfg.num_layers // c, c


def _init_mamba_block(key, cfg: ArchConfig) -> dict:
    return {"norm": jnp.zeros((cfg.d_model,), jnp.float32),
            "cell": ssm.init_ssm(key, cfg)}


def _init_shared_block(key, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 2)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {
        "norm1": jnp.zeros((cfg.d_model,), jnp.float32),
        "norm2": jnp.zeros((cfg.d_model,), jnp.float32),
        "attn": attn.init_attn(ks[0], cfg),
        "ffn": ffn_mod.init_ffn(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_ffn, dt),
    }


def init_hybrid(key, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 4)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    in_key = "embed" if cfg.tie_embeddings else "embed_in"
    params = {
        in_key: embed_init(ks[0], cfg.vocab_size, cfg.d_model, dt),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        "mamba_layers": stack_init(ks[1], cfg.num_layers,
                                   lambda k: _init_mamba_block(k, cfg)),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = embed_init(ks[2], cfg.d_model, cfg.vocab_size, dt)
    if cfg.shared_attn_every:
        params["shared"] = _init_shared_block(ks[3], cfg)
    return params


def _regroup(tree, g: int, c: int):
    """[L, ...] stacked params -> [G, C, ...]."""
    return jax.tree_util.tree_map(
        lambda x: x.reshape(g, c, *x.shape[1:]), tree)


def _mamba_scan(params_c, cfg: ArchConfig, h, states=None):
    def body(carry, xs):
        if states is None:
            bp = xs
            out, _ = ssm.ssm_forward(bp["cell"], cfg, rms_norm(carry, bp["norm"]))
            return carry + out, None
        bp, st = xs
        out, new_st = ssm.ssm_forward(bp["cell"], cfg,
                                      rms_norm(carry, bp["norm"]), state=st)
        return carry + out, new_st

    fn = jax.checkpoint(body) if (cfg.remat and states is None) else body
    xs = params_c if states is None else (params_c, states)
    return scan_util.scan(fn, h, xs)


def _shared_block(sp, cfg: ArchConfig, h, positions, cache=None, cache_pos=None):
    a, new_cache = attn.attn_forward(sp["attn"], cfg, rms_norm(h, sp["norm1"]),
                                     positions, kv_cache=cache,
                                     cache_pos=cache_pos)
    h = h + a
    h = h + ffn_mod.ffn_forward(sp["ffn"], cfg.ffn_act,
                                rms_norm(h, sp["norm2"]), cfg.gated_ffn)
    return h, new_cache


def hybrid_forward(params: dict, cfg: ArchConfig, tokens: jnp.ndarray):
    h = embed_tokens(params, cfg, tokens)
    h = constrain(h, "batch", None, None)
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    g, c = group_dims(cfg)
    grouped = _regroup(params["mamba_layers"], g, c)

    if not cfg.shared_attn_every:
        h, _ = _mamba_scan(params["mamba_layers"], cfg, h)
        return unembed(params, cfg, h)

    shared = params["shared"]

    def group_body(carry, params_g):
        x, _ = _mamba_scan(params_g, cfg, carry)
        x, _ = _shared_block(shared, cfg, x, positions)
        return x, None

    h, _ = scan_util.scan(group_body, h, grouped)
    return unembed(params, cfg, h)


def hybrid_loss(params: dict, cfg: ArchConfig, batch: dict) -> jnp.ndarray:
    tokens = batch["tokens"]
    logits = hybrid_forward(params, cfg, tokens)
    return cross_entropy(logits[:, :-1], tokens[:, 1:])


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ArchConfig, batch: int, cache_len: int) -> dict:
    g, c = group_dims(cfg)
    one = ssm.init_ssm_state(cfg, batch)
    if cfg.shared_attn_every:
        mamba = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None, None], (g, c, *x.shape)), one)
    else:                                  # pure-SSM: flat [L, ...] states
        mamba = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (cfg.num_layers, *x.shape)), one)
    state = {"mamba": mamba, "pos": jnp.zeros((), jnp.int32)}
    if cfg.shared_attn_every:
        kv = attn.init_kv_cache(cfg, batch, cache_len)
        state["shared_kv"] = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (g, *x.shape)), kv)
    return state


def decode_step(params: dict, cfg: ArchConfig, tokens: jnp.ndarray,
                state: dict) -> tuple[jnp.ndarray, dict]:
    h = embed_tokens(params, cfg, tokens)
    b, s, _ = h.shape
    pos = state["pos"]
    positions = jnp.broadcast_to(pos + jnp.arange(s, dtype=jnp.int32)[None],
                                 (b, s))
    g, c = group_dims(cfg)
    grouped = _regroup(params["mamba_layers"], g, c)

    if not cfg.shared_attn_every:
        h, new_m = _mamba_scan(params["mamba_layers"], cfg, h,
                               states=state["mamba"])
        logits = unembed(params, cfg, h)
        return logits[:, -1], {"mamba": new_m, "pos": pos + s}

    shared = params["shared"]

    def group_body(carry, xs):
        params_g, m_states, kv = xs
        x, new_m = _mamba_scan(params_g, cfg, carry, states=m_states)
        x, new_kv = _shared_block(shared, cfg, x, positions,
                                  cache=kv, cache_pos=pos)
        return x, (new_m, new_kv)

    h, (new_m, new_kv) = scan_util.scan(
        group_body, h, (grouped, state["mamba"], state["shared_kv"]))
    logits = unembed(params, cfg, h)
    return logits[:, -1], {"mamba": new_m, "shared_kv": new_kv, "pos": pos + s}

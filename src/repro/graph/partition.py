"""Graph partitioning for multi-host pods.

At 1000+ nodes the full graph does not live in one host's RAM (papers100M
features alone are 57 GB).  We hash-partition node ids across hosts: each host
owns the CSR rows and the feature rows of its nodes.  The GNS cache refresh is
then a collective: every host samples its share of the cache (probability mass
restricted to owned nodes, properly renormalized) and all-gathers the cached
feature rows — after which *minibatch* feature traffic is mostly local cache
hits, which is exactly the paper's point applied at pod scale.

This module is host-side bookkeeping (numpy); the device-side dry-run models
the resulting per-chip tensors.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.csr import CSRGraph


@dataclasses.dataclass(frozen=True)
class Partition:
    """One host's shard of the graph."""
    host_id: int
    num_hosts: int
    owned: np.ndarray          # int64 node ids owned by this host (sorted)
    local_indptr: np.ndarray   # CSR over owned rows (indices are GLOBAL ids)
    local_indices: np.ndarray

    @property
    def num_owned(self) -> int:
        return len(self.owned)

    def owner_of(self, nodes: np.ndarray) -> np.ndarray:
        return nodes % self.num_hosts


def hash_partition(g: CSRGraph, num_hosts: int) -> list[Partition]:
    """Partition rows by ``node_id % num_hosts`` (DistDGL-style hash).

    Hash partitioning keeps the expected degree mass balanced on power-law
    graphs without a METIS pass (which would not scale to 100M nodes in this
    container anyway); the paper's own distributed follow-up (DistDGL) uses
    the same fallback.
    """
    parts = []
    all_ids = np.arange(g.num_nodes, dtype=np.int64)
    for h in range(num_hosts):
        owned = all_ids[all_ids % num_hosts == h]
        deg = g.indptr[owned + 1] - g.indptr[owned]
        local_indptr = np.zeros(len(owned) + 1, dtype=np.int64)
        np.cumsum(deg, out=local_indptr[1:])
        local_indices = np.empty(int(deg.sum()), dtype=np.int32)
        # ragged gather of each owned row
        pos = 0
        starts, ends = g.indptr[owned], g.indptr[owned + 1]
        # vectorized ragged copy
        total = int(deg.sum())
        if total:
            flat = np.concatenate([g.indices[s:e] for s, e in zip(starts, ends)]) \
                if len(owned) < 65536 else _ragged_gather(g.indices, starts, ends, total)
            local_indices[:] = flat
        parts.append(Partition(host_id=h, num_hosts=num_hosts, owned=owned,
                               local_indptr=local_indptr, local_indices=local_indices))
        del pos
    return parts


def _ragged_gather(indices: np.ndarray, starts: np.ndarray, ends: np.ndarray,
                   total: int) -> np.ndarray:
    """Vectorized ragged row gather: builds a flat index without Python loops."""
    lens = ends - starts
    out_idx = np.repeat(starts, lens)
    # within-row offsets
    csum = np.zeros(len(lens) + 1, dtype=np.int64)
    np.cumsum(lens, out=csum[1:])
    within = np.arange(total, dtype=np.int64) - np.repeat(csum[:-1], lens)
    return indices[out_idx + within]


def cache_refresh_traffic_bytes(cache_size: int, feat_dim: int,
                                num_hosts: int, bytes_per_el: int = 4) -> int:
    """Bytes all-gathered per cache refresh at pod scale.

    Each host contributes ~cache_size/num_hosts rows and receives the rest —
    i.e. ring all-gather moves cache_size*feat_dim*(num_hosts-1)/num_hosts
    bytes per host.  Used by the roofline/§Perf accounting to show the refresh
    amortizes over P epochs (paper Table 6 shows P up to 5 is accuracy-neutral).
    """
    rows_recv = cache_size * (num_hosts - 1) // max(num_hosts, 1)
    return rows_recv * feat_dim * bytes_per_el

"""Immutable CSR graph storage (host side).

The GNS paper keeps the full graph topology and node features in CPU memory and
samples minibatches there (mixed CPU-GPU training, §2.2).  This mirrors DGL's
in-memory CSR: ``indptr`` (int64, |V|+1) and ``indices`` (int32, |E|).

All sampler-facing operations are vectorized numpy; nothing here touches JAX so
importing this module never initializes a device backend.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Out-neighbor CSR.  For undirected graphs store both edge directions."""

    indptr: np.ndarray   # int64 [num_nodes + 1]
    indices: np.ndarray  # int32 [num_edges]

    def __post_init__(self):
        assert self.indptr.ndim == 1 and self.indices.ndim == 1
        assert self.indptr[0] == 0 and self.indptr[-1] == len(self.indices)

    @property
    def num_nodes(self) -> int:
        return len(self.indptr) - 1

    @property
    def num_edges(self) -> int:
        return len(self.indices)

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v] : self.indptr[v + 1]]

    # ------------------------------------------------------------------
    # Construction helpers
    # ------------------------------------------------------------------
    @staticmethod
    def from_edges(src: np.ndarray, dst: np.ndarray, num_nodes: int,
                   symmetrize: bool = True, dedup: bool = True) -> "CSRGraph":
        """Build CSR from an edge list.  O(E log E), fully vectorized."""
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        if symmetrize:
            src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        # drop self loops
        keep = src != dst
        src, dst = src[keep], dst[keep]
        if dedup:
            key = src * num_nodes + dst
            key = np.unique(key)
            src, dst = key // num_nodes, key % num_nodes
        else:
            order = np.argsort(src, kind="stable")
            src, dst = src[order], dst[order]
        indptr = np.zeros(num_nodes + 1, dtype=np.int64)
        np.add.at(indptr, src + 1, 1)
        np.cumsum(indptr, out=indptr)
        return CSRGraph(indptr=indptr, indices=dst.astype(np.int32))

    # ------------------------------------------------------------------
    # Batched neighbor access (sampler hot path)
    # ------------------------------------------------------------------
    def sample_neighbors(self, nodes: np.ndarray, k: int,
                         rng: np.random.Generator,
                         replace: Optional[bool] = None) -> tuple[np.ndarray, np.ndarray]:
        """Uniformly sample up to ``k`` neighbors for each node in ``nodes``.

        Returns ``(nbrs, mask)`` of shape (len(nodes), k), int32/bool.  Nodes
        with degree ``<= k`` get their full neighbor list (no replacement) and
        the remaining lanes masked out — matching DGL's ``sample_neighbors``
        semantics used by the paper's NS baseline.  Padded lanes hold 0.
        """
        nodes = np.asarray(nodes, dtype=np.int64)
        deg = self.indptr[nodes + 1] - self.indptr[nodes]
        n = len(nodes)
        out = np.zeros((n, k), dtype=np.int32)
        mask = np.zeros((n, k), dtype=bool)

        # --- nodes with deg <= k: copy all neighbors (vectorized ragged copy)
        small = deg <= k
        if small.any():
            sn = nodes[small]
            sdeg = deg[small]
            # ragged -> padded via a flat gather
            starts = self.indptr[sn]
            lane = np.arange(k)[None, :]
            src_idx = starts[:, None] + np.minimum(lane, np.maximum(sdeg - 1, 0)[:, None])
            # isolated nodes (deg 0) produce an OOB flat index; clamp — the
            # mask discards the gathered value.
            src_idx = np.minimum(src_idx, max(len(self.indices) - 1, 0))
            vals = self.indices[src_idx]
            m = lane < sdeg[:, None]
            rows = np.where(small)[0]
            out[rows] = np.where(m, vals, 0)
            mask[rows] = m

        # --- nodes with deg > k: sample k offsets without replacement
        big = ~small
        if big.any():
            bn = nodes[big]
            bdeg = deg[big]
            rows = np.where(big)[0]
            # Vectorized sampling without replacement via argpartition of
            # random keys: generate (m, k) unique offsets per row using the
            # Floyd-ish trick — random floats ranked per row.
            # For rows with huge degree this is O(m*k) not O(m*deg).
            r = rng.random((len(bn), k))
            # map k uniform draws onto distinct offsets: draw k floats, scale
            # to deg, resolve collisions by re-draw for the (rare) duplicates.
            offs = (r * bdeg[:, None]).astype(np.int64)
            # resolve duplicates within each row (cheap loop, rare)
            for _ in range(4):
                srt = np.sort(offs, axis=1)
                dup = (srt[:, 1:] == srt[:, :-1]).any(axis=1)
                if not dup.any():
                    break
                ridx = np.where(dup)[0]
                offs[ridx] = (rng.random((len(ridx), k)) * bdeg[ridx][:, None]).astype(np.int64)
            else:
                # fall back to exact per-row choice for stubborn rows
                ridx = np.where((np.sort(offs, 1)[:, 1:] == np.sort(offs, 1)[:, :-1]).any(1))[0]
                for i in ridx:
                    offs[i] = rng.choice(bdeg[i], size=k, replace=False)
            out[rows] = self.indices[self.indptr[bn][:, None] + offs]
            mask[rows] = True
        return out, mask

    def induced_cache_adjacency(self, cache_mask: np.ndarray) -> "CacheAdjacency":
        """Precompute, for every node, its neighbors that fall in the cache.

        This is the paper's induced subgraph S (§3.3): built once per cache
        refresh so that per-minibatch 'neighbors ∩ cache' queries are O(1)
        lookups instead of O(deg) scans.  Returns a CSR over the same node id
        space whose adjacency lists contain only cached neighbors.
        """
        in_cache = cache_mask[self.indices]          # bool [E]
        counts = np.zeros(self.num_nodes, dtype=np.int64)
        # segment count of cached neighbors per node
        seg = np.repeat(np.arange(self.num_nodes), self.degrees)
        np.add.at(counts, seg[in_cache], 1)
        new_indptr = np.zeros(self.num_nodes + 1, dtype=np.int64)
        np.cumsum(counts, out=new_indptr[1:])
        new_indices = self.indices[in_cache].astype(np.int32)
        return CacheAdjacency(indptr=new_indptr, indices=new_indices)


@dataclasses.dataclass(frozen=True)
class CacheAdjacency(CSRGraph):
    """CSR holding only cached neighbors — the induced subgraph S of §3.3."""

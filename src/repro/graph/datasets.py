"""Named synthetic datasets replicating the *shape* of paper Table 2.

Each entry scales the paper's dataset down (container is 1 core / 35 GB) while
preserving the quantities that drive GNS behavior: average degree, feature
dimension, train fraction, and number of classes.  ``scale`` multiplies node
counts; the default configs are sized for CI-speed tests and the benchmark
harness bumps them up.

Paper Table 2 (original → synthetic default):
  Yelp              716,847 nodes, avg deg 10, feat 300, 100 cls, 75% train → 72k nodes
  Amazon          1,598,960 nodes, avg deg 83, feat 200, 107 cls, 85% train → 40k nodes (deg 40)
  OAG-paper      15,257,994 nodes, avg deg 14, feat 768, 146 cls, 43% train → 60k nodes
  OGBN-products   2,449,029 nodes, avg deg 51, feat 100,  47 cls, 10% train → 61k nodes
  OGBN-papers100M 111M nodes,     avg deg 30, feat 128, 172 cls,  1% train → 100k nodes
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.csr import CSRGraph
from repro.graph.generate import sbm_graph, node_features_from_labels


@dataclasses.dataclass
class GraphDataset:
    name: str
    graph: CSRGraph
    features: np.ndarray       # float32 [V, F]  (host feature store)
    labels: np.ndarray         # int32 [V]
    train_idx: np.ndarray      # int64
    val_idx: np.ndarray
    test_idx: np.ndarray
    num_classes: int

    @property
    def feat_dim(self) -> int:
        return self.features.shape[1]


@dataclasses.dataclass(frozen=True)
class _Spec:
    nodes: int
    avg_deg: float
    feat: int
    classes: int
    train_frac: float
    val_frac: float


# name -> (scaled default spec); classes capped at 32 to keep one-hot cheap.
DATASETS: dict[str, _Spec] = {
    "yelp":          _Spec(nodes=72_000,  avg_deg=10, feat=300, classes=32, train_frac=0.75, val_frac=0.10),
    "amazon":        _Spec(nodes=40_000,  avg_deg=40, feat=200, classes=32, train_frac=0.85, val_frac=0.05),
    "oag-paper":     _Spec(nodes=60_000,  avg_deg=14, feat=768, classes=32, train_frac=0.43, val_frac=0.05),
    "ogbn-products": _Spec(nodes=61_000,  avg_deg=51, feat=100, classes=32, train_frac=0.10, val_frac=0.02),
    "ogbn-papers":   _Spec(nodes=100_000, avg_deg=30, feat=128, classes=32, train_frac=0.01, val_frac=0.001),
    # tiny config for unit tests
    "tiny":          _Spec(nodes=2_000,   avg_deg=8,  feat=32,  classes=8,  train_frac=0.5,  val_frac=0.1),
}


def get_dataset(name: str, scale: float = 1.0, seed: int = 0) -> GraphDataset:
    spec = DATASETS[name]
    n = max(int(spec.nodes * scale), 256)
    g, labels = sbm_graph(n, num_blocks=spec.classes, avg_degree=spec.avg_deg,
                          seed=seed)
    feats = node_features_from_labels(labels, spec.feat, noise=1.5, seed=seed + 1)
    rng = np.random.default_rng(seed + 2)
    perm = rng.permutation(n)
    n_tr = int(n * spec.train_frac)
    n_va = max(int(n * spec.val_frac), 1)
    return GraphDataset(
        name=name, graph=g, features=feats, labels=labels,
        train_idx=np.sort(perm[:n_tr]),
        val_idx=np.sort(perm[n_tr:n_tr + n_va]),
        test_idx=np.sort(perm[n_tr + n_va:]),
        num_classes=spec.classes,
    )

"""Graph substrate: CSR storage, synthetic giant-graph generators, partitioning.

The paper trains on graphs with up to 111M nodes / 3.2B edges kept in host
memory (Table 2).  This package provides the host-side graph store used by the
GNS sampler: an immutable CSR structure backed by numpy, fast vectorized
synthetic generators that replicate the *shape* of the paper's datasets
(power-law degree distribution, feature dim, train fraction), and a hash
partitioner for multi-host pods.
"""
from repro.graph.csr import CSRGraph
from repro.graph.generate import powerlaw_graph, sbm_graph
from repro.graph.datasets import get_dataset, DATASETS, GraphDataset
from repro.graph.partition import hash_partition, Partition

__all__ = [
    "CSRGraph",
    "powerlaw_graph",
    "sbm_graph",
    "get_dataset",
    "DATASETS",
    "GraphDataset",
    "hash_partition",
    "Partition",
]

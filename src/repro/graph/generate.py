"""Synthetic giant-graph generators.

The paper evaluates on power-law graphs (Yelp, Amazon, OAG, OGBN-products,
OGBN-papers100M — Table 2).  The container cannot hold the real datasets, so we
generate graphs that replicate the properties the GNS mechanism depends on:

* heavy-tailed (power-law) degree distribution — makes a small degree-biased
  cache cover most edge endpoints (paper §3.2: "For a power-law graph, we only
  need to maintain a small cache of nodes to cover majority of the nodes");
* community structure + correlated labels (SBM) — so that *accuracy* of GNS vs
  NS vs LADIES is a meaningful comparison, not just throughput;
* configurable feature dim / train fraction matching Table 2 rows.

Everything is vectorized numpy; a 1M-node / 25M-edge graph generates in ~2 s.
"""
from __future__ import annotations

import numpy as np

from repro.graph.csr import CSRGraph


def _powerlaw_degrees(n: int, avg_deg: float, alpha: float,
                      rng: np.random.Generator) -> np.ndarray:
    """Draw a degree sequence ~ Zipf(alpha) scaled to the requested mean.

    Hub cap: max(sqrt(n), 20*avg_deg) — a bare sqrt(n) cap amputates the
    tail on container-scale graphs (sqrt(9k) = 95 ~= 2x a degree-51 mean),
    which silently removes the hub-coverage property GNS's degree-biased
    cache depends on (paper §3.2).  The real OGBN graphs have max degree
    >> sqrt(n)-equivalent at small n (products: 17k at |V|=2.4M).
    """
    u = rng.random(n)
    raw = u ** (-1.0 / (alpha - 1.0))
    deg = raw * (avg_deg / raw.mean())
    cap = max(float(n) ** 0.5, 20.0 * avg_deg)
    deg = np.minimum(deg, cap)
    deg = deg * (avg_deg / max(deg.mean(), 1e-9))   # re-center after cap
    return np.maximum(deg.astype(np.int64), 1)


def powerlaw_graph(num_nodes: int, avg_degree: float = 10.0,
                   alpha: float = 2.1, seed: int = 0) -> CSRGraph:
    """Configuration-model power-law graph (undirected, deduped, no loops)."""
    rng = np.random.default_rng(seed)
    # each edge consumes two stubs but contributes 2 to total degree after
    # symmetrization, so stub count per node ~ avg_degree gives mean ~avg_degree
    deg = _powerlaw_degrees(num_nodes, avg_degree, alpha, rng)
    stubs = np.repeat(np.arange(num_nodes, dtype=np.int64), deg)
    rng.shuffle(stubs)
    if len(stubs) % 2:
        stubs = stubs[:-1]
    src, dst = stubs[0::2], stubs[1::2]
    return CSRGraph.from_edges(src, dst, num_nodes)


def sbm_graph(num_nodes: int, num_blocks: int = 16, avg_degree: float = 10.0,
              p_in: float = 0.8, alpha: float = 2.1, seed: int = 0
              ) -> tuple[CSRGraph, np.ndarray]:
    """Power-law degree-corrected stochastic block model.

    Returns ``(graph, block_labels)``.  Each stub connects within its block
    with probability ``p_in``, else to a uniform random stub — giving both the
    power-law degrees GNS exploits and community-correlated labels so node
    classification accuracy separates good from bad samplers.
    """
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, num_blocks, size=num_nodes)
    deg = _powerlaw_degrees(num_nodes, avg_degree, alpha, rng)
    stubs = np.repeat(np.arange(num_nodes, dtype=np.int64), deg)
    rng.shuffle(stubs)
    if len(stubs) % 2:
        stubs = stubs[:-1]
    src, dst = stubs[0::2].copy(), stubs[1::2].copy()

    # Rewire cross-block pairs: with prob p_in, replace dst with a same-block
    # node (degree-biased within block via stub resampling).
    cross = labels[src] != labels[dst]
    rewire = cross & (rng.random(len(src)) < p_in)
    if rewire.any():
        # bucket stubs by block for biased within-block choice
        order = np.argsort(labels[stubs], kind="stable")
        sorted_stubs = stubs[order]
        block_of_sorted = labels[sorted_stubs]
        starts = np.searchsorted(block_of_sorted, np.arange(num_blocks))
        ends = np.searchsorted(block_of_sorted, np.arange(num_blocks), side="right")
        b = labels[src[rewire]]
        lo, hi = starts[b], ends[b]
        pick = lo + (rng.random(len(b)) * np.maximum(hi - lo, 1)).astype(np.int64)
        dst[rewire] = sorted_stubs[np.minimum(pick, len(sorted_stubs) - 1)]
    g = CSRGraph.from_edges(src, dst, num_nodes)
    return g, labels.astype(np.int32)


def node_features_from_labels(labels: np.ndarray, feat_dim: int,
                              noise: float = 1.0, seed: int = 0) -> np.ndarray:
    """Gaussian class-prototype features: x_i = proto[y_i] + noise*N(0,I).

    Weak per-node signal (noise ≥ 1) so a model must aggregate neighborhoods
    to classify well — i.e. sampler quality matters, as in the paper's tasks.
    """
    rng = np.random.default_rng(seed)
    num_classes = int(labels.max()) + 1
    protos = rng.normal(size=(num_classes, feat_dim)).astype(np.float32)
    x = protos[labels] + noise * rng.normal(size=(len(labels), feat_dim)).astype(np.float32)
    return x.astype(np.float32)

"""Three-term roofline from the compiled dry-run (no real hardware).

Terms (per step, per chip — the SPMD-partitioned HLO *is* the per-chip
program, so cost_analysis numbers are already per device):

  compute_s    = HLO_FLOPs_per_chip / peak_FLOPs          (197 TF bf16 v5e)
  memory_s     = HLO_bytes_per_chip / HBM_bw              (819 GB/s)
  collective_s = collective_operand_bytes_per_chip / ICI  (~50 GB/s/link)

collective bytes are NOT in cost_analysis: we parse the post-SPMD HLO text
and sum operand sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (async *-start forms counted once; *-done
skipped).  This is the documented convention from the assignment; ring-
algorithm factors (x2 for all-reduce etc.) are folded into interpretation,
not the raw term.

The dominant term approximates step time under perfect overlap; the roofline
fraction we report in EXPERIMENTS.md §Perf is
  useful_model_flops / (dominant_s * peak * chips).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

from repro.configs.base import ArchConfig, ShapeSpec


@dataclasses.dataclass(frozen=True)
class HW:
    """TPU v5e per-chip numbers (assignment-specified)."""
    peak_flops: float = 197e12        # bf16 FLOP/s
    hbm_bw: float = 819e9             # B/s
    ici_bw: float = 50e9              # B/s per link
    hbm_bytes: float = 16e9           # capacity (memory table)


V5E = HW()

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _group_size(line: str) -> int:
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return m.group(1).count(",") + 1
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))       # [num_groups, group_size]<=[...]
    return 1


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Per-device wire bytes per collective type, from post-SPMD HLO text.

    Compiled HLO prints operands by name only, so sizes come from the RESULT
    shape(s) plus the replica group size S, converted to ring-algorithm bytes
    on the wire per device (the quantity a link-bandwidth roofline needs):

      all-gather          (S-1)/S * result         (result = gathered size)
      all-reduce        2*(S-1)/S * result         (reduce-scatter + gather)
      reduce-scatter      (S-1)   * result         (operand = S * result)
      all-to-all          (S-1)/S * result
      collective-permute            result         (one send per device)

    Async ``*-start`` forms count once; ``*-done`` is skipped.
    Returns {op_type: {"bytes": int, "count": int}, ..., "total": int}.
    """
    out: dict = {c: {"bytes": 0, "count": 0} for c in _COLLECTIVES}
    total = 0
    for line in hlo_text.splitlines():
        line = line.strip()
        m = None
        for c in _COLLECTIVES:
            for form in (f" {c}(", f" {c}-start("):
                idx = line.find(form)
                if idx >= 0:
                    m = (c, idx)
                    break
            if m:
                break
        if not m:
            continue
        c, opcode_at = m
        eq = line.find("=")
        if eq < 0 or eq > opcode_at:
            continue
        result_region = line[eq + 1:opcode_at]       # shapes (maybe a tuple)
        rb = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(result_region))
        s = max(_group_size(line), 1)
        if c == "all-gather":
            b = rb * (s - 1) // max(s, 1)
        elif c == "all-reduce":
            b = 2 * rb * (s - 1) // max(s, 1)
        elif c == "reduce-scatter":
            b = rb * (s - 1)
        elif c in ("all-to-all", "ragged-all-to-all"):
            b = rb * (s - 1) // max(s, 1)
        else:                                        # collective-permute
            b = rb
        out[c]["bytes"] += int(b)
        out[c]["count"] += 1
        total += int(b)
    out["total"] = total
    return out


def model_flops(cfg: ArchConfig, shape: ShapeSpec,
                n_active: Optional[float] = None) -> float:
    """Useful MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per step.

    D = tokens processed this step (decode: global_batch new tokens).
    N counts active parameters (MoE: shared + top_k routed experts + attn).
    ``n_active`` overrides the analytic count with the exact number derived
    from param structs (launch/dryrun.py does this).
    """
    n = n_active if n_active is not None else active_params(cfg)
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d                    # forward only
    return 2.0 * n * shape.global_batch      # decode: 1 token per sequence


def total_params(cfg: ArchConfig) -> float:
    return _param_count(cfg, active_only=False)


def active_params(cfg: ArchConfig) -> float:
    return _param_count(cfg, active_only=True)


def _param_count(cfg: ArchConfig, active_only: bool) -> float:
    d, l = cfg.d_model, cfg.num_layers
    dh = cfg.head_dim_eff
    h, hkv = cfg.num_heads, cfg.num_kv_heads
    # attention
    if cfg.mla is not None:
        m = cfg.mla
        attn = (d * m.q_lora + m.q_lora * h * (m.qk_nope + m.qk_rope)
                + d * (m.kv_lora + m.qk_rope)
                + m.kv_lora * h * (m.qk_nope + m.v_head)
                + h * m.v_head * d)
    else:
        attn = d * h * dh + 2 * d * hkv * dh + h * dh * d
    # ffn / moe / xlstm / ssm per layer
    def ffn_params(dff):
        return d * dff * (3 if cfg.gated_ffn else 2)

    per_layer = attn
    if cfg.moe is not None:
        mo = cfg.moe
        e_active = mo.top_k if active_only else mo.num_experts
        per_layer += 3 * d * mo.d_expert * e_active
        per_layer += d * mo.num_experts            # router
        if mo.num_shared:
            per_layer += 3 * d * (mo.d_expert * mo.num_shared)
        if mo.dense_residual:
            per_layer += ffn_params(cfg.d_ff)
        dense_layers = mo.first_dense_layers
        moe_layers = l - dense_layers
        total = moe_layers * per_layer + dense_layers * (attn + ffn_params(cfg.d_ff))
    elif cfg.xlstm is not None:
        x = cfg.xlstm
        di = int(x.proj_factor * d)
        dqk = int(x.qk_factor * di)
        mlstm = (2 * d * di + di * dqk * 2 + di * di + di * 2 * x.num_heads
                 + di * di + di * d)
        slstm = 4 * d * d + d * d // x.num_heads * 4 * d // d + d * d
        n_s = len(x.slstm_at)
        total = (l - n_s) * mlstm + n_s * (4 * d * d + d * d)
    elif cfg.ssm is not None:
        s = cfg.ssm
        di = s.expand * d
        conv_dim = di + 2 * s.n_groups * s.d_state
        nh = di // s.head_dim
        mamba = (d * (2 * di + 2 * s.n_groups * s.d_state + nh)
                 + s.d_conv * conv_dim + di * d)
        total = l * mamba
        if cfg.shared_attn_every:
            total += attn + ffn_params(cfg.d_ff)   # ONE shared block
    else:
        per_layer += ffn_params(cfg.d_ff)
        total = l * per_layer
    if cfg.encoder_layers:
        enc = cfg.encoder_layers * (attn + ffn_params(cfg.d_ff))
        xattn = l * attn                            # decoder cross-attn
        total = total + enc + xattn
    # embeddings
    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    return float(total + emb)


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_chip: float
    bytes_per_chip: float
    collective_bytes_per_chip: float
    collective_detail: dict
    model_flops_total: float
    hlo_flops_total: float
    useful_ratio: float          # MODEL_FLOPS / HLO_FLOPs (remat/waste probe)
    dominant: str
    roofline_fraction: float     # useful flops vs dominant-term-limited peak
    chips: int

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        return d


def analyze_compiled(cost: dict, hlo_text: str, cfg: ArchConfig,
                     shape: ShapeSpec, chips: int, hw: HW = V5E,
                     n_active: Optional[float] = None) -> RooflineTerms:
    """Terms straight from one compiled artifact (beware: scan bodies are
    counted once by cost_analysis — launch/dryrun.py uses unrolled probes
    and calls roofline_terms directly)."""
    return roofline_terms(float(cost.get("flops", 0.0)),
                          float(cost.get("bytes accessed", 0.0)),
                          collective_bytes_from_hlo(hlo_text),
                          cfg, shape, chips, hw=hw, n_active=n_active)


def roofline_terms(flops: float, byt: float, coll: dict, cfg: ArchConfig,
                   shape: ShapeSpec, chips: int, hw: HW = V5E,
                   n_active: Optional[float] = None) -> RooflineTerms:
    cb = float(coll["total"])

    compute_s = flops / hw.peak_flops
    memory_s = byt / hw.hbm_bw
    collective_s = cb / hw.ici_bw

    mf = model_flops(cfg, shape, n_active=n_active)
    hlo_total = flops * chips
    useful = mf / hlo_total if hlo_total else 0.0

    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    dom_s = terms[dominant]
    frac = (mf / (dom_s * hw.peak_flops * chips)) if dom_s > 0 else 0.0
    return RooflineTerms(
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        flops_per_chip=flops, bytes_per_chip=byt,
        collective_bytes_per_chip=cb, collective_detail=coll,
        model_flops_total=mf, hlo_flops_total=hlo_total, useful_ratio=useful,
        dominant=dominant, roofline_fraction=frac, chips=chips)

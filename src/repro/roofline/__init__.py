"""Roofline analysis from compiled dry-run artifacts (deliverable g)."""
from repro.roofline.analysis import (HW, RooflineTerms, analyze_compiled,
                                     collective_bytes_from_hlo, model_flops,
                                     roofline_terms)

__all__ = ["HW", "RooflineTerms", "analyze_compiled",
           "collective_bytes_from_hlo", "model_flops", "roofline_terms"]

"""Collective census with op provenance — the §Perf profiling tool.

Compiles the (unrolled) cost probe for one cell and prints the top collective
ops with their HLO metadata ``op_name`` (which carries the jaxpr path, i.e.
WHICH model line produced the op).  This is the dry-run profiler: no
wall-clock trace exists on CPU, so sharding work is driven by reading the
collective structure of the lowered program (system instructions §Pallas
hints).

Usage:
  PYTHONPATH=src python -m repro.roofline.inspect --arch qwen2-7b \
      --shape train_4k [--probe-units 2]
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import collections
import re

import jax

from repro.configs import SHAPES, get_config
from repro.roofline.analysis import (_COLLECTIVES, _SHAPE_RE, _group_size,
                                     _shape_bytes)

_META_RE = re.compile(r'op_name="([^"]*)"')


def collective_census(hlo_text: str) -> list:
    """[(bytes, op_type, result_shape, group_size, op_name), ...] desc."""
    rows = []
    for line in hlo_text.splitlines():
        line = line.strip()
        hit = None
        for c in _COLLECTIVES:
            for form in (f" {c}(", f" {c}-start("):
                if form in line:
                    hit = (c, line.find(form))
                    break
            if hit:
                break
        if not hit:
            continue
        c, opcode_at = hit
        eq = line.find("=")
        if eq < 0 or eq > opcode_at:
            continue
        region = line[eq + 1:opcode_at]
        shapes = _SHAPE_RE.findall(region)
        rb = sum(_shape_bytes(d, s) for d, s in shapes)
        s = max(_group_size(line), 1)
        mult = {"all-gather": (s - 1) / s, "all-reduce": 2 * (s - 1) / s,
                "reduce-scatter": (s - 1), "all-to-all": (s - 1) / s,
                "ragged-all-to-all": (s - 1) / s}.get(c, 1.0)
        m = _META_RE.search(line)
        name = m.group(1) if m else "?"
        rows.append((int(rb * mult), c, "+".join(f"{d}[{sh}]" for d, sh in shapes),
                     s, name))
    rows.sort(reverse=True)
    return rows


_RESULT_RE = re.compile(r"^\s*(?:ROOT\s+)?%[\w.\-]+ = ")


def memory_census(hlo_text: str, top: int = 25):
    """Aggregate HLO result bytes by (opcode, site) — a write-traffic proxy
    for finding what inflates the 'bytes accessed' roofline term."""
    by_site = collections.Counter()
    total = 0
    for line in hlo_text.splitlines():
        if not _RESULT_RE.match(line):
            continue
        eq = line.find("=")
        rest = line[eq + 1:].lstrip()
        shapes = []
        # result region = up to the opcode token (first identifier followed by '(')
        m2 = re.match(r"((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+)([\w\-]+)\(",
                      rest)
        if not m2:
            continue
        region, opcode = m2.group(1), m2.group(2)
        if opcode in ("tuple", "get-tuple-element", "parameter", "constant"):
            continue
        b = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(region))
        if b < (1 << 20):
            continue
        mm = _META_RE.search(line)
        name = mm.group(1) if mm else "?"
        site = "/".join(name.split("/")[-2:])
        by_site[(opcode, site)] += b
        total += b
    print(f"\n== memory census (>=1MB results): {total/1e9:.2f} GB total ==")
    for (opcode, site), b in by_site.most_common(top):
        print(f"  {b/1e9:8.2f} GB  {opcode:<22} {site}")


def summarize(rows, top: int = 25):
    total = sum(r[0] for r in rows)
    print(f"collective ops: {len(rows)}, wire bytes/chip: {total/1e9:.2f} GB")
    by_site = collections.Counter()
    for b, c, shape, s, name in rows:
        # collapse the site to the last two path segments
        site = "/".join(name.split("/")[-3:])
        by_site[(c, site)] += b
    print("\n-- by site --")
    for (c, site), b in by_site.most_common(top):
        print(f"  {b/1e9:8.2f} GB  {c:<18} {site}")
    print("\n-- largest single ops --")
    for b, c, shape, s, name in rows[:top]:
        print(f"  {b/1e9:8.2f} GB  {c:<18} g={s:<4} {shape}  {name[-80:]}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--probe-units", type=int, default=None,
                    help="layer units for the probe cfg (default: plan's u1)")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--linear", action="store_true",
                    help="linear-attention traffic probe (memory census)")
    args = ap.parse_args()

    from repro.launch.dryrun import _compile_probe, _probe_plan
    from repro.launch.mesh import make_production_mesh
    from repro.launch import sharding as shlib
    from repro.launch.specs import input_specs
    from repro.launch.steps import (make_prefill_step, make_serve_step,
                                make_train_step)
    from repro.models import scan_util
    from repro.models.lm import get_model
    from repro.optim.adam import AdamConfig, AdamW

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    make, u1, _, _ = _probe_plan(cfg)
    probe_cfg = make(args.probe_units or u1)
    mesh = make_production_mesh(multi_pod=args.multipod)
    model = get_model(probe_cfg)

    from repro.kernels.probe_ctx import linear_attention_traffic
    import contextlib
    lin = linear_attention_traffic() if args.linear else contextlib.nullcontext()
    with shlib.use_mesh(mesh), shlib.arch_scope(probe_cfg), scan_util.unrolled(), lin:
        specs = input_specs(probe_cfg, shape, mesh, model=model)
        p_structs, p_sh = specs["params"]
        if shape.kind in ("decode", "prefill"):
            step = (make_serve_step(model) if shape.kind == "decode"
                else make_prefill_step(model))
            t_struct, t_sh = specs["tokens"]
            s_structs, s_sh = specs["state"]
            compiled = jax.jit(step, in_shardings=(p_sh, t_sh, s_sh),
                               out_shardings=(t_sh, s_sh),
                               donate_argnums=(2,)).lower(
                                   p_structs, t_struct, s_structs).compile()
        else:
            opt = AdamW(AdamConfig(lr=3e-4))
            step = make_train_step(model, opt)
            b_structs, b_sh = specs["batch"]
            o_structs = jax.eval_shape(opt.init, p_structs)
            o_sh = {"m": p_sh, "v": p_sh,
                    "step": jax.sharding.NamedSharding(
                        mesh, jax.sharding.PartitionSpec())}
            loss_sh = jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec())
            compiled = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                               out_shardings=(p_sh, o_sh, loss_sh),
                               donate_argnums=(0, 1)).lower(
                                   p_structs, o_structs, b_structs).compile()
    hlo = compiled.as_text()
    rows = collective_census(hlo)
    print(f"== {args.arch} x {args.shape} (probe units "
          f"{args.probe_units or u1}, mesh {mesh.shape}) ==")
    summarize(rows, top=args.top)
    memory_census(hlo, top=args.top)


if __name__ == "__main__":
    main()

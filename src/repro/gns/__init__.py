"""Unified GNS engine: one declarative config, one compiled step.

Public surface:

* :class:`EngineConfig` (+ ``DataConfig`` / ``MeshConfig`` / ``ModelConfig``
  sub-configs and the ``preset`` registry) — the single declarative
  description of a run; round-trips through ``to_dict``/``from_dict``.
* :class:`GNSEngine` — owns the wiring FeatureStore → sampler →
  EpochLoader/Prefetcher → compiled step and exposes ``fit`` / ``evaluate``
  / ``infer`` / ``describe``.
* :class:`TrainReport` — fit() result (timings, losses, traffic meter).
* ``collate_groups`` / ``make_train_step`` — the DP>1 collation and the one
  train step every surface compiles (the dry-run lowers the same function).

Quickstart::

    from repro.gns import EngineConfig, GNSEngine

    engine = GNSEngine(EngineConfig.preset("quickstart"))
    report = engine.fit(epochs=2)
    f1 = engine.evaluate()
    logits = engine.infer(node_ids)      # serves from the live cache
    print(engine.describe())
"""
from repro.gns.config import (DataConfig, EngineConfig, FabricConfig,
                              MeshConfig, ModelConfig, PRESETS, ServeConfig,
                              StreamConfig, TenantConfig)
from repro.gns.engine import (GNSEngine, TrainReport, collate_groups,
                              make_train_step)

__all__ = [
    "EngineConfig", "DataConfig", "MeshConfig", "ModelConfig", "ServeConfig",
    "FabricConfig", "StreamConfig", "TenantConfig",
    "PRESETS",
    "GNSEngine", "TrainReport", "collate_groups", "make_train_step",
]

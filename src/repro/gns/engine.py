"""GNSEngine — the unified engine behind every GNS surface.

One object owns the wiring the trainer, the examples, the benchmarks and the
pod-scale dry-run each used to hand-assemble:

    FeatureStore  →  sampler  →  EpochLoader / Prefetcher  →  compiled step

built from one declarative :class:`~repro.gns.config.EngineConfig`, and
exposing the four verbs every surface needs:

* :meth:`fit`      — the paper's §2.2 training loop (sample → slice → copy →
  compute) with the Fig. 1/2 timing/traffic breakdown on the meter;
* :meth:`evaluate` — micro-F1 over held-out targets (meter suspended);
* :meth:`infer`    — mini-batch inference reusing the LIVE cache generation:
  the first serving-shaped entry point — logits for arbitrary node ids at
  cache-hit feature cost, no refresh, no training side effects;
* :meth:`describe` — the lowering/traffic report ``launch.dryrun_gnn``
  prints, for THIS config.

**DP > 1 in one compiled step** (the PR-3 follow-up this engine closes): on
a mesh with data-parallel axes the engine samples one minibatch per DP group
per step, collates them into a single group-ordered batch
(:func:`collate_groups`), and passes a device-resident int32 **home-shard
vector** — one entry per group, ``-1`` when that group's batch has no
locality contract — to the train step.  The fused input op branches on the
owner shard at RUNTIME (``lax.cond`` on the traced vector,
``kernels.ops._fused_forward``), so a single jit cache entry serves batches
with any mix of home shards; the old path retraced on every distinct
``MiniBatch.local_shard`` because it was a static jit argument.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import jax
import numpy as np

from repro.core.minibatch import DeviceBatch, LayerBlock, MiniBatch
from repro.core.pipeline import EpochLoader, Prefetcher
from repro.core.sampler import GNSSampler, make_sampler
from repro.featurestore import FeatureStore, TrafficMeter
from repro.gns.config import EngineConfig
from repro.kernels.ops import dp_group_count
from repro.launch import sharding as shlib
from repro.models import graphsage
from repro.optim.adam import AdamW


@dataclasses.dataclass
class TrainReport:
    epoch_times: list
    losses: list
    val_acc: list
    meter: TrafficMeter
    input_nodes_per_batch: float = 0.0
    cached_nodes_per_batch: float = 0.0
    isolated_per_batch: float = 0.0


def make_train_step(mcfg: graphsage.SageConfig, opt: AdamW):
    """The one train step every surface compiles.

    ``home_shards`` is the device-resident per-group home-shard vector (or
    None to lower the plain psum input path); it is a TRACED operand, so the
    jitted step never retraces when a batch's home shard changes.
    ``device_adj`` (a DeviceCacheAdj pytree, or None for host-backend runs)
    switches layer 0 to the on-device GNS draw — it is also traced, so
    generation swaps reuse the same compiled step.
    """
    def train_step(params, opt_state, batch, cache_table, home_shards,
                   device_adj=None):
        (loss, acc), grads = jax.value_and_grad(
            graphsage.loss_fn, has_aux=True)(params, batch, cache_table,
                                             mcfg, home_shards, device_adj)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss, acc
    return train_step


def collate_groups(mbs: Sequence[MiniBatch], fused: bool
                   ) -> tuple[MiniBatch, np.ndarray]:
    """Collate one MiniBatch per DP group into a single step batch.

    Group-order concatenation of every device array; block pads stay
    PER-GROUP (``SageConfig.num_groups`` tells the model to gather each
    group's leading rows instead of slicing a global prefix).  Gather
    indices are group-local per assembly, so upper-layer blocks — consumed
    by GLOBAL gathers in the model — are offset by ``g·num_src``; the input
    block stays group-local when the fused op consumes it (its shard_map
    body sees exactly one group's slice) and is offset otherwise.

    Returns the collated batch plus the int32 home-shard vector (one entry
    per group, -1 where the group's batch had no locality contract).  All
    batches must carry the SAME cache generation — the loader only polls
    generation swaps at step boundaries, so a swap can never tear a step.
    """
    if len(mbs) == 1:
        mb = mbs[0]
        ls = mb.local_shard if mb.local_shard is not None else -1
        return mb, np.array([ls], np.int32)
    gens = {mb.cache_gen.version if mb.cache_gen is not None else -1
            for mb in mbs}
    assert len(gens) == 1, f"step spans cache generations {gens}"
    blocks = []
    for li in range(len(mbs[0].device.blocks)):
        bs = [mb.device.blocks[li] for mb in mbs]
        s, d = bs[0].num_src, bs[0].num_dst
        offset = li > 0 or not fused
        blocks.append(LayerBlock(
            nbr_idx=np.concatenate(
                [b.nbr_idx + (g * s if offset else 0)
                 for g, b in enumerate(bs)]).astype(np.int32),
            nbr_w=np.concatenate([b.nbr_w for b in bs]),
            dst_mask=np.concatenate([b.dst_mask for b in bs]),
            num_src=s, num_dst=d))
    def _cat(field):
        vals = [getattr(mb.device, field) for mb in mbs]
        return None if vals[0] is None else np.concatenate(vals)

    dev = DeviceBatch(
        blocks=tuple(blocks),
        input_cache_slots=_cat("input_cache_slots"),
        input_streamed=_cat("input_streamed"),
        input_mask=_cat("input_mask"),
        labels=_cat("labels"),
        label_mask=_cat("label_mask"),
        # device-backend fields: fallback lanes concat like any row array;
        # the [1, 2] per-batch keys stack to [G, 2] (draw_lanes indexes the
        # key by group, counters by group-LOCAL row)
        input_fb_rows=_cat("input_fb_rows"),
        input_fb_w=_cat("input_fb_w"),
        sample_key=_cat("sample_key"))
    home = np.array([mb.local_shard if mb.local_shard is not None else -1
                     for mb in mbs], np.int32)
    out = MiniBatch(
        device=dev,
        input_node_ids=np.concatenate([mb.input_node_ids for mb in mbs]),
        num_input=sum(mb.num_input for mb in mbs),
        num_cached=sum(mb.num_cached for mb in mbs),
        bytes_streamed=sum(mb.bytes_streamed for mb in mbs),
        num_isolated=sum(mb.num_isolated for mb in mbs),
        cache_gen=mbs[0].cache_gen)
    return out, home


class GNSEngine:
    """The wired pipeline for one :class:`EngineConfig` (module docstring)."""

    def __init__(self, cfg: EngineConfig, *, dataset=None, mesh=None,
                 model_cfg: Optional[graphsage.SageConfig] = None,
                 cache_shard_axis: Optional[str] = None):
        """``dataset`` / ``mesh`` / ``model_cfg`` override the declarative
        sub-configs with concrete objects (the GNNTrainer shim's path)."""
        self.cfg = cfg
        if dataset is None:
            from repro.graph.datasets import get_dataset
            dataset = get_dataset(cfg.data.name, scale=cfg.data.scale,
                                  seed=cfg.data.seed)
        self.ds = dataset
        if mesh is None and cfg.mesh is not None:
            from repro.launch.mesh import make_host_mesh
            mesh = make_host_mesh(cfg.mesh.data, cfg.mesh.model)
        self.mesh = mesh
        self.seed = cfg.seed
        self.scfg = cfg.sampler_config()
        if getattr(self.scfg, "backend", "host") == "device":
            assert cfg.sampler == "gns", (
                "backend='device' is the GNS device sampler — "
                f"sampler={cfg.sampler!r} has no device backend")
        mcfg = model_cfg
        if mcfg is None:
            m = cfg.model
            sk = getattr(m, "sample_kernel", "auto")
            if sk == "auto":
                # interpret-mode Pallas grids at bench shapes are
                # uncompilably slow off-TPU; the jnp reference is the
                # production path there (same bits — see sampling/rng.py)
                sk = "pallas" if jax.default_backend() == "tpu" else "reference"
            mcfg = graphsage.SageConfig(
                feat_dim=self.ds.feat_dim, hidden_dim=m.hidden_dim,
                num_classes=self.ds.num_classes,
                num_layers=len(self.scfg.fanouts),
                aggregate_impl=m.aggregate_impl, input_impl=m.input_impl,
                input_kernel=m.input_kernel, sample_kernel=sk)
        self.meter = TrafficMeter()
        # side-channel transfer meters: eval and one-shot-inference copies
        # must book their wall time SOMEWHERE (the meterlint pass pairs
        # every transfer with an accounting write) without skewing the
        # training breakdown the paper's tables are built from
        self.meter_eval = TrafficMeter()
        self.meter_infer = TrafficMeter()
        if cfg.sampler == "gns":
            # the facade owns all three feature tiers + the refresh lifecycle
            self.store = FeatureStore(
                self.ds.features, self.ds.graph, self.scfg.cache,
                train_idx=self.ds.train_idx, mesh=mesh,
                shard_axis=cache_shard_axis, meter=self.meter,
                importance_mode=self.scfg.importance_mode,
                build_adjacency=True, seed=cfg.seed)
        else:
            self.store = None
        if (self.store is not None and mesh is not None
                and mcfg.cache_shard_axis is None
                and (mcfg.input_impl == "fused"
                     or getattr(self.scfg, "backend", "host") == "device")):
            # fused input AND device sampling must psum over the SAME axis
            # the upload shards on
            mcfg = dataclasses.replace(mcfg,
                                       cache_shard_axis=self.store.shard_axis)
        # DP groups: one minibatch per group per step, collated (module doc)
        self.num_groups = dp_group_count(mesh, mcfg.cache_shard_axis)
        if self.num_groups > 1:
            from repro.core.minibatch import block_pad_sizes
            s0 = block_pad_sizes(self.scfg.batch_size, self.scfg.fanouts)[0][1]
            assert self.scfg.batch_size % self.num_groups == 0 \
                and s0 % self.num_groups == 0, (
                    f"batch_size={self.scfg.batch_size} (input pad {s0}) "
                    f"must divide the {self.num_groups} DP groups so eval "
                    f"batches can shard over the DP axes")
        self.mcfg = dataclasses.replace(mcfg, num_groups=self.num_groups)
        self.mcfg_eval = dataclasses.replace(self.mcfg, num_groups=1)
        self.sampler = make_sampler(cfg.sampler, self.ds.graph, self.scfg,
                                    self.ds.features, self.ds.labels,
                                    train_idx=self.ds.train_idx,
                                    store=self.store)
        self.params = graphsage.init_params(jax.random.PRNGKey(cfg.seed),
                                            self.mcfg)
        self.opt = AdamW(cfg.optim)
        self.opt_state = self.opt.init(self.params)
        self._dummy_cache = graphsage.dummy_cache_table(self.ds.feat_dim)

        # collation must keep layer-0 indices group-local ONLY when the
        # fused op will actually shard_map them (mesh + cache axis); a fused
        # model without a cache axis runs the op on the GLOBAL arrays, so
        # layer 0 needs the same per-group offsets as the upper layers
        self._collate_fused = (
            self.mcfg.input_impl == "fused" and mesh is not None
            and self.mcfg.cache_shard_axis in getattr(mesh, "axis_names", ()))
        self._train_step = jax.jit(make_train_step(self.mcfg, self.opt))
        mcfg_eval = self.mcfg_eval

        @jax.jit
        def eval_step(params, batch, cache_table, device_adj=None):
            return graphsage.loss_fn(params, batch, cache_table, mcfg_eval,
                                     None, device_adj)

        @jax.jit
        def logits_step(params, batch, cache_table, device_adj=None):
            return graphsage.forward(params, batch, cache_table, mcfg_eval,
                                     None, device_adj)

        self._eval_step = eval_step
        self._logits_step = logits_step
        # serving-shaped inference: one sampler per padded batch size
        # ("bucket"), all sharing THE store — so every bucket rides the same
        # live cache generation and feeds the same policy/placement signals,
        # while jax.jit keys the one logits step per bucket shape (a small
        # fixed set of compiled steps, never retraced in steady state)
        self._bucket_samplers: dict = {}
        # streaming ingest (repro.stream): wired eagerly when the config
        # declares it, lazily on the first ingest() otherwise
        self._stream = None
        if cfg.stream is not None and self.store is not None:
            self._init_stream(cfg.stream)

    # ------------------------------------------------------------------
    def _cache_table(self, mb: Optional[MiniBatch] = None):
        """The device table the batch's slots index into.

        Each MiniBatch carries the :class:`Generation` it was assembled
        against, so even when an async refresh swaps the live generation
        between sampling and stepping, the step reads the table matching the
        batch's slot map — a swap can never tear a batch.
        """
        gen = getattr(mb, "cache_gen", None) if mb is not None else None
        if gen is not None:
            return gen.table
        return self._dummy_cache

    def _put_batch(self, host_batch, meter: Optional[TrafficMeter] = None):
        """Host->device transfer with paired accounting.

        Every engine transfer funnels through here so each copy's wall
        time books to exactly one :class:`TrafficMeter` — training by
        default, the eval/infer side meters or a serving meter when passed.
        The meterlint pass enforces the pairing repo-wide (error tier).
        """
        m = meter if meter is not None else self.meter
        t0 = time.perf_counter()
        out = jax.device_put(host_batch)
        m.t_copy += time.perf_counter() - t0
        return out

    @staticmethod
    def _device_adj(mb: Optional[MiniBatch]):
        """The batch's pinned generation's device CSR (None = host backend).

        Resolved from ``cache_gen`` exactly like :meth:`_cache_table`, so a
        mid-swap batch draws against the SAME generation it gathers from.
        """
        gen = getattr(mb, "cache_gen", None) if mb is not None else None
        return getattr(gen, "device_adj", None) if gen is not None else None

    def run_batch(self, mb: MiniBatch,
                  home_shards: Optional[np.ndarray] = None
                  ) -> tuple[float, float]:
        """One optimizer step on a (possibly group-collated) minibatch."""
        if self.num_groups > 1:
            expect = self.num_groups * self.scfg.batch_size
            got = int(mb.device.labels.shape[0])
            assert got == expect, (
                f"DP={self.num_groups} steps consume GROUP-COLLATED batches "
                f"({expect} labels, got {got}): use fit(), or collate "
                f"{self.num_groups} per-group minibatches via collate_groups")
        if home_shards is None:
            ls = mb.local_shard if mb.local_shard is not None else -1
            home_shards = np.full(max(self.num_groups, 1), -1, np.int32)
            home_shards[0] = ls
        m = self.meter
        dev_batch = self._put_batch(mb.device)
        m.add_batch(mb.bytes_streamed)
        t0 = time.perf_counter()
        with shlib.use_mesh(self.mesh):     # no-op scope when mesh is None
            self.params, self.opt_state, loss, acc = self._train_step(
                self.params, self.opt_state, dev_batch, self._cache_table(mb),
                jax.numpy.asarray(home_shards, jax.numpy.int32),
                self._device_adj(mb))
        loss = float(loss)
        m.t_compute += time.perf_counter() - t0
        return loss, float(acc)

    # ------------------------------------------------------------------
    def fit(self, epochs: int, max_batches: Optional[int] = None,
            prefetch: Optional[bool] = None,
            eval_every: Optional[int] = None,
            eval_batches: int = 8) -> TrainReport:
        """The §2.2 training loop; ``max_batches`` bounds STEPS per epoch
        (at DP > 1 each step consumes ``num_groups`` minibatches)."""
        if prefetch is None:
            prefetch = self.cfg.prefetch
        G = max(self.num_groups, 1)
        loader = EpochLoader(self.sampler, self.ds.train_idx, seed=self.seed,
                             max_batches=(max_batches * G
                                          if max_batches is not None else None),
                             dp_groups=G)
        report = TrainReport([], [], [], self.meter)
        n_inputs, n_cached, n_iso, n_b = 0, 0, 0, 0
        fused = self._collate_fused
        for ep in range(epochs):
            t_ep = time.perf_counter()
            # epoch start (cache refresh happens in sampler.start_epoch)
            it = loader.epoch(ep)
            if prefetch:
                it = Prefetcher(it, depth=2, meter=self.meter)
            else:
                it = self._timed(it)
            ep_losses = []
            group_buf: list = []
            for mb in it:
                group_buf.append(mb)
                if len(group_buf) < G:
                    continue
                step_mb, home = collate_groups(group_buf, fused)
                group_buf = []
                loss, _ = self.run_batch(step_mb, home)
                ep_losses.append(loss)
                n_inputs += step_mb.num_input
                n_cached += step_mb.num_cached
                n_iso += step_mb.num_isolated
                n_b += 1
            report.epoch_times.append(time.perf_counter() - t_ep)
            report.losses.append(float(np.mean(ep_losses)) if ep_losses
                                 else float("nan"))
            if eval_every and (ep + 1) % eval_every == 0:
                report.val_acc.append(
                    self.evaluate(self.ds.val_idx, eval_batches))
        if n_b:
            # per MINIBATCH, not per step: a DP>1 step consumes G of them,
            # and the paper's Table 3/4 comparisons are per-minibatch
            n_mb = n_b * G
            report.input_nodes_per_batch = n_inputs / n_mb
            report.cached_nodes_per_batch = n_cached / n_mb
            report.isolated_per_batch = n_iso / n_mb
        return report

    def _timed(self, it):
        """Wrap a batch iterator, attributing wall time to meter.t_sample.

        The store self-reports the host gather inside ``sample`` to
        meter.t_slice and (sync-mode) cache builds inside ``start_epoch``
        to meter.t_refresh; subtract both deltas so each second lands in
        exactly one bucket.  Clamped at zero: an async build finishing
        during a short window could otherwise over-subtract.
        """
        it = iter(it)
        while True:
            t0 = time.perf_counter()
            slice0 = self.meter.t_slice
            refresh0 = self.meter.t_refresh
            try:
                mb = next(it)
            except StopIteration:
                return
            elapsed = time.perf_counter() - t0
            self.meter.t_sample += max(
                elapsed - (self.meter.t_slice - slice0)
                - (self.meter.t_refresh - refresh0), 0.0)
            yield mb

    # ------------------------------------------------------------------
    def evaluate(self, idx: Optional[np.ndarray] = None,
                 num_batches: int = 8) -> float:
        """Micro-F1 (= accuracy for single-label tasks, as in the paper)."""
        if idx is None:
            idx = self.ds.val_idx
        b = self.scfg.batch_size
        idx = np.asarray(idx)
        if len(idx) < b:  # pad by wrapping; mask handles duplicates' weight
            idx = np.concatenate([idx, idx[: b - len(idx)]])
        rng = np.random.default_rng(1234)
        if isinstance(self.sampler, GNSSampler):
            self.sampler.ensure_cache(rng)
        if self.store is not None:
            self.store.record = False   # eval must not skew training metrics
                                        # or the adaptive policy's miss EMA
        correct, total = 0.0, 0.0
        try:
            for i in range(num_batches):
                lo = (i * b) % (len(idx) - b + 1)
                targets = idx[lo:lo + b]
                mb = self.sampler.sample(targets, rng)
                with shlib.use_mesh(self.mesh):
                    _, acc = self._eval_step(
                        self.params,
                        self._put_batch(mb.device, meter=self.meter_eval),
                        self._cache_table(mb), self._device_adj(mb))
                correct += float(acc)
                total += 1.0
        finally:
            if self.store is not None:
                self.store.record = True
        return correct / max(total, 1.0)

    # ------------------------------------------------------------------
    # serving-shaped inference (the repro.serve subsystem's engine surface)
    # ------------------------------------------------------------------
    def _bucket_sampler(self, bucket: int):
        """A sampler whose padded shapes are sized for ``bucket`` targets.

        Separate instances per bucket (never ``self.sampler``): each bucket
        is a distinct set of static pad sizes, and a dedicated instance keeps
        the serving path off the training sampler's scratch state.  All
        bucket samplers share ``self.store``, so they resolve against the
        SAME live generation and feed the same adaptive-policy/placement
        traffic signals.
        """
        s = self._bucket_samplers.get(bucket)
        if s is None:
            scfg = dataclasses.replace(self.scfg, batch_size=int(bucket))
            s = make_sampler(self.cfg.sampler, self.ds.graph, scfg,
                             self.ds.features, self.ds.labels,
                             train_idx=self.ds.train_idx, store=self.store)
            self._bucket_samplers[bucket] = s
        return s

    def ensure_cache(self, rng: Optional[np.random.Generator] = None) -> None:
        """Cold-start the cache generation (no-op for storeless samplers)."""
        if isinstance(self.sampler, GNSSampler):
            self.sampler.ensure_cache(rng)

    def infer_prepare(self, node_ids: np.ndarray, bucket: Optional[int] = None,
                      rng: Optional[np.random.Generator] = None,
                      sampler=None) -> MiniBatch:
        """Sample one inference minibatch padded to ``bucket`` targets.

        The returned batch PINS the cache generation it was assembled
        against (``MiniBatch.cache_gen``), so :meth:`infer_compute` reads a
        matching slot-map/table pair even if an async refresh swaps the live
        generation in between — the serving loop's in-flight safety contract.
        Accounting follows the store's current mode (the server wraps this
        in ``FeatureStore.serving``; :meth:`infer` suspends it entirely).

        ``sampler`` overrides the per-bucket serving sampler (its pad sizes
        must match ``bucket``) — the one-shot :meth:`infer` passes the
        training sampler so it never duplicates the O(V) sampler scratch.
        """
        ids = np.asarray(node_ids, dtype=np.int64)
        if bucket is None:
            bucket = self.scfg.batch_size
        assert len(ids) <= bucket, (len(ids), bucket)
        if rng is None:
            rng = np.random.default_rng(4321)
        if sampler is None:
            sampler = self._bucket_sampler(bucket)
        else:
            assert sampler.cfg.batch_size == bucket, (
                sampler.cfg.batch_size, bucket)
        if isinstance(sampler, GNSSampler):
            if self.store.generation is None:
                self.ensure_cache(rng)
            sampler.adopt_generation()    # follow the live gen (monotonic)
        return sampler.sample(ids, rng)

    def infer_compute(self, mb: MiniBatch,
                      meter: Optional[TrafficMeter] = None) -> np.ndarray:
        """Run the compiled inference step on a prepared batch.

        Returns logits ``[bucket, classes]`` (padded rows included — slice
        the leading real rows off).  One jit cache entry per bucket shape:
        the device table is an UNTRACED operand resolved per batch from the
        batch's pinned generation, so generation swaps never retrace.

        ``meter`` receives the host->device copy time (serving callers pass
        their own so concurrent workers never race one meter; default is
        the engine's inference side meter).
        """
        with shlib.use_mesh(self.mesh):
            logits = self._logits_step(
                self.params,
                self._put_batch(mb.device,
                                meter=meter if meter is not None
                                else self.meter_infer),
                self._cache_table(mb), self._device_adj(mb))
        return np.asarray(logits)

    @property
    def infer_step(self):
        """The one compiled inference step (jit-cached per bucket shape)."""
        return self._logits_step

    def serve(self, serve_cfg=None):
        """A :class:`repro.serve.GNSServer` over this engine (not started).

        The default config goes through :meth:`EngineConfig.serve_config`,
        so the unified ``EngineConfig.refresh`` hint (when set) decides
        ``refresh_every`` for serving exactly as it decides the training
        path's cache period.
        """
        from repro.serve import GNSServer
        return GNSServer(self, serve_cfg if serve_cfg is not None
                         else self.cfg.serve_config())

    def serve_fabric(self, fabric_cfg=None, serve_cfg=None):
        """A :class:`repro.serve.ServeFabric` fleet over this engine (not
        started).  Defaults come from ``EngineConfig.serve.fabric`` (per
        :meth:`EngineConfig.serve_config`, so the unified refresh hint
        applies) — a bare ``FabricConfig()`` when unset."""
        from repro.serve import ServeFabric
        return ServeFabric(self, cfg=fabric_cfg, serve_cfg=serve_cfg)

    def infer(self, node_ids: np.ndarray) -> np.ndarray:
        """Mini-batch inference over arbitrary node ids.  [N, classes] f32.

        The one-shot entry point: reuses the LIVE cache generation (no
        refresh is triggered beyond the cold-start one), suspends all
        traffic/policy accounting, and leaves the training state untouched.
        For a request stream, use :meth:`serve` — the persistent loop
        micro-batches into size buckets and feeds the adaptive policy.
        """
        ids = np.asarray(node_ids, dtype=np.int64)
        b = self.scfg.batch_size
        rng = np.random.default_rng(4321)
        self.ensure_cache(rng)
        out = np.zeros((len(ids), self.mcfg.num_classes), np.float32)
        if self.store is not None:
            self.store.record = False
        try:
            for lo in range(0, len(ids), b):
                chunk = ids[lo:lo + b]
                targets = np.resize(chunk, b)    # wrap-pad the tail batch
                # one-shot path: reuse the TRAINING sampler (documented as
                # not concurrent with fit) — a bucket sampler here would
                # duplicate its O(V) scratch for nothing
                mb = self.infer_prepare(targets, bucket=b, rng=rng,
                                        sampler=self.sampler)
                out[lo:lo + len(chunk)] = self.infer_compute(mb)[:len(chunk)]
        finally:
            if self.store is not None:
                self.store.record = True
        return out

    # ------------------------------------------------------------------
    # streaming ingest (repro.stream)
    # ------------------------------------------------------------------
    def _init_stream(self, scfg=None):
        """Attach a :class:`repro.stream.DeltaBuffer` to the store."""
        from repro.gns.config import StreamConfig
        from repro.stream import DeltaBuffer
        assert self.store is not None, (
            "streaming ingest rides the GNS feature store's generation "
            f"machinery — sampler={self.cfg.sampler!r} has no store")
        if scfg is None:
            scfg = (self.cfg.stream if self.cfg.stream is not None
                    else StreamConfig())
        buf = DeltaBuffer(self.ds.graph.num_nodes, self.ds.feat_dim,
                          max_pending=scfg.max_pending)
        self.store.labels = self.ds.labels
        self.store.attach_stream(buf, scfg)
        self.store.add_merge_listener(self._on_merge)
        self._stream = buf
        return buf

    def _on_merge(self, store, batch) -> None:
        """Builder-thread merge callback: re-point the engine's dataset view
        at the post-merge host tiers (pure reference swaps — samplers adopt
        structure separately, at their own swap point)."""
        self.ds.graph = store.graph
        self.ds.features = store.features
        if store.labels is not None:
            self.ds.labels = store.labels

    @property
    def stream(self):
        """The delta staging buffer (created on first touch)."""
        return self._stream if self._stream is not None \
            else self._init_stream()

    @property
    def pending_deltas(self) -> int:
        """Staged mutations awaiting the next generation merge."""
        return self.store.pending_deltas() if self.store is not None else 0

    def ingest(self, src, dst, op: str = "insert") -> int:
        """Stage edge mutations for the next generation merge.

        Non-blocking and thread-safe (serving stays live); raises
        :class:`repro.serve.QueueFull` past ``stream.max_pending``.  The
        edges become visible to sampling/serving only when a generation
        built after the merge is adopted — in-flight batches replay
        bitwise-identically against their pinned pre-merge generation.
        Returns the first assigned sequence number.
        """
        buf = self.stream
        if op == "insert":
            return buf.add_edges(src, dst)
        assert op == "delete", f"op must be insert|delete, got {op!r}"
        return buf.delete_edges(src, dst)

    def ingest_nodes(self, features: np.ndarray,
                     labels: Optional[np.ndarray] = None) -> np.ndarray:
        """Stage new nodes (+feature rows); returns their assigned ids.

        Ids are allocated contiguously above the current id space, so
        staged edges may reference them immediately.
        """
        return self.stream.add_nodes(features, labels)

    def ingest_events(self, ev) -> int:
        """Stage one :class:`repro.data.temporal.EventBatch` (nodes first,
        then the edges that may reference them)."""
        buf = self.stream
        if ev.node_feats is not None and len(ev.node_feats):
            ids = buf.add_nodes(ev.node_feats, ev.node_labels)
            assert int(ids[0]) == ev.node_base, (
                "event batches must be ingested in stream order",
                int(ids[0]), ev.node_base)
        return buf.add_edges(ev.src, ev.dst)

    def save(self, directory, step: int = 0, *, keep: int = 3):
        """Checkpoint model + optimizer state AND the un-merged delta log.

        The streaming buffer's seq-stamped ops ride the checkpoint's ``aux``
        side-payload (variable shapes between saves), so a crash between an
        ingest and the next generation merge loses nothing: :meth:`restore`
        replays them with their original seqs and last-op-wins resolution
        makes the replay idempotent.
        """
        from repro import checkpoint as ckpt
        tree = {"params": self.params, "opt_state": self.opt_state}
        aux = {}
        extra: dict = {"seed": self.cfg.seed}
        if self._stream is not None:
            st = self._stream.state()
            extra["stream"] = {"next_node": int(st["next_node"]),
                               "next_seq": int(st["next_seq"])}
            aux = {f"stream/{k}": v for k, v in st.items()}
        return ckpt.save_checkpoint(directory, step, tree, extra=extra,
                                    keep=keep, aux=aux)

    def restore(self, directory, step: Optional[int] = None) -> int:
        """Resume from :meth:`save`: params/opt state plus the staged delta
        log (re-staged into this engine's buffer when the checkpoint carried
        one).  Returns the restored step."""
        from repro import checkpoint as ckpt
        tree_like = {"params": self.params, "opt_state": self.opt_state}
        tree, step, _extra = ckpt.load_checkpoint(directory, tree_like,
                                                  step=step)
        self.params, self.opt_state = tree["params"], tree["opt_state"]
        aux = ckpt.load_aux(directory, step)
        stream_state = {k.split("/", 1)[1]: v for k, v in aux.items()
                        if k.startswith("stream/")}
        if stream_state:
            self.stream.restore(stream_state)
        return step

    def merge_deltas(self):
        """Force a merge NOW: synchronous refresh (drains the buffer at the
        build boundary) + adoption by the training sampler.  The serving
        path instead lets the fabric watchdog kick an ASYNC refresh when
        ``store.stream_merge_due()`` — same machinery, no pause.
        """
        assert self.store is not None
        gen = self.store.refresh(version=self.store.version + 1)
        self.sampler.adopt_generation()
        return gen

    # ------------------------------------------------------------------
    def describe(self) -> dict:
        """Lowering/traffic report for THIS config (what dryrun_gnn prints).

        With a mesh: the full pod-scale record — compiled-step cost
        analysis, per-chip cache bytes, shard-aware upload bytes per
        generation, and the locality-placement cross-shard traffic
        simulation.  Without one: the host-side subset (no lowering).
        """
        from repro.gns.describe import describe_lowering, traffic_report
        if self.mesh is None:
            rec = traffic_report(
                num_nodes=self.ds.graph.num_nodes, feat_dim=self.ds.feat_dim,
                cache_frac=self.scfg.cache.fraction,
                batch=self.scfg.batch_size, fanouts=self.scfg.fanouts,
                n_shards=(self.store.n_shards if self.store else 1),
                meter=self.meter,
                backend=getattr(self.scfg, "backend", "host"))
        else:
            rec = describe_lowering(
                mesh=self.mesh, num_nodes=self.ds.graph.num_nodes,
                feat_dim=self.ds.feat_dim, num_classes=self.ds.num_classes,
                cache_frac=self.scfg.cache.fraction,
                batch=self.scfg.batch_size * max(self.num_groups, 1),
                fanouts=tuple(self.scfg.fanouts),
                hidden_dim=self.mcfg.hidden_dim,
                input_impl=self.mcfg.input_impl,
                backend=getattr(self.scfg, "backend", "host"),
                sample_kernel=getattr(self.mcfg, "sample_kernel", "reference"),
                optim=self.cfg.optim)
        if self._stream is not None and self.store is not None:
            # run-state fields are volatile by design — repro.gns.describe's
            # diff() excludes them by name, like meter/compile_s
            rec["stream"] = {
                "enabled": True,
                "max_pending": self.store.stream_cfg.max_pending,
                "incremental_placement":
                    self.store.stream_cfg.incremental_placement,
                "pending_deltas": self.store.pending_deltas(),
                "merges_applied": self.store.merges_applied,
                "rows_migrated": self.store.rows_migrated,
            }
        return rec

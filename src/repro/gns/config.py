"""Declarative engine configuration: one config, every surface.

``EngineConfig`` is the single description of a GNS training/inference run —
dataset, sampler, cache/placement, mesh, model, optimizer and serving
sub-configs — that :class:`repro.gns.engine.GNSEngine` turns into the wired
pipeline
(FeatureStore → sampler → EpochLoader/Prefetcher → compiled step).  It
replaces the hand-assembled ``GNNTrainer.__init__`` kwarg pile that every
example and benchmark used to rebuild independently.

Design rules:

* **Pure data.**  Every field is a frozen dataclass of plain values; the
  whole config round-trips through ``to_dict``/``from_dict`` (JSON-safe), so
  a run can be logged, diffed and replayed.
* **Existing configs are reused, not wrapped.**  ``SamplerConfig``
  (repro.core.sampler), ``CacheConfig`` (repro.featurestore — placement
  included) and ``AdamConfig`` (repro.optim.adam) appear verbatim as
  sub-configs; only the dataset/mesh/model descriptions needed new
  declarative types.  ``EngineConfig.cache`` is the authoritative cache
  config — it is injected into ``sampling.cache`` at build time
  (:meth:`EngineConfig.sampler_config`), so the two can never drift.
* **Presets are the sharing mechanism.**  Benchmarks and examples start from
  a named preset (:meth:`EngineConfig.preset`) and override explicitly;
  the benchmarked and the trained configuration come from one literal.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import numpy as np

from repro.core.sampler import SamplerConfig
from repro.featurestore import CacheConfig
from repro.optim.adam import AdamConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    """Named synthetic dataset (repro.graph.datasets) + scale."""
    name: str = "ogbn-products"
    scale: float = 0.5
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    """Declarative host mesh: (data, model) axis sizes over local devices.

    ``GNSEngine`` builds the jax mesh via ``launch.mesh.make_host_mesh``;
    passing a concrete ``jax.sharding.Mesh`` to the engine overrides this.
    """
    data: int = 1
    model: int = 1


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Declarative GraphSAGE dims; feat_dim / num_classes / num_layers are
    resolved from the dataset and sampler at build time (pass a concrete
    ``SageConfig`` to the engine to override everything)."""
    hidden_dim: int = 256
    aggregate_impl: str = "reference"   # "reference" | "pallas"
    input_impl: str = "where"           # "where" | "fused"
    input_kernel: str = "pallas"        # fused backend: "pallas" | "reference"
    sample_kernel: str = "auto"         # device-sampling gather backend:
                                        # "auto" (pallas on TPU, jnp
                                        # reference elsewhere) | "pallas" |
                                        # "reference"


@dataclasses.dataclass(frozen=True)
class TenantConfig:
    """One serving tenant: its fair-share weight and its admission quota.

    ``weight`` sets the tenant's share of worker throughput under
    saturation (stride scheduling: a weight-2 tenant is dequeued twice as
    often as a weight-1 tenant).  ``max_queue`` bounds how many of the
    tenant's requests may wait on any ONE worker — the per-tenant
    backpressure that keeps a flooding tenant's QueueFull its own problem.
    """
    name: str
    weight: float = 1.0
    max_queue: int = 64


@dataclasses.dataclass(frozen=True)
class FabricConfig:
    """Declarative multi-tenant serving fabric (``repro.serve.ServeFabric``).

    Scales the single ``GNSServer`` worker to a fleet over ONE shared cache
    generation: each worker owns a DP group (and therefore a home shard of
    the sharded cache), requests are routed to the worker whose shard owns
    their hot rows, and per-tenant weighted-fair queues isolate tenants
    from each other's bursts.
    """
    workers: int = 2                # fleet size; worker i serves DP group i
    tenants: Sequence[TenantConfig] = ()
                                    # declared tenants; unknown tenants are
                                    # auto-registered with the defaults below
    default_weight: float = 1.0
    default_quota: int = 64         # per-tenant per-worker queue bound for
                                    # auto-registered tenants
    routing: str = "locality"       # "locality" (placement-derived routing
                                    # table + ownership vote) | "spread"
                                    # (least-loaded, ignores the table)
    stall_timeout_ms: float = 1000.0
                                    # a worker whose heartbeat is older than
                                    # this while it owes work is STALLED:
                                    # routed around + its queue re-routed
    watch_interval_ms: float = 20.0
                                    # watchdog poll period (health checks,
                                    # generation swaps, refresh kicks)
    max_retries: int = 2            # failover re-routes per request before
                                    # its future fails with WorkerDown
    transport: str = "inproc"       # "inproc" (threads over one cache) |
                                    # "tcp" (repro.rpc: each worker is a
                                    # RemoteWorkerProxy to a WorkerEndpoint
                                    # process with its own cache replica)
    endpoints: Sequence[str] = ()   # "host:port" per worker (tcp transport;
                                    # len must equal ``workers``)
    heartbeat_ms: float = 100.0     # endpoint heartbeat period; beat ages
                                    # feed the SAME stall_timeout_ms watchdog
                                    # rule as in-proc workers
    connect_timeout_ms: float = 5000.0
                                    # per-attempt TCP connect timeout
    connect_retries: int = 5        # bounded reconnect attempts with
                                    # exponential backoff + deterministic
                                    # (seeded) jitter
    connect_backoff_ms: float = 50.0
                                    # backoff base: attempt k sleeps
                                    # base * 2^k * (1 + 0.25*jitter)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Declarative serving sub-block (``repro.serve.GNSServer``).

    ``buckets`` are the ONLY padded inference-batch sizes the server ever
    ships to the device: the micro-batcher coalesces queued requests and pads
    to the smallest bucket that holds them, so steady-state serving compiles
    exactly one inference step per bucket (``GNSEngine.infer_prepare`` /
    ``infer_compute``) and never retraces — the `launch/serve.py` step-cache
    design transplanted onto the GNS cache tier.
    """
    buckets: Sequence[int] = (32, 128, 512)
                                    # ascending padded batch sizes; the
                                    # largest is the per-step id budget
    max_queue: int = 256            # admission control: queued requests
                                    # beyond this are REJECTED (QueueFull)
    max_wait_ms: float = 2.0        # micro-batch coalescing window: how long
                                    # the batcher holds the first request of
                                    # a batch while more arrive
    default_deadline_ms: Optional[float] = None
                                    # per-request deadline (ms from submit);
                                    # requests still queued past it complete
                                    # as "expired" without touching the
                                    # device.  None = no deadline.
    refresh_every: Optional[int] = None
                                    # kick an async cache refresh every N
                                    # served batches, so the adaptive policy
                                    # (fed by serving traffic) re-draws the
                                    # generation toward the INFERENCE hot
                                    # set.  None = never refresh while
                                    # serving.
    latency_window: int = 2048      # rolling per-request latency records
                                    # kept for the p50/p99 view
    fabric: Optional[FabricConfig] = None
                                    # multi-tenant fleet settings; None means
                                    # ``GNSEngine.serve_fabric()`` falls back
                                    # to FabricConfig() defaults


@dataclasses.dataclass(frozen=True)
class RefreshConfig:
    """ONE async-refresh schedule for every surface that kicks refreshes.

    Before this, the training path (``CacheConfig.period`` /
    ``async_refresh``) and the serving path (``ServeConfig.refresh_every``)
    were configured independently and could disagree on whether refreshes
    run at all.  ``EngineConfig.refresh`` is the single hint: when set, it
    overrides the corresponding fields of both sub-configs at build time
    (:meth:`EngineConfig.cache_config` / :meth:`EngineConfig.serve_config`).
    When ``None``, the sub-configs stand alone exactly as before.
    """
    period: int = 1                 # training: refresh every N epochs
    async_refresh: bool = False     # training: build next gen off-thread
    serve_every: Optional[int] = None
                                    # serving: async refresh every N served
                                    # batches (None = never while serving)


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Declarative streaming-ingest sub-block (``repro.stream``).

    Governs the :class:`~repro.stream.DeltaBuffer` the engine's
    ``ingest()`` surface stages edge/node deltas into, and when/how the
    store folds them into the live structure.  Deltas are merged ONLY at a
    generation boundary (``FeatureStore._build``), so the atomic swap that
    already carries features carries structure too — in-flight batches
    stay pinned to the pre-merge generation, bitwise-identical.
    """
    max_pending: int = 4096         # DeltaBuffer admission bound: ops staged
                                    # beyond this are REJECTED (QueueFull —
                                    # the serving tier's discipline)
    merge_min_pending: int = 1      # the fabric watchdog kicks a merging
                                    # refresh once this many ops are buffered
    incremental_placement: bool = True
                                    # locality re-solve touches only rows
                                    # whose traffic/degree changed since the
                                    # last solve (bounded migration set);
                                    # False = full re-solve every generation
    symmetrize: bool = True         # mirror each delta op (undirected CSR —
                                    # matches CSRGraph.from_edges)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """One declarative description of a GNS run (see module docstring)."""
    sampler: str = "gns"                # ns | gns | ladies | lazygcn
    data: DataConfig = dataclasses.field(default_factory=DataConfig)
    sampling: SamplerConfig = dataclasses.field(
        default_factory=lambda: SamplerConfig(batch_size=256))
    cache: CacheConfig = dataclasses.field(default_factory=CacheConfig)
    model: ModelConfig = dataclasses.field(default_factory=ModelConfig)
    optim: AdamConfig = dataclasses.field(
        default_factory=lambda: AdamConfig(lr=3e-3))
    mesh: Optional[MeshConfig] = None
    serve: ServeConfig = dataclasses.field(default_factory=ServeConfig)
    refresh: Optional[RefreshConfig] = None
                                        # unified refresh hint (overrides
                                        # cache.period/async_refresh AND
                                        # serve.refresh_every when set)
    stream: Optional[StreamConfig] = None
                                        # streaming-ingest settings; None
                                        # still allows ``engine.ingest()``
                                        # (lazy-attached with defaults)
    seed: int = 0
    prefetch: bool = False              # fit() default (overridable per call)

    # ------------------------------------------------------------------
    def cache_config(self) -> CacheConfig:
        """``EngineConfig.cache`` with the unified refresh hint applied."""
        if self.refresh is None:
            return self.cache
        return dataclasses.replace(self.cache, period=self.refresh.period,
                                   async_refresh=self.refresh.async_refresh)

    def serve_config(self) -> ServeConfig:
        """``EngineConfig.serve`` with the unified refresh hint applied."""
        if self.refresh is None:
            return self.serve
        return dataclasses.replace(self.serve,
                                   refresh_every=self.refresh.serve_every)

    def sampler_config(self) -> SamplerConfig:
        """The sampler config with THE cache config injected — the one
        object handed to ``make_sampler``/``FeatureStore`` so
        ``EngineConfig.cache`` and ``sampling.cache`` cannot diverge (and,
        via :meth:`cache_config`, so the refresh hint reaches the sampler
        path too)."""
        return dataclasses.replace(self.sampling, cache=self.cache_config())

    # ------------------------------------------------------------------
    # dict round-trip (JSON-safe)
    # ------------------------------------------------------------------
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        md = d["optim"]["moment_dtype"]
        if not isinstance(md, str):
            d["optim"]["moment_dtype"] = np.dtype(md).name
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "EngineConfig":
        return _build(cls, d)

    # ------------------------------------------------------------------
    @classmethod
    def preset(cls, name: str, **overrides) -> "EngineConfig":
        """A named baseline config, optionally overridden field-by-field.

        Overrides are top-level ``EngineConfig`` fields (sub-configs are
        replaced whole — use ``dataclasses.replace`` on the result for
        field-level tweaks).
        """
        base = PRESETS[name]
        return dataclasses.replace(base, **overrides) if overrides else base


# ---------------------------------------------------------------------------
# nested reconstruction
# ---------------------------------------------------------------------------

_TUPLE_FIELDS = {"fanouts", "walk_fanouts", "buckets", "endpoints"}
_DTYPES = {"float32": np.float32, "bfloat16": None}   # resolved lazily


def _moment_dtype(name: str):
    if name == "bfloat16":
        import jax.numpy as jnp
        return jnp.bfloat16
    import jax.numpy as jnp
    return {"float32": jnp.float32, "float16": jnp.float16}.get(name, jnp.float32)


def _build(cls_, d):
    """Rebuild a (possibly nested) frozen dataclass from its asdict form."""
    if d is None:
        return None
    kw = {}
    for f in dataclasses.fields(cls_):
        if f.name not in d:
            continue
        v = d[f.name]
        sub = _NESTED.get((cls_, f.name))
        seq_sub = _NESTED_SEQ.get((cls_, f.name))
        if sub is not None:
            kw[f.name] = _build(sub, v)
        elif seq_sub is not None and v is not None:
            kw[f.name] = tuple(
                _build(seq_sub, el) if isinstance(el, dict) else el
                for el in v)
        elif f.name in _TUPLE_FIELDS and v is not None:
            kw[f.name] = tuple(v)
        elif cls_ is AdamConfig and f.name == "moment_dtype" \
                and isinstance(v, str):
            kw[f.name] = _moment_dtype(v)
        else:
            kw[f.name] = v
    return cls_(**kw)


_NESTED = {
    (EngineConfig, "data"): DataConfig,
    (EngineConfig, "sampling"): SamplerConfig,
    (EngineConfig, "cache"): CacheConfig,
    (EngineConfig, "model"): ModelConfig,
    (EngineConfig, "optim"): AdamConfig,
    (EngineConfig, "mesh"): MeshConfig,
    (EngineConfig, "serve"): ServeConfig,
    (EngineConfig, "refresh"): RefreshConfig,
    (EngineConfig, "stream"): StreamConfig,
    (SamplerConfig, "cache"): CacheConfig,
    (ServeConfig, "fabric"): FabricConfig,
}

# sequence-of-dataclass fields: rebuilt element-wise into a tuple
_NESTED_SEQ = {
    (FabricConfig, "tenants"): TenantConfig,
}


# ---------------------------------------------------------------------------
# presets — the single home for configurations shared across surfaces
# ---------------------------------------------------------------------------

PRESETS: dict = {
    # examples/quickstart.py: laptop-scale GNS-vs-NS comparison
    "quickstart": EngineConfig(
        sampler="gns",
        data=DataConfig(name="ogbn-products", scale=1.0),
        sampling=SamplerConfig(batch_size=128, fanouts=(5, 10, 15)),
        cache=CacheConfig(fraction=0.05, period=1)),
    # examples/train_gns_graphsage.py: the paper's §4.1 training setup
    "paper_train": EngineConfig(
        sampler="gns",
        data=DataConfig(name="ogbn-products", scale=0.5),
        sampling=SamplerConfig(batch_size=1000, fanouts=(5, 10, 15)),
        cache=CacheConfig(fraction=0.01, period=1)),
    # benchmarks/common.run_trainer: CI-scale harness defaults.  The cache
    # fraction matches the paper's 1% COVERAGE at container scale (see the
    # note in benchmarks/common.py); every bench_* module starts here, so a
    # benchmarked configuration is by construction a trainable one.
    "bench_ci": EngineConfig(
        sampler="gns",
        data=DataConfig(name="ogbn-products", scale=0.25),
        sampling=SamplerConfig(batch_size=512, fanouts=(5, 10, 15),
                               layer_size=512),
        cache=CacheConfig(fraction=0.05, period=1)),
    # benchmarks/bench_stream.py + the temporal-event replay scenario:
    # serve-while-mutating with locality placement over a sharded cache,
    # deltas drained by the fabric watchdog at generation boundaries
    "stream_replay": EngineConfig(
        sampler="gns",
        data=DataConfig(name="ogbn-products", scale=0.25),
        sampling=SamplerConfig(batch_size=256, fanouts=(5, 10)),
        cache=CacheConfig(fraction=0.05, strategy="adaptive",
                          placement="locality", shards=2),
        serve=ServeConfig(buckets=(32, 128), max_wait_ms=2.0),
        stream=StreamConfig(merge_min_pending=1)),
}

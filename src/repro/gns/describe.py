"""Lowering / traffic reports for an engine config (``GNSEngine.describe``).

This is the machinery behind ``launch.dryrun_gnn``: lower + compile the
engine's train step (``gns.engine.make_train_step`` — the SAME function the
in-process engine jits, home-shard vector included) on a production or
mocked mesh at the requested dimensions, and report roofline terms,
per-chip cache bytes, shard-aware upload bytes per generation, and the
locality-placement cross-shard traffic simulation.

``fast_path`` selects what the input layer lowers:

* ``"dynamic"`` (default) — the engine's device-resident home-shard vector:
  one compiled step serving any mix of per-group home shards (owner-shard
  ``lax.cond`` + psum of exact-zero non-owner partials);
* ``"static"``  — the PR-3 static ``local_shard=0`` lowering (owner kernel +
  recursive-doubling ppermute broadcast), kept for HLO comparison;
* ``"off"``     — the plain per-shard + psum path, no locality gate.
"""
from __future__ import annotations

import time

import numpy as np

from repro.configs.base import ShapeSpec
from repro.core.minibatch import block_pad_sizes
from repro.optim.adam import AdamConfig


def batch_structs(mesh, batch, fanouts, feat_dim, cache_axis=None,
                  backend="host"):
    """ShapeDtypeStruct DeviceBatch + shardings (batch dims on the DP axes).

    Group-aware: ``batch`` is the GLOBAL target count; block pads are built
    from the per-DP-group batch (``batch // num_groups``) and concatenated
    group-first, exactly the layout ``gns.engine.collate_groups`` produces —
    so the lowered step is the one the engine runs.  The global shapes match
    the ungrouped pads (the pad chain is multiplicative in the batch).

    ``backend="device"`` lowers the device-sampler batch: the input block is
    the placeholder (one dead lane, src == dst == D0 — no layer-0 neighbor
    lanes ship) and the batch carries the fallback lanes + per-group sample
    key the fused draw consumes.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.minibatch import DeviceBatch, LayerBlock
    from repro.kernels.ops import dp_group_count
    from repro.launch import sharding as shlib

    groups = dp_group_count(mesh, cache_axis)
    assert batch % groups == 0, (batch, groups)
    pads = block_pad_sizes(batch // groups, fanouts)
    dp = shlib.batch_axes(mesh)     # () on a 1-D cache-only mesh -> replicate
    dp = tuple(a for a in dp if a != cache_axis)
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)

    def sd(shape, dtype):
        return jax.ShapeDtypeStruct(shape, dtype)

    def sh(*parts):
        return NamedSharding(mesh, P(*parts))

    device = backend == "device"
    blocks, blocks_sh = [], []
    for li, (d, s) in enumerate(pads):
        if li == 0 and device:
            k, s = 1, d              # placeholder input block (device draw)
        else:
            k = fanouts[li]
        blocks.append(LayerBlock(
            nbr_idx=sd((groups * d, k), jnp.int32),
            nbr_w=sd((groups * d, k), jnp.float32),
            dst_mask=sd((groups * d,), jnp.float32), num_src=s, num_dst=d))
        blocks_sh.append(LayerBlock(
            nbr_idx=sh(dp, None), nbr_w=sh(dp, None), dst_mask=sh(dp),
            num_src=s, num_dst=d))
    s0 = groups * (pads[0][0] if device else pads[0][1])
    k0 = fanouts[0]
    batch_struct = DeviceBatch(
        blocks=tuple(blocks),
        input_cache_slots=sd((s0,), jnp.int32),
        input_streamed=sd((s0, feat_dim), jnp.float32),
        input_mask=sd((s0,), jnp.float32),
        labels=sd((batch,), jnp.int32),
        label_mask=sd((batch,), jnp.float32),
        input_fb_rows=sd((s0, k0), jnp.int32) if device else None,
        input_fb_w=sd((s0, k0), jnp.float32) if device else None,
        sample_key=sd((groups, 2), jnp.uint32) if device else None)
    batch_sh = DeviceBatch(
        blocks=tuple(blocks_sh),
        input_cache_slots=sh(dp),
        input_streamed=sh(dp, None),
        input_mask=sh(dp),
        labels=sh(dp),
        label_mask=sh(dp),
        input_fb_rows=sh(dp, None) if device else None,
        input_fb_w=sh(dp, None) if device else None,
        sample_key=sh(dp, None) if device else None)
    home_struct = sd((groups,), jnp.int32)
    home_sh = sh(dp)
    return batch_struct, batch_sh, home_struct, home_sh


def placement_traffic_sim(cache_rows: int, n_shards: int, n_groups: int,
                          dominant_share: float = 0.8,
                          seed: int = 0) -> dict:
    """Cross-shard lookup traffic, contiguous vs locality, at paper |C|.

    Runs the REAL placement solver (``featurestore.placement``) on a
    synthetic Zipf demand histogram at full production cache size (1.11M
    rows on papers100M): each cached row's traffic is Zipf-distributed and
    ``dominant_share`` of it comes from one uniformly-drawn DP group — the
    skew Data Tiering (arXiv:2111.05894) reports for real access traces.
    Reports the fraction of hit traffic served by the requesting group's
    home shard under both placements.
    """
    from repro.featurestore.placement import _assign, home_shard

    rng = np.random.default_rng(seed)
    rows_per_shard = cache_rows // n_shards
    total = rng.zipf(1.5, cache_rows).astype(np.float64)
    dom = rng.integers(0, n_groups, cache_rows)
    # per-(group, row) traffic without materializing [G, R] for the metric:
    # dominant group carries dominant_share, the rest spread evenly
    rest = total * (1.0 - dominant_share) / max(n_groups - 1, 1)
    pref = np.array([home_shard(g, n_shards) for g in range(n_groups)])[dom]

    # contiguous: shard of a slot is slot // rows_per_shard (membership is
    # traffic-agnostic, so hot rows land uniformly across shards)
    def local_traffic(shard_of_slot):
        local = np.zeros(cache_rows)
        for g in range(n_groups):
            mine = dom == g
            share = np.where(mine, dominant_share * total, rest)
            local += share * (shard_of_slot == home_shard(g, n_shards))
        return float(local.sum())

    grand = float(total.sum())
    contiguous = np.arange(cache_rows) // rows_per_shard
    # locality: the real greedy solver on (total, preferred shard) — the
    # exact code path FeatureStore._solve_placement runs, via the same
    # internal assignment
    locality, _ = _assign(total, pref, n_shards, rows_per_shard, seed=seed)
    frac_cont = local_traffic(contiguous) / grand
    frac_loc = local_traffic(locality) / grand
    return {
        "lookup_local_frac_contiguous": round(frac_cont, 4),
        "lookup_local_frac_locality": round(frac_loc, 4),
        "crossshard_rows_frac_contiguous": round(1 - frac_cont, 4),
        "crossshard_rows_frac_locality": round(1 - frac_loc, 4),
    }


def traffic_report(*, num_nodes: int, feat_dim: int, cache_frac: float,
                   batch: int, fanouts, n_shards: int = 1,
                   meter=None, backend: str = "host") -> dict:
    """Host-side subset of the record: no mesh, no lowering.

    ``backend="device"`` reports the device-resident sampling lowering: the
    input block degenerates to its dst rows (the layer-0 neighbor lanes are
    drawn inside the step against the generation's cache_adj CSR), so the
    per-batch input rows — and the worst-case streamed bytes — shrink by
    the (1 + k0) input-fanout factor.
    """
    from repro.featurestore import FeatureStore

    cache_rows = FeatureStore.padded_rows(num_nodes, cache_frac,
                                          multiple=max(n_shards, 1))
    table_bytes = cache_rows * feat_dim * 4
    pads = block_pad_sizes(batch, fanouts)
    s0 = pads[0][0] if backend == "device" else pads[0][1]
    rec = {
        "arch": "gnn-graphsage-gns", "status": "ok", "mesh": None,
        "sampler_backend": backend,
        "cache_rows": cache_rows, "cache_table_bytes": table_bytes,
        "input_rows_per_batch": s0,
        "streamed_bytes_per_batch_worstcase": s0 * feat_dim * 4,
    }
    if meter is not None:
        rec["meter"] = meter.breakdown()
    return rec


def describe_lowering(*, mesh, num_nodes: int, feat_dim: int,
                      num_classes: int, cache_frac: float, batch: int,
                      fanouts, hidden_dim: int = 256,
                      input_impl: str = "fused",
                      input_kernel: str = "reference",
                      fast_path: str = "dynamic",
                      backend: str = "host",
                      sample_kernel: str = "reference",
                      avg_degree: int = 16,
                      optim: AdamConfig = None) -> dict:
    """Lower + compile the engine train step on ``mesh``; return the record.

    ``batch`` is global (one minibatch per DP group, collated); the step
    lowered is ``gns.engine.make_train_step`` — byte-for-byte the function
    ``GNSEngine`` jits in process.

    ``backend="device"`` lowers the device-resident sampling step instead:
    the batch structs carry the placeholder input block + fallback lanes +
    sample key, a replicated :class:`~repro.sampling.DeviceCacheAdj` struct
    (``avg_degree`` sizes its indices capacity — shapes only, no data)
    feeds the fused draw→gather, and the input-row/streamed-bytes terms
    shrink by the (1 + k0) factor the device draw removes.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.featurestore import FeatureStore
    from repro.gns.engine import make_train_step
    from repro.kernels.ops import dp_group_count
    from repro.launch import sharding as shlib
    from repro.launch.mesh import cache_shard_axis
    from repro.models import graphsage
    from repro.optim.adam import AdamW
    from repro.roofline.analysis import collective_bytes_from_hlo, \
        roofline_terms

    assert fast_path in ("dynamic", "static", "off"), fast_path
    chips = mesh.size
    cache_axis = cache_shard_axis(mesh)
    groups = dp_group_count(mesh, cache_axis)
    mcfg = graphsage.SageConfig(feat_dim=feat_dim, hidden_dim=hidden_dim,
                                num_classes=num_classes,
                                num_layers=len(fanouts),
                                input_impl=input_impl,
                                input_kernel=input_kernel,
                                sample_kernel=sample_kernel,
                                cache_shard_axis=cache_axis,
                                num_groups=groups)
    opt = AdamW(optim or AdamConfig(lr=3e-3))
    # device-tier shape via the feature-store facade (pads rows so the
    # cache-axis shards divide evenly — the pod-scale cache tier)
    n_shards = mesh.shape[cache_axis]
    cache_rows = FeatureStore.padded_rows(num_nodes, cache_frac,
                                          multiple=n_shards)

    p_structs = jax.eval_shape(
        lambda: graphsage.init_params(jax.random.PRNGKey(0), mcfg))
    p_sh = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), p_structs)     # tiny -> replicated
    o_structs = jax.eval_shape(opt.init, p_structs)
    o_sh = {"m": p_sh, "v": p_sh, "step": NamedSharding(mesh, P())}
    cache_struct = jax.ShapeDtypeStruct((cache_rows, feat_dim), jnp.float32)
    cache_sh = NamedSharding(mesh, P(cache_axis, None))    # row-sharded cache
    b_structs, b_sh, home_struct, home_sh = batch_structs(
        mesh, batch, fanouts, feat_dim, cache_axis, backend=backend)

    adj_struct = adj_sh = None
    if backend == "device":
        # the device CSR structs (replicated — the draw stays global, only
        # the gather shard_maps); indices capacity mirrors the power-of-two
        # sizing of build_device_cache_adj at the estimated nnz
        from repro.sampling.adjacency import DeviceCacheAdj
        nnz = max(1024, cache_rows * avg_degree)
        cap = 1 << (nnz - 1).bit_length()
        repl = NamedSharding(mesh, P())
        adj_struct = DeviceCacheAdj(
            indptr=jax.ShapeDtypeStruct((cache_rows + 1,), jnp.int32),
            indices=jax.ShapeDtypeStruct((cap,), jnp.int32),
            deg=jax.ShapeDtypeStruct((cache_rows,), jnp.float32),
            hitp=jax.ShapeDtypeStruct((cache_rows,), jnp.float32))
        adj_sh = DeviceCacheAdj(indptr=repl, indices=repl, deg=repl,
                                hitp=repl)

    base_step = make_train_step(mcfg, opt)
    if fast_path == "dynamic" and backend == "device":
        def train_step(params, opt_state, batch_, cache_table, home, adj):
            p, o, loss, _ = base_step(params, opt_state, batch_, cache_table,
                                      home, adj)
            return p, o, loss
        args = (p_structs, o_structs, b_structs, cache_struct, home_struct,
                adj_struct)
        in_sh = (p_sh, o_sh, b_sh, cache_sh, home_sh, adj_sh)
    elif fast_path == "dynamic":
        def train_step(params, opt_state, batch_, cache_table, home):
            p, o, loss, _ = base_step(params, opt_state, batch_, cache_table,
                                      home)
            return p, o, loss
        args = (p_structs, o_structs, b_structs, cache_struct, home_struct)
        in_sh = (p_sh, o_sh, b_sh, cache_sh, home_sh)
    else:
        ls = 0 if fast_path == "static" else None

        def train_step(params, opt_state, batch_, cache_table):
            p, o, loss, _ = base_step(params, opt_state, batch_, cache_table,
                                      ls)
            return p, o, loss
        args = (p_structs, o_structs, b_structs, cache_struct)
        in_sh = (p_sh, o_sh, b_sh, cache_sh)

    t0 = time.time()
    with shlib.use_mesh(mesh):
        lowered = jax.jit(
            train_step,
            in_shardings=in_sh,
            out_shardings=(p_sh, o_sh, NamedSharding(mesh, P()))).lower(*args)
        compiled = lowered.compile()
    t_compile = time.time() - t0

    cost_list = compiled.cost_analysis()
    cost = cost_list[0] if isinstance(cost_list, (list, tuple)) else cost_list
    coll = collective_bytes_from_hlo(compiled.as_text())
    try:
        mem = compiled.memory_analysis()
        mem_d = {"argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                 "temp_bytes": getattr(mem, "temp_size_in_bytes", None)}
    except Exception as e:
        mem_d = {"error": str(e)}

    # roofline: no scan in the 3-layer GNN -> cost_analysis is exact
    n_params = sum(np.prod(l.shape)
                   for l in jax.tree_util.tree_leaves(p_structs))
    flops = float(cost.get("flops", 0.0))
    byt = float(cost.get("bytes accessed", 0.0))
    shape = ShapeSpec("train_1k", 1, batch, "train")   # D = batch target nodes
    terms = roofline_terms(flops, byt, coll, _gnn_cfg_stub(), shape, chips,
                           n_active=float(n_params))
    table_bytes = cache_rows * feat_dim * 4
    # cross-shard lookup traffic before/after the locality placement map:
    # the real solver on a skewed synthetic demand at this config's |C|
    n_dp_groups = max(chips // n_shards, 1)
    placement_sim = placement_traffic_sim(cache_rows, n_shards,
                                          min(n_dp_groups, 64))
    pads0 = block_pad_sizes(batch // groups, fanouts)[0]
    s0_rows = groups * (pads0[0] if backend == "device" else pads0[1])
    row_bytes = feat_dim * 4
    rec = {
        "arch": "gnn-graphsage-gns", "shape": "train_1k",
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        "chips": chips,
        "status": "ok", "kind": "train",
        "sampler_backend": backend,
        "input_rows_per_batch": s0_rows,
        "input_impl": mcfg.input_impl, "cache_shard_axis": cache_axis,
        "dp_groups": groups,
        "fast_path": fast_path,
        "local_fast_path": fast_path != "off",
        "params_total": float(n_params),
        "cache_rows": cache_rows,
        "cache_bytes_per_chip": table_bytes / n_shards,
        # per-generation refresh transfer: shard-aware upload vs replicating
        # the full table to every chip (the paper-scale saving PR 2 landed)
        "upload_bytes_per_gen_sharded": table_bytes * chips // n_shards,
        "upload_bytes_per_gen_replicated": table_bytes * chips,
        # locality placement: fraction of cache-hit rows the requesting DP
        # group's home shard serves, and the implied cross-shard row bytes
        # per batch, contiguous vs locality (PR 3's saving)
        **placement_sim,
        "crossshard_bytes_per_batch_contiguous": int(
            s0_rows * row_bytes *
            placement_sim["crossshard_rows_frac_contiguous"]),
        "crossshard_bytes_per_batch_locality": int(
            s0_rows * row_bytes *
            placement_sim["crossshard_rows_frac_locality"]),
        "memory_analysis": mem_d,
        "cost_flops_per_device": flops, "cost_bytes_per_device": byt,
        "roofline": terms.as_dict(), "compile_s": round(t_compile, 2),
    }
    return rec


def _gnn_cfg_stub():
    """Minimal cfg for roofline_terms' model_flops (n_active overrides)."""
    from repro.configs.base import ArchConfig
    return ArchConfig(name="gnn", family="gnn", num_layers=3, d_model=256,
                      num_heads=1, num_kv_heads=1, d_ff=0, vocab_size=1)


# ---------------------------------------------------------------------------
# diff mode: compare two configs' lowering/traffic records (ROADMAP follow-up)
# ---------------------------------------------------------------------------

# keys that vary run-to-run without the configuration changing: wall-clock
# measurements, per-process memory analysis, and streaming-ingest run state
# (staged/merged/migrated counts) have no place in a diff
_VOLATILE = ("compile_s", "memory_analysis", "meter",
             "pending_deltas", "merges_applied", "rows_migrated")


def _flatten(d: dict, prefix: str = "") -> dict:
    """Nested dict -> {dotted.key: leaf}, volatile keys dropped."""
    out = {}
    for k, v in d.items():
        if k in _VOLATILE:
            continue
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            out.update(_flatten(v, prefix=key + "."))
        else:
            out[key] = v
    return out


def diff_records(rec_a: dict, rec_b: dict) -> dict:
    """Structural diff of two describe records (or any nested dicts).

    Returns ``{"only_a": {...}, "only_b": {...}, "changed": {key: [a, b]},
    "same": bool}`` over dotted leaf keys, with run-volatile keys
    (compile wall time, memory analysis, live meter readings) excluded so
    two runs of the SAME config diff as identical.
    """
    fa, fb = _flatten(rec_a), _flatten(rec_b)
    changed = {k: [fa[k], fb[k]] for k in sorted(fa.keys() & fb.keys())
               if fa[k] != fb[k]}
    only_a = {k: fa[k] for k in sorted(fa.keys() - fb.keys())}
    only_b = {k: fb[k] for k in sorted(fb.keys() - fa.keys())}
    return {"only_a": only_a, "only_b": only_b, "changed": changed,
            "same": not (changed or only_a or only_b)}


def diff(cfg_a, cfg_b, *, dataset_a=None, dataset_b=None) -> dict:
    """Compare two :class:`~repro.gns.EngineConfig` runs end to end.

    Builds the engine for each config (``dataset_*`` shortcut concrete
    datasets, e.g. in tests) and diffs both layers:

    * ``config`` — the declarative fields themselves (what the operator
      changed);
    * ``record`` — each config's ``GNSEngine.describe()`` lowering/traffic
      record (what that change DID to cache rows, per-chip bytes, upload
      traffic, roofline terms, locality fractions ...).

    The CLI lives in ``launch/dryrun_gnn.py`` (``--diff A B`` with preset
    names or config-JSON paths).
    """
    from repro.gns.engine import GNSEngine

    rec_a = GNSEngine(cfg_a, dataset=dataset_a).describe()
    rec_b = GNSEngine(cfg_b, dataset=dataset_b).describe()
    out = {
        "config": diff_records(cfg_a.to_dict(), cfg_b.to_dict()),
        "record": diff_records(rec_a, rec_b),
    }
    out["same"] = out["config"]["same"] and out["record"]["same"]
    return out

"""Data substrate: synthetic token corpus, sharded loaders, vocab cache,
temporal event streams (the serve-while-mutating ingest workload)."""
from repro.data.temporal import (EventBatch, TemporalEventStream,
                                 temporal_event_stream)
from repro.data.tokens import SyntheticCorpus, TokenPipeline

__all__ = ["SyntheticCorpus", "TokenPipeline",
           "EventBatch", "TemporalEventStream", "temporal_event_stream"]

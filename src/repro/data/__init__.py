"""Data substrate: synthetic token corpus, sharded loaders, vocab cache."""
from repro.data.tokens import SyntheticCorpus, TokenPipeline

__all__ = ["SyntheticCorpus", "TokenPipeline"]

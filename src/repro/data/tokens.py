"""Token data pipeline for the LM trainers.

* :class:`SyntheticCorpus` — deterministic Zipf-distributed token stream
  (power-law token frequencies: the same access skew GNS exploits on graphs,
  reused by the hot-vocab embedding cache in data/vocab_cache.py).
* :class:`TokenPipeline` — sharded, prefetched host loader:
    - deterministic per-(host, epoch, step) slicing: every host of a 1000-node
      job computes ITS shard of the global batch from the seed alone — no
      data server, no coordination, bit-exact restart from a step index;
    - bounded background prefetch (straggler mitigation: the host pipeline
      runs ahead of the device step, same Prefetcher as the GNN path).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticCorpus:
    """Zipf token sampler — stands in for a tokenized web corpus."""
    vocab_size: int
    zipf_a: float = 1.2
    seed: int = 0

    def batch(self, epoch: int, step: int, batch: int, seq_len: int,
              host: int = 0, num_hosts: int = 1) -> np.ndarray:
        """[batch/num_hosts, seq_len] int32 — this host's shard, deterministic."""
        assert batch % num_hosts == 0, (batch, num_hosts)
        b_local = batch // num_hosts
        ss = np.random.SeedSequence([self.seed, epoch, step, host])
        rng = np.random.default_rng(ss)
        # inverse-CDF Zipf over a finite vocab (np.random.zipf is unbounded)
        ranks = np.arange(1, self.vocab_size + 1, dtype=np.float64)
        w = ranks ** (-self.zipf_a)
        cdf = np.cumsum(w) / w.sum()
        u = rng.random((b_local, seq_len))
        return np.searchsorted(cdf, u).astype(np.int32)


class TokenPipeline:
    """Prefetched host loader emitting train_step-layout batches.

    Emits dicts matching launch/specs.train_batch_structs with the leading
    [accum] microbatch dim (launch/steps.py layout).
    """

    def __init__(self, corpus: SyntheticCorpus, batch: int, seq_len: int,
                 accum: int = 1, host: int = 0, num_hosts: int = 1,
                 prefetch: int = 2, extra_builders: Optional[dict] = None):
        assert batch % max(accum, 1) == 0
        self.corpus, self.batch, self.seq_len = corpus, batch, seq_len
        self.accum = max(accum, 1)
        self.host, self.num_hosts = host, num_hosts
        self.prefetch = prefetch
        self.extra_builders = extra_builders or {}

    def _make(self, epoch: int, step: int) -> dict:
        toks = self.corpus.batch(epoch, step, self.batch, self.seq_len,
                                 self.host, self.num_hosts)
        b_local = toks.shape[0]
        out = {"tokens": toks.reshape(self.accum, b_local // self.accum,
                                      self.seq_len)}
        for name, fn in self.extra_builders.items():
            out[name] = fn(epoch, step, self.accum, b_local // self.accum)
        return out

    def epoch(self, epoch: int, steps: int, start_step: int = 0) -> Iterator[dict]:
        """Prefetched iterator over ``steps`` batches (resume at start_step)."""
        q: queue.Queue = queue.Queue(maxsize=self.prefetch)
        stop = object()

        def producer():
            try:
                for s in range(start_step, steps):
                    q.put(self._make(epoch, s))
            finally:
                q.put(stop)

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        while True:
            item = q.get()
            if item is stop:
                return
            yield item

"""Hot-vocabulary embedding cache — the GNS mechanism applied to LM tables.

DESIGN.md §5: large-vocab archs (gemma 256k, seamless 256k, qwen2 152k) have
Zipf-skewed token access — the same power-law skew GNS exploits via
degree-proportional cache sampling (paper eq. 6).  Mapping:

  graph node              -> vocab token
  node degree             -> token frequency (EMA of observed counts)
  GPU feature cache       -> HBM-pinned hot-row table (host keeps full table)
  cache-prioritized sample-> input lookups served from cache, misses streamed
  eq. (11) p^C            -> inclusion probability of a token in the cache
  eq. (10) 1/p rescale    -> importance-corrected *sampled softmax* negatives

Input embeddings are exact (a lookup, not a sample) — no correction needed;
the paper's importance math is reused where sampling genuinely happens: the
output softmax.  ``sampled_softmax_loss`` draws negatives from the cache
distribution and reweights logits by -log(E[count]) exactly like sampled-
softmax literature, with the GNS eq. (11) inclusion form.

Traffic accounting reuses :class:`repro.featurestore.TrafficMeter` so
benchmarks report the same host->device byte savings as the GNN path.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.featurestore import TrafficMeter


@dataclasses.dataclass(frozen=True)
class VocabCacheConfig:
    fraction: float = 0.01            # |C| / vocab (paper default 1%)
    period: int = 1                   # refresh every N epochs (paper Table 6)
    strategy: str = "sampled"         # "sampled" (GNS eq. 6) | "topk"
    ema: float = 0.9                  # frequency EMA decay across refreshes

    def size(self, vocab: int) -> int:
        return max(int(vocab * self.fraction), 1)


class VocabCache:
    """Host-resident full embedding table + device-pinned hot rows."""

    def __init__(self, host_table: np.ndarray, cfg: VocabCacheConfig,
                 sharding: Optional[jax.sharding.Sharding] = None,
                 seed: int = 0):
        self.host_table = host_table                     # [V, d] (never on device)
        self.cfg = cfg
        self.sharding = sharding
        self.vocab, self.dim = host_table.shape
        self.size = cfg.size(self.vocab)
        self.freq = np.ones(self.vocab, np.float64)      # uniform prior
        self._rng = np.random.default_rng(seed)
        self.version = -1
        self.slot_of = np.full(self.vocab, -1, np.int32)
        self.token_ids = np.zeros(self.size, np.int64)
        self.table: Optional[jax.Array] = None
        self.probs = self.freq / self.freq.sum()

    # -- frequency tracking (the "degree" analog) ---------------------------
    def observe(self, tokens: np.ndarray):
        counts = np.bincount(tokens.reshape(-1), minlength=self.vocab)
        self.freq = self.cfg.ema * self.freq + (1 - self.cfg.ema) * counts

    # -- refresh (paper §3.2) ------------------------------------------------
    def refresh(self, version: int, meter: Optional[TrafficMeter] = None):
        self.probs = self.freq / self.freq.sum()
        if self.cfg.strategy == "topk":
            ids = np.argpartition(self.probs, -self.size)[-self.size:]
        else:                                            # Gumbel top-k sample
            g = -np.log(-np.log(self._rng.random(self.vocab) + 1e-300) + 1e-300)
            keys = np.log(self.probs + 1e-300) + g
            ids = np.argpartition(keys, -self.size)[-self.size:]
        ids = np.sort(ids.astype(np.int64))
        self.token_ids = ids
        self.slot_of = np.full(self.vocab, -1, np.int32)
        self.slot_of[ids] = np.arange(self.size, dtype=np.int32)
        rows = self.host_table[ids]
        self.table = jnp.asarray(rows)
        if self.sharding is not None:
            self.table = jax.device_put(self.table, self.sharding)
        self.version = version
        if meter is not None:
            meter.bytes_cache_fill += rows.nbytes

    # -- batch assembly (host side) ------------------------------------------
    def assemble(self, tokens: np.ndarray,
                 meter: Optional[TrafficMeter] = None) -> dict:
        """slots + streamed rows for a token batch [...]; exact lookup.

        Streamed rows are deduplicated per batch (the paper's 'distinct input
        nodes' — Table 4 analog): each missing token's row crosses the host
        boundary once per batch, not once per occurrence.
        """
        slots = self.slot_of[tokens]                     # [...]: slot or -1
        miss_tokens = np.unique(tokens[slots < 0])
        streamed = self.host_table[miss_tokens]          # [M, d]
        # local index of each miss occurrence into the streamed block
        local = np.searchsorted(miss_tokens, tokens)
        local = np.where(slots < 0, local, 0).astype(np.int32)
        if meter is not None:
            meter.add_batch(int(streamed.nbytes))
        return {"slots": slots.astype(np.int32),
                "streamed": streamed.astype(np.float32),
                "miss_local": local}

    def hit_rate(self, tokens: np.ndarray) -> float:
        return float((self.slot_of[tokens] >= 0).mean())

    # -- eq. (11): inclusion probability of a token in the sampled cache ----
    def inclusion_probs(self, token_ids: np.ndarray) -> np.ndarray:
        p = self.probs[token_ids]
        return 1.0 - (1.0 - p) ** self.size


# ---------------------------------------------------------------------------
# device-side pure functions (jit-safe)
# ---------------------------------------------------------------------------

def embed_with_cache(cache_table: jnp.ndarray, batch: dict) -> jnp.ndarray:
    """h = where(slot >= 0, cache[slot], streamed[miss_local]) — exact."""
    slots = batch["slots"]
    hit = slots >= 0
    cached = jnp.take(cache_table, jnp.clip(slots, 0), axis=0)
    missed = jnp.take(batch["streamed"], batch["miss_local"], axis=0)
    return jnp.where(hit[..., None], cached, missed)


def sampled_softmax_loss(hidden: jnp.ndarray, labels: jnp.ndarray,
                         label_rows: jnp.ndarray, cache_table: jnp.ndarray,
                         cache_inclusion: jnp.ndarray) -> jnp.ndarray:
    """Sampled softmax with cache negatives + GNS eq. (11) correction.

    hidden [T, d]; labels [T]; label_rows [T, d] = unembed rows of the gold
    tokens; cache_table [C, d] = negatives; cache_inclusion [C] = p^C from
    eq. (11).  Subtracting log p^C makes the sampled partition an unbiased
    estimate of the full one (standard sampled-softmax correction with the
    GNS inclusion probability as the proposal mass).
    """
    t = hidden.shape[0]
    pos = jnp.einsum("td,td->t", hidden.astype(jnp.float32),
                     label_rows.astype(jnp.float32))
    neg = hidden.astype(jnp.float32) @ cache_table.astype(jnp.float32).T  # [T, C]
    neg = neg - jnp.log(jnp.clip(cache_inclusion, 1e-9, 1.0))[None, :]
    # exclude accidental hits of the gold token among negatives
    # (cache slot of the label, if present, would double-count the positive)
    all_logits = jnp.concatenate([pos[:, None], neg], axis=1)
    logz = jax.nn.logsumexp(all_logits, axis=1)
    return jnp.mean(logz - pos)

"""Temporal event streams: the serve-while-mutating workload generator.

GDELT-shaped replay for the streaming-ingest subsystem (``repro.stream``):
real event graphs arrive as timestamped batches of *interactions between
entities* — mostly between entities already known (with heavy-tailed,
preferential recurrence: hot actors stay hot), plus a trickle of new
entities that must become queryable shortly after they appear.

:func:`temporal_event_stream` synthesizes that shape on top of any loaded
:class:`~repro.graph.datasets.GraphDataset`:

* event endpoints are drawn **preferentially by degree** (the recurrence
  skew that makes the GNS cache effective also concentrates ingest on hot
  rows — exactly the regime the incremental placement re-solve must absorb);
* each batch introduces ``new_node_frac`` new entities with feature/label
  rows, id-contiguous above the current space (matching
  ``DeltaBuffer.add_nodes`` allocation, so batches replay in order via
  ``engine.ingest_events``);
* every new entity is attached to at least one existing hot entity, so
  post-merge queries for it have neighbors to sample.

The stream is deterministic in ``seed`` — replaying it against a rebuilt
engine reproduces the same post-merge structure bit for bit (the merge
kernel's rebuild-equivalence contract extends end to end).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class EventBatch:
    """One timestamped slice of the event stream (an ``ingest_events`` unit).

    ``src``/``dst`` are absolute node ids under the assumption batches are
    ingested IN ORDER: new entities of this batch occupy
    ``[node_base, node_base + len(node_feats))``, contiguous above
    everything staged before them.
    """
    t_start: int
    t_end: int
    src: np.ndarray                      # int64 [n_events]
    dst: np.ndarray                      # int64 [n_events]
    node_feats: Optional[np.ndarray]     # f32 [n_new, F] | None
    node_labels: Optional[np.ndarray]    # int64 [n_new] | None
    node_base: int                       # first new id (== id space before)

    @property
    def num_events(self) -> int:
        return len(self.src)

    @property
    def num_new_nodes(self) -> int:
        return 0 if self.node_feats is None else len(self.node_feats)


class TemporalEventStream:
    """An ordered, replayable sequence of :class:`EventBatch` (list-like)."""

    def __init__(self, batches: List[EventBatch], base_nodes: int):
        self.batches = batches
        self.base_nodes = int(base_nodes)   # id space before any batch

    def __len__(self) -> int:
        return len(self.batches)

    def __iter__(self) -> Iterator[EventBatch]:
        return iter(self.batches)

    def __getitem__(self, i: int) -> EventBatch:
        return self.batches[i]

    @property
    def total_events(self) -> int:
        return sum(b.num_events for b in self.batches)

    @property
    def total_new_nodes(self) -> int:
        return sum(b.num_new_nodes for b in self.batches)


def temporal_event_stream(dataset, *, num_batches: int = 8,
                          events_per_batch: int = 64,
                          new_node_frac: float = 0.1,
                          seed: int = 0) -> TemporalEventStream:
    """Synthesize a GDELT-shaped event stream over ``dataset`` (module doc).

    ``new_node_frac`` is the fraction of each batch's events that introduce
    a brand-new entity (one new node + its attachment edge per such event).
    """
    g = dataset.graph
    feats = np.asarray(dataset.features)
    feat_dim = feats.shape[1]
    num_classes = int(dataset.num_classes)
    rng = np.random.default_rng(seed)

    # preferential-attachment weights: degree+1 for loaded entities; new
    # entities enter at the mean weight so they can recur in later batches
    w = np.asarray(g.degrees, dtype=np.float64) + 1.0
    mean_w = float(w.mean())
    next_node = int(g.num_nodes)
    feat_loc = feats.mean(axis=0)
    feat_scale = feats.std(axis=0) + 1e-6

    batches: List[EventBatch] = []
    for b in range(num_batches):
        n_new = max(1, int(round(events_per_batch * new_node_frac))) \
            if new_node_frac > 0 else 0
        n_rec = events_per_batch - n_new
        p = w / w.sum()
        # recurring interactions between known entities (hot ↔ hot skew)
        src = rng.choice(len(w), size=n_rec, p=p)
        dst = rng.choice(len(w), size=n_rec, p=p)
        # resample self-pairs once (the merge drops self-loops anyway; this
        # just keeps the event count honest)
        loop = src == dst
        dst[loop] = rng.choice(len(w), size=int(loop.sum()), p=p)
        node_feats = node_labels = None
        if n_new:
            base = next_node
            # new entities look like the loaded ones (feature marginals)
            node_feats = rng.normal(
                feat_loc, feat_scale, size=(n_new, feat_dim)
            ).astype(np.float32)
            node_labels = rng.integers(0, max(num_classes, 1),
                                       size=n_new, dtype=np.int64)
            # each new entity attaches to one existing (preferential) anchor
            anchors = rng.choice(len(w), size=n_new, p=p)
            src = np.concatenate([src, np.arange(base, base + n_new)])
            dst = np.concatenate([dst, anchors])
            next_node = base + n_new
            w = np.concatenate([w, np.full(n_new, mean_w)])
        batches.append(EventBatch(
            t_start=b * events_per_batch,
            t_end=(b + 1) * events_per_batch,
            src=src.astype(np.int64), dst=dst.astype(np.int64),
            node_feats=node_feats, node_labels=node_labels,
            node_base=int(next_node - n_new) if n_new
            else int(next_node)))
    return TemporalEventStream(batches, base_nodes=int(g.num_nodes))

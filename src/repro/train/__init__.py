"""Training runtimes: GNN trainer (the paper's pipeline) + LM trainer."""
from repro.train.trainer import GNNTrainer, TrainReport

__all__ = ["GNNTrainer", "TrainReport"]

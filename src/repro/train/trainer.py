"""GNN trainer — thin compatibility shim over :class:`repro.gns.GNSEngine`.

The paper's mixed CPU-GPU training loop (§2.2) lives in the engine now
(``src/repro/gns/``): one declarative :class:`~repro.gns.EngineConfig`
drives the FeatureStore → sampler → EpochLoader/Prefetcher → compiled-step
wiring, and the engine's train step takes the device-resident per-group
home-shard vector (no static ``local_shard`` jit argument, no per-batch
retracing — the DP > 1 fast-path regime).

``GNNTrainer`` keeps the historical constructor/``train``/``evaluate``
surface by building the equivalent ``EngineConfig`` and delegating; state
(``params`` / ``opt_state`` / ``meter`` / ``store`` / ``sampler``) aliases
the engine's, so trainer-driven and engine-driven runs are the same run.
New code should use the engine directly — see README "Engine API" for the
kwarg → config-field migration table.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.sampler import SamplerConfig
from repro.gns.config import EngineConfig
from repro.gns.engine import GNSEngine, TrainReport
from repro.graph.datasets import GraphDataset
from repro.models import graphsage
from repro.optim.adam import AdamConfig

__all__ = ["GNNTrainer", "TrainReport"]


class GNNTrainer:
    """Shim: the historical kwarg surface, engine underneath."""

    def __init__(self, ds: GraphDataset, sampler_name: str,
                 sampler_cfg: Optional[SamplerConfig] = None,
                 model_cfg: Optional[graphsage.SageConfig] = None,
                 adam_cfg: Optional[AdamConfig] = None,
                 mesh=None, cache_shard_axis: Optional[str] = None,
                 seed: int = 0):
        scfg = sampler_cfg or SamplerConfig(batch_size=256)
        cfg = EngineConfig(sampler=sampler_name, sampling=scfg,
                           cache=scfg.cache,
                           optim=adam_cfg or AdamConfig(lr=3e-3),
                           seed=seed)
        self.engine = GNSEngine(cfg, dataset=ds, mesh=mesh,
                                model_cfg=model_cfg,
                                cache_shard_axis=cache_shard_axis)
        self.sampler_name = sampler_name

    # -- state aliases (read/write flows through to the engine) ------------
    @property
    def ds(self):
        return self.engine.ds

    @property
    def mesh(self):
        return self.engine.mesh

    @property
    def scfg(self):
        return self.engine.scfg

    @property
    def mcfg(self):
        return self.engine.mcfg

    @property
    def meter(self):
        return self.engine.meter

    @property
    def store(self):
        return self.engine.store

    @property
    def sampler(self):
        return self.engine.sampler

    @property
    def opt(self):
        return self.engine.opt

    @property
    def seed(self):
        return self.engine.seed

    @property
    def params(self):
        return self.engine.params

    @params.setter
    def params(self, v):
        self.engine.params = v

    @property
    def opt_state(self):
        return self.engine.opt_state

    @opt_state.setter
    def opt_state(self, v):
        self.engine.opt_state = v

    # -- the historical verbs ---------------------------------------------
    def run_batch(self, mb) -> tuple[float, float]:
        return self.engine.run_batch(mb)

    def train(self, epochs: int, max_batches: Optional[int] = None,
              prefetch: bool = False, eval_every: Optional[int] = None,
              eval_batches: int = 8) -> TrainReport:
        return self.engine.fit(epochs, max_batches=max_batches,
                               prefetch=prefetch, eval_every=eval_every,
                               eval_batches=eval_batches)

    def evaluate(self, idx: np.ndarray, num_batches: int = 8) -> float:
        return self.engine.evaluate(idx, num_batches=num_batches)

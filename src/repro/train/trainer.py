"""GNN trainer — the paper's mixed CPU-GPU training loop (§2.2), JAX edition.

Reproduces the six steps of §2.2 with explicit timing so the benchmark
harness can emit the paper's Fig. 1/2 runtime breakdown:

  1. sample minibatch (host, numpy)            -> meter.t_sample
  2. slice node features (host gather)          -> inside sampler._assemble
  3. copy sliced data to device                 -> meter.t_copy
  4-6. forward/backward/optimizer (jitted)      -> meter.t_compute

For GNS the cache refresh uploads the cached rows once per period
(meter.bytes_cache_fill); per-batch traffic then shrinks to the streamed
misses (meter.bytes_streamed) — the paper's central saving.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import CacheConfig
from repro.core.pipeline import EpochLoader, Prefetcher
from repro.core.sampler import GNSSampler, SamplerConfig, make_sampler
from repro.featurestore import FeatureStore, TrafficMeter
from repro.graph.datasets import GraphDataset
from repro.launch import sharding as shlib
from repro.models import graphsage
from repro.optim.adam import AdamConfig, AdamW


@dataclasses.dataclass
class TrainReport:
    epoch_times: list
    losses: list
    val_acc: list
    meter: TrafficMeter
    input_nodes_per_batch: float = 0.0
    cached_nodes_per_batch: float = 0.0
    isolated_per_batch: float = 0.0


class GNNTrainer:
    def __init__(self, ds: GraphDataset, sampler_name: str,
                 sampler_cfg: Optional[SamplerConfig] = None,
                 model_cfg: Optional[graphsage.SageConfig] = None,
                 adam_cfg: Optional[AdamConfig] = None,
                 mesh=None, cache_shard_axis: Optional[str] = None,
                 seed: int = 0):
        """``mesh`` (+ optional ``cache_shard_axis``) makes the feature
        store shard-aware: each refresh uploads only each device's own
        shard of the generation table instead of replicating it.  The
        train/eval steps then run under that mesh scope, and a fused model
        config inherits the store's shard axis, so the input layer reads the
        table via the per-shard kernel + psum instead of an XLA all-gather
        of the whole table every step (pair the mesh with
        ``SageConfig(input_impl="fused")`` — the "where" input path cannot
        exploit the sharded layout)."""
        self.ds = ds
        self.sampler_name = sampler_name
        self.mesh = mesh
        self.scfg = sampler_cfg or SamplerConfig(batch_size=256)
        self.mcfg = model_cfg or graphsage.SageConfig(
            feat_dim=ds.feat_dim, num_classes=ds.num_classes)
        self.meter = TrafficMeter()
        if sampler_name == "gns":
            # the facade owns all three feature tiers + the refresh lifecycle
            self.store = FeatureStore(
                ds.features, ds.graph, self.scfg.cache, train_idx=ds.train_idx,
                mesh=mesh, shard_axis=cache_shard_axis,
                meter=self.meter, importance_mode=self.scfg.importance_mode,
                build_adjacency=True, seed=seed)
        else:
            self.store = None
        if (self.store is not None and mesh is not None
                and self.mcfg.input_impl == "fused"
                and self.mcfg.cache_shard_axis is None):
            # fused steps must psum over the SAME axis the upload shards on
            self.mcfg = dataclasses.replace(
                self.mcfg, cache_shard_axis=self.store.shard_axis)
        self.sampler = make_sampler(sampler_name, ds.graph, self.scfg,
                                    ds.features, ds.labels,
                                    train_idx=ds.train_idx, store=self.store)
        self.params = graphsage.init_params(jax.random.PRNGKey(seed), self.mcfg)
        self.opt = AdamW(adam_cfg or AdamConfig(lr=3e-3))
        self.opt_state = self.opt.init(self.params)
        self.seed = seed
        self._dummy_cache = graphsage.dummy_cache_table(ds.feat_dim)

        mcfg = self.mcfg
        # locality fast path: honor MiniBatch.local_shard only when the fused
        # sharded input path is active AND the mesh has a single DP group —
        # the host assembles one batch per step, so with DP > 1 the groups
        # would need per-group home shards inside one compiled step (the
        # dry-run's regime, not the in-process trainer's).
        dp = 1
        if mesh is not None:
            dp = int(np.prod([mesh.shape[a] for a in mesh.axis_names
                              if a != self.mcfg.cache_shard_axis] or [1]))
        self._use_local_fast_path = (
            self.mcfg.input_impl == "fused" and mesh is not None
            and self.mcfg.cache_shard_axis in getattr(mesh, "axis_names", ())
            and dp == 1)

        @partial(jax.jit, static_argnames=("local_shard",))
        def train_step(params, opt_state, batch, cache_table,
                       local_shard=None):
            (loss, acc), grads = jax.value_and_grad(
                graphsage.loss_fn, has_aux=True)(params, batch, cache_table,
                                                 mcfg, local_shard)
            params, opt_state = self.opt.update(grads, opt_state, params)
            return params, opt_state, loss, acc

        @jax.jit
        def eval_step(params, batch, cache_table):
            return graphsage.loss_fn(params, batch, cache_table, mcfg)

        self._train_step = train_step
        self._eval_step = eval_step

    # ------------------------------------------------------------------
    def _cache_table(self, mb=None):
        """The device table the batch's slots index into.

        Each MiniBatch carries the :class:`Generation` it was assembled
        against, so even when an async refresh swaps the live generation
        between sampling and stepping, the step reads the table matching the
        batch's slot map — a swap can never tear a batch.
        """
        gen = getattr(mb, "cache_gen", None) if mb is not None else None
        if gen is not None:
            return gen.table
        return self._dummy_cache

    def run_batch(self, mb) -> tuple[float, float]:
        m = self.meter
        t0 = time.perf_counter()
        dev_batch = jax.device_put(mb.device)
        m.t_copy += time.perf_counter() - t0
        m.add_batch(mb.bytes_streamed)
        t0 = time.perf_counter()
        ls = mb.local_shard if self._use_local_fast_path else None
        with shlib.use_mesh(self.mesh):     # no-op scope when mesh is None
            self.params, self.opt_state, loss, acc = self._train_step(
                self.params, self.opt_state, dev_batch, self._cache_table(mb),
                local_shard=ls)
        loss = float(loss)
        m.t_compute += time.perf_counter() - t0
        return loss, float(acc)

    def train(self, epochs: int, max_batches: Optional[int] = None,
              prefetch: bool = False, eval_every: Optional[int] = None,
              eval_batches: int = 8) -> TrainReport:
        loader = EpochLoader(self.sampler, self.ds.train_idx, seed=self.seed,
                             max_batches=max_batches)
        report = TrainReport([], [], [], self.meter)
        n_inputs, n_cached, n_iso, n_b = 0, 0, 0, 0
        for ep in range(epochs):
            t_ep = time.perf_counter()
            # epoch start (cache refresh happens in sampler.start_epoch)
            it = loader.epoch(ep)
            if prefetch:
                it = Prefetcher(it, depth=2)
            else:
                it = self._timed(it)
            ep_losses = []
            for mb in it:
                loss, _ = self.run_batch(mb)
                ep_losses.append(loss)
                n_inputs += mb.num_input
                n_cached += mb.num_cached
                n_iso += mb.num_isolated
                n_b += 1
            report.epoch_times.append(time.perf_counter() - t_ep)
            report.losses.append(float(np.mean(ep_losses)) if ep_losses else float("nan"))
            if eval_every and (ep + 1) % eval_every == 0:
                report.val_acc.append(self.evaluate(self.ds.val_idx, eval_batches))
        if n_b:
            report.input_nodes_per_batch = n_inputs / n_b
            report.cached_nodes_per_batch = n_cached / n_b
            report.isolated_per_batch = n_iso / n_b
        return report

    def _timed(self, it):
        """Wrap a batch iterator, attributing wall time to meter.t_sample.

        The store self-reports the host gather inside ``sample`` to
        meter.t_slice and (sync-mode) cache builds inside ``start_epoch``
        to meter.t_refresh; subtract both deltas so each second lands in
        exactly one bucket.  Clamped at zero: an async build finishing
        during a short window could otherwise over-subtract.
        """
        it = iter(it)
        while True:
            t0 = time.perf_counter()
            slice0 = self.meter.t_slice
            refresh0 = self.meter.t_refresh
            try:
                mb = next(it)
            except StopIteration:
                return
            elapsed = time.perf_counter() - t0
            self.meter.t_sample += max(
                elapsed - (self.meter.t_slice - slice0)
                - (self.meter.t_refresh - refresh0), 0.0)
            yield mb

    def evaluate(self, idx: np.ndarray, num_batches: int = 8) -> float:
        """Micro-F1 (= accuracy for single-label tasks, as in the paper)."""
        b = self.scfg.batch_size
        idx = np.asarray(idx)
        if len(idx) < b:  # pad by wrapping; mask handles duplicates' weight
            idx = np.concatenate([idx, idx[: b - len(idx)]])
        rng = np.random.default_rng(1234)
        if isinstance(self.sampler, GNSSampler):
            self.sampler.ensure_cache(rng)
        if self.store is not None:
            self.store.record = False   # eval must not skew training metrics
                                        # or the adaptive policy's miss EMA
        correct, total = 0.0, 0.0
        try:
            for i in range(num_batches):
                lo = (i * b) % (len(idx) - b + 1)
                targets = idx[lo:lo + b]
                mb = self.sampler.sample(targets, rng)
                with shlib.use_mesh(self.mesh):
                    _, acc = self._eval_step(self.params,
                                             jax.device_put(mb.device),
                                             self._cache_table(mb))
                correct += float(acc)
                total += 1.0
        finally:
            if self.store is not None:
                self.store.record = True
        return correct / max(total, 1.0)

"""Placement-aware request routing for the serve fabric.

Worker ``w`` serves DP group ``w``, whose fused lookups resolve locally on
home shard ``home_shard(w, n_shards)`` — so the worker that should serve a
request is the one whose home shard owns the most of the request's cached
rows.  :class:`Router` scores each healthy worker by that ownership count
against the store's :class:`~repro.featurestore.RoutingTable` (re-adopted
at every generation swap) and picks the argmax, breaking ties toward the
least-loaded worker.

The feedback loop that makes this converge: routed requests land on their
worker's DP-group histogram (``TrafficMeter.observe_group`` inside the
serving scope), the placement solver's next generation moves each hot row
to the home shard of the group that requested it most, and the refreshed
routing table then scores those rows as local to that worker — skewed
per-tenant traffic ends up pinned worker-local without anyone declaring a
partition up front.

When there is no table yet (cold store, meshless engine, or
``routing="spread"``) the router degrades to least-loaded dispatch.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Optional, Sequence

import numpy as np

from repro.analysis import guarded_by
from repro.featurestore import RoutingTable, home_shard


@dataclasses.dataclass(frozen=True)
class RouteDecision:
    """Where one request goes, and why (feeds the per-route meter)."""
    worker: int
    known: int = 0          # ids with a known owner shard
    local: int = 0          # of those, ids the chosen worker's shard owns
    fallback: bool = False  # True = least-loaded dispatch (no table/votes)


@guarded_by("_rlock", "_routed_load", writes_only=("_rtable",))
class Router:
    """Pick a worker per request: ownership vote, least-loaded fallback.

    ``_rtable`` is swapped whole under ``_rlock`` and read as a lock-free
    snapshot (the frozen :class:`RoutingTable` is immutable); the
    per-worker dispatch counters live under the lock.
    """

    def __init__(self, worker_groups: Sequence[int], n_shards: int,
                 table: Optional[RoutingTable] = None,
                 mode: str = "locality"):
        assert mode in ("locality", "spread"), mode
        self._rlock = threading.Lock()
        self._rtable = table
        self.mode = mode
        self.worker_groups = tuple(int(g) for g in worker_groups)
        self.n_shards = max(int(n_shards), 1)
        self.homes = tuple(home_shard(g, self.n_shards)
                           for g in self.worker_groups)
        self._routed_load = np.zeros(len(self.worker_groups), dtype=np.int64)

    # ------------------------------------------------------------------
    def adopt(self, table: Optional[RoutingTable]) -> None:
        """Swap in a freshly derived table (generation-swap hook)."""
        with self._rlock:
            self._rtable = table

    @property
    def table_version(self) -> int:
        t = self._rtable
        return t.version if t is not None else -1

    # ------------------------------------------------------------------
    def route(self, node_ids: np.ndarray,
              healthy: Sequence[int]) -> RouteDecision:
        """Choose one of ``healthy`` (worker indices) for this request."""
        assert healthy, "route() with no healthy workers"
        table = self._rtable             # lock-free snapshot (writes_only)
        if (self.mode == "locality" and table is not None
                and self.n_shards > 1):
            owners = table.owners(node_ids)
            known = int((owners >= 0).sum())
            if known:
                votes = [int((owners == self.homes[w]).sum())
                         for w in healthy]
                top = max(votes)
                if top > 0:
                    with self._rlock:
                        tied = [w for w, v in zip(healthy, votes)
                                if v == top]
                        w = min(tied, key=lambda i: (self._routed_load[i], i))
                        self._routed_load[w] += 1
                    return RouteDecision(worker=w, known=known, local=top)
        # fallback: least-loaded healthy worker (deterministic tie-break)
        with self._rlock:
            w = min(healthy, key=lambda i: (self._routed_load[i], i))
            self._routed_load[w] += 1
        return RouteDecision(worker=w, fallback=True)

    def loads(self) -> np.ndarray:
        """Requests dispatched per worker so far (observability)."""
        with self._rlock:
            return self._routed_load.copy()

"""Serving-side accounting: per-request latency + a TrafficMeter view.

The training loop's :class:`~repro.featurestore.meter.TrafficMeter` answers
"where did the bytes go"; a serving tier additionally has to answer "where
did the *milliseconds* go, per request".  :class:`ServeMeter` owns both:

* ``traffic`` — a dedicated :class:`TrafficMeter` the feature store routes
  serving lookups into (``FeatureStore.serving`` scope), so the serving
  cache-hit rate, streamed bytes and cross-shard lanes are readable without
  untangling them from training traffic;
* per-request latency records split into **queue wait** (submit → the
  micro-batcher dequeues it into a batch) and **compute** (sample + step +
  readback for the batch it rode), with p50/p99 over a bounded rolling
  window — globally AND per tenant;
* admission/outcome counters (submitted / rejected / expired / served /
  deadline_miss / errors) — the backpressure ledger, per tenant too, so
  "whose burst got shed" is a direct read;
* per-route counters (ids with a known owner shard, ids routed to the shard
  that owns them, fallback dispatches, failovers, retries) — the fabric's
  locality + failover ledger;
* the **cache-hit trajectory**: per-batch device-tier hit fraction, the
  signal that shows the adaptive policy converging onto the inference hot
  set after a serving-driven refresh (`bench_serve.run_trajectory`).

One meter may be shared by a whole worker fleet (``ServeFabric``), so every
mutable field is written under ``lock`` via the ``observe_*`` methods — the
single-server PR 5 "worker-only counters stay lock-free" carve-out is gone.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Deque, Optional

import numpy as np

from repro.analysis import guarded_by, holds_lock
from repro.featurestore.meter import TrafficMeter


@dataclasses.dataclass
class BatchRecord:
    """One served micro-batch (host-side bookkeeping, never traced)."""
    bucket: int                 # padded batch size shipped to the device
    n_requests: int             # requests coalesced into it
    n_ids: int                  # real target rows (<= bucket)
    compute_s: float            # sample + compiled step + readback
    cache_version: int          # generation the batch was pinned to (-1 none)
    hit_fraction: float         # device-tier hits / requested input nodes


class TenantStats:
    """One tenant's slice of the ledger (mutated only under the owning
    :class:`ServeMeter`'s lock — never annotated or locked itself)."""

    # counter names are deliberately n_-prefixed: the bare names belong to
    # ServeMeter's @guarded_by annotation, and the analyzer's external-access
    # rule keys on attr-name uniqueness across annotated classes
    __slots__ = ("n_submitted", "n_rejected", "n_served", "n_expired",
                 "n_deadline_miss", "n_retries", "queue_wait", "compute",
                 "total")

    def __init__(self, latency_window: int):
        self.n_submitted = 0
        self.n_rejected = 0
        self.n_served = 0
        self.n_expired = 0
        self.n_deadline_miss = 0
        self.n_retries = 0          # failover re-routes of this tenant's
                                    # requests
        self.queue_wait: Deque[float] = collections.deque(
            maxlen=latency_window)
        self.compute: Deque[float] = collections.deque(maxlen=latency_window)
        self.total: Deque[float] = collections.deque(maxlen=latency_window)

    def as_dict(self) -> dict:
        out = {"submitted": self.n_submitted, "rejected": self.n_rejected,
               "served": self.n_served, "expired": self.n_expired,
               "deadline_miss": self.n_deadline_miss,
               "retries": self.n_retries}
        out.update(_latency_percentiles(
            (("queue_wait", self.queue_wait), ("compute", self.compute),
             ("total", self.total))))
        return out


def _latency_percentiles(named_bufs) -> dict:
    out = {}
    for name, buf in named_bufs:
        if buf:
            arr = np.asarray(buf, dtype=np.float64)
            out[f"{name}_p50_ms"] = round(
                float(np.percentile(arr, 50)) * 1e3, 3)
            out[f"{name}_p99_ms"] = round(
                float(np.percentile(arr, 99)) * 1e3, 3)
        else:
            out[f"{name}_p50_ms"] = out[f"{name}_p99_ms"] = None
    return out


# NOTE: ``padded_rows`` is deliberately missing from the annotation — the
# name would collide with the unrelated ``FeatureStore.padded_rows``
# staticmethod in the analyzer's attr-unique external-access rule.  It is
# still only ever written under ``lock`` (observe_batch).
@guarded_by("lock", "submitted", "rejected", "expired", "served",
            "deadline_miss", "errors", "refresh_failures", "batches",
            "real_rows", "swaps_observed", "routed_known_ids",
            "routed_local_ids", "route_fallbacks", "failovers",
            "retries_total", "tenant_stats", "worker_batches",
            "remote_worker_stats")
class ServeMeter:
    """Latency + traffic accounting for one server or one worker fleet.

    Every counter may be written from arbitrary threads (client submit
    paths, N fabric workers, the watchdog), so ALL mutation goes through
    ``observe_*`` methods that take ``lock``; readers (``snapshot``,
    ``percentiles``) lock too.  The exception is ``traffic``: the fabric
    serializes sampling under its sample lock, so the TrafficMeter keeps
    its lock-free single-writer contract.
    """

    def __init__(self, latency_window: int = 2048):
        self.traffic = TrafficMeter()       # serving-side tier view
        self.lock = threading.Lock()
        self.latency_window = latency_window
        self.submitted = 0
        self.rejected = 0                   # admission control (queue full)
        self.expired = 0                    # deadline passed while queued
        self.served = 0
        self.deadline_miss = 0              # served, but past its deadline
        self.errors = 0
        self.refresh_failures = 0           # failed serving-driven builds
        self.batches = 0
        self.padded_rows = 0                # sum of buckets shipped
        self.real_rows = 0                  # sum of real target rows
        self.swaps_observed = 0             # generation adoptions mid-stream
        # fabric routing/failover ledger
        self.routed_known_ids = 0           # ids with a known owner shard
        self.routed_local_ids = 0           # of those, routed to their owner
        self.route_fallbacks = 0            # least-loaded dispatches
        self.failovers = 0                  # workers taken out of rotation
        self.retries_total = 0              # requests re-routed after a
                                            # stall/death
        self.tenant_stats: dict = {}        # name -> TenantStats
        self.worker_batches: dict = {}      # worker index -> batches served
        self.remote_worker_stats: dict = {} # worker index -> endpoint-side
                                            # stats dict (tcp transport:
                                            # absorbed via STATS frames so
                                            # per-tenant ledgers aggregate
                                            # across hosts)
        self._queue_wait: Deque[float] = collections.deque(maxlen=latency_window)
        self._compute: Deque[float] = collections.deque(maxlen=latency_window)
        self._total: Deque[float] = collections.deque(maxlen=latency_window)
        self._rpc_wait: Deque[float] = collections.deque(maxlen=latency_window)
                                            # tcp transport: per-request wire
                                            # + (de)serialization time — the
                                            # RPC-vs-compute latency split
        self.batch_log: Deque[BatchRecord] = collections.deque(maxlen=latency_window)

    # ------------------------------------------------------------------
    @holds_lock("lock")
    def _tenant_locked(self, name: str) -> TenantStats:
        ts = self.tenant_stats.get(name)
        if ts is None:
            ts = self.tenant_stats[name] = TenantStats(
                min(self.latency_window, 512))
        return ts

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def observe_submit(self, tenant: Optional[str] = None) -> None:
        with self.lock:
            self.submitted += 1
            if tenant is not None:
                self._tenant_locked(tenant).n_submitted += 1

    def observe_reject(self, tenant: Optional[str] = None) -> None:
        with self.lock:
            self.rejected += 1
            if tenant is not None:
                self._tenant_locked(tenant).n_rejected += 1

    def observe_expired(self, queue_wait_s: float,
                        tenant: Optional[str] = None) -> None:
        with self.lock:
            self.expired += 1
            if tenant is not None:
                ts = self._tenant_locked(tenant)
                ts.n_expired += 1
                ts.queue_wait.append(queue_wait_s)

    def observe_error(self, n_requests: int = 1) -> None:
        with self.lock:
            self.errors += n_requests

    # ------------------------------------------------------------------
    # completion
    # ------------------------------------------------------------------
    def observe_request(self, queue_wait_s: float, compute_s: float,
                        total_s: float, tenant: Optional[str] = None,
                        late: bool = False,
                        rpc_s: Optional[float] = None) -> None:
        with self.lock:
            self.served += 1
            if late:
                self.deadline_miss += 1
            if rpc_s is not None:
                self._rpc_wait.append(rpc_s)
            self._queue_wait.append(queue_wait_s)
            self._compute.append(compute_s)
            self._total.append(total_s)
            if tenant is not None:
                ts = self._tenant_locked(tenant)
                ts.n_served += 1
                if late:
                    ts.n_deadline_miss += 1
                ts.queue_wait.append(queue_wait_s)
                ts.compute.append(compute_s)
                ts.total.append(total_s)

    def observe_batch(self, rec: BatchRecord,
                      worker: Optional[int] = None) -> None:
        with self.lock:
            self.batches += 1
            self.padded_rows += rec.bucket
            self.real_rows += rec.n_ids
            self.batch_log.append(rec)
            if worker is not None:
                self.worker_batches[worker] = \
                    self.worker_batches.get(worker, 0) + 1

    # ------------------------------------------------------------------
    # fabric: routing / failover / generation events
    # ------------------------------------------------------------------
    def observe_route(self, known: int, local: int,
                      fallback: bool = False) -> None:
        with self.lock:
            self.routed_known_ids += known
            self.routed_local_ids += local
            if fallback:
                self.route_fallbacks += 1

    def observe_failover(self) -> None:
        with self.lock:
            self.failovers += 1

    def observe_retry(self, tenant: Optional[str] = None) -> None:
        with self.lock:
            self.retries_total += 1
            if tenant is not None:
                self._tenant_locked(tenant).n_retries += 1

    def observe_swap(self) -> None:
        with self.lock:
            self.swaps_observed += 1

    def observe_refresh_failure(self) -> None:
        with self.lock:
            self.refresh_failures += 1

    def observe_remote_stats(self, worker: int, stats: dict) -> None:
        """Absorb one endpoint's STATS reply (tcp transport): the remote
        per-tenant ledger + wire counters, keyed by worker index, so a
        cross-host fleet still has ONE aggregation point."""
        with self.lock:
            self.remote_worker_stats[worker] = dict(stats)

    # ------------------------------------------------------------------
    # readers
    # ------------------------------------------------------------------
    def batch_count(self) -> int:
        with self.lock:
            return self.batches

    @property
    def cache_hit_rate(self) -> float:
        """Device-tier hit rate over ALL serving lookups so far."""
        return self.traffic.tier("device").hit_rate

    def hit_trajectory(self) -> list:
        """Per-batch device-tier hit fraction, oldest first."""
        with self.lock:
            return [r.hit_fraction for r in self.batch_log]

    def generation_trail(self) -> list:
        """Per-batch pinned cache version, oldest first (monotonic by the
        adoption contract — asserted in tests/test_gns_server.py)."""
        with self.lock:
            return [r.cache_version for r in self.batch_log]

    @property
    def fill_fraction(self) -> float:
        """Real rows / padded rows shipped — micro-batching efficiency."""
        with self.lock:
            rows = self.real_rows
            padded = self.padded_rows
        return rows / padded if padded else 0.0

    @property
    def route_local_fraction(self) -> float:
        """Of the ids with a known owner shard, the fraction that was routed
        to the worker whose home shard owns them."""
        with self.lock:
            known, local = self.routed_known_ids, self.routed_local_ids
        return local / known if known else 0.0

    def percentiles(self) -> dict:
        with self.lock:
            named = [("queue_wait", self._queue_wait),
                     ("compute", self._compute),
                     ("total", self._total)]
            if self._rpc_wait:
                named.append(("rpc_wait", self._rpc_wait))
            return _latency_percentiles(named)

    def tenant_snapshot(self) -> dict:
        """Per-tenant ledger: counters + p50/p99, JSON-safe."""
        with self.lock:
            return {name: ts.as_dict()
                    for name, ts in sorted(self.tenant_stats.items())}

    def snapshot(self) -> dict:
        """JSON-safe summary (what `bench_serve` and the example print)."""
        with self.lock:
            real, padded = self.real_rows, self.padded_rows
            known, local = self.routed_known_ids, self.routed_local_ids
            out = {
                "submitted": self.submitted, "served": self.served,
                "rejected": self.rejected, "expired": self.expired,
                "deadline_miss": self.deadline_miss, "errors": self.errors,
                "refresh_failures": self.refresh_failures,
                "batches": self.batches,
                "fill_fraction": round(real / padded if padded else 0.0, 4),
                "swaps_observed": self.swaps_observed,
                **_latency_percentiles(
                    (("queue_wait", self._queue_wait),
                     ("compute", self._compute),
                     ("total", self._total))),
            }
            if self._rpc_wait:
                out.update(_latency_percentiles(
                    (("rpc_wait", self._rpc_wait),)))
            if self.remote_worker_stats:
                out["remote"] = {str(k): v for k, v in sorted(
                    self.remote_worker_stats.items())}
            if self.tenant_stats:
                out["tenants"] = {name: ts.as_dict()
                                  for name, ts in
                                  sorted(self.tenant_stats.items())}
            if known or self.route_fallbacks or self.failovers:
                out["routing"] = {
                    "route_local_fraction":
                        round(local / known if known else 0.0, 4),
                    "routed_known_ids": known,
                    "route_fallbacks": self.route_fallbacks,
                    "failovers": self.failovers,
                    "retries": self.retries_total,
                    "worker_batches": dict(sorted(
                        self.worker_batches.items())),
                }
        out["cache_hit_rate"] = round(self.cache_hit_rate, 4)
        out["traffic"] = self.traffic.breakdown()
        return out

"""Serving-side accounting: per-request latency + a TrafficMeter view.

The training loop's :class:`~repro.featurestore.meter.TrafficMeter` answers
"where did the bytes go"; a serving tier additionally has to answer "where
did the *milliseconds* go, per request".  :class:`ServeMeter` owns both:

* ``traffic`` — a dedicated :class:`TrafficMeter` the feature store routes
  serving lookups into (``FeatureStore.serving`` scope), so the serving
  cache-hit rate, streamed bytes and cross-shard lanes are readable without
  untangling them from training traffic;
* per-request latency records split into **queue wait** (submit → the
  micro-batcher dequeues it into a batch) and **compute** (sample + step +
  readback for the batch it rode), with p50/p99 over a bounded rolling
  window;
* admission/outcome counters (submitted / rejected / expired / served /
  deadline_miss / errors) — the backpressure ledger;
* the **cache-hit trajectory**: per-batch device-tier hit fraction, the
  signal that shows the adaptive policy converging onto the inference hot
  set after a serving-driven refresh (`bench_serve.run_trajectory`).
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Deque, Optional

import numpy as np

from repro.analysis import guarded_by
from repro.featurestore.meter import TrafficMeter


@dataclasses.dataclass
class BatchRecord:
    """One served micro-batch (host-side bookkeeping, never traced)."""
    bucket: int                 # padded batch size shipped to the device
    n_requests: int             # requests coalesced into it
    n_ids: int                  # real target rows (<= bucket)
    compute_s: float            # sample + compiled step + readback
    cache_version: int          # generation the batch was pinned to (-1 none)
    hit_fraction: float         # device-tier hits / requested input nodes


@guarded_by("lock", "submitted", "rejected")
class ServeMeter:
    """Latency + traffic accounting for one :class:`GNSServer`.

    ``submitted``/``rejected`` are written from arbitrary client threads
    (``GNSServer.submit``) and so live under ``lock`` — for reads too:
    ``snapshot()`` runs on whatever thread asks for it.  Every other
    counter is worker-only by construction and stays lock-free.
    """

    def __init__(self, latency_window: int = 2048):
        self.traffic = TrafficMeter()       # serving-side tier view
        self.lock = threading.Lock()        # guards the ADMISSION counters:
                                            # submit() increments them from
                                            # arbitrary client threads (all
                                            # other counters are worker-only)
        self.submitted = 0
        self.rejected = 0                   # admission control (queue full)
        self.expired = 0                    # deadline passed while queued
        self.served = 0
        self.deadline_miss = 0              # served, but past its deadline
        self.errors = 0
        self.refresh_failures = 0           # failed serving-driven builds
        self.batches = 0
        self.padded_rows = 0                # sum of buckets shipped
        self.real_rows = 0                  # sum of real target rows
        self.swaps_observed = 0             # generation adoptions mid-stream
        self._queue_wait: Deque[float] = collections.deque(maxlen=latency_window)
        self._compute: Deque[float] = collections.deque(maxlen=latency_window)
        self._total: Deque[float] = collections.deque(maxlen=latency_window)
        self.batch_log: Deque[BatchRecord] = collections.deque(maxlen=latency_window)

    # ------------------------------------------------------------------
    def observe_request(self, queue_wait_s: float, compute_s: float,
                        total_s: float) -> None:
        self._queue_wait.append(queue_wait_s)
        self._compute.append(compute_s)
        self._total.append(total_s)

    def observe_batch(self, rec: BatchRecord) -> None:
        self.batches += 1
        self.padded_rows += rec.bucket
        self.real_rows += rec.n_ids
        self.batch_log.append(rec)

    # ------------------------------------------------------------------
    @property
    def cache_hit_rate(self) -> float:
        """Device-tier hit rate over ALL serving lookups so far."""
        return self.traffic.tier("device").hit_rate

    def hit_trajectory(self) -> list:
        """Per-batch device-tier hit fraction, oldest first."""
        return [r.hit_fraction for r in self.batch_log]

    def generation_trail(self) -> list:
        """Per-batch pinned cache version, oldest first (monotonic by the
        adoption contract — asserted in tests/test_gns_server.py)."""
        return [r.cache_version for r in self.batch_log]

    @property
    def fill_fraction(self) -> float:
        """Real rows / padded rows shipped — micro-batching efficiency."""
        return self.real_rows / self.padded_rows if self.padded_rows else 0.0

    def percentiles(self) -> dict:
        out = {}
        for name, buf in (("queue_wait", self._queue_wait),
                          ("compute", self._compute),
                          ("total", self._total)):
            if buf:
                arr = np.asarray(buf, dtype=np.float64)
                out[f"{name}_p50_ms"] = round(float(np.percentile(arr, 50)) * 1e3, 3)
                out[f"{name}_p99_ms"] = round(float(np.percentile(arr, 99)) * 1e3, 3)
            else:
                out[f"{name}_p50_ms"] = out[f"{name}_p99_ms"] = None
        return out

    def snapshot(self) -> dict:
        """JSON-safe summary (what `bench_serve` and the example print)."""
        with self.lock:   # admission counters race client submit() threads
            submitted, rejected = self.submitted, self.rejected
        return {
            "submitted": submitted, "served": self.served,
            "rejected": rejected, "expired": self.expired,
            "deadline_miss": self.deadline_miss, "errors": self.errors,
            "refresh_failures": self.refresh_failures,
            "batches": self.batches,
            "fill_fraction": round(self.fill_fraction, 4),
            "cache_hit_rate": round(self.cache_hit_rate, 4),
            "swaps_observed": self.swaps_observed,
            **self.percentiles(),
            "traffic": self.traffic.breakdown(),
        }

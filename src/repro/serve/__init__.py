"""GNS serving subsystem: persistent request loop off the live cache.

Public surface:

* :class:`GNSServer` — bounded request queue + single-worker serving loop
  over a :class:`~repro.gns.GNSEngine`; ``submit()`` / ``infer()`` /
  ``start()`` / ``stop()`` (or use it as a context manager).
* :class:`ServeConfig` — the declarative sub-block (``EngineConfig.serve``):
  size buckets, queue bound, batching window, deadlines, serving-driven
  refresh cadence.
* :class:`MicroBatcher` — dynamic micro-batching into size buckets (one
  compiled inference step per bucket, zero steady-state recompilation).
* :class:`ServeMeter` / :class:`BatchRecord` — per-request latency
  (queue wait vs compute, p50/p99), admission/outcome counters, the
  serving-side :class:`~repro.featurestore.TrafficMeter` view and the
  cache-hit trajectory.
* :class:`ServeResult` / :class:`ServeFuture` and the control-flow errors
  :class:`QueueFull` / :class:`ServerClosed`.
* :class:`ServeFabric` — the multi-tenant, multi-worker fleet over ONE
  engine: per-tenant weighted-fair scheduling (:class:`FairScheduler`),
  placement-aware routing (:class:`Router`), watchdog failover
  (:class:`WorkerDown` on exhausted retries), per-tenant/per-route
  meter breakdowns.  Configured by ``FabricConfig``
  (``EngineConfig.serve.fabric``); built via ``engine.serve_fabric()``.

Quickstart::

    from repro.gns import EngineConfig, GNSEngine

    engine = GNSEngine(EngineConfig.preset("quickstart"))
    with engine.serve() as server:
        fut = server.submit(node_ids)          # micro-batched + bucketed
        logits = fut.result(timeout=10).logits
    print(server.meter.snapshot())             # p50/p99, hit rate, rejects
"""
from repro.gns.config import FabricConfig, ServeConfig, TenantConfig
from repro.serve.batcher import MicroBatcher
from repro.serve.fabric import (FabricWorker, ServeFabric, WorkerDown,
                                WorkerKilled)
from repro.serve.metrics import BatchRecord, ServeMeter, TenantStats
from repro.serve.router import RouteDecision, Router
from repro.serve.server import (GNSServer, QueueFull, ServeFuture,
                                ServeResult, ServerClosed)
from repro.serve.tenancy import FairScheduler, UnknownTenant

__all__ = [
    "GNSServer", "ServeConfig", "MicroBatcher",
    "ServeMeter", "BatchRecord", "TenantStats",
    "ServeResult", "ServeFuture", "QueueFull", "ServerClosed",
    "ServeFabric", "FabricWorker", "FabricConfig", "TenantConfig",
    "FairScheduler", "UnknownTenant",
    "Router", "RouteDecision", "WorkerDown", "WorkerKilled",
]

"""GNSServer — the persistent GNS serving loop.

Turns ``GNSEngine.infer()`` from a one-shot call into a production-shaped
request loop over the SAME machinery training uses:

* requests (node-id chunks, optional deadlines) enter a **bounded queue**
  (admission control: a full queue rejects, it never silently grows);
* a single worker thread pulls **dynamically micro-batched**, size-bucketed
  batches (:class:`~repro.serve.batcher.MicroBatcher`) and runs them through
  the engine's compiled inference step — one jit entry per bucket, zero
  recompilation in steady state;
* every batch **rides the live cache generation safely**: the sampled
  minibatch pins the generation it was assembled against
  (``MiniBatch.cache_gen``), so an async refresh swapping underneath can
  never tear an in-flight request — its results are bitwise-identical to a
  no-swap run (tests/test_gns_server.py);
* serving lookups run inside ``FeatureStore.serving(meter.traffic)``:
  tier/time accounting lands on the serving-side meter while the adaptive
  policy's EMA and the placement histograms keep observing — so with
  ``ServeConfig.refresh_every`` set, periodic async refreshes re-draw the
  cache toward the *inference* hot set (the paper's cache loop, closed for
  a workload it never considered);
* per-request latency (queue wait vs compute) and the cache-hit trajectory
  are readable from :class:`~repro.serve.metrics.ServeMeter` at any time.

Swap points mirror the training loader (`core/pipeline.EpochLoader`): the
worker polls ``swap_if_ready`` between batches and the bucket samplers adopt
monotonically — never while a batch is being assembled or computed.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional, Sequence

import numpy as np

from repro.analysis import guarded_by
from repro.serve.batcher import MicroBatcher
from repro.serve.metrics import BatchRecord, ServeMeter


class QueueFull(RuntimeError):
    """Admission control: the bounded request queue refused the request."""


class ServerClosed(RuntimeError):
    """submit() after stop() (or before start())."""


@dataclasses.dataclass
class ServeResult:
    """One completed request."""
    logits: Optional[np.ndarray]    # [n_ids, classes] f32; None unless ok
    status: str                     # "ok" | "expired" | "error"
    queue_wait_s: float = 0.0       # submit -> dequeued into a batch
    compute_s: float = 0.0          # its batch's sample + step + readback
    total_s: float = 0.0            # submit -> completion
    bucket: int = 0                 # padded batch size it rode (0 if none)
    cache_version: int = -1         # generation its batch was pinned to


@guarded_by("_lock", writes_only=("_result", "_err"))
class ServeFuture:
    """Completion handle for one submitted request.

    Completion is first-wins: a second ``_complete``/``_fail`` is ignored
    (a request is served OR failed, never re-resolved — defense in depth
    for shutdown edges).  ``_result``/``_err`` are written under ``_lock``;
    ``result()`` reads them lock-free, which is safe because ``_ev.set()``
    happens-after the write and ``_ev.wait()`` happens-before the read."""

    def __init__(self):
        self._ev = threading.Event()
        self._lock = threading.Lock()
        self._result: Optional[ServeResult] = None
        self._err: Optional[BaseException] = None

    def done(self) -> bool:
        return self._ev.is_set()

    def result(self, timeout: Optional[float] = None) -> ServeResult:
        if not self._ev.wait(timeout):
            raise TimeoutError("request not completed within timeout")
        if self._err is not None:
            raise self._err
        return self._result

    # server-side completion
    def _complete(self, result: ServeResult) -> None:
        with self._lock:
            if self._ev.is_set():
                return
            self._result = result
            self._ev.set()

    def _fail(self, err: BaseException) -> None:
        with self._lock:
            if self._ev.is_set():
                return
            self._err = err
            self._ev.set()


@dataclasses.dataclass
class _Pending:
    """A queued request (internal)."""
    node_ids: np.ndarray
    future: ServeFuture
    t_submit: float                   # monotonic
    deadline: Optional[float]         # absolute monotonic, None = unbounded


@guarded_by("_state_lock", writes_only=("refresh_error", "_accepting"))
class GNSServer:
    """The persistent serving loop over one :class:`~repro.gns.GNSEngine`.

    Usage::

        server = engine.serve()            # or GNSServer(engine, serve_cfg)
        with server:                       # start()/stop() pair
            fut = server.submit(node_ids)  # raises QueueFull when saturated
            res = fut.result(timeout=10)   # res.logits: [n_ids, classes]
        print(server.meter.snapshot())     # p50/p99, hit rate, rejects ...
    """

    def __init__(self, engine, cfg=None):
        if cfg is None:
            cfg = engine.cfg.serve
        self.engine = engine
        self.cfg = cfg
        self.meter = ServeMeter(latency_window=cfg.latency_window)
        self.batcher = MicroBatcher(cfg.buckets,
                                    max_wait_s=cfg.max_wait_ms * 1e-3,
                                    max_queue=cfg.max_queue)
        self._rng = np.random.default_rng(engine.cfg.seed + 0x5E12)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._drain = True
        self._state_lock = threading.Lock()
                              # guards WRITES of the worker->client flags
                              # (refresh_error, _accepting): clients read
                              # them lock-free as snapshots
        self._accepting = False
        self._last_version = -1
        self.refresh_error: Optional[BaseException] = None
                              # last failed serving-driven generation build
                              # (serving continues on the live generation)

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def start(self) -> "GNSServer":
        assert self._thread is None, "server already started"
        # cold-start the cache OUTSIDE the loop so the first request does
        # not pay the generation build
        self.engine.ensure_cache(self._rng)
        self._stop.clear()
        with self._state_lock:
            self._accepting = True
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="gns-serve")
        self._thread.start()
        return self

    def stop(self, drain: bool = True, timeout: Optional[float] = None) -> None:
        """Stop accepting; by default serve out the queue, then join.

        ``drain=False`` makes the worker exit at the next batch boundary
        instead; queued requests are cancelled AFTER the join (never
        concurrently with the worker — a request must not be served and
        failed at the same time)."""
        with self._state_lock:
            self._accepting = False
        self._drain = drain
        self._stop.set()
        t = self._thread
        if t is not None:
            t.join(timeout)
            if t.is_alive():
                # join timed out: the worker still owns the queue — leave
                # it alone (cancelling now could fail a request the worker
                # is serving); the caller may retry stop()
                return
        self._thread = None
        self._cancel_queued()         # whatever the worker left behind

    def __enter__(self) -> "GNSServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    @property
    def running(self) -> bool:
        t = self._thread
        return t is not None and t.is_alive()

    # ------------------------------------------------------------------
    # client surface
    # ------------------------------------------------------------------
    def submit(self, node_ids: np.ndarray,
               deadline_ms: Optional[float] = None) -> ServeFuture:
        """Enqueue one inference request; returns its completion future.

        Raises :class:`QueueFull` when the bounded queue refuses it
        (backpressure — the caller sheds or retries), :class:`ServerClosed`
        after ``stop()``.  ``deadline_ms`` (default from the config) is
        measured from submission; a request still queued past it completes
        with ``status="expired"`` and never touches the device.
        """
        if not self._accepting:
            raise ServerClosed("server is not accepting requests")
        ids = np.asarray(node_ids, dtype=np.int64).ravel()
        if not len(ids):
            raise ValueError("empty request")
        if len(ids) > self.batcher.capacity:
            raise ValueError(
                f"request of {len(ids)} ids exceeds the largest bucket "
                f"{self.batcher.capacity} — chunk it client-side")
        if deadline_ms is None:
            deadline_ms = self.cfg.default_deadline_ms
        now = time.monotonic()
        pending = _Pending(
            node_ids=ids, future=ServeFuture(), t_submit=now,
            deadline=now + deadline_ms * 1e-3 if deadline_ms is not None
            else None)
        self.meter.observe_submit()         # locked: races across clients
        if not self.batcher.offer(pending):
            self.meter.observe_reject()
            raise QueueFull(
                f"request queue at capacity ({self.cfg.max_queue})")
        if not self._accepting:
            # stop() raced our enqueue and its cancellation sweep may have
            # already run — never hand out a future nobody will complete
            if not self.running:
                self._cancel_queued()
            raise ServerClosed("server stopped while the request enqueued")
        return pending.future

    def infer(self, node_ids: np.ndarray,
              timeout: Optional[float] = 60.0) -> np.ndarray:
        """Blocking convenience: submit + wait; returns [n_ids, classes]."""
        res = self.submit(node_ids).result(timeout)
        if res.status != "ok":
            raise RuntimeError(f"request ended with status={res.status!r}")
        return res.logits

    # ------------------------------------------------------------------
    # worker loop
    # ------------------------------------------------------------------
    def _run(self) -> None:
        eng = self.engine
        store = eng.store
        while True:
            batch = self.batcher.next_batch(timeout=0.05)
            if batch is None:
                if self._stop.is_set():
                    return
                continue
            t_start = time.monotonic()
            live, expired = [], []
            for p in batch:
                (expired if p.deadline is not None and p.deadline < t_start
                 else live).append(p)
            for p in expired:
                self.meter.observe_expired(t_start - p.t_submit)
                p.future._complete(ServeResult(
                    logits=None, status="expired",
                    queue_wait_s=t_start - p.t_submit,
                    total_s=t_start - p.t_submit))
            if not live:
                continue
            try:
                self._serve_batch(live, t_start)
            except BaseException as e:    # keep the loop alive; fail the batch
                self.meter.observe_error(len(live))
                for p in live:
                    p.future._fail(e)
            # swap point: publish a completed async refresh BETWEEN batches
            # (mirrors EpochLoader._poll_store — never mid-assembly), and
            # kick the next serving-driven refresh when due.  A FAILED
            # background build (swap_if_ready re-raises it here) must not
            # kill the loop: keep serving the live generation and surface
            # the error on the meter/server instead.
            if store is not None:
                try:
                    if store.swap_if_ready():
                        self.meter.observe_swap()
                    n_batches = self.meter.batch_count()
                    due = (self.cfg.refresh_every is not None
                           and n_batches > 0
                           and n_batches % self.cfg.refresh_every == 0)
                    if due and not store.refreshing and not self._stop.is_set():
                        store.begin_refresh(self._rng,
                                            version=store.version + 1)
                except BaseException as e:
                    with self._state_lock:   # publish to client threads
                        self.refresh_error = e
                    self.meter.observe_refresh_failure()
            if self._stop.is_set() and (not self._drain
                                        or self.batcher.qsize() == 0):
                return

    def _serve_batch(self, live: Sequence[_Pending], t_start: float) -> None:
        eng = self.engine
        ids = np.concatenate([p.node_ids for p in live])
        bucket = self.batcher.bucket_for(len(ids))
        t0 = time.perf_counter()
        if eng.store is not None:
            # serving-mode accounting: tier traffic -> the serve meter,
            # policy EMA + placement histograms keep observing
            with eng.store.serving(self.meter.traffic):
                mb = eng.infer_prepare(ids, bucket=bucket, rng=self._rng)
        else:
            mb = eng.infer_prepare(ids, bucket=bucket, rng=self._rng)
        # the per-bucket compiled step; its host->device copy books to the
        # serving traffic meter alongside the tier accounting above
        logits = eng.infer_compute(mb, meter=self.meter.traffic)
        compute_s = time.perf_counter() - t0
        t_done = time.monotonic()
        version = mb.cache_version
        self._last_version = version
        self.meter.observe_batch(BatchRecord(
            bucket=bucket, n_requests=len(live), n_ids=len(ids),
            compute_s=compute_s, cache_version=version,
            hit_fraction=mb.num_cached / max(mb.num_input, 1)))
        lo = 0
        for p in live:
            n = len(p.node_ids)
            # copy, don't view: a view would leak the other coalesced
            # requests' rows through .base and pin the whole padded batch
            res = ServeResult(
                logits=logits[lo:lo + n].copy(), status="ok",
                queue_wait_s=t_start - p.t_submit, compute_s=compute_s,
                total_s=t_done - p.t_submit, bucket=bucket,
                cache_version=version)
            lo += n
            self.meter.observe_request(
                res.queue_wait_s, res.compute_s, res.total_s,
                late=p.deadline is not None and t_done > p.deadline)
            p.future._complete(res)

    def _cancel_queued(self) -> None:
        for p in self.batcher.drain():
            p.future._fail(ServerClosed("server stopped before serving"))

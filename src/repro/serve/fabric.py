"""ServeFabric — the multi-tenant, multi-worker serving fleet.

PR 5's :class:`~repro.serve.server.GNSServer` proved one worker can serve
off the live cache generation; the fabric scales that to the ROADMAP's
"millions of users" shape without giving up any of its invariants:

* **N workers, one cache.**  Each :class:`FabricWorker` owns a DP group
  (``group = worker index``), a :class:`~repro.serve.tenancy.FairScheduler`
  and a :class:`~repro.serve.batcher.MicroBatcher`; all of them sample
  against the SAME :class:`~repro.featurestore.FeatureStore` generation.
  Sampling windows (store.serving scope + ``infer_prepare``) are serialized
  under one fabric-level sample lock — the store's "one accounting mode at
  a time" contract and the shared per-bucket samplers both require it —
  while the compiled ``infer_compute`` steps run concurrently.
* **Placement-aware routing.**  ``submit`` routes each request through
  :class:`~repro.serve.router.Router` to the worker whose home shard owns
  the most of its ids (table re-adopted at every generation swap); the
  request's ids then land on that worker's DP-group histogram, so the next
  placement solve pulls its hot rows fully local — routing and placement
  converge on each other.
* **Per-tenant isolation.**  Admission happens at the chosen worker's
  per-tenant bounded queue: a flooding tenant fills its OWN quota and eats
  its own :class:`~repro.serve.server.QueueFull` while other tenants'
  admissions and latency stay flat (asserted in tests/test_fabric_sched.py
  and benchmarks/bench_fabric.py).
* **Failover.**  A watchdog thread detects stalled workers (stale
  heartbeat) and dead workers (thread gone): either way the worker leaves
  the routing rotation and its queued requests are re-routed to healthy
  workers (``max_retries`` re-routes per request, then the future fails
  with :class:`WorkerDown`); a dead worker's in-flight batch is reclaimed
  and re-routed too.  A stalled worker that wakes up re-enters the
  rotation on its next heartbeat.
* **Generation maintenance is centralized.**  Only the watchdog publishes
  completed refreshes (``swap_if_ready``) and kicks serving-driven
  refreshes — workers never touch the swap path, so batches keep pinning
  their generation exactly as in the single-server proof
  (bitwise-identical results across a mid-stream swap,
  tests/test_fabric_chaos.py).

Lock order (enforced by the runtime sanitizer suite-wide): every lock in
the fabric is leaf-held — no code path acquires a second fabric lock while
holding one, and meter/scheduler/router internals take only their own.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import List, Optional, Sequence

import numpy as np

from repro.analysis import TrackedLock, guarded_by, sanitizer_enabled
from repro.featurestore.meter import TrafficMeter
from repro.serve.batcher import MicroBatcher
from repro.serve.metrics import BatchRecord, ServeMeter
from repro.serve.router import Router
from repro.serve.server import (QueueFull, ServeFuture, ServeResult,
                                ServerClosed)
from repro.serve.tenancy import FairScheduler

DEFAULT_TENANT = "default"


class WorkerDown(RuntimeError):
    """No healthy worker could take the request (after retries)."""


class WorkerKilled(RuntimeError):
    """Chaos-test injection: the worker thread aborts mid-batch."""


@dataclasses.dataclass
class _FabPending:
    """A routed request (fabric-internal; batcher-compatible shape)."""
    node_ids: np.ndarray
    future: ServeFuture
    t_submit: float                   # monotonic
    deadline: Optional[float]         # absolute monotonic, None = unbounded
    tenant: str = DEFAULT_TENANT
    attempts: int = 0                 # failover re-routes so far


@guarded_by("_wlock", "_inflight", writes_only=("last_beat",))
class FabricWorker:
    """One serving worker: scheduler -> micro-batcher -> compiled step.

    The worker thread is the only writer of ``_inflight`` while alive; the
    watchdog reclaims it (under ``_wlock``) only after the thread died.
    ``last_beat`` is written once per loop iteration and read lock-free by
    the watchdog (writes_only snapshot contract).
    """

    def __init__(self, fabric: "ServeFabric", index: int):
        self.fabric = fabric
        self.index = index
        self.group = index                  # DP group / histogram row;
                                            # home shard = group % n_shards
        cfg, serve_cfg = fabric.cfg, fabric.serve_cfg
        self.scheduler = FairScheduler(
            cfg.tenants, default_weight=cfg.default_weight,
            default_quota=cfg.default_quota)
        # the batcher's own queue is kept shallow (~ one batch ahead):
        # backlog lives in the scheduler where quotas + fair order apply
        self.batcher = MicroBatcher(
            serve_cfg.buckets, max_wait_s=serve_cfg.max_wait_ms * 1e-3,
            max_queue=max(serve_cfg.max_queue, 2 * len(serve_cfg.buckets)))
        self.copy_meter = TrafficMeter()    # single-writer host->device
                                            # booking for THIS worker's
                                            # compute calls
        self._rng = np.random.default_rng(
            fabric.engine.cfg.seed + 0xFAB0 + index)
        self._wlock = threading.Lock()
        self._inflight: List[_FabPending] = []
        self.last_beat = time.monotonic()
        self._fed_ids = 0                   # ids sitting in the batcher
                                            # (worker-thread only)
        self.stall_s = 0.0                  # chaos hook: sleep mid-batch
        self._die = False                   # chaos hook: abort mid-batch
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def start(self) -> None:
        assert self._thread is None, "worker already started"
        self._thread = threading.Thread(
            target=self._run, daemon=True, name=f"gns-fabric-{self.index}")
        self._thread.start()

    def alive(self) -> bool:
        t = self._thread
        return t is not None and t.is_alive()

    def join(self, timeout: float) -> None:
        t = self._thread
        if t is not None:
            t.join(timeout)

    def kill(self) -> None:
        """Chaos hook: the next batch aborts the worker thread mid-flight."""
        self._die = True

    def beat_age(self, now: float) -> float:
        return now - self.last_beat       # lock-free snapshot (writes_only)

    def take_inflight(self) -> List[_FabPending]:
        """Watchdog reclaim — only meaningful once the thread is dead."""
        with self._wlock:
            out, self._inflight = self._inflight, []
        return out

    def backlog(self) -> int:
        return self.scheduler.qsize() + self.batcher.qsize()

    # ------------------------------------------------------------------
    # worker thread
    # ------------------------------------------------------------------
    def _beat(self) -> None:
        with self._wlock:
            self.last_beat = time.monotonic()

    def _pump(self) -> None:
        """Move requests scheduler -> batcher in weighted-fair order, at
        most ~one batch's worth ahead (backlog must stay in the scheduler
        so quotas keep meaning something)."""
        while self._fed_ids < self.batcher.capacity:
            nxt = self.scheduler.pop()
            if nxt is None:
                return
            tenant, p = nxt
            if not self.batcher.offer(p):
                self.scheduler.push_front(tenant, p)   # keep FIFO
                return
            self._fed_ids += len(p.node_ids)

    def _run(self) -> None:
        fab = self.fabric
        while True:
            self._beat()
            self._pump()
            batch = self.batcher.next_batch(timeout=0.002)
            if batch is None:
                if fab.stopping and (not fab.drain_on_stop
                                     or self.backlog() == 0):
                    return
                self.scheduler.work_ev.wait(timeout=0.02)
                continue
            self._fed_ids -= sum(len(p.node_ids) for p in batch)
            t_start = time.monotonic()
            live, expired = [], []
            for p in batch:
                (expired if p.deadline is not None and p.deadline < t_start
                 else live).append(p)
            for p in expired:
                fab.meter.observe_expired(t_start - p.t_submit,
                                          tenant=p.tenant)
                p.future._complete(ServeResult(
                    logits=None, status="expired",
                    queue_wait_s=t_start - p.t_submit,
                    total_s=t_start - p.t_submit))
            if not live:
                continue
            with self._wlock:
                self._inflight = list(live)
            try:
                self._serve_batch(live, t_start)
            except WorkerKilled:
                return         # chaos: die with the batch in flight — the
                               # watchdog reclaims _inflight and re-routes
            except BaseException as e:
                fab.meter.observe_error(len(live))
                for p in live:
                    p.future._fail(e)
            with self._wlock:
                self._inflight = []
            if fab.stopping and (not fab.drain_on_stop
                                 or self.backlog() == 0):
                return

    def _serve_batch(self, live: Sequence[_FabPending],
                     t_start: float) -> None:
        fab = self.fabric
        eng = fab.engine
        ids = np.concatenate([p.node_ids for p in live])
        bucket = self.batcher.bucket_for(len(ids))
        t0 = time.perf_counter()
        mb = fab._prepare(self, ids, bucket)
        if self.stall_s:
            time.sleep(self.stall_s)      # chaos hook: in-flight stall
        if self._die:
            raise WorkerKilled(f"worker {self.index} killed (chaos hook)")
        logits = eng.infer_compute(mb, meter=self.copy_meter)
        compute_s = time.perf_counter() - t0
        t_done = time.monotonic()
        version = mb.cache_version
        fab.meter.observe_batch(BatchRecord(
            bucket=bucket, n_requests=len(live), n_ids=len(ids),
            compute_s=compute_s, cache_version=version,
            hit_fraction=mb.num_cached / max(mb.num_input, 1)),
            worker=self.index)
        lo = 0
        for p in live:
            n = len(p.node_ids)
            # copy, don't view (same rationale as GNSServer._serve_batch)
            res = ServeResult(
                logits=logits[lo:lo + n].copy(), status="ok",
                queue_wait_s=t_start - p.t_submit, compute_s=compute_s,
                total_s=t_done - p.t_submit, bucket=bucket,
                cache_version=version)
            lo += n
            fab.meter.observe_request(
                res.queue_wait_s, res.compute_s, res.total_s,
                tenant=p.tenant,
                late=p.deadline is not None and t_done > p.deadline)
            p.future._complete(res)


@guarded_by("_flock", "_healthy", writes_only=("_fab_accepting",
                                               "fabric_error"))
class ServeFabric:
    """The worker fleet + router + watchdog over one GNSEngine.

    Usage::

        fabric = engine.serve_fabric()        # FabricConfig via EngineConfig
        with fabric:
            fut = fabric.submit(ids, tenant="mobile")
            res = fut.result(timeout=10)
        print(fabric.meter.snapshot())        # incl. per-tenant + routing
    """

    def __init__(self, engine, cfg=None, serve_cfg=None):
        if cfg is None:
            cfg = engine.cfg.serve_config().fabric
        if cfg is None:
            from repro.gns.config import FabricConfig
            cfg = FabricConfig()
        assert cfg.workers >= 1, cfg
        self.engine = engine
        self.cfg = cfg
        self.serve_cfg = (serve_cfg if serve_cfg is not None
                          else engine.cfg.serve_config())
        self.meter = ServeMeter(latency_window=self.serve_cfg.latency_window)
        n_shards = engine.store.n_shards if engine.store is not None else 1
        self.router = Router(range(cfg.workers), n_shards,
                             mode=("locality" if cfg.routing == "locality"
                                   else "spread"))
        # serializes every worker's sampling window: the store's
        # serving-scope/dp_group flips and the shared per-bucket samplers
        # are store-global state.  Wrapped for the sanitizer's lock-order
        # graph even though no guarded attrs live under it.
        lk = threading.Lock()
        self._sample_lock = (TrackedLock(lk, "ServeFabric._sample_lock")
                             if sanitizer_enabled() else lk)
        self._flock = threading.Lock()
        self._healthy = frozenset(range(cfg.workers))
        self._fab_accepting = False
        self.fabric_error: Optional[BaseException] = None
                                    # last failed serving-driven build
                                    # (serving continues on the live gen)
        self.stopping = False       # worker exit flag (monotonic: set once
                                    # by stop(), plain-read by workers)
        self.drain_on_stop = True
        self._stop = threading.Event()
        self._refresh_rng = np.random.default_rng(engine.cfg.seed + 0x5E12)
        self._last_refresh_batches = 0
        if cfg.transport == "tcp":
            # cross-host fleet: each worker is a proxy over a TCP channel
            # to a WorkerEndpoint process holding its own cache replica
            from repro.rpc import RemoteWorkerProxy
            endpoints = tuple(cfg.endpoints)
            assert len(endpoints) == cfg.workers, (
                f"transport='tcp' needs one endpoint per worker: "
                f"{len(endpoints)} endpoints for {cfg.workers} workers")
            self.workers = [RemoteWorkerProxy(self, i, endpoints[i])
                            for i in range(cfg.workers)]
        else:
            assert cfg.transport == "inproc", cfg.transport
            self.workers = [FabricWorker(self, i) for i in range(cfg.workers)]
        self._watchdog: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def start(self) -> "ServeFabric":
        assert self._watchdog is None, "fabric already started"
        if self.cfg.transport == "tcp":
            # generation 0 lives on the endpoints (same config + same seeded
            # rng streams -> bitwise the generation the inproc fabric would
            # build); the placement leader's HELLO_ACK ships the routing
            # table, adopted via _adopt_remote_table during w.start()
            for w in self.workers:
                w.start()
        else:
            # cold-start the cache before any worker runs, and give the
            # router its first table (generation 0's layout)
            self.engine.ensure_cache(self._refresh_rng)
            if self.engine.store is not None:
                self.router.adopt(self.engine.store.routing_table())
            for w in self.workers:
                w.start()
        self._stop.clear()
        self._watchdog = threading.Thread(
            target=self._watch, daemon=True, name="gns-fabric-watchdog")
        self._watchdog.start()
        with self._flock:
            self._fab_accepting = True
        return self

    def stop(self, drain: bool = True, timeout: float = 30.0) -> None:
        """Stop accepting, serve out queues (``drain=True``), join, cancel
        leftovers (never concurrently with a live worker)."""
        with self._flock:
            self._fab_accepting = False
        self.drain_on_stop = drain
        self.stopping = True
        self._stop.set()
        wd = self._watchdog
        if wd is not None:
            wd.join(timeout)
        self._watchdog = None
        deadline = time.monotonic() + timeout
        for w in self.workers:
            w.join(max(deadline - time.monotonic(), 0.1))
        for w in self.workers:
            if w.alive():
                continue      # stalled past timeout: leave its queue alone
            for _tenant, p in w.scheduler.drain():
                p.future._fail(ServerClosed("fabric stopped before serving"))
            for p in w.batcher.drain():
                p.future._fail(ServerClosed("fabric stopped before serving"))
            for p in w.take_inflight():
                p.future._fail(ServerClosed("fabric stopped before serving"))

    def __enter__(self) -> "ServeFabric":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def healthy(self) -> List[int]:
        with self._flock:
            return sorted(self._healthy)

    # ------------------------------------------------------------------
    # client surface
    # ------------------------------------------------------------------
    def submit(self, node_ids: np.ndarray, tenant: str = DEFAULT_TENANT,
               deadline_ms: Optional[float] = None,
               worker: Optional[int] = None) -> ServeFuture:
        """Route + enqueue one request for ``tenant``.

        Raises :class:`QueueFull` when the tenant's queue on the chosen
        worker is at quota (that tenant's backpressure — nobody else's),
        :class:`WorkerDown` when no healthy worker exists,
        :class:`ServerClosed` outside start()/stop().  ``worker`` pins the
        request to one worker, bypassing routing AND health (test/ops
        escape hatch).
        """
        if not self._fab_accepting:
            raise ServerClosed("fabric is not accepting requests")
        ids = np.asarray(node_ids, dtype=np.int64).ravel()
        if not len(ids):
            raise ValueError("empty request")
        capacity = self.workers[0].batcher.capacity
        if len(ids) > capacity:
            raise ValueError(
                f"request of {len(ids)} ids exceeds the largest bucket "
                f"{capacity} — chunk it client-side")
        if deadline_ms is None:
            deadline_ms = self.serve_cfg.default_deadline_ms
        now = time.monotonic()
        p = _FabPending(
            node_ids=ids, future=ServeFuture(), t_submit=now,
            deadline=now + deadline_ms * 1e-3 if deadline_ms is not None
            else None, tenant=tenant)
        if worker is not None:
            target = worker
            self.meter.observe_route(0, 0, fallback=True)
        else:
            with self._flock:
                healthy = sorted(self._healthy)
            if not healthy:
                raise WorkerDown("no healthy workers")
            d = self.router.route(ids, healthy)
            target = d.worker
            self.meter.observe_route(d.known, d.local, fallback=d.fallback)
        self.meter.observe_submit(tenant)
        if not self.workers[target].scheduler.offer(tenant, p):
            self.meter.observe_reject(tenant)
            raise QueueFull(
                f"tenant {tenant!r} queue at quota on worker {target}")
        if not self._fab_accepting:
            # stop() raced the enqueue; its cancellation sweep may already
            # have run — never hand out a future nobody will complete
            p.future._fail(ServerClosed("fabric stopped while enqueueing"))
            raise ServerClosed("fabric stopped while the request enqueued")
        return p.future

    def infer(self, node_ids: np.ndarray, tenant: str = DEFAULT_TENANT,
              timeout: Optional[float] = 60.0) -> np.ndarray:
        """Blocking convenience: submit + wait; returns [n_ids, classes]."""
        res = self.submit(node_ids, tenant=tenant).result(timeout)
        if res.status != "ok":
            raise RuntimeError(f"request ended with status={res.status!r}")
        return res.logits

    # ------------------------------------------------------------------
    # sampling window (shared-store critical section)
    # ------------------------------------------------------------------
    def _prepare(self, worker: FabricWorker, ids: np.ndarray, bucket: int):
        """One serialized sampling window: route tier accounting to the
        serve meter, stamp the worker's DP group on the store (per-group
        histograms = the routing table's future), sample + assemble."""
        eng = self.engine
        with self._sample_lock:
            if eng.store is not None:
                eng.store.dp_group = worker.group
                with eng.store.serving(self.meter.traffic):
                    return eng.infer_prepare(ids, bucket=bucket,
                                             rng=worker._rng)
            return eng.infer_prepare(ids, bucket=bucket, rng=worker._rng)

    # ------------------------------------------------------------------
    # watchdog: health, failover, generation maintenance
    # ------------------------------------------------------------------
    def _watch(self) -> None:
        interval = self.cfg.watch_interval_ms * 1e-3
        stall_s = self.cfg.stall_timeout_ms * 1e-3
        while not self._stop.wait(interval):
            self._poll_store()
            now = time.monotonic()
            for w in self.workers:
                dead = not w.alive()
                stalled = (not dead) and w.beat_age(now) > stall_s
                if dead or stalled:
                    with self._flock:
                        was_healthy = w.index in self._healthy
                        self._healthy = self._healthy - {w.index}
                        any_healthy = bool(self._healthy)
                    if was_healthy:
                        self.meter.observe_failover()
                    if stalled and not any_healthy:
                        # EVERY worker is stalled (e.g. a first-batch
                        # compile storm): nowhere to re-route, and the
                        # workers are alive — leave the queue in place, it
                        # is served when they wake up.  Dead workers still
                        # drain below (fail fast is right when nothing can
                        # ever serve the requests).
                        continue
                    orphans = [p for _t, p in w.scheduler.drain()]
                    if dead:
                        # the thread is gone: its batcher + in-flight batch
                        # are safe to reclaim (no concurrent owner)
                        orphans.extend(w.batcher.drain())
                        orphans.extend(w.take_inflight())
                    for p in orphans:
                        self._reroute(p)
                else:
                    with self._flock:
                        if w.index not in self._healthy:
                            self._healthy = self._healthy | {w.index}

    def _reroute(self, p: _FabPending) -> None:
        """Failover: hand an orphaned request to a healthy worker."""
        if p.future.done():
            return
        now = time.monotonic()
        if p.deadline is not None and p.deadline < now:
            self.meter.observe_expired(now - p.t_submit, tenant=p.tenant)
            p.future._complete(ServeResult(
                logits=None, status="expired",
                queue_wait_s=now - p.t_submit, total_s=now - p.t_submit))
            return
        p.attempts += 1
        self.meter.observe_retry(p.tenant)
        if p.attempts > self.cfg.max_retries:
            p.future._fail(WorkerDown(
                f"request re-routed {p.attempts - 1} times without being "
                f"served"))
            return
        with self._flock:
            healthy = sorted(self._healthy)
        if not healthy:
            p.future._fail(WorkerDown("no healthy workers"))
            return
        d = self.router.route(p.node_ids, healthy)
        if not self.workers[d.worker].scheduler.offer(p.tenant, p):
            self.meter.observe_reject(p.tenant)
            p.future._fail(QueueFull(
                f"tenant {p.tenant!r} queue at quota on failover target "
                f"{d.worker}"))

    def _poll_store(self) -> None:
        """Swap point + refresh cadence + streaming-ingest drain (the
        single-server loop's tail, centralized so N workers never race the
        swap)."""
        if self.cfg.transport == "tcp":
            # generations live on the endpoints: the coordinator only drives
            # the refresh CADENCE (broadcast REFRESH frames); each endpoint
            # swaps locally and ships its new table back in a SWAPPED frame
            # (_on_remote_swap adopts the placement leader's copy)
            every = self.serve_cfg.refresh_every
            if every is None or self._stop.is_set():
                return
            n = self.meter.batch_count()
            if n > 0 and n - self._last_refresh_batches >= every:
                self._last_refresh_batches = n
                for w in self.workers:
                    if w.alive():
                        w.request_refresh()
            return
        store = self.engine.store
        if store is None:
            return
        try:
            if store.swap_if_ready():
                self.meter.observe_swap()
                self.router.adopt(store.routing_table())
            every = self.serve_cfg.refresh_every
            if every is not None and not self._stop.is_set():
                n = self.meter.batch_count()
                if (n > 0 and n - self._last_refresh_batches >= every
                        and not store.refreshing):
                    self._last_refresh_batches = n
                    store.begin_refresh(self._refresh_rng,
                                        version=store.version + 1)
            # streaming ingest: staged deltas past the merge threshold kick
            # an ASYNC build (which drains the buffer at its boundary) —
            # serving never pauses, the swap above publishes the merge
            if (not self._stop.is_set() and store.stream_merge_due()
                    and not store.refreshing):
                store.begin_refresh(self._refresh_rng,
                                    version=store.version + 1)
        except BaseException as e:
            with self._flock:         # publish to client threads
                self.fabric_error = e
            self.meter.observe_refresh_failure()

    # ------------------------------------------------------------------
    # tcp transport hooks (called by RemoteWorkerProxy threads)
    # ------------------------------------------------------------------
    def _placement_leader(self, candidate: int) -> int:
        """Which endpoint's routing table the Router follows: the
        lowest-index live worker (``candidate`` counts as live — it is the
        worker currently reporting).  Replicas under adaptive policies can
        drift apart; following ONE keeps routing coherent (divergence only
        costs locality on the others, never correctness)."""
        with self._flock:
            alive = {w.index for w in self.workers if w.alive()}
        alive.add(candidate)
        return min(alive)

    def _adopt_remote_table(self, index: int, table) -> None:
        """HELLO_ACK handshake: adopt the placement leader's table."""
        if table is not None and index == self._placement_leader(index):
            self.router.adopt(table)

    def _on_remote_swap(self, index: int, table) -> None:
        """SWAPPED frame: an endpoint published a new generation."""
        if index == self._placement_leader(index):
            self.meter.observe_swap()
            if table is not None:
                self.router.adopt(table)

    def _note_fabric_error(self, err: BaseException) -> None:
        with self._flock:
            self.fabric_error = err
        self.meter.observe_refresh_failure()

    # ------------------------------------------------------------------
    # observability
    # ------------------------------------------------------------------
    def rpc_traffic(self) -> dict:
        """Aggregate wire-bytes view over the proxies' channel meters."""
        tx = sum(w.copy_meter.bytes_rpc_tx for w in self.workers)
        rx = sum(w.copy_meter.bytes_rpc_rx for w in self.workers)
        return {"bytes_rpc_tx": tx, "bytes_rpc_rx": rx}

    def pull_remote_stats(self, timeout: float = 5.0) -> dict:
        """tcp transport: pull each live endpoint's STATS (remote tenant
        ledgers + wire counters) into the serve meter's ``remote`` section.
        Returns the raw per-worker replies."""
        out = {}
        if self.cfg.transport != "tcp":
            return out
        for w in self.workers:
            if not w.alive():
                continue
            try:
                stats = w.fetch_remote_stats(timeout=timeout)
            except BaseException:
                continue
            out[w.index] = stats
            self.meter.observe_remote_stats(w.index, stats)
        return out

    def snapshot(self) -> dict:
        """``meter.snapshot()`` plus the transport view: scheduler fair-share
        counters per worker and, over tcp, the aggregate wire traffic."""
        snap = self.meter.snapshot()
        snap["scheduler_counters"] = {
            w.index: w.scheduler.counters() for w in self.workers}
        if self.cfg.transport == "tcp":
            snap["rpc"] = self.rpc_traffic()
        return snap

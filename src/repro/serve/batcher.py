"""Dynamic micro-batching: coalesce queued requests into size buckets.

The compiled-step economics drive the design (`launch/serve.py`'s step-cache
idea, transplanted): every distinct padded batch shape is one XLA
compilation, so the batcher only ever emits batches padded to a SMALL FIXED
set of sizes (``ServeConfig.buckets``).  Steady-state serving therefore runs
with one compiled inference step per bucket and zero recompilation —
asserted by `benchmarks/bench_serve.py`.

Coalescing rule: take the oldest queued request, then keep absorbing
requests until either (a) the id budget (the largest bucket) is full,
(b) the batching window ``max_wait_s`` elapses, or (c) waiting any longer
would push the oldest absorbed request past its deadline.  The batch is
then padded up to the smallest bucket that holds its ids.

The batcher also owns the **bounded request queue** — the admission-control
surface: ``offer`` refuses (returns False) when the queue is full, and the
server turns that refusal into a :class:`~repro.serve.server.QueueFull`
rejection instead of letting latency grow without bound.
"""
from __future__ import annotations

import queue
import time
from typing import Optional, Sequence

# close the coalescing window this far BEFORE the earliest deadline in the
# batch: dispatching AT the deadline would expire a request the server had
# every chance to serve (the deadline gates admission-to-batch, so it must
# leave the batcher before the clock runs out)
DEADLINE_MARGIN_S = 0.005


class MicroBatcher:
    """Bounded FIFO of pending requests + the coalescing policy."""

    def __init__(self, buckets: Sequence[int], max_wait_s: float,
                 max_queue: int):
        buckets = tuple(int(b) for b in buckets)
        assert buckets and all(b > 0 for b in buckets), buckets
        assert list(buckets) == sorted(buckets), \
            f"buckets must be ascending: {buckets}"
        self.buckets = buckets
        self.capacity = buckets[-1]          # per-batch id budget
        self.max_wait_s = max_wait_s
        self._q: queue.Queue = queue.Queue(maxsize=max_queue)
        self._carry = None    # request pulled but not fitting the last batch

    # ------------------------------------------------------------------
    def offer(self, pending) -> bool:
        """Enqueue; False = queue full (the admission-control refusal)."""
        try:
            self._q.put_nowait(pending)
            return True
        except queue.Full:
            return False

    def qsize(self) -> int:
        return self._q.qsize() + (1 if self._carry is not None else 0)

    def drain(self) -> list:
        """Pull everything queued right now, no coalescing, no waiting
        (the server's cancellation path)."""
        out = []
        if self._carry is not None:
            out.append(self._carry)
            self._carry = None
        while True:
            try:
                out.append(self._q.get_nowait())
            except queue.Empty:
                return out

    def bucket_for(self, n_ids: int) -> int:
        """Smallest bucket holding ``n_ids`` rows."""
        assert 0 < n_ids <= self.capacity, (n_ids, self.capacity)
        for b in self.buckets:
            if n_ids <= b:
                return b
        return self.capacity        # unreachable given the assert

    # ------------------------------------------------------------------
    def next_batch(self, timeout: float) -> Optional[list]:
        """Pull one coalesced batch (FIFO order), or None on idle timeout.

        ``timeout`` bounds only the wait for the FIRST request (the server's
        stop-flag poll interval); once one is in hand, further absorption is
        bounded by the batching window / deadlines / the id budget.
        """
        if self._carry is not None:
            first, self._carry = self._carry, None
        else:
            try:
                first = self._q.get(timeout=timeout)
            except queue.Empty:
                return None
        batch = [first]
        total = len(first.node_ids)
        window_end = time.monotonic() + self.max_wait_s
        if first.deadline is not None:
            window_end = min(window_end, first.deadline - DEADLINE_MARGIN_S)
        while total < self.capacity:
            remaining = window_end - time.monotonic()
            try:
                nxt = (self._q.get_nowait() if remaining <= 0
                       else self._q.get(timeout=remaining))
            except queue.Empty:
                break
            if total + len(nxt.node_ids) > self.capacity:
                self._carry = nxt        # keep FIFO: lead the next batch
                break
            batch.append(nxt)
            total += len(nxt.node_ids)
            if nxt.deadline is not None:
                window_end = min(window_end,
                                 nxt.deadline - DEADLINE_MARGIN_S)
            # window closed -> the loop keeps absorbing via get_nowait only
            # (drains whatever is already queued, never waits again)
        return batch

"""Per-tenant admission + weighted-fair scheduling for the serve fabric.

One :class:`FairScheduler` sits in front of each fabric worker's
micro-batcher.  It answers two questions the single-server bounded queue
could not:

* **whose request is refused** when the system saturates — every tenant has
  its own bounded queue (``TenantConfig.max_queue``), so a flooding tenant
  collects its own :class:`~repro.serve.server.QueueFull` while everyone
  else's admissions are untouched; and
* **whose request runs next** — classic stride scheduling: each tenant
  carries a ``pass`` value advanced by ``stride ∝ 1/weight`` per dequeue,
  and the scheduler always pops the FIFO head of the minimum-pass non-empty
  tenant.  Under saturation, throughput share converges to the weight
  ratio; any positive-weight tenant is dequeued after at most
  ``ceil(total_weight / weight)`` pops (no starvation); requests within one
  tenant never reorder.

A tenant rejoining after idling restarts at ``max(own pass, global virtual
time)`` — it cannot hoard credit while idle and then monopolize the worker
(the standard stride-scheduling rejoin rule).

The scheduler is deliberately engine-free and jax-free: items are opaque,
which is what lets ``tests/test_fabric_sched.py`` drive the invariants
property-style with plain integers.
"""
from __future__ import annotations

import collections
import threading
from typing import Any, Deque, Dict, Optional, Sequence, Tuple

from repro.analysis import guarded_by, holds_lock

# pass/virtual-time quantum for a weight-1.0 tenant; only ratios matter
_STRIDE1 = float(1 << 20)


class UnknownTenant(KeyError):
    """offer() for a tenant that is not declared (auto-register disabled)."""


@guarded_by("_slock", "_tq", "_tpass", "_tweight", "_tstride", "_tquota",
            "_torder", "_vtime", "_toffered", "_tpopped")
class FairScheduler:
    """Weighted-fair (stride) scheduler over per-tenant bounded FIFOs.

    All state lives under ``_slock``; the public surface is ``offer`` /
    ``pop`` / ``drain`` / ``qsize``.  ``work_ev`` is a plain Event a worker
    may wait on instead of polling — set whenever any queue is non-empty
    (a lost wakeup is bounded by the worker's wait timeout, never dropped
    work).
    """

    def __init__(self, tenants: Sequence[Any] = (),
                 default_weight: float = 1.0, default_quota: int = 64,
                 auto_register: bool = True):
        self._slock = threading.Lock()
        self._tq: Dict[str, Deque[Any]] = {}
        self._tpass: Dict[str, float] = {}
        self._tweight: Dict[str, float] = {}
        self._tstride: Dict[str, float] = {}
        self._tquota: Dict[str, int] = {}
        self._torder: Dict[str, int] = {}   # registration rank: pass ties
                                            # break deterministically
        self._toffered: Dict[str, int] = {} # admitted offers per tenant
        self._tpopped: Dict[str, int] = {}  # fair-order dispatches per
                                            # tenant (cross-host fleets
                                            # aggregate these per proxy)
        self._vtime = 0.0                   # global virtual time (last pass
                                            # dispatched)
        self.default_weight = float(default_weight)
        self.default_quota = int(default_quota)
        self.auto_register = auto_register
        self.work_ev = threading.Event()
        with self._slock:
            for t in tenants:
                self._register_locked(t.name, weight=t.weight,
                                      quota=t.max_queue)

    # ------------------------------------------------------------------
    @holds_lock("_slock")
    def _register_locked(self, name: str, weight: Optional[float] = None,
                         quota: Optional[int] = None) -> None:
        w = self.default_weight if weight is None else float(weight)
        if w <= 0:
            raise ValueError(f"tenant {name!r}: weight must be > 0")
        self._tq[name] = collections.deque()
        self._tweight[name] = w
        self._tstride[name] = _STRIDE1 / w
        self._tquota[name] = int(self.default_quota if quota is None
                                 else quota)
        self._tpass[name] = self._vtime
        self._torder[name] = len(self._torder)
        self._toffered[name] = 0
        self._tpopped[name] = 0

    # ------------------------------------------------------------------
    def offer(self, tenant: str, item: Any) -> bool:
        """Enqueue ``item`` for ``tenant``; False = that tenant's queue is
        at quota (admission control — reject, never grow)."""
        with self._slock:
            q = self._tq.get(tenant)
            if q is None:
                if not self.auto_register:
                    raise UnknownTenant(tenant)
                self._register_locked(tenant)
                q = self._tq[tenant]
            if len(q) >= self._tquota[tenant]:
                return False
            if not q:
                # rejoin after idle: no hoarded credit
                self._tpass[tenant] = max(self._tpass[tenant], self._vtime)
            q.append(item)
            self._toffered[tenant] += 1
            self.work_ev.set()
            return True

    def push_front(self, tenant: str, item: Any) -> None:
        """Return an item to the head of its tenant queue (a worker pumped
        it but the batcher refused) — preserves FIFO, ignores quota (the
        item was already admitted once)."""
        with self._slock:
            if tenant not in self._tq:
                self._register_locked(tenant)
            self._tq[tenant].appendleft(item)
            self.work_ev.set()

    def pop(self) -> Optional[Tuple[str, Any]]:
        """Dequeue the FIFO head of the minimum-pass non-empty tenant, or
        None when everything is empty."""
        with self._slock:
            best = None
            for name, q in self._tq.items():
                if not q:
                    continue
                key = (self._tpass[name], self._torder[name])
                if best is None or key < best[0]:
                    best = (key, name)
            if best is None:
                self.work_ev.clear()
                return None
            name = best[1]
            item = self._tq[name].popleft()
            self._tpopped[name] += 1
            self._vtime = self._tpass[name]
            self._tpass[name] += self._tstride[name]
            if not any(self._tq.values()):
                self.work_ev.clear()
            return name, item

    # ------------------------------------------------------------------
    def qsize(self, tenant: Optional[str] = None) -> int:
        with self._slock:
            if tenant is not None:
                q = self._tq.get(tenant)
                return len(q) if q is not None else 0
            return sum(len(q) for q in self._tq.values())

    def drain(self) -> list:
        """Remove and return every queued (tenant, item), fair order not
        preserved — failover/shutdown sweep."""
        with self._slock:
            out = []
            for name, q in self._tq.items():
                while q:
                    out.append((name, q.popleft()))
            self.work_ev.clear()
            return out

    def weight(self, tenant: str) -> float:
        with self._slock:
            return self._tweight.get(tenant, self.default_weight)

    def depths(self) -> dict:
        """Per-tenant queue depth snapshot (observability)."""
        with self._slock:
            return {name: len(q) for name, q in self._tq.items()}

    def counters(self) -> dict:
        """Per-tenant admitted/dispatched totals — the fair-share ledger a
        multi-host fleet sums across its per-proxy schedulers (each remote
        worker's fair order is applied coordinator-side, so these ARE the
        cross-host dispatch counts)."""
        with self._slock:
            return {name: {"offered": self._toffered.get(name, 0),
                           "popped": self._tpopped.get(name, 0),
                           "queued": len(q)}
                    for name, q in self._tq.items()}

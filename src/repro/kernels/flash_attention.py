"""Pallas TPU kernel: blocked attention with online softmax (FlashAttention).

The LM-side compute hot-spot for the assigned architectures (train_4k /
prefill_32k).  TPU-native adaptation notes (DESIGN.md §2):

* Tiles are sized for VMEM and the MXU: q/k/v blocks are (block_q, head_dim)
  and (block_k, head_dim) with head_dim ∈ {64, 128, 256} — MXU-aligned on the
  contracting dim; scores block (block_q, block_k) stays in registers/VMEM.
* Online softmax carries (m, l, acc) in VMEM scratch across the innermost
  kv-block grid dimension (Pallas TPU grids execute sequentially, so scratch
  is a legal carry — this replaces the CUDA shared-memory accumulator).
* GQA is handled in the index_map (kv head = q head // group) — no
  jnp.repeat materialization of K/V.
* Causal + sliding-window masking is applied per-tile from global indices;
  fully-masked tiles are skipped via ``pl.when`` (the causal wedge costs
  ~2x fewer tiles, the SWA band makes long-context linear in seq).

Supports: causal LM (decode & train), sliding-window (h2o-danube3, zamba2
shared attn option), cross-attention (seamless enc-dec), MQA/GQA (gemma,
qwen2, starcoder2, ...), q_len != kv_len (decode with KV cache).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: Optional[int],
            block_q: int, block_k: int, num_kv_blocks: int,
            q_offset: int, kv_len: int):
    iq = pl.program_id(2)
    jk = pl.program_id(3)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # global row/col ranges of this tile
    row0 = iq * block_q + q_offset          # first query's absolute kv position
    col0 = jk * block_k

    # tile-level visibility (skip fully-masked tiles)
    visible = col0 < kv_len
    if causal:
        visible &= col0 <= row0 + block_q - 1
    if window is not None:
        visible &= col0 + block_k - 1 > row0 - window

    @pl.when(visible)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, dh)
        v = v_ref[0, 0].astype(jnp.float32)
        s = q @ k.T                                        # (bq, bk)

        rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        cols = col0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = cols < kv_len
        if causal:
            mask &= cols <= rows
        if window is not None:
            mask &= cols > rows - window
        s = jnp.where(mask, s, _NEG)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)                        # kill -1e30 rows
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
        m_ref[...] = m_new

    @pl.when(jk == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: Optional[int] = None,
                           scale: Optional[float] = None,
                           block_q: int = 128, block_k: int = 128,
                           kv_len: Optional[int] = None,
                           q_offset: Optional[int] = None,
                           interpret: bool = False) -> jax.Array:
    """q: [B, Hq, Sq, Dh]; k, v: [B, Hkv, Sk_padded, Dh].  Returns q-shaped.

    ``kv_len`` masks padded keys (defaults to Sk).  Sq/Sk must be multiples
    of block_q/block_k (ops.py pads).  Query positions are aligned to the
    *end* of the kv axis (decode convention): absolute position of query i is
    ``i + q_offset`` with ``q_offset = kv_len - actual_q_len`` — pass it
    explicitly when q carries end-padding.
    """
    b, hq, sq, dh = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    if scale is None:
        scale = dh ** -0.5
    if kv_len is None:
        kv_len = sk
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    nq, nk = sq // block_q, sk // block_k
    if q_offset is None:
        q_offset = kv_len - sq

    grid = (b, hq, nq, nk)
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, num_kv_blocks=nk,
        q_offset=q_offset, kv_len=kv_len)

    fn = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, dh), lambda b_, h, i, j: (b_, h // group, j, 0)),
            pl.BlockSpec((1, 1, block_k, dh), lambda b_, h, i, j: (b_, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh), lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),     # m: running max
            pltpu.VMEM((block_q,), jnp.float32),     # l: running denom
            pltpu.VMEM((block_q, dh), jnp.float32),  # acc: running numerator
        ],
        interpret=interpret,
    )
    return fn(q, k, v)

"""Pallas TPU kernel: fused cache-lookup + first-layer gather-aggregation.

The GNS input layer resolves every input row against the device cache and
immediately aggregates it into the first GraphSAGE layer:

    h0[r]    = slots[r] >= 0 ? cache_table[slots[r]] : streamed[r]
    out[b,:] = Σ_k  w[b, k] · h0[idx[b, k], :]

The seed did this in two XLA ops (a [S0, F] ``where``-assembled h0, then the
gather-aggregate), materializing the full padded input-layer feature matrix
in HBM.  This kernel fuses both: the *scalar-prefetched* ``idx`` and
pre-gathered per-lane ``slots[idx]`` arrays (both [B, K] — the full [S0]
slot map would blow SMEM at paper scale) drive the BlockSpec index maps of
BOTH source operands — per grid step the pipeline DMAs one (1, block_d)
tile from the cache table at row ``max(slots[idx[b,k]], 0)`` and one from
the streamed buffer at row ``idx[b,k]``, and the VPU selects the live lane
and accumulates.  h0 never exists in memory.

Grid: ``(B, num_d_blocks, K)`` — K innermost so the output tile stays
resident in VMEM across the accumulation, exactly like ``gather_agg``.
Cost per output row: K·block_d·4B from each source stream (the dead lane's
DMA is the price of branch-free pipelining) vs. the unfused path's extra
S0·F·4B h0 round-trip through HBM; for the paper's shapes (S0 ≈ 176k per
batch vs B·K = 16k lanes) the fused path moves strictly fewer bytes.

**Sharded tables** (production mesh): when the cache table is row-partitioned
into contiguous shards over the mesh's cache axis, each device runs the SAME
kernel against its local shard with a shard-local view of the slot map:
global slots owned by the shard become local rows (``shard_slot_map``),
every other lane's weight is zeroed (``shard_lane_weights`` — misses are
contributed by shard 0 only, from the replicated streamed buffer), and the
per-shard partials are psum-ed over the cache axis.  The decomposition only
inserts zero terms and regroups the fixed-order sum, so integer-exact inputs
stay bitwise identical to the single-device kernel.

**Local fast path** (locality-aware placement, PR 3): when the host
established at batch-assembly time that EVERY hit slot of the batch lives on
one known shard (``FeatureStore.assemble_input`` returns ``local_shard``
after the placement solver co-located the group's hot rows), the cross-shard
psum is unnecessary: that shard's partial — the plain single-device kernel
on its local block with a shard-local slot map and UNMASKED lane weights
(``claim_all=True``: hits and misses alike are claimed by the local shard,
misses riding the replicated streamed buffer) — already *is* the full
result.  ``kernels.ops._fused_forward`` then runs the kernel only on the
owner shard (``lax.cond``) and broadcasts the finished rows with a one-to-
all ``ppermute`` instead of all-reducing zero partials from every shard.
The contract (all hits local) lives in the store; violating it silently
drops the non-local hit lanes' contributions, which is why only
``assemble_input`` may produce a ``local_shard``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, lane_slots_ref, w_ref, cache_ref, streamed_ref, out_ref):
    b = pl.program_id(0)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    hit = lane_slots_ref[b, k] >= 0
    w = w_ref[b, k]
    # both candidate tiles were DMA'd by the index maps; select on the VPU.
    # Accumulation order is fixed (K innermost, ascending) and matches the
    # sequential reference; XLA may contract the mul+add into an FMA, so
    # bitwise parity holds whenever the products are exactly representable
    # (the parity test uses integer-valued f32) and to ~1 ulp otherwise.
    val = jnp.where(hit, cache_ref[...], streamed_ref[...])
    out_ref[...] += w * val.astype(out_ref.dtype)


def cache_lookup_agg_pallas(cache_table: jax.Array, streamed: jax.Array,
                            slots: jax.Array, idx: jax.Array, w: jax.Array,
                            block_d: int = 2048,
                            interpret: bool = False) -> jax.Array:
    """out[b] = Σ_k w[b,k] · (slots[idx[b,k]] >= 0 ? cache[slots[idx[b,k]]]
                                                   : streamed[idx[b,k]]).

    Args:
      cache_table: [C, D] device cache tier (f32 or bf16).
      streamed:    [S0, D] host-gathered miss rows (0 where cached).
      slots:       [S0] int32 cache slot per input row, -1 = miss.
      idx:         [B, K] int32 input-row indices (padded lanes carry w == 0).
      w:           [B, K] f32 aggregation weights.
    Returns [B, D] f32.
    """
    _, d = cache_table.shape
    assert streamed.shape[1] == d
    bsz, num_k = idx.shape
    block_d = min(block_d, d)
    while d % block_d:          # largest divisor <= requested block
        block_d -= 1
    grid = (bsz, d // block_d, num_k)

    # Pre-gather the per-lane slots to [B, K] on the XLA side: SMEM then
    # holds only the two small lane arrays (4·B·K bytes each), never the
    # full [S0] slot map (~700 KB at the paper's 176k-row input layer,
    # beyond TPU SMEM).
    lane_slots = jnp.take(slots.astype(jnp.int32), idx.astype(jnp.int32),
                          axis=0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,               # idx + lane_slots ride in SMEM
        grid=grid,
        in_specs=[
            # weights: full (B, K) in VMEM — tiny (4·B·K bytes)
            pl.BlockSpec((bsz, num_k),
                         lambda b, db, k, idx_ref, sl_ref: (0, 0)),
            # cache rows: slot of the gathered input row (clamped for misses —
            # the dead tile is discarded by the select)
            pl.BlockSpec((1, block_d),
                         lambda b, db, k, idx_ref, sl_ref:
                         (jnp.maximum(sl_ref[b, k], 0), db)),
            # streamed rows: the gathered input row itself
            pl.BlockSpec((1, block_d),
                         lambda b, db, k, idx_ref, sl_ref:
                         (idx_ref[b, k], db)),
        ],
        out_specs=pl.BlockSpec((1, block_d),
                               lambda b, db, k, idx_ref, sl_ref: (b, db)),
    )
    fn = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, d), jnp.float32),
        interpret=interpret,
    )
    return fn(idx.astype(jnp.int32), lane_slots,
              w.astype(jnp.float32), cache_table, streamed)


# ---------------------------------------------------------------------------
# shard-local views (global slot -> (shard, local row), contiguous blocks)
# ---------------------------------------------------------------------------

def shard_slot_map(slots: jax.Array, shard, rows_per_shard: int) -> jax.Array:
    """Global slot map -> this shard's local rows; everything else -> -1.

    Shard ``s`` owns the contiguous global slots [s·rps, (s+1)·rps) — the
    same row blocks a ``NamedSharding(mesh, P(axis, None))`` places on device
    ``s`` along the cache axis.  ``shard`` may be a traced scalar
    (``jax.lax.axis_index`` inside shard_map) or a Python int (tests).
    """
    slots = slots.astype(jnp.int32)
    lo = shard * rows_per_shard
    owned = (slots >= lo) & (slots < lo + rows_per_shard)
    return jnp.where(owned, slots - lo, -1)


def shard_lane_weights(w: jax.Array, lane_slots: jax.Array, shard,
                       rows_per_shard: int) -> jax.Array:
    """Zero every lane this shard does not contribute.

    A lane is contributed by exactly one shard: cache hits by the shard
    owning the slot, misses (slot < 0, served from the replicated streamed
    buffer) by shard 0.  Summing the per-shard partials therefore recovers
    the single-device result — with only zero terms added, so integer-exact
    inputs reproduce it bitwise.
    """
    lo = shard * rows_per_shard
    owned = (lane_slots >= lo) & (lane_slots < lo + rows_per_shard)
    miss = lane_slots < 0
    contribute = owned | (miss & (shard == 0))
    return jnp.where(contribute, w.astype(jnp.float32), 0.0)


def cache_lookup_agg_shard_partial(local_table: jax.Array,
                                   streamed: jax.Array, slots: jax.Array,
                                   idx: jax.Array, w: jax.Array, shard,
                                   rows_per_shard: int,
                                   block_d: int = 2048,
                                   interpret: bool = False,
                                   use_kernel: bool = True,
                                   claim_all: bool = False) -> jax.Array:
    """One shard's partial of the fused lookup: kernel on the LOCAL table.

    Used as the ``shard_map`` body over the cache axis (``shard`` =
    ``axis_index``) and, shard-by-shard in a Python loop, by the parity
    tests that validate the slot mapping without a multi-device mesh.
    ``use_kernel=False`` runs the pure-jnp oracle instead of the Pallas
    kernel (the dry-run path: interpret-mode Pallas at pod-scale grids is
    not lowerable economically from a CPU host).

    ``claim_all=True`` is the LOCAL FAST PATH partial: every lane — hit and
    miss — is claimed by this shard (weights unmasked), so under the host
    contract that all hit slots live here, this single partial equals the
    full single-device kernel bitwise and no psum is needed.  Hit slots NOT
    on this shard map to -1 and would wrongly read the (zeroed) streamed
    row — the caller must hold the contract.
    """
    idx = idx.astype(jnp.int32)
    local_slots = shard_slot_map(slots, shard, rows_per_shard)
    if claim_all:
        w_eff = w.astype(jnp.float32)
    else:
        lane_slots = jnp.take(slots.astype(jnp.int32), idx, axis=0)
        w_eff = shard_lane_weights(w, lane_slots, shard, rows_per_shard)
    if not use_kernel:
        from repro.kernels import ref
        return ref.cache_lookup_agg_ref(local_table, streamed, local_slots,
                                        idx, w_eff)
    return cache_lookup_agg_pallas(local_table, streamed, local_slots, idx,
                                   w_eff, block_d=block_d,
                                   interpret=interpret)

"""Jit'd public wrappers around the Pallas kernels.

Dispatch policy: on TPU the compiled Pallas kernels run natively; on CPU
(this container, CI) they run in ``interpret=True`` mode — same kernel body,
Python-evaluated — so every test exercises the real kernel logic.  Callers
can force the reference path with ``impl="reference"`` (the dry-run uses it:
interpret-mode Pallas cannot be lowered into an XLA-for-TPU HLO from a CPU
host, and the reference path gives XLA the fusion freedom the roofline
analysis measures).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.cache_lookup import cache_lookup_agg_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gather_agg import gather_agg_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_axis(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("impl", "block_d"))
def gather_agg(feat: jax.Array, idx: jax.Array, w: jax.Array,
               impl: str = "pallas", block_d: int = 512) -> jax.Array:
    """Fused gather + weighted aggregation (GNS hot-spot).  [B,D] f32."""
    if impl == "reference":
        return ref.gather_agg_ref(feat, idx, w)
    d = feat.shape[1]
    bd = min(block_d, d)
    while d % bd:
        bd -= 1
    return gather_agg_pallas(feat, idx, w, block_d=bd, interpret=_interpret())


def _dp_spec(mesh, shard_axis):
    """(dp axes, batch PartitionSpec entry) for the fused op's shard_map.

    The batch operands ride whatever logical-batch axes the mesh has, minus
    the cache axis (a 1-D benchmark mesh sharded over its only axis leaves
    the batch replicated).  Uses ``sharding.batch_axes`` so the axis-role
    rule lives in one place.
    """
    from repro.launch.sharding import batch_axes

    dp = tuple(a for a in batch_axes(mesh) if a != shard_axis)
    return dp, (dp if len(dp) > 1 else (dp[0] if dp else None))


def _fused_forward(cache_table, streamed, slots, idx, w, local_shards,
                   impl, block_d, mesh, shard_axis, local_shard=None,
                   dynamic=False):
    """Forward of the fused input op; shard_map over the cache axis if given.

    Sharded contract (the production regime): the table is row-partitioned
    into contiguous shards over ``shard_axis``; batch operands ride the DP
    axes (each data-parallel group resolves its OWN minibatch, so inside the
    body ``idx``/``slots`` are group-local); each shard contributes the lanes
    it owns (misses ride shard 0's replicated streamed buffer) and the
    partials are psum-ed over the cache axis — see
    ``kernels.cache_lookup.shard_lane_weights`` for why the regrouped sum is
    exact.

    ``local_shard`` (static int) is the locality fast path: the host
    verified at batch assembly that EVERY hit slot lives on that shard
    (locality-aware placement, ``FeatureStore.assemble_input``), so the
    owner's ``claim_all`` partial is already the full result — the other
    shards skip the kernel entirely (``lax.cond``) and the finished rows are
    ppermute-broadcast from the owner instead of all-reduced.  Bitwise equal
    to the psum path whenever the host contract holds.

    ``local_shards`` + ``dynamic=True`` is the DEVICE-RESIDENT variant of the
    same fast path: a traced int32 vector carrying one home shard per DP
    group (-1 = no locality contract for that group's batch), sharded over
    the DP axes so each group's body instance reads its own scalar.  The
    owner test becomes a runtime branch — ``lax.cond`` skips the kernel on
    every non-owner shard and the owner runs the ``claim_all`` partial — so
    ONE compiled step serves batches with any mix of home shards (including
    none) without retracing, which is what makes the fast path usable at
    DP > 1 where each group's batch may be homed on a different shard.  The
    combine stays the single psum: with the non-owner partials skipped to
    exact zeros it reproduces the owner's rows bitwise (only +0.0 terms are
    added), while a psum-free broadcast would need the owner in the ppermute
    permutation — a *static* quantity — and collectives inside a
    data-dependent ``lax.cond`` deadlock multi-group meshes.
    """
    from repro.kernels.cache_lookup import cache_lookup_agg_shard_partial

    use_kernel = impl != "reference"
    if mesh is not None and shard_axis in mesh.axis_names:
        from jax.sharding import PartitionSpec as P
        from repro.launch.sharding import shard_map_compat

        n = mesh.shape[shard_axis]
        rows = cache_table.shape[0]
        assert rows % n == 0, (
            f"cache table rows {rows} must divide the cache axis "
            f"{shard_axis}={n} (pad via CacheConfig.shards / padded_rows)")
        rps = rows // n
        dp, bspec = _dp_spec(mesh, shard_axis)

        if dynamic and n > 1:
            from repro.kernels.cache_lookup import (shard_lane_weights,
                                                    shard_slot_map)

            def body(tbl, st, sl, ix, ww, lsv):
                shard = jax.lax.axis_index(shard_axis)
                ls = lsv[0]                  # this group's home shard or -1
                fast = ls >= 0
                lane_slots = jnp.take(sl.astype(jnp.int32), ix, axis=0)
                # fast: claim-all weights (owner serves hits AND misses);
                # slow: the usual owner-per-lane masking, psum reassembles
                w_eff = jnp.where(fast, ww.astype(jnp.float32),
                                  shard_lane_weights(ww, lane_slots, shard,
                                                     rps))
                local_slots = shard_slot_map(sl, shard, rps)

                def _run(t, s_, sl_, ix_, we):
                    if not use_kernel:
                        return ref.cache_lookup_agg_ref(t, s_, sl_, ix_, we)
                    return cache_lookup_agg_pallas(t, s_, sl_, ix_, we,
                                                   block_d=block_d,
                                                   interpret=_interpret())

                def _skip(t, s_, sl_, ix_, we):
                    return jnp.zeros((ix_.shape[0], t.shape[1]), jnp.float32)

                part = jax.lax.cond(fast & (shard != ls), _skip, _run,
                                    tbl, st, local_slots, ix, w_eff)
                # single combine for both regimes: on fast batches every
                # non-owner term is an exact zero, so the psum returns the
                # owner partial bitwise and only the owner paid the kernel
                return jax.lax.psum(part, shard_axis)

            fn = shard_map_compat(
                body, mesh=mesh,
                in_specs=(P(shard_axis, None), P(bspec, None), P(bspec),
                          P(bspec, None), P(bspec, None), P(bspec)),
                out_specs=P(bspec, None))
            return fn(cache_table, streamed, slots, idx, w, local_shards)

        if local_shard is not None and n > 1:
            ls = int(local_shard)
            assert 0 <= ls < n, (local_shard, n)

            def body(tbl, st, sl, ix, ww):
                shard = jax.lax.axis_index(shard_axis)

                def _owner(t, s_, sl_, ix_, ww_):
                    return cache_lookup_agg_shard_partial(
                        t, s_, sl_, ix_, ww_, ls, rps, block_d=block_d,
                        interpret=_interpret(), use_kernel=use_kernel,
                        claim_all=True)

                def _skip(t, s_, sl_, ix_, ww_):
                    return jnp.zeros((ix_.shape[0], t.shape[1]), jnp.float32)

                part = jax.lax.cond(shard == ls, _owner, _skip,
                                    tbl, st, sl, ix, ww)
                # broadcast the finished rows from the owner by recursive
                # doubling: round k sends from the 2^k devices that already
                # hold them (a static set — ppermute sources must be unique,
                # so one-to-all is built as a log2(n) tree).  Each device
                # receives the rows exactly once -> (n-1)·|out| total bytes,
                # half an all-reduce's, with no adds — the psum skip.
                j = (shard - ls) % n        # my distance from the owner
                out = part
                shift = 1
                while shift < n:
                    senders = min(shift, n - shift)
                    perm = [((ls + a) % n, (ls + a + shift) % n)
                            for a in range(senders)]
                    recv = jax.lax.ppermute(out, shard_axis, perm)
                    newly = (j >= shift) & (j < shift + senders)
                    out = jnp.where(newly, recv, out)
                    shift *= 2
                return out
        else:
            def body(tbl, st, sl, ix, ww):
                shard = jax.lax.axis_index(shard_axis)
                part = cache_lookup_agg_shard_partial(
                    tbl, st, sl, ix, ww, shard, rps, block_d=block_d,
                    interpret=_interpret(), use_kernel=use_kernel)
                return jax.lax.psum(part, shard_axis)

        fn = shard_map_compat(
            body, mesh=mesh,
            in_specs=(P(shard_axis, None), P(bspec, None), P(bspec),
                      P(bspec, None), P(bspec, None)),
            out_specs=P(bspec, None))
        return fn(cache_table, streamed, slots, idx, w)
    if not use_kernel:
        return ref.cache_lookup_agg_ref(cache_table, streamed, slots, idx, w)
    return cache_lookup_agg_pallas(cache_table, streamed, slots, idx, w,
                                   block_d=block_d, interpret=_interpret())


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9, 10, 11))
def _fused(cache_table, streamed, slots, idx, w, local_shards, impl, block_d,
           mesh, shard_axis, local_shard, dynamic):
    return _fused_forward(cache_table, streamed, slots, idx, w, local_shards,
                          impl, block_d, mesh, shard_axis, local_shard,
                          dynamic)


def _fused_fwd(cache_table, streamed, slots, idx, w, local_shards, impl,
               block_d, mesh, shard_axis, local_shard, dynamic):
    out = _fused_forward(cache_table, streamed, slots, idx, w, local_shards,
                         impl, block_d, mesh, shard_axis, local_shard,
                         dynamic)
    return out, (cache_table, streamed, slots, idx, w, local_shards)


def _fused_bwd(impl, block_d, mesh, shard_axis, local_shard, dynamic, res, g):
    """Hand-written VJP in plain jnp: Pallas kernels carry no AD rules.

    ``local_shard`` (and the traced ``local_shards`` vector) is deliberately
    ignored: under the fast-path contract (every hit lane owned by that one
    shard) the generic owner-claims-its-lanes backward already scatters each
    gradient on exactly the right shard — hits land on the home shard
    because it owns them, misses are replicated as always — so forward-fast
    and forward-psum share one backward and cannot drift apart.

    The sharded path MUST mirror the forward's shard_map rather than run
    global-array math: inside the forward each DP group's ``idx``/``slots``
    are group-local, so a global ``take``/scatter would resolve group g>0's
    lanes against group 0's rows.  The backward therefore shard_maps with
    the same specs — each cache shard owns its lanes' table gradient
    (psum-ed over the DP axes, since every group writes the same table),
    streamed/weight gradients stay group-local, and the per-lane h0 needed
    for dw is psum-ed over the cache axis exactly like the forward output.
    """
    cache_table, streamed, slots, idx, w, local_shards = res
    f0 = jax.dtypes.float0
    zslots = np.zeros(slots.shape, f0)
    zidx = np.zeros(idx.shape, f0)
    zls = np.zeros(local_shards.shape, f0)

    if mesh is not None and shard_axis in mesh.axis_names:
        from jax.sharding import PartitionSpec as P
        from repro.launch.sharding import shard_map_compat

        n = mesh.shape[shard_axis]
        rps = cache_table.shape[0] // n
        dp, bspec = _dp_spec(mesh, shard_axis)

        def body(tbl, st, sl, ix, ww, gg):
            from repro.kernels.cache_lookup import (shard_lane_weights,
                                                    shard_slot_map)

            shard = jax.lax.axis_index(shard_axis)
            gg = gg.astype(jnp.float32)
            lane_slots = jnp.take(sl.astype(jnp.int32), ix, axis=0)  # [b, k]
            # the lane-claim rule (owner for hits, shard 0 for misses) and
            # the local-row mapping come from the SAME helpers the forward
            # kernel uses — forward and backward cannot desync
            lane_local = shard_slot_map(lane_slots, shard, rps)
            own = lane_local >= 0
            miss = lane_slots < 0
            claim = shard_lane_weights(jnp.ones_like(lane_slots, jnp.float32),
                                       lane_slots, shard, rps)       # 0/1
            rows_own = jnp.take(tbl, jnp.maximum(lane_local, 0), axis=0)
            rows_miss = jnp.take(st, ix, axis=0)
            # each lane's h0 comes from exactly one shard (the claim mask) —
            # the psum below reassembles it, like the forward
            h0_part = jnp.where(own[..., None],
                                rows_own.astype(jnp.float32),
                                rows_miss.astype(jnp.float32)) * claim[..., None]
            dw = jax.lax.psum(jnp.einsum("bd,bkd->bk", gg, h0_part),
                              shard_axis).astype(ww.dtype)
            dlane = ww.astype(jnp.float32)[..., None] * gg[:, None, :]
            dcache = jnp.zeros((rps, tbl.shape[1]), tbl.dtype).at[
                jnp.maximum(lane_local, 0)].add(
                jnp.where(own[..., None], dlane, 0.0).astype(tbl.dtype))
            if dp:
                dcache = jax.lax.psum(dcache, dp)
            # miss lanes are shard-independent: every shard computes the
            # identical (replicated-over-cache-axis) streamed gradient
            dstreamed = jnp.zeros(st.shape, st.dtype).at[ix].add(
                jnp.where(miss[..., None], dlane, 0.0).astype(st.dtype))
            return dcache, dstreamed, dw

        fn = shard_map_compat(
            body, mesh=mesh,
            in_specs=(P(shard_axis, None), P(bspec, None), P(bspec),
                      P(bspec, None), P(bspec, None), P(bspec, None)),
            out_specs=(P(shard_axis, None), P(bspec, None), P(bspec, None)))
        dcache, dstreamed, dw = fn(cache_table, streamed, slots, idx, w, g)
        return dcache, dstreamed, zslots, zidx, dw, zls

    g = g.astype(jnp.float32)
    lane_slots = jnp.take(slots.astype(jnp.int32), idx, axis=0)     # [B, K]
    hit = (lane_slots >= 0)[..., None]
    rows_hit = jnp.take(cache_table, jnp.clip(lane_slots, 0), axis=0)
    rows_miss = jnp.take(streamed, idx, axis=0)
    h0 = jnp.where(hit, rows_hit, rows_miss).astype(jnp.float32)    # [B, K, D]
    dw = jnp.einsum("bd,bkd->bk", g, h0).astype(w.dtype)
    dlane = w.astype(jnp.float32)[..., None] * g[:, None, :]        # [B, K, D]
    dcache = jnp.zeros(cache_table.shape, cache_table.dtype).at[
        jnp.clip(lane_slots, 0)].add(
        jnp.where(hit, dlane, 0.0).astype(cache_table.dtype))
    dstreamed = jnp.zeros(streamed.shape, streamed.dtype).at[idx].add(
        jnp.where(hit, 0.0, dlane).astype(streamed.dtype))
    return dcache, dstreamed, zslots, zidx, dw, zls


_fused.defvjp(_fused_fwd, _fused_bwd)


def dp_group_count(mesh, shard_axis: Optional[str]) -> int:
    """Number of data-parallel groups the fused op's batch operands span.

    One rule for the op, the engine's collation and the dry-run's batch
    structs: the groups are the product of the mesh's batch axes minus the
    cache axis (1 without a mesh) — the length a ``local_shards`` home-shard
    vector must have.
    """
    if mesh is None:
        return 1
    dp, _ = _dp_spec(mesh, shard_axis)
    g = 1
    for a in dp:
        g *= mesh.shape[a]
    return g


@functools.partial(jax.jit,
                   static_argnames=("impl", "block_d", "mesh", "shard_axis",
                                    "local_shard"))
def cache_lookup_agg(cache_table: jax.Array, streamed: jax.Array,
                     slots: jax.Array, idx: jax.Array, w: jax.Array,
                     impl: str = "pallas", block_d: int = 512,
                     mesh=None, shard_axis: Optional[str] = None,
                     local_shard: Optional[int] = None,
                     local_shards=None) -> jax.Array:
    """Fused GNS input layer: cache/streamed select + gather-agg.  [B,D] f32.

    Differentiable (custom VJP) so the train step's backward flows into the
    cache table / streamed rows / weights.  Pass ``mesh`` + ``shard_axis``
    (both static) to run the shard-aware path: per-device kernel on the
    local table shard, psum over the cache axis.  ``local_shard`` (static;
    only meaningful with a mesh) switches to the psum-free local fast path —
    the caller must hold the all-hits-local contract established by
    ``FeatureStore.assemble_input`` (which is where the value comes from).

    ``local_shards`` is the TRACED variant of the same gate: an int32 vector
    with one home shard per DP group (-1 = psum path for that group),
    sharded over the DP axes inside the op.  Because it is a device operand
    rather than a static argument, one compiled step serves batches with any
    mix of home shards without retracing — the DP > 1 regime.  Mutually
    exclusive with ``local_shard`` (the static argument wins).
    """
    d = cache_table.shape[1]
    bd = min(block_d, d)
    while d % bd:
        bd -= 1
    if mesh is None or shard_axis not in getattr(mesh, "axis_names", ()):
        local_shard = None          # nothing to skip without a cache axis
        local_shards = None
    if local_shard is not None:
        local_shards = None         # static gate wins (legacy callers)
    dynamic = local_shards is not None
    if dynamic:
        groups = dp_group_count(mesh, shard_axis)
        local_shards = jnp.asarray(local_shards, jnp.int32).reshape(-1)
        assert local_shards.shape == (groups,), (
            f"local_shards must carry one home shard per DP group "
            f"({groups}), got shape {local_shards.shape}")
    else:
        local_shards = jnp.full((1,), -1, jnp.int32)   # placeholder operand
    return _fused(cache_table, streamed, slots.astype(jnp.int32),
                  idx.astype(jnp.int32), w, local_shards, impl, bd, mesh,
                  shard_axis, local_shard, dynamic)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "impl", "block_q", "block_k"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None, impl: str = "pallas",
                    block_q: int = 128, block_k: int = 128) -> jax.Array:
    """Blocked attention; pads seq dims to block multiples and unpads."""
    if impl == "reference":
        return ref.mha_ref(q, k, v, causal=causal, window=window, scale=scale)
    sq, sk = q.shape[2], k.shape[2]
    bq = min(block_q, max(16, 1 << (sq - 1).bit_length()))
    bk = min(block_k, max(16, 1 << (sk - 1).bit_length()))
    qp = _pad_axis(q, 2, bq)
    kp = _pad_axis(k, 2, bk)
    vp = _pad_axis(v, 2, bk)
    out = flash_attention_pallas(qp, kp, vp, causal=causal, window=window,
                                 scale=scale, block_q=bq, block_k=bk,
                                 kv_len=sk, q_offset=sk - sq,
                                 interpret=_interpret())
    return out[:, :, :sq, :]

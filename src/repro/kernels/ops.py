"""Jit'd public wrappers around the Pallas kernels.

Dispatch policy: on TPU the compiled Pallas kernels run natively; on CPU
(this container, CI) they run in ``interpret=True`` mode — same kernel body,
Python-evaluated — so every test exercises the real kernel logic.  Callers
can force the reference path with ``impl="reference"`` (the dry-run uses it:
interpret-mode Pallas cannot be lowered into an XLA-for-TPU HLO from a CPU
host, and the reference path gives XLA the fusion freedom the roofline
analysis measures).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.cache_lookup import cache_lookup_agg_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gather_agg import gather_agg_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_axis(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("impl", "block_d"))
def gather_agg(feat: jax.Array, idx: jax.Array, w: jax.Array,
               impl: str = "pallas", block_d: int = 512) -> jax.Array:
    """Fused gather + weighted aggregation (GNS hot-spot).  [B,D] f32."""
    if impl == "reference":
        return ref.gather_agg_ref(feat, idx, w)
    d = feat.shape[1]
    bd = min(block_d, d)
    while d % bd:
        bd -= 1
    return gather_agg_pallas(feat, idx, w, block_d=bd, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("impl", "block_d"))
def cache_lookup_agg(cache_table: jax.Array, streamed: jax.Array,
                     slots: jax.Array, idx: jax.Array, w: jax.Array,
                     impl: str = "pallas", block_d: int = 512) -> jax.Array:
    """Fused GNS input layer: cache/streamed select + gather-agg.  [B,D] f32."""
    if impl == "reference":
        return ref.cache_lookup_agg_ref(cache_table, streamed, slots, idx, w)
    d = cache_table.shape[1]
    bd = min(block_d, d)
    while d % bd:
        bd -= 1
    return cache_lookup_agg_pallas(cache_table, streamed, slots, idx, w,
                                   block_d=bd, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "impl", "block_q", "block_k"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None, impl: str = "pallas",
                    block_q: int = 128, block_k: int = 128) -> jax.Array:
    """Blocked attention; pads seq dims to block multiples and unpads."""
    if impl == "reference":
        return ref.mha_ref(q, k, v, causal=causal, window=window, scale=scale)
    sq, sk = q.shape[2], k.shape[2]
    bq = min(block_q, max(16, 1 << (sq - 1).bit_length()))
    bk = min(block_k, max(16, 1 << (sk - 1).bit_length()))
    qp = _pad_axis(q, 2, bq)
    kp = _pad_axis(k, 2, bk)
    vp = _pad_axis(v, 2, bk)
    out = flash_attention_pallas(qp, kp, vp, causal=causal, window=window,
                                 scale=scale, block_q=bq, block_k=bk,
                                 kv_len=sk, q_offset=sk - sq,
                                 interpret=_interpret())
    return out[:, :, :sq, :]

"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def gather_agg_ref(feat: jax.Array, idx: jax.Array, w: jax.Array) -> jax.Array:
    """out[b] = sum_k w[b,k] * feat[idx[b,k]]  (f32 accumulate)."""
    gathered = jnp.take(feat, idx, axis=0).astype(jnp.float32)   # [B, K, D]
    return jnp.einsum("bk,bkd->bd", w.astype(jnp.float32), gathered)


def cache_lookup_agg_ref(cache_table: jax.Array, streamed: jax.Array,
                         slots: jax.Array, idx: jax.Array,
                         w: jax.Array) -> jax.Array:
    """Fused cache-lookup + first-layer aggregation oracle.

    out[b] = Σ_k w[b,k] · h0[idx[b,k]] with
    h0[r] = slots[r] >= 0 ? cache_table[slots[r]] : streamed[r].

    Accumulates sequentially over k in f32 — the same association order as
    the Pallas kernel's K-innermost grid — so interpret-mode parity is
    *bitwise* whenever the per-step products are exactly representable
    (XLA:CPU contracts mul+add to FMA, which only differs from separate
    rounding when the product is inexact), and within 1 ulp otherwise.
    """
    s = jnp.take(slots.astype(jnp.int32), idx, axis=0)            # [B, K]
    hit_rows = jnp.take(cache_table, jnp.clip(s, 0), axis=0)      # [B, K, D]
    miss_rows = jnp.take(streamed, idx, axis=0)                   # [B, K, D]
    rows = jnp.where((s >= 0)[..., None], hit_rows,
                     miss_rows).astype(jnp.float32)
    wf = w.astype(jnp.float32)
    out = jnp.zeros((idx.shape[0], cache_table.shape[1]), jnp.float32)
    for k in range(idx.shape[1]):        # static K; matches kernel accum order
        out = out + wf[:, k:k + 1] * rows[:, k]
    return out


def mha_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
            causal: bool = True, window: Optional[int] = None,
            scale: Optional[float] = None,
            bias: Optional[jax.Array] = None,
            kv_len=None, q_pos=None, kv_pos=None) -> jax.Array:
    """Reference multi-head attention with GQA, causal and sliding-window.

    q: [B, Hq, Sq, Dh]; k, v: [B, Hkv, Sk, Dh] with Hq % Hkv == 0.
    ``kv_len`` (static int or traced scalar) masks keys at positions >= it
    and end-aligns the queries to it (decode with a partially-filled cache).
    ``q_pos`` [Sq] / ``kv_pos`` [Sk]: explicit absolute positions for
    ring-buffer (SWA) caches, where slot order is not position order; slots
    with kv_pos < 0 are unwritten and masked.  Overrides kv_len alignment.
    Computes in f32, returns q.dtype.  Sharding is decided by the CALLER
    (models/attention.py wraps this in shard_map on the production mesh) —
    the oracle itself stays mesh-free.
    """
    b, hq, sq, dh = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    if scale is None:
        scale = dh ** -0.5
    # GQA-native grouped einsum: never materialize k/v at Hq heads — the
    # repeat would make backward's dk/dv partial sums Hq/Hkv times larger
    # (measured as a per-layer all-reduce storm, EXPERIMENTS.md §Perf it. 0).
    qf = (q.astype(jnp.float32) * scale).reshape(b, hkv, g, sq, dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    from repro.kernels.probe_ctx import linear_attention_on
    if linear_attention_on() and sq > 1:
        # flash-kernel HBM-traffic stand-in (see kernels/probe_ctx.py):
        # q/k/v read once, out written once; O(S) intermediates only.
        # (single-token decode keeps the real path: reading the whole KV
        # cache per step IS the memory cost of decoding.)
        kv = jnp.einsum("bnkd,bnke->bnde", kf, vf)          # [b,n,dh,dv]
        out = jnp.einsum("bngqd,bnde->bngqe", qf, kv)
        return out.reshape(b, hq, sq, v.shape[-1]).astype(q.dtype)

    s = jnp.einsum("bngqd,bnkd->bngqk", qf, kf)
    if bias is not None:                       # must broadcast to [b,n,g,q,k]
        s = s + bias
    if q_pos is not None:
        iq = q_pos[:, None]
        jk = kv_pos[None, :]
        mask = jk >= 0                         # unwritten ring slots
        if causal:
            mask &= jk <= iq
        if window is not None:
            mask &= jk > iq - window
    else:
        end = sk if kv_len is None else kv_len
        iq = jnp.arange(sq)[:, None] + (end - sq)  # align ends (decode-friendly)
        jk = jnp.arange(sk)[None, :]
        mask = jk < end                            # padded / unwritten cache rows
        if causal:
            mask &= jk <= iq
        if window is not None:
            mask &= jk > iq - window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)        # fully-masked rows -> 0
    out = jnp.einsum("bngqk,bnkd->bngqd", p, vf)
    out = out.reshape(b, hq, sq, v.shape[-1])  # dv != dqk in MLA
    return out.astype(q.dtype)

"""Pallas TPU kernel: fused row-gather + weighted segment aggregation.

This is the GNS minibatch hot-spot (DESIGN.md §2): the padded-block layout
turns the GraphSAGE neighbor aggregation into

    out[b, :] = Σ_k  w[b, k] · feat[idx[b, k], :]

i.e. a gather of K rows per destination followed by a weighted reduction.
On GPU the paper relies on cuSPARSE-style SpMM; the TPU-native adaptation is
a *scalar-prefetch gather*: the neighbor indices are scalar-prefetched (SMEM)
and drive the BlockSpec ``index_map`` of the feature operand, so the Pallas
pipeline DMAs exactly the needed feature rows HBM→VMEM, double-buffered, one
(1, block_d) tile per grid step.  The weighted accumulation runs on the VPU
while the next row is in flight.

Grid: ``(B, num_d_blocks, K)`` — K innermost so the output tile stays
resident in VMEM across the accumulation; the feature table itself never
materializes in VMEM (only gathered rows do), which is what makes a
device-cache table of 10⁵–10⁶ rows workable.

Memory/roofline: per output row this moves K·block_d·4B of features and
writes block_d·4B — arithmetic intensity ≈ 2 FLOPs/4 bytes; the kernel is
HBM-bandwidth-bound by construction, matching the paper's data-movement
framing.  Block sizes default to the full feature dim (≤ 2048 lanes ≈ 8 KB
per buffer), far under VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, w_ref, feat_ref, out_ref, *, num_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    b = pl.program_id(0)
    w = w_ref[b, k]
    # feat_ref holds the (1, block_d) tile of row idx[b, k], DMA'd by the
    # index_map below; accumulate on the VPU.
    out_ref[...] += w * feat_ref[...].astype(out_ref.dtype)


def gather_agg_pallas(feat: jax.Array, idx: jax.Array, w: jax.Array,
                      block_d: int = 2048, interpret: bool = False) -> jax.Array:
    """out[b] = sum_k w[b,k] * feat[idx[b,k]].

    Args:
      feat: [N, D] feature/cache table (f32 or bf16).
      idx:  [B, K] int32 row indices (padded lanes must carry w == 0).
      w:    [B, K] f32 weights.
    Returns [B, D] f32.
    """
    n, d = feat.shape
    bsz, num_k = idx.shape
    block_d = min(block_d, d)
    while d % block_d:          # largest divisor <= requested block
        block_d -= 1
    grid = (bsz, d // block_d, num_k)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,               # idx rides in SMEM
        grid=grid,
        in_specs=[
            # weights: full (B, K) in VMEM — tiny (4·B·K bytes)
            pl.BlockSpec((bsz, num_k), lambda b, db, k, idx_ref: (0, 0)),
            # feature rows: gathered by the scalar-prefetched indices
            pl.BlockSpec((1, block_d), lambda b, db, k, idx_ref: (idx_ref[b, k], db)),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda b, db, k, idx_ref: (b, db)),
    )
    fn = pl.pallas_call(
        functools.partial(_kernel, num_k=num_k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, d), jnp.float32),
        interpret=interpret,
    )
    return fn(idx.astype(jnp.int32), w.astype(jnp.float32), feat)

"""Probe context: swap attention inners for HBM-traffic stand-ins.

The dry-run's memory roofline term comes from cost_analysis of a CPU-backend
compile, where the reference attention's softmax chain materializes every
[B,H,S,S] intermediate in "HBM".  On the TPU target those live in VMEM
inside the flash kernel (kernels/flash_attention.py); counting them as HBM
traffic would overstate the memory term ~10x (EXPERIMENTS.md §Dry-run "cost
accounting").

Under ``linear_attention_traffic()``, mha_ref computes a *linear-cost*
stand-in with exactly the flash kernel's HBM footprint — q, k, v read once,
out written once — so the probe's 'bytes accessed' matches the kernelized
TPU execution.  FLOPs are taken from the un-switched reference pass (the
kernel really does perform the S^2 matmuls), collectives are identical in
both (attention is shard_map-local).  Only train/prefill attention is
switched; decode reads its whole KV cache every step — that reference
traffic is real and stays.
"""
from __future__ import annotations

import contextlib
import threading

_state = threading.local()


def linear_attention_on() -> bool:
    return getattr(_state, "linear", False)


@contextlib.contextmanager
def linear_attention_traffic(on: bool = True):
    prev = linear_attention_on()
    _state.linear = on
    try:
        yield
    finally:
        _state.linear = prev

"""Runtime concurrency annotations + the debug-mode lock sanitizer.

This module is the *runtime half* of ``gnscheck`` (the static half lives in
the sibling pass modules and is driven by ``python -m repro.analysis``).  It
is deliberately stdlib-only so the annotated subsystems — ``featurestore``,
``serve``, ``core.pipeline`` — stay importable without jax.

Two annotations form the registry both halves read:

* :func:`guarded_by` — class decorator declaring which instance attributes
  are protected by which lock attribute::

      @guarded_by("_lock", "_shadow", "_thread", writes_only=("_live",))
      class FeatureStore: ...

  ``writes_only`` attributes follow the publish-subscribe idiom: every WRITE
  must hold the lock (so the reference swap is atomic w.r.t. other writers)
  while lock-free snapshot READS are the documented contract.

* :func:`holds_lock` — method decorator asserting the method is only ever
  entered with the named lock already held (callee-side of a split-locking
  protocol).

The static pass proves every read/write of a guarded attribute is dominated
by ``with self.<lock>`` (see ``repro.analysis.locks``).  The runtime
sanitizer — enabled under pytest via ``tests/conftest.py`` or the
``REPRO_LOCK_SANITIZER=1`` environment variable — closes the gap static
analysis can't: it wraps the named locks in ownership-tracking proxies, makes
any unguarded *write* to a guarded attribute raise
:class:`LockDisciplineError` at the faulting line (instead of losing a
stress-test lottery), and records the global lock-acquisition order, raising
:class:`LockOrderError` the first time two locks are ever taken in opposite
orders — the PR-5 race class as a deterministic CI failure.
"""
from __future__ import annotations

import functools
import os
import threading
from typing import Dict, Tuple

__all__ = [
    "guarded_by", "holds_lock", "enable_sanitizer", "sanitizer_enabled",
    "reset_lock_order", "TrackedLock", "LockDisciplineError", "LockOrderError",
]


class LockDisciplineError(AssertionError):
    """A guarded attribute was written without holding its declared lock."""


class LockOrderError(AssertionError):
    """Two locks were acquired in an order that closes a wait-for cycle."""


_enabled = os.environ.get("REPRO_LOCK_SANITIZER", "") not in ("", "0")


def enable_sanitizer(on: bool = True) -> None:
    """Globally switch the runtime checks (call before instances exist:
    locks are wrapped at assignment time, in ``__init__``)."""
    global _enabled
    _enabled = on


def sanitizer_enabled() -> bool:
    return _enabled


# ---------------------------------------------------------------------------
# lock-order graph (labels are `Class.attr`; edges mean "held while taking")
# ---------------------------------------------------------------------------

_held = threading.local()          # per-thread stack of lock labels
_order_mu = threading.Lock()
_order: Dict[str, set] = {}        # label -> labels acquired while holding it


def reset_lock_order() -> None:
    """Clear the recorded acquisition-order graph (test isolation helper)."""
    with _order_mu:
        _order.clear()


def _reaches(src: str, dst: str) -> bool:
    """True if ``dst`` is reachable from ``src`` in the order graph."""
    stack, seen = [src], set()
    while stack:
        n = stack.pop()
        if n == dst:
            return True
        if n in seen:
            continue
        seen.add(n)
        stack.extend(_order.get(n, ()))
    return False


def _record_order(prev: str, label: str) -> None:
    if prev == label:
        # same-label nesting is two *instances* of one class (per-instance
        # ordering is out of scope for a class-granular graph) — skip rather
        # than flag every legitimate pairwise use as a self-cycle
        return
    with _order_mu:
        edges = _order.setdefault(prev, set())
        if label in edges:
            return
        if _reaches(label, prev):
            raise LockOrderError(
                f"lock-order cycle: acquired {label!r} while holding "
                f"{prev!r}, but {prev!r} has (transitively) been acquired "
                f"while holding {label!r} — a deadlock waiting for the "
                f"right interleaving")
        edges.add(label)


class TrackedLock:
    """Ownership/ordering proxy over a ``threading.Lock`` (or RLock).

    Supports the subset of the lock protocol the repo uses (``with``,
    ``acquire``/``release``, ``locked``) plus :meth:`held_by_current_thread`
    for the sanitizer's ownership asserts.
    """

    __slots__ = ("_lock", "label", "_owner")

    def __init__(self, lock, label: str):
        self._lock = lock
        self.label = label
        self._owner = None          # thread ident holding it (approximate
                                    # for RLocks: last acquirer)

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        ok = self._lock.acquire(blocking, timeout)
        if ok:
            stack = getattr(_held, "stack", None)
            if stack is None:
                stack = _held.stack = []
            try:
                if stack:
                    _record_order(stack[-1], self.label)
            except LockOrderError:
                self._lock.release()
                raise
            self._owner = threading.get_ident()
            stack.append(self.label)
        return ok

    def release(self) -> None:
        stack = getattr(_held, "stack", None)
        if stack and self.label in stack:
            # remove the most recent occurrence (supports non-LIFO release)
            for i in range(len(stack) - 1, -1, -1):
                if stack[i] == self.label:
                    del stack[i]
                    break
        if self._owner == threading.get_ident():
            self._owner = None
        self._lock.release()

    def locked(self) -> bool:
        return self._lock.locked()

    def held_by_current_thread(self) -> bool:
        return self._owner == threading.get_ident()

    def __enter__(self) -> "TrackedLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:
        return f"TrackedLock({self.label!r}, owner={self._owner})"


_RAW_LOCK_TYPES: Tuple[type, ...] = (type(threading.Lock()),
                                     type(threading.RLock()))


# ---------------------------------------------------------------------------
# annotations
# ---------------------------------------------------------------------------

def guarded_by(lock_name: str, *attrs: str, writes_only: Tuple[str, ...] = ()):
    """Class decorator: declare ``attrs`` protected by ``self.<lock_name>``.

    ``attrs`` require the lock for reads AND writes; ``writes_only`` attrs
    require it for writes (lock-free snapshot reads are the contract).  The
    static pass enforces both; the runtime sanitizer enforces writes (plain
    attribute reads cannot be intercepted without a prohibitive
    ``__getattribute__`` override).
    """

    def deco(cls):
        guarded = dict(getattr(cls, "__gnscheck_guarded__", {}))
        for a in attrs:
            guarded[a] = (lock_name, "rw")
        for a in writes_only:
            guarded[a] = (lock_name, "w")
        cls.__gnscheck_guarded__ = guarded
        lock_attrs = {ln for ln, _ in guarded.values()}

        orig_setattr = cls.__setattr__

        def __setattr__(self, name, value):
            if _enabled:
                if (name in lock_attrs
                        and isinstance(value, _RAW_LOCK_TYPES)):
                    value = TrackedLock(
                        value, f"{type(self).__name__}.{name}")
                info = guarded.get(name)
                if (info is not None
                        and self.__dict__.get("_gnscheck_ready", False)):
                    lk = self.__dict__.get(info[0])
                    if (isinstance(lk, TrackedLock)
                            and not lk.held_by_current_thread()):
                        raise LockDisciplineError(
                            f"unguarded write to {type(self).__name__}."
                            f"{name} (guarded by {info[0]!r}) on thread "
                            f"{threading.current_thread().name!r}")
            orig_setattr(self, name, value)

        cls.__setattr__ = __setattr__

        orig_init = cls.__init__

        @functools.wraps(orig_init)
        def __init__(self, *a, **k):
            orig_init(self, *a, **k)
            # construction happens-before publication: checks arm only
            # after __init__ returns
            object.__setattr__(self, "_gnscheck_ready", True)

        cls.__init__ = __init__
        return cls

    return deco


def holds_lock(lock_name: str):
    """Method decorator: the caller must already hold ``self.<lock_name>``.

    The static pass treats the whole body as lock-dominated; in sanitizer
    mode entry without ownership raises :class:`LockDisciplineError`.
    """

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(self, *a, **k):
            if _enabled:
                lk = getattr(self, lock_name, None)
                if (isinstance(lk, TrackedLock)
                        and not lk.held_by_current_thread()):
                    raise LockDisciplineError(
                        f"{type(self).__name__}.{fn.__name__} requires "
                        f"{lock_name!r} held on entry")
            return fn(self, *a, **k)

        wrapper.__gnscheck_holds_lock__ = lock_name
        return wrapper

    return deco

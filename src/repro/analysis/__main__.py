"""CLI driver: ``python -m repro.analysis`` (a.k.a. ``gnscheck``).

Exit codes: 0 clean (or all violations baselined), 1 new violations or
stale baseline entries (ratchet breach), 2 usage error.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List

from .common import RepoIndex, Violation


def run_passes(index: RepoIndex) -> List[Violation]:
    # imported lazily so `import repro.analysis` stays cheap for the
    # runtime-annotation consumers
    from . import generation, locks, meterlint, retrace, trace_purity
    out: List[Violation] = []
    for mod in (trace_purity, locks, generation, retrace, meterlint):
        out.extend(mod.run(index))
    out.sort(key=lambda v: (v.path, v.line, v.rule))
    return out


def main(argv: List[str] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="gnscheck",
        description="repo-specific static analysis: trace purity, lock "
                    "discipline, generation pinning, retrace hazards")
    ap.add_argument("--root", default=None,
                    help="scan root (default: the repro package this "
                         "module was imported from)")
    ap.add_argument("--baseline", default=None,
                    help="baseline file; findings resolve against it "
                         "(new violation OR stale entry -> exit 1)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="regenerate --baseline from current findings")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable output")
    ap.add_argument("--strict-warnings", action="store_true",
                    help="warnings also affect the exit code")
    args = ap.parse_args(argv)

    if args.root is not None:
        root = Path(args.root)
        prefix = root.name
    else:
        root = Path(__file__).resolve().parents[1]   # .../src/repro
        prefix = "repro"
    if not root.is_dir():
        print(f"gnscheck: scan root {root} is not a directory",
              file=sys.stderr)
        return 2

    index = RepoIndex(root, package_prefix=prefix)
    violations = run_passes(index)
    errors = [v for v in violations if v.severity != "warning"]
    warnings = [v for v in violations if v.severity == "warning"]

    from . import baseline as bl

    if args.write_baseline:
        if not args.baseline:
            print("gnscheck: --write-baseline requires --baseline",
                  file=sys.stderr)
            return 2
        n = bl.write(Path(args.baseline), violations)
        print(f"gnscheck: wrote {n} baseline entries to {args.baseline}")
        for v in warnings:
            print(v.render())
        return 0

    new, stale = (errors, [])
    if args.baseline:
        new, stale = bl.compare(violations, bl.load(Path(args.baseline)))

    if args.as_json:
        print(json.dumps({
            "violations": [vars(v) for v in violations],
            "new": [vars(v) for v in new],
            "stale_baseline": stale,
        }, indent=2, default=str))
    else:
        for v in violations:
            baselined = args.baseline and v.severity != "warning" \
                and v not in new
            suffix = "  [baselined]" if baselined else ""
            print(v.render() + suffix)
        for k in stale:
            print(f"baseline: stale entry (violation fixed but not removed "
                  f"from baseline): {k}")
        n_base = len(errors) - len(new)
        print(f"gnscheck: {len(errors)} error(s) "
              f"({len(new)} new, {n_base} baselined), "
              f"{len(warnings)} warning(s), {len(stale)} stale baseline "
              f"entr{'y' if len(stale) == 1 else 'ies'}")

    failed = bool(new) or bool(stale)
    if args.strict_warnings and warnings:
        failed = True
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())

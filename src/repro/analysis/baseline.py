"""Baseline ratchet — same contract as the coverage floor, for findings.

The checked-in baseline is the set of consciously-tolerated violation keys
(line-number-free: ``rule|path|symbol|detail``, with ``#N`` suffixes for
repeats).  Comparison is two-sided:

* a violation NOT in the baseline is **new** → fail (the pass tightens);
* a baseline entry with no matching violation is **stale** → fail (the file
  may only shrink; fixing a violation must delete its entry, so the ratchet
  can't silently slacken).

``--write-baseline`` regenerates the file from the current findings.
Warnings are never baselined — they don't affect the exit code.
"""
from __future__ import annotations

import collections
from pathlib import Path
from typing import Dict, List, Tuple

from .common import Violation

HEADER = (
    "# gnscheck baseline — consciously tolerated violations.\n"
    "# This file is a ratchet: entries may be REMOVED (by fixing the\n"
    "# violation), never added. New violations fail CI; stale entries\n"
    "# fail CI. Regenerate with: python -m repro.analysis --write-baseline\n")


def keyed(violations: List[Violation]) -> List[str]:
    """Stable keys with #N disambiguation for identical repeats."""
    counts: Dict[str, int] = collections.Counter()
    out: List[str] = []
    for v in violations:
        if v.severity == "warning":
            continue
        k = v.key()
        counts[k] += 1
        out.append(k if counts[k] == 1 else f"{k}#{counts[k]}")
    return out


def load(path: Path) -> List[str]:
    if not path.exists():
        return []
    out = []
    for line in path.read_text().splitlines():
        line = line.strip()
        if line and not line.startswith("#"):
            out.append(line)
    return out


def write(path: Path, violations: List[Violation]) -> int:
    keys = sorted(keyed(violations))
    path.write_text(HEADER + "".join(k + "\n" for k in keys))
    return len(keys)


def compare(violations: List[Violation], baseline: List[str]
            ) -> Tuple[List[Violation], List[str]]:
    """-> (new_violations, stale_baseline_entries)."""
    current = keyed(violations)
    base_set = set(baseline)
    cur_set = set(current)
    new = []
    counts: Dict[str, int] = collections.Counter()
    for v in violations:
        if v.severity == "warning":
            continue
        k = v.key()
        counts[k] += 1
        kk = k if counts[k] == 1 else f"{k}#{counts[k]}"
        if kk not in base_set:
            new.append(v)
    stale = sorted(base_set - cur_set)
    return new, stale

"""Error-tier lint — TrafficMeter pairing.

The whole paper is an argument about *bytes moved between tiers*; the repo
encodes that in ``TrafficMeter``.  A host↔device transfer that skips the
books silently corrupts every ``upload_ratio`` / ``bytes_per_batch``
acceptance number downstream, so: any function in the tier-transfer
packages (``featurestore/``, ``sampling/``, ``gns/``, ``serve/``) that
issues a device transfer (``jax.device_put``, ``jnp.asarray``/``jnp.array``
on host data, ``make_array_from_callback``) must also touch a meter in the
same function body.

Error tier since the fabric PR: every engine transfer now funnels through
``GNSEngine._put_batch`` (metered), so an unpaired transfer is a
regression, not a nag — new code books its copy or lands behind an
explicit suppression/baseline entry.
"""
from __future__ import annotations

import ast
from typing import List

from .common import RepoIndex, Violation, dotted, parents

TRANSFER_CALLS = {"device_put", "make_array_from_callback",
                  "make_array_from_single_device_arrays"}
ARRAY_CTORS = {"jnp.asarray", "jnp.array"}
SCOPE_PREFIXES = ("repro/featurestore/", "repro/sampling/",
                  "repro/gns/", "repro/serve/", "repro/stream/",
                  "repro/rpc/",
                  "featurestore/", "sampling/", "gns/", "serve/", "stream/",
                  "rpc/")
# traced modules: jnp.asarray there is device-side math, not a tier transfer
EXCLUDE_SUFFIXES = ("kernels.py", "ref.py", "rng.py", "ops.py")
METER_MARKERS = {"meter", "bytes_cache_upload", "bytes_adj_upload",
                 "bytes_gather", "account", "record_upload"}


def _fn_has_meter(fn: ast.AST) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Attribute) and (
                node.attr in METER_MARKERS
                or node.attr.startswith("bytes_")
                or node.attr.startswith("t_")):
            return True
        if isinstance(node, ast.Name) and node.id in METER_MARKERS:
            return True
        if isinstance(node, ast.arg) and node.arg == "meter":
            return True
    return False


def run(index: RepoIndex) -> List[Violation]:
    out: List[Violation] = []
    for mi in index.modules.values():
        # scoped to the tier-transfer packages inside the repro tree; an
        # arbitrary scan root (the analyzer's own test fixtures) is all in
        # scope — there is no package structure to scope by
        in_repro = mi.name.split(".")[0] == "repro"
        if in_repro and not mi.path.startswith(SCOPE_PREFIXES):
            continue
        if mi.path.endswith(EXCLUDE_SUFFIXES):
            continue
        for local, fi in mi.functions.items():
            fn = fi.node
            transfers: List[ast.Call] = []
            for node in ast.walk(fn):
                if node is not fn and isinstance(
                        node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                if not isinstance(node, ast.Call):
                    continue
                d = dotted(node.func)
                if d is None:
                    continue
                tail = d.split(".")[-1]
                if tail in TRANSFER_CALLS or d in ARRAY_CTORS:
                    transfers.append(node)
            if not transfers:
                continue
            if _fn_has_meter(fn):
                continue
            first = transfers[0]
            sup = mi.suppressed(first.lineno)
            if "meter-unpaired-transfer" in sup or "*" in sup:
                continue
            out.append(Violation(
                rule="meter-unpaired-transfer", path=mi.path,
                line=first.lineno, symbol=local,
                message=(f"{len(transfers)} device transfer(s) "
                         f"(`{dotted(first.func)}`) with no TrafficMeter "
                         "accounting in the same function — unbooked "
                         "tier traffic"),
                detail=local, severity="error"))
    return out

"""Pass 4 — retrace hazards.

PR 4/5 assert *dynamically* (via compile counters) that steady state incurs
zero recompilation; this pass guards the same property *statically*:

``retrace-scalar-arg``
    a jit root whose parameter is annotated / defaulted as a Python scalar
    (``int``/``bool``/``float``/``str``) but is NOT listed in
    ``static_argnums``/``static_argnames``.  Python scalars hash into the
    jit cache key only when static; passed dynamically they are weak-typed
    tracers and every distinct *value that changes rank/shape decisions*
    upstream means a silent retrace.
``retrace-scalar-flow``
    ``len(...)`` / ``int(...)`` / ``.item()`` / ``.shape[...]`` expressions
    used directly as arguments at a call site of a known-jitted callable —
    runtime-derived scalars entering a traced signature positionally.
``retrace-pad-registry``
    structural markers over the shape-padding sites the zero-recompile
    guarantee rests on.  Each registry entry pins a function to a required
    source idiom; if a refactor drops the idiom (e.g. the power-of-two
    rounding in ``build_device_cache_adj``), the pass fails *here* instead
    of the serving benchmark failing three PRs later.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set, Tuple

from .common import RepoIndex, Violation, dotted, find_trace_roots

SCALAR_ANNOTS = {"int", "bool", "str"}   # float params are usually traced
                                         # weights (lr, temp) — exempt

# (path-suffix, function-local-name, required-substring, reason)
PAD_REGISTRY: List[Tuple[str, str, str, str]] = [
    ("sampling/adjacency.py", "build_device_cache_adj", "bit_length",
     "DeviceCacheAdj capacity must stay power-of-two padded "
     "(zero-recompile across refreshes)"),
    ("serve/batcher.py", "MicroBatcher.bucket_for", "self.buckets",
     "serve batches must quantize to the fixed bucket ladder"),
    ("featurestore/store.py", "CacheConfig.size", "%",
     "cache size must stay quantized (device-count multiple)"),
]


def _scalar_annotation(arg: ast.arg) -> Optional[str]:
    a = arg.annotation
    if a is None:
        return None
    d = dotted(a)
    if d in SCALAR_ANNOTS:
        return d
    # Optional[int] / int | None
    if isinstance(a, ast.Subscript) and dotted(a.value) in ("Optional",
                                                            "typing.Optional"):
        inner = dotted(a.slice)
        if inner in SCALAR_ANNOTS:
            return inner
    if isinstance(a, ast.BinOp) and isinstance(a.op, ast.BitOr):
        for side in (a.left, a.right):
            d = dotted(side)
            if d in SCALAR_ANNOTS:
                return d
    return None


def _scalar_default(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value,
                                                     (int, bool, str)) \
            and not isinstance(node.value, float) and node.value is not None:
        return type(node.value).__name__
    return None


def run(index: RepoIndex) -> List[Violation]:
    out: List[Violation] = []
    roots = find_trace_roots(index)

    # --- retrace-scalar-arg ------------------------------------------------
    seen: Set[str] = set()
    for root in roots:
        if root.kind != "jit":
            continue  # pallas/shard_map have their own argument regimes
        fi = index.func(root.ref)
        if fi is None or not isinstance(fi.node, (ast.FunctionDef,
                                                  ast.AsyncFunctionDef)):
            continue
        mi = fi.module
        sym = fi.qualname.split(":", 1)[1]
        a = fi.node.args
        params = [*a.posonlyargs, *a.args]
        names = [p.arg for p in params]
        if names and names[0] == "self":
            params, names = params[1:], names[1:]
        static = set(root.static_names)
        for i in root.static_nums:
            if 0 <= i < len(names):
                static.add(names[i])
        # defaults align to the tail of params
        defaults: List[Optional[ast.AST]] = \
            [None] * (len(params) - len(a.defaults)) + list(a.defaults)
        for p, dflt in zip(params, defaults):
            if p.arg in static:
                continue
            why = _scalar_annotation(p)
            if why is None and dflt is not None:
                why = _scalar_default(dflt)
            if why is None:
                continue
            key = f"{root.ref}:{p.arg}"
            if key in seen:
                continue
            seen.add(key)
            sup = mi.suppressed(p.lineno)
            if "retrace-scalar-arg" in sup or "*" in sup:
                continue
            out.append(Violation(
                rule="retrace-scalar-arg", path=mi.path, line=p.lineno,
                symbol=sym,
                message=(f"jit parameter `{p.arg}: {why}` is not in "
                         "static_argnums/static_argnames — every new value "
                         "is a potential retrace; mark it static or pass "
                         "an array"),
                detail=p.arg))

    # --- retrace-scalar-flow ----------------------------------------------
    jitted_names: Set[str] = set()
    for root in roots:
        fi = index.func(root.ref)
        if fi is not None:
            jitted_names.add(fi.name)
    # names jit results are bound to: f = jax.jit(g) / self._step = jax.jit(...)
    for mi in index.modules.values():
        for node in ast.walk(mi.tree):
            if isinstance(node, ast.Assign) and isinstance(node.value,
                                                           ast.Call):
                d = dotted(node.value.func)
                if d in ("jax.jit", "jit"):
                    for t in node.targets:
                        td = dotted(t)
                        if td:
                            jitted_names.add(td.split(".")[-1])
    for mi in index.modules.values():
        for node in ast.walk(mi.tree):
            if not isinstance(node, ast.Call):
                continue
            d = dotted(node.func)
            if d is None or d.split(".")[-1] not in jitted_names:
                continue
            for arg in node.args:
                bad = None
                if isinstance(arg, ast.Call):
                    ad = dotted(arg.func)
                    if ad in ("len", "int"):
                        bad = f"{ad}(...)"
                    elif isinstance(arg.func, ast.Attribute) \
                            and arg.func.attr == "item":
                        bad = ".item()"
                if bad is None:
                    continue
                sup = mi.suppressed(node.lineno)
                if "retrace-scalar-flow" in sup or "*" in sup:
                    continue
                sym_fn = None
                cur = node
                from .common import parents as _parents
                for p in _parents(node):
                    if isinstance(p, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                        cls = None
                        for q in _parents(p):
                            if isinstance(q, ast.ClassDef):
                                cls = q.name
                                break
                        sym_fn = f"{cls}.{p.name}" if cls else p.name
                        break
                out.append(Violation(
                    rule="retrace-scalar-flow", path=mi.path,
                    line=node.lineno, symbol=sym_fn or "<module>",
                    message=(f"runtime scalar `{bad}` flows positionally "
                             f"into jitted `{d}` — pad to a static shape "
                             "or mark the parameter static"),
                    detail=f"{d.split('.')[-1]}:{bad}"))

    # --- retrace-pad-registry ----------------------------------------------
    for suffix, local, needle, reason in PAD_REGISTRY:
        hit_module = None
        for mi in index.modules.values():
            if mi.path.endswith(suffix):
                hit_module = mi
                break
        if hit_module is None:
            continue  # file moved: the baseline ratchet will catch churn
        fi = hit_module.functions.get(local)
        if fi is None:
            out.append(Violation(
                rule="retrace-pad-registry", path=hit_module.path, line=1,
                symbol=local,
                message=(f"pad-registry function `{local}` not found in "
                         f"{suffix} — {reason}"),
                detail=f"{local}:missing"))
            continue
        seg = ast.get_source_segment(
            "\n".join(hit_module.source_lines), fi.node)
        if seg is None:
            start = fi.node.lineno - 1
            end = getattr(fi.node, "end_lineno", start + 1)
            seg = "\n".join(hit_module.source_lines[start:end])
        if needle not in seg:
            out.append(Violation(
                rule="retrace-pad-registry", path=hit_module.path,
                line=fi.node.lineno, symbol=local,
                message=(f"`{local}` lost its `{needle}` padding idiom — "
                         f"{reason}"),
                detail=f"{local}:{needle}"))
    return out

"""Pass 1 — trace purity.

Walks the call graph rooted at every function handed to ``jax.jit`` /
``shard_map`` / ``pallas_call`` and flags Python-side nondeterminism or
state inside the traced region.  This is the contract ``sampling/rng.py``'s
counter-based RNG exists to uphold: everything a trace observes must be a
pure function of its (traced or static) inputs, or retraces silently produce
different programs than the one the tests blessed.

Rules
-----
``trace-nondeterminism``
    ``random.*``, unseeded ``np.random.*``, ``time.*`` (incl. ``sleep``),
    ``datetime.now``/``utcnow``, ``uuid.*``, ``os.urandom`` anywhere in the
    traced call graph.
``trace-global-state``
    ``global`` / ``nonlocal`` declarations inside traced functions.
``trace-self-mutation``
    assignment / augmented-assignment to ``self.<attr>`` inside a traced
    method — traced code runs once per compilation, not once per step, so
    instance state mutated here is a correctness bug.
``trace-mutation``
    mutating method calls (``append``/``update``/``pop``/...) on names not
    bound locally in the function — closed-over mutable state.
``trace-host-branch``
    ``if``/``while`` tests that name a root parameter which is not listed in
    ``static_argnums``/``static_argnames`` (root functions only: deeper in
    the graph we can't tell tracers from Python values without type
    inference, and the root signature is where the hazard enters).
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from .common import (FuncInfo, ModuleInfo, RepoIndex, TraceRoot, Violation,
                     dotted, find_trace_roots, parents)

NONDET_CALLS = {
    "random.random", "random.randint", "random.randrange", "random.choice",
    "random.choices", "random.shuffle", "random.sample", "random.uniform",
    "random.gauss", "random.normalvariate", "random.getrandbits",
    "np.random.rand", "np.random.randn", "np.random.randint",
    "np.random.random", "np.random.choice", "np.random.permutation",
    "np.random.shuffle", "np.random.uniform", "np.random.normal",
    "numpy.random.rand", "numpy.random.randn", "numpy.random.randint",
    "numpy.random.random", "numpy.random.choice", "numpy.random.permutation",
    "time.time", "time.perf_counter", "time.monotonic", "time.sleep",
    "time.process_time", "time.time_ns", "time.perf_counter_ns",
    "datetime.now", "datetime.utcnow", "datetime.datetime.now",
    "datetime.datetime.utcnow", "uuid.uuid4", "uuid.uuid1", "os.urandom",
}

MUTATING_METHODS = {
    "append", "extend", "insert", "add", "update", "pop", "popitem",
    "clear", "remove", "discard", "setdefault", "sort", "reverse",
}

# names whose use in a branch test never forces a host read of a tracer
_BRANCH_SAFE_CALLS = {"isinstance", "len", "hasattr", "getattr", "callable",
                      "issubclass", "type"}


def _local_bindings(fn: ast.AST) -> Set[str]:
    """Names bound inside the function (params, assigns, for, with, comps)."""
    out: Set[str] = set()
    node = fn
    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
        a = node.args
        for arg in (*a.posonlyargs, *a.args, *a.kwonlyargs):
            out.add(arg.arg)
        if a.vararg:
            out.add(a.vararg.arg)
        if a.kwarg:
            out.add(a.kwarg.arg)
    for n in ast.walk(node):
        if isinstance(n, ast.Name) and isinstance(n.ctx, (ast.Store,)):
            out.add(n.id)
        elif isinstance(n, (ast.For, ast.AsyncFor)):
            for t in ast.walk(n.target):
                if isinstance(t, ast.Name):
                    out.add(t.id)
        elif isinstance(n, ast.comprehension):
            for t in ast.walk(n.target):
                if isinstance(t, ast.Name):
                    out.add(t.id)
        elif isinstance(n, ast.withitem) and n.optional_vars is not None:
            for t in ast.walk(n.optional_vars):
                if isinstance(t, ast.Name):
                    out.add(t.id)
    return out


def _root_param_names(fi: FuncInfo) -> List[str]:
    node = fi.node
    if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
        return []
    a = node.args
    names = [arg.arg for arg in (*a.posonlyargs, *a.args)]
    if names and names[0] == "self":
        names = names[1:]
    return names


def _branch_names(test: ast.AST) -> Set[str]:
    """Bare names read in a branch test, minus safe-call arguments and
    `x is None` patterns (shape/None dispatch is static by construction)."""
    skip: Set[int] = set()
    for n in ast.walk(test):
        if isinstance(n, ast.Call):
            fname = dotted(n.func)
            if fname in _BRANCH_SAFE_CALLS:
                for sub in ast.walk(n):
                    skip.add(id(sub))
        elif isinstance(n, ast.Compare) and any(
                isinstance(op, (ast.Is, ast.IsNot)) for op in n.ops):
            for sub in ast.walk(n):
                skip.add(id(sub))
        elif isinstance(n, ast.Attribute):
            # obj.shape / obj.ndim / cfg.flag — attribute reads are either
            # static metadata or config, not a tracer-value read
            for sub in ast.walk(n):
                skip.add(id(sub))
    out: Set[str] = set()
    for n in ast.walk(test):
        if (isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)
                and id(n) not in skip):
            out.add(n.id)
    return out


def _check_function(fi: FuncInfo, mi: ModuleInfo,
                    root: Optional[TraceRoot]) -> List[Violation]:
    out: List[Violation] = []
    fn = fi.node
    local = _local_bindings(fn)
    sym = fi.qualname.split(":", 1)[1]

    def emit(rule: str, line: int, msg: str, detail: str) -> None:
        if rule in mi.suppressed(line) or "*" in mi.suppressed(line):
            return
        out.append(Violation(rule=rule, path=mi.path, line=line,
                             symbol=sym, message=msg, detail=detail))

    for node in ast.walk(fn):
        # don't descend into nested defs here; they are separate graph nodes
        if node is not fn and isinstance(
                node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if isinstance(node, (ast.Global, ast.Nonlocal)):
            emit("trace-global-state", node.lineno,
                 f"`{type(node).__name__.lower()} {', '.join(node.names)}` "
                 "inside a traced function",
                 ",".join(node.names))
        elif isinstance(node, ast.Call):
            d = dotted(node.func)
            if d is None:
                continue
            # normalize through the import map's first component
            norm = d
            head = d.split(".")[0]
            imp = mi.imports.get(head)
            if imp is not None:
                norm = imp + d[len(head):]
            if d in NONDET_CALLS or norm in NONDET_CALLS:
                emit("trace-nondeterminism", node.lineno,
                     f"call to nondeterministic `{d}` in traced code — use "
                     "the counter-based RNG (sampling/rng.py) instead",
                     d)
            elif (isinstance(node.func, ast.Attribute)
                  and node.func.attr in MUTATING_METHODS
                  and isinstance(getattr(node, "_gns_parent", None),
                                 ast.Expr)):
                # result-discarded call: the stdlib mutators return None, so
                # a bare `x.update(...)` statement is mutation — while
                # `new = opt.update(...)` is the pure-functional idiom
                base = dotted(node.func.value)
                if base is not None and base.split(".")[0] not in local \
                        and not base.startswith("self."):
                    emit("trace-mutation", node.lineno,
                         f"mutating call `{d}()` on non-local `{base}` — "
                         "closed-over mutable state in a traced region",
                         d)
        elif isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                td = dotted(t)
                if td is not None and td.startswith("self.") \
                        and td.count(".") == 1:
                    emit("trace-self-mutation", node.lineno,
                         f"write to `{td}` inside traced code runs once per "
                         "compile, not once per step",
                         td)

    # host branching on non-static root params (roots only)
    if root is not None and root.kind == "jit":
        params = _root_param_names(fi)
        static = set(root.static_names)
        for i in root.static_nums:
            if 0 <= i < len(params):
                static.add(params[i])
        dyn = set(params) - static
        for node in ast.walk(fn):
            if node is not fn and isinstance(
                    node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if isinstance(node, (ast.If, ast.While)):
                hit = _branch_names(node.test) & dyn
                for name in sorted(hit):
                    emit("trace-host-branch", node.lineno,
                         f"`if {name}: ...` branches on jit parameter "
                         f"`{name}` — mark it static_argnames or use "
                         "`jnp.where`/`lax.cond`",
                         name)
    return out


def run(index: RepoIndex) -> List[Violation]:
    roots = find_trace_roots(index)
    by_ref = {}
    for r in roots:
        by_ref.setdefault(r.ref, r)
    reachable = index.reachable([r.ref for r in roots])
    out: List[Violation] = []
    seen_keys: Set[str] = set()
    for ref in sorted(reachable):
        fi = index.func(ref)
        if fi is None:
            continue
        for v in _check_function(fi, fi.module, by_ref.get(ref)):
            k = v.key() + f"@{v.line}"
            if k not in seen_keys:
                seen_keys.add(k)
                out.append(v)
    return out

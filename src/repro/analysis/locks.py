"""Pass 2 — lock discipline.

Reads the ``@guarded_by(...)`` registry (see ``repro.analysis.runtime``) off
class decorators and proves, per annotated class, that every read/write of a
guarded attribute is dominated by ``with self.<lock>`` — but only for code
that can actually race: methods reachable from a
``threading.Thread(target=...)`` entry point, or from public methods of a
class that owns such a thread (the client-facing half of the race).

Rules
-----
``lock-unguarded-write``
    ``self.<attr> = ...`` outside ``with self.<lock>`` for a guarded attr.
``lock-unguarded-read``
    a load of a read/write-guarded attr outside the lock.
``lock-external-access``
    ``obj.<attr>`` where ``obj`` is an instance of an annotated class and
    the access is not under ``with obj.<lock>`` (same base expression).

``__init__`` is exempt (construction happens-before publication).  Methods
decorated ``@holds_lock("<lock>")`` are treated as lock-dominated bodies.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from .common import ModuleInfo, RepoIndex, Violation, dotted, parents


@dataclasses.dataclass
class GuardedClass:
    module: ModuleInfo
    cls_name: str
    node: ast.ClassDef
    lock_of: Dict[str, Tuple[str, str]]   # attr -> (lock_name, "rw"|"w")


def _parse_guarded(index: RepoIndex) -> List[GuardedClass]:
    out: List[GuardedClass] = []
    for mi in index.modules.values():
        for node in ast.walk(mi.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            lock_of: Dict[str, Tuple[str, str]] = {}
            for dec in node.decorator_list:
                if not isinstance(dec, ast.Call):
                    continue
                d = dotted(dec.func)
                if d is None or d.split(".")[-1] != "guarded_by":
                    continue
                if not dec.args or not isinstance(dec.args[0], ast.Constant):
                    continue
                lock_name = dec.args[0].value
                for a in dec.args[1:]:
                    if isinstance(a, ast.Constant) and isinstance(a.value,
                                                                  str):
                        lock_of[a.value] = (lock_name, "rw")
                for kw in dec.keywords:
                    if kw.arg == "writes_only" and isinstance(
                            kw.value, (ast.Tuple, ast.List)):
                        for el in kw.value.elts:
                            if isinstance(el, ast.Constant):
                                lock_of[el.value] = (lock_name, "w")
            if lock_of:
                out.append(GuardedClass(module=mi, cls_name=node.name,
                                        node=node, lock_of=lock_of))
    return out


# ---------------------------------------------------------------------------
# thread reachability
# ---------------------------------------------------------------------------

def _thread_entry_refs(index: RepoIndex) -> List[str]:
    """Functions passed as ``target=`` to ``threading.Thread``."""
    out: List[str] = []
    for mi in index.modules.values():
        for node in ast.walk(mi.tree):
            if not isinstance(node, ast.Call):
                continue
            d = dotted(node.func)
            if d is None or d.split(".")[-1] != "Thread":
                continue
            for kw in node.keywords:
                if kw.arg != "target":
                    continue
                td = dotted(kw.value)
                if td is None:
                    # lambda / nested closure target: the enclosing function
                    # is the effective entry point
                    for p in parents(node):
                        if isinstance(p, (ast.FunctionDef,
                                          ast.AsyncFunctionDef)):
                            for local, fi in mi.functions.items():
                                if fi.node is p:
                                    out.append(f"{mi.name}:{local}")
                            break
                    continue
                if td.startswith("self."):
                    meth = td[len("self."):]
                    for local in mi.functions:
                        if local.endswith("." + meth):
                            out.append(f"{mi.name}:{local}")
                else:
                    r = index.resolve(mi, td)
                    if r is None and "." not in td:
                        # nested entry point: Thread(target=_run) where
                        # `_run` is a def local to the enclosing method
                        for p in parents(node):
                            if isinstance(p, (ast.FunctionDef,
                                              ast.AsyncFunctionDef)):
                                for local, fi in mi.functions.items():
                                    if fi.node is p:
                                        cand = f"{local}.{td}"
                                        if cand in mi.functions:
                                            r = f"{mi.name}:{cand}"
                                        break
                                if r:
                                    break
                    if r:
                        out.append(r)
    return out


def _racy_classes(index: RepoIndex,
                  guarded: List[GuardedClass]) -> Set[Tuple[str, str]]:
    """(module, class) pairs whose guarded state is touched from a spawned
    thread — plus classes that spawn a thread themselves (their public
    methods are the other side of the race)."""
    entries = _thread_entry_refs(index)
    reach = index.reachable(entries, unique_name_fallback=True)
    racy: Set[Tuple[str, str]] = set()
    for gc in guarded:
        prefix = f"{gc.module.name}:{gc.cls_name}."
        # a method of the class is thread-reachable
        if any(r.startswith(prefix) for r in reach):
            racy.add((gc.module.name, gc.cls_name))
            continue
        # the class itself spawns threads
        for node in ast.walk(gc.node):
            if isinstance(node, ast.Call):
                d = dotted(node.func)
                if d is not None and d.split(".")[-1] == "Thread":
                    racy.add((gc.module.name, gc.cls_name))
                    break
        # or a guarded attr of it is read by thread-reachable code elsewhere
        if (gc.module.name, gc.cls_name) not in racy:
            for ref in reach:
                fi = index.func(ref)
                if fi is None:
                    continue
                for n in ast.walk(fi.node):
                    if (isinstance(n, ast.Attribute)
                            and n.attr in gc.lock_of):
                        racy.add((gc.module.name, gc.cls_name))
                        break
                else:
                    continue
                break
        # or its guarded attrs are touched from a module that spawns threads
        # (the client-facing half of a race: GNSServer.submit bumping
        # ServeMeter counters from arbitrary caller threads)
        if (gc.module.name, gc.cls_name) not in racy:
            for mi in index.modules.values():
                spawns = any(
                    isinstance(n, ast.Call)
                    and (dotted(n.func) or "").split(".")[-1] == "Thread"
                    for n in ast.walk(mi.tree))
                if not spawns:
                    continue
                if mi is gc.module or any(
                        isinstance(n, ast.Attribute)
                        and n.attr in gc.lock_of
                        for n in ast.walk(mi.tree)):
                    racy.add((gc.module.name, gc.cls_name))
                    break
    return racy


# ---------------------------------------------------------------------------
# dominance
# ---------------------------------------------------------------------------

def _under_lock(node: ast.AST, base: str, lock_name: str) -> bool:
    """Is ``node`` inside ``with <base>.<lock_name>`` (any ancestor)?"""
    want = f"{base}.{lock_name}"
    for p in parents(node):
        if isinstance(p, (ast.With, ast.AsyncWith)):
            for item in p.items:
                d = dotted(item.context_expr)
                if d == want:
                    return True
                # with self._lock: ... / cond-acquire helpers like
                # self._lock.acquire() are not with-items; only exact match
                if isinstance(item.context_expr, ast.Call):
                    dd = dotted(item.context_expr.func)
                    if dd == want:       # e.g. contextmanager wrapper
                        return True
    return False


def _method_holds(fn: ast.AST, lock_name: str) -> bool:
    if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
        return False
    for dec in fn.decorator_list:
        if isinstance(dec, ast.Call):
            d = dotted(dec.func)
            if d and d.split(".")[-1] == "holds_lock" and dec.args \
                    and isinstance(dec.args[0], ast.Constant) \
                    and dec.args[0].value == lock_name:
                return True
    return False


def _enclosing_function(node: ast.AST) -> Optional[ast.AST]:
    for p in parents(node):
        if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            return p
    return None


def run(index: RepoIndex) -> List[Violation]:
    guarded = _parse_guarded(index)
    racy = _racy_classes(index, guarded)
    out: List[Violation] = []
    attr_owner: Dict[str, List[GuardedClass]] = {}
    for gc in guarded:
        for attr in gc.lock_of:
            attr_owner.setdefault(attr, []).append(gc)

    # (a) self-access inside annotated classes ------------------------------
    for gc in guarded:
        if (gc.module.name, gc.cls_name) not in racy:
            continue
        mi = gc.module
        for node in ast.walk(gc.node):
            if not isinstance(node, ast.Attribute):
                continue
            if node.attr not in gc.lock_of:
                continue
            if dotted(node) != f"self.{node.attr}":
                continue
            lock_name, mode = gc.lock_of[node.attr]
            fn = _enclosing_function(node)
            if fn is None:
                continue
            fn_name = getattr(fn, "name", "<lambda>")
            if fn_name in ("__init__", "__post_init__", "__repr__"):
                continue
            is_write = isinstance(node.ctx, (ast.Store, ast.Del)) or (
                isinstance(getattr(node, "_gns_parent", None), ast.AugAssign)
                and getattr(node, "_gns_parent").target is node)
            if mode == "w" and not is_write:
                continue
            if _under_lock(node, "self", lock_name):
                continue
            if _method_holds(fn, lock_name):
                continue
            sym = f"{gc.cls_name}.{fn_name}"
            rule = ("lock-unguarded-write" if is_write
                    else "lock-unguarded-read")
            if rule in mi.suppressed(node.lineno) \
                    or "*" in mi.suppressed(node.lineno):
                continue
            out.append(Violation(
                rule=rule, path=mi.path, line=node.lineno, symbol=sym,
                message=(f"{'write to' if is_write else 'read of'} "
                         f"`self.{node.attr}` (guarded by `{lock_name}`) "
                         f"outside `with self.{lock_name}`"),
                detail=node.attr))

    # (b) external access: obj.<guardedattr> outside `with obj.<lock>` ------
    guarded_attr_names = set(attr_owner)
    for mi in index.modules.values():
        for node in ast.walk(mi.tree):
            if not isinstance(node, ast.Attribute):
                continue
            if node.attr not in guarded_attr_names:
                continue
            d = dotted(node)
            if d is None or d == f"self.{node.attr}":
                continue  # self-access handled above (or unresolvable base)
            base = d[: -(len(node.attr) + 1)]
            # only flag when the base *name* matches an annotated class's
            # known instance spelling would be unsound; instead require the
            # attr be unique to annotated classes AND the base look like an
            # instance (skip module-level constants and cls refs)
            owners = attr_owner[node.attr]
            if len({(gc.module.name, gc.cls_name) for gc in owners}) != 1:
                continue
            gc = owners[0]
            if (gc.module.name, gc.cls_name) not in racy:
                continue
            lock_name, mode = gc.lock_of[node.attr]
            fn = _enclosing_function(node)
            if fn is None:
                continue  # module top level: import-time, single-threaded
            # same-class private use via another instance name is still code
            # inside the annotated class — keep; tests are excluded by scan
            # root anyway
            is_write = isinstance(node.ctx, (ast.Store, ast.Del))
            if mode == "w" and not is_write:
                continue
            if _under_lock(node, base, lock_name):
                continue
            if _method_holds(fn, lock_name):
                continue
            fn_name = getattr(fn, "name", "<lambda>")
            if fn_name in ("__init__", "__repr__"):
                continue
            rule = ("lock-unguarded-write" if is_write
                    else "lock-unguarded-read")
            if rule in mi.suppressed(node.lineno) \
                    or "*" in mi.suppressed(node.lineno):
                continue
            # locate enclosing class for the symbol, if any
            cls = None
            for p in parents(node):
                if isinstance(p, ast.ClassDef):
                    cls = p.name
                    break
            sym = f"{cls}.{fn_name}" if cls else fn_name
            out.append(Violation(
                rule=rule, path=mi.path, line=node.lineno, symbol=sym,
                message=(f"{'write to' if is_write else 'read of'} "
                         f"`{d}` (guarded by `{gc.cls_name}.{lock_name}`) "
                         f"outside `with {base}.{lock_name}`"),
                detail=f"{base}.{node.attr}"))
    return out
